//! Chain-scalability study: the paper's headline comparison as a single
//! runnable — dd throughput, memory footprint and lookup cost for both
//! drivers across chain lengths (a compact Fig 10+12+15 sweep).
//!
//!     cargo run --release --example chain_scalability

use sqemu::bench::figures::{run_pair, ExpConfig};
use sqemu::guest::dd::Dd;
use sqemu::guest::Workload;
use sqemu::qcow::image::DataMode;
use sqemu::util::human_ns;

fn main() -> anyhow::Result<()> {
    println!(
        "{:>6} | {:>10} {:>10} | {:>9} {:>9} | {:>10} {:>10}",
        "chain", "vq MiB/s", "sq MiB/s", "vq MiB", "sq MiB", "vq lookup", "sq lookup"
    );
    println!("{}", "-".repeat(78));
    for chain_len in [1usize, 10, 25, 50, 100, 200] {
        let cfg = ExpConfig {
            disk_size: 2 << 30,
            chain_len,
            populated: 0.9,
            data_mode: DataMode::Synthetic,
            ..Default::default()
        };
        let (v, s) = run_pair(&cfg, || Box::new(Dd::default()) as Box<dyn Workload>)?;
        println!(
            "{:>6} | {:>10.1} {:>10.1} | {:>9.1} {:>9.1} | {:>10} {:>10}",
            chain_len,
            v.stats.throughput_bps() / (1 << 20) as f64,
            s.stats.throughput_bps() / (1 << 20) as f64,
            v.mem_peak as f64 / (1 << 20) as f64,
            s.mem_peak as f64 / (1 << 20) as f64,
            human_ns(v.lookup_hist.mean() as u64),
            human_ns(s.lookup_hist.mean() as u64),
        );
    }
    println!(
        "\nvanilla degrades in every column as the chain grows; sqemu stays flat \
         (§4 problem, §5 fix, §6 evaluation)."
    );
    Ok(())
}
