//! Cloud trace replay: drive a *real* chain through a year of the §3
//! population model's snapshot schedule — client snapshots (kept),
//! provider snapshots (mergeable), streaming at the threshold — and
//! measure what the guest feels before/after under both drivers.
//!
//!     cargo run --release --example cloud_trace_replay

use sqemu::cache::CacheConfig;
use sqemu::chaingen::{generate, ChainSpec};
use sqemu::guest::fio::Fio;
use sqemu::guest::Workload;
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::memory::MemoryAccountant;
use sqemu::qcow::image::DataMode;
use sqemu::qcow::{qcheck, snapshot, Chain};
use sqemu::storage::node::StorageNode;
use sqemu::util::human_ns;
use sqemu::util::rng::Rng;
use sqemu::vdisk::scalable::ScalableDriver;
use sqemu::vdisk::Driver;

const STREAM_THRESHOLD: usize = 30;

fn main() -> anyhow::Result<()> {
    let clock = VirtClock::new();
    let node = StorageNode::new("nfs", clock.clone(), CostModel::default());

    // a daily-snapshot, backup-style chain (the take-away-4 profile that
    // grows long), starting from a 5-file base image
    let mut chain = generate(
        &node,
        &ChainSpec {
            disk_size: 512 << 20,
            chain_len: 5,
            populated: 0.4,
            stamped: true,
            data_mode: DataMode::Synthetic,
            prefix: "trace".into(),
            ..Default::default()
        },
    )?;
    let mut rng = Rng::new(0x7AACE);
    let mut snaps = 0u64;
    let mut streams = 0u64;
    let mut mergeable: Vec<u16> = Vec::new();
    let mut next_file = 5usize;

    println!("replaying 365 days of snapshot schedule (daily client, keep 70%)...");
    for day in 0..365 {
        // the guest writes a little every day
        let img = chain.active();
        for _ in 0..8 {
            let vc = rng.below(img.geom().num_vclusters());
            let off = img.alloc_data_cluster()?;
            img.set_l2_entry(
                vc,
                sqemu::qcow::entry::L2Entry::local(off, Some(img.chain_index())),
            )?;
        }
        // daily snapshot; 30% get deleted by the client later -> mergeable
        let name = format!("trace-{next_file}");
        next_file += 1;
        snapshot::snapshot_sqemu(&mut chain, &node, &name)?;
        snaps += 1;
        if rng.chance(0.3) {
            mergeable.push((chain.len() - 2) as u16);
        }
        // provider streaming at the threshold: merge the oldest mergeable
        // run (client-kept snapshots survive, §3)
        if chain.len() >= STREAM_THRESHOLD && mergeable.len() >= 2 {
            let from = mergeable[0];
            let to = *mergeable.last().unwrap();
            let contiguous = mergeable.len() as u16 == to - from + 1;
            if contiguous {
                let copied = snapshot::stream_merge(&mut chain, from, to)?;
                streams += 1;
                mergeable.clear();
                if day % 90 == 0 {
                    println!(
                        "  day {day:>3}: streamed {from}..={to} ({copied} clusters), \
                         chain now {}",
                        chain.len()
                    );
                }
            } else {
                // merge just the first contiguous pair
                let to = mergeable[1];
                if mergeable[1] == mergeable[0] + 1 {
                    snapshot::stream_merge(&mut chain, mergeable[0], to)?;
                    streams += 1;
                }
                mergeable.remove(0);
            }
        }
    }
    println!(
        "\nafter a year: {snaps} snapshots, {streams} streaming merges, final \
         chain length {}",
        chain.len()
    );
    let report = qcheck::check_chain(&chain)?;
    anyhow::ensure!(report.is_clean(), "chain corrupt: {:?}", report.errors);
    println!("qcheck: clean ({} clusters)", report.ok_clusters);

    // what does the guest feel on this aged chain?
    let active = chain.active().name.clone();
    for kind in ["sqemu"] {
        let chain = Chain::open(&node, &active, DataMode::Synthetic)?;
        let mut d = ScalableDriver::new(
            chain,
            CacheConfig::default(),
            clock.clone(),
            CostModel::default(),
            MemoryAccountant::new(),
        );
        let stats = Fio { io_size: 4 << 10, ops: 5_000, seed: 9 }.run(&mut d, &clock)?;
        println!(
            "{kind} on the aged chain: {:.1} MiB/s random 4K, mean lookup {}",
            stats.throughput_bps() / (1 << 20) as f64,
            human_ns(d.lookup_latency().mean() as u64)
        );
    }
    Ok(())
}
