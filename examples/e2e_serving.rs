//! END-TO-END DRIVER: the full system on a realistic small workload.
//!
//! A coordinator with three storage nodes serves a mixed fleet:
//!  * two SQEMU VMs and one vanilla VM on 60-snapshot chains;
//!  * concurrent client threads issue batched read/write requests;
//!  * mid-run the control plane takes a live snapshot of every VM and
//!    stream-merges one chain window;
//!  * the bulk PJRT path (boot prefetch planning) runs against a live
//!    chain.
//!
//! Reports per-VM throughput/latency (virtual time), fleet wall-clock
//! throughput, low-level cache counters and the memory account — the
//! numbers recorded in EXPERIMENTS.md §E2E.
//!
//!     make artifacts && cargo run --release --example e2e_serving

use sqemu::cache::CacheConfig;
use sqemu::chaingen::ChainSpec;
use sqemu::coordinator::server::VmChain;
use sqemu::coordinator::{Coordinator, VmConfig};
use sqemu::qcow::image::DataMode;
use sqemu::qcow::Chain;
use sqemu::util::rng::Rng;
use sqemu::util::{human_bytes, human_ns};
use sqemu::vdisk::DriverKind;
use std::time::Instant;

const DISK: u64 = 1 << 30;
const CHAIN_LEN: usize = 60;
const REQUESTS_PER_CLIENT: u64 = 4_000;
const CLIENTS_PER_VM: usize = 2;

fn main() -> anyhow::Result<()> {
    let coord = Coordinator::with_fresh_nodes(3)?;
    let fleet = [
        ("vm-sq-0", DriverKind::Scalable),
        ("vm-sq-1", DriverKind::Scalable),
        ("vm-vq-0", DriverKind::Vanilla),
    ];
    println!("== launch: {} VMs on chains of {CHAIN_LEN} ==", fleet.len());
    for (i, (name, kind)) in fleet.iter().enumerate() {
        let t0 = Instant::now();
        coord.launch_vm(
            name,
            VmConfig {
                driver: *kind,
                cache: CacheConfig::new(512, 2 << 20),
                chain: VmChain::Generate(ChainSpec {
                    disk_size: DISK,
                    chain_len: CHAIN_LEN,
                    populated: 0.5,
                    stamped: *kind == DriverKind::Scalable,
                    data_mode: DataMode::Synthetic,
                    prefix: name.to_string(),
                    seed: 0xE2E ^ i as u64,
                    ..Default::default()
                }),
            },
        )?;
        println!("  {name} ({}) up in {:?}", kind.name(), t0.elapsed());
    }

    // bulk PJRT path: boot-prefetch plan for vm-sq-0's chain
    let chain = Chain::open(
        coord.nodes.as_ref(),
        &format!("vm-sq-0-{}", CHAIN_LEN - 1),
        DataMode::Synthetic,
    )?;
    let bt = coord.translator();
    let plan = bt.prefetch_plan(&chain, 4096)?;
    println!(
        "\n== bulk translation ({}) ==\nboot-prefetch plan: {} of the first 4096 \
         clusters resolve to backing files",
        if bt.is_accelerated() { "PJRT artifacts" } else { "host fallback" },
        plan.len()
    );

    // serve: concurrent clients against every VM
    println!("\n== serving {REQUESTS_PER_CLIENT} reqs x {CLIENTS_PER_VM} clients per VM ==");
    let wall0 = Instant::now();
    let virt0 = coord.clock.now();
    let mut handles = vec![];
    for (name, _) in &fleet {
        for c in 0..CLIENTS_PER_VM {
            let client = coord.client(name)?;
            let name = name.to_string();
            handles.push(std::thread::spawn(move || -> anyhow::Result<()> {
                let mut rng = Rng::new(c as u64 ^ 0xC11E27);
                for i in 0..REQUESTS_PER_CLIENT {
                    let voff = rng.below(DISK - 8192);
                    if rng.chance(0.15) {
                        client.write(voff, vec![(i % 251) as u8; 1024])?;
                    } else {
                        client.read(voff, 4096)?;
                    }
                }
                let _ = name;
                Ok(())
            }));
        }
    }

    // control plane acts while the fleet serves: live snapshots + stream
    std::thread::sleep(std::time::Duration::from_millis(50));
    for (name, _) in &fleet {
        let ns = coord.snapshot_vm(name, &format!("{name}-live-snap"))?;
        println!("  live snapshot of {name}: {}", human_ns(ns));
    }
    let report = coord.stream_vm("vm-sq-1", 5, 15)?;
    println!(
        "  streamed vm-sq-1 files 5..=15: {} clusters moved, chain {} -> {}, {}",
        report.copied_clusters,
        report.len_before,
        report.len_after,
        human_ns(report.merge_ns)
    );

    for h in handles {
        h.join().unwrap()?;
    }
    let wall = wall0.elapsed();
    let virt = coord.clock.now() - virt0;

    println!("\n== results ==");
    let mut total_ops = 0u64;
    for (name, _) in &fleet {
        let s = coord.vm_stats(name)?;
        let c = coord.client(name)?.counters()?;
        let ops = s.reads + s.writes;
        total_ops += ops;
        println!(
            "  {name}: {ops} ops ({} read) | hits {} misses {} hit-unalloc {} | \
             snapshots {} streams {}",
            human_bytes(s.bytes_read),
            c.hits,
            c.misses,
            c.hit_unallocated,
            s.snapshots,
            s.streams
        );
    }
    println!(
        "\nfleet: {total_ops} ops | wall {:.2}s = {:.0} ops/s | virtual {} \
         (mean {} per op)",
        wall.as_secs_f64(),
        total_ops as f64 / wall.as_secs_f64(),
        human_ns(virt),
        human_ns(virt / total_ops.max(1))
    );
    println!("memory accounted across the fleet: {}", human_bytes(coord.acct.total()));
    println!("storage usage per node: {:?}", coord.nodes.usage()
        .iter().map(|(n, b)| format!("{n}={}", human_bytes(*b))).collect::<Vec<_>>());
    coord.shutdown();
    println!("\ne2e OK");
    Ok(())
}
