//! Quickstart: build a snapshot chain, read/write through both drivers,
//! and see the paper's effect in 60 lines.
//!
//!     cargo run --release --example quickstart

use sqemu::cache::CacheConfig;
use sqemu::chaingen::{generate, ChainSpec};
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::memory::MemoryAccountant;
use sqemu::qcow::image::DataMode;
use sqemu::qcow::Chain;
use sqemu::storage::node::StorageNode;
use sqemu::util::{human_bytes, human_ns};
use sqemu::vdisk::scalable::ScalableDriver;
use sqemu::vdisk::vanilla::VanillaDriver;
use sqemu::vdisk::Driver;

fn main() -> anyhow::Result<()> {
    // a simulated storage node with the paper's cost model (Eq. 1)
    let clock = VirtClock::new();
    let node = StorageNode::new("nfs-0", clock.clone(), CostModel::default());

    // a 1 GiB disk behind a chain of 40 snapshots, 60% populated,
    // SQEMU-formatted (bfi-stamped, L2-copied snapshots)
    let spec = ChainSpec {
        disk_size: 1 << 30,
        chain_len: 40,
        populated: 0.6,
        stamped: true,
        data_mode: DataMode::Synthetic,
        ..Default::default()
    };
    let chain = generate(&node, &spec)?;
    println!(
        "chain: {} files, active '{}', {} on disk",
        chain.len(),
        chain.active().name,
        human_bytes(chain.total_file_bytes())
    );

    // read the same 4 MiB through both drivers and compare costs
    for sqemu in [false, true] {
        let chain = Chain::open(&node, &spec.active_name(), DataMode::Synthetic)?;
        let acct = MemoryAccountant::new();
        let mut driver: Box<dyn Driver> = if sqemu {
            Box::new(ScalableDriver::new(
                chain,
                CacheConfig::default(),
                clock.clone(),
                CostModel::default(),
                acct.clone(),
            ))
        } else {
            Box::new(VanillaDriver::new(
                chain,
                CacheConfig::default(),
                clock.clone(),
                CostModel::default(),
                acct.clone(),
            ))
        };
        let mut buf = vec![0u8; 64 << 10];
        let t0 = clock.now();
        for i in 0..64u64 {
            driver.read(i * (16 << 20), &mut buf)?; // scattered reads
        }
        // COW write: cluster 0 moves into the active volume (synthetic
        // data mode stores no payload bytes; ownership is what matters)
        driver.write(123, b"hello snapshot chains")?;
        let (owner, _) = driver.chain().resolve_walk(0)?.expect("allocated");
        assert_eq!(owner as usize, driver.chain().len() - 1, "COW into active");
        let c = driver.counters();
        println!(
            "{:>7}: 64 reads in {:>10} | hits {:>4} misses {:>4} \
             hit-unallocated {:>5} | driver memory {}",
            driver.kind().name(),
            human_ns(clock.now() - t0),
            c.hits,
            c.misses,
            c.hit_unallocated,
            human_bytes(acct.total()),
        );
    }
    println!("\nsame bytes, very different cost — that is the paper in one run.");
    Ok(())
}
