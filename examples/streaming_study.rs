//! Streaming study (§4.1): what a backing-file merge costs and what it
//! buys — plan-vs-actual validation through the PJRT `stream_fold`
//! kernel, the guest-visible disruption window, and the before/after
//! chain-walk cost.
//!
//!     make artifacts && cargo run --release --example streaming_study

use sqemu::cache::CacheConfig;
use sqemu::chaingen::{generate, ChainSpec};
use sqemu::coordinator::streaming::StreamingOrchestrator;
use sqemu::guest::fio::Fio;
use sqemu::guest::Workload;
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::memory::MemoryAccountant;
use sqemu::qcow::image::DataMode;
use sqemu::qcow::Chain;
use sqemu::runtime::service::RuntimeService;
use sqemu::storage::node::StorageNode;
use sqemu::util::human_ns;
use sqemu::vdisk::vanilla::VanillaDriver;
use sqemu::vdisk::Driver;

fn fio_cost(node: &StorageNode, clock: &std::sync::Arc<VirtClock>, active: &str) -> anyhow::Result<(f64, f64)> {
    let chain = Chain::open(node, active, DataMode::Synthetic)?;
    let mut d = VanillaDriver::new(
        chain,
        CacheConfig::new(512, 256 << 10),
        clock.clone(),
        CostModel::default(),
        MemoryAccountant::new(),
    );
    let stats = Fio { io_size: 4 << 10, ops: 4_000, seed: 5 }.run(&mut d, clock)?;
    Ok((
        stats.throughput_bps() / (1 << 20) as f64,
        d.lookup_latency().mean(),
    ))
}

fn main() -> anyhow::Result<()> {
    let clock = VirtClock::new();
    let node = StorageNode::new("nfs", clock.clone(), CostModel::default());
    let mut chain = generate(
        &node,
        &ChainSpec {
            disk_size: 512 << 20,
            chain_len: 24,
            populated: 0.7,
            stamped: true,
            data_mode: DataMode::Synthetic,
            prefix: "st".into(),
            ..Default::default()
        },
    )?;
    let active = chain.active().name.clone();
    let (before_bps, before_lookup) = fio_cost(&node, &clock, &active)?;
    println!(
        "before streaming: chain {}, fio {:.1} MiB/s, mean lookup {}",
        chain.len(),
        before_bps,
        human_ns(before_lookup as u64)
    );

    let svc = RuntimeService::try_default();
    let accel = svc.is_some();
    let orch = StreamingOrchestrator::new(svc);
    println!(
        "\nplanning merges with {}...",
        if accel { "the PJRT stream_fold kernel" } else { "host kernels" }
    );
    // merge the mergeable middle of the chain in two windows
    for (from, to) in [(2u16, 10u16), (3, 8)] {
        let planned = orch.plan(&chain, from, to)?;
        let t0 = clock.now();
        let report = orch.merge(&mut chain, from, to)?;
        println!(
            "  window {from:>2}..={to:>2}: planned {planned:>6} clusters, copied \
             {:>6}, chain {} -> {}, disruption {}",
            report.copied_clusters,
            report.len_before,
            report.len_after,
            human_ns(clock.now() - t0)
        );
        assert_eq!(planned, report.copied_clusters, "plan != execution");
    }

    let (after_bps, after_lookup) = fio_cost(&node, &clock, &active)?;
    println!(
        "\nafter streaming: chain {}, fio {:.1} MiB/s ({:+.0}%), mean lookup {} \
         ({:+.0}%)",
        chain.len(),
        after_bps,
        100.0 * (after_bps - before_bps) / before_bps,
        human_ns(after_lookup as u64),
        100.0 * (after_lookup - before_lookup) / before_lookup,
    );
    println!(
        "\nstreaming shortens the walk for vanilla consumers but costs a pause \
         and cannot touch client-kept snapshots — the paper's motivation for \
         fixing the driver instead (§4.1, take-away 5)."
    );
    Ok(())
}
