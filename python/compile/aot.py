"""AOT export: lower every L2 graph to HLO *text* + a manifest for Rust.

HLO text (NOT serialized protos) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` 0.1.6 crate) rejects; the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --outdir ../artifacts
Writes ``<name>.hlo.txt`` per artifact plus ``manifest.json`` describing
shapes/dtypes so the Rust runtime can validate its buffers.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import ARTIFACTS, BATCH, CHAIN, CLUSTERS, STREAM_DEPTH


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_all(outdir: str) -> dict:
    os.makedirs(outdir, exist_ok=True)
    manifest = {
        "constants": {
            "batch": BATCH,
            "clusters": CLUSTERS,
            "chain": CHAIN,
            "stream_depth": STREAM_DEPTH,
            "unallocated": -1,
        },
        "artifacts": {},
    }
    for name, (fn, example_args) in ARTIFACTS.items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_tree = jax.eval_shape(fn, *example_args)
        flat_out = jax.tree_util.tree_leaves(out_tree)
        manifest["artifacts"][name] = {
            "file": os.path.basename(path),
            "inputs": [
                {"shape": list(a.shape), "dtype": str(a.dtype)}
                for a in example_args
            ],
            "outputs": [
                {"shape": list(o.shape), "dtype": str(o.dtype)}
                for o in flat_out
            ],
        }
        print(f"wrote {path} ({len(text)} chars, {len(flat_out)} outputs)")
    mpath = os.path.join(outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--outdir", default="../artifacts")
    # kept for Makefile back-compat; --out FILE means "outdir of FILE"
    p.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = p.parse_args()
    outdir = os.path.dirname(args.out) if args.out else args.outdir
    export_all(outdir or ".")


if __name__ == "__main__":
    main()
