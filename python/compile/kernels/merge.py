"""Layer-1 Pallas kernel: L2-table merge / cache correction (§5.3, §5.4).

The same precedence rule serves three paper operations:
  * cache correction — refreshing a unified-cache slice from an on-disk
    backing-file slice;
  * SQEMU snapshot creation — stamping the new active volume with the full
    L2 content of the previous one;
  * streaming — folding the tables of merged (deleted) backing files.

Rule: the entry from ``b`` wins iff ``bfi_v <= bfi_b`` (newer-or-equal
backing file index takes precedence; -1 = unallocated loses to anything).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Elementwise over clusters; 1024 i32s per block keeps VMEM use trivial and
# the grid long enough to pipeline HBM streams on real hardware.
BLOCK_C = 1024


def _merge_kernel(off_v_ref, bfi_v_ref, off_b_ref, bfi_b_ref,
                  out_off_ref, out_bfi_ref):
    bfi_v = bfi_v_ref[...]
    bfi_b = bfi_b_ref[...]
    take_b = bfi_v <= bfi_b
    out_off_ref[...] = jnp.where(take_b, off_b_ref[...], off_v_ref[...])
    out_bfi_ref[...] = jnp.where(take_b, bfi_b, bfi_v)


@functools.partial(jax.jit, static_argnames=("block_c",))
def merge_l2(off_v, bfi_v, off_b, bfi_b, *, block_c=BLOCK_C):
    """Merge slice ``b`` into slice ``v`` under the §5.3 precedence rule.

    All inputs are i32[c] with c % block_c == 0. Returns (off, bfi).
    """
    (c,) = off_v.shape
    grid = (c // block_c,)
    spec = pl.BlockSpec((block_c,), lambda i: (i,))
    return pl.pallas_call(
        _merge_kernel,
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((c,), jnp.int32),
            jax.ShapeDtypeStruct((c,), jnp.int32),
        ],
        interpret=True,
    )(off_v, bfi_v, off_b, bfi_b)
