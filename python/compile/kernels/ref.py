"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package
is checked against these functions by pytest/hypothesis at build time
(python/tests/test_kernel.py). Keep them dead simple — no pallas, no
cleverness.

Data model (shared with the Rust side, see rust/src/runtime/):
  * An L2 table is flattened per virtual disk into two i32 arrays of length
    ``num_clusters``:
      - ``off[c]``  : host cluster offset of virtual cluster ``c`` inside the
                      backing file that owns it, or -1 if unallocated.
      - ``bfi[c]``  : backing_file_index of the owning file (0 = base image,
                      increasing towards the active volume), or -1.
  * The vanilla (vQemu) driver has no ``bfi`` metadata; its view is a stack
    ``tables[n, c]`` of per-backing-file offset arrays (-1 = not present in
    that file) that must be walked from the active volume (n-1) downwards.
"""

import jax
import jax.numpy as jnp

UNALLOCATED = -1


def direct_translate_ref(off, bfi, vbs):
    """SQEMU direct access: one gather per request (§5.3).

    Returns ``(bfi[vbs], off[vbs])`` — the owning backing file and host
    cluster for each requested virtual cluster.
    """
    return jnp.take(bfi, vbs, axis=0), jnp.take(off, vbs, axis=0)


def chain_walk_translate_ref(tables, vbs):
    """vQemu chain walk: scan backing files from the active volume down.

    ``tables`` is ``i32[n, c]``; for each request the first file (highest
    index) holding the cluster wins. Returns ``(bfi, off)`` with -1/-1 when
    no file in the chain holds the cluster.
    """
    n = tables.shape[0]
    off0 = jnp.full(vbs.shape, UNALLOCATED, dtype=jnp.int32)
    bfi0 = jnp.full(vbs.shape, UNALLOCATED, dtype=jnp.int32)

    def body(i, carry):
        off, bfi = carry
        j = n - 1 - i
        t = jnp.take(tables[j], vbs, axis=0)
        found = (bfi == UNALLOCATED) & (t != UNALLOCATED)
        return (
            jnp.where(found, t, off),
            jnp.where(found, jnp.int32(j), bfi),
        )

    off, bfi = jax.lax.fori_loop(0, n, body, (off0, bfi0))
    return bfi, off


def merge_l2_ref(off_v, bfi_v, off_b, bfi_b):
    """Cache correction / L2 merge rule (§5.3, §5.4).

    The entry from slice ``b`` replaces the entry in slice ``v`` iff
    ``bfi_v <= bfi_b``. With the -1 unallocated sentinel this also covers
    "v unallocated, b allocated" (take b) and "both unallocated" (no-op).
    """
    take_b = bfi_v <= bfi_b
    return jnp.where(take_b, off_b, off_v), jnp.where(take_b, bfi_b, bfi_v)


def bfi_histogram_ref(bfi, num_files):
    """Per-backing-file lookup distribution (Fig 13c bulk path).

    Counts how many resolved requests land on each backing file index;
    index ``num_files`` accumulates unallocated (-1) results.
    """
    clipped = jnp.where(bfi == UNALLOCATED, num_files, bfi)
    return jnp.bincount(clipped, length=num_files + 1).astype(jnp.int32)
