"""Layer-1 Pallas kernels: batched virtual-cluster -> host-cluster resolution.

Two kernels implement the two driver designs the paper compares:

  * ``direct_translate``   — SQEMU (§5.3): the L2 entry already carries the
    ``backing_file_index`` of the owning file, so resolution is a single
    gather regardless of chain length. O(1) table traffic per request.
  * ``chain_walk_translate`` — vQemu baseline (§2, Fig 3): no ownership
    metadata; the kernel walks the chain from the active volume downwards
    with masked selects. O(N) table traffic per request — this asymmetry is
    exactly the scalability problem of §4 expressed at the kernel level.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the L2 table block is the
VMEM-resident analogue of the driver's slice cache; requests are tiled over
the grid; the chain walk is a ``fori_loop`` over chain depth (sequential HBM
block streams), not an unrolled loop. interpret=True everywhere — the CPU
PJRT plugin cannot run Mosaic custom-calls; real-TPU perf is estimated in
DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import UNALLOCATED

# Default block of requests resolved per grid step. 256 i32 lanes is a
# multiple of the 8x128 VPU tile; the table block dominates VMEM instead.
BLOCK_B = 256


def _direct_kernel(vb_ref, off_ref, bfi_ref, out_bfi_ref, out_off_ref):
    vb = vb_ref[...]
    table_off = off_ref[...]
    table_bfi = bfi_ref[...]
    out_off_ref[...] = jnp.take(table_off, vb, axis=0)
    out_bfi_ref[...] = jnp.take(table_bfi, vb, axis=0)


@functools.partial(jax.jit, static_argnames=("block_b",))
def direct_translate(off, bfi, vbs, *, block_b=BLOCK_B):
    """Resolve ``vbs`` against a unified L2 table (SQEMU direct access).

    Args:
      off:  i32[c] host cluster offsets (-1 unallocated).
      bfi:  i32[c] owning backing_file_index (-1 unallocated).
      vbs:  i32[b] requested virtual cluster indices, b % block_b == 0.
    Returns:
      (bfi_out, off_out): i32[b] each.
    """
    (b,) = vbs.shape
    (c,) = off.shape
    grid = (b // block_b,)
    return pl.pallas_call(
        _direct_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((c,), lambda i: (0,)),  # whole table resident
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=True,
    )(vbs, off, bfi)


def _walk_kernel(vb_ref, tables_ref, out_bfi_ref, out_off_ref):
    vb = vb_ref[...]
    tables = tables_ref[...]
    n = tables.shape[0]
    off0 = jnp.full(vb.shape, UNALLOCATED, dtype=jnp.int32)
    bfi0 = jnp.full(vb.shape, UNALLOCATED, dtype=jnp.int32)

    def body(i, carry):
        off, bfi = carry
        j = n - 1 - i
        # One full table row streamed per chain hop: the O(N) traffic the
        # paper's Eq. 1 charges to vQemu.
        t = jnp.take(tables[j], vb, axis=0)
        found = (bfi == UNALLOCATED) & (t != UNALLOCATED)
        return (
            jnp.where(found, t, off),
            jnp.where(found, jnp.int32(j), bfi),
        )

    off, bfi = jax.lax.fori_loop(0, n, body, (off0, bfi0))
    out_off_ref[...] = off
    out_bfi_ref[...] = bfi


@functools.partial(jax.jit, static_argnames=("block_b",))
def chain_walk_translate(tables, vbs, *, block_b=BLOCK_B):
    """Resolve ``vbs`` by walking a chain of per-file tables (vQemu).

    Args:
      tables: i32[n, c] per-backing-file host offsets (-1 = absent).
      vbs:    i32[b] requested virtual cluster indices, b % block_b == 0.
    Returns:
      (bfi_out, off_out): i32[b] each.
    """
    (b,) = vbs.shape
    n, c = tables.shape
    grid = (b // block_b,)
    return pl.pallas_call(
        _walk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((n, c), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=True,
    )(vbs, tables)
