"""Layer-2: the exported compute graphs, composed from the L1 kernels.

Each entry in ``ARTIFACTS`` is one AOT-compiled computation the Rust
coordinator loads from ``artifacts/<name>.hlo.txt``. Shapes are static
(PJRT AOT requires it); the Rust side chunks and pads bulk work to these
shapes — see rust/src/runtime/.

Exported graphs:
  * ``translate_direct`` — SQEMU bulk resolution (boot prefetch, batch
    translation in the coordinator): gather + per-file lookup histogram.
  * ``translate_walk``   — vQemu baseline resolution, for the figure benches
    that compare the two designs at the bulk level.
  * ``merge_l2``         — cache-correction / snapshot-copy / streaming merge
    of two flattened L2 tables.
  * ``stream_fold``      — streaming planner: fold a whole stack of
    backing-file tables into one table in a single call (scan of merge_l2),
    used by the coordinator's streaming orchestrator.
"""

import jax
import jax.numpy as jnp

from .kernels.merge import merge_l2
from .kernels.ref import UNALLOCATED
from .kernels.translate import chain_walk_translate, direct_translate

# Static export shapes. One artifact resolves BATCH requests against a table
# of CLUSTERS virtual clusters; CHAIN is the chain-walk depth per call (the
# Rust side loops calls for deeper chains). CLUSTERS=8192 indexes a 512 MiB
# disk at the default 64 KiB cluster size; bulk ops tile bigger disks.
# BATCH=4096 (was 256): one PJRT dispatch per 4096-request bulk op instead
# of 16 — see EXPERIMENTS.md §Perf (3.5x on the bulk path).
BATCH = 4096
CLUSTERS = 8192
CHAIN = 32
STREAM_DEPTH = 8


def translate_direct(off, bfi, vbs):
    """(bfi[b], off[b], hist[n+1]) for SQEMU direct access.

    The histogram over owning backing files (clamped to CHAIN files;
    index CHAIN = unallocated) feeds Fig 13c's bulk accounting.
    """
    out_bfi, out_off = direct_translate(off, bfi, vbs)
    clipped = jnp.clip(out_bfi, UNALLOCATED, CHAIN - 1)
    clipped = jnp.where(clipped == UNALLOCATED, CHAIN, clipped)
    hist = jnp.zeros((CHAIN + 1,), jnp.int32).at[clipped].add(1)
    return out_bfi, out_off, hist


def translate_walk(tables, vbs):
    """(bfi[b], off[b]) for the vQemu chain walk baseline."""
    out_bfi, out_off = chain_walk_translate(tables, vbs)
    return out_bfi, out_off


def stream_fold(offs, bfis):
    """Fold ``STREAM_DEPTH`` stacked tables (oldest first) into one.

    ``offs``/``bfis`` are i32[STREAM_DEPTH, CLUSTERS]; row order is chain
    order, so later rows take precedence via the merge rule.
    """

    def step(carry, row):
        off_v, bfi_v = carry
        off_b, bfi_b = row
        off, bfi = merge_l2(off_v, bfi_v, off_b, bfi_b)
        return (off, bfi), None

    init = (
        jnp.full((CLUSTERS,), UNALLOCATED, jnp.int32),
        jnp.full((CLUSTERS,), UNALLOCATED, jnp.int32),
    )
    (off, bfi), _ = jax.lax.scan(step, init, (offs, bfis))
    return off, bfi


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


# name -> (fn, example_args). aot.py lowers each with these static shapes.
ARTIFACTS = {
    "translate_direct": (
        translate_direct,
        (_i32(CLUSTERS), _i32(CLUSTERS), _i32(BATCH)),
    ),
    "translate_walk": (
        translate_walk,
        (_i32(CHAIN, CLUSTERS), _i32(BATCH)),
    ),
    "merge_l2": (
        merge_l2,
        (_i32(CLUSTERS), _i32(CLUSTERS), _i32(CLUSTERS), _i32(CLUSTERS)),
    ),
    "stream_fold": (
        stream_fold,
        (_i32(STREAM_DEPTH, CLUSTERS), _i32(STREAM_DEPTH, CLUSTERS)),
    ),
}
