"""Kernel-vs-reference correctness: the CORE L1 signal.

Every Pallas kernel is swept against its pure-jnp oracle (kernels/ref.py)
with hypothesis over shapes, chain depths, and table contents, plus a set
of hand-written edge cases mirroring the paper's semantics (§2, §5.3).
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.merge import merge_l2
from compile.kernels.ref import (
    UNALLOCATED,
    chain_walk_translate_ref,
    direct_translate_ref,
    merge_l2_ref,
)
from compile.kernels.translate import chain_walk_translate, direct_translate

SETTINGS = settings(max_examples=25, deadline=None)


def random_table(rng, n_files, clusters, fill=0.7):
    """Random per-file offset stack + the flattened (off, bfi) view."""
    tables = np.full((n_files, clusters), UNALLOCATED, np.int32)
    for j in range(n_files):
        mask = rng.random(clusters) < fill
        tables[j, mask] = rng.integers(0, 1 << 20, mask.sum())
    # flattened "sqemu" view: newest file owning each cluster wins
    off = np.full(clusters, UNALLOCATED, np.int32)
    bfi = np.full(clusters, UNALLOCATED, np.int32)
    for j in range(n_files):
        present = tables[j] != UNALLOCATED
        off[present] = tables[j, present]
        bfi[present] = j
    return tables, off, bfi


# ---------------------------------------------------------------- direct


@SETTINGS
@given(
    seed=st.integers(0, 2**31 - 1),
    blocks=st.integers(1, 4),
    clusters=st.sampled_from([64, 256, 1024]),
    fill=st.floats(0.0, 1.0),
)
def test_direct_translate_matches_ref(seed, blocks, clusters, fill):
    rng = np.random.default_rng(seed)
    b = 128 * blocks
    _, off, bfi = random_table(rng, 4, clusters, fill)
    vbs = rng.integers(0, clusters, b).astype(np.int32)
    got_bfi, got_off = direct_translate(
        jnp.asarray(off), jnp.asarray(bfi), jnp.asarray(vbs), block_b=128
    )
    ref_bfi, ref_off = direct_translate_ref(
        jnp.asarray(off), jnp.asarray(bfi), jnp.asarray(vbs)
    )
    np.testing.assert_array_equal(got_bfi, ref_bfi)
    np.testing.assert_array_equal(got_off, ref_off)


def test_direct_translate_unallocated_passthrough():
    off = jnp.full((128,), UNALLOCATED, jnp.int32)
    bfi = jnp.full((128,), UNALLOCATED, jnp.int32)
    vbs = jnp.arange(128, dtype=jnp.int32)
    got_bfi, got_off = direct_translate(off, bfi, vbs, block_b=128)
    assert np.all(np.asarray(got_bfi) == UNALLOCATED)
    assert np.all(np.asarray(got_off) == UNALLOCATED)


# ------------------------------------------------------------ chain walk


@SETTINGS
@given(
    seed=st.integers(0, 2**31 - 1),
    n_files=st.integers(1, 12),
    clusters=st.sampled_from([64, 256]),
    fill=st.floats(0.0, 1.0),
)
def test_chain_walk_matches_ref(seed, n_files, clusters, fill):
    rng = np.random.default_rng(seed)
    tables, _, _ = random_table(rng, n_files, clusters, fill)
    vbs = rng.integers(0, clusters, 128).astype(np.int32)
    got_bfi, got_off = chain_walk_translate(
        jnp.asarray(tables), jnp.asarray(vbs), block_b=128
    )
    ref_bfi, ref_off = chain_walk_translate_ref(
        jnp.asarray(tables), jnp.asarray(vbs)
    )
    np.testing.assert_array_equal(got_bfi, ref_bfi)
    np.testing.assert_array_equal(got_off, ref_off)


@SETTINGS
@given(seed=st.integers(0, 2**31 - 1), n_files=st.integers(1, 8))
def test_walk_equals_direct_on_flattened_view(seed, n_files):
    """The paper's core equivalence: direct access over the sqemu metadata
    must resolve exactly what the vanilla chain walk resolves (§5.3)."""
    rng = np.random.default_rng(seed)
    clusters = 256
    tables, off, bfi = random_table(rng, n_files, clusters, 0.5)
    vbs = rng.integers(0, clusters, 128).astype(np.int32)
    walk_bfi, walk_off = chain_walk_translate(
        jnp.asarray(tables), jnp.asarray(vbs), block_b=128
    )
    dir_bfi, dir_off = direct_translate(
        jnp.asarray(off), jnp.asarray(bfi), jnp.asarray(vbs), block_b=128
    )
    np.testing.assert_array_equal(walk_bfi, dir_bfi)
    np.testing.assert_array_equal(walk_off, dir_off)


def test_chain_walk_newest_file_wins():
    # cluster 0 present in files 0 and 2 -> file 2 wins
    tables = np.full((3, 64), UNALLOCATED, np.int32)
    tables[0, 0] = 11
    tables[2, 0] = 22
    tables[1, 1] = 33
    vbs = np.zeros(128, np.int32)
    vbs[1] = 1
    got_bfi, got_off = chain_walk_translate(
        jnp.asarray(tables), jnp.asarray(vbs), block_b=128
    )
    assert int(got_bfi[0]) == 2 and int(got_off[0]) == 22
    assert int(got_bfi[1]) == 1 and int(got_off[1]) == 33


# ----------------------------------------------------------------- merge


@SETTINGS
@given(seed=st.integers(0, 2**31 - 1), clusters=st.sampled_from([1024, 4096]))
def test_merge_matches_ref(seed, clusters):
    rng = np.random.default_rng(seed)

    def col():
        off = rng.integers(-1, 1 << 20, clusters).astype(np.int32)
        bfi = rng.integers(-1, 64, clusters).astype(np.int32)
        off[bfi == UNALLOCATED] = UNALLOCATED
        return jnp.asarray(off), jnp.asarray(bfi)

    off_v, bfi_v = col()
    off_b, bfi_b = col()
    got_off, got_bfi = merge_l2(off_v, bfi_v, off_b, bfi_b)
    ref_off, ref_bfi = merge_l2_ref(off_v, bfi_v, off_b, bfi_b)
    np.testing.assert_array_equal(got_off, ref_off)
    np.testing.assert_array_equal(got_bfi, ref_bfi)


def test_merge_precedence_rule():
    """§5.3: b wins iff bfi_v <= bfi_b (ties go to b)."""
    off_v = jnp.asarray(np.array([1, 2, 3, UNALLOCATED] * 256, np.int32))
    bfi_v = jnp.asarray(np.array([5, 2, 2, UNALLOCATED] * 256, np.int32))
    off_b = jnp.asarray(np.array([9, 9, 9, 9] * 256, np.int32))
    bfi_b = jnp.asarray(np.array([2, 5, 2, 0] * 256, np.int32))
    got_off, got_bfi = merge_l2(off_v, bfi_v, off_b, bfi_b)
    got_off = np.asarray(got_off)[:4]
    got_bfi = np.asarray(got_bfi)[:4]
    # v newer -> keep v; b newer -> take b; tie -> take b; v unalloc -> b
    np.testing.assert_array_equal(got_off, [1, 9, 9, 9])
    np.testing.assert_array_equal(got_bfi, [5, 5, 2, 0])


@SETTINGS
@given(seed=st.integers(0, 2**31 - 1))
def test_merge_result_is_elementwise_max_bfi(seed):
    """Cache correction never decreases a cached backing_file_index — the
    invariant backing rust/src/cache/unified.rs (merge == max on bfi)."""
    rng = np.random.default_rng(seed)
    bfi_v = rng.integers(-1, 32, 1024).astype(np.int32)
    bfi_b = rng.integers(-1, 32, 1024).astype(np.int32)
    off = rng.integers(0, 100, 1024).astype(np.int32)
    _, got_bfi = merge_l2(
        jnp.asarray(off), jnp.asarray(bfi_v),
        jnp.asarray(off), jnp.asarray(bfi_b),
    )
    np.testing.assert_array_equal(np.asarray(got_bfi), np.maximum(bfi_v, bfi_b))
