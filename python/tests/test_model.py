"""L2 graph tests: exported-shape composition + manifest consistency."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import UNALLOCATED, merge_l2_ref
from compile.model import (
    ARTIFACTS,
    BATCH,
    CHAIN,
    CLUSTERS,
    STREAM_DEPTH,
    stream_fold,
    translate_direct,
    translate_walk,
)

SETTINGS = settings(max_examples=10, deadline=None)


def test_artifact_shapes_lower():
    """Every exported graph traces at its manifest shape."""
    for name, (fn, example_args) in ARTIFACTS.items():
        out = jax.eval_shape(fn, *example_args)
        assert jax.tree_util.tree_leaves(out), name


def test_translate_direct_histogram():
    rng = np.random.default_rng(0)
    off = rng.integers(0, 1 << 20, CLUSTERS).astype(np.int32)
    bfi = rng.integers(0, CHAIN, CLUSTERS).astype(np.int32)
    # mark some clusters unallocated
    hole = rng.random(CLUSTERS) < 0.2
    off[hole] = UNALLOCATED
    bfi[hole] = UNALLOCATED
    vbs = rng.integers(0, CLUSTERS, BATCH).astype(np.int32)
    got_bfi, got_off, hist = translate_direct(
        jnp.asarray(off), jnp.asarray(bfi), jnp.asarray(vbs)
    )
    hist = np.asarray(hist)
    assert hist.sum() == BATCH
    # histogram matches a recount of the returned bfi
    got = np.asarray(got_bfi)
    for j in range(CHAIN):
        assert hist[j] == (got == j).sum()
    assert hist[CHAIN] == (got == UNALLOCATED).sum()


def test_translate_walk_export_shape():
    rng = np.random.default_rng(1)
    tables = np.full((CHAIN, CLUSTERS), UNALLOCATED, np.int32)
    tables[0] = rng.integers(0, 100, CLUSTERS)
    vbs = rng.integers(0, CLUSTERS, BATCH).astype(np.int32)
    got_bfi, got_off = translate_walk(jnp.asarray(tables), jnp.asarray(vbs))
    assert np.all(np.asarray(got_bfi) == 0)
    np.testing.assert_array_equal(np.asarray(got_off), tables[0][vbs])


@SETTINGS
@given(seed=st.integers(0, 2**31 - 1))
def test_stream_fold_equals_pairwise_merge(seed):
    """stream_fold == left fold of merge_l2_ref over rows (oldest first)."""
    rng = np.random.default_rng(seed)
    offs = rng.integers(-1, 1 << 16, (STREAM_DEPTH, CLUSTERS)).astype(np.int32)
    bfis = rng.integers(-1, 64, (STREAM_DEPTH, CLUSTERS)).astype(np.int32)
    offs[bfis == UNALLOCATED] = UNALLOCATED
    got_off, got_bfi = stream_fold(jnp.asarray(offs), jnp.asarray(bfis))
    off = jnp.full((CLUSTERS,), UNALLOCATED, jnp.int32)
    bfi = jnp.full((CLUSTERS,), UNALLOCATED, jnp.int32)
    for j in range(STREAM_DEPTH):
        off, bfi = merge_l2_ref(off, bfi, jnp.asarray(offs[j]), jnp.asarray(bfis[j]))
    np.testing.assert_array_equal(np.asarray(got_off), np.asarray(off))
    np.testing.assert_array_equal(np.asarray(got_bfi), np.asarray(bfi))


def test_hlo_text_exports(tmp_path):
    """End-to-end: every artifact lowers to parseable HLO text with the
    manifest's declared output arity."""
    from compile.aot import export_all

    manifest = export_all(str(tmp_path))
    for name, meta in manifest["artifacts"].items():
        text = (tmp_path / meta["file"]).read_text()
        assert text.startswith("HloModule"), name
        assert len(meta["outputs"]) >= 2
