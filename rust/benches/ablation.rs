//! Ablations over the design choices DESIGN.md §4 calls out:
//!
//! 1. slice size (Qemu's `l2-cache-entry-size`): lookup cost vs fetch
//!    amortization under sequential and random workloads;
//! 2. the §5.4 snapshot-time L2 copy vs a hypothetical "stamp-free"
//!    sqemu (unified cache only, no backing_file_index => correction
//!    walk): quantifies how much of the win is the format extension vs
//!    the single cache;
//! 3. hop cost sensitivity: the Eq. 1 T_F term that drives the vanilla
//!    collapse (model-robustness check).

use sqemu::bench::figures::{run_workload, ExpConfig};
use sqemu::bench::table::{f1, mibs, Table};
use sqemu::bench::BenchArgs;
use sqemu::guest::dd::Dd;
use sqemu::guest::fio::Fio;
use sqemu::qcow::image::DataMode;
use sqemu::vdisk::DriverKind;

fn main() {
    let args = BenchArgs::parse();
    let disk = 2u64 << 30;
    let chain = if args.quick { 25 } else { 100 };

    // ---------------------------------------------------- 1. slice size
    let mut t = Table::new(
        "ablation_slice_size",
        &format!("slice size ablation (sqemu, chain {chain})"),
        &["slice_entries", "dd_MBps", "fio_MBps", "misses_dd"],
    );
    for slice_entries in [32u64, 128, 512, 2048] {
        let cfg = ExpConfig {
            disk_size: disk,
            chain_len: chain,
            populated: 0.9,
            slice_entries,
            data_mode: DataMode::Synthetic,
            ..Default::default()
        };
        let dd = run_workload(DriverKind::Scalable, &cfg, &mut Dd::default()).unwrap();
        let fio = run_workload(
            DriverKind::Scalable,
            &cfg,
            &mut Fio { io_size: 4 << 10, ops: 10_000, seed: 1 },
        )
        .unwrap();
        t.row(&[
            slice_entries.to_string(),
            mibs(dd.stats.throughput_bps()),
            mibs(fio.stats.throughput_bps()),
            dd.counters.misses.to_string(),
        ]);
    }
    t.finish();
    println!(
        "larger slices amortize fetches for sequential dd (fewer misses) with \
         no penalty here; Qemu's 4 KiB default (512 entries) is already on \
         the plateau — supporting the paper's choice to keep the vanilla \
         cache organization (§5.3)."
    );

    // ------------------------------- 2. format extension vs unified cache
    // "stamp-free sqemu" = ScalableDriver over a *vanilla* chain: single
    // unified cache, but no backing_file_index -> correction chain walk.
    let mut t = Table::new(
        "ablation_stamps",
        &format!("what the bfi stamps buy (chain {chain}, dd)"),
        &["variant", "dd_MBps", "misses", "hit_unalloc"],
    );
    for (name, kind, stamped) in [
        ("vanilla (per-file caches)", DriverKind::Vanilla, false),
        ("unified cache only (no stamps)", DriverKind::Scalable, false),
        ("full sqemu (stamps + unified)", DriverKind::Scalable, true),
    ] {
        let mut cfg = ExpConfig {
            disk_size: disk,
            chain_len: chain,
            populated: 0.9,
            data_mode: DataMode::Synthetic,
            ..Default::default()
        };
        cfg.seed ^= 1; // distinct prefix space per run is handled internally
        let out = if stamped {
            run_workload(kind, &cfg, &mut Dd::default()).unwrap()
        } else {
            // force an unstamped chain for the scalable driver by running
            // it against the vanilla-generated chain
            let clock = sqemu::metrics::clock::VirtClock::new();
            let node = sqemu::storage::node::StorageNode::new(
                "ab",
                clock.clone(),
                sqemu::metrics::clock::CostModel::default(),
            );
            let spec = cfg.chain_spec(false, "ab");
            let chain = sqemu::chaingen::generate(&node, &spec).unwrap();
            sqemu::bench::figures::run_on_chain(
                kind,
                &cfg,
                chain,
                clock,
                &mut Dd::default(),
                0,
            )
            .unwrap()
        };
        t.row(&[
            name.into(),
            mibs(out.stats.throughput_bps()),
            out.counters.misses.to_string(),
            out.counters.hit_unallocated.to_string(),
        ]);
    }
    t.finish();
    println!(
        "the unified cache alone helps memory but not the walk; the \
         backing_file_index stamps are what deliver O(1) resolution — the \
         paper needs BOTH principles (§5.1)."
    );

    // ---------------------------------------------- 3. hop cost sensitivity
    let mut t = Table::new(
        "ablation_hop_cost",
        "vanilla dd throughput vs chain under different T_F interpretations",
        &["chain", "pct_of_len1 (T_F=1us, model)", "note"],
    );
    let mut base = 0.0;
    for len in [1usize, 50, 200] {
        let cfg = ExpConfig {
            disk_size: disk,
            chain_len: len,
            populated: 0.9,
            data_mode: DataMode::Synthetic,
            ..Default::default()
        };
        let out = run_workload(DriverKind::Vanilla, &cfg, &mut Dd::default()).unwrap();
        let bps = out.stats.throughput_bps();
        if base == 0.0 {
            base = bps;
        }
        t.row(&[
            len.to_string(),
            f1(100.0 * bps / base),
            if len == 1 { "baseline".into() } else { "Eq.1 linear".into() },
        ]);
    }
    t.finish();
    println!(
        "with T_F at the paper's ~1 us software-hop cost the vanilla collapse \
         tracks Fig 10; setting T_F=T_M (pure RAM probes) would flatten it to \
         <10% loss — the collapse IS the per-hop software stack, exactly \
         Eq. 1's point."
    );
}
