//! Fig 1 — virtualization slowdown by workload type.
//!
//! The paper measures dd/fio/NPB/stream/netperf on EC2/Azure/private
//! cloud vs bare metal and finds the disk-intensive workloads suffer the
//! most. Our substrate reproduces the *disk* column: the same request
//! stream against the raw device (bare metal) vs through the virtual-disk
//! stack (driver + indexing + chain), on identical device cost models.
//! CPU/memory/network rows are reported as the near-1x baselines they are
//! in the paper (no indexing indirection in our model => pass-through).

use sqemu::bench::figures::{run_workload, ExpConfig};
use sqemu::bench::table::{f2, Table};
use sqemu::bench::BenchArgs;
use sqemu::guest::dd::Dd;
use sqemu::guest::fio::Fio;
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::qcow::image::DataMode;
use sqemu::storage::backend::Backend;
use sqemu::storage::mem::MemBackend;
use sqemu::storage::timed::Timed;
use sqemu::util::rng::Rng;
use sqemu::vdisk::DriverKind;

/// Raw-device run: the same byte stream straight to a timed backend.
fn raw_device(disk: u64, sequential: bool, ops: u64) -> f64 {
    let clock = VirtClock::new();
    let cost = CostModel::default();
    let dev = Timed::new(MemBackend::new(), clock.clone(), cost);
    dev.truncate_to(disk).unwrap();
    let mut rng = Rng::new(1);
    let t0 = clock.now();
    let mut bytes = 0u64;
    if sequential {
        let mut buf = vec![0u8; 4 << 20];
        let mut pos = 0;
        while pos < disk {
            let n = buf.len().min((disk - pos) as usize);
            dev.read_at(&mut buf[..n], pos).unwrap();
            pos += n as u64;
            bytes += n as u64;
        }
    } else {
        let mut buf = vec![0u8; 4 << 10];
        for _ in 0..ops {
            let pos = rng.below(disk / 4096) * 4096;
            dev.read_at(&mut buf, pos).unwrap();
            bytes += 4096;
        }
    }
    bytes as f64 / ((clock.now() - t0) as f64 / 1e9)
}

fn main() {
    let args = BenchArgs::parse();
    let disk = if args.full { 8 << 30 } else { 1 << 30 };
    let cfg = ExpConfig {
        disk_size: disk,
        chain_len: 1,
        populated: 1.0,
        data_mode: DataMode::Synthetic,
        ..Default::default()
    };

    let mut t = Table::new(
        "fig01_virt_overhead",
        "slowdown vs bare metal (disk rows measured; lower is better)",
        &["workload", "bare_MBps", "virt_MBps", "slowdown"],
    );

    // dd (throughput-oriented disk)
    let raw = raw_device(disk, true, 0);
    let virt = run_workload(DriverKind::Vanilla, &cfg, &mut Dd::default())
        .unwrap()
        .stats
        .throughput_bps();
    t.row(&[
        "dd (disk seq)".into(),
        f2(raw / (1 << 20) as f64),
        f2(virt / (1 << 20) as f64),
        f2(raw / virt),
    ]);

    // fio (latency-oriented disk): virtualization hurts most here (paper:
    // the fio slowdown is ~1639x the NPB one)
    let ops = if args.quick { 2_000 } else { 20_000 };
    let raw = raw_device(disk, false, ops);
    let virt = run_workload(
        DriverKind::Vanilla,
        &cfg,
        &mut Fio { io_size: 4 << 10, ops, seed: 2 },
    )
    .unwrap()
    .stats
    .throughput_bps();
    t.row(&[
        "fio (disk rand)".into(),
        f2(raw / (1 << 20) as f64),
        f2(virt / (1 << 20) as f64),
        f2(raw / virt),
    ]);

    // non-disk resources: direct access in modern VMs => ~1x (reported
    // for completeness; our substrate models no CPU/net indirection)
    for name in ["NPB (cpu)", "stream (mem)", "netperf (net)"] {
        t.row(&[name.into(), "-".into(), "-".into(), f2(1.0)]);
    }
    t.finish();
    println!("\npaper shape: disk workloads dominate the slowdown; fio >> dd > rest");
}
