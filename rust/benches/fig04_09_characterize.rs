//! Figs 4, 5, 6, 8, 9 — the §3 characterization study, regenerated from
//! the calibrated population model.

use sqemu::bench::table::{f1, Table};
use sqemu::bench::BenchArgs;
use sqemu::characterize::population::{Fig9Key, Population, PopulationConfig};
use sqemu::characterize::sizes::{size_cdf, Party};
use sqemu::util::human_bytes;

fn main() {
    let args = BenchArgs::parse();
    let n_chains = if args.full { 60_000 } else { 20_000 };

    // ---------------------------------------------------------- Fig 4
    let mut t = Table::new(
        "fig04_size_cdf",
        "CDF of requested virtual disk sizes",
        &["quantile", "first_party", "third_party"],
    );
    let first = size_cdf(41, Party::First, 50_000);
    let third = size_cdf(42, Party::Third, 50_000);
    for q in [0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
        t.row(&[
            format!("{q:.2}"),
            human_bytes(first.quantile(q)),
            human_bytes(third.quantile(q)),
        ]);
    }
    t.finish();
    println!("take-away 1: modes at 10 GiB (first) / 50 GiB (third), tail to ~10 TiB");

    let pop = Population::simulate(PopulationConfig {
        n_chains,
        ..Default::default()
    });

    // ---------------------------------------------------------- Fig 5
    let mut t = Table::new(
        "fig05_longest_chain",
        "longest chain over the year",
        &["day", "longest_chain"],
    );
    for (day, len) in pop.longest_per_day.iter().step_by(30) {
        t.row(&[day.to_string(), len.to_string()]);
    }
    let (d, l) = *pop.longest_per_day.last().unwrap();
    t.row(&[d.to_string(), l.to_string()]);
    t.finish();
    println!("take-away 2: chains of several hundred to 1000+ files exist all year");

    // ---------------------------------------------------------- Fig 6
    let (chains, files) = pop.chain_length_cdfs();
    let mut t = Table::new(
        "fig06_chain_length_cdf",
        "CDF of chain length (per chain / per file)",
        &["length", "P_chains", "P_files"],
    );
    for len in [1u64, 5, 10, 20, 29, 30, 35, 50, 100, 300, 1000] {
        t.row(&[
            len.to_string(),
            format!("{:.3}", chains.at(len)),
            format!("{:.3}", files.at(len)),
        ]);
    }
    t.finish();
    println!(
        "take-away 2: most chains short; visible mass at the streaming threshold (30-35)"
    );

    // ---------------------------------------------------------- Fig 8
    let scatter = pop.sharing_scatter();
    let mut t = Table::new(
        "fig08_sharing",
        "shared backing files vs chain length (bucketed scatter)",
        &["len_bucket", "chains", "mean_shared", "max_shared", "pct_unshared"],
    );
    for (lo, hi) in [(1usize, 5), (6, 10), (11, 29), (30, 35), (36, 100), (101, 2000)] {
        let bucket: Vec<&(usize, usize)> = scatter
            .iter()
            .filter(|(l, _)| *l >= lo && *l <= hi)
            .collect();
        if bucket.is_empty() {
            continue;
        }
        let n = bucket.len();
        let mean = bucket.iter().map(|(_, s)| *s).sum::<usize>() as f64 / n as f64;
        let max = bucket.iter().map(|(_, s)| *s).max().unwrap();
        let unshared = bucket.iter().filter(|(_, s)| *s == 0).count();
        t.row(&[
            format!("{lo}-{hi}"),
            n.to_string(),
            f1(mean),
            max.to_string(),
            f1(100.0 * unshared as f64 / n as f64),
        ]);
    }
    t.finish();
    println!("take-away 3: sharing highly variable; base images + disk copies");

    // ---------------------------------------------------------- Fig 9
    let mut t = Table::new(
        "fig09_snapshot_frequency",
        "snapshot creation events: position in chain vs elapsed since last",
        &["position", "<1h", "<1d", "<1w", "<1mo", "<3mo", ">=3mo"],
    );
    let total: u64 = pop.fig9.values().sum();
    for (lo, hi) in [(0u32, 5), (6, 10), (11, 29), (30, 35), (36, 100), (101, 5000)] {
        let mut buckets = [0u64; 6];
        for (k, &n) in &pop.fig9 {
            if k.position >= lo && k.position <= hi {
                buckets[k.elapsed_bucket as usize] += n;
            }
        }
        let pct = |c: u64| format!("{:.2}%", 100.0 * c as f64 / total as f64);
        t.row(&[
            format!("{lo}-{hi}"),
            pct(buckets[0]),
            pct(buckets[1]),
            pct(buckets[2]),
            pct(buckets[3]),
            pct(buckets[4]),
            pct(buckets[5]),
        ]);
    }
    t.finish();
    // take-away 4 check: high positions dominated by fast snapshotting
    let mut long_total = 0u64;
    let mut long_fast = 0u64;
    for (k, &n) in &pop.fig9 {
        if k.position > 100 {
            long_total += n;
            if k.elapsed_bucket <= 2 {
                long_fast += n;
            }
        }
    }
    println!(
        "take-away 4: long chains built by daily-or-faster snapshots \
         ({:.1}% of position>100 events)",
        100.0 * long_fast as f64 / long_total.max(1) as f64
    );
    let _ = Fig9Key { position: 0, elapsed_bucket: 0 };
}
