//! Fig 10 — the §4.3 problem assessment: vanilla I/O throughput and
//! memory footprint vs chain size (paper: 20 GiB disk, 60 MiB layers,
//! chains 0..300, dd full read, per-file caches sized for the disk).

use sqemu::bench::figures::{run_workload, ExpConfig};
use sqemu::bench::table::{f1, mibs, Table};
use sqemu::bench::BenchArgs;
use sqemu::guest::dd::Dd;
use sqemu::qcow::image::DataMode;
use sqemu::vdisk::DriverKind;

fn main() {
    let args = BenchArgs::parse();
    // paper: 20 GiB disk; scaled default: 2 GiB
    let disk = if args.full { 20 << 30 } else { 2 << 30 };
    let chains: Vec<usize> = if args.full {
        vec![1, 25, 50, 100, 150, 200, 250, 300]
    } else if args.quick {
        vec![1, 25, 100]
    } else {
        vec![1, 25, 50, 100, 200, 300]
    };

    let mut t = Table::new(
        "fig10_problem",
        "vanilla Qemu: dd read throughput + memory overhead vs chain size",
        &["chain", "MBps", "pct_of_no_snapshot", "mem_overhead_MiB"],
    );
    let mut base_bps = 0.0;
    for &len in &chains {
        let cfg = ExpConfig {
            disk_size: disk,
            chain_len: len,
            populated: 0.9,
            data_mode: DataMode::Synthetic,
            ..Default::default()
        };
        let out = run_workload(DriverKind::Vanilla, &cfg, &mut Dd::default()).unwrap();
        let bps = out.stats.throughput_bps();
        if base_bps == 0.0 {
            base_bps = bps;
        }
        t.row(&[
            len.to_string(),
            mibs(bps),
            f1(100.0 * bps / base_bps),
            f1(out.mem_peak as f64 / (1 << 20) as f64),
        ]);
    }
    t.finish();
    println!(
        "\npaper shape: throughput collapses to ~39% at chain 300; memory grows \
         linearly (one full-disk L2 cache per snapshot). take-away 6."
    );
}
