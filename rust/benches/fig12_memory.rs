//! Fig 12 — memory overhead of SQEMU vs vanilla after a full-disk dd,
//! while varying chain length (§6.2). Paper headline: 3.9x reduction at
//! chain 50, 15.2x at 500, 17.6x at 1000.

use sqemu::bench::figures::{run_pair, ExpConfig};
use sqemu::bench::table::{f1, Table};
use sqemu::bench::BenchArgs;
use sqemu::guest::dd::Dd;
use sqemu::guest::Workload;
use sqemu::qcow::image::DataMode;

fn main() {
    let args = BenchArgs::parse();
    let mut t = Table::new(
        "fig12_memory",
        "memory overhead after dd full read (MiB; lower is better)",
        &["chain", "vqemu_MiB", "sqemu_MiB", "reduction_x"],
    );
    for len in args.chain_lengths() {
        let cfg = ExpConfig {
            disk_size: args.disk_size(),
            chain_len: len,
            populated: 0.9,
            data_mode: DataMode::Synthetic,
            ..Default::default()
        };
        let (v, s) = run_pair(&cfg, || {
            Box::new(Dd::default()) as Box<dyn Workload>
        })
        .unwrap();
        t.row(&[
            len.to_string(),
            f1(v.mem_peak as f64 / (1 << 20) as f64),
            f1(s.mem_peak as f64 / (1 << 20) as f64),
            f1(v.mem_peak as f64 / s.mem_peak as f64),
        ]);
    }
    t.finish();
    println!(
        "\npaper shape: vanilla linear in chain length (per-file caches); sqemu \
         near-flat with a slight per-snapshot residue; reduction grows with the \
         chain (3.9x @ 50, 15.2x @ 500, 17.6x @ 1000 in the paper)."
    );
}
