//! Fig 13 — low-level metrics during a full-disk dd (§6.3):
//! (a) cache misses vs chain, (b) cache hit unallocated vs chain,
//! (c) distribution of cache lookups over the chain's files (with the
//! boot-time spike on the base image) at a fixed chain length.

use sqemu::bench::figures::{run_pair, run_workload, ExpConfig};
use sqemu::bench::table::{f1, Table};
use sqemu::bench::BenchArgs;
use sqemu::guest::boot::BootTrace;
use sqemu::guest::dd::Dd;
use sqemu::guest::{Workload, WorkloadStats};
use sqemu::metrics::clock::VirtClock;
use sqemu::qcow::image::DataMode;
use sqemu::vdisk::{Driver, DriverKind};
use std::sync::Arc;

/// Boot then dd — reproduces the Fig 13c base-image spike.
struct BootThenDd;

impl Workload for BootThenDd {
    fn name(&self) -> &str {
        "boot+dd"
    }

    fn run(
        &mut self,
        driver: &mut dyn Driver,
        clock: &Arc<VirtClock>,
    ) -> anyhow::Result<WorkloadStats> {
        let mut boot = BootTrace {
            sequential_bytes: 32 << 20,
            scattered_reads: 200,
            seed: 0xB007,
        };
        let b = boot.run(driver, clock)?;
        let mut dd = Dd::default();
        let mut d = dd.run(driver, clock)?;
        d.ops += b.ops;
        d.bytes += b.bytes;
        d.elapsed_ns += b.elapsed_ns;
        Ok(d)
    }
}

fn main() {
    let args = BenchArgs::parse();

    // (a) + (b): misses and hit-unallocated vs chain length
    let mut t = Table::new(
        "fig13ab_misses_unallocated",
        "cache misses / hit-unallocated during dd (lower is better)",
        &["chain", "vq_miss", "sq_miss", "miss_x", "vq_unalloc", "sq_unalloc", "unalloc_x"],
    );
    for len in args.chain_lengths() {
        let cfg = ExpConfig {
            disk_size: args.disk_size(),
            chain_len: len,
            populated: 0.9,
            data_mode: DataMode::Synthetic,
            ..Default::default()
        };
        let (v, s) = run_pair(&cfg, || Box::new(Dd::default()) as Box<dyn Workload>)
            .unwrap();
        t.row(&[
            len.to_string(),
            v.counters.misses.to_string(),
            s.counters.misses.to_string(),
            f1(v.counters.misses as f64 / s.counters.misses.max(1) as f64),
            v.counters.hit_unallocated.to_string(),
            s.counters.hit_unallocated.to_string(),
            f1(v.counters.hit_unallocated as f64
                / s.counters.hit_unallocated.max(1) as f64),
        ]);
    }
    t.finish();
    println!(
        "\npaper shape: sqemu misses flat & ~10x lower at depth; sqemu \
         hit-unallocated constant while vanilla explodes with chain walks"
    );

    // (c): lookup distribution over files at a fixed chain
    let len = if args.full { 500 } else { 100 };
    let cfg = ExpConfig {
        disk_size: args.disk_size(),
        chain_len: len,
        populated: 0.9,
        data_mode: DataMode::Synthetic,
        ..Default::default()
    };
    let mut t = Table::new(
        "fig13c_lookup_distribution",
        &format!("cache lookups per backing file (boot+dd, chain {len})"),
        &["system", "file0_base", "mid_files_mean", "active", "total"],
    );
    for kind in [DriverKind::Vanilla, DriverKind::Scalable] {
        let out = run_workload(kind, &cfg, &mut BootThenDd).unwrap();
        let lk = &out.counters.per_file_lookups;
        let base = lk.first().copied().unwrap_or(0);
        let active = lk.last().copied().unwrap_or(0);
        let mid: Vec<u64> = lk[1..lk.len().saturating_sub(1)].to_vec();
        let mid_mean = if mid.is_empty() {
            0.0
        } else {
            mid.iter().sum::<u64>() as f64 / mid.len() as f64
        };
        t.row(&[
            kind.name().into(),
            base.to_string(),
            f1(mid_mean),
            active.to_string(),
            lk.iter().sum::<u64>().to_string(),
        ]);
    }
    t.finish();
    println!(
        "\npaper shape: vanilla touches every file's cache (~15x more lookups \
         total); sqemu concentrates on the active volume; base image shows the \
         boot spike under vanilla"
    );
}
