//! Fig 14 — cache lookup latency distribution on chains 1 and 100 (§6.3).
//! Paper: sqemu mean 1.8x lower at depth, bimodal (hit vs
//! hit-unallocated); vanilla spreads wide with the walk length.

use sqemu::bench::figures::{run_workload, ExpConfig};
use sqemu::bench::table::Table;
use sqemu::bench::BenchArgs;
use sqemu::guest::dd::Dd;
use sqemu::qcow::image::DataMode;
use sqemu::util::human_ns;
use sqemu::vdisk::DriverKind;

fn main() {
    let args = BenchArgs::parse();
    let chains = if args.full { vec![1usize, 100, 500] } else { vec![1usize, 100] };
    let mut t = Table::new(
        "fig14_lookup_latency",
        "cache lookup latency distribution during dd (virtual time)",
        &["system", "chain", "mean", "p50", "p99", "modes"],
    );
    for &len in &chains {
        for kind in [DriverKind::Vanilla, DriverKind::Scalable] {
            let cfg = ExpConfig {
                disk_size: args.disk_size(),
                chain_len: len,
                populated: 0.9,
                data_mode: DataMode::Synthetic,
                ..Default::default()
            };
            let out = run_workload(kind, &cfg, &mut Dd::default()).unwrap();
            let h = &out.lookup_hist;
            let modes: Vec<String> =
                h.modes(0.05).into_iter().map(human_ns).collect();
            t.row(&[
                kind.name().into(),
                len.to_string(),
                human_ns(h.mean() as u64),
                human_ns(h.quantile(0.5)),
                human_ns(h.quantile(0.99)),
                modes.join(" / "),
            ]);
        }
    }
    t.finish();
    println!(
        "\npaper shape: at depth, sqemu's distribution concentrates around two \
         modes (hit / hit-unallocated) with a ~2x lower mean; vanilla's mean \
         grows with the chain and spreads widely"
    );
}
