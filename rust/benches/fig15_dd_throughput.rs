//! Fig 15 — dd throughput vs chain length (§6.4.1). Paper: sqemu flat;
//! vanilla loses up to 84% at chain 1000.

use sqemu::bench::figures::{run_pair, ExpConfig};
use sqemu::bench::table::{f1, mibs, Table};
use sqemu::bench::BenchArgs;
use sqemu::guest::dd::Dd;
use sqemu::guest::Workload;
use sqemu::qcow::image::DataMode;

fn main() {
    let args = BenchArgs::parse();
    let mut t = Table::new(
        "fig15_dd_throughput",
        "dd sequential read throughput vs chain length (MiB/s)",
        &["chain", "vqemu_MBps", "sqemu_MBps", "vq_pct_of_len1", "sq_pct_of_len1"],
    );
    let mut v1 = 0.0;
    let mut s1 = 0.0;
    for len in args.chain_lengths() {
        let cfg = ExpConfig {
            disk_size: args.disk_size(),
            chain_len: len,
            populated: 0.9,
            data_mode: DataMode::Synthetic,
            ..Default::default()
        };
        let (v, s) = run_pair(&cfg, || Box::new(Dd::default()) as Box<dyn Workload>)
            .unwrap();
        let (vb, sb) = (v.stats.throughput_bps(), s.stats.throughput_bps());
        if v1 == 0.0 {
            v1 = vb;
            s1 = sb;
        }
        t.row(&[
            len.to_string(),
            mibs(vb),
            mibs(sb),
            f1(100.0 * vb / v1),
            f1(100.0 * sb / s1),
        ]);
    }
    t.finish();
    println!(
        "\npaper shape: sqemu flat (~100% of its chain-1 throughput); vanilla \
         degrades steeply (−84% at chain 1000 in the paper)"
    );
}
