//! Fig 16 — fio 4 KiB random-read throughput vs cache size at a fixed
//! chain (§6.4.1). Both systems get the same *total* budget; vanilla
//! splits it across the chain's per-file caches (S/L each).

use sqemu::bench::figures::{run_workload, ExpConfig};
use sqemu::bench::table::{f2, Table};
use sqemu::bench::BenchArgs;
use sqemu::guest::fio::Fio;
use sqemu::qcow::image::DataMode;
use sqemu::util::human_bytes;
use sqemu::vdisk::DriverKind;

fn main() {
    let args = BenchArgs::parse();
    let chain_len = if args.full { 500 } else { 100 };
    let ops = if args.quick { 3_000 } else { 20_000 };
    // cache budgets scale with the disk (the full sweep is the paper's
    // 1 MiB..4 GiB on 50 GiB; the scaled sweep keeps the same
    // budget/index ratios on the 4 GiB disk)
    let cache_sizes: Vec<u64> = if args.full {
        vec![1 << 20, 4 << 20, 16 << 20, 32 << 20, 128 << 20, 1 << 30, 4u64 << 30]
    } else {
        vec![64 << 10, 128 << 10, 256 << 10, 512 << 10, 1 << 20, 4 << 20, 32 << 20]
    };

    let mut t = Table::new(
        "fig16_fio_cache",
        &format!("fio 4K randread vs total cache budget (chain {chain_len})"),
        &["cache_total", "vqemu_MBps", "sqemu_MBps", "sq_over_vq"],
    );
    for &cache in &cache_sizes {
        let mk_cfg = |split| ExpConfig {
            disk_size: args.disk_size(),
            chain_len,
            populated: 0.9,
            cache_bytes: cache,
            split_vanilla_cache: split,
            data_mode: DataMode::Synthetic,
            ..Default::default()
        };
        let v = run_workload(
            DriverKind::Vanilla,
            &mk_cfg(true),
            &mut Fio { io_size: 4 << 10, ops, seed: 0xF16 },
        )
        .unwrap();
        let s = run_workload(
            DriverKind::Scalable,
            &mk_cfg(false),
            &mut Fio { io_size: 4 << 10, ops, seed: 0xF16 },
        )
        .unwrap();
        let (vb, sb) = (v.stats.throughput_bps(), s.stats.throughput_bps());
        t.row(&[
            human_bytes(cache),
            f2(vb / (1 << 20) as f64),
            f2(sb / (1 << 20) as f64),
            f2(sb / vb),
        ]);
    }
    t.finish();
    println!(
        "\npaper shape: sqemu wins at every budget; sqemu nears peak from a \
         modest cache (32 MiB in the paper) while vanilla needs orders of \
         magnitude more (4 GiB) because the budget splinters across the chain"
    );
}
