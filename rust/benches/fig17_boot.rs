//! Fig 17 — VM boot time vs chain length at two disk sizes (§6.4.2).
//! Paper: vanilla 10s -> 40s+ (4x) at chain 1000; sqemu 10s -> 17s
//! (1.7x); disk size barely matters.

use sqemu::bench::figures::{run_workload, ExpConfig};
use sqemu::bench::table::{f2, Table};
use sqemu::bench::BenchArgs;
use sqemu::guest::boot::BootTrace;
use sqemu::qcow::image::DataMode;
use sqemu::util::human_bytes;
use sqemu::vdisk::DriverKind;

fn main() {
    let args = BenchArgs::parse();
    // paper: 50 and 150 GiB; scaled: 4 and 12 GiB
    let disks: Vec<u64> = if args.full {
        vec![50 << 30, 150 << 30]
    } else {
        vec![4 << 30, 12 << 30]
    };
    let mut t = Table::new(
        "fig17_boot",
        "VM boot time (virtual seconds) vs chain length and disk size",
        &["disk", "chain", "vqemu_s", "sqemu_s", "vq_over_sq"],
    );
    let mut growth = Vec::new();
    for &disk in &disks {
        let mut first: Option<(f64, f64)> = None;
        let mut last = (0.0, 0.0);
        for len in args.chain_lengths() {
            let cfg = ExpConfig {
                disk_size: disk,
                chain_len: len,
                populated: 0.9,
                data_mode: DataMode::Synthetic,
                ..Default::default()
            };
            let v = run_workload(DriverKind::Vanilla, &cfg, &mut BootTrace::default())
                .unwrap();
            let s = run_workload(DriverKind::Scalable, &cfg, &mut BootTrace::default())
                .unwrap();
            let (vs, ss) = (
                v.stats.elapsed_ns as f64 / 1e9,
                s.stats.elapsed_ns as f64 / 1e9,
            );
            first.get_or_insert((vs, ss));
            last = (vs, ss);
            t.row(&[
                human_bytes(disk),
                len.to_string(),
                f2(vs),
                f2(ss),
                f2(vs / ss),
            ]);
        }
        let (v1, s1) = first.unwrap();
        growth.push((disk, last.0 / v1, last.1 / s1));
    }
    t.finish();
    for (disk, vg, sg) in growth {
        println!(
            "disk {}: boot time grew {vg:.1}x under vanilla, {sg:.1}x under sqemu",
            human_bytes(disk)
        );
    }
    println!(
        "\npaper shape: boot time grows ~4x under vanilla vs ~1.7x under sqemu; \
         disk size does not really influence the results"
    );
}
