//! Fig 18 — RocksDB-YCSB macro-benchmark (§6.4.2): YCSB-C read-only
//! point lookups over a store filling 40% of the disk whose valid
//! clusters are uniformly distributed over the chain. Two cache sizes,
//! two chain lengths; throughput (a, c) and execution time (b, d).
//! Paper headline: +33% @ chain 50, +47..48% @ chain 500.

use sqemu::bench::figures::{run_workload, ExpConfig};
use sqemu::bench::table::{f1, f2, Table};
use sqemu::bench::BenchArgs;
use sqemu::guest::kvstore::KvStore;
use sqemu::guest::ycsb::YcsbC;
use sqemu::guest::{Workload, WorkloadStats};
use sqemu::metrics::clock::VirtClock;
use sqemu::qcow::image::DataMode;
use sqemu::vdisk::{Driver, DriverKind};
use std::sync::Arc;

/// Attach-and-run workload (the store spans the whole populated chain).
struct YcsbOverChain {
    requests: u64,
}
// store spans the chain's populated clusters; see KvStore::attach_populated

impl Workload for YcsbOverChain {
    fn name(&self) -> &str {
        "ycsb-c-chain"
    }

    fn run(
        &mut self,
        driver: &mut dyn Driver,
        clock: &Arc<VirtClock>,
    ) -> anyhow::Result<WorkloadStats> {
        let store = KvStore::attach_populated(driver)?;
        let mut y = YcsbC::unchecked(store, self.requests, 0x4C5B);
        y.run(driver, clock)
    }
}

fn main() {
    let args = BenchArgs::parse();
    let chains: Vec<usize> = if args.full { vec![50, 500] } else { vec![50, 200] };
    let caches: Vec<u64> = vec![1 << 20, 3 << 20];
    let requests = if args.full {
        500_000
    } else if args.quick {
        20_000
    } else {
        100_000
    };

    let mut t = Table::new(
        "fig18_ycsb",
        &format!("YCSB-C over the chain-backed store ({requests} requests)"),
        &[
            "chain", "cache", "vq_kops", "sq_kops", "thr_gain_pct",
            "vq_exec_s", "sq_exec_s", "time_cut_pct",
        ],
    );
    for &chain_len in &chains {
        for &cache in &caches {
            let cfg = ExpConfig {
                disk_size: args.disk_size(),
                chain_len,
                // §6.1: disk populated at 25% for macro-benchmarks
                populated: 0.25,
                // Fig 18 sets Qemu's l2-cache-size, which is per driver
                // instance — vanilla gets the budget per file (unlike
                // Fig 16's equal-total comparison)
                cache_bytes: cache,
                split_vanilla_cache: false,
                data_mode: DataMode::Synthetic,
                ..Default::default()
            };
            let v = run_workload(
                DriverKind::Vanilla,
                &cfg,
                &mut YcsbOverChain { requests },
            )
            .unwrap();
            let s = run_workload(
                DriverKind::Scalable,
                &cfg,
                &mut YcsbOverChain { requests },
            )
            .unwrap();
            let (vi, si) = (v.stats.iops(), s.stats.iops());
            let (vt, st) = (
                v.stats.elapsed_ns as f64 / 1e9,
                s.stats.elapsed_ns as f64 / 1e9,
            );
            t.row(&[
                chain_len.to_string(),
                format!("{}M", cache >> 20),
                f2(vi / 1e3),
                f2(si / 1e3),
                f1(100.0 * (si - vi) / vi),
                f2(vt),
                f2(st),
                f1(100.0 * (vt - st) / vt),
            ]);
        }
    }
    t.finish();
    println!(
        "\npaper shape: sqemu throughput gain grows with the chain (+33% @ 50, \
         +47% @ 500); execution time cut 22-40%; cache size is secondary at \
         fixed chain"
    );
}
