//! Fig 19 — the cost of SQEMU's snapshot-time L2 copy (§6.5):
//! (a) per-snapshot disk-usage overhead vs disk size (Eq. 2),
//! (b) snapshot creation time vs disk size. Paper: ~6 MiB and ~70 ms at
//! 50 GiB; 7-12x slower than vanilla but O(ms).

use sqemu::bench::table::{f1, f2, Table};
use sqemu::bench::BenchArgs;
use sqemu::chaingen::{generate, ChainSpec};
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::qcow::image::DataMode;
use sqemu::qcow::layout::Geometry;
use sqemu::qcow::snapshot;
use sqemu::storage::node::StorageNode;
use sqemu::util::human_bytes;

fn main() {
    let args = BenchArgs::parse();
    // paper sweeps 50..200 GiB; scaled 4..16 GiB
    let disks: Vec<u64> = if args.full {
        vec![50 << 30, 100 << 30, 150 << 30, 200u64 << 30]
    } else {
        vec![4 << 30, 8 << 30, 12 << 30, 16 << 30]
    };

    let mut t = Table::new(
        "fig19_snapshot",
        "snapshot creation: disk overhead (worst case) + creation time",
        &[
            "disk", "eq2_MiB", "measured_MiB", "vq_snap_ms", "sq_snap_ms", "slowdown_x",
        ],
    );
    for &disk in &disks {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        // worst case: every cluster allocated ("the disk is full")
        let mut chain = generate(
            &node,
            &ChainSpec {
                disk_size: disk,
                chain_len: 1,
                populated: 1.0,
                stamped: true,
                data_mode: DataMode::Synthetic,
                prefix: format!("d{disk}"),
                ..Default::default()
            },
        )
        .unwrap();
        let geom = Geometry::new(16, disk).unwrap();
        let eq2 = geom.num_vclusters() * 8; // Eq. 2: disk/cluster * entry

        let t0 = clock.now();
        snapshot::snapshot_sqemu(&mut chain, &node, &format!("d{disk}-sq")).unwrap();
        let sq_ns = clock.now() - t0;
        let s_sq = chain.active().file_len();

        let t0 = clock.now();
        snapshot::snapshot_vanilla(&mut chain, &node, &format!("d{disk}-vq")).unwrap();
        let vq_ns = clock.now() - t0;
        let s_vq = chain.active().file_len();

        let overhead = s_sq.saturating_sub(s_vq);
        t.row(&[
            human_bytes(disk),
            f1(eq2 as f64 / (1 << 20) as f64),
            f1(overhead as f64 / (1 << 20) as f64),
            f2(vq_ns as f64 / 1e6),
            f2(sq_ns as f64 / 1e6),
            f1(sq_ns as f64 / vq_ns.max(1) as f64),
        ]);
    }
    t.finish();
    println!(
        "\npaper shape: overhead linear in disk size and matching Eq. 2 (~6 MiB \
         per snapshot at 50 GiB); sqemu snapshotting 7-12x slower than vanilla \
         but absolute cost stays in the ms range"
    );
}
