//! Fig 20 (beyond the paper — §4.1 extended to the online setting):
//! guest-visible latency while a chain is being shortened.
//!
//! The paper measures streaming as an offline, stop-the-world merge and
//! reports guests suffering a ~100x latency hit. This bench compares:
//!
//! * **offline** — `stream_merge` of the whole chain with the VM
//!   paused: every guest request arriving during the merge waits for
//!   the full pause window.
//! * **live** — the `blockjob` engine at several rate limits: requests
//!   keep being served between bounded increments; a request waits for
//!   at most one increment plus its own service time.
//!
//! Open-loop harness: guest requests arrive every `ARRIVAL_NS` of
//! virtual time; the job soaks idle time between arrivals (its I/O
//! charges the same virtual clock, so any overshoot past an arrival
//! shows up as queueing delay in that request's latency).

use sqemu::bench::table::{f1, f2, Table};
use sqemu::bench::BenchArgs;
use sqemu::blockjob::{JobKind, JobRunner, JobShared, LiveStreamJob, Step};
use sqemu::cache::CacheConfig;
use sqemu::chaingen::{generate, ChainSpec};
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::histogram::Histogram;
use sqemu::metrics::memory::MemoryAccountant;
use sqemu::qcow::image::DataMode;
use sqemu::qcow::snapshot;
use sqemu::storage::node::StorageNode;
use sqemu::util::rng::Rng;
use sqemu::vdisk::scalable::ScalableDriver;
use sqemu::vdisk::Driver;
use std::sync::Arc;

const ARRIVAL_NS: u64 = 300_000; // one guest request per 300 µs
const OP_BYTES: usize = 4096;

fn spec(disk: u64, chain_len: usize) -> ChainSpec {
    ChainSpec {
        disk_size: disk,
        chain_len,
        populated: 0.3,
        stamped: true,
        data_mode: DataMode::Synthetic,
        prefix: "live".into(),
        seed: 0xF16_20,
        ..Default::default()
    }
}

fn fresh_driver(
    disk: u64,
    chain_len: usize,
) -> (Arc<VirtClock>, ScalableDriver) {
    let clock = VirtClock::new();
    let node = StorageNode::new("s", clock.clone(), CostModel::default());
    let chain = generate(&*node, &spec(disk, chain_len)).unwrap();
    let d = ScalableDriver::new(
        chain,
        CacheConfig::new(512, 2 << 20),
        clock.clone(),
        CostModel::default(),
        MemoryAccountant::new(),
    );
    (clock, d)
}

fn guest_op(d: &mut ScalableDriver, rng: &mut Rng, disk: u64) {
    let voff = rng.below(disk - OP_BYTES as u64);
    if rng.chance(0.2) {
        d.write(voff, &[7u8; OP_BYTES]).unwrap();
    } else {
        let mut buf = vec![0u8; OP_BYTES];
        d.read(voff, &mut buf).unwrap();
    }
}

/// Offline baseline: merge the whole chain with the guest paused; the
/// pause window is the worst-case latency of any request queued behind
/// it. Returns (merge_ns, copied_clusters).
fn offline_merge(disk: u64, chain_len: usize) -> (u64, u64) {
    let (clock, mut d) = fresh_driver(disk, chain_len);
    let t0 = clock.now();
    let to = (d.chain().len() - 1) as u16;
    let copied = snapshot::stream_merge(d.chain_mut(), 0, to).unwrap();
    d.reopen().unwrap();
    (clock.now() - t0, copied)
}

/// Live run at `rate_bps` (0 = unlimited). Returns (job_ns, copied,
/// served_during_job, latency histogram of requests served while the
/// job ran).
fn live_run(disk: u64, chain_len: usize, rate_bps: u64) -> (u64, u64, u64, Histogram) {
    let (clock, mut d) = fresh_driver(disk, chain_len);
    let fence = Arc::clone(d.fence());
    let shared = Arc::new(JobShared::new("fig20", JobKind::Stream, rate_bps));
    let job = Box::new(LiveStreamJob::new(d.chain(), Arc::clone(&fence)));
    let cluster = d.chain().active().geom().cluster_size();
    let mut runner = JobRunner::new(job, Arc::clone(&shared), fence, 32, 32 * cluster, clock.now());
    let t0 = clock.now();
    let mut rng = Rng::new(0x6E57);
    let mut hist = Histogram::new();
    let mut next_arrival = clock.now() + ARRIVAL_NS;
    let mut served = 0u64;
    let mut finished_at = None;
    while finished_at.is_none() {
        // job soaks the time until the next guest arrival
        loop {
            let now = clock.now();
            if now >= next_arrival {
                break;
            }
            match runner.step(&mut d, now) {
                Step::Ran => {}
                Step::Starved { ready_at } => {
                    let target = ready_at.min(next_arrival);
                    if target > now {
                        clock.advance(target - now);
                    }
                    if ready_at >= next_arrival {
                        break;
                    }
                }
                Step::Finished => {
                    finished_at = Some(clock.now());
                    break;
                }
                Step::Paused => break,
            }
        }
        if finished_at.is_some() {
            break;
        }
        // serve one request; overshoot past the arrival is queueing delay
        let now = clock.now();
        if now < next_arrival {
            clock.advance(next_arrival - now);
        }
        let arrival = next_arrival;
        guest_op(&mut d, &mut rng, disk);
        hist.record(clock.now() - arrival);
        served += 1;
        next_arrival = arrival + ARRIVAL_NS;
    }
    let st = shared.status();
    assert!(st.error.is_none(), "job failed: {:?}", st.error);
    assert_eq!(d.chain().len(), 1, "chain collapsed live");
    (finished_at.unwrap() - t0, st.copied, served, hist)
}

fn main() {
    let args = BenchArgs::parse();
    let (disk, chain_len) = if args.full {
        (1u64 << 30, 1000)
    } else if args.quick {
        (64u64 << 20, 50)
    } else {
        (256u64 << 20, 100)
    };
    // ≥3 rate-limit settings plus unlimited
    let rates: [u64; 4] = [64 << 20, 256 << 20, 1 << 30, 0];

    let mut t = Table::new(
        "fig20_live_blockjobs",
        "guest latency while shortening the chain: offline merge vs live stream",
        &[
            "mode", "rate_MiBps", "chain", "copied", "job_ms", "served",
            "p50_us", "p99_us", "max_us",
        ],
    );

    let (pause_ns, copied) = offline_merge(disk, chain_len);
    // a request arriving mid-merge waits for the remaining pause: the
    // whole window is the worst case and ~the p99 of queued requests
    t.row(&[
        "offline".into(),
        "-".into(),
        format!("{chain_len}"),
        format!("{copied}"),
        f2(pause_ns as f64 / 1e6),
        "0".into(),
        f1(pause_ns as f64 / 1e3),
        f1(pause_ns as f64 / 1e3),
        f1(pause_ns as f64 / 1e3),
    ]);

    for &rate in &rates {
        let (job_ns, copied, served, hist) = live_run(disk, chain_len, rate);
        t.row(&[
            "live".into(),
            if rate == 0 { "inf".into() } else { format!("{}", rate >> 20) },
            format!("{chain_len}"),
            format!("{copied}"),
            f2(job_ns as f64 / 1e6),
            format!("{served}"),
            f1(hist.quantile(0.50) as f64 / 1e3),
            f1(hist.quantile(0.99) as f64 / 1e3),
            f1(hist.max() as f64 / 1e3),
        ]);
    }
    t.finish();
    println!(
        "\npaper shape: the offline merge stalls the guest for the whole window \
         (§4.1's disruption); the live job keeps serving — p99 stays within one \
         increment of the no-job baseline and falls as the rate limit tightens, \
         trading job completion time for guest latency"
    );
}
