//! Fig 21 (beyond the paper — §3's capacity problem closed): node
//! used-bytes over time while 100-deep chains stream, with and without
//! garbage collection.
//!
//! Setup: 8 sqemu chains share one base image (§3/Fig 8 sharing); each
//! chain is `depth` snapshots deep with one populated cluster per layer.
//! The chains stream to length 1 one after another. Without GC, every
//! dropped backing file stays on the node forever — used-bytes only
//! grows (the merges even add copies), which is exactly the leak PR 1
//! shipped. With GC, each stream's drop set is condemned and a sweep
//! returns the capacity; the shared base survives until the last chain
//! streams, then goes too.
//!
//! Columns: `used_MiB` is physical storage, `pressure_MiB` is what thin
//! provisioning counts (condemned files excluded — capacity reopens for
//! placement before the sweep finishes), `reclaimed_MiB` is cumulative.

use sqemu::bench::table::{f2, Table};
use sqemu::bench::BenchArgs;
use sqemu::cache::CacheConfig;
use sqemu::coordinator::server::VmChain;
use sqemu::coordinator::{Coordinator, VmConfig};
use sqemu::qcow::entry::L2Entry;
use sqemu::qcow::image::{DataMode, Image};
use sqemu::qcow::layout::{Geometry, FEATURE_BFI};
use sqemu::qcow::{snapshot, Chain};
use sqemu::storage::store::FileStore;
use sqemu::vdisk::DriverKind;
use std::sync::Arc;

const N_CHAINS: usize = 8;

/// Build the shared-base fleet: one base, `N_CHAINS` chains of `depth`
/// snapshots on top of it, one VM per chain.
fn build_fleet(coord: &Arc<Coordinator>, depth: usize) {
    let nodes = Arc::clone(&coord.nodes);
    let b = nodes.create_file("base").unwrap();
    let base = Image::create(
        "base",
        b,
        Geometry::new(16, 64 << 20).unwrap(),
        FEATURE_BFI,
        0,
        None,
        DataMode::Real,
    )
    .unwrap();
    let off = base.alloc_data_cluster().unwrap();
    base.write_data(off, 0, &[0xBB; 4096]).unwrap();
    base.set_l2_entry(0, L2Entry::local(off, Some(0))).unwrap();
    drop(base);
    for k in 0..N_CHAINS {
        let mut chain = Chain::open(nodes.as_ref(), "base", DataMode::Real).unwrap();
        for d in 1..=depth {
            snapshot::snapshot_sqemu(&mut chain, nodes.as_ref(), &format!("c{k}-{d}"))
                .unwrap();
            let img = chain.active();
            let off = img.alloc_data_cluster().unwrap();
            img.write_data(off, 0, &[(k + d) as u8; 4096]).unwrap();
            img.set_l2_entry(
                (1 + (d % 500)) as u64,
                L2Entry::local(off, Some(img.chain_index())),
            )
            .unwrap();
        }
        coord
            .launch_vm(
                &format!("vm-{k}"),
                VmConfig {
                    driver: DriverKind::Scalable,
                    cache: CacheConfig::new(128, 2 << 20),
                    chain: VmChain::Existing {
                        active_name: format!("c{k}-{depth}"),
                        data_mode: DataMode::Real,
                    },
                },
            )
            .unwrap();
    }
}

struct Sample {
    label: String,
    t_ms: f64,
    used_mib: f64,
    pressure_mib: f64,
    condemned: u64,
    reclaimed_mib: f64,
}

/// Stream every chain to length 1; with `with_gc`, run a sweep after
/// each stream. Returns the capacity timeline.
fn run(depth: usize, with_gc: bool) -> Vec<Sample> {
    let coord = Coordinator::with_fresh_nodes(1).unwrap();
    build_fleet(&coord, depth);
    let reg = Arc::clone(coord.gc_registry());
    let mib = |b: u64| b as f64 / (1 << 20) as f64;
    let mut samples = Vec::new();
    let mut sample = |label: String, coord: &Arc<Coordinator>| {
        let node = &coord.nodes.nodes()[0];
        samples.push(Sample {
            label,
            t_ms: coord.clock.now() as f64 / 1e6,
            used_mib: mib(node.used_bytes()),
            pressure_mib: mib(node.pressure_bytes()),
            condemned: reg.condemned_count() as u64,
            reclaimed_mib: mib(reg.reclaimed_total()),
        });
    };
    sample("setup".into(), &coord);
    for k in 0..N_CHAINS {
        coord
            .stream_vm(&format!("vm-{k}"), 0, depth as u16)
            .unwrap();
        sample(format!("stream-{k}"), &coord);
        if with_gc {
            coord.run_gc(0).unwrap();
            // shared-base invariant, visible in the timeline: the base
            // outlives every sweep but the one after the last stream
            let base_alive = coord.nodes.locate("base").is_some();
            assert_eq!(base_alive, k + 1 < N_CHAINS, "base lifetime wrong");
            sample(format!("gc-{k}"), &coord);
        }
    }
    coord.shutdown();
    samples
}

fn main() {
    let args = BenchArgs::parse();
    let depth = if args.full {
        500
    } else if args.quick {
        25
    } else {
        100
    };

    let mut t = Table::new(
        "fig21_gc_reclaim",
        "node capacity while streaming 8 shared-base chains: GC vs none",
        &[
            "mode", "event", "t_ms", "used_MiB", "pressure_MiB", "condemned",
            "reclaimed_MiB",
        ],
    );
    for with_gc in [false, true] {
        let mode = if with_gc { "gc" } else { "no-gc" };
        let samples = run(depth, with_gc);
        let last_used = samples.last().map(|s| s.used_mib).unwrap_or(0.0);
        for s in &samples {
            t.row(&[
                mode.into(),
                s.label.clone(),
                f2(s.t_ms),
                f2(s.used_mib),
                f2(s.pressure_mib),
                format!("{}", s.condemned),
                f2(s.reclaimed_mib),
            ]);
        }
        if with_gc {
            println!(
                "gc: final footprint {last_used:.2} MiB across {N_CHAINS} \
                 collapsed single-file chains"
            );
        } else {
            println!(
                "no-gc: {last_used:.2} MiB stranded on the node after all \
                 chains collapsed (the PR 1 leak)"
            );
        }
    }
    t.finish();
    println!(
        "\npaper shape: without GC the node's used-bytes never comes back \
         after a stream — §3's 500-file chains would strand their whole \
         history; with GC each sweep returns the dropped files' capacity, \
         thin-provisioning pressure falls the moment files are condemned, \
         and the shared base image is reclaimed only after the last \
         referencing chain streams"
    );
}
