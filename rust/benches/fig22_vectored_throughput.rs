//! Fig 22 (ours) — vectored vs per-cluster request paths, simulated
//! throughput and device-I/O counts.
//!
//! Once resolution is O(1) (the paper's contribution), the remaining
//! per-request costs dominate: every guest request pays one device seek
//! (`T_L + T_D`) even when its neighbours are physically contiguous, and
//! every cluster pays a cache probe even when 16 of them live in one
//! resident slice. The vectored pipeline (readv -> slice-group
//! resolution -> contiguity coalescer -> `Backend::read_vectored`)
//! amortizes both. This bench measures sequential 4 KiB reads and
//! YCSB-style batched point reads on stamped chains of 1/100/500 files,
//! per-cluster vs vectored, in virtual time.

use sqemu::bench::smoke::{device_ios, mbps, seq4k_compare};
use sqemu::bench::table::{f1, Table};
use sqemu::bench::BenchArgs;
use sqemu::cache::CacheConfig;
use sqemu::chaingen::{generate, ChainSpec};
use sqemu::guest::kvstore::KvStore;
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::memory::MemoryAccountant;
use sqemu::qcow::image::DataMode;
use sqemu::storage::node::StorageNode;
use sqemu::util::rng::Rng;
use sqemu::vdisk::scalable::ScalableDriver;
use std::sync::Arc;

fn driver(len: usize, disk: u64, prefix: &str) -> (ScalableDriver, Arc<VirtClock>) {
    let clock = VirtClock::new();
    let node = StorageNode::new("fig22", clock.clone(), CostModel::default());
    let chain = generate(
        &*node,
        &ChainSpec {
            disk_size: disk,
            chain_len: len,
            populated: 1.0,
            stamped: true,
            data_mode: DataMode::Synthetic,
            prefix: prefix.into(),
            ..Default::default()
        },
    )
    .unwrap();
    let geom = *chain.active().geom();
    (
        ScalableDriver::new(
            chain,
            CacheConfig::full_disk(&geom),
            clock.clone(),
            CostModel::default(),
            MemoryAccountant::new(),
        ),
        clock,
    )
}

fn main() {
    let args = BenchArgs::parse();
    let disk: u64 = if args.full { 1 << 30 } else { 256 << 20 };
    let region: u64 = if args.quick { 2 << 20 } else { 16 << 20 };
    let lens: Vec<usize> = if args.quick { vec![1, 50] } else { vec![1, 100, 500] };

    let mut seq = Table::new(
        "fig22_vectored_seq",
        "sequential 4K reads: per-request vs vectored 1 MiB submissions",
        &[
            "chain",
            "scalar_MBps",
            "vec_MBps",
            "speedup",
            "scalar_IOs",
            "vec_IOs",
            "merged",
            "vec_probes",
        ],
    );
    let mut rand_t = Table::new(
        "fig22_vectored_rand",
        "YCSB-C point reads: get() loop vs multi_get batches of 32",
        &["chain", "scalar_MBps", "vec_MBps", "speedup", "scalar_IOs", "vec_IOs"],
    );

    for &len in &lens {
        // ---------------------------------------------- sequential 4 KiB
        let (mut d, clock) = driver(len, disk, &format!("sq-{len}"));
        let cmp = seq4k_compare(&mut d, &clock, region).unwrap();
        let (sm, vm) = (mbps(region, cmp.scalar_ns), mbps(region, cmp.vectored_ns));
        seq.row(&[
            len.to_string(),
            f1(sm),
            f1(vm),
            f1(vm / sm),
            cmp.scalar_device_ios.to_string(),
            cmp.vectored_device_ios.to_string(),
            cmp.merged_ios.to_string(),
            cmp.vectored_probes.to_string(),
        ]);

        // ------------------------------------- YCSB-style uniform reads
        let (mut d, clock) = driver(len, disk, &format!("yc-{len}"));
        let kv = KvStore::attach_spread(&d, 0.4).unwrap();
        let ops: u64 = if args.quick { 512 } else { 4096 };
        let mut rng = Rng::new(0xF1622 ^ len as u64);
        let keys: Vec<u64> = (0..ops).map(|_| rng.below(kv.records)).collect();
        // warm both paths' slices
        for &k in keys.iter().take(64) {
            kv.get_unchecked(&mut d, k).unwrap();
        }
        let ios0 = device_ios(&d);
        let t0 = clock.now();
        for &k in &keys {
            kv.get_unchecked(&mut d, k).unwrap();
        }
        let scalar_ns = clock.now() - t0;
        let scalar_ios = device_ios(&d) - ios0;
        let ios1 = device_ios(&d);
        let t1 = clock.now();
        for batch in keys.chunks(32) {
            kv.multi_get_unchecked(&mut d, batch).unwrap();
        }
        let vec_ns = clock.now() - t1;
        let vec_ios = device_ios(&d) - ios1;
        let bytes = ops * 4096;
        let (sm, vm) = (mbps(bytes, scalar_ns), mbps(bytes, vec_ns));
        rand_t.row(&[
            len.to_string(),
            f1(sm),
            f1(vm),
            f1(vm / sm),
            scalar_ios.to_string(),
            vec_ios.to_string(),
        ]);
    }
    seq.finish();
    rand_t.finish();
    println!(
        "\nreading: vectored sequential throughput is bounded by bandwidth + one \
         seek per contiguous run instead of one seek per request; random point \
         reads gain mainly from slice-group resolution (probes) and the \
         occasional same-slice coalesce"
    );
}
