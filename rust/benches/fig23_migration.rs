//! Fig 23 (beyond the paper — §3's placement problem closed): live
//! chain migration and the fleet rebalancer.
//!
//! Part A — guest-visible latency while a VM's whole chain is mirrored
//! to another storage node, at several migration rate limits (the fig20
//! open-loop harness pointed at a `MirrorJob`): requests keep being
//! served between bounded increments, so p99 stays within one increment
//! of the no-job baseline and tightens as the rate limit drops, trading
//! migration time for guest latency.
//!
//! Part B — fleet balance over time: an 8-chain fleet deliberately
//! skewed onto node-0 (the drift §3 says placement accumulates), with
//! and without the rebalancer. Without, the max/min pressure ratio
//! never moves; with, each migration plus a GC sweep walks it under the
//! 1.5x threshold.
//!
//! Emits `BENCH_fig23.json` (CI uploads it as an artifact).

use sqemu::bench::table::{f1, f2, Table};
use sqemu::bench::BenchArgs;
use sqemu::blockjob::{JobKind, JobRunner, JobShared, Step};
use sqemu::cache::CacheConfig;
use sqemu::chaingen::{generate, ChainSpec};
use sqemu::coordinator::placement::NodeSet;
use sqemu::coordinator::server::VmChain;
use sqemu::coordinator::{Coordinator, VmConfig};
use sqemu::gc::GcRegistry;
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::histogram::Histogram;
use sqemu::metrics::memory::MemoryAccountant;
use sqemu::migrate::MirrorJob;
use sqemu::qcow::image::DataMode;
use sqemu::storage::node::StorageNode;
use sqemu::util::rng::Rng;
use sqemu::vdisk::scalable::ScalableDriver;
use sqemu::vdisk::{Driver, DriverKind};
use std::fmt::Write as _;
use std::sync::Arc;

const ARRIVAL_NS: u64 = 300_000; // one guest request per 300 µs
const OP_BYTES: usize = 4096;

fn spec(disk: u64, chain_len: usize, prefix: &str) -> ChainSpec {
    ChainSpec {
        disk_size: disk,
        chain_len,
        populated: 0.3,
        stamped: true,
        data_mode: DataMode::Synthetic,
        prefix: prefix.into(),
        seed: 0xF16_23,
        ..Default::default()
    }
}

/// Two-node fleet with the whole chain pinned to node-0.
fn fresh_driver(
    disk: u64,
    chain_len: usize,
) -> (Arc<VirtClock>, Arc<NodeSet>, Arc<GcRegistry>, ScalableDriver) {
    let clock = VirtClock::new();
    let nodes = Arc::new(
        NodeSet::new(vec![
            StorageNode::new("node-0", clock.clone(), CostModel::default()),
            StorageNode::new("node-1", clock.clone(), CostModel::default()),
        ])
        .unwrap(),
    );
    let store = nodes.pinned("node-0").unwrap();
    let chain = generate(&store, &spec(disk, chain_len, "mig")).unwrap();
    let gc = Arc::new(GcRegistry::new(Arc::clone(&nodes)));
    gc.sync_chain("vm", chain.file_names());
    let d = ScalableDriver::new(
        chain,
        CacheConfig::new(512, 2 << 20),
        clock.clone(),
        CostModel::default(),
        MemoryAccountant::new(),
    );
    (clock, nodes, gc, d)
}

fn guest_op(d: &mut ScalableDriver, rng: &mut Rng, disk: u64) {
    let voff = rng.below(disk - OP_BYTES as u64);
    if rng.chance(0.2) {
        d.write(voff, &[7u8; OP_BYTES]).unwrap();
    } else {
        let mut buf = vec![0u8; OP_BYTES];
        d.read(voff, &mut buf).unwrap();
    }
}

struct MigRun {
    job_ns: u64,
    copied: u64,
    bytes: u64,
    served: u64,
    hist: Histogram,
}

/// Open-loop migration run at `rate_bps` (0 = unlimited): guest
/// requests arrive every ARRIVAL_NS of virtual time, the mirror soaks
/// the idle time between them.
fn live_migrate(disk: u64, chain_len: usize, rate_bps: u64) -> MigRun {
    let (clock, nodes, gc, mut d) = fresh_driver(disk, chain_len);
    d.flush().unwrap();
    let fence = Arc::clone(d.fence());
    let shared = Arc::new(JobShared::new("fig23", JobKind::Mirror, rate_bps));
    let job = Box::new(
        MirrorJob::new(d.chain(), Arc::clone(&nodes), Arc::clone(&gc), "node-1", "vm")
            .unwrap(),
    );
    let cluster = d.chain().active().geom().cluster_size();
    let mut runner =
        JobRunner::new(job, Arc::clone(&shared), fence, 32, 32 * cluster, clock.now());
    let t0 = clock.now();
    let mut rng = Rng::new(0x6E57);
    let mut hist = Histogram::new();
    let mut next_arrival = clock.now() + ARRIVAL_NS;
    let mut served = 0u64;
    let mut finished_at = None;
    while finished_at.is_none() {
        loop {
            let now = clock.now();
            if now >= next_arrival {
                break;
            }
            match runner.step(&mut d, now) {
                Step::Ran => {}
                Step::Starved { ready_at } => {
                    let target = ready_at.min(next_arrival);
                    if target > now {
                        clock.advance(target - now);
                    }
                    if ready_at >= next_arrival {
                        break;
                    }
                }
                Step::Finished => {
                    finished_at = Some(clock.now());
                    break;
                }
                Step::Paused => break,
            }
        }
        if finished_at.is_some() {
            break;
        }
        let now = clock.now();
        if now < next_arrival {
            clock.advance(next_arrival - now);
        }
        let arrival = next_arrival;
        guest_op(&mut d, &mut rng, disk);
        hist.record(clock.now() - arrival);
        served += 1;
        next_arrival = arrival + ARRIVAL_NS;
    }
    let st = shared.status();
    assert!(st.error.is_none(), "migration failed: {:?}", st.error);
    // the whole chain now resolves to node-1
    for f in d.chain().file_names() {
        assert_eq!(nodes.locate(&f).as_deref(), Some("node-1"), "{f}");
    }
    MigRun {
        job_ns: finished_at.unwrap() - t0,
        copied: st.copied,
        bytes: st.bytes_copied,
        served,
        hist,
    }
}

struct RatioSample {
    mode: &'static str,
    event: String,
    pressures: Vec<u64>,
    ratio: f64,
}

/// Part B: the 8-chain skewed fleet, with or without the rebalancer.
fn fleet_timeline(chain_len: usize, with_rebalancer: bool) -> Vec<RatioSample> {
    let mode = if with_rebalancer { "rebalance" } else { "static" };
    let coord = Coordinator::with_fresh_nodes(2).unwrap();
    for v in 0..8usize {
        let pin = if v == 7 { "node-1" } else { "node-0" };
        let store = coord.nodes.pinned(pin).unwrap();
        let name = format!("vm-{v}");
        generate(&store, &spec(32 << 20, chain_len, &name)).unwrap();
        coord
            .launch_vm(
                &name,
                VmConfig {
                    driver: DriverKind::Scalable,
                    cache: CacheConfig::new(128, 2 << 20),
                    chain: VmChain::Existing {
                        active_name: format!("{name}-{}", chain_len - 1),
                        data_mode: DataMode::Synthetic,
                    },
                },
            )
            .unwrap();
    }
    let sample = |event: String, coord: &Arc<Coordinator>| -> RatioSample {
        let pressures: Vec<u64> = coord
            .nodes
            .nodes()
            .iter()
            .map(|n| n.committed_bytes())
            .collect();
        RatioSample {
            mode,
            event,
            ratio: sqemu::migrate::rebalance::pressure_ratio(&pressures),
            pressures,
        }
    };
    let mut samples = vec![sample("setup".into(), &coord)];
    if with_rebalancer {
        // plan once (dry run), then execute move by move so the
        // timeline shows each migration landing
        let plan = coord.rebalance(1.5, 0, true).unwrap().plan;
        for (i, m) in plan.moves.iter().enumerate() {
            let shared = coord.migrate_vm(&m.vm, &m.to, 0).unwrap();
            let st = coord.wait_job(&shared);
            assert!(st.error.is_none(), "move of {} failed: {:?}", m.vm, st.error);
            samples.push(sample(format!("move-{i}:{}->{}", m.from, m.to), &coord));
        }
        coord.run_gc(0).unwrap();
        samples.push(sample("gc".into(), &coord));
        assert!(
            samples.last().unwrap().ratio <= 1.5,
            "rebalancer left the fleet skewed: {:.2}",
            samples.last().unwrap().ratio
        );
    } else {
        samples.push(sample("end".into(), &coord));
    }
    coord.shutdown();
    samples
}

fn main() {
    let args = BenchArgs::parse();
    let (disk, chain_len) = if args.full {
        (1u64 << 30, 500)
    } else if args.quick {
        (32u64 << 20, 25)
    } else {
        (128u64 << 20, 100)
    };
    let rates: [u64; 3] = [64 << 20, 256 << 20, 0];

    let mut t = Table::new(
        "fig23_migration",
        "guest latency during live chain migration + fleet balance timeline",
        &[
            "part", "mode", "rate_MiBps", "chain", "copied", "job_ms", "served",
            "p50_us", "p99_us", "max_us",
        ],
    );
    let mut json = String::new();
    json.push_str("{\n  \"schema\": \"sqemu-bench-fig23/1\",\n  \"migration\": [\n");
    for (i, &rate) in rates.iter().enumerate() {
        let r = live_migrate(disk, chain_len, rate);
        let rate_label = if rate == 0 {
            "inf".to_string()
        } else {
            format!("{}", rate >> 20)
        };
        t.row(&[
            "A".into(),
            "migrate".into(),
            rate_label,
            format!("{chain_len}"),
            format!("{}", r.copied),
            f2(r.job_ns as f64 / 1e6),
            format!("{}", r.served),
            f1(r.hist.quantile(0.50) as f64 / 1e3),
            f1(r.hist.quantile(0.99) as f64 / 1e3),
            f1(r.hist.max() as f64 / 1e3),
        ]);
        let _ = writeln!(
            json,
            "    {{\"rate_bps\": {rate}, \"chain\": {chain_len}, \
             \"copied_chunks\": {}, \"bytes\": {}, \"job_ns\": {}, \
             \"served\": {}, \"p50_ns\": {}, \"p99_ns\": {}, \"max_ns\": {}}}{}",
            r.copied,
            r.bytes,
            r.job_ns,
            r.served,
            r.hist.quantile(0.50),
            r.hist.quantile(0.99),
            r.hist.max(),
            if i + 1 < rates.len() { "," } else { "" },
        );
    }
    json.push_str("  ],\n  \"fleet\": [\n");

    let fleet_chain = chain_len.min(50);
    let mut all: Vec<RatioSample> = Vec::new();
    for with in [false, true] {
        all.extend(fleet_timeline(fleet_chain, with));
    }
    for (i, s) in all.iter().enumerate() {
        t.row(&[
            "B".into(),
            s.mode.into(),
            "-".into(),
            format!("{fleet_chain}"),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            f2(s.ratio),
        ]);
        let _ = writeln!(
            json,
            "    {{\"mode\": \"{}\", \"event\": \"{}\", \"pressures\": {:?}, \
             \"ratio\": {:.4}}}{}",
            s.mode,
            s.event,
            s.pressures,
            s.ratio,
            if i + 1 < all.len() { "," } else { "" },
        );
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_fig23.json", &json).expect("write BENCH_fig23.json");
    t.finish();
    println!(
        "\npaper shape: the mirror keeps the guest's p99 within one increment \
         while the whole chain changes nodes (tightening the rate limit trades \
         migration time for latency), and the rebalancer + GC walk a skewed \
         fleet's max/min pressure ratio under 1.5x — placement is now a \
         managed, continuously corrected decision instead of a create-time \
         accident\n(wrote BENCH_fig23.json)"
    );
}
