//! Fig 24 (beyond the paper — §3's capacity problem, multiplied):
//! logical vs physical bytes on a chaingen cloned-chain population,
//! with and without the capacity subsystem (zero clusters, compressed
//! clusters, content-addressed dedup).
//!
//! Setup: one golden 2-layer chain; every clone gets a private active
//! volume snapshotted over the SAME immutable backing files (the
//! `copy_virtual_disk` population). Each clone then runs an identical
//! write mix — all-zero clusters, constant (compressible) fills,
//! in-guest copies of readable content, and a thin stream of unique
//! data. With the subsystem off every write materializes a cluster in
//! the clone's active; with it on, zeros allocate nothing, constants
//! compress, and copies resolve to shared extents seeded from the
//! golden base at launch.
//!
//! Acceptance: capacity-on logical/physical >= 3x on this population.
//! Emits `BENCH_fig24.json` (CI uploads it as an artifact).

use sqemu::bench::table::{f1, f2, Table};
use sqemu::bench::BenchArgs;
use sqemu::cache::CacheConfig;
use sqemu::chaingen::{generate, ChainSpec};
use sqemu::coordinator::placement::NodeSet;
use sqemu::coordinator::server::{CoordinatorConfig, VmChain};
use sqemu::coordinator::{Coordinator, VmConfig};
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::histogram::Histogram;
use sqemu::qcow::image::DataMode;
use sqemu::qcow::{snapshot, Chain};
use sqemu::storage::node::StorageNode;
use sqemu::util::rng::Rng;
use sqemu::vdisk::DriverKind;
use std::fmt::Write as _;
use std::sync::Arc;

const CS: u64 = 64 << 10;
const DISK: u64 = 32 << 20;
const CLUSTERS: u64 = DISK / CS;

struct Outcome {
    logical: u64,
    physical: u64,
    saved: u64,
    extents: u64,
    refs: u64,
    p50_ns: u64,
    p99_ns: u64,
}

fn run(capacity: bool, clones: usize, writes: u64) -> Outcome {
    let clock = VirtClock::new();
    let nodes = vec![StorageNode::new("node-0", clock.clone(), CostModel::default())];
    let coord = Coordinator::new(
        Arc::new(NodeSet::new(nodes).unwrap()),
        clock,
        CoordinatorConfig { capacity, ..Default::default() },
        None,
    );
    // golden base + per-clone actives over the shared immutable prefix
    let store = coord.nodes.pinned("node-0").unwrap();
    let mut gold = generate(
        &store,
        &ChainSpec {
            disk_size: DISK,
            chain_len: 2,
            populated: 0.25,
            stamped: true,
            data_mode: DataMode::Real,
            prefix: "gold".into(),
            seed: 0x601D,
            ..Default::default()
        },
    )
    .unwrap();
    snapshot::snapshot_sqemu(&mut gold, &store, "vm-0-active").unwrap();
    let shared: Vec<_> = gold.images()[..gold.len() - 1].to_vec();
    for v in 1..clones {
        let mut sib = Chain::new(Arc::clone(&shared[0])).unwrap();
        sib.replace_images(shared.clone());
        snapshot::snapshot_sqemu(&mut sib, &store, &format!("vm-{v}-active")).unwrap();
    }
    drop(gold);
    drop(shared);
    let clients: Vec<_> = (0..clones)
        .map(|v| {
            coord
                .launch_vm(
                    &format!("vm-{v}"),
                    VmConfig {
                        driver: DriverKind::Scalable,
                        cache: CacheConfig::new(128, 2 << 20),
                        chain: VmChain::Existing {
                            active_name: format!("vm-{v}-active"),
                            data_mode: DataMode::Real,
                        },
                    },
                )
                .unwrap()
        })
        .collect();
    // identical per-clone workload: the cloned-population write mix
    for c in &clients {
        let mut rng = Rng::new(0xF16_24);
        for i in 0..writes {
            let vc = rng.below(CLUSTERS);
            let data = match i % 8 {
                // all-zero clusters: OFLAG_ZERO, no allocation
                0 | 1 => vec![0u8; CS as usize],
                // constant fills: compress on first sight, dedup after
                2 | 3 => vec![0x40 | (i % 3) as u8; CS as usize],
                // a thin stream of unique data: must always be stored
                7 => {
                    let mut b = vec![0u8; CS as usize];
                    rng.fill_bytes(&mut b);
                    b
                }
                // in-guest copy of readable content: dedups against the
                // seeded golden base or an earlier write
                _ => {
                    let src = rng.below(CLUSTERS);
                    c.read(src * CS, CS as usize).unwrap()
                }
            };
            c.write(vc * CS, data).unwrap();
        }
        c.flush().unwrap();
    }
    // read latency over the resulting population (random 4 KiB reads
    // across zero, compressed, dedup-shared and plain clusters)
    let mut hist = Histogram::new();
    let mut rng = Rng::new(0x24_EAD);
    for c in &clients {
        for _ in 0..256 {
            let off = rng.below(DISK - 4096);
            let t0 = coord.clock.now();
            c.read(off, 4096).unwrap();
            hist.record(coord.clock.now() - t0);
        }
    }
    let cap_rows = coord.refresh_capacity();
    let (logical, physical) =
        cap_rows.iter().fold((0u64, 0u64), |(l, p), r| (l + r.1, p + r.2));
    let fleet = coord.dedup_index().fleet_stats();
    coord.shutdown();
    Outcome {
        logical,
        physical,
        saved: fleet.saved_bytes,
        extents: fleet.extents,
        refs: fleet.refs,
        p50_ns: hist.quantile(0.50),
        p99_ns: hist.quantile(0.99),
    }
}

fn main() {
    let args = BenchArgs::parse();
    let (clones, writes) = if args.full {
        (16, 384)
    } else if args.quick {
        (4, 96)
    } else {
        (8, 192)
    };
    let mut t = Table::new(
        "fig24_dedup_capacity",
        "cloned-population capacity: logical vs physical, subsystem off/on",
        &[
            "mode", "clones", "writes", "logical_MiB", "physical_MiB", "ratio",
            "saved_MiB", "extents", "p50_us", "p99_us",
        ],
    );
    let mib = |b: u64| b as f64 / (1 << 20) as f64;
    let mut json = String::new();
    json.push_str("{\n  \"schema\": \"sqemu-bench-fig24/1\",\n  \"runs\": [\n");
    let mut ratios = [0f64; 2];
    let mut physicals = [0u64; 2];
    for (k, capacity) in [false, true].into_iter().enumerate() {
        let o = run(capacity, clones, writes);
        let ratio = o.logical as f64 / o.physical.max(1) as f64;
        ratios[k] = ratio;
        physicals[k] = o.physical;
        let mode = if capacity { "capacity" } else { "baseline" };
        t.row(&[
            mode.into(),
            format!("{clones}"),
            format!("{writes}"),
            f2(mib(o.logical)),
            f2(mib(o.physical)),
            f2(ratio),
            f2(mib(o.saved)),
            format!("{}", o.extents),
            f1(o.p50_ns as f64 / 1e3),
            f1(o.p99_ns as f64 / 1e3),
        ]);
        let _ = writeln!(
            json,
            "    {{\"capacity\": {capacity}, \"clones\": {clones}, \
             \"writes\": {writes}, \"logical_bytes\": {}, \
             \"physical_bytes\": {}, \"ratio\": {ratio:.4}, \
             \"saved_bytes\": {}, \"extents\": {}, \"refs\": {}, \
             \"p50_ns\": {}, \"p99_ns\": {}}}{}",
            o.logical,
            o.physical,
            o.saved,
            o.extents,
            o.refs,
            o.p50_ns,
            o.p99_ns,
            if capacity { "" } else { "," },
        );
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_fig24.json", &json).expect("write BENCH_fig24.json");
    t.finish();
    let reduction = physicals[0] as f64 / physicals[1].max(1) as f64;
    println!(
        "\npaper shape: the cloned population stores its golden base once \
         regardless, but only the capacity subsystem keeps the clones' own \
         writes from multiplying it back out — zeros vanish, constants \
         compress, in-guest copies share extents. Capacity multiplication \
         {:.2}x (baseline {:.2}x), physical bytes reduced {reduction:.2}x \
         by the subsystem\n(wrote BENCH_fig24.json)",
        ratios[1], ratios[0],
    );
    assert!(
        ratios[1] >= 3.0,
        "capacity-on multiplication below the 3x acceptance bar: {:.2}",
        ratios[1]
    );
}
