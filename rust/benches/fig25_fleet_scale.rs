//! Fig 25 (beyond the paper — §3's fleet, scaled): the sharded data
//! plane serving a large modeled fleet.
//!
//! 10k VMs (full mode) are modeled in waves of concurrently running
//! clones: each wave snapshots a golden base into per-VM active volumes
//! (the clone-population shape), boot-storms the shared base, runs a
//! private COW write mix, flushes, and is decommissioned; a GC sweep
//! reclaims the wave before the next one launches, so the resident set
//! stays bounded while the run still pushes 10k launches through the
//! shard pool and the per-node I/O schedulers.
//!
//! Measured:
//! * device-time utilization — fraction of device-busy virtual time
//!   spent moving bytes at the cost model's theoretical bandwidth
//!   (the rest is seeks); cross-VM merge windows are what keep it high
//!   during the boot-storm and the contiguous write bursts.
//! * guest request latency p50/p99 (enqueue -> completion, virtual ns)
//!   aggregated over every VM's service histogram.
//!
//! Acceptance: utilization >= 0.90, and the schedulers must have merged
//! seeks across VMs (merged_seeks > 0). Emits `BENCH_fig25.json` (CI
//! uploads it as an artifact).

use sqemu::bench::table::{f1, f2, Table};
use sqemu::bench::BenchArgs;
use sqemu::cache::CacheConfig;
use sqemu::chaingen::{generate, ChainSpec};
use sqemu::coordinator::placement::NodeSet;
use sqemu::coordinator::server::{CoordinatorConfig, VmChain};
use sqemu::coordinator::{Coordinator, VmConfig};
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::histogram::Histogram;
use sqemu::qcow::image::DataMode;
use sqemu::qcow::{snapshot, Chain};
use sqemu::storage::node::StorageNode;
use sqemu::vdisk::DriverKind;
use std::fmt::Write as _;
use std::sync::Arc;

const CS: u64 = 64 << 10;
/// Golden base per wave: what every clone boot-storms.
const BASE: u64 = 8 << 20;
/// Private COW writes per VM (contiguous burst).
const WRITE_CLUSTERS: u64 = 16;

struct Outcome {
    vms: usize,
    utilization: f64,
    busy_ms: f64,
    moved_mib: f64,
    seeks: u64,
    merged_seeks: u64,
    p50_ns: u64,
    p99_ns: u64,
    shard_wakeups: u64,
    shard_passes: u64,
}

fn run(total_vms: usize, wave: usize, threads: usize) -> Outcome {
    let clock = VirtClock::new();
    let nodes: Vec<_> = (0..2)
        .map(|i| {
            StorageNode::new(&format!("node-{i}"), clock.clone(), CostModel::default())
        })
        .collect();
    let coord = Coordinator::new(
        Arc::new(NodeSet::new(nodes).unwrap()),
        clock,
        CoordinatorConfig::default(),
        None,
    );
    let mut lat_p50 = Histogram::new();
    let mut lat_p99 = Histogram::new();
    let waves = (total_vms + wave - 1) / wave;
    for w in 0..waves {
        let in_wave = wave.min(total_vms - w * wave);
        let store = coord.nodes.pinned(&format!("node-{}", w % 2)).unwrap();
        // golden base + per-clone actives over the shared immutable base
        let mut gold = generate(
            &store,
            &ChainSpec {
                disk_size: BASE,
                chain_len: 1,
                populated: 1.0,
                stamped: true,
                data_mode: DataMode::Real,
                prefix: format!("g{w}"),
                seed: 0xF25 + w as u64,
                ..Default::default()
            },
        )
        .unwrap();
        snapshot::snapshot_sqemu(&mut gold, &store, &format!("w{w}-v0-active")).unwrap();
        let shared: Vec<_> = gold.images()[..gold.len() - 1].to_vec();
        for v in 1..in_wave {
            let mut sib = Chain::new(Arc::clone(&shared[0])).unwrap();
            sib.replace_images(shared.clone());
            snapshot::snapshot_sqemu(&mut sib, &store, &format!("w{w}-v{v}-active"))
                .unwrap();
        }
        drop(gold);
        drop(shared);
        // the wave boots and runs concurrently across the shard pool
        let mut handles = Vec::new();
        for t in 0..threads {
            let coord = Arc::clone(&coord);
            handles.push(std::thread::spawn(move || {
                for v in (t..in_wave).step_by(threads) {
                    let name = format!("w{w}-v{v}");
                    let client = coord
                        .launch_vm(
                            &name,
                            VmConfig {
                                driver: DriverKind::Scalable,
                                cache: CacheConfig::new(32, 64 << 10),
                                chain: VmChain::Existing {
                                    active_name: format!("w{w}-v{v}-active"),
                                    data_mode: DataMode::Real,
                                },
                            },
                        )
                        .unwrap();
                    // boot storm: read the whole shared base as one
                    // vectored submission (cross-VM merge fodder)
                    let reqs: Vec<(u64, usize)> = (0..BASE / CS)
                        .map(|c| (c * CS, CS as usize))
                        .collect();
                    client.readv(&reqs).unwrap();
                    // private COW burst: contiguous clusters, one entry
                    let base = (v as u64 % 4) * WRITE_CLUSTERS * CS;
                    let burst: Vec<(u64, Vec<u8>)> = (0..WRITE_CLUSTERS)
                        .map(|k| {
                            (base + k * CS, vec![(v as u8) ^ (k as u8); CS as usize])
                        })
                        .collect();
                    client.writev(burst).unwrap();
                    client.flush().unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // drain the wave: latency histograms, then decommission + GC so
        // the resident set stays bounded across 10k modeled VMs
        let mut snaps = Vec::new();
        for v in 0..in_wave {
            snaps.push(coord.vm_stats(&format!("w{w}-v{v}")).unwrap());
        }
        for s in snaps {
            lat_p50.record(s.req_p50_ns);
            lat_p99.record(s.req_p99_ns);
        }
        for v in 0..in_wave {
            coord.decommission_vm(&format!("w{w}-v{v}")).unwrap();
        }
        coord.run_gc(0).unwrap();
    }
    let cost = CostModel::default();
    let (mut busy, mut fresh, mut seeks, mut merged) = (0u64, 0u64, 0u64, 0u64);
    for node in coord.nodes.nodes() {
        let s = node.scheduler().snapshot();
        busy += s.busy_ns;
        fresh += s.fresh_bytes;
        seeks += s.seeks;
        merged += s.merged_seeks;
    }
    let xfer = cost.io_ns(fresh) - cost.io_ns(0);
    let shards = coord.shard_stats();
    let outcome = Outcome {
        vms: total_vms,
        utilization: xfer as f64 / busy.max(1) as f64,
        busy_ms: busy as f64 / 1e6,
        moved_mib: fresh as f64 / (1 << 20) as f64,
        seeks,
        merged_seeks: merged,
        p50_ns: lat_p50.quantile(0.50),
        p99_ns: lat_p99.quantile(0.99),
        shard_wakeups: shards.iter().map(|s| s.wakeups).sum(),
        shard_passes: shards.iter().map(|s| s.passes).sum(),
    };
    // the telemetry registry must agree with the private tally above:
    // re-derive device-time utilization from the Prometheus scrape and
    // hold the two within 1% (they read the same schedulers, so any
    // divergence is an exporter bug, not noise)
    let text = coord.telemetry().render();
    let sum_family = |name: &str| -> u64 {
        text.lines()
            .filter(|l| l.starts_with(name) && l.contains('{'))
            .map(|l| {
                l.split_whitespace()
                    .nth(1)
                    .and_then(|v| v.parse::<u64>().ok())
                    .unwrap_or_else(|| panic!("unparsable scrape line: {l}"))
            })
            .sum()
    };
    let reg_busy = sum_family("sqemu_iosched_busy_ns_total");
    let reg_fresh = sum_family("sqemu_iosched_fresh_bytes_total");
    assert!(reg_busy > 0, "registry exported no device-busy time");
    let reg_xfer = cost.io_ns(reg_fresh) - cost.io_ns(0);
    let reg_util = reg_xfer as f64 / reg_busy as f64;
    let divergence =
        (reg_util - outcome.utilization).abs() / outcome.utilization.max(1e-9);
    println!(
        "telemetry cross-check: registry utilization {reg_util:.4} vs tallied \
         {:.4} ({:.3}% divergence)",
        outcome.utilization,
        divergence * 100.0,
    );
    assert!(
        divergence <= 0.01,
        "registry-derived utilization diverges from the private tally by \
         {:.3}% (> 1%)",
        divergence * 100.0,
    );
    coord.shutdown();
    outcome
}

fn main() {
    let args = BenchArgs::parse();
    let (total_vms, wave, threads) = if args.full {
        (10_000, 250, 8)
    } else if args.quick {
        (1_000, 250, 8)
    } else {
        (2_500, 250, 8)
    };
    let mut t = Table::new(
        "fig25_fleet_scale",
        "sharded data plane at fleet scale: device utilization and latency",
        &[
            "vms", "util", "busy_ms", "moved_MiB", "seeks", "merged_seeks",
            "p50_us", "p99_us", "passes", "wakeups",
        ],
    );
    let o = run(total_vms, wave, threads);
    t.row(&[
        format!("{}", o.vms),
        f2(o.utilization),
        f1(o.busy_ms),
        f1(o.moved_mib),
        format!("{}", o.seeks),
        format!("{}", o.merged_seeks),
        f1(o.p50_ns as f64 / 1e3),
        f1(o.p99_ns as f64 / 1e3),
        format!("{}", o.shard_passes),
        format!("{}", o.shard_wakeups),
    ]);
    let mut json = String::new();
    json.push_str("{\n  \"schema\": \"sqemu-bench-fig25/1\",\n  \"runs\": [\n");
    let _ = writeln!(
        json,
        "    {{\"vms\": {}, \"wave\": {wave}, \"utilization\": {:.4}, \
         \"busy_ns\": {}, \"fresh_bytes\": {}, \"seeks\": {}, \
         \"merged_seeks\": {}, \"p50_ns\": {}, \"p99_ns\": {}, \
         \"shard_passes\": {}, \"shard_wakeups\": {}}}",
        o.vms,
        o.utilization,
        (o.busy_ms * 1e6) as u64,
        (o.moved_mib * (1 << 20) as f64) as u64,
        o.seeks,
        o.merged_seeks,
        o.p50_ns,
        o.p99_ns,
        o.shard_passes,
        o.shard_wakeups,
    );
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_fig25.json", &json).expect("write BENCH_fig25.json");
    t.finish();
    println!(
        "\npaper shape: one executor per core serves the whole fleet; per-VM \
         rings keep submissions lock-free and the per-node merge windows \
         keep the device streaming instead of seeking — {:.1}% of device \
         time moved bytes at theoretical bandwidth across {} modeled VMs \
         ({} seeks avoided by cross-VM merging)\n(wrote BENCH_fig25.json)",
        o.utilization * 100.0,
        o.vms,
        o.merged_seeks,
    );
    assert!(
        o.utilization >= 0.90,
        "device-time utilization below the 0.90 acceptance bar: {:.4}",
        o.utilization
    );
    assert!(o.merged_seeks > 0, "no cross-VM merges happened");
}
