//! §Perf hot-path micro-benches (wall clock — the code cost itself, not
//! the simulated device time): resolve+read for cache hit, hit
//! unallocated and miss, under both drivers, plus the bulk PJRT
//! translation path.

use sqemu::bench::timer::Timer;
use sqemu::bench::BenchArgs;
use sqemu::cache::CacheConfig;
use sqemu::chaingen::{generate, ChainSpec};
use sqemu::coordinator::BulkTranslator;
use sqemu::metrics::clock::{CostModel, VirtClock};
use sqemu::metrics::memory::MemoryAccountant;
use sqemu::qcow::image::DataMode;
use sqemu::qcow::Chain;
use sqemu::runtime::service::RuntimeService;
use sqemu::storage::node::StorageNode;
use sqemu::vdisk::scalable::ScalableDriver;
use sqemu::vdisk::vanilla::VanillaDriver;
use sqemu::vdisk::{Driver, DriverKind};
use std::hint::black_box;

fn chain_on(node: &StorageNode, len: usize, prefix: &str) -> Chain {
    generate(
        node,
        &ChainSpec {
            disk_size: 1 << 30,
            chain_len: len,
            populated: 0.9,
            stamped: true,
            data_mode: DataMode::Synthetic,
            prefix: prefix.into(),
            ..Default::default()
        },
    )
    .unwrap()
}

fn driver(node: &StorageNode, clock: &std::sync::Arc<VirtClock>, kind: DriverKind, len: usize, prefix: &str) -> Box<dyn Driver> {
    let chain = chain_on(node, len, prefix);
    let cfg = CacheConfig::new(512, 64 << 20);
    match kind {
        DriverKind::Vanilla => Box::new(VanillaDriver::new(
            chain,
            cfg,
            clock.clone(),
            CostModel::default(),
            MemoryAccountant::new(),
        )),
        DriverKind::Scalable => Box::new(ScalableDriver::new(
            chain,
            cfg,
            clock.clone(),
            CostModel::default(),
            MemoryAccountant::new(),
        )),
    }
}

fn main() {
    let args = BenchArgs::parse();
    let timer = if args.quick {
        Timer { warmup_iters: 20, samples: 10, iters_per_sample: 50 }
    } else {
        Timer::default()
    };
    let clock = VirtClock::new();
    let node = StorageNode::new("hot", clock.clone(), CostModel::default());
    println!("=== hotpath — wall-clock ns/op (lower is better) ===");

    let mut buf = vec![0u8; 4 << 10];
    // warm read paths at chain depth 1 and 64 for both drivers
    for (kind, len) in [
        (DriverKind::Scalable, 1usize),
        (DriverKind::Scalable, 64),
        (DriverKind::Vanilla, 1),
        (DriverKind::Vanilla, 64),
    ] {
        let prefix = format!("{}-{}", kind.name(), len);
        let mut d = driver(&node, &clock, kind, len, &prefix);
        // warm the caches over the probe region first
        for vc in 0..512u64 {
            d.read(vc << 16, &mut buf[..1]).unwrap();
        }
        let mut vc = 0u64;
        timer
            .bench(&format!("warm 4K read {} chain={}", kind.name(), len), || {
                vc = (vc + 1) % 512;
                d.read(black_box(vc << 16), black_box(&mut buf)).unwrap();
            })
            .print();
    }

    // vectored warm path: one 1 MiB readv (16 clusters, one slice-group
    // probe, coalesced device run) vs 16 per-cluster reads
    for (kind, len) in [(DriverKind::Scalable, 64usize), (DriverKind::Vanilla, 64)] {
        let prefix = format!("vec-{}-{}", kind.name(), len);
        let mut d = driver(&node, &clock, kind, len, &prefix);
        let mut big = vec![0u8; 1 << 20];
        // pre-allocate the L2 table, then 1 MiB of contiguous clusters in
        // the active volume so runs actually merge
        d.write(17 << 16, &[1u8; 64]).unwrap();
        d.write(0, &big).unwrap();
        timer
            .bench(&format!("warm 1M readv {} chain={}", kind.name(), len), || {
                let mut iovs: Vec<(u64, &mut [u8])> = vec![(0, big.as_mut_slice())];
                d.readv(black_box(&mut iovs)).unwrap();
            })
            .print();
        timer
            .bench(
                &format!("warm 1M per-cluster {} chain={}", kind.name(), len),
                || {
                    for c in 0..16u64 {
                        d.read(black_box(c << 16), &mut big[..64 << 10]).unwrap();
                    }
                },
            )
            .print();
    }

    // cold-miss path (fresh driver each iteration region; approximate by
    // cycling a huge region so slices keep missing)
    {
        let mut d = driver(&node, &clock, DriverKind::Scalable, 16, "cold-sq");
        let clusters = (1u64 << 30) >> 16;
        let mut vc = 0u64;
        timer
            .bench("cold-ish 4K read sqemu chain=16", || {
                vc = (vc + 4099) % clusters;
                d.read(black_box(vc << 16), black_box(&mut buf)).unwrap();
            })
            .print();
    }

    // bulk translation: host vs PJRT
    {
        let chain = chain_on(&node, 8, "bulk");
        let (off, bfi) = BulkTranslator::flatten_active(&chain, 0, 8192).unwrap();
        let vbs: Vec<i32> = (0..4096).map(|i| (i * 3) % off.len() as i32).collect();
        let host = BulkTranslator::new(None);
        timer
            .bench("bulk translate 4096 reqs (host)", || {
                black_box(host.translate(&off, &bfi, &vbs).unwrap());
            })
            .print();
        if let Some(svc) = RuntimeService::try_default() {
            let accel = BulkTranslator::new(Some(svc));
            timer
                .bench("bulk translate 4096 reqs (pjrt)", || {
                    black_box(accel.translate(&off, &bfi, &vbs).unwrap());
                })
                .print();
        } else {
            println!("(pjrt bulk translate skipped: no artifacts)");
        }
    }
}
