//! Virtual-time experiment runner shared by the figure benches: build a
//! chain per spec, run a guest workload under either driver, and collect
//! every §6.1 metric in one pass.

use crate::cache::CacheConfig;
use crate::chaingen::{generate, ChainSpec};
use crate::guest::{Workload, WorkloadStats};
use crate::metrics::clock::{CostModel, VirtClock};
use crate::metrics::counters::CounterSnapshot;
use crate::metrics::histogram::Histogram;
use crate::metrics::memory::MemoryAccountant;
use crate::qcow::image::DataMode;
use crate::qcow::Chain;
use crate::storage::node::StorageNode;
use crate::vdisk::scalable::ScalableDriver;
use crate::vdisk::vanilla::VanillaDriver;
use crate::vdisk::{Driver, DriverKind};
use anyhow::Result;
use std::sync::Arc;

/// One experiment configuration (one point of a figure).
#[derive(Clone, Debug)]
pub struct ExpConfig {
    pub disk_size: u64,
    pub chain_len: usize,
    pub populated: f64,
    /// Cache bytes given to the system under test. For vanilla this is
    /// the *per-file* cache size unless `split_vanilla_cache` is set, in
    /// which case the budget is divided by the chain length (Fig 16's
    /// equal-total-budget comparison).
    pub cache_bytes: u64,
    pub split_vanilla_cache: bool,
    pub slice_entries: u64,
    pub data_mode: DataMode,
    pub seed: u64,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            disk_size: 4 << 30,
            chain_len: 1,
            populated: 0.9,
            cache_bytes: 0, // 0 = full-disk cache (the §6.1 default)
            split_vanilla_cache: false,
            slice_entries: 512,
            data_mode: DataMode::Synthetic,
            seed: 0xF16,
        }
    }
}

impl ExpConfig {
    pub fn chain_spec(&self, stamped: bool, prefix: &str) -> ChainSpec {
        ChainSpec {
            disk_size: self.disk_size,
            cluster_bits: 16,
            chain_len: self.chain_len,
            populated: self.populated,
            stamped,
            data_mode: self.data_mode,
            seed: self.seed,
            prefix: prefix.into(),
        }
    }

    fn cache_cfg(&self, kind: DriverKind) -> CacheConfig {
        let geom = crate::qcow::layout::Geometry::new(16, self.disk_size).unwrap();
        let mut bytes = if self.cache_bytes == 0 {
            CacheConfig::full_disk_bytes(&geom)
        } else {
            self.cache_bytes
        };
        if kind == DriverKind::Vanilla && self.split_vanilla_cache {
            bytes = (bytes / self.chain_len as u64).max(4096);
        }
        CacheConfig::new(self.slice_entries, bytes)
    }
}

/// Everything a figure can need from one run.
pub struct RunOutput {
    pub kind: DriverKind,
    pub stats: WorkloadStats,
    pub counters: CounterSnapshot,
    pub lookup_hist: Histogram,
    /// Peak accounted memory (the paper's "Qemu overhead on top of guest
    /// RAM"), bytes.
    pub mem_peak: u64,
    /// Resident cache bytes at the end of the run.
    pub cache_bytes: u64,
    /// Total physical bytes of the chain's files (Fig 19a).
    pub chain_file_bytes: u64,
    /// Virtual ns spent generating/snapshotting the chain (Fig 19b uses
    /// dedicated measurements; this is informational).
    pub setup_ns: u64,
}

/// Build the chain and driver for `kind`, run `workload`, collect.
pub fn run_workload(
    kind: DriverKind,
    cfg: &ExpConfig,
    workload: &mut dyn Workload,
) -> Result<RunOutput> {
    let clock = VirtClock::new();
    let node = StorageNode::new("bench", clock.clone(), CostModel::default());
    let spec = cfg.chain_spec(kind == DriverKind::Scalable, "d");
    let (chain, setup_ns) = {
        let t0 = clock.now();
        let c = generate(&node, &spec)?;
        (c, clock.now() - t0)
    };
    run_on_chain(kind, cfg, chain, clock, workload, setup_ns)
}

/// Run on an already-built chain (lets benches reuse expensive chains).
pub fn run_on_chain(
    kind: DriverKind,
    cfg: &ExpConfig,
    chain: Chain,
    clock: Arc<VirtClock>,
    workload: &mut dyn Workload,
    setup_ns: u64,
) -> Result<RunOutput> {
    let acct = MemoryAccountant::new();
    let cache_cfg = cfg.cache_cfg(kind);
    let mut driver: Box<dyn Driver> = match kind {
        DriverKind::Vanilla => Box::new(VanillaDriver::new(
            chain,
            cache_cfg,
            clock.clone(),
            CostModel::default(),
            acct.clone(),
        )),
        DriverKind::Scalable => Box::new(ScalableDriver::new(
            chain,
            cache_cfg,
            clock.clone(),
            CostModel::default(),
            acct.clone(),
        )),
    };
    acct.reset_peak();
    clock.reset();
    let stats = workload.run(driver.as_mut(), &clock)?;
    Ok(RunOutput {
        kind,
        stats,
        counters: driver.counters(),
        lookup_hist: driver.lookup_latency(),
        mem_peak: acct.peak(),
        cache_bytes: driver.cache_bytes(),
        chain_file_bytes: driver.chain().total_file_bytes(),
        setup_ns,
    })
}

/// Run the same workload under both drivers (fresh chains, same spec).
pub fn run_pair(
    cfg: &ExpConfig,
    mk: impl Fn() -> Box<dyn Workload>,
) -> Result<(RunOutput, RunOutput)> {
    let v = run_workload(DriverKind::Vanilla, cfg, mk().as_mut())?;
    let s = run_workload(DriverKind::Scalable, cfg, mk().as_mut())?;
    Ok((v, s))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::guest::dd::Dd;

    #[test]
    fn pair_runs_and_sqemu_wins_on_chains() {
        let cfg = ExpConfig {
            disk_size: 64 << 20,
            chain_len: 12,
            populated: 0.8,
            ..Default::default()
        };
        let (v, s) = run_pair(&cfg, || {
            Box::new(Dd { block_size: 1 << 20, limit: None })
        })
        .unwrap();
        assert_eq!(v.stats.bytes, s.stats.bytes);
        // the paper's claims, in miniature: faster and leaner
        assert!(
            s.stats.throughput_bps() > v.stats.throughput_bps(),
            "sqemu {} <= vanilla {}",
            s.stats.throughput_bps(),
            v.stats.throughput_bps()
        );
        assert!(s.mem_peak < v.mem_peak);
        assert!(s.counters.misses < v.counters.misses);
    }
}
