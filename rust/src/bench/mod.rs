//! The figure-regeneration harness: everything `rust/benches/fig*.rs`
//! share. (No `criterion` in the offline crate set — [`timer`] provides
//! the wall-clock micro-bench loop for the hot-path benches, and
//! [`figures`] the virtual-time experiment runner for the paper's
//! tables/figures.)
//!
//! Conventions:
//! * every bench prints a paper-shaped table to stdout and appends a CSV
//!   copy under `target/figures/` so EXPERIMENTS.md can cite runs;
//! * default scale is a reduced testbed (4 GiB disks, chains <= 200)
//!   so `cargo bench` completes quickly; `--full` (or
//!   `SQEMU_BENCH_FULL=1`) switches to paper scale (50 GiB, chains to
//!   1000).

pub mod figures;
pub mod smoke;
pub mod table;
pub mod timer;

pub use figures::{ExpConfig, RunOutput};
pub use table::Table;
pub use timer::Timer;

/// Shared bench CLI: `cargo bench --bench figNN -- [--full] [--quick]`.
#[derive(Clone, Debug, Default)]
pub struct BenchArgs {
    pub full: bool,
    pub quick: bool,
}

impl BenchArgs {
    pub fn parse() -> BenchArgs {
        let mut a = BenchArgs {
            full: std::env::var_os("SQEMU_BENCH_FULL").is_some(),
            quick: std::env::var_os("SQEMU_BENCH_QUICK").is_some(),
        };
        for arg in std::env::args().skip(1) {
            match arg.as_str() {
                "--full" => a.full = true,
                "--quick" => a.quick = true,
                // cargo-bench passes --bench; ignore unknown flags
                _ => {}
            }
        }
        a
    }

    /// Chain lengths to sweep for the main scalability figures.
    pub fn chain_lengths(&self) -> Vec<usize> {
        if self.full {
            vec![1, 5, 25, 50, 100, 200, 500, 1000]
        } else if self.quick {
            vec![1, 10, 50]
        } else {
            vec![1, 5, 25, 50, 100, 200]
        }
    }

    /// Disk size for the sweeps (paper: 50 GiB).
    pub fn disk_size(&self) -> u64 {
        if self.full {
            50 << 30
        } else {
            4 << 30
        }
    }
}
