//! `sqemu bench --json`: a reduced-scale smoke run of the hot-path and
//! vectored-throughput benches that emits a machine-readable
//! `BENCH_hotpath.json` (wall-clock ns/op plus simulated MB/s and
//! device-I/O counts per path). CI uploads the file as an artifact so
//! the perf trajectory is tracked per commit instead of only existing on
//! developer machines.

use crate::bench::timer::Timer;
use crate::cache::CacheConfig;
use crate::chaingen::{generate, ChainSpec};
use crate::metrics::clock::{CostModel, VirtClock};
use crate::metrics::memory::MemoryAccountant;
use crate::qcow::image::DataMode;
use crate::storage::node::StorageNode;
use crate::vdisk::scalable::ScalableDriver;
use crate::vdisk::Driver;
use anyhow::{Context, Result};
use std::fmt::Write as _;
use std::sync::Arc;

const CS: u64 = 64 << 10;

/// Total device I/O operations the chain's files have served.
pub fn device_ios(d: &dyn Driver) -> u64 {
    d.chain()
        .images()
        .iter()
        .map(|i| i.backend().device_ios())
        .sum()
}

/// Total cache probes (per-file lookups) the driver has performed.
pub fn probes(d: &dyn Driver) -> u64 {
    d.counters().per_file_lookups.iter().sum()
}

/// Result of one [`seq4k_compare`] run.
pub struct Seq4kCompare {
    pub scalar_ns: u64,
    pub vectored_ns: u64,
    pub scalar_device_ios: u64,
    pub vectored_device_ios: u64,
    pub vectored_probes: u64,
    pub merged_ios: u64,
}

/// THE sequential-4K measurement: warm the caches over `region` bytes,
/// then read the region once with per-request 4 KiB reads and once with
/// vectored 1 MiB submissions of 4 KiB iovs (`region` must be a multiple
/// of 1 MiB). Shared by `fig22_vectored_throughput`, the CI smoke run
/// and the acceptance tests so the methodology cannot drift.
pub fn seq4k_compare(
    d: &mut dyn Driver,
    clock: &VirtClock,
    region: u64,
) -> Result<Seq4kCompare> {
    let cs = d.chain().active().geom().cluster_size();
    let mut buf = vec![0u8; 4096];
    let mut vc = 0u64;
    while vc * cs < region {
        d.read(vc * cs, &mut buf[..1])?;
        vc += 1;
    }
    let ios0 = device_ios(d);
    let t0 = clock.now();
    let mut off = 0u64;
    while off < region {
        d.read(off, &mut buf)?;
        off += 4096;
    }
    let scalar_ns = clock.now() - t0;
    let scalar_device_ios = device_ios(d) - ios0;

    let mut big = vec![0u8; 1 << 20];
    let ios1 = device_ios(d);
    let probes1 = probes(d);
    let merged1 = d.vec_io().merged_ios;
    let t1 = clock.now();
    let mut base = 0u64;
    while base < region {
        let mut iovs: Vec<(u64, &mut [u8])> = big
            .chunks_mut(4096)
            .enumerate()
            .map(|(i, c)| (base + i as u64 * 4096, c))
            .collect();
        d.readv(&mut iovs)?;
        base += 1 << 20;
    }
    let vectored_ns = clock.now() - t1;
    Ok(Seq4kCompare {
        scalar_ns,
        vectored_ns,
        scalar_device_ios,
        vectored_device_ios: device_ios(d) - ios1,
        vectored_probes: probes(d) - probes1,
        merged_ios: d.vec_io().merged_ios - merged1,
    })
}

fn sq_driver(
    node: &StorageNode,
    clock: &Arc<VirtClock>,
    len: usize,
    prefix: &str,
) -> Result<ScalableDriver> {
    let chain = generate(
        node,
        &ChainSpec {
            disk_size: 64 << 20,
            chain_len: len,
            populated: 1.0,
            stamped: true,
            data_mode: DataMode::Synthetic,
            prefix: prefix.into(),
            ..Default::default()
        },
    )?;
    Ok(ScalableDriver::new(
        chain,
        CacheConfig::new(512, 8 << 20),
        clock.clone(),
        CostModel::default(),
        MemoryAccountant::new(),
    ))
}

/// Virtual-time throughput in MiB/s.
pub fn mbps(bytes: u64, ns: u64) -> f64 {
    if ns == 0 {
        return 0.0;
    }
    bytes as f64 / (1 << 20) as f64 / (ns as f64 / 1e9)
}

/// One virtual-time comparison row: sequential 4 KiB reads over `region`
/// bytes, per-request vs vectored in 1 MiB submissions.
struct VecRow {
    chain: usize,
    scalar_mbps: f64,
    vectored_mbps: f64,
    scalar_device_ios: u64,
    vectored_device_ios: u64,
    merged_ios: u64,
}

fn vectored_row(len: usize) -> Result<VecRow> {
    let clock = VirtClock::new();
    let node = StorageNode::new("smoke", clock.clone(), CostModel::default());
    let mut d = sq_driver(&node, &clock, len, &format!("smoke-{len}"))?;
    let region: u64 = 4 << 20;
    let cmp = seq4k_compare(&mut d, &clock, region)?;
    Ok(VecRow {
        chain: len,
        scalar_mbps: mbps(region, cmp.scalar_ns),
        vectored_mbps: mbps(region, cmp.vectored_ns),
        scalar_device_ios: cmp.scalar_device_ios,
        vectored_device_ios: cmp.vectored_device_ios,
        merged_ios: cmp.merged_ios,
    })
}

/// Run the smoke suite and write `json_path`.
pub fn run_smoke(json_path: &str) -> Result<()> {
    let timer = Timer { warmup_iters: 10, samples: 5, iters_per_sample: 20 };
    let clock = VirtClock::new();
    let node = StorageNode::new("smoke-hot", clock.clone(), CostModel::default());
    let mut hot = Vec::new();
    {
        let mut d = sq_driver(&node, &clock, 64, "hot")?;
        let mut buf = vec![0u8; 4096];
        for vc in 0..64u64 {
            d.read(vc * CS, &mut buf[..1])?;
        }
        let mut vc = 0u64;
        hot.push(timer.bench("warm 4K read sqemu chain=64", || {
            vc = (vc + 1) % 64;
            d.read(vc * CS, &mut buf).unwrap();
        }));
        let mut big = vec![0u8; 1 << 20];
        // pre-allocate the L2 table, then 1 MiB of contiguous clusters in
        // the active volume so the vectored path has a run to merge
        d.write(17 * CS, &[1u8; 64])?;
        d.write(0, &big)?;
        hot.push(timer.bench("warm 1M readv sqemu chain=64", || {
            let mut iovs: Vec<(u64, &mut [u8])> = vec![(0, big.as_mut_slice())];
            d.readv(&mut iovs).unwrap();
        }));
        hot.push(timer.bench("warm 1M per-cluster reads sqemu chain=64", || {
            for c in 0..16u64 {
                d.read(c * CS, &mut big[..CS as usize]).unwrap();
            }
        }));
    }

    println!("=== bench smoke — wall clock ===");
    for r in &hot {
        r.print();
    }
    let mut rows = Vec::new();
    for len in [1usize, 100, 500] {
        rows.push(vectored_row(len)?);
    }
    println!("\n=== bench smoke — simulated sequential 4K reads ===");
    for r in &rows {
        println!(
            "chain={:<4} scalar {:>8.1} MB/s ({} IOs) | vectored {:>8.1} MB/s \
             ({} IOs, {} merged)",
            r.chain,
            r.scalar_mbps,
            r.scalar_device_ios,
            r.vectored_mbps,
            r.vectored_device_ios,
            r.merged_ios
        );
    }

    let mut json = String::new();
    json.push_str("{\n  \"schema\": \"sqemu-bench-smoke/1\",\n  \"hotpath\": [\n");
    for (i, r) in hot.iter().enumerate() {
        let _ = writeln!(
            json,
            "    {{\"name\": \"{}\", \"mean_ns\": {:.1}, \"p50_ns\": {:.1}, \
             \"p99_ns\": {:.1}}}{}",
            r.name,
            r.mean_ns,
            r.p50_ns,
            r.p99_ns,
            if i + 1 < hot.len() { "," } else { "" }
        );
    }
    json.push_str("  ],\n  \"vectored_seq4k\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = writeln!(
            json,
            "    {{\"chain\": {}, \"scalar_mbps\": {:.1}, \"vectored_mbps\": {:.1}, \
             \"scalar_device_ios\": {}, \"vectored_device_ios\": {}, \
             \"merged_ios\": {}}}{}",
            r.chain,
            r.scalar_mbps,
            r.vectored_mbps,
            r.scalar_device_ios,
            r.vectored_device_ios,
            r.merged_ios,
            if i + 1 < rows.len() { "," } else { "" }
        );
    }
    json.push_str("  ]\n}\n");
    std::fs::write(json_path, &json)
        .with_context(|| format!("write bench json to {json_path}"))?;
    println!("\nwrote {json_path}");
    Ok(())
}
