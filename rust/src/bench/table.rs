//! Aligned stdout tables + CSV copies under target/figures/.

use std::io::Write;
use std::path::PathBuf;

pub struct Table {
    id: String,
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(id: &str, title: &str, headers: &[&str]) -> Table {
        Table {
            id: id.to_string(),
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "ragged table row");
        self.rows.push(cells.to_vec());
    }

    /// Print aligned to stdout and write `target/figures/<id>.csv`.
    pub fn finish(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n=== {} — {} ===", self.id, self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        if let Err(e) = self.write_csv() {
            eprintln!("(csv write failed: {e})");
        }
    }

    fn write_csv(&self) -> std::io::Result<()> {
        let dir = csv_dir();
        std::fs::create_dir_all(&dir)?;
        let mut f = std::fs::File::create(dir.join(format!("{}.csv", self.id)))?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

pub fn csv_dir() -> PathBuf {
    PathBuf::from("target/figures")
}

/// Format helpers used across benches.
pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}

pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn mibs(bps: f64) -> String {
    format!("{:.1}", bps / (1 << 20) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[should_panic(expected = "ragged")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("t", "x", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn builds_and_prints() {
        let mut t = Table::new("test_table", "demo", &["k", "v"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["bb".into(), "22".into()]);
        t.finish();
    }
}
