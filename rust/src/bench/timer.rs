//! Wall-clock micro-bench loop (the criterion stand-in) for the §Perf
//! hot-path benches.

use crate::util::stats::Summary;
use std::time::Instant;

pub struct Timer {
    pub warmup_iters: u32,
    pub samples: u32,
    pub iters_per_sample: u32,
}

impl Default for Timer {
    fn default() -> Self {
        Timer { warmup_iters: 100, samples: 30, iters_per_sample: 100 }
    }
}

#[derive(Clone, Debug)]
pub struct TimerReport {
    pub name: String,
    /// mean ns per iteration
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl TimerReport {
    pub fn print(&self) {
        println!(
            "{:<42} {:>12.0} ns/iter  (sd {:>8.0}, p50 {:>10.0}, p99 {:>10.0})",
            self.name, self.mean_ns, self.stddev_ns, self.p50_ns, self.p99_ns
        );
    }
}

impl Timer {
    /// Benchmark `f`, returning per-iteration stats. `f` should include
    /// its own state; use `std::hint::black_box` on inputs/outputs.
    pub fn bench(&self, name: &str, mut f: impl FnMut()) -> TimerReport {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut s = Summary::new();
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..self.iters_per_sample {
                f();
            }
            let dt = t0.elapsed().as_nanos() as f64 / self.iters_per_sample as f64;
            s.add(dt);
        }
        TimerReport {
            name: name.to_string(),
            mean_ns: s.mean(),
            stddev_ns: s.stddev(),
            p50_ns: s.median(),
            p99_ns: s.percentile(99.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let t = Timer { warmup_iters: 5, samples: 5, iters_per_sample: 10 };
        let mut x = 0u64;
        let r = t.bench("noop-ish", || {
            x = std::hint::black_box(x.wrapping_add(1));
        });
        assert!(r.mean_ns >= 0.0);
        assert!(r.p99_ns >= r.p50_ns);
    }
}
