//! Live block jobs: incremental, rate-limited chain maintenance that
//! runs *concurrently with guest I/O*.
//!
//! §3's chains rot to ~1000 files because shortening them is disruptive:
//! the offline paths ([`crate::qcow::snapshot::stream_merge`],
//! [`crate::qcow::snapshot::convert_to_sqemu`]) pause the VM for the
//! whole operation (§4.1 reports a 100x guest latency hit while a merge
//! runs). This module is the QEMU-style answer — cooperative background
//! jobs that execute in bounded increments interleaved with guest
//! requests on the VM's worker thread:
//!
//! * [`BlockJob`] — the job interface: `run_increment(chain, budget)`
//!   processes a bounded number of virtual clusters, `finalize` performs
//!   the one-shot completion (catch-up pass + chain/header rewrite).
//! * [`stream::LiveStreamJob`] — incremental top-down copy of backing
//!   clusters into the active volume; when it completes, the chain
//!   collapses to a single file with no guest-visible pause.
//! * [`stamp::LiveStampJob`] — online vanilla→SQEMU conversion: walks
//!   the chain stamping `backing_file_index` entries into the active
//!   volume, then flips the format flag, so a running VM migrates to the
//!   scalable format without downtime.
//! * [`rate::RateLimiter`] — token bucket (with debt) that meters job
//!   bytes against a caller-supplied clock (the virtual clock in the
//!   coordinator, wall time in the CLI).
//! * [`runner::JobRunner`] — drives one job on a driver: pause / resume
//!   / cancel, rate limiting, progress accounting, and the completion
//!   protocol (flush → finalize → reopen → `qcheck`).
//! * [`scheduler::JobScheduler`] — coordinator-level admission control:
//!   jobs reserve I/O bandwidth per storage node and are rejected when a
//!   node's maintenance budget is exhausted.
//!
//! Correctness model (see DESIGN.md §7): jobs and guest requests share
//! one worker thread, so increments are atomic with respect to guest
//! I/O. The [`JobFence`] (held by [`crate::vdisk::common::DriverBase`])
//! is the write intercept connecting the two sides: guest writes mark
//! clusters *newer-than-the-job* (never clobbered), and job moves mark
//! mappings the driver's caches may hold stale (the write path then
//! consults the on-disk entry). Backing files are never mutated or
//! dropped before `finalize`, so stale *read* mappings still reach
//! bit-identical data.

pub mod rate;
pub mod runner;
pub mod scheduler;
pub mod stamp;
pub mod stream;

pub use rate::RateLimiter;
pub use runner::{JobRunner, Step};
pub use scheduler::JobScheduler;
pub use stamp::LiveStampJob;
pub use stream::LiveStreamJob;

use crate::qcow::Chain;
use crate::util::{lock_unpoisoned, Notify};
use anyhow::Result;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Which maintenance operation a job performs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    /// Copy backing clusters into the active volume, then collapse the
    /// chain to a single file (live analogue of `stream_merge`).
    Stream,
    /// Stamp `backing_file_index` entries into the active volume, then
    /// set the format flag (live analogue of `convert_to_sqemu`).
    Stamp,
    /// Sweep the GC deferred-delete set: rate-limited physical deletion
    /// of unreferenced files ([`crate::gc::GcJob`]). Runs on the
    /// coordinator, not a VM worker — it owns no chain.
    Gc,
    /// Mirror the VM's whole chain to another storage node and switch
    /// over atomically ([`crate::migrate::MirrorJob`]) — the live
    /// migration that turns static placement into a managed fleet.
    Mirror,
    /// Walk chain heads and refresh per-node logical-byte accounting
    /// ([`crate::dedup::CapacityScanJob`]) — the background form of
    /// `refresh_capacity`, so recovery never serializes behind it.
    Scan,
}

impl JobKind {
    pub fn name(self) -> &'static str {
        match self {
            JobKind::Stream => "stream",
            JobKind::Stamp => "stamp",
            JobKind::Gc => "gc",
            JobKind::Mirror => "mirror",
            JobKind::Scan => "scan",
        }
    }

    pub fn parse(s: &str) -> Option<JobKind> {
        match s {
            "stream" => Some(JobKind::Stream),
            "stamp" => Some(JobKind::Stamp),
            "gc" => Some(JobKind::Gc),
            "mirror" => Some(JobKind::Mirror),
            "scan" => Some(JobKind::Scan),
            _ => None,
        }
    }
}

/// Lifecycle state of a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Running,
    Paused,
    Completed,
    Cancelled,
    Failed,
}

impl JobState {
    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Completed | JobState::Cancelled | JobState::Failed)
    }

    pub fn name(self) -> &'static str {
        match self {
            JobState::Running => "running",
            JobState::Paused => "paused",
            JobState::Completed => "completed",
            JobState::Cancelled => "cancelled",
            JobState::Failed => "failed",
        }
    }
}

/// Outcome of one bounded increment.
#[derive(Clone, Copy, Debug, Default)]
pub struct Increment {
    /// Virtual clusters examined this increment.
    pub processed: u64,
    /// Clusters copied (stream) / entries stamped (stamp).
    pub copied: u64,
    /// Bytes of job I/O charged against the rate limiter.
    pub bytes: u64,
    /// All clusters examined; only `finalize` remains.
    pub complete: bool,
}

/// A cooperative chain-maintenance job.
///
/// Implementations must uphold two invariants so they can interleave
/// with guest I/O: (1) never mutate a backing file, only the active
/// volume; (2) never overwrite an L2 entry the guest wrote after the job
/// started (consult the [`JobFence`]).
pub trait BlockJob: Send {
    fn kind(&self) -> JobKind;

    /// Total work units (virtual clusters) the job will examine.
    fn total_clusters(&self) -> u64;

    /// Process up to `budget` clusters against `chain`. Called on the VM
    /// worker thread; nothing else touches the chain during the call.
    fn run_increment(&mut self, chain: &mut Chain, budget: u64) -> Result<Increment>;

    /// One-shot completion, atomic with respect to guest I/O: a catch-up
    /// pass over clusters whose on-disk entries were clobbered by stale
    /// cache writebacks, then the chain/header rewrite. The caller must
    /// flush the driver before and reopen it after.
    fn finalize(&mut self, chain: &mut Chain) -> Result<()>;
}

/// The write intercept shared between a running job and the drivers.
///
/// Guest side: every guest write marks its virtual cluster, so the job
/// treats it as *already newer* and never clobbers it. Job side: every
/// relocated cluster records its new host offset, so the driver's write
/// path knows its cached mapping may be stale and consults the on-disk
/// entry instead (reads may keep using stale mappings — the data they
/// reach is bit-identical until `finalize`, which reopens the driver).
#[derive(Debug, Default)]
pub struct JobFence {
    active: AtomicBool,
    guest: Mutex<HashSet<u64>>,
    moved: Mutex<HashMap<u64, u64>>,
}

impl JobFence {
    pub fn begin(&self) {
        lock_unpoisoned(&self.guest).clear();
        lock_unpoisoned(&self.moved).clear();
        self.active.store(true, Ordering::Release);
    }

    pub fn end(&self) {
        self.active.store(false, Ordering::Release);
        lock_unpoisoned(&self.guest).clear();
        lock_unpoisoned(&self.moved).clear();
    }

    pub fn is_active(&self) -> bool {
        self.active.load(Ordering::Acquire)
    }

    /// Guest wrote `vc`: the job must treat the cluster as newer.
    pub fn note_guest_write(&self, vc: u64) {
        if self.is_active() {
            lock_unpoisoned(&self.guest).insert(vc);
        }
    }

    pub fn guest_wrote(&self, vc: u64) -> bool {
        self.is_active() && lock_unpoisoned(&self.guest).contains(&vc)
    }

    /// Job relocated `vc` into the active volume at `host_off`.
    pub fn note_job_move(&self, vc: u64, host_off: u64) {
        if self.is_active() {
            lock_unpoisoned(&self.moved).insert(vc, host_off);
        }
    }

    /// The active-volume host offset the job copied `vc` to, if any.
    pub fn job_moved(&self, vc: u64) -> Option<u64> {
        if !self.is_active() {
            return None;
        }
        lock_unpoisoned(&self.moved).get(&vc).copied()
    }

    /// Snapshot of every (vc, host_off) the job relocated — the only
    /// clusters a stale cache writeback can have clobbered, hence the
    /// exact work list of `finalize`'s catch-up pass. Sorted by virtual
    /// cluster so recovery replays are deterministic.
    pub fn moved_snapshot(&self) -> Vec<(u64, u64)> {
        let mut v: Vec<(u64, u64)> =
            lock_unpoisoned(&self.moved).iter().map(|(&k, &v)| (k, v)).collect();
        v.sort_unstable();
        v
    }
}

/// Cross-thread job handle: progress counters, state and control flags.
/// The worker thread owns the job; everything else observes/controls it
/// through this.
#[derive(Debug)]
pub struct JobShared {
    pub id: String,
    pub kind: JobKind,
    pub rate_bps: u64,
    state: Mutex<JobState>,
    error: Mutex<Option<String>>,
    pub processed: AtomicU64,
    pub copied: AtomicU64,
    pub total: AtomicU64,
    pub bytes_copied: AtomicU64,
    pub increments: AtomicU64,
    pub started_ns: AtomicU64,
    pub finished_ns: AtomicU64,
    cancel: AtomicBool,
    pause: AtomicBool,
    /// Doorbell of the executor driving this job. A paused job's
    /// executor parks instead of polling; `resume`/`cancel` ring it so
    /// the job restarts promptly with zero idle wakeups.
    waker: Mutex<Option<Arc<Notify>>>,
}

impl JobShared {
    pub fn new(id: &str, kind: JobKind, rate_bps: u64) -> Self {
        JobShared {
            id: id.to_string(),
            kind,
            rate_bps,
            state: Mutex::new(JobState::Running),
            error: Mutex::new(None),
            processed: AtomicU64::new(0),
            copied: AtomicU64::new(0),
            total: AtomicU64::new(0),
            bytes_copied: AtomicU64::new(0),
            increments: AtomicU64::new(0),
            started_ns: AtomicU64::new(0),
            finished_ns: AtomicU64::new(0),
            cancel: AtomicBool::new(false),
            pause: AtomicBool::new(false),
            waker: Mutex::new(None),
        }
    }

    /// Register the executor doorbell to ring on `resume`/`cancel`.
    pub fn set_waker(&self, w: Arc<Notify>) {
        *lock_unpoisoned(&self.waker) = Some(w);
    }

    /// Drop the registered doorbell (job finished or VM moved).
    pub fn clear_waker(&self) {
        *lock_unpoisoned(&self.waker) = None;
    }

    fn wake(&self) {
        if let Some(w) = lock_unpoisoned(&self.waker).as_ref() {
            w.notify();
        }
    }

    pub fn state(&self) -> JobState {
        let s = *lock_unpoisoned(&self.state);
        if s == JobState::Running && self.pause.load(Ordering::Relaxed) {
            JobState::Paused
        } else {
            s
        }
    }

    pub fn set_state(&self, s: JobState) {
        *lock_unpoisoned(&self.state) = s;
    }

    pub fn set_error(&self, msg: String) {
        *lock_unpoisoned(&self.error) = Some(msg);
    }

    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
        self.wake();
    }

    pub fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }

    pub fn pause(&self) {
        self.pause.store(true, Ordering::Relaxed);
    }

    pub fn resume(&self) {
        self.pause.store(false, Ordering::Relaxed);
        self.wake();
    }

    pub fn paused(&self) -> bool {
        self.pause.load(Ordering::Relaxed)
    }

    /// Point-in-time status snapshot.
    pub fn status(&self) -> JobStatus {
        JobStatus {
            id: self.id.clone(),
            kind: self.kind,
            state: self.state(),
            processed: self.processed.load(Ordering::Relaxed),
            copied: self.copied.load(Ordering::Relaxed),
            total: self.total.load(Ordering::Relaxed),
            bytes_copied: self.bytes_copied.load(Ordering::Relaxed),
            increments: self.increments.load(Ordering::Relaxed),
            rate_bps: self.rate_bps,
            started_ns: self.started_ns.load(Ordering::Relaxed),
            finished_ns: self.finished_ns.load(Ordering::Relaxed),
            error: lock_unpoisoned(&self.error).clone(),
        }
    }
}

/// Progress report for one job (CLI `sqemu job list`, coordinator API).
#[derive(Clone, Debug)]
pub struct JobStatus {
    pub id: String,
    pub kind: JobKind,
    pub state: JobState,
    pub processed: u64,
    pub copied: u64,
    pub total: u64,
    pub bytes_copied: u64,
    pub increments: u64,
    pub rate_bps: u64,
    pub started_ns: u64,
    pub finished_ns: u64,
    pub error: Option<String>,
}

impl JobStatus {
    /// Fraction of clusters examined, in [0, 1].
    pub fn progress(&self) -> f64 {
        if self.total == 0 {
            1.0
        } else {
            self.processed as f64 / self.total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fence_tracks_both_sides_only_while_active() {
        let f = JobFence::default();
        f.note_guest_write(3);
        assert!(!f.guest_wrote(3), "inactive fence records nothing");
        f.begin();
        f.note_guest_write(3);
        f.note_job_move(7, 1 << 16);
        assert!(f.guest_wrote(3));
        assert!(!f.guest_wrote(4));
        assert_eq!(f.job_moved(7), Some(1 << 16));
        assert_eq!(f.job_moved(3), None);
        f.end();
        assert!(!f.guest_wrote(3));
        assert_eq!(f.job_moved(7), None);
    }

    #[test]
    fn shared_state_machine_and_status() {
        let s = JobShared::new("job-1", JobKind::Stream, 64 << 20);
        assert_eq!(s.state(), JobState::Running);
        s.pause();
        assert_eq!(s.state(), JobState::Paused);
        s.resume();
        s.processed.store(10, Ordering::Relaxed);
        s.total.store(40, Ordering::Relaxed);
        let st = s.status();
        assert_eq!(st.state, JobState::Running);
        assert!((st.progress() - 0.25).abs() < 1e-9);
        s.set_state(JobState::Completed);
        assert!(s.state().is_terminal());
    }

    #[test]
    fn resume_and_cancel_ring_the_registered_waker() {
        let s = JobShared::new("job-2", JobKind::Stream, 64 << 20);
        let w = Arc::new(Notify::new());
        s.set_waker(Arc::clone(&w));
        s.pause();
        assert!(
            !w.wait_timeout(std::time::Duration::from_millis(5)),
            "pause alone does not wake the executor"
        );
        s.resume();
        assert!(w.wait_timeout(std::time::Duration::from_millis(100)));
        s.cancel();
        assert!(w.wait_timeout(std::time::Duration::from_millis(100)));
        s.clear_waker();
        s.resume();
        assert!(
            !w.wait_timeout(std::time::Duration::from_millis(5)),
            "cleared waker stays silent"
        );
    }

    #[test]
    fn kind_parse_roundtrip() {
        assert_eq!(JobKind::parse("stream"), Some(JobKind::Stream));
        assert_eq!(JobKind::parse("stamp"), Some(JobKind::Stamp));
        assert_eq!(JobKind::parse("bogus"), None);
        assert_eq!(JobKind::Stream.name(), "stream");
    }
}
