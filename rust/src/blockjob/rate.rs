//! Token-bucket rate limiter for background job I/O.
//!
//! Clock-agnostic: every method takes `now` in nanoseconds so the same
//! limiter meters virtual time in the coordinator (where job and guest
//! I/O charge the shared [`crate::metrics::clock::VirtClock`]) and wall
//! time in the offline CLI.
//!
//! Debt model: an increment copies whole clusters, so `consume` is
//! charged *after* the work and may drive the balance negative; the
//! runner then stays starved until the deficit refills. Overshoot is
//! bounded by one increment.

/// Token bucket over bytes with signed balance (debt allowed).
#[derive(Clone, Debug)]
pub struct RateLimiter {
    /// Refill rate in bytes/second; 0 = unlimited.
    rate_bps: u64,
    /// Maximum positive balance in bytes (burst size).
    burst: u64,
    /// Current balance in byte-nanoseconds (bytes * 1e9), signed.
    balance_bns: i128,
    last_ns: u64,
}

const NS_PER_SEC: i128 = 1_000_000_000;

impl RateLimiter {
    /// A limiter refilling at `rate_bps` with a burst of `burst` bytes
    /// (clamped to at least one token so progress is always possible).
    pub fn new(rate_bps: u64, burst: u64, now_ns: u64) -> RateLimiter {
        let burst = burst.max(1);
        RateLimiter {
            rate_bps,
            burst,
            balance_bns: burst as i128 * NS_PER_SEC,
            last_ns: now_ns,
        }
    }

    /// No limiting: `ready_at` is always `now`.
    pub fn unlimited(now_ns: u64) -> RateLimiter {
        RateLimiter::new(0, 1, now_ns)
    }

    pub fn rate_bps(&self) -> u64 {
        self.rate_bps
    }

    pub fn is_unlimited(&self) -> bool {
        self.rate_bps == 0
    }

    fn refill(&mut self, now_ns: u64) {
        if now_ns <= self.last_ns {
            return;
        }
        let dt = (now_ns - self.last_ns) as i128;
        self.last_ns = now_ns;
        if self.rate_bps == 0 {
            return;
        }
        let cap = self.burst as i128 * NS_PER_SEC;
        self.balance_bns = (self.balance_bns + self.rate_bps as i128 * dt).min(cap);
    }

    /// Charge `bytes` of completed job I/O (may go into debt).
    pub fn consume(&mut self, bytes: u64, now_ns: u64) {
        self.refill(now_ns);
        if self.rate_bps == 0 {
            return;
        }
        self.balance_bns -= bytes as i128 * NS_PER_SEC;
    }

    /// Earliest time (ns) at which the balance is non-negative — i.e.
    /// when the next increment may run. Returns `now_ns` when not
    /// starved.
    pub fn ready_at(&mut self, now_ns: u64) -> u64 {
        self.refill(now_ns);
        if self.rate_bps == 0 || self.balance_bns >= 0 {
            return now_ns;
        }
        let deficit = -self.balance_bns;
        let rate = self.rate_bps as i128;
        let wait = (deficit + rate - 1) / rate; // ceil(deficit / rate) ns
        now_ns + wait as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_never_starves() {
        let mut l = RateLimiter::unlimited(0);
        l.consume(u64::MAX / 2, 0);
        assert_eq!(l.ready_at(0), 0);
        assert!(l.is_unlimited());
    }

    #[test]
    fn debt_delays_readiness_by_rate() {
        // 1000 bytes/s, burst 1000: consume 3000 bytes at t=0 leaves a
        // 2000-byte deficit = 2 seconds of refill.
        let mut l = RateLimiter::new(1000, 1000, 0);
        assert_eq!(l.ready_at(0), 0);
        l.consume(3000, 0);
        let ready = l.ready_at(0);
        assert_eq!(ready, 2 * 1_000_000_000);
        // halfway there, still starved; at `ready`, runnable again
        assert!(l.ready_at(1_000_000_000) > 1_000_000_000);
        assert_eq!(l.ready_at(ready), ready);
    }

    #[test]
    fn balance_caps_at_burst() {
        let mut l = RateLimiter::new(1000, 500, 0);
        // a long idle period must not accumulate more than `burst`
        l.refill(1_000_000_000_000);
        l.consume(500, 1_000_000_000_000);
        assert_eq!(l.ready_at(1_000_000_000_000), 1_000_000_000_000);
        l.consume(1, 1_000_000_000_000);
        assert!(l.ready_at(1_000_000_000_000) > 1_000_000_000_000);
    }

    #[test]
    fn time_never_runs_backwards() {
        let mut l = RateLimiter::new(1000, 1000, 100);
        l.consume(2000, 100);
        let r1 = l.ready_at(100);
        // an earlier timestamp must not panic or corrupt the balance
        let r0 = l.ready_at(50);
        assert!(r0 >= 50);
        assert_eq!(l.ready_at(r1), r1);
    }

    #[test]
    fn steady_state_throughput_matches_rate() {
        // consume 100-byte increments as fast as allowed for 1 virtual
        // second: total throughput must be ~rate.
        let rate = 10_000u64;
        let mut l = RateLimiter::new(rate, 100, 0);
        let mut now = 0u64;
        let mut total = 0u64;
        while now < NS_PER_SEC as u64 {
            now = l.ready_at(now);
            if now >= NS_PER_SEC as u64 {
                break;
            }
            l.consume(100, now);
            total += 100;
        }
        assert!(total >= rate - 200 && total <= rate + 200, "total={total}");
    }
}
