//! The job driver: runs one [`BlockJob`] against a VM's [`Driver`] in
//! bounded, rate-limited steps, and owns the completion protocol.
//!
//! The runner lives on the VM worker thread next to the driver. Between
//! guest requests (and while the queue is idle) the worker calls
//! [`JobRunner::step`]; each step runs at most one increment, so a
//! queued guest request waits for at most `increment_clusters` of job
//! work — that bound, together with the [`RateLimiter`], is what keeps
//! the guest's p99 flat while the chain shrinks (the bench
//! `fig20_live_blockjobs` sweeps it).
//!
//! Completion protocol: flush the driver (persist guest-dirty cache
//! slices), run the job's `finalize` (catch-up + chain rewrite), reopen
//! the driver (rebuild caches for the new shape), end the fence, then
//! run [`qcheck`] over the result — a job only reports `Completed` if
//! the chain checks clean; any error flips it to `Failed` with the
//! errors recorded.

use super::{BlockJob, JobFence, JobShared, JobState, RateLimiter};
use crate::qcow::qcheck;
use crate::vdisk::Driver;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::Arc;

/// What one call to [`JobRunner::step`] did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Step {
    /// Ran one increment.
    Ran,
    /// Token bucket empty; runnable again at `ready_at` (ns).
    Starved { ready_at: u64 },
    /// Job is paused; nothing to do until resumed.
    Paused,
    /// Job reached a terminal state; drop the runner.
    Finished,
}

pub struct JobRunner {
    job: Box<dyn BlockJob>,
    limiter: RateLimiter,
    shared: Arc<JobShared>,
    fence: Arc<JobFence>,
    increment_clusters: u64,
    copy_done: bool,
}

impl JobRunner {
    /// Begin a job: raises the fence and stamps the start time. The
    /// caller stores the runner next to the driver it will step.
    pub fn new(
        job: Box<dyn BlockJob>,
        shared: Arc<JobShared>,
        fence: Arc<JobFence>,
        increment_clusters: u64,
        burst_bytes: u64,
        now_ns: u64,
    ) -> JobRunner {
        fence.begin();
        shared.total.store(job.total_clusters(), Relaxed);
        shared.started_ns.store(now_ns, Relaxed);
        shared.set_state(JobState::Running);
        let limiter = RateLimiter::new(shared.rate_bps, burst_bytes.max(1), now_ns);
        JobRunner {
            job,
            limiter,
            shared,
            fence,
            increment_clusters: increment_clusters.max(1),
            copy_done: false,
        }
    }

    pub fn shared(&self) -> &Arc<JobShared> {
        &self.shared
    }

    /// Should the worker poll the queue instead of blocking on it?
    pub fn wants_cpu(&self) -> bool {
        !self.shared.state().is_terminal() && !self.shared.paused()
    }

    /// Advance the job by at most one increment.
    pub fn step(&mut self, driver: &mut dyn Driver, now_ns: u64) -> Step {
        if self.shared.state().is_terminal() {
            return Step::Finished;
        }
        if self.shared.cancelled() {
            // cooperative cancel: leave the chain as-is (partial copies
            // are consistent — they duplicate, never replace, data)
            self.fence.end();
            self.shared.set_state(JobState::Cancelled);
            self.shared.finished_ns.store(now_ns, Relaxed);
            return Step::Finished;
        }
        if self.shared.paused() {
            return Step::Paused;
        }
        if !self.copy_done {
            let ready_at = self.limiter.ready_at(now_ns);
            if ready_at > now_ns {
                return Step::Starved { ready_at };
            }
            match self.job.run_increment(driver.chain_mut(), self.increment_clusters) {
                Err(e) => return self.fail(now_ns, format!("increment failed: {e:#}")),
                Ok(inc) => {
                    self.shared.processed.fetch_add(inc.processed, Relaxed);
                    self.shared.copied.fetch_add(inc.copied, Relaxed);
                    self.shared.bytes_copied.fetch_add(inc.bytes, Relaxed);
                    self.shared.increments.fetch_add(1, Relaxed);
                    self.limiter.consume(inc.bytes, now_ns);
                    self.copy_done = inc.complete;
                }
            }
            return Step::Ran;
        }
        self.finish(driver, now_ns)
    }

    /// Flush → finalize → reopen → qcheck. Only a clean check completes.
    fn finish(&mut self, driver: &mut dyn Driver, now_ns: u64) -> Step {
        if let Err(e) = driver.flush() {
            return self.fail(now_ns, format!("pre-finalize flush failed: {e:#}"));
        }
        if let Err(e) = self.job.finalize(driver.chain_mut()) {
            let _ = driver.reopen();
            return self.fail(now_ns, format!("finalize failed: {e:#}"));
        }
        if let Err(e) = driver.reopen() {
            return self.fail(now_ns, format!("post-finalize reopen failed: {e:#}"));
        }
        self.fence.end();
        match qcheck::check_chain(driver.chain()) {
            Err(e) => self.fail(now_ns, format!("qcheck failed to run: {e:#}")),
            Ok(report) if !report.is_clean() => self.fail(
                now_ns,
                format!(
                    "qcheck found {} errors after {} job: {}",
                    report.errors.len(),
                    self.job.kind().name(),
                    report.errors.join("; ")
                ),
            ),
            Ok(_) => {
                self.shared.set_state(JobState::Completed);
                self.shared.finished_ns.store(now_ns, Relaxed);
                Step::Finished
            }
        }
    }

    fn fail(&mut self, now_ns: u64, msg: String) -> Step {
        self.fence.end();
        self.shared.set_error(msg);
        self.shared.set_state(JobState::Failed);
        self.shared.finished_ns.store(now_ns, Relaxed);
        Step::Finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blockjob::{JobKind, LiveStreamJob};
    use crate::cache::CacheConfig;
    use crate::chaingen::{generate, ChainSpec};
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::metrics::memory::MemoryAccountant;
    use crate::qcow::image::DataMode;
    use crate::storage::node::StorageNode;
    use crate::vdisk::scalable::ScalableDriver;
    use crate::vdisk::Driver as _;

    fn driver_on_chain(len: usize) -> (Arc<VirtClock>, ScalableDriver) {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let chain = generate(
            &*node,
            &ChainSpec {
                disk_size: 8 << 20,
                chain_len: len,
                populated: 0.5,
                data_mode: DataMode::Real,
                ..Default::default()
            },
        )
        .unwrap();
        let d = ScalableDriver::new(
            chain,
            CacheConfig::new(16, 256 << 10),
            clock.clone(),
            CostModel::default(),
            MemoryAccountant::new(),
        );
        (clock, d)
    }

    fn stream_runner(d: &ScalableDriver, rate_bps: u64, now: u64) -> JobRunner {
        let fence = Arc::clone(d.fence());
        let shared = Arc::new(JobShared::new("job-1", JobKind::Stream, rate_bps));
        let job = Box::new(LiveStreamJob::new(d.chain(), Arc::clone(&fence)));
        JobRunner::new(job, shared, fence, 16, 1 << 20, now)
    }

    #[test]
    fn runs_to_completion_and_checks_clean() {
        let (clock, mut d) = driver_on_chain(5);
        let mut r = stream_runner(&d, 0, clock.now());
        loop {
            match r.step(&mut d, clock.now()) {
                Step::Finished => break,
                Step::Starved { ready_at } => {
                    let now = clock.now();
                    clock.advance(ready_at - now);
                }
                _ => {}
            }
        }
        let st = r.shared().status();
        assert_eq!(st.state, JobState::Completed, "error: {:?}", st.error);
        assert_eq!(d.chain().len(), 1, "chain collapsed");
        assert!(st.increments > 1, "work was incremental");
        assert_eq!(st.processed, st.total);
    }

    #[test]
    fn rate_limit_starves_and_virtual_time_unstarves() {
        let (clock, mut d) = driver_on_chain(4);
        // 1 MiB/s with 64 KiB clusters: every cluster copied starves the
        // bucket for ~62 ms of virtual time
        let mut r = stream_runner(&d, 1 << 20, clock.now());
        let mut starved = 0u32;
        loop {
            match r.step(&mut d, clock.now()) {
                Step::Finished => break,
                Step::Starved { ready_at } => {
                    starved += 1;
                    let now = clock.now();
                    assert!(ready_at > now);
                    clock.advance(ready_at - now);
                }
                _ => {}
            }
        }
        assert!(starved > 0, "limiter never engaged");
        assert_eq!(r.shared().status().state, JobState::Completed);
    }

    #[test]
    fn cancel_is_cooperative_and_leaves_chain_intact() {
        let (clock, mut d) = driver_on_chain(4);
        let mut r = stream_runner(&d, 0, clock.now());
        assert_eq!(r.step(&mut d, clock.now()), Step::Ran);
        r.shared().cancel();
        assert_eq!(r.step(&mut d, clock.now()), Step::Finished);
        assert_eq!(r.shared().status().state, JobState::Cancelled);
        assert_eq!(d.chain().len(), 4, "chain shape untouched");
        assert!(!d.fence().is_active(), "fence lowered on cancel");
        let report = crate::qcow::qcheck::check_chain(d.chain()).unwrap();
        assert!(report.is_clean(), "{:?}", report.errors);
    }

    #[test]
    fn pause_and_resume() {
        let (clock, mut d) = driver_on_chain(3);
        let r0 = stream_runner(&d, 0, clock.now());
        r0.shared().pause();
        let mut r = r0;
        assert_eq!(r.step(&mut d, clock.now()), Step::Paused);
        assert!(!r.wants_cpu());
        r.shared().resume();
        assert_eq!(r.step(&mut d, clock.now()), Step::Ran);
    }
}
