//! Admission control for background jobs: per-storage-node maintenance
//! bandwidth budgets.
//!
//! The coordinator runs many VMs whose chains share storage nodes; if
//! every VM streamed at once, maintenance I/O would crowd out guest I/O
//! (§4.1's disruption, fleet-wide). The scheduler grants each job a
//! bytes/second reservation against the node holding the VM's active
//! volume and rejects jobs once a node's budget is spent; reservations
//! are released when jobs reach a terminal state.

use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::Mutex;

/// Per-node maintenance-bandwidth ledger.
pub struct JobScheduler {
    /// Max aggregate job bytes/second per node.
    budget_bps: u64,
    reserved: Mutex<HashMap<String, u64>>,
}

/// A granted reservation; hand it back via [`JobScheduler::release`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Reservation {
    pub node: String,
    pub rate_bps: u64,
}

impl JobScheduler {
    pub fn new(budget_bps: u64) -> JobScheduler {
        JobScheduler {
            budget_bps,
            reserved: Mutex::new(HashMap::new()),
        }
    }

    pub fn budget_bps(&self) -> u64 {
        self.budget_bps
    }

    /// Reserve `rate_bps` on `node`. An unlimited job (`rate_bps == 0`)
    /// reserves the node's whole budget — it will saturate whatever it
    /// is given, so nothing else should be admitted beside it.
    pub fn admit(&self, node: &str, rate_bps: u64) -> Result<Reservation> {
        let need = if rate_bps == 0 { self.budget_bps } else { rate_bps };
        if need > self.budget_bps {
            bail!(
                "job rate {need} B/s exceeds the per-node maintenance budget \
                 {} B/s",
                self.budget_bps
            );
        }
        let mut reserved = self.reserved.lock().unwrap();
        let used = reserved.get(node).copied().unwrap_or(0);
        if used + need > self.budget_bps {
            bail!(
                "node '{node}' maintenance budget exhausted: {used} of {} B/s \
                 reserved, {need} requested",
                self.budget_bps
            );
        }
        reserved.insert(node.to_string(), used + need);
        Ok(Reservation { node: node.to_string(), rate_bps: need })
    }

    /// Release a reservation (job completed, failed, or was cancelled).
    pub fn release(&self, r: &Reservation) {
        let mut reserved = self.reserved.lock().unwrap();
        if let Some(used) = reserved.get_mut(&r.node) {
            *used = used.saturating_sub(r.rate_bps);
            if *used == 0 {
                reserved.remove(&r.node);
            }
        }
    }

    /// Currently reserved bytes/second on `node`.
    pub fn reserved_bps(&self, node: &str) -> u64 {
        self.reserved.lock().unwrap().get(node).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_until_budget_then_rejects() {
        let s = JobScheduler::new(100);
        let a = s.admit("n0", 60).unwrap();
        assert!(s.admit("n0", 60).is_err(), "over budget");
        let b = s.admit("n0", 40).unwrap();
        // a different node has its own budget
        let _c = s.admit("n1", 100).unwrap();
        s.release(&a);
        s.release(&b);
        assert_eq!(s.reserved_bps("n0"), 0);
        assert_eq!(s.reserved_bps("n1"), 100);
    }

    #[test]
    fn unlimited_job_takes_the_whole_node() {
        let s = JobScheduler::new(1 << 20);
        let r = s.admit("n0", 0).unwrap();
        assert_eq!(r.rate_bps, 1 << 20);
        assert!(s.admit("n0", 1).is_err());
        s.release(&r);
        assert!(s.admit("n0", 1).is_ok());
    }

    #[test]
    fn oversized_request_rejected_outright() {
        let s = JobScheduler::new(100);
        assert!(s.admit("n0", 200).is_err());
        assert_eq!(s.reserved_bps("n0"), 0);
    }
}
