//! Live stamp: online vanilla→SQEMU conversion.
//!
//! The offline [`crate::qcow::snapshot::convert_to_sqemu`] walks the
//! whole chain with the VM paused. This job performs the same
//! stamping — every virtual cluster's `(backing_file_index, offset)`
//! owner written into the active volume's L2 table — in bounded
//! increments interleaved with guest I/O. Guest writes during the job
//! produce local entries that are newer than any stamp, and the
//! [`JobFence`] keeps the job from overwriting them. `finalize` runs a
//! catch-up pass (stale cache writebacks may have wiped stamps from
//! disk) and then sets the `FEATURE_BFI` header flag, so the running
//! VM's chain is migrated to the scalable format with no downtime: on
//! the next driver reopen the unified cache treats the active volume's
//! index as complete.

use super::{BlockJob, Increment, JobFence, JobKind};
use crate::qcow::entry::L2Entry;
use crate::qcow::layout::ENTRY_SIZE;
use crate::qcow::Chain;
use anyhow::Result;
use std::sync::Arc;

pub struct LiveStampJob {
    cursor: u64,
    total: u64,
    fence: Arc<JobFence>,
    /// Stamps this job wrote — the only entries a stale cache
    /// writeback can have wiped, hence `finalize`'s exact work list.
    written: Vec<(u64, L2Entry)>,
}

impl LiveStampJob {
    pub fn new(chain: &Chain, fence: Arc<JobFence>) -> LiveStampJob {
        LiveStampJob {
            cursor: 0,
            total: chain.active().geom().num_vclusters(),
            fence,
            written: Vec::new(),
        }
    }

    /// Resume an interrupted stamp run from a checkpointed cursor.
    /// Stamping is idempotent (an entry equal to the walk result is
    /// skipped), so any checkpoint at or before the real progress is
    /// safe.
    pub fn resume_at(chain: &Chain, fence: Arc<JobFence>, cursor: u64) -> LiveStampJob {
        let mut job = LiveStampJob::new(chain, fence);
        job.cursor = cursor.min(job.total);
        job
    }

    /// Clusters examined so far — the checkpoint a journal persists.
    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    /// Stamp one cluster's owner into the active volume. Returns the
    /// metadata bytes written (0 if the entry was already correct).
    fn stamp_cluster(&mut self, chain: &Chain, vc: u64) -> Result<u64> {
        let active = chain.active();
        let own = active.chain_index();
        let current = active.l2_entry(vc)?;
        if current.is_allocated_here() {
            // locally owned (pre-existing or a guest write during the
            // job): already resolvable in one step; leave it alone
            return Ok(0);
        }
        let Some((bfi, off)) = chain.resolve_walk(vc)? else {
            return Ok(0); // true hole
        };
        let entry = if bfi == own {
            L2Entry::local(off, Some(bfi))
        } else {
            L2Entry::remote(off, bfi)
        };
        if entry == current {
            return Ok(0);
        }
        active.set_l2_entry(vc, entry)?;
        self.written.push((vc, entry));
        Ok(ENTRY_SIZE)
    }
}

impl BlockJob for LiveStampJob {
    fn kind(&self) -> JobKind {
        JobKind::Stamp
    }

    fn total_clusters(&self) -> u64 {
        self.total
    }

    fn run_increment(&mut self, chain: &mut Chain, budget: u64) -> Result<Increment> {
        let mut inc = Increment::default();
        while inc.processed < budget && self.cursor < self.total {
            let vc = self.cursor;
            self.cursor += 1;
            inc.processed += 1;
            if self.fence.guest_wrote(vc) {
                continue; // the guest's local entry is newer than any stamp
            }
            let bytes = self.stamp_cluster(chain, vc)?;
            if bytes > 0 {
                inc.copied += 1;
                inc.bytes += bytes;
            }
        }
        inc.complete = self.cursor >= self.total;
        Ok(inc)
    }

    fn finalize(&mut self, chain: &mut Chain) -> Result<()> {
        // Catch-up: re-write any stamp a stale cache writeback wiped.
        // Only stamps this job wrote can have been clobbered (entries
        // that predate the job were already in any fetched slice), so
        // the recorded list is the exact work list — the pause here is
        // O(stamps written), with no chain re-walk. A cluster the guest
        // wrote meanwhile is locally allocated and must keep the
        // guest's newer entry.
        let active = chain.active();
        for &(vc, entry) in &self.written {
            let current = active.l2_entry(vc)?;
            if current != entry && !current.is_allocated_here() {
                active.set_l2_entry(vc, entry)?;
            }
        }
        // The active volume's index is now complete: flip the format
        // flag so drivers (and future snapshots) treat it as SQEMU.
        active.set_feature_bfi()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::qcow::image::{DataMode, Image};
    use crate::qcow::layout::Geometry;
    use crate::qcow::{qcheck, snapshot};
    use crate::storage::node::StorageNode;

    fn vanilla_chain(n: usize) -> (Arc<StorageNode>, Chain) {
        let node = StorageNode::new("s", VirtClock::new(), CostModel::default());
        let b = node.create_file("img-0").unwrap();
        let img = Image::create(
            "img-0",
            b,
            Geometry::new(16, 16 << 20).unwrap(),
            0,
            0,
            None,
            DataMode::Real,
        )
        .unwrap();
        let mut chain = Chain::new(Arc::new(img)).unwrap();
        for i in 0..n {
            let img = chain.active();
            let off = img.alloc_data_cluster().unwrap();
            img.write_data(off, 0, &[i as u8 + 1; 32]).unwrap();
            img.set_l2_entry(i as u64, L2Entry::local(off, None)).unwrap();
            snapshot::snapshot_vanilla(&mut chain, &node, &format!("img-{}", i + 1)).unwrap();
        }
        (node, chain)
    }

    #[test]
    fn stamps_match_offline_conversion_and_flip_the_flag() {
        let (_n, mut chain) = vanilla_chain(4);
        assert!(!chain.active().has_bfi());
        let fence = Arc::new(JobFence::default());
        fence.begin();
        let mut job = LiveStampJob::new(&chain, Arc::clone(&fence));
        let mut inc = Increment::default();
        let mut stamped = 0;
        while !inc.complete {
            inc = job.run_increment(&mut chain, 5).unwrap();
            stamped += inc.copied;
        }
        assert_eq!(stamped, 4, "one owned cluster per layer");
        job.finalize(&mut chain).unwrap();
        fence.end();
        assert!(chain.active().has_bfi(), "format flag flipped");
        // every stamp agrees with the chain walk (the §5 invariant)
        let active = chain.active();
        let own = active.chain_index();
        for vc in 0..active.geom().num_vclusters() {
            assert_eq!(
                active.l2_entry(vc).unwrap().sqemu_view(own),
                chain.resolve_walk(vc).unwrap(),
                "vc={vc}"
            );
        }
        assert!(qcheck::check_chain(&chain).unwrap().is_clean());
    }

    #[test]
    fn flag_survives_reopen_and_enables_sqemu_snapshots() {
        let (node, mut chain) = vanilla_chain(2);
        let fence = Arc::new(JobFence::default());
        fence.begin();
        let mut job = LiveStampJob::new(&chain, Arc::clone(&fence));
        while !job.run_increment(&mut chain, 64).unwrap().complete {}
        job.finalize(&mut chain).unwrap();
        fence.end();
        let active_name = chain.active().name.clone();
        drop(chain);
        let reopened = Chain::open(&*node, &active_name, DataMode::Real).unwrap();
        assert!(reopened.active().has_bfi());
        // a stamped chain can now take SQEMU snapshots
        let mut c = reopened;
        snapshot::snapshot_sqemu(&mut c, &*node, "img-sq").unwrap();
        assert!(qcheck::check_chain(&c).unwrap().is_clean());
    }

    #[test]
    fn idempotent_on_already_stamped_chain() {
        let (_n, mut chain) = vanilla_chain(3);
        snapshot::convert_to_sqemu(&chain).unwrap();
        chain.active().set_feature_bfi().unwrap();
        let fence = Arc::new(JobFence::default());
        fence.begin();
        let mut job = LiveStampJob::new(&chain, Arc::clone(&fence));
        let mut restamped = 0;
        let mut inc = Increment::default();
        while !inc.complete {
            inc = job.run_increment(&mut chain, 64).unwrap();
            restamped += inc.copied;
        }
        job.finalize(&mut chain).unwrap();
        fence.end();
        assert_eq!(restamped, 0, "no entry rewritten on a stamped chain");
    }
}
