//! Live stream: incremental top-down copy of backing clusters into the
//! active volume, concurrent with guest I/O.
//!
//! The offline [`crate::qcow::snapshot::stream_merge`] pauses the VM for
//! the whole merge. This job walks the virtual-cluster space in bounded
//! increments instead; each increment copies clusters whose newest
//! version lives in a backing file into the active volume. Guest writes
//! that land during the job mark their cluster in the [`JobFence`] as
//! already-newer and are never clobbered. When every cluster has been
//! examined, `finalize` runs a catch-up pass (repairing entries that a
//! stale cache writeback clobbered, reusing the already-copied data
//! cluster recorded in the fence) and collapses the chain to the active
//! volume alone.
//!
//! Backing files are never mutated, so any stale cached mapping a driver
//! holds mid-job still reads bit-identical data.

use super::{BlockJob, Increment, JobFence, JobKind};
use crate::qcow::entry::{decode_offset, ClusterLoc, L2Entry};
use crate::qcow::{Chain, Image};
use anyhow::{bail, Result};
use std::sync::Arc;

pub struct LiveStreamJob {
    cursor: u64,
    total: u64,
    fence: Arc<JobFence>,
    /// Scratch cluster buffer, reused across increments.
    buf: Vec<u8>,
}

impl LiveStreamJob {
    pub fn new(chain: &Chain, fence: Arc<JobFence>) -> LiveStreamJob {
        let geom = *chain.active().geom();
        LiveStreamJob {
            cursor: 0,
            total: geom.num_vclusters(),
            fence,
            buf: vec![0u8; geom.cluster_size() as usize],
        }
    }

    /// Resume an interrupted stream from a checkpointed cursor. Copies
    /// are idempotent (they duplicate, never replace, data), so any
    /// checkpoint at or before the real progress is safe — clusters
    /// already pulled are skipped as already-local.
    pub fn resume_at(chain: &Chain, fence: Arc<JobFence>, cursor: u64) -> LiveStreamJob {
        let mut job = LiveStreamJob::new(chain, fence);
        job.cursor = cursor.min(job.total);
        job
    }

    /// Clusters examined so far — the checkpoint a journal persists.
    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    /// Copy `vc`'s newest backing version into the active volume, if any.
    /// Returns the bytes copied (0 when the cluster needs no work).
    fn pull_cluster(&mut self, chain: &Chain, vc: u64) -> Result<u64> {
        let active = chain.active();
        let active_idx = (chain.len() - 1) as u16;
        if active.l2_entry(vc)?.is_allocated_here() {
            return Ok(0); // already local (guest write or earlier copy)
        }
        // a stale cache writeback may have clobbered an entry this job
        // already wrote; the data cluster is still ours — just re-link it
        if let Some(off) = self.fence.job_moved(vc) {
            let stamp = if active.has_bfi() { Some(active_idx) } else { None };
            active.set_l2_entry(vc, L2Entry::local(off, stamp))?;
            return Ok(0);
        }
        let Some((bfi, word)) = chain.resolve_walk(vc)? else {
            return Ok(0); // hole
        };
        if bfi == active_idx {
            return Ok(0);
        }
        let src = chain.get(bfi).expect("walk returned in-range index");
        let stamp = if active.has_bfi() { Some(active_idx) } else { None };
        match decode_offset(word) {
            ClusterLoc::Zero => {
                // a backing zero cluster needs no data copy: record an
                // equally deviceless zero entry in the active volume
                active.set_l2_entry(vc, L2Entry::zero_cluster(stamp))?;
                Ok(0)
            }
            ClusterLoc::Data(off) => {
                let new_off = active.alloc_data_cluster()?;
                src.read_data(off, 0, &mut self.buf)?;
                active.write_data(new_off, 0, &self.buf)?;
                active.set_l2_entry(vc, L2Entry::local(new_off, stamp))?;
                self.fence.note_job_move(vc, new_off);
                Ok(self.buf.len() as u64)
            }
            ClusterLoc::Compressed { off, units } => {
                // decompress out of the backing file; the copy lands
                // plain (payload packing is per-file, not streamable)
                let new_off = active.alloc_data_cluster()?;
                src.read_compressed(off, units, &mut self.buf)?;
                active.write_data(new_off, 0, &self.buf)?;
                active.set_l2_entry(vc, L2Entry::local(new_off, stamp))?;
                self.fence.note_job_move(vc, new_off);
                Ok(self.buf.len() as u64)
            }
        }
    }
}

impl BlockJob for LiveStreamJob {
    fn kind(&self) -> JobKind {
        JobKind::Stream
    }

    fn total_clusters(&self) -> u64 {
        self.total
    }

    fn run_increment(&mut self, chain: &mut Chain, budget: u64) -> Result<Increment> {
        let mut inc = Increment::default();
        while inc.processed < budget && self.cursor < self.total {
            let vc = self.cursor;
            self.cursor += 1;
            inc.processed += 1;
            if self.fence.guest_wrote(vc) {
                continue; // guest data is newer; never clobber
            }
            let bytes = self.pull_cluster(chain, vc)?;
            if bytes > 0 {
                inc.copied += 1;
                inc.bytes += bytes;
            }
        }
        inc.complete = self.cursor >= self.total;
        Ok(inc)
    }

    fn finalize(&mut self, chain: &mut Chain) -> Result<()> {
        // Catch-up: the driver's flush may have written back slices
        // whose cached entries predate this job's copies. Only clusters
        // this job relocated can have been clobbered (pre-existing
        // local entries and guest writes were in the cache when their
        // slice was fetched), so the fence's moved set is the exact
        // work list — the pause here is O(clusters copied by the job),
        // not O(disk). This call is atomic with respect to guest I/O.
        for (vc, _off) in self.fence.moved_snapshot() {
            self.pull_cluster(chain, vc)?;
        }
        // Collapse the chain: the active volume becomes a base image.
        let active: Arc<Image> = Arc::clone(chain.active());
        active.update_header(0, None)?;
        if active.has_bfi() {
            restamp_base(&active)?;
        }
        chain.replace_images(vec![active]);
        Ok(())
    }
}

/// Rewrite the stamps of a freshly collapsed active volume: every entry
/// must be local data stamped with the new chain index 0 (or a hole).
fn restamp_base(img: &Image) -> Result<u64> {
    let geom = *img.geom();
    let per_l2 = geom.entries_per_l2();
    let mut rewritten = 0u64;
    for l1_idx in 0..geom.l1_entries() {
        let l2_off = img.l1_entry(l1_idx);
        if l2_off == 0 {
            continue;
        }
        let mut entries = img.read_l2_slice(l2_off, 0, per_l2)?;
        let mut dirty = false;
        for raw in entries.iter_mut() {
            let e = L2Entry(*raw);
            if e.is_zero() {
                continue;
            }
            if !e.is_allocated_here() {
                bail!(
                    "live stream finalize: L1[{l1_idx}] holds a remote entry \
                     after the catch-up pass (stamp {:?})",
                    e.bfi()
                );
            }
            let out = L2Entry::local(e.host_offset(), Some(0));
            if out != e {
                *raw = out.raw();
                dirty = true;
                rewritten += 1;
            }
        }
        if dirty {
            img.write_l2_slice(l2_off, 0, &entries)?;
        }
    }
    Ok(rewritten)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::qcow::image::DataMode;
    use crate::qcow::layout::{Geometry, FEATURE_BFI};
    use crate::qcow::{qcheck, snapshot};
    use crate::storage::node::StorageNode;

    const CS: u64 = 64 << 10;

    fn chain_with_layers(n: usize) -> (Arc<StorageNode>, Chain) {
        let node = StorageNode::new("s", VirtClock::new(), CostModel::default());
        let b = node.create_file("img-0").unwrap();
        let img = Image::create(
            "img-0",
            b,
            Geometry::new(16, 16 << 20).unwrap(),
            FEATURE_BFI,
            0,
            None,
            DataMode::Real,
        )
        .unwrap();
        let mut chain = Chain::new(Arc::new(img)).unwrap();
        for i in 0..n {
            let img = chain.active();
            let off = img.alloc_data_cluster().unwrap();
            img.write_data(off, 0, &[i as u8 + 1; 64]).unwrap();
            img.set_l2_entry(i as u64, L2Entry::local(off, Some(img.chain_index())))
                .unwrap();
            snapshot::snapshot_sqemu(&mut chain, &node, &format!("img-{}", i + 1)).unwrap();
        }
        (node, chain)
    }

    #[test]
    fn streams_whole_chain_into_active_volume() {
        let (_n, mut chain) = chain_with_layers(4);
        let fence = Arc::new(JobFence::default());
        fence.begin();
        let mut job = LiveStreamJob::new(&chain, Arc::clone(&fence));
        let mut inc = Increment::default();
        let mut copied = 0;
        while !inc.complete {
            inc = job.run_increment(&mut chain, 7).unwrap();
            assert!(inc.processed <= 7, "budget respected");
            copied += inc.copied;
        }
        assert_eq!(copied, 4, "one cluster per layer");
        job.finalize(&mut chain).unwrap();
        fence.end();
        assert_eq!(chain.len(), 1);
        assert_eq!(chain.active().chain_index(), 0);
        assert_eq!(chain.active().backing_name(), None);
        let r = qcheck::check_chain(&chain).unwrap();
        assert!(r.is_clean(), "{:?}", r.errors);
        for i in 0..4u64 {
            let (bfi, off) = chain.resolve_walk(i).unwrap().unwrap();
            assert_eq!(bfi, 0);
            let mut buf = [0u8; 8];
            chain.get(0).unwrap().read_data(off, 0, &mut buf).unwrap();
            assert_eq!(buf, [i as u8 + 1; 8]);
        }
    }

    #[test]
    fn guest_written_clusters_are_never_clobbered() {
        let (_n, mut chain) = chain_with_layers(3);
        let fence = Arc::new(JobFence::default());
        fence.begin();
        let mut job = LiveStreamJob::new(&chain, Arc::clone(&fence));
        // simulate a guest COW write to cluster 1 before the job gets there
        let active = Arc::clone(chain.active());
        let own = active.chain_index();
        let off = active.alloc_data_cluster().unwrap();
        active.write_data(off, 0, &[0xAB; 64]).unwrap();
        active.set_l2_entry(1, L2Entry::local(off, Some(own))).unwrap();
        fence.note_guest_write(1);

        let mut inc = Increment::default();
        while !inc.complete {
            inc = job.run_increment(&mut chain, 100).unwrap();
        }
        job.finalize(&mut chain).unwrap();
        fence.end();
        let (_bfi, o) = chain.resolve_walk(1).unwrap().unwrap();
        let mut buf = [0u8; 8];
        chain.get(0).unwrap().read_data(o, 0, &mut buf).unwrap();
        assert_eq!(buf, [0xAB; 8], "guest write survived the stream");
    }

    #[test]
    fn clobbered_entry_is_relinked_not_recopied() {
        let (_n, mut chain) = chain_with_layers(2);
        let fence = Arc::new(JobFence::default());
        fence.begin();
        let mut job = LiveStreamJob::new(&chain, Arc::clone(&fence));
        let mut inc = Increment::default();
        while !inc.complete {
            inc = job.run_increment(&mut chain, 100).unwrap();
        }
        // simulate a stale cache writeback clobbering cluster 0's entry
        // back to its pre-job remote stamp
        let moved_off = fence.job_moved(0).unwrap();
        let base_off = chain.get(0).unwrap().l2_entry(0).unwrap().host_offset();
        chain
            .active()
            .set_l2_entry(0, L2Entry::remote(base_off, 0))
            .unwrap();
        let len_before = chain.active().file_len();
        job.finalize(&mut chain).unwrap();
        fence.end();
        // finalize reused the already-copied cluster: no new allocation
        assert_eq!(chain.active().file_len(), len_before);
        assert_eq!(
            chain.active().l2_entry(0).unwrap().host_offset(),
            moved_off
        );
        assert!(qcheck::check_chain(&chain).unwrap().is_clean());
    }
}
