//! Cache sizing.

use crate::qcow::layout::{Geometry, ENTRY_SIZE};

/// Per-slice bookkeeping overhead (tag, dirty, ref, LRU links, map slot) —
/// counted in the memory accountant alongside the entry payload.
pub const SLICE_OVERHEAD: u64 = 64;

/// Fixed per-cache overhead (the cache struct itself + table headroom);
/// vanilla pays this once *per backing file*.
pub const CACHE_FIXED_OVERHEAD: u64 = 4096;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheConfig {
    /// L2 entries per slice (Qemu's `l2-cache-entry-size` / 8; default
    /// 4 KiB slices = 512 entries).
    pub slice_entries: u64,
    /// Maximum cache size in bytes (Qemu's `l2-cache-size`).
    pub max_bytes: u64,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig { slice_entries: 512, max_bytes: 1 << 20 } // 1 MiB default [8]
    }
}

impl CacheConfig {
    pub fn new(slice_entries: u64, max_bytes: u64) -> Self {
        CacheConfig { slice_entries, max_bytes }
    }

    /// Bytes of one resident slice (payload + bookkeeping).
    pub fn slice_bytes(&self) -> u64 {
        self.slice_entries * ENTRY_SIZE + SLICE_OVERHEAD
    }

    /// Capacity in slices.
    pub fn capacity_slices(&self) -> u64 {
        (self.max_bytes / self.slice_bytes()).max(1)
    }

    /// The cache size that holds *all* L2 entries of a disk ("the size of
    /// the L2 cache needed to hold the entirety of L2 entries", §6.1 —
    /// 6.25 MiB for a 50 GiB disk).
    pub fn full_disk_bytes(geom: &Geometry) -> u64 {
        let slices = crate::util::div_ceil(
            geom.num_vclusters(),
            CacheConfig::default().slice_entries,
        );
        slices * CacheConfig::default().slice_bytes() + CACHE_FIXED_OVERHEAD
    }

    /// Config sized to hold the entire disk index (the §6 default).
    pub fn full_disk(geom: &Geometry) -> CacheConfig {
        CacheConfig {
            slice_entries: CacheConfig::default().slice_entries,
            max_bytes: Self::full_disk_bytes(geom),
        }
    }

    /// Logical slice key for a virtual cluster.
    pub fn slice_key(&self, vcluster: u64) -> u64 {
        vcluster / self.slice_entries
    }

    /// Index of a virtual cluster within its slice.
    pub fn slice_index(&self, vcluster: u64) -> u64 {
        vcluster % self.slice_entries
    }

    /// First virtual cluster of slice `key`.
    pub fn slice_base(&self, key: u64) -> u64 {
        key * self.slice_entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_qemu_doc() {
        let c = CacheConfig::default();
        assert_eq!(c.slice_entries, 512);
        assert_eq!(c.max_bytes, 1 << 20);
        assert!(c.capacity_slices() >= 250);
    }

    #[test]
    fn full_disk_50g_is_about_6mib() {
        // §6.1: 6.25 MiB of L2 entries for a 50 GiB disk
        let geom = Geometry::new(16, 50 << 30).unwrap();
        let bytes = CacheConfig::full_disk_bytes(&geom);
        let payload = geom.num_vclusters() * ENTRY_SIZE;
        assert!(bytes >= payload);
        assert!(bytes < payload + payload / 8 + 2 * CACHE_FIXED_OVERHEAD);
    }

    #[test]
    fn slice_addressing() {
        let c = CacheConfig::new(32, 1 << 20);
        assert_eq!(c.slice_key(0), 0);
        assert_eq!(c.slice_key(31), 0);
        assert_eq!(c.slice_key(32), 1);
        assert_eq!(c.slice_index(33), 1);
        assert_eq!(c.slice_base(2), 64);
    }
}
