//! Intrusive doubly-linked LRU over a slab — O(1) touch/insert/evict.
//! (No `lru` crate in the offline set; eviction scans would be O(n) and
//! the caches hold thousands of slices.)

use std::collections::HashMap;

/// Slab-backed LRU index mapping `u64` keys to values.
pub struct LruIndex<V> {
    map: HashMap<u64, usize>,
    slab: Vec<Node<V>>,
    free: Vec<usize>,
    head: usize, // most recently used
    tail: usize, // least recently used
}

struct Node<V> {
    key: u64,
    value: V,
    prev: usize,
    next: usize,
}

const NIL: usize = usize::MAX;

impl<V> LruIndex<V> {
    pub fn new() -> Self {
        LruIndex {
            map: HashMap::new(),
            slab: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, key: u64) -> bool {
        self.map.contains_key(&key)
    }

    /// Get without touching recency.
    pub fn peek(&self, key: u64) -> Option<&V> {
        self.map.get(&key).map(|&i| &self.slab[i].value)
    }

    /// Get mutably and mark as most recently used.
    pub fn touch(&mut self, key: u64) -> Option<&mut V> {
        let &idx = self.map.get(&key)?;
        self.unlink(idx);
        self.link_front(idx);
        Some(&mut self.slab[idx].value)
    }

    /// Insert (or replace) a value as most recently used.
    pub fn insert(&mut self, key: u64, value: V) -> Option<V> {
        if let Some(&idx) = self.map.get(&key) {
            let old = std::mem::replace(&mut self.slab[idx].value, value);
            self.unlink(idx);
            self.link_front(idx);
            return Some(old);
        }
        let idx = match self.free.pop() {
            Some(i) => {
                self.slab[i] = Node { key, value, prev: NIL, next: NIL };
                i
            }
            None => {
                self.slab.push(Node { key, value, prev: NIL, next: NIL });
                self.slab.len() - 1
            }
        };
        self.map.insert(key, idx);
        self.link_front(idx);
        None
    }

    /// Remove and return the least recently used entry.
    pub fn pop_lru(&mut self) -> Option<(u64, V)>
    where
        V: Default,
    {
        if self.tail == NIL {
            return None;
        }
        let idx = self.tail;
        self.remove_idx(idx)
    }

    /// Remove a specific key.
    pub fn remove(&mut self, key: u64) -> Option<(u64, V)>
    where
        V: Default,
    {
        let &idx = self.map.get(&key)?;
        self.remove_idx(idx)
    }

    fn remove_idx(&mut self, idx: usize) -> Option<(u64, V)>
    where
        V: Default,
    {
        self.unlink(idx);
        let key = self.slab[idx].key;
        self.map.remove(&key);
        self.free.push(idx);
        let value = std::mem::take(&mut self.slab[idx].value);
        Some((key, value))
    }

    /// Iterate (key, value) from most to least recently used.
    pub fn iter(&self) -> impl Iterator<Item = (u64, &V)> {
        LruIter { lru: self, cur: self.head }
    }

    fn unlink(&mut self, idx: usize) {
        let (prev, next) = (self.slab[idx].prev, self.slab[idx].next);
        if prev != NIL {
            self.slab[prev].next = next;
        } else if self.head == idx {
            self.head = next;
        }
        if next != NIL {
            self.slab[next].prev = prev;
        } else if self.tail == idx {
            self.tail = prev;
        }
        self.slab[idx].prev = NIL;
        self.slab[idx].next = NIL;
    }

    fn link_front(&mut self, idx: usize) {
        self.slab[idx].prev = NIL;
        self.slab[idx].next = self.head;
        if self.head != NIL {
            self.slab[self.head].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }
}

impl<V> Default for LruIndex<V> {
    fn default() -> Self {
        Self::new()
    }
}

struct LruIter<'a, V> {
    lru: &'a LruIndex<V>,
    cur: usize,
}

impl<'a, V> Iterator for LruIter<'a, V> {
    type Item = (u64, &'a V);

    fn next(&mut self) -> Option<Self::Item> {
        if self.cur == NIL {
            return None;
        }
        let node = &self.lru.slab[self.cur];
        self.cur = node.next;
        Some((node.key, &node.value))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_touch_evict_order() {
        let mut lru = LruIndex::new();
        lru.insert(1, "a");
        lru.insert(2, "b");
        lru.insert(3, "c");
        lru.touch(1); // order now (MRU) 1, 3, 2 (LRU)
        assert_eq!(lru.pop_lru().unwrap(), (2, "b"));
        assert_eq!(lru.pop_lru().unwrap(), (3, "c"));
        assert_eq!(lru.pop_lru().unwrap(), (1, "a"));
        assert!(lru.pop_lru().is_none());
    }

    #[test]
    fn replace_keeps_single_entry() {
        let mut lru = LruIndex::new();
        lru.insert(5, 1u32);
        assert_eq!(lru.insert(5, 2u32), Some(1));
        assert_eq!(lru.len(), 1);
        assert_eq!(*lru.peek(5).unwrap(), 2);
    }

    #[test]
    fn remove_arbitrary() {
        let mut lru = LruIndex::new();
        for k in 0..10u64 {
            lru.insert(k, k);
        }
        assert_eq!(lru.remove(4).unwrap(), (4, 4));
        assert_eq!(lru.len(), 9);
        assert!(!lru.contains(4));
        // slab slot reused
        lru.insert(100, 100);
        assert_eq!(lru.len(), 10);
    }

    #[test]
    fn iter_is_mru_first() {
        let mut lru = LruIndex::new();
        lru.insert(1, ());
        lru.insert(2, ());
        lru.insert(3, ());
        let keys: Vec<u64> = lru.iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec![3, 2, 1]);
    }

    #[test]
    fn heavy_churn_consistent() {
        let mut lru = LruIndex::new();
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..10_000 {
            let k = rng.below(64);
            match rng.below(3) {
                0 => {
                    lru.insert(k, k);
                }
                1 => {
                    lru.touch(k);
                }
                _ => {
                    lru.remove(k);
                }
            }
            assert!(lru.len() <= 64);
        }
        // drain fully without panic
        while lru.pop_lru().is_some() {}
        assert!(lru.is_empty());
    }
}
