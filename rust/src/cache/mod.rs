//! L2 indexing caches (§2 "Qcow2 Cache Organization").
//!
//! The same slice-granular LRU structure backs both designs:
//! * vanilla — one [`SliceCache`] per backing file, managed independently
//!   (the §4 scalability problem: footprint and lookups scale with chain
//!   length);
//! * SQEMU — a single [`unified::UnifiedCache`] for the whole chain,
//!   keyed by the active volume's logical slice index, refreshed by the
//!   §5.3 cache-correction rule.
//!
//! A slice is the unit of caching and eviction ("the slice is also the
//! granularity of the cache eviction policy, which is LRU", §2). Cache
//! keys are *logical*: `vcluster / slice_entries`, the virtual-disk slice
//! index — equivalent to Qemu's `l2_slice_offset` tag but independent of
//! where a given file physically placed its L2 table.

pub mod config;
pub mod lru;
pub mod slice;
pub mod unified;

pub use config::CacheConfig;
pub use slice::SliceCache;
pub use unified::UnifiedCache;
