//! The slice-granular LRU cache used by both driver designs.

use super::config::{CacheConfig, CACHE_FIXED_OVERHEAD};
use super::lru::LruIndex;
use crate::metrics::memory::{MemCategory, MemoryAccountant, Registration};
use std::sync::Arc;

/// One resident slice: the L2 entries plus the §2 bookkeeping fields
/// (`dirty`, `ref`; the tag is the LRU key).
#[derive(Clone, Debug, Default)]
pub struct Slice {
    pub entries: Vec<u64>,
    pub dirty: bool,
    /// Threads currently using the slice (pinned slices are not evicted).
    pub refcnt: u32,
}

/// An LRU cache of L2 slices for one file (vanilla) or one chain (SQEMU).
pub struct SliceCache {
    cfg: CacheConfig,
    lru: LruIndex<Slice>,
    mem: Registration,
}

impl SliceCache {
    pub fn new(cfg: CacheConfig, acct: &Arc<MemoryAccountant>) -> Self {
        SliceCache {
            cfg,
            lru: LruIndex::new(),
            mem: acct.register(MemCategory::Cache, CACHE_FIXED_OVERHEAD),
        }
    }

    pub fn cfg(&self) -> &CacheConfig {
        &self.cfg
    }

    /// Look up a slice and mark it most recently used.
    pub fn get(&mut self, key: u64) -> Option<&mut Slice> {
        self.lru.touch(key)
    }

    /// Is the slice resident (no recency update)?
    pub fn contains(&self, key: u64) -> bool {
        self.lru.contains(key)
    }

    /// Insert a fetched slice; if the cache is at capacity the LRU victim
    /// is returned for writeback when dirty ("a cache entry can be
    /// evicted ... when the cache is full", §2).
    pub fn insert(&mut self, key: u64, entries: Vec<u64>) -> Option<(u64, Slice)> {
        debug_assert_eq!(entries.len() as u64, self.cfg.slice_entries);
        let mut evicted = None;
        if !self.lru.contains(key)
            && self.lru.len() as u64 >= self.cfg.capacity_slices()
        {
            evicted = self.evict_one();
        }
        self.lru.insert(key, Slice { entries, dirty: false, refcnt: 0 });
        self.update_mem();
        evicted
    }

    /// Pop the least-recently-used unpinned slice.
    fn evict_one(&mut self) -> Option<(u64, Slice)> {
        // collect pinned slices we must skip (rare; refcnt is held only
        // across a single request)
        let mut skipped = Vec::new();
        let victim = loop {
            match self.lru.pop_lru() {
                None => break None,
                Some((k, s)) if s.refcnt > 0 => skipped.push((k, s)),
                Some(v) => break Some(v),
            }
        };
        for (k, s) in skipped {
            self.lru.insert(k, s);
        }
        self.update_mem();
        victim
    }

    /// Mark a resident slice dirty (write path).
    pub fn mark_dirty(&mut self, key: u64) {
        if let Some(s) = self.lru.touch(key) {
            s.dirty = true;
        }
    }

    /// Remove every slice, returning the dirty ones for writeback
    /// (VM shutdown, §2).
    pub fn drain(&mut self) -> Vec<(u64, Slice)> {
        let mut dirty = Vec::new();
        while let Some((k, s)) = self.lru.pop_lru() {
            if s.dirty {
                dirty.push((k, s));
            }
        }
        self.update_mem();
        dirty
    }

    pub fn resident_slices(&self) -> u64 {
        self.lru.len() as u64
    }

    /// Live bytes attributed to this cache.
    pub fn resident_bytes(&self) -> u64 {
        CACHE_FIXED_OVERHEAD + self.resident_slices() * self.cfg.slice_bytes()
    }

    fn update_mem(&mut self) {
        self.mem.resize(self.resident_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(slice_entries: u64, max_bytes: u64) -> (SliceCache, Arc<MemoryAccountant>) {
        let acct = MemoryAccountant::new();
        (
            SliceCache::new(CacheConfig::new(slice_entries, max_bytes), &acct),
            acct,
        )
    }

    #[test]
    fn insert_get_roundtrip() {
        let (mut c, _a) = cache(4, 1 << 20);
        assert!(c.get(0).is_none());
        c.insert(0, vec![1, 2, 3, 4]);
        assert_eq!(c.get(0).unwrap().entries, vec![1, 2, 3, 4]);
    }

    #[test]
    fn eviction_at_capacity_lru_order() {
        let slice_bytes = CacheConfig::new(4, 0).slice_bytes();
        let (mut c, _a) = cache(4, 2 * slice_bytes); // capacity 2
        assert_eq!(c.cfg().capacity_slices(), 2);
        assert!(c.insert(1, vec![0; 4]).is_none());
        assert!(c.insert(2, vec![0; 4]).is_none());
        c.get(1); // 2 becomes LRU
        let (k, _) = c.insert(3, vec![0; 4]).unwrap();
        assert_eq!(k, 2);
        assert!(c.contains(1) && c.contains(3) && !c.contains(2));
    }

    #[test]
    fn dirty_eviction_surfaces_for_writeback() {
        let slice_bytes = CacheConfig::new(4, 0).slice_bytes();
        let (mut c, _a) = cache(4, slice_bytes); // capacity 1
        c.insert(7, vec![9; 4]);
        c.mark_dirty(7);
        let (k, s) = c.insert(8, vec![0; 4]).unwrap();
        assert_eq!(k, 7);
        assert!(s.dirty);
        assert_eq!(s.entries, vec![9; 4]);
    }

    #[test]
    fn pinned_slices_survive_eviction() {
        let slice_bytes = CacheConfig::new(4, 0).slice_bytes();
        let (mut c, _a) = cache(4, 2 * slice_bytes);
        c.insert(1, vec![0; 4]);
        c.get(1).unwrap().refcnt = 1;
        c.insert(2, vec![0; 4]);
        let (k, _) = c.insert(3, vec![0; 4]).unwrap();
        assert_eq!(k, 2, "pinned slice 1 skipped");
        assert!(c.contains(1));
    }

    #[test]
    fn memory_accounting_tracks_residency() {
        let (mut c, a) = cache(512, 1 << 20);
        let base = a.live(MemCategory::Cache);
        assert_eq!(base, CACHE_FIXED_OVERHEAD);
        for k in 0..10 {
            c.insert(k, vec![0; 512]);
        }
        let per_slice = c.cfg().slice_bytes();
        assert_eq!(a.live(MemCategory::Cache), CACHE_FIXED_OVERHEAD + 10 * per_slice);
        c.drain();
        assert_eq!(a.live(MemCategory::Cache), CACHE_FIXED_OVERHEAD);
    }

    #[test]
    fn drain_returns_only_dirty() {
        let (mut c, _a) = cache(4, 1 << 20);
        c.insert(1, vec![0; 4]);
        c.insert(2, vec![0; 4]);
        c.mark_dirty(2);
        let dirty = c.drain();
        assert_eq!(dirty.len(), 1);
        assert_eq!(dirty[0].0, 2);
        assert_eq!(c.resident_slices(), 0);
    }
}
