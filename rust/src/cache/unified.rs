//! The SQEMU unified indexing cache (§5.3).
//!
//! One cache for the whole chain. Cached entries are kept in *chain frame*:
//! every entry is a stamped `(backing_file_index, offset)` reference
//! regardless of which file it was read from, so a single slice can
//! describe clusters living in many backing files ("one can find in the
//! same slice L2 entries describing data clusters belonging to distinct
//! backing files", §5.3).

use super::config::CacheConfig;
use super::slice::{Slice, SliceCache};
use crate::metrics::memory::MemoryAccountant;
use crate::qcow::entry::L2Entry;
use std::sync::Arc;

/// Unified cache + the cache-correction rule.
pub struct UnifiedCache {
    cache: SliceCache,
    /// Chain index of the active volume (the frame of reference).
    active_index: u16,
}

impl UnifiedCache {
    pub fn new(cfg: CacheConfig, active_index: u16, acct: &Arc<MemoryAccountant>) -> Self {
        UnifiedCache { cache: SliceCache::new(cfg, acct), active_index }
    }

    pub fn cfg(&self) -> &CacheConfig {
        &self.cache.cfg()
    }

    pub fn active_index(&self) -> u16 {
        self.active_index
    }

    /// Bring a slice of chain-frame entries into the cache — the drivers'
    /// scratch fetch path normalizes in place (via [`normalize`]) before
    /// insertion, so a miss costs one cache-owned allocation, not three.
    /// Returns an evicted slice, already denormalized for writeback to
    /// the active volume.
    pub fn insert_normalized(&mut self, key: u64, entries: &[u64]) -> Option<(u64, Vec<u64>)> {
        let evicted = self.cache.insert(key, entries.to_vec());
        evicted.map(|(k, s)| (k, self.denormalize_slice(&s)))
    }

    /// Look up the entry for `vcluster`. `Some(Some((bfi, off)))` = hit on
    /// an owned cluster; `Some(None)` = slice resident but cluster
    /// unallocated anywhere; `None` = slice not resident (cache miss).
    pub fn lookup(&mut self, vcluster: u64) -> Option<Option<(u16, u64)>> {
        let key = self.cache.cfg().slice_key(vcluster);
        let idx = self.cache.cfg().slice_index(vcluster) as usize;
        let slice = self.cache.get(key)?;
        let e = L2Entry(slice.entries[idx]);
        Some(e.bfi().map(|b| (b, e.host_offset())))
    }

    /// One probe for a whole request batch: the resident slice's
    /// chain-frame entries, or `None` on a cache miss. The batch resolver
    /// decodes every cluster of a slice group from this single probe.
    pub fn lookup_slice(&mut self, key: u64) -> Option<&[u64]> {
        self.cache.get(key).map(|s| s.entries.as_slice())
    }

    /// The §5.3 cache correction with chain-frame entries: merge a
    /// (normalized) slice fetched from a backing file into the resident
    /// slice — an entry is replaced iff its stamp is `<=` the incoming
    /// one. Marks the slice dirty so it is written back on eviction
    /// ("then it sets dirty to 1 in s_v", §5.3). Returns
    /// `(corrected_count, merged_slice)` so the caller resolves from the
    /// merge result without a second cache probe.
    pub fn correct_normalized(
        &mut self,
        key: u64,
        normalized: &[u64],
    ) -> Option<(u64, &[u64])> {
        let slice = self.cache.get(key)?;
        let mut corrected = 0;
        for (i, &b) in normalized.iter().enumerate() {
            let bfi_v = L2Entry(slice.entries[i]).bfi();
            let bfi_b = L2Entry(b).bfi();
            // None (unallocated) orders below any stamp
            if bfi_v <= bfi_b && slice.entries[i] != b {
                slice.entries[i] = b;
                corrected += 1;
            }
        }
        if corrected > 0 {
            slice.dirty = true;
        }
        Some((corrected, slice.entries.as_slice()))
    }

    /// Record a write: the active volume now owns `vcluster` at `off`.
    /// The slice must be resident.
    pub fn record_write(&mut self, vcluster: u64, off: u64) {
        let active = self.active_index;
        self.record_entry(vcluster, active, off);
    }

    /// Record an arbitrary post-write mapping in chain frame: `vcluster`
    /// now resolves to offset word `off` in file `bfi` (a capacity-policy
    /// write may map to a backing file via a dedup share, or to a
    /// flagged zero/compressed word — the offset word passes through
    /// opaquely, like everywhere else in the cache).
    pub fn record_entry(&mut self, vcluster: u64, bfi: u16, off: u64) {
        let key = self.cache.cfg().slice_key(vcluster);
        let idx = self.cache.cfg().slice_index(vcluster) as usize;
        if let Some(slice) = self.cache.get(key) {
            slice.entries[idx] = L2Entry::remote(off, bfi).raw();
            slice.dirty = true;
        }
    }

    pub fn contains(&self, key: u64) -> bool {
        self.cache.contains(key)
    }

    /// Flush all dirty slices, denormalized for the active volume's L2
    /// table on disk.
    pub fn drain(&mut self) -> Vec<(u64, Vec<u64>)> {
        let drained = self.cache.drain();
        drained
            .into_iter()
            .map(|(k, s)| (k, self.denormalize_slice(&s)))
            .collect()
    }

    pub fn resident_bytes(&self) -> u64 {
        self.cache.resident_bytes()
    }

    pub fn resident_slices(&self) -> u64 {
        self.cache.resident_slices()
    }

    fn denormalize_slice(&self, s: &Slice) -> Vec<u64> {
        s.entries
            .iter()
            .map(|&raw| denormalize(raw, self.active_index))
            .collect()
    }
}

/// Convert a raw on-disk entry read from file `from_index` into the chain
/// frame: a stamped remote reference (or zero for a true hole).
pub fn normalize(raw: u64, from_index: u16) -> u64 {
    let e = L2Entry(raw);
    match e.sqemu_view(from_index) {
        Some((bfi, off)) => L2Entry::remote(off, bfi).raw(),
        None => 0,
    }
}

/// Convert a chain-frame entry back to on-disk form for the active
/// volume: clusters owned by the active volume become local (ALLOCATED)
/// entries so vanilla drivers keep working (§5.1 backward compatibility).
pub fn denormalize(raw: u64, active_index: u16) -> u64 {
    let e = L2Entry(raw);
    match e.bfi() {
        Some(bfi) if bfi == active_index => {
            L2Entry::local(e.host_offset(), Some(bfi)).raw()
        }
        _ => raw,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uc(active: u16) -> UnifiedCache {
        let acct = MemoryAccountant::new();
        UnifiedCache::new(CacheConfig::new(4, 1 << 20), active, &acct)
    }

    /// Test shorthand for the drivers' fetch path: normalize a raw
    /// on-disk slice read from `from`, then insert/correct it.
    fn insert_raw(c: &mut UnifiedCache, key: u64, raw: &[u64], from: u16) {
        let n: Vec<u64> = raw.iter().map(|&r| normalize(r, from)).collect();
        c.insert_normalized(key, &n);
    }

    fn correct_raw(c: &mut UnifiedCache, key: u64, raw: &[u64], from: u16) -> u64 {
        let n: Vec<u64> = raw.iter().map(|&r| normalize(r, from)).collect();
        c.correct_normalized(key, &n).map(|(cnt, _)| cnt).unwrap_or(0)
    }

    #[test]
    fn lookup_states() {
        let mut c = uc(2);
        assert_eq!(c.lookup(0), None); // miss: slice absent
        // slice from the active volume: cluster 0 owned by file 0,
        // cluster 1 owned by active (2), cluster 2 unallocated
        let raw = vec![
            L2Entry::remote(5 << 16, 0).raw(),
            L2Entry::local(7 << 16, Some(2)).raw(),
            0,
            0,
        ];
        insert_raw(&mut c, 0, &raw, 2);
        assert_eq!(c.lookup(0), Some(Some((0, 5 << 16))));
        assert_eq!(c.lookup(1), Some(Some((2, 7 << 16))));
        assert_eq!(c.lookup(2), Some(None));
    }

    #[test]
    fn normalize_unstamped_local() {
        // vanilla entry read from file 1: local allocation, no stamp
        let raw = L2Entry::local(3 << 16, None).raw();
        let n = L2Entry(normalize(raw, 1));
        assert_eq!(n.bfi(), Some(1));
        assert_eq!(n.host_offset(), 3 << 16);
        assert!(!n.is_allocated_here());
    }

    #[test]
    fn denormalize_restores_local_form() {
        let chain_frame = L2Entry::remote(3 << 16, 2).raw();
        let d = L2Entry(denormalize(chain_frame, 2));
        assert!(d.is_allocated_here());
        assert_eq!(d.bfi(), Some(2));
        // non-active stamps stay remote
        let keep = L2Entry::remote(3 << 16, 1).raw();
        assert_eq!(denormalize(keep, 2), keep);
    }

    #[test]
    fn correction_takes_newer_or_equal() {
        let mut c = uc(5);
        // resident slice: entry 0 stamped bfi=1, entry 1 unallocated,
        // entry 2 stamped bfi=4
        let resident = vec![
            L2Entry::remote(1 << 16, 1).raw(),
            0,
            L2Entry::remote(4 << 16, 4).raw(),
            0,
        ];
        insert_raw(&mut c, 0, &resident, 5);
        // slice from backing file 3: owns entries 0, 1 and 2 locally
        let backing = vec![
            L2Entry::local(9 << 16, None).raw(),
            L2Entry::local(8 << 16, None).raw(),
            L2Entry::local(7 << 16, None).raw(),
            0,
        ];
        let corrected = correct_raw(&mut c, 0, &backing, 3);
        // entry 0: 1 <= 3 -> corrected; entry 1: None <= 3 -> corrected;
        // entry 2: 4 > 3 -> kept
        assert_eq!(corrected, 2);
        assert_eq!(c.lookup(0), Some(Some((3, 9 << 16))));
        assert_eq!(c.lookup(1), Some(Some((3, 8 << 16))));
        assert_eq!(c.lookup(2), Some(Some((4, 4 << 16))));
    }

    #[test]
    fn correction_marks_dirty_and_drains_denormalized() {
        let mut c = uc(1);
        insert_raw(&mut c, 0, &[0, 0, 0, 0], 1);
        let backing = vec![L2Entry::local(2 << 16, None).raw(), 0, 0, 0];
        assert_eq!(correct_raw(&mut c, 0, &backing, 0), 1);
        let dirty = c.drain();
        assert_eq!(dirty.len(), 1);
        let e = L2Entry(dirty[0].1[0]);
        assert_eq!(e.bfi(), Some(0));
        assert!(!e.is_allocated_here()); // remote stamp persisted
    }

    #[test]
    fn slice_lookup_and_normalized_paths_match_raw_ones() {
        let mut c = uc(2);
        assert!(c.lookup_slice(0).is_none());
        let raw = vec![
            L2Entry::remote(5 << 16, 0).raw(),
            L2Entry::local(7 << 16, Some(2)).raw(),
            0,
            0,
        ];
        // the scratch path: normalize in place, insert without re-normalizing
        let normalized: Vec<u64> = raw.iter().map(|&r| normalize(r, 2)).collect();
        c.insert_normalized(0, &normalized);
        let slice = c.lookup_slice(0).unwrap().to_vec();
        assert_eq!(L2Entry(slice[0]).bfi(), Some(0));
        assert_eq!(L2Entry(slice[1]).bfi(), Some(2));
        assert_eq!(c.lookup(0), Some(Some((0, 5 << 16))));
        assert_eq!(c.lookup(2), Some(None));
        // correction through the normalized path returns the merged slice
        let backing: Vec<u64> =
            [L2Entry::local(9 << 16, None).raw(), 0, 0, 0]
                .iter()
                .map(|&r| normalize(r, 1))
                .collect();
        let (n, merged) = c.correct_normalized(0, &backing).unwrap();
        assert_eq!(n, 1);
        assert_eq!(L2Entry(merged[0]).bfi(), Some(1));
        assert_eq!(c.lookup(0), Some(Some((1, 9 << 16))));
    }

    #[test]
    fn record_write_claims_for_active() {
        let mut c = uc(3);
        insert_raw(&mut c, 0, &[L2Entry::remote(1 << 16, 0).raw(), 0, 0, 0], 3);
        c.record_write(0, 9 << 16);
        assert_eq!(c.lookup(0), Some(Some((3, 9 << 16))));
        let dirty = c.drain();
        let e = L2Entry(dirty[0].1[0]);
        assert!(e.is_allocated_here()); // written back in local form
        assert_eq!(e.host_offset(), 9 << 16);
    }
}
