//! Configurable chain generation — "the release of SQEMU includes a
//! highly configurable chain generation script" (§6.1). This is that
//! script, as a library: build a chain of a given length over a given
//! disk size, with valid clusters uniformly distributed over the backing
//! files and a configurable populated fraction.

use crate::qcow::entry::L2Entry;
use crate::qcow::image::{DataMode, Image};
use crate::qcow::layout::{Geometry, FEATURE_BFI};
use crate::qcow::{snapshot, Chain};
use crate::storage::store::FileStore;
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::Arc;

/// Specification of a generated chain (§6.1 methodology).
#[derive(Clone, Debug)]
pub struct ChainSpec {
    /// Virtual disk size in bytes (paper default: 50 GiB).
    pub disk_size: u64,
    /// Cluster size exponent (default 16 = 64 KiB).
    pub cluster_bits: u32,
    /// Total files in the chain (backing files + active volume).
    pub chain_len: usize,
    /// Fraction of virtual clusters populated (0.9 for dd runs, 0.25 for
    /// RocksDB runs in the paper).
    pub populated: f64,
    /// Create with the SQEMU format extension (stamped entries,
    /// snapshot-time L2 copy) or vanilla.
    pub stamped: bool,
    pub data_mode: DataMode,
    pub seed: u64,
    /// File name prefix on the storage node.
    pub prefix: String,
}

impl Default for ChainSpec {
    fn default() -> Self {
        ChainSpec {
            disk_size: 50 << 30,
            cluster_bits: 16,
            chain_len: 1,
            populated: 0.9,
            stamped: true,
            data_mode: DataMode::Synthetic,
            seed: 0x5EED,
            prefix: "disk".into(),
        }
    }
}

impl ChainSpec {
    pub fn geometry(&self) -> Result<Geometry> {
        Geometry::new(self.cluster_bits, self.disk_size)
    }

    pub fn file_name(&self, idx: usize) -> String {
        format!("{}-{idx}", self.prefix)
    }

    pub fn active_name(&self) -> String {
        self.file_name(self.chain_len - 1)
    }
}

/// Generate a chain per `spec` on `node`. Valid clusters are uniformly
/// distributed over the chain's files; writes land in the file that is
/// active when they happen, exactly like the paper's incremental layers.
pub fn generate(node: &dyn FileStore, spec: &ChainSpec) -> Result<Chain> {
    let geom = spec.geometry()?;
    let mut rng = Rng::new(spec.seed);
    let n = spec.chain_len.max(1);

    // choose the populated cluster set and assign each a uniform layer
    let total = geom.num_vclusters();
    let populated = ((total as f64) * spec.populated) as u64;
    let mut vcs: Vec<u64> = (0..total).collect();
    rng.shuffle(&mut vcs);
    vcs.truncate(populated as usize);
    let mut per_layer: Vec<Vec<u64>> = vec![Vec::new(); n];
    for vc in vcs {
        let layer = rng.below(n as u64) as usize;
        per_layer[layer].push(vc);
    }

    let flags = if spec.stamped { FEATURE_BFI } else { 0 };
    let b = node.create_file(&spec.file_name(0))?;
    let img = Image::create(
        &spec.file_name(0),
        b,
        geom,
        flags,
        0,
        None,
        spec.data_mode,
    )?;
    let mut chain = Chain::new(Arc::new(img))?;

    for (layer, vcs) in per_layer.iter().enumerate() {
        write_layer(&chain, vcs, spec.data_mode, &mut rng)?;
        if layer + 1 < n {
            let name = spec.file_name(layer + 1);
            if spec.stamped {
                snapshot::snapshot_sqemu(&mut chain, node, &name)?;
            } else {
                snapshot::snapshot_vanilla(&mut chain, node, &name)?;
            }
        }
    }
    Ok(chain)
}

/// Populate `vcs` in the current active volume (random data for Real
/// mode; Synthetic mode only charges and indexes).
fn write_layer(chain: &Chain, vcs: &[u64], mode: DataMode, rng: &mut Rng) -> Result<()> {
    let img = chain.active();
    let cs = img.geom().cluster_size() as usize;
    let stamp = if img.has_bfi() { Some(img.chain_index()) } else { None };
    let mut data = vec![0u8; cs];
    for &vc in vcs {
        let off = img.alloc_data_cluster()?;
        if mode == DataMode::Real {
            rng.fill_bytes(&mut data);
            img.write_data(off, 0, &data)?;
        }
        img.set_l2_entry(vc, L2Entry::local(off, stamp))?;
    }
    Ok(())
}

/// Virtual disk copy (§3, Fig 7 bottom): the active volume becomes a
/// shared backing file and two fresh active volumes are created on top.
/// Returns the two resulting chains; all previous files are shared.
pub fn copy_virtual_disk(
    mut chain: Chain,
    node: &dyn FileStore,
    name_a: &str,
    name_b: &str,
) -> Result<(Chain, Chain)> {
    let stamped = chain.active().has_bfi();
    let snap = |chain: &mut Chain, name: &str| -> Result<()> {
        if stamped {
            snapshot::snapshot_sqemu(chain, node, name)
        } else {
            snapshot::snapshot_vanilla(chain, node, name)
        }
    };
    snap(&mut chain, name_a)?;
    // build the sibling chain over the same backing files
    let shared: Vec<Arc<Image>> = chain.images()[..chain.len() - 1].to_vec();
    let mut sibling = Chain::new(Arc::clone(&shared[0]))?;
    sibling.replace_images(shared);
    snap(&mut sibling, name_b)?;
    Ok((chain, sibling))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::storage::node::StorageNode;
    use crate::qcow::qcheck;

    fn small_spec(chain_len: usize, stamped: bool) -> ChainSpec {
        ChainSpec {
            disk_size: 32 << 20,
            chain_len,
            populated: 0.5,
            stamped,
            data_mode: DataMode::Real,
            ..Default::default()
        }
    }

    fn node() -> Arc<StorageNode> {
        StorageNode::new("s", VirtClock::new(), CostModel::default())
    }

    #[test]
    fn generates_requested_shape() {
        let node = node();
        let chain = generate(&node, &small_spec(5, true)).unwrap();
        assert_eq!(chain.len(), 5);
        assert!(qcheck::check_chain(&chain).unwrap().is_clean());
        // populated fraction is roughly respected
        let geom = *chain.active().geom();
        let mut allocated = 0;
        for vc in 0..geom.num_vclusters() {
            if chain.resolve_walk(vc).unwrap().is_some() {
                allocated += 1;
            }
        }
        let frac = allocated as f64 / geom.num_vclusters() as f64;
        assert!((frac - 0.5).abs() < 0.05, "frac={frac}");
    }

    #[test]
    fn layers_hold_distinct_clusters() {
        let node = node();
        let chain = generate(&node, &small_spec(4, true)).unwrap();
        // ownership spread over all four files (uniform distribution)
        let geom = *chain.active().geom();
        let mut owners = vec![0u64; 4];
        for vc in 0..geom.num_vclusters() {
            if let Some((bfi, _)) = chain.resolve_walk(vc).unwrap() {
                owners[bfi as usize] += 1;
            }
        }
        for (i, &count) in owners.iter().enumerate() {
            assert!(count > 0, "layer {i} owns nothing: {owners:?}");
        }
    }

    #[test]
    fn vanilla_spec_produces_unstamped_chain() {
        let node = node();
        let chain = generate(&node, &small_spec(3, false)).unwrap();
        assert!(!chain.active().has_bfi());
        assert!(qcheck::check_chain(&chain).unwrap().is_clean());
    }

    #[test]
    fn deterministic_generation() {
        let n1 = node();
        let n2 = node();
        let c1 = generate(&n1, &small_spec(3, true)).unwrap();
        let c2 = generate(&n2, &small_spec(3, true)).unwrap();
        let geom = *c1.active().geom();
        for vc in 0..geom.num_vclusters() {
            assert_eq!(
                c1.resolve_walk(vc).unwrap().map(|(b, _)| b),
                c2.resolve_walk(vc).unwrap().map(|(b, _)| b),
            );
        }
    }

    #[test]
    fn disk_copy_shares_backing_files() {
        let node = node();
        let chain = generate(&node, &small_spec(3, true)).unwrap();
        let (a, b) = copy_virtual_disk(chain, &node, "copy-a", "copy-b").unwrap();
        assert_eq!(a.len(), 4);
        assert_eq!(b.len(), 4);
        // all but the active volume are the same Arc'd images
        for i in 0..3u16 {
            assert!(Arc::ptr_eq(a.get(i).unwrap(), b.get(i).unwrap()));
        }
        assert_ne!(a.active().name, b.active().name);
    }
}
