//! §3 characterization: a synthetic model of the cloud partner's
//! infrastructure, calibrated to the distributions the paper reports, and
//! the generators for Figs 4, 5, 6, 8 and 9.
//!
//! The real study covers one European datacenter over 2020 (2.8 M VM
//! boots, hundreds of thousands of daily chains). We cannot have those
//! traces (repro band 0/5), so [`Population`] simulates a fleet of chains
//! whose parameters reproduce the paper's take-aways:
//!
//! 1. sizes 10 GB (first party, 30%) / 50 GB (third party, 40%), up to
//!    10 TB;
//! 2. long chains exist (up to 1000+); streaming at threshold 30 caps
//!    many chains (the CDF jump at 30-35);
//! 3. sharing from disk copies and base images, highly variable;
//! 4. high-frequency (daily+) snapshotting on a non-negligible subset —
//!    the source of the long chains (client snapshots are unmergeable).

pub mod population;
pub mod sizes;

pub use population::{Population, PopulationConfig};
pub use sizes::{size_cdf, Party};
