//! Figs 5, 6, 8, 9: a year-long simulation of the datacenter's chain
//! population.
//!
//! Each chain carries a snapshot process (client- and provider-made),
//! a streaming trigger at the provider's threshold, disk-copy events that
//! share backing files between chains, and base-image sharing. The model
//! parameters are calibrated to the paper's reported shapes; see the
//! tests for the take-aways they must reproduce.

use crate::util::rng::Rng;
use crate::util::stats::Cdf;
use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct PopulationConfig {
    pub n_chains: usize,
    pub days: usize,
    /// Provider streaming threshold ("triggered around size 30", §3).
    pub streaming_threshold: usize,
    /// Fraction of chains built on a shared base OS image ("generally
    /// made of around 5 chained backing files", §3).
    pub base_image_fraction: f64,
    pub base_image_files: usize,
    /// Per-chain per-day probability of a virtual disk copy.
    pub copy_rate: f64,
    pub seed: u64,
}

impl Default for PopulationConfig {
    fn default() -> Self {
        PopulationConfig {
            n_chains: 20_000,
            days: 365,
            streaming_threshold: 30,
            base_image_fraction: 0.8,
            base_image_files: 5,
            copy_rate: 2e-4,
            seed: 0xC10D,
        }
    }
}

/// One chain's simulated state.
#[derive(Clone, Debug)]
struct ChainState {
    /// Files in the chain (base image files included).
    len: usize,
    /// Mergeable (provider-made or client-deleted) snapshots.
    mergeable: usize,
    /// Backing files shared with at least one other chain.
    shared: usize,
    /// Mean days between snapshots for this chain.
    interval: f64,
    /// Probability a client snapshot is kept (unmergeable).
    keep_prob: f64,
    /// Day of the previous link creation.
    last_snap: f64,
    /// Day the chain was created (VMs boot all year round — one every
    /// 12 seconds in the studied region, so most chains are young).
    birth: f64,
}

/// Snapshot-creation event record for Fig 9.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fig9Key {
    /// Position in the chain at creation time.
    pub position: u32,
    /// Elapsed-time bucket since the previous link: 0 = <1h, 1 = <1d,
    /// 2 = <1w, 3 = <1mo, 4 = <3mo, 5 = >=3mo.
    pub elapsed_bucket: u8,
}

pub struct Population {
    pub cfg: PopulationConfig,
    chains: Vec<ChainState>,
    /// Fig 5 series: (day, longest chain length).
    pub longest_per_day: Vec<(usize, usize)>,
    /// Fig 9 aggregation: event counts per (position, elapsed bucket).
    pub fig9: HashMap<Fig9Key, u64>,
}

fn elapsed_bucket(days: f64) -> u8 {
    if days < 1.0 / 24.0 {
        0
    } else if days < 1.0 {
        1
    } else if days < 7.0 {
        2
    } else if days < 30.0 {
        3
    } else if days < 90.0 {
        4
    } else {
        5
    }
}

impl Population {
    /// Run the year-long simulation.
    pub fn simulate(cfg: PopulationConfig) -> Population {
        let mut rng = Rng::new(cfg.seed);
        let mut chains: Vec<ChainState> = (0..cfg.n_chains)
            .map(|_| {
                // snapshot cadence mixture (take-away 4): a small
                // high-frequency class produces the 1000+ chains
                let r = rng.f64();
                let interval = if r < 0.005 {
                    0.25 + rng.f64() * 0.5 // several per day
                } else if r < 0.075 {
                    1.0 + rng.f64() // daily
                } else if r < 0.175 {
                    7.0 * (0.7 + rng.f64()) // weekly
                } else if r < 0.395 {
                    30.0 * (1.0 + rng.f64()) // monthly-ish
                } else {
                    90.0 + rng.f64() * 300.0 // rare
                };
                // backup-style chains keep client snapshots
                let keep_prob = if rng.chance(0.3) {
                    0.8 + rng.f64() * 0.2
                } else {
                    rng.f64() * 0.5
                };
                let base = if rng.chance(cfg.base_image_fraction) {
                    cfg.base_image_files
                } else {
                    1
                };
                let birth = rng.f64() * cfg.days as f64;
                ChainState {
                    len: base,
                    mergeable: 0,
                    shared: if base > 1 { base - 1 } else { 0 },
                    interval,
                    keep_prob,
                    last_snap: birth,
                    birth,
                }
            })
            .collect();

        let mut longest_per_day = Vec::with_capacity(cfg.days);
        let mut fig9: HashMap<Fig9Key, u64> = HashMap::new();
        let mut copies: Vec<ChainState> = Vec::new();

        for day in 0..cfg.days {
            for c in chains.iter_mut() {
                if (day as f64) < c.birth {
                    continue;
                }
                // Poisson-ish: probability of >=1 snapshot today
                let lambda = 1.0 / c.interval;
                let snaps_today = if lambda >= 1.0 {
                    lambda.round() as usize
                } else if rng.chance(lambda) {
                    1
                } else {
                    0
                };
                for s in 0..snaps_today {
                    let now = day as f64 + s as f64 / snaps_today.max(1) as f64;
                    let key = Fig9Key {
                        position: c.len as u32,
                        elapsed_bucket: elapsed_bucket(now - c.last_snap),
                    };
                    *fig9.entry(key).or_default() += 1;
                    c.last_snap = now;
                    c.len += 1;
                    // provider-made snapshots (thin provisioning etc.)
                    // and deleted client snapshots are mergeable
                    let client_kept = rng.chance(c.keep_prob);
                    if !client_kept {
                        c.mergeable += 1;
                    }
                }
                // streaming: triggered at the threshold; the provider
                // merges deleted/provider snapshots, which pins chains
                // with enough mergeable files at ~threshold (the 30-35
                // pile of Fig 6) while fully-kept client chains keep
                // growing (take-away 4)
                if c.len > cfg.streaming_threshold && c.mergeable > 0 {
                    let merge = c.mergeable.min(c.len - cfg.streaming_threshold);
                    c.len -= merge;
                    c.mergeable -= merge;
                }
                // disk copy: the whole current chain becomes shared
                if rng.chance(cfg.copy_rate) {
                    c.shared = c.len.max(c.shared);
                    let mut twin = c.clone();
                    twin.len = c.len + 1; // fresh active volume each
                    c.len += 1;
                    twin.last_snap = day as f64;
                    twin.birth = day as f64;
                    copies.push(twin);
                }
            }
            if !copies.is_empty() {
                chains.append(&mut copies);
            }
            let max_len = chains.iter().map(|c| c.len).max().unwrap_or(0);
            longest_per_day.push((day, max_len));
        }
        Population { cfg, chains, longest_per_day, fig9 }
    }

    /// Fig 6: CDF over chains and CDF over files (each file weighted by
    /// its chain's length).
    pub fn chain_length_cdfs(&self) -> (Cdf, Cdf) {
        let per_chain: Vec<u64> = self.chains.iter().map(|c| c.len as u64).collect();
        let mut per_file = Vec::new();
        for c in &self.chains {
            for _ in 0..c.len {
                per_file.push(c.len as u64);
            }
        }
        (Cdf::new(per_chain), Cdf::new(per_file))
    }

    /// Fig 8 scatter: (chain length, shared backing files) per chain.
    pub fn sharing_scatter(&self) -> Vec<(usize, usize)> {
        self.chains.iter().map(|c| (c.len, c.shared.min(c.len - 1))).collect()
    }

    pub fn n_chains(&self) -> usize {
        self.chains.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Population {
        Population::simulate(PopulationConfig {
            n_chains: 3000,
            days: 365,
            ..Default::default()
        })
    }

    #[test]
    fn longest_chain_reaches_several_hundred() {
        // take-away 2: chains up to 1000 exist; always one >= 800 late
        // in the year (scaled population: several hundred suffices
        // proportionally — the class exists)
        let p = small();
        let (_, max_late) = p.longest_per_day[300];
        assert!(max_late > 300, "longest at day 300: {max_late}");
    }

    #[test]
    fn most_chains_are_short() {
        // §3: chains of length <= 10 are > 80% of chains... "chains of
        // length 10 or lower represent more than 80% of the chains"
        let p = small();
        let (chains, files) = p.chain_length_cdfs();
        assert!(chains.at(10) > 0.6, "P(len<=10)={}", chains.at(10));
        // files skew longer than chains (long chains hold many files)
        assert!(files.at(10) < chains.at(10));
    }

    #[test]
    fn streaming_caps_many_chains_near_threshold() {
        let p = small();
        let (chains, _) = p.chain_length_cdfs();
        // visible mass just above the threshold region 30..36
        let jump = chains.at(36) - chains.at(29);
        assert!(jump > 0.01, "no mass at the streaming threshold: {jump}");
    }

    #[test]
    fn sharing_is_variable_and_bounded() {
        let p = small();
        let scatter = p.sharing_scatter();
        assert!(scatter.iter().any(|&(_, s)| s == 0), "some chains unshared");
        assert!(scatter.iter().any(|&(_, s)| s >= 4), "base-image sharing");
        for &(len, shared) in &scatter {
            assert!(shared <= len, "sharing bounded by chain length");
        }
    }

    #[test]
    fn high_frequency_snapshots_dominate_long_chains() {
        // take-away 4: long chains come from daily-or-faster snapshotting
        let p = small();
        let mut long_events = 0u64;
        let mut long_fast = 0u64;
        for (k, &n) in &p.fig9 {
            if k.position > 100 {
                long_events += n;
                if k.elapsed_bucket <= 2 {
                    long_fast += n;
                }
            }
        }
        assert!(long_events > 0);
        assert!(
            long_fast as f64 / long_events as f64 > 0.9,
            "long chains built by fast snapshotting"
        );
    }

    #[test]
    fn population_grows_by_copies() {
        let p = small();
        assert!(p.n_chains() > 3000);
    }
}
