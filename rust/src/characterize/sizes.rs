//! Fig 4: CDF of requested virtual-disk sizes, first vs third party.

use crate::util::rng::Rng;
use crate::util::stats::Cdf;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Party {
    /// Provider-internal VMs.
    First,
    /// Client VMs.
    Third,
}

/// Sample one requested disk size in bytes.
///
/// Calibration (take-away 1): 10 GB is the default and makes up 30% of
/// first-party requests; 50 GB is the most popular third-party size at
/// 40%; sizes stretch to 10 TB with a heavy tail; small test disks exist.
pub fn sample_size(rng: &mut Rng, party: Party) -> u64 {
    const GB: u64 = 1 << 30;
    match party {
        Party::First => {
            let r = rng.f64();
            if r < 0.30 {
                10 * GB // the default size
            } else if r < 0.55 {
                // small operational volumes 1..10 GB
                rng.range(1, 10) * GB
            } else if r < 0.90 {
                // service volumes 10..500 GB, log-uniformish
                (10.0 * (50.0f64).powf(rng.f64())) as u64 * GB
            } else {
                // big data / backup volumes up to 10 TB
                (500.0 * (20.0f64).powf(rng.f64())) as u64 * GB
            }
        }
        Party::Third => {
            let r = rng.f64();
            if r < 0.40 {
                50 * GB // the most popular client size
            } else if r < 0.55 {
                10 * GB
            } else if r < 0.90 {
                (10.0 * (100.0f64).powf(rng.f64())) as u64 * GB
            } else {
                (1000.0 * (10.0f64).powf(rng.f64())) as u64 * GB
            }
        }
    }
}

/// Build the Fig 4 CDF for `n` requests of one party.
pub fn size_cdf(seed: u64, party: Party, n: usize) -> Cdf {
    let mut rng = Rng::new(seed);
    Cdf::new((0..n).map(|_| sample_size(&mut rng, party)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    const GB: u64 = 1 << 30;

    #[test]
    fn first_party_mode_at_10gb() {
        let cdf = size_cdf(1, Party::First, 20_000);
        // ~30% of requests exactly 10 GB
        let at_10 = cdf.at(10 * GB) - cdf.at(10 * GB - 1);
        assert!((at_10 - 0.30).abs() < 0.03, "at_10={at_10}");
    }

    #[test]
    fn third_party_mode_at_50gb() {
        let cdf = size_cdf(2, Party::Third, 20_000);
        let at_50 = cdf.at(50 * GB) - cdf.at(50 * GB - 1);
        assert!((at_50 - 0.40).abs() < 0.03, "at_50={at_50}");
    }

    #[test]
    fn sizes_reach_10tb() {
        let cdf = size_cdf(3, Party::First, 50_000);
        assert!(cdf.quantile(1.0) >= 5 << 40, "max={}", cdf.quantile(1.0));
        assert!(cdf.quantile(1.0) <= 16 << 40);
    }
}
