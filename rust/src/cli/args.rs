//! Flag parsing: `--key value` and boolean `--flag` pairs.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument '{a}'");
            };
            // boolean flag if next token is absent or another flag
            if i + 1 >= argv.len() || argv[i + 1].starts_with("--") {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            } else {
                flags.insert(key.to_string(), argv[i + 1].clone());
                i += 2;
            }
        }
        Ok(Args { flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key)
            .ok_or_else(|| anyhow!("missing required flag --{key}"))
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn size_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => crate::util::parse_size(v)
                .ok_or_else(|| anyhow!("--{key} expects a size (e.g. 50G), got '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
            .unwrap()
    }

    #[test]
    fn key_values_and_bools() {
        let a = parse("--dir /tmp/x --size 50G --vanilla --n 3");
        assert_eq!(a.get("dir"), Some("/tmp/x"));
        assert_eq!(a.size_or("size", 0).unwrap(), 50 << 30);
        assert!(a.bool("vanilla"));
        assert_eq!(a.u64_or("n", 0).unwrap(), 3);
        assert_eq!(a.u64_or("missing", 7).unwrap(), 7);
        assert!(a.require("nope").is_err());
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(&["oops".into()]).is_err());
    }
}
