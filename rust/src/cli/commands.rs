//! Subcommand implementations.

use super::Args;
use crate::cache::CacheConfig;
use crate::chaingen::ChainSpec;
use crate::characterize::population::{Population, PopulationConfig};
use crate::coordinator::server::VmChain;
use crate::coordinator::{Coordinator, VmConfig};
use crate::qcow::image::{DataMode, Image};
use crate::qcow::layout::{Geometry, DEFAULT_CLUSTER_BITS, FEATURE_BFI};
use crate::qcow::{qcheck, snapshot, Chain};
use crate::runtime::service::{verify_service, RuntimeService};
use crate::storage::dir::DirStore;
use crate::storage::store::FileStore;
use crate::util::{human_bytes, human_ns};
use crate::vdisk::DriverKind;
use anyhow::{bail, Result};

fn store(args: &Args) -> Result<DirStore> {
    DirStore::new(args.get("dir").unwrap_or("."))
}

pub fn create(args: &Args) -> Result<()> {
    let s = store(args)?;
    let name = args.require("name")?;
    let size = args.size_or("size", 50 << 30)?;
    let bits = args.u64_or("cluster-bits", DEFAULT_CLUSTER_BITS as u64)? as u32;
    let flags = if args.bool("vanilla") { 0 } else { FEATURE_BFI };
    let geom = Geometry::new(bits, size)?;
    let backend = s.create_file(name)?;
    Image::create(name, backend, geom, flags, 0, None, DataMode::Real)?;
    println!(
        "created '{name}': {} virtual, {} clusters of {}, format {}",
        human_bytes(size),
        geom.num_vclusters(),
        human_bytes(geom.cluster_size()),
        if flags & FEATURE_BFI != 0 { "sqemu" } else { "vanilla" },
    );
    Ok(())
}

pub fn snapshot(args: &Args) -> Result<()> {
    let s = store(args)?;
    let active = args.require("active")?;
    let new = args.require("new")?;
    let mut chain = Chain::open(&s, active, DataMode::Real)?;
    let sqemu = chain.active().has_bfi() || chain.len() == 1 && !args.bool("vanilla");
    let t0 = std::time::Instant::now();
    if sqemu && !args.bool("vanilla") {
        snapshot::snapshot_sqemu(&mut chain, &s, new)?;
    } else {
        snapshot::snapshot_vanilla(&mut chain, &s, new)?;
    }
    println!(
        "snapshot '{new}' created on top of '{active}' in {} (chain length {})",
        human_ns(t0.elapsed().as_nanos() as u64),
        chain.len()
    );
    Ok(())
}

pub fn convert(args: &Args) -> Result<()> {
    let s = store(args)?;
    let active = args.require("active")?;
    let chain = Chain::open(&s, active, DataMode::Real)?;
    let stamped = snapshot::convert_to_sqemu(&chain)?;
    println!("stamped {stamped} L2 entries in '{active}' (chain length {})", chain.len());
    Ok(())
}

pub fn stream(args: &Args) -> Result<()> {
    let s = store(args)?;
    let active = args.require("active")?;
    let from = args.u64_or("from", 0)? as u16;
    let to = args.require("to")?.parse::<u16>()?;
    let mut chain = Chain::open(&s, active, DataMode::Real)?;
    let before = chain.len();
    let copied = snapshot::stream_merge(&mut chain, from, to)?;
    println!(
        "streamed files {from}..={to}: {copied} clusters copied, chain {before} -> {}",
        chain.len()
    );
    // merged predecessors are gone from the chain; delete their files
    Ok(())
}

pub fn info(args: &Args) -> Result<()> {
    let s = store(args)?;
    let name = args.require("name")?;
    let backend = s.open_file(name)?;
    let img = Image::open(name, backend, DataMode::Real)?;
    let geom = *img.geom();
    println!("file:          {name}");
    println!("virtual size:  {}", human_bytes(geom.virtual_size));
    println!("cluster size:  {}", human_bytes(geom.cluster_size()));
    println!("physical size: {}", human_bytes(img.file_len()));
    println!("format:        {}", if img.has_bfi() { "sqemu (bfi-stamped)" } else { "vanilla" });
    println!("chain index:   {}", img.chain_index());
    println!("backing file:  {}", img.backing_name().unwrap_or_else(|| "(none)".into()));
    println!("L1 entries:    {}", geom.l1_entries());
    Ok(())
}

pub fn check(args: &Args) -> Result<()> {
    let s = store(args)?;
    let active = args.require("active")?;
    let chain = Chain::open(&s, active, DataMode::Real)?;
    let report = qcheck::check_chain(&chain)?;
    println!(
        "chain '{active}': {} files, {} consistent clusters, {} leaked",
        chain.len(),
        report.ok_clusters,
        report.leaked_clusters
    );
    if report.is_clean() {
        println!("no errors found");
        Ok(())
    } else {
        for e in &report.errors {
            eprintln!("ERROR: {e}");
        }
        bail!("{} consistency errors", report.errors.len());
    }
}

pub fn characterize(args: &Args) -> Result<()> {
    let cfg = PopulationConfig {
        n_chains: args.u64_or("chains", 20_000)? as usize,
        days: args.u64_or("days", 365)? as usize,
        ..Default::default()
    };
    println!("simulating {} chains over {} days...", cfg.n_chains, cfg.days);
    let pop = Population::simulate(cfg);
    let (chains, files) = pop.chain_length_cdfs();
    println!("\nchain-length CDF (Fig 6):");
    for len in [1u64, 5, 10, 30, 35, 50, 100, 500, 1000] {
        println!(
            "  len <= {len:>5}: {:>5.1}% of chains, {:>5.1}% of files",
            100.0 * chains.at(len),
            100.0 * files.at(len)
        );
    }
    let (_, longest) = *pop.longest_per_day.last().unwrap();
    println!("\nlongest chain at year end (Fig 5): {longest}");
    let scatter = pop.sharing_scatter();
    let unshared = scatter.iter().filter(|(_, s)| *s == 0).count();
    println!(
        "sharing (Fig 8): {} chains, {:.1}% with no sharing, max shared {}",
        scatter.len(),
        100.0 * unshared as f64 / scatter.len() as f64,
        scatter.iter().map(|(_, s)| *s).max().unwrap_or(0)
    );
    println!("\n(run `cargo bench --bench fig04_09_characterize` for the full tables)");
    Ok(())
}

pub fn serve(args: &Args) -> Result<()> {
    let vms = args.u64_or("vms", 4)?;
    let chain_len = args.u64_or("chain", 50)? as usize;
    let requests = args.u64_or("requests", 2_000)?;
    let kind = if args.bool("vanilla") {
        DriverKind::Vanilla
    } else {
        DriverKind::Scalable
    };
    let coord = Coordinator::with_fresh_nodes(3)?;
    println!(
        "coordinator: 3 storage nodes, {vms} x {} VMs on chains of {chain_len}",
        kind.name()
    );
    for v in 0..vms {
        let name = format!("vm-{v}");
        coord.launch_vm(
            &name,
            VmConfig {
                driver: kind,
                cache: CacheConfig::new(512, 4 << 20),
                chain: VmChain::Generate(ChainSpec {
                    disk_size: 1 << 30,
                    chain_len,
                    populated: 0.5,
                    stamped: kind == DriverKind::Scalable,
                    data_mode: DataMode::Synthetic,
                    prefix: name.clone(),
                    seed: 0x5EED ^ v,
                    ..Default::default()
                }),
            },
        )?;
    }
    let t0 = std::time::Instant::now();
    let mut handles = vec![];
    for name in coord.vm_names() {
        let client = coord.client(&name)?;
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut rng = crate::util::rng::Rng::new(fxhash(name.as_bytes()));
            for _ in 0..requests {
                let voff = rng.below((1 << 30) - 4096);
                if rng.chance(0.2) {
                    client.write(voff, vec![1u8; 512])?;
                } else {
                    client.read(voff, 4096)?;
                }
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().unwrap()?;
    }
    let wall = t0.elapsed();
    println!("\nper-VM stats after {requests} requests each:");
    for name in coord.vm_names() {
        let s = coord.vm_stats(&name)?;
        println!(
            "  {name}: {} reads / {} writes, {} read",
            s.reads,
            s.writes,
            human_bytes(s.bytes_read)
        );
    }
    let total_ops = vms * requests;
    println!(
        "\nfleet: {total_ops} ops in {:.2}s wall = {:.0} ops/s; virtual time {}",
        wall.as_secs_f64(),
        total_ops as f64 / wall.as_secs_f64(),
        human_ns(coord.clock.now())
    );
    println!("memory accounted: {}", human_bytes(coord.acct.total()));
    coord.shutdown();
    Ok(())
}

pub fn selftest(_args: &Args) -> Result<()> {
    print!("artifacts: ");
    match RuntimeService::try_default() {
        None => println!("NOT FOUND (run `make artifacts`); host fallback active"),
        Some(svc) => {
            println!(
                "loaded (clusters={}, batch={}, chain={}, stream_depth={})",
                svc.clusters, svc.batch, svc.chain, svc.stream_depth
            );
            print!("pjrt-vs-host differential: ");
            verify_service(&svc)?;
            println!("OK");
            svc.shutdown();
        }
    }
    println!("cli selftest passed");
    Ok(())
}

fn fxhash(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}
