//! Subcommand implementations.

use super::Args;
use crate::blockjob::{BlockJob, JobFence, JobKind, LiveStampJob, LiveStreamJob, RateLimiter};
use crate::cache::CacheConfig;
use crate::chaingen::ChainSpec;
use crate::characterize::population::{Population, PopulationConfig};
use crate::coordinator::server::VmChain;
use crate::coordinator::{Coordinator, VmConfig};
use crate::qcow::image::{DataMode, Image};
use crate::qcow::layout::{Geometry, DEFAULT_CLUSTER_BITS, FEATURE_BFI};
use crate::qcow::{qcheck, snapshot, Chain};
use crate::runtime::service::{verify_service, RuntimeService};
use crate::storage::dir::DirStore;
use crate::storage::store::FileStore;
use crate::util::{human_bytes, human_ns};
use crate::vdisk::DriverKind;
use anyhow::{bail, Result};

fn store(args: &Args) -> Result<DirStore> {
    DirStore::new(args.get("dir").unwrap_or("."))
}

pub fn create(args: &Args) -> Result<()> {
    let s = store(args)?;
    let name = args.require("name")?;
    let size = args.size_or("size", 50 << 30)?;
    let bits = args.u64_or("cluster-bits", DEFAULT_CLUSTER_BITS as u64)? as u32;
    let flags = if args.bool("vanilla") { 0 } else { FEATURE_BFI };
    let geom = Geometry::new(bits, size)?;
    let backend = s.create_file(name)?;
    Image::create(name, backend, geom, flags, 0, None, DataMode::Real)?;
    println!(
        "created '{name}': {} virtual, {} clusters of {}, format {}",
        human_bytes(size),
        geom.num_vclusters(),
        human_bytes(geom.cluster_size()),
        if flags & FEATURE_BFI != 0 { "sqemu" } else { "vanilla" },
    );
    Ok(())
}

pub fn snapshot(args: &Args) -> Result<()> {
    let s = store(args)?;
    let active = args.require("active")?;
    let new = args.require("new")?;
    let mut chain = Chain::open(&s, active, DataMode::Real)?;
    let sqemu = chain.active().has_bfi() || chain.len() == 1 && !args.bool("vanilla");
    let t0 = std::time::Instant::now();
    if sqemu && !args.bool("vanilla") {
        snapshot::snapshot_sqemu(&mut chain, &s, new)?;
    } else {
        snapshot::snapshot_vanilla(&mut chain, &s, new)?;
    }
    println!(
        "snapshot '{new}' created on top of '{active}' in {} (chain length {})",
        human_ns(t0.elapsed().as_nanos() as u64),
        chain.len()
    );
    Ok(())
}

pub fn convert(args: &Args) -> Result<()> {
    let s = store(args)?;
    let active = args.require("active")?;
    let chain = Chain::open(&s, active, DataMode::Real)?;
    let stamped = snapshot::convert_to_sqemu(&chain)?;
    println!("stamped {stamped} L2 entries in '{active}' (chain length {})", chain.len());
    Ok(())
}

pub fn stream(args: &Args) -> Result<()> {
    let s = store(args)?;
    let active = args.require("active")?;
    let from = args.u64_or("from", 0)? as u16;
    let to = args.require("to")?.parse::<u16>()?;
    let mut chain = Chain::open(&s, active, DataMode::Real)?;
    let before = chain.len();
    let copied = snapshot::stream_merge(&mut chain, from, to)?;
    println!(
        "streamed files {from}..={to}: {copied} clusters copied, chain {before} -> {}",
        chain.len()
    );
    println!(
        "merged predecessors are no longer part of the chain; reclaim their \
         files with `sqemu gc run --dir <dir> --active <heads>` once no other \
         chain shares them"
    );
    Ok(())
}

/// `sqemu job <verb>`: incremental, rate-limited chain maintenance over
/// a directory store. Unlike `sqemu stream`/`convert` (which run to
/// completion in one blocking pass), a job runs in bounded increments,
/// honours a bytes/second rate limit against wall time, records its
/// lifecycle in `<dir>/sqemu-jobs.log`, and polls for a cooperative
/// cancel marker between increments — so `sqemu job cancel` from
/// another terminal stops it at the next increment boundary.
pub fn job(verb: &str, args: &Args) -> Result<()> {
    match verb {
        "start" => job_start(args),
        "list" => job_list(args),
        "cancel" => job_cancel(args),
        other => bail!("unknown job verb '{other}' (try start|list|cancel)"),
    }
}

fn journal_path(dir: &str) -> std::path::PathBuf {
    std::path::Path::new(dir).join("sqemu-jobs.log")
}

fn cancel_marker(dir: &str, id: &str) -> std::path::PathBuf {
    std::path::Path::new(dir).join(format!("sqemu-job-{id}.cancel"))
}

fn journal_append(
    dir: &str,
    id: &str,
    kind: JobKind,
    state: &str,
    processed: u64,
    total: u64,
    copied: u64,
) -> Result<()> {
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(journal_path(dir))?;
    writeln!(f, "{id} {} {state} {processed}/{total} {copied}", kind.name())?;
    // the journal is the job's crash-recovery record: a checkpoint that
    // is not on stable storage is a checkpoint that never happened
    f.sync_all()?;
    Ok(())
}

/// A journal line is well-formed when it carries all five fields and a
/// parsable progress fraction — anything else (typically the torn tail
/// of a crashed append) is skipped, never fatal.
fn journal_parse(line: &str) -> Option<(&str, &str, &str, u64, u64, u64)> {
    let f: Vec<&str> = line.split_whitespace().collect();
    if f.len() < 5 {
        return None;
    }
    let (processed, total) = f[3].split_once('/')?;
    Some((
        f[0],
        f[1],
        f[2],
        processed.parse().ok()?,
        total.parse().ok()?,
        f[4].parse().ok()?,
    ))
}

/// The cluster cursor a crashed/cancelled job of this id can resume
/// from: its last durably journalled progress (0 when the journal knows
/// nothing useful, e.g. the job completed).
fn journal_resume_point(dir: &str, id: &str, kind: JobKind) -> Result<u64> {
    let content = std::fs::read_to_string(journal_path(dir)).unwrap_or_default();
    let mut cursor = None;
    for line in content.lines() {
        let Some((lid, lkind, state, processed, _total, _copied)) =
            journal_parse(line)
        else {
            continue; // torn line: the progress it recorded is lost
        };
        if lid != id {
            continue;
        }
        if lkind != kind.name() {
            bail!("journal has job '{id}' as kind '{lkind}', not '{}'", kind.name());
        }
        cursor = match state {
            "completed" => Some(0),
            _ => Some(processed),
        };
    }
    Ok(cursor.unwrap_or(0))
}

/// Durably checkpoint a running job: the image state the checkpoint
/// describes is flushed BEFORE the journal line that claims it (the
/// same data-before-mapping ordering the format itself uses).
const CHECKPOINT_EVERY_INCREMENTS: u64 = 32;

fn job_start(args: &Args) -> Result<()> {
    let s = store(args)?;
    let dir = args.get("dir").unwrap_or(".").to_string();
    let active = args.require("active")?;
    let kind_s = args.get("kind").unwrap_or("stream");
    let kind = JobKind::parse(kind_s)
        .ok_or_else(|| anyhow::anyhow!("--kind expects stream|stamp, got '{kind_s}'"))?;
    let rate = args.size_or("rate", 0)?; // bytes/s; 0 = unlimited
    let increment = args.u64_or("increment", 32)?.max(1);
    let id = args
        .get("id")
        .map(str::to_string)
        .unwrap_or_else(|| format!("job-{}", std::process::id()));

    let resume_from = if args.bool("resume") {
        journal_resume_point(&dir, &id, kind)?
    } else {
        0
    };

    let mut chain = Chain::open(&s, active, DataMode::Real)?;
    let cluster = chain.active().geom().cluster_size();
    let fence = std::sync::Arc::new(JobFence::default());
    fence.begin();
    let mut job: Box<dyn BlockJob> = match kind {
        JobKind::Stream => Box::new(LiveStreamJob::resume_at(
            &chain,
            std::sync::Arc::clone(&fence),
            resume_from,
        )),
        JobKind::Stamp => Box::new(LiveStampJob::resume_at(
            &chain,
            std::sync::Arc::clone(&fence),
            resume_from,
        )),
        JobKind::Gc => bail!("garbage collection runs via `sqemu gc run`, not `job start`"),
        JobKind::Mirror => bail!(
            "chain migration needs a multi-node fleet; try `sqemu migrate` \
             (coordinator demo)"
        ),
        JobKind::Scan => bail!(
            "capacity scans run on a coordinator fleet; try `sqemu control \
             status` (HA demo)"
        ),
    };
    let total = job.total_clusters();
    let len_before = chain.len();
    journal_append(&dir, &id, kind, "running", resume_from, total, 0)?;
    println!(
        "job '{id}': {} over '{active}' ({total} clusters, chain length \
         {len_before}, rate {}{})",
        kind.name(),
        if rate == 0 { "unlimited".to_string() } else { format!("{}/s", human_bytes(rate)) },
        if resume_from > 0 {
            format!(", resumed at cluster {resume_from}")
        } else {
            String::new()
        },
    );

    let t0 = std::time::Instant::now();
    let now_ns = |t0: &std::time::Instant| t0.elapsed().as_nanos() as u64;
    let mut limiter = RateLimiter::new(rate, increment * cluster, now_ns(&t0));
    let marker = cancel_marker(&dir, &id);
    // a marker left over from cancelling an already-finished job (or a
    // recycled default id) must not kill this fresh job
    let _ = std::fs::remove_file(&marker);
    let (mut processed, mut copied) = (resume_from, 0u64);
    let mut increments = 0u64;
    loop {
        if marker.exists() {
            let _ = std::fs::remove_file(&marker);
            // same ordering as a checkpoint: the image state this line
            // claims must be durable before the line exists, or a later
            // `--resume` could skip past copies a power cut destroyed
            chain.active().flush()?;
            journal_append(&dir, &id, kind, "cancelled", processed, total, copied)?;
            println!("job '{id}' cancelled at {processed}/{total} clusters");
            return Ok(());
        }
        let now = now_ns(&t0);
        let ready = limiter.ready_at(now);
        if ready > now {
            std::thread::sleep(std::time::Duration::from_nanos(ready - now));
        }
        let inc = job.run_increment(&mut chain, increment)?;
        processed += inc.processed;
        copied += inc.copied;
        increments += 1;
        limiter.consume(inc.bytes, now_ns(&t0));
        if inc.complete {
            break;
        }
        if increments % CHECKPOINT_EVERY_INCREMENTS == 0 {
            // image state first, then the journal line that claims it:
            // a crash between the two resumes a little early, never late
            chain.active().flush()?;
            journal_append(&dir, &id, kind, "checkpoint", processed, total, copied)?;
        }
    }
    job.finalize(&mut chain)?;
    fence.end();
    // fail loudly if the finished job left anything inconsistent
    let report = qcheck::check_chain(&chain)?;
    if !report.is_clean() {
        journal_append(&dir, &id, kind, "failed", processed, total, copied)?;
        for e in &report.errors {
            eprintln!("ERROR: {e}");
        }
        bail!("post-job qcheck found {} errors", report.errors.len());
    }
    journal_append(&dir, &id, kind, "completed", processed, total, copied)?;
    match kind {
        JobKind::Stream => println!(
            "job '{id}' completed: {copied} clusters copied, chain {len_before} -> {} \
             (merged backing files can now be deleted)",
            chain.len()
        ),
        JobKind::Stamp => println!(
            "job '{id}' completed: {copied} entries stamped; '{}' now carries the \
             sqemu format flag",
            chain.active().name
        ),
        JobKind::Gc | JobKind::Mirror | JobKind::Scan => {
            unreachable!("rejected above")
        }
    }
    println!("qcheck: clean ({} consistent clusters)", report.ok_clusters);
    Ok(())
}

fn job_list(args: &Args) -> Result<()> {
    let dir = args.get("dir").unwrap_or(".");
    let path = journal_path(dir);
    let content = match std::fs::read_to_string(&path) {
        Ok(c) => c,
        Err(_) => {
            println!("no jobs recorded in {}", path.display());
            return Ok(());
        }
    };
    // latest WELL-FORMED journal line per job id, in first-seen order: a
    // torn trailing line (the crashed append of a dead job) is skipped
    // instead of shadowing the job's last good state or failing the list
    let mut order: Vec<&str> = Vec::new();
    let mut latest: std::collections::BTreeMap<&str, (&str, &str, u64, u64, u64)> =
        Default::default();
    let mut torn = 0usize;
    for line in content.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let Some((id, kind, state, processed, total, copied)) = journal_parse(line)
        else {
            torn += 1;
            continue;
        };
        if !latest.contains_key(id) {
            order.push(id);
        }
        latest.insert(id, (kind, state, processed, total, copied));
    }
    println!("{:<16} {:<8} {:<10} {:>14} {:>8}", "ID", "KIND", "STATE", "PROGRESS", "COPIED");
    for id in order {
        let (kind, state, processed, total, copied) = latest[id];
        println!(
            "{:<16} {:<8} {:<10} {:>14} {:>8}",
            id,
            kind,
            state,
            format!("{processed}/{total}"),
            copied
        );
    }
    if torn > 0 {
        eprintln!("(skipped {torn} torn journal line(s) from an interrupted append)");
    }
    Ok(())
}

fn job_cancel(args: &Args) -> Result<()> {
    let dir = args.get("dir").unwrap_or(".");
    let id = args.require("id")?;
    std::fs::write(cancel_marker(dir, id), b"cancel")?;
    println!(
        "cancel requested for job '{id}'; a running `sqemu job start` in \
         {dir} will stop at its next increment boundary"
    );
    Ok(())
}

/// `sqemu gc <verb>`: capacity reclamation over a directory store.
///
/// The live chain heads are named with `--active a,b,...`; every image
/// file in the directory that no head's backing walk reaches is garbage
/// (the leftovers of `sqemu stream` / `job start --kind stream`, which
/// drop files from the chain but cannot know whether another chain still
/// shares them — the operator's `--active` list is that knowledge here;
/// in the coordinator the GC registry tracks it automatically).
///
/// * `gc status` (or `gc run --dry-run`) — the leak audit: report
///   reachable / garbage files and reclaimable bytes, delete nothing.
/// * `gc run` — physically delete the garbage files.
pub fn gc(verb: &str, args: &Args) -> Result<()> {
    let dry = match verb {
        "run" => args.bool("dry-run"),
        "status" => true,
        other => bail!("unknown gc verb '{other}' (try run|status)"),
    };
    let s = store(args)?;
    let dir = args.get("dir").unwrap_or(".").to_string();
    let actives = args.require("active")?;
    let heads: Vec<&str> = actives.split(',').filter(|h| !h.is_empty()).collect();
    if heads.is_empty() {
        // an empty head list would make *everything* garbage — refuse
        bail!("--active must name at least one live chain head");
    }

    // reachable set: walk backing names from every live chain head
    let mut reachable = std::collections::HashSet::new();
    for head in &heads {
        crate::gc::walk_backing(&s, head, &mut reachable)?;
    }

    // diff the directory against reachability
    let mut garbage: Vec<(String, u64)> = Vec::new();
    let mut skipped = 0usize;
    for entry in std::fs::read_dir(&dir)? {
        let entry = entry?;
        if !entry.file_type()?.is_file() {
            continue;
        }
        let name = entry.file_name().to_string_lossy().to_string();
        if reachable.contains(&name) {
            continue;
        }
        // only files that parse as images are GC candidates; journals,
        // cancel markers and foreign files are never touched
        let is_image = s
            .open_file(&name)
            .and_then(|b| Image::open(&name, b, DataMode::Real))
            .is_ok();
        if is_image {
            garbage.push((name, entry.metadata()?.len()));
        } else {
            skipped += 1;
        }
    }
    garbage.sort();

    let total: u64 = garbage.iter().map(|(_, b)| *b).sum();
    println!(
        "gc over '{dir}': {} reachable from {} chain head(s), {} garbage \
         image(s) ({}), {skipped} non-image file(s) ignored",
        reachable.len(),
        heads.len(),
        garbage.len(),
        human_bytes(total),
    );
    for (name, bytes) in &garbage {
        if dry {
            println!("  would delete {name} ({})", human_bytes(*bytes));
        } else {
            s.delete_file(name)?;
            println!("  deleted {name} ({})", human_bytes(*bytes));
        }
    }
    if dry {
        println!("dry run: nothing deleted; `sqemu gc run` reclaims {}", human_bytes(total));
    } else {
        println!("reclaimed {}", human_bytes(total));
    }
    Ok(())
}

pub fn info(args: &Args) -> Result<()> {
    let s = store(args)?;
    let name = args.require("name")?;
    let backend = s.open_file(name)?;
    let img = Image::open(name, backend, DataMode::Real)?;
    let geom = *img.geom();
    println!("file:          {name}");
    println!("virtual size:  {}", human_bytes(geom.virtual_size));
    println!("cluster size:  {}", human_bytes(geom.cluster_size()));
    println!("physical size: {}", human_bytes(img.file_len()));
    println!("format:        {}", if img.has_bfi() { "sqemu (bfi-stamped)" } else { "vanilla" });
    println!("chain index:   {}", img.chain_index());
    println!("backing file:  {}", img.backing_name().unwrap_or_else(|| "(none)".into()));
    println!("L1 entries:    {}", geom.l1_entries());
    Ok(())
}

pub fn check(args: &Args) -> Result<()> {
    let s = store(args)?;
    let active = args.require("active")?;
    let chain = Chain::open(&s, active, DataMode::Real)?;
    if args.bool("repair") {
        let rep = qcheck::repair_chain(&chain)?;
        if rep.changed() {
            println!(
                "repair: {} L1 pointer(s) cleared, {} dangling mapping(s) \
                 cleared, {} stamp(s) fixed, {} reftable slot(s) cleared, \
                 {} refcount(s) rewritten ({} leaked cluster(s) reclaimed), \
                 {} orphaned tail cluster(s) truncated",
                rep.l1_cleared,
                rep.entries_cleared,
                rep.stamps_fixed,
                rep.reftable_cleared,
                rep.refcounts_rewritten,
                rep.leaks_reclaimed,
                rep.tail_clusters_truncated,
            );
        } else {
            println!("repair: nothing to fix");
        }
    }
    let report = qcheck::check_chain(&chain)?;
    println!(
        "chain '{active}': {} files, {} consistent clusters, {} leaked",
        chain.len(),
        report.ok_clusters,
        report.leaked_clusters
    );
    if report.is_clean() {
        println!("no errors found");
        Ok(())
    } else {
        for e in &report.errors {
            eprintln!("ERROR: {e}");
        }
        bail!("{} consistency errors", report.errors.len());
    }
}

pub fn characterize(args: &Args) -> Result<()> {
    let cfg = PopulationConfig {
        n_chains: args.u64_or("chains", 20_000)? as usize,
        days: args.u64_or("days", 365)? as usize,
        ..Default::default()
    };
    println!("simulating {} chains over {} days...", cfg.n_chains, cfg.days);
    let pop = Population::simulate(cfg);
    let (chains, files) = pop.chain_length_cdfs();
    println!("\nchain-length CDF (Fig 6):");
    for len in [1u64, 5, 10, 30, 35, 50, 100, 500, 1000] {
        println!(
            "  len <= {len:>5}: {:>5.1}% of chains, {:>5.1}% of files",
            100.0 * chains.at(len),
            100.0 * files.at(len)
        );
    }
    let (_, longest) = *pop.longest_per_day.last().unwrap();
    println!("\nlongest chain at year end (Fig 5): {longest}");
    let scatter = pop.sharing_scatter();
    let unshared = scatter.iter().filter(|(_, s)| *s == 0).count();
    println!(
        "sharing (Fig 8): {} chains, {:.1}% with no sharing, max shared {}",
        scatter.len(),
        100.0 * unshared as f64 / scatter.len() as f64,
        scatter.iter().map(|(_, s)| *s).max().unwrap_or(0)
    );
    println!("\n(run `cargo bench --bench fig04_09_characterize` for the full tables)");
    Ok(())
}

pub fn serve(args: &Args) -> Result<()> {
    use crate::coordinator::server::CoordinatorConfig;
    use crate::coordinator::NodeSet;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::storage::node::StorageNode;
    let vms = args.u64_or("vms", 4)?;
    let chain_len = args.u64_or("chain", 50)? as usize;
    let requests = args.u64_or("requests", 2_000)?;
    let trace_sample = args.u64_or("trace-sample", 0)?;
    let kind = if args.bool("vanilla") {
        DriverKind::Vanilla
    } else {
        DriverKind::Scalable
    };
    let clock = VirtClock::new();
    let nodes = (0..3)
        .map(|i| {
            StorageNode::new(&format!("node-{i}"), clock.clone(), CostModel::default())
        })
        .collect();
    let coord = Coordinator::new(
        std::sync::Arc::new(NodeSet::new(nodes)?),
        clock,
        CoordinatorConfig { trace_sample, ..Default::default() },
        RuntimeService::try_default(),
    );
    println!(
        "coordinator: 3 storage nodes, {vms} x {} VMs on chains of {chain_len}",
        kind.name()
    );
    for v in 0..vms {
        let name = format!("vm-{v}");
        coord.launch_vm(
            &name,
            VmConfig {
                driver: kind,
                cache: CacheConfig::new(512, 4 << 20),
                chain: VmChain::Generate(ChainSpec {
                    disk_size: 1 << 30,
                    chain_len,
                    populated: 0.5,
                    stamped: kind == DriverKind::Scalable,
                    data_mode: DataMode::Synthetic,
                    prefix: name.clone(),
                    seed: 0x5EED ^ v,
                    ..Default::default()
                }),
            },
        )?;
    }
    let t0 = std::time::Instant::now();
    let mut handles = vec![];
    for name in coord.vm_names() {
        let client = coord.client(&name)?;
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut rng = crate::util::rng::Rng::new(fxhash(name.as_bytes()));
            for _ in 0..requests {
                let voff = rng.below((1 << 30) - (64 << 10));
                if rng.chance(0.2) {
                    client.write(voff, vec![1u8; 512])?;
                } else if rng.chance(0.125) {
                    // a vectored burst: 8 sequential 4 KiB reads in one
                    // round-trip (they coalesce into merged device reads)
                    let reqs: Vec<(u64, usize)> =
                        (0..8).map(|i| (voff + i * 4096, 4096)).collect();
                    client.readv(&reqs)?;
                } else {
                    client.read(voff, 4096)?;
                }
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().unwrap()?;
    }
    let wall = t0.elapsed();
    println!("\nper-VM stats after {requests} requests each:");
    for name in coord.vm_names() {
        let s = coord.vm_stats(&name)?;
        println!(
            "  {name}: {} reads / {} writes, {} read; {} batched ops, \
             {} merged device reads ({} coalesced)",
            s.reads,
            s.writes,
            human_bytes(s.bytes_read),
            s.batched_ops,
            s.merged_ios,
            human_bytes(s.coalesced_bytes)
        );
    }
    let total_ops = vms * requests;
    println!(
        "\nfleet: {total_ops} ops in {:.2}s wall = {:.0} ops/s; virtual time {}",
        wall.as_secs_f64(),
        total_ops as f64 / wall.as_secs_f64(),
        human_ns(coord.clock.now())
    );
    println!("memory accounted: {}", human_bytes(coord.acct.total()));
    println!("\nring occupancy per shard executor:");
    for s in coord.shard_stats() {
        println!(
            "  shard-{}: {} vms, {} queued now, {} served over {} passes \
             ({:.1} ops/pass), {} park wakeups",
            s.shard,
            s.vms,
            s.queued,
            s.served,
            s.passes,
            s.served as f64 / s.passes.max(1) as f64,
            s.wakeups,
        );
    }
    if let Some(path) = args.get("metrics") {
        std::fs::write(path, coord.telemetry().render())?;
        println!("metrics scrape written to {path}");
    }
    if let Some(path) = args.get("trace") {
        let ring = coord.trace_ring();
        std::fs::write(path, ring.to_json())?;
        println!(
            "trace dump written to {path} ({} spans buffered of {} recorded, \
             {} dropped)",
            ring.len(),
            ring.total(),
            ring.dropped(),
        );
    }
    coord.shutdown();
    Ok(())
}

// --------------------------------------------------- fleet demos
// `migrate`, `rebalance` and `node status` operate a live multi-node
// coordinator. The CLI's directory store is a single namespace with no
// notion of nodes, so these commands build a deterministic in-process
// fleet (deliberately skewed onto node-0, the shape §3 says placement
// drifts into) and act on it — the `serve` convention.

fn demo_fleet(args: &Args) -> Result<std::sync::Arc<Coordinator>> {
    use crate::chaingen::generate;
    let n_nodes = (args.u64_or("nodes", 3)? as usize).max(2);
    let vms = args.u64_or("vms", 6)? as usize;
    let chain_len = (args.u64_or("chain", 12)? as usize).max(1);
    let coord = Coordinator::with_fresh_nodes(n_nodes)?;
    for v in 0..vms {
        // two thirds of the fleet lands on node-0, the rest round-robin
        let pin = if 3 * v < 2 * vms {
            "node-0".to_string()
        } else {
            format!("node-{}", 1 + v % (n_nodes - 1))
        };
        let store = coord.nodes.pinned(&pin)?;
        let name = format!("vm-{v}");
        generate(
            &store,
            &ChainSpec {
                disk_size: 64 << 20,
                chain_len,
                populated: 0.4,
                stamped: true,
                data_mode: DataMode::Synthetic,
                prefix: name.clone(),
                seed: 0x517E ^ v as u64,
                ..Default::default()
            },
        )?;
        coord.launch_vm(
            &name,
            VmConfig {
                driver: DriverKind::Scalable,
                cache: CacheConfig::new(128, 2 << 20),
                chain: VmChain::Existing {
                    active_name: format!("{name}-{}", chain_len - 1),
                    data_mode: DataMode::Synthetic,
                },
            },
        )?;
    }
    Ok(coord)
}

fn print_node_status(coord: &Coordinator) {
    println!(
        "{:<10} {:>10} {:>10} {:>12} {:>10} {:>10} {:>12} {:>6}",
        "NODE", "logical", "physical", "pressure", "condemned", "reserved", "reclaimed", "gc"
    );
    for s in coord.nodes.node_stats() {
        println!(
            "{:<10} {:>10} {:>10} {:>12} {:>10} {:>10} {:>12} {:>6}",
            s.name,
            human_bytes(s.logical_bytes),
            human_bytes(s.used_bytes),
            human_bytes(s.pressure_bytes),
            human_bytes(s.condemned_bytes),
            human_bytes(s.reserved_bytes),
            human_bytes(s.reclaimed_bytes),
            s.gc_deletes,
        );
    }
    let pressures: Vec<u64> = coord
        .nodes
        .nodes()
        .iter()
        .map(|n| n.committed_bytes())
        .collect();
    println!(
        "fleet max/min pressure ratio: {:.2}",
        crate::migrate::rebalance::pressure_ratio(&pressures)
    );
    println!(
        "\n{:<10} {:>6} {:>8} {:>10} {:>10} {:>10} {:>10}",
        "SHARD", "vms", "queued", "served", "passes", "ops/pass", "wakeups"
    );
    for s in coord.shard_stats() {
        println!(
            "{:<10} {:>6} {:>8} {:>10} {:>10} {:>10.1} {:>10}",
            format!("shard-{}", s.shard),
            s.vms,
            s.queued,
            s.served,
            s.passes,
            s.served as f64 / s.passes.max(1) as f64,
            s.wakeups,
        );
    }
    for node in coord.nodes.nodes() {
        let io = node.scheduler().snapshot();
        if io.busy_ns > 0 {
            println!(
                "{}: device util {:.1}% ({} merged seeks, {} transferred \
                 under merge windows)",
                node.name,
                node.scheduler().utilization() * 100.0,
                io.merged_seeks,
                human_bytes(io.fresh_bytes),
            );
        }
    }
}

/// `sqemu node status`: per-node used/pressure/condemned/reclaimed bytes
/// and migration reservations over the demo fleet.
pub fn node(verb: &str, args: &Args) -> Result<()> {
    match verb {
        "status" => {
            let coord = demo_fleet(args)?;
            coord.refresh_capacity();
            print_node_status(&coord);
            coord.shutdown();
            Ok(())
        }
        other => bail!("unknown node verb '{other}' (try status)"),
    }
}

/// `sqemu dedup status [--nodes N] [--vms V] [--writes W]`: run a
/// capacity-enabled demo fleet — a cloned population whose guests write
/// identical content (the golden-image pattern §3 describes) plus
/// all-zero and compressible clusters — and report per-node dedup
/// extents and the fleet's logical/physical capacity multiplication.
pub fn dedup(verb: &str, args: &Args) -> Result<()> {
    match verb {
        "status" => dedup_status(args),
        other => bail!("unknown dedup verb '{other}' (try status)"),
    }
}

fn dedup_status(args: &Args) -> Result<()> {
    use crate::coordinator::server::CoordinatorConfig;
    use crate::coordinator::NodeSet;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::storage::node::StorageNode;
    let n_nodes = (args.u64_or("nodes", 1)? as usize).max(1);
    let vms = (args.u64_or("vms", 4)? as usize).max(1);
    let writes = args.u64_or("writes", 48)?;
    let clock = VirtClock::new();
    let nodes = (0..n_nodes)
        .map(|i| {
            StorageNode::new(&format!("node-{i}"), clock.clone(), CostModel::default())
        })
        .collect();
    let coord = Coordinator::new(
        std::sync::Arc::new(NodeSet::new(nodes)?),
        clock,
        CoordinatorConfig { capacity: true, ..Default::default() },
        None,
    );
    const CS: u64 = 64 << 10;
    let clusters = (32u64 << 20) / CS;
    // one golden chain; every clone gets a private active volume
    // snapshotted over the SAME immutable backing files — the
    // `copy_virtual_disk` population shape. Launch then seeds the dedup
    // index from the shared base, so guest rewrites of golden content
    // resolve to remote references instead of fresh clusters.
    let store = coord.nodes.pinned("node-0")?;
    let mut gold = crate::chaingen::generate(
        &store,
        &ChainSpec {
            disk_size: 32 << 20,
            chain_len: 2,
            populated: 0.25,
            stamped: true,
            data_mode: DataMode::Real,
            prefix: "gold".into(),
            seed: 0x601D,
            ..Default::default()
        },
    )?;
    crate::qcow::snapshot::snapshot_sqemu(&mut gold, &store, "vm-0-active")?;
    let shared: Vec<_> = gold.images()[..gold.len() - 1].to_vec();
    for v in 1..vms {
        let mut sib = crate::qcow::Chain::new(std::sync::Arc::clone(&shared[0]))?;
        sib.replace_images(shared.clone());
        crate::qcow::snapshot::snapshot_sqemu(
            &mut sib,
            &store,
            &format!("vm-{v}-active"),
        )?;
    }
    for v in 0..vms {
        let name = format!("vm-{v}");
        coord.launch_vm(
            &name,
            VmConfig {
                driver: DriverKind::Scalable,
                cache: CacheConfig::new(128, 2 << 20),
                chain: VmChain::Existing {
                    active_name: format!("vm-{v}-active"),
                    data_mode: DataMode::Real,
                },
            },
        )?;
    }
    println!(
        "capacity fleet: {n_nodes} node(s), {vms} clone VM(s) over one \
         golden base, {writes} full-cluster writes each (same workload \
         per clone)"
    );
    for name in coord.vm_names() {
        let client = coord.client(&name)?;
        // every clone runs the SAME deterministic workload — identical
        // bytes at identical offsets, the dedup index's best case
        let mut rng = crate::util::rng::Rng::new(0xC10_E);
        for i in 0..writes {
            let vc = rng.below(clusters);
            let data = match i % 4 {
                // all-zero cluster: allocates nothing (OFLAG_ZERO)
                0 => vec![0u8; CS as usize],
                // compressible cluster: RLE shrinks it (OFLAG_COMPRESSED)
                1 => vec![(i % 251) as u8; CS as usize],
                // the guest copies a cluster it can already read (the
                // in-guest file-copy pattern): identical bytes dedup
                // against the seeded golden base or an earlier write
                _ => {
                    let src = rng.below(clusters);
                    client.read(src * CS, CS as usize)?
                }
            };
            client.write(vc * CS, data)?;
        }
        client.flush()?;
    }
    let capacity = coord.refresh_capacity();
    let ix = coord.dedup_index();
    println!(
        "\n{:<10} {:>8} {:>8} {:>10} {:>10} {:>10} {:>7}",
        "NODE", "extents", "refs", "saved", "logical", "physical", "ratio"
    );
    let (mut tot_l, mut tot_p) = (0u64, 0u64);
    for (name, logical, physical) in &capacity {
        let s = ix.node_stats(name);
        tot_l += logical;
        tot_p += physical;
        println!(
            "{:<10} {:>8} {:>8} {:>10} {:>10} {:>10} {:>6.2}x",
            name,
            s.extents,
            s.refs,
            human_bytes(s.saved_bytes),
            human_bytes(*logical),
            human_bytes(*physical),
            *logical as f64 / (*physical).max(1) as f64,
        );
    }
    let fleet = ix.fleet_stats();
    println!(
        "\nfleet: {} extents, {} references, {} of writes served by sharing",
        fleet.extents,
        fleet.refs,
        human_bytes(fleet.saved_bytes)
    );
    println!(
        "fleet capacity multiplication: {} logical / {} physical = {:.2}x",
        human_bytes(tot_l),
        human_bytes(tot_p),
        tot_l as f64 / tot_p.max(1) as f64
    );
    let audit = coord.gc_audit();
    println!(
        "audit: {} stale extent(s){}",
        audit.stale_extents.len(),
        if audit.stale_extents.is_empty() { " (clean)" } else { "" }
    );
    coord.shutdown();
    Ok(())
}

/// `sqemu control status [--nodes N] [--vms V]`: run the demo fleet
/// under the HA control plane — a write-ahead [`StateStore`] on a
/// dedicated metadata node, lease-based VM ownership, a leader kill and
/// a standby failover — and print the store status at each step.
///
/// [`StateStore`]: crate::control::StateStore
pub fn control(verb: &str, args: &Args) -> Result<()> {
    match verb {
        "status" => control_status(args),
        other => bail!("unknown control verb '{other}' (try status)"),
    }
}

fn control_status(args: &Args) -> Result<()> {
    use crate::control::StateStore;
    use crate::coordinator::server::CoordinatorConfig;
    use crate::coordinator::NodeSet;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::storage::node::StorageNode;
    let n_nodes = (args.u64_or("nodes", 2)? as usize).max(1);
    let vms = (args.u64_or("vms", 4)? as usize).max(1);
    let clock = VirtClock::new();
    let data_nodes = (0..n_nodes)
        .map(|i| {
            StorageNode::new(&format!("node-{i}"), clock.clone(), CostModel::default())
        })
        .collect();
    let nodes = std::sync::Arc::new(NodeSet::new(data_nodes)?);
    // the control log lives OFF the data plane, on its own metadata node
    let meta = StorageNode::new("meta-0", clock.clone(), CostModel::default());
    let store = StateStore::open(std::sync::Arc::clone(&meta))?;
    let cfg = CoordinatorConfig {
        lease_ttl_ns: 2_000_000_000,
        ..Default::default()
    };
    let a = Coordinator::new(
        std::sync::Arc::clone(&nodes),
        clock.clone(),
        cfg.clone(),
        None,
    );
    a.attach_control(std::sync::Arc::clone(&store), "coord-a")?;
    a.campaign()?;
    for v in 0..vms {
        let name = format!("vm-{v}");
        let pin = nodes.pinned(&format!("node-{}", v % n_nodes))?;
        crate::chaingen::generate(
            &pin,
            &ChainSpec {
                disk_size: 16 << 20,
                chain_len: 3,
                populated: 0.3,
                stamped: true,
                data_mode: DataMode::Synthetic,
                prefix: name.clone(),
                seed: 0xC0DE ^ v as u64,
                ..Default::default()
            },
        )?;
        a.launch_vm(
            &name,
            VmConfig {
                driver: DriverKind::Scalable,
                cache: CacheConfig::new(128, 2 << 20),
                chain: VmChain::Existing {
                    active_name: format!("{name}-2"),
                    data_mode: DataMode::Synthetic,
                },
            },
        )?;
    }
    for name in a.vm_names() {
        let client = a.client(&name)?;
        for i in 0..16u64 {
            client.write(i * 4096, vec![0x5A; 4096])?;
        }
        client.flush()?;
    }
    println!("leader 'coord-a' holds the fleet:");
    print_control_status(&a.control_status()?);
    println!("\nkilling 'coord-a' (no drain, leases left in the log) ...");
    a.halt();
    let b = Coordinator::new(std::sync::Arc::clone(&nodes), clock, cfg, None);
    b.attach_control(store, "coord-b")?;
    let report = b.takeover()?;
    println!(
        "standby 'coord-b' took over: {} chain(s) re-adopted from {} \
         logged lease(s) — no fleet scan ({} migration(s) resolved)",
        report.chains_checked,
        b.vm_names().len(),
        report.migrations_committed + report.migrations_rolled_back,
    );
    println!("\nnew leader 'coord-b':");
    print_control_status(&b.control_status()?);
    b.shutdown_clean()?;
    println!("\nafter clean shutdown (next recovery skips the repair scan):");
    print_control_status(&b.control_status()?);
    Ok(())
}

fn print_control_status(st: &crate::control::StoreStatus) {
    println!(
        "  log:   generation {}, {} records ({}), {}",
        st.generation,
        st.records,
        human_bytes(st.log_bytes),
        if st.wedged { "WEDGED" } else { "healthy" },
    );
    println!(
        "  epoch: {} (leader {})",
        st.epoch,
        if st.leader.is_empty() { "(none)" } else { &st.leader },
    );
    println!(
        "  fleet: {} vm(s), {} lease(s), {} job(s), {} migration(s) in \
         flight, clean shutdown: {}",
        st.vms, st.leases, st.jobs, st.migrations, st.clean_shutdown,
    );
}

/// `sqemu metrics [--vms N] [--nodes K] [--requests R] [--names]
/// [--out FILE] [--trace FILE]`: run a full-featured fleet — capacity
/// subsystem on, HA control plane attached, trace sampling, guest load,
/// a stream job, a live migration and a GC sweep — and emit one
/// Prometheus-text scrape of the telemetry registry. Every subsystem
/// exports, so the scrape (and `--names`, the sorted metric-name
/// inventory CI diffs against `telemetry/metrics.txt`) covers the whole
/// family set.
pub fn metrics(args: &Args) -> Result<()> {
    use crate::control::StateStore;
    use crate::coordinator::server::CoordinatorConfig;
    use crate::coordinator::{JobSpec, NodeSet};
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::storage::node::StorageNode;
    const CS: u64 = 64 << 10;
    let n_nodes = (args.u64_or("nodes", 2)? as usize).max(2);
    let vms = (args.u64_or("vms", 8)? as usize).max(2);
    let requests = args.u64_or("requests", 64)?;
    let clock = VirtClock::new();
    let data_nodes = (0..n_nodes)
        .map(|i| {
            StorageNode::new(&format!("node-{i}"), clock.clone(), CostModel::default())
        })
        .collect();
    let nodes = std::sync::Arc::new(NodeSet::new(data_nodes)?);
    // the control log lives off the data plane, on its own metadata node
    let meta = StorageNode::new("meta-0", clock.clone(), CostModel::default());
    let store = StateStore::open(std::sync::Arc::clone(&meta))?;
    let coord = Coordinator::new(
        std::sync::Arc::clone(&nodes),
        clock,
        CoordinatorConfig {
            capacity: true,
            trace_sample: 4,
            lease_ttl_ns: 5_000_000_000,
            ..Default::default()
        },
        None,
    );
    coord.attach_control(store, "coord-0")?;
    coord.campaign()?;
    for v in 0..vms {
        let name = format!("vm-{v}");
        let pin = nodes.pinned(&format!("node-{}", v % n_nodes))?;
        crate::chaingen::generate(
            &pin,
            &ChainSpec {
                disk_size: 16 << 20,
                chain_len: 3,
                populated: 0.3,
                stamped: true,
                data_mode: DataMode::Synthetic,
                prefix: name.clone(),
                seed: 0x3E7 ^ v as u64,
                ..Default::default()
            },
        )?;
        coord.launch_vm(
            &name,
            VmConfig {
                driver: DriverKind::Scalable,
                cache: CacheConfig::new(128, 2 << 20),
                chain: VmChain::Existing {
                    active_name: format!("{name}-2"),
                    data_mode: DataMode::Synthetic,
                },
            },
        )?;
    }
    // guest load: zero and duplicate-content cluster writes (dedup
    // food), vectored bursts (coalescer food), scattered reads
    let clusters = (16u64 << 20) / CS;
    for name in coord.vm_names() {
        let client = coord.client(&name)?;
        let mut rng = crate::util::rng::Rng::new(fxhash(name.as_bytes()));
        for i in 0..requests {
            let vc = rng.below(clusters - 1);
            match i % 4 {
                0 => client.write(vc * CS, vec![0u8; CS as usize])?,
                1 => client.write(vc * CS, vec![(i % 5) as u8 + 1; CS as usize])?,
                2 => {
                    let reqs: Vec<(u64, usize)> =
                        (0..8).map(|k| (vc * CS + k * 4096, 4096)).collect();
                    client.readv(&reqs)?;
                }
                _ => {
                    client.read(vc * CS, 4096)?;
                }
            }
        }
        client.flush()?;
    }
    // exercise the job, migrate and gc subsystems so their counters move
    let job = coord.start_job("vm-0", JobSpec::stream(0))?;
    coord.wait_job(&job);
    let mig = coord.migrate_vm("vm-1", "node-0", 0)?;
    let guest = coord.client("vm-1")?;
    let mut served = 0u64;
    while !mig.state().is_terminal() {
        guest.read((served % 32) * 4096, 4096)?;
        served += 1;
    }
    coord.wait_job(&mig);
    coord.run_gc(0)?;
    coord.snapshot_vm("vm-0", "vm-0-metrics-snap")?;
    coord.renew_leases()?;

    let reg = coord.telemetry();
    if args.bool("names") {
        // the sorted metric-name inventory (CI diffs this against the
        // checked-in telemetry/metrics.txt) — nothing else on stdout
        for n in reg.metric_names() {
            println!("{n}");
        }
    } else {
        let text = reg.render();
        match args.get("out") {
            Some(path) if path != "true" => {
                std::fs::write(path, &text)?;
                println!(
                    "scrape written to {path}: {} families, {} lines, {} VMs, \
                     {} node(s) + meta",
                    reg.metric_names().len(),
                    text.lines().count(),
                    vms,
                    n_nodes,
                );
            }
            _ => print!("{text}"),
        }
    }
    if let Some(path) = args.get("trace") {
        if path != "true" {
            std::fs::write(path, coord.trace_ring().to_json())?;
        }
    }
    coord.shutdown_clean()?;
    Ok(())
}

/// `sqemu top [--vms N] [--iterations I] [--interval-ms MS]`: a live
/// fleet view refreshed from the telemetry registry while a background
/// workload runs — per-VM request p50/p99 and throughput counters,
/// per-node device utilization, per-shard queue depth. Everything shown
/// comes from [`Registry::gather`]: `top` is a registry consumer, not
/// another stats path.
///
/// [`Registry::gather`]: crate::telemetry::Registry::gather
pub fn top(args: &Args) -> Result<()> {
    use std::sync::atomic::{AtomicBool, Ordering};
    let vms = (args.u64_or("vms", 4)? as usize).max(1);
    let iterations = args.u64_or("iterations", 5)?;
    let interval = args.u64_or("interval-ms", 200)?;
    let coord = Coordinator::with_fresh_nodes(3)?;
    for v in 0..vms {
        let name = format!("vm-{v}");
        coord.launch_vm(
            &name,
            VmConfig {
                driver: DriverKind::Scalable,
                cache: CacheConfig::new(128, 2 << 20),
                chain: VmChain::Generate(ChainSpec {
                    disk_size: 64 << 20,
                    chain_len: 8,
                    populated: 0.4,
                    stamped: true,
                    data_mode: DataMode::Synthetic,
                    prefix: name.clone(),
                    seed: 0x701 ^ v as u64,
                    ..Default::default()
                }),
            },
        )?;
    }
    let stop = std::sync::Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    for name in coord.vm_names() {
        let client = coord.client(&name)?;
        let stop = std::sync::Arc::clone(&stop);
        workers.push(std::thread::spawn(move || {
            let mut rng = crate::util::rng::Rng::new(fxhash(name.as_bytes()));
            while !stop.load(Ordering::Relaxed) {
                let voff = rng.below((64 << 20) - (64 << 10));
                let done = if rng.chance(0.25) {
                    client.write(voff, vec![0x5A; 512]).is_ok()
                } else {
                    client.read(voff, 4096).is_ok()
                };
                if !done {
                    break; // fleet shutting down under us
                }
            }
        }));
    }
    for frame in 0..iterations {
        std::thread::sleep(std::time::Duration::from_millis(interval));
        let fams = coord.telemetry().gather();
        println!(
            "--- sqemu top: frame {}/{iterations}, virtual time {} ---",
            frame + 1,
            human_ns(coord.clock.now()),
        );
        let reads = family_values(&fams, "sqemu_guest_reads_total");
        let writes = family_values(&fams, "sqemu_guest_writes_total");
        let p99 = family_values(&fams, "sqemu_guest_req_p99_ns");
        let at = |m: &[(String, f64)], key: &str| {
            m.iter().find(|(k, _)| k == key).map(|(_, v)| *v).unwrap_or(0.0)
        };
        println!(
            "{:<10} {:>10} {:>10} {:>10} {:>10}",
            "VM", "reads", "writes", "p50_us", "p99_us"
        );
        for (vm, p50) in family_values(&fams, "sqemu_guest_req_p50_ns") {
            println!(
                "{:<10} {:>10} {:>10} {:>10.1} {:>10.1}",
                vm,
                at(&reads, &vm) as u64,
                at(&writes, &vm) as u64,
                p50 / 1e3,
                at(&p99, &vm) / 1e3,
            );
        }
        println!("{:<10} {:>12}", "NODE", "device_util");
        for (node, util) in family_values(&fams, "sqemu_node_device_utilization") {
            println!("{:<10} {:>11.1}%", node, util * 100.0);
        }
        let shard_vms = family_values(&fams, "sqemu_shard_vms");
        println!("{:<10} {:>8} {:>8}", "SHARD", "depth", "vms");
        for (shard, depth) in family_values(&fams, "sqemu_shard_queue_depth") {
            println!(
                "{:<10} {:>8} {:>8}",
                format!("shard-{shard}"),
                depth as u64,
                at(&shard_vms, &shard) as u64,
            );
        }
    }
    stop.store(true, Ordering::Relaxed);
    for w in workers {
        let _ = w.join();
    }
    coord.shutdown();
    Ok(())
}

/// First-label-value -> numeric value for one gathered family, in
/// sample order (the `top` frame extractor).
fn family_values(
    fams: &[crate::telemetry::Family],
    name: &str,
) -> Vec<(String, f64)> {
    use crate::telemetry::SampleValue;
    let Some(f) = fams.iter().find(|f| f.name == name) else {
        return Vec::new();
    };
    f.samples
        .iter()
        .filter_map(|s| {
            let label =
                s.labels.first().map(|(_, v)| v.clone()).unwrap_or_default();
            match &s.value {
                SampleValue::Counter(v) => Some((label, *v as f64)),
                SampleValue::Gauge(v) => Some((label, *v)),
                SampleValue::Histo(_) => None,
            }
        })
        .collect()
}

/// `sqemu migrate --vm V --to NODE [--rate 64M]`: live-migrate one VM's
/// chain in the demo fleet while its guest keeps reading.
pub fn migrate(args: &Args) -> Result<()> {
    let coord = demo_fleet(args)?;
    let vm = args.get("vm").unwrap_or("vm-0").to_string();
    let to = args.require("to")?;
    let rate = args.size_or("rate", 0)?;
    println!("before migration:");
    print_node_status(&coord);
    let shared = coord.migrate_vm(&vm, to, rate)?;
    // the guest keeps serving while the mirror converges
    let client = coord.client(&vm)?;
    let mut guest_reads = 0u64;
    while !shared.state().is_terminal() {
        client.read((guest_reads % 64) * 4096, 4096)?;
        guest_reads += 1;
    }
    let st = coord.wait_job(&shared);
    match st.error {
        Some(e) => bail!("migration failed: {e}"),
        None => println!(
            "\nmigrated '{vm}' to '{to}': {} chunks copied ({}), {} increments, \
             {guest_reads} guest reads served during the move",
            st.copied,
            human_bytes(st.bytes_copied),
            st.increments,
        ),
    }
    let gc = coord.run_gc(0)?;
    println!(
        "gc: {} superseded source copies reclaimed ({})",
        gc.files_deleted,
        human_bytes(gc.reclaimed_bytes)
    );
    println!("\nafter migration + gc:");
    print_node_status(&coord);
    coord.shutdown();
    Ok(())
}

/// `sqemu rebalance [--dry-run] [--threshold 1.5] [--rate 256M]`: plan
/// (and unless dry-run, execute) migrations until the fleet's max/min
/// pressure ratio is under the threshold.
pub fn rebalance(args: &Args) -> Result<()> {
    let coord = demo_fleet(args)?;
    let dry = args.bool("dry-run");
    let threshold: f64 = match args.get("threshold") {
        None => 1.5,
        Some(v) => v
            .parse()
            .map_err(|_| anyhow::anyhow!("--threshold expects a number, got '{v}'"))?,
    };
    let rate = args.size_or("rate", 0)?;
    println!("before rebalance:");
    print_node_status(&coord);
    let report = coord.rebalance(threshold, rate, dry)?;
    println!(
        "\nplan: {} move(s), ratio {:.2} -> {:.2} (threshold {threshold})",
        report.plan.moves.len(),
        report.plan.ratio_before,
        report.plan.ratio_projected,
    );
    for m in &report.plan.moves {
        println!(
            "  {} {}: {} -> {} ({})",
            if dry { "would move" } else { "moved" },
            m.vm,
            m.from,
            m.to,
            human_bytes(m.bytes)
        );
    }
    if !dry {
        let gc = coord.run_gc(0)?;
        println!(
            "executed {} move(s); gc reclaimed {} source copies ({})",
            report.executed,
            gc.files_deleted,
            human_bytes(gc.reclaimed_bytes)
        );
        println!("\nafter rebalance + gc (final ratio {:.2}):", report.final_ratio);
        print_node_status(&coord);
    }
    coord.shutdown();
    Ok(())
}

/// `sqemu bench [--json [path]]`: the CI smoke run of the hot-path and
/// vectored benches; always writes the JSON artifact (default
/// `BENCH_hotpath.json`) so the perf trajectory is tracked.
pub fn bench(args: &Args) -> Result<()> {
    let path = match args.get("json") {
        None | Some("true") => "BENCH_hotpath.json",
        Some(p) => p,
    };
    crate::bench::smoke::run_smoke(path)
}

pub fn selftest(_args: &Args) -> Result<()> {
    print!("artifacts: ");
    match RuntimeService::try_default() {
        None => println!("NOT FOUND (run `make artifacts`); host fallback active"),
        Some(svc) => {
            println!(
                "loaded (clusters={}, batch={}, chain={}, stream_depth={})",
                svc.clusters, svc.batch, svc.chain, svc.stream_depth
            );
            print!("pjrt-vs-host differential: ");
            verify_service(&svc)?;
            println!("OK");
            svc.shutdown();
        }
    }
    println!("cli selftest passed");
    Ok(())
}

fn fxhash(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn journal_parser_accepts_wellformed_rejects_torn() {
        let parsed = journal_parse("job-1 stream running 5/10 3").unwrap();
        assert_eq!(parsed, ("job-1", "stream", "running", 5, 10, 3));
        // the torn tail of a crashed append, in various stages of loss
        assert!(journal_parse("job-1 stream running 5/10").is_none());
        assert!(journal_parse("job-1 stream runn").is_none());
        assert!(journal_parse("job-1 stream running 5x10 3").is_none());
        assert!(journal_parse("job-1 stream running a/10 3").is_none());
        assert!(journal_parse("").is_none());
    }

    #[test]
    fn resume_point_uses_last_wellformed_checkpoint() {
        let dir = std::env::temp_dir().join(format!(
            "sqemu-journal-test-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let d = dir.to_str().unwrap();
        std::fs::write(
            journal_path(d),
            "job-1 stream running 0/64 0\n\
             job-1 stream checkpoint 32/64 20\n\
             job-1 stream chec",
        )
        .unwrap();
        // the torn trailing line is ignored; the durable checkpoint wins
        assert_eq!(journal_resume_point(d, "job-1", JobKind::Stream).unwrap(), 32);
        // unknown job: start from scratch
        assert_eq!(journal_resume_point(d, "job-2", JobKind::Stream).unwrap(), 0);
        // kind mismatch is an operator error, not a silent restart
        assert!(journal_resume_point(d, "job-1", JobKind::Stamp).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
