//! The `sqemu` CLI — hand-rolled argument parsing (no `clap` in the
//! offline crate set). Subcommands cover the image tools (`qemu-img`
//! analogues over real files), the simulation/figure harness and the
//! coordinator demo.
//!
//! ```text
//! sqemu create  --dir D --name N --size 50G [--vanilla]
//! sqemu snapshot --dir D --active N --new M
//! sqemu convert --dir D --active N            # stamp a vanilla chain
//! sqemu stream  --dir D --active N --from I --to J
//! sqemu job start --dir D --active N --kind stream|stamp [--rate 64M] [--resume]
//! sqemu job list --dir D                      # job journal
//! sqemu job cancel --dir D --id J             # cooperative cancel
//! sqemu gc run --dir D --active A[,B,...] [--dry-run]
//! sqemu gc status --dir D --active A[,B,...]  # leak audit, deletes nothing
//! sqemu info    --dir D --name N
//! sqemu check   --dir D --active N [--repair] # verify; --repair recovers
//! sqemu characterize [--chains N]             # §3 figures
//! sqemu serve   [--vms N] [--chain L] [--metrics F] [--trace F] [--trace-sample N]
//! sqemu metrics [--vms N] [--names] [--out F] [--trace F]  # telemetry scrape
//! sqemu top     [--vms N] [--iterations I] [--interval-ms MS]  # live fleet view
//! sqemu migrate --to node-1 [--vm vm-0] [--rate 64M]  # live-migrate a chain
//! sqemu rebalance [--dry-run] [--threshold 1.5]       # fleet rebalancer
//! sqemu node status [--nodes N] [--vms V]     # per-node capacity + per-shard queues
//! sqemu dedup status [--nodes N] [--vms V]    # capacity-multiplication demo
//! sqemu control status [--nodes N] [--vms V]  # HA control-plane demo (log, leases, failover)
//! sqemu bench   [--json [path]]               # CI perf smoke artifact
//! sqemu selftest                              # artifacts + runtime
//! ```

mod args;
mod commands;

use anyhow::{bail, Result};
pub use args::Args;

pub fn run(argv: Vec<String>) -> Result<()> {
    let Some((cmd, rest)) = argv.split_first() else {
        print_usage();
        return Ok(());
    };
    if cmd == "job" {
        // `sqemu job <verb> --flags ...` — the verb is positional
        let Some((verb, rest)) = rest.split_first() else {
            bail!("usage: sqemu job start|list|cancel --dir D ...");
        };
        let args = Args::parse(rest)?;
        return commands::job(verb, &args);
    }
    if cmd == "gc" {
        // `sqemu gc <verb> --flags ...` — the verb is positional
        let Some((verb, rest)) = rest.split_first() else {
            bail!("usage: sqemu gc run|status --dir D --active A[,B,...] [--dry-run]");
        };
        let args = Args::parse(rest)?;
        return commands::gc(verb, &args);
    }
    if cmd == "node" {
        // `sqemu node <verb> --flags ...` — the verb is positional
        let Some((verb, rest)) = rest.split_first() else {
            bail!("usage: sqemu node status [--nodes N] [--vms V] [--chain L]");
        };
        let args = Args::parse(rest)?;
        return commands::node(verb, &args);
    }
    if cmd == "dedup" {
        // `sqemu dedup <verb> --flags ...` — the verb is positional
        let Some((verb, rest)) = rest.split_first() else {
            bail!("usage: sqemu dedup status [--nodes N] [--vms V] [--writes W]");
        };
        let args = Args::parse(rest)?;
        return commands::dedup(verb, &args);
    }
    if cmd == "control" {
        // `sqemu control <verb> --flags ...` — the verb is positional
        let Some((verb, rest)) = rest.split_first() else {
            bail!("usage: sqemu control status [--nodes N] [--vms V]");
        };
        let args = Args::parse(rest)?;
        return commands::control(verb, &args);
    }
    let args = Args::parse(rest)?;
    match cmd.as_str() {
        "create" => commands::create(&args),
        "snapshot" => commands::snapshot(&args),
        "convert" => commands::convert(&args),
        "stream" => commands::stream(&args),
        "info" => commands::info(&args),
        "check" => commands::check(&args),
        "characterize" => commands::characterize(&args),
        "serve" => commands::serve(&args),
        "metrics" => commands::metrics(&args),
        "top" => commands::top(&args),
        "migrate" => commands::migrate(&args),
        "rebalance" => commands::rebalance(&args),
        "bench" => commands::bench(&args),
        "selftest" => commands::selftest(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `sqemu help`)"),
    }
}

fn print_usage() {
    println!(
        "sqemu — Virtual Disk Snapshot Management at Scale (SQEMU reproduction)\n\
         \n\
         image tools (real files):\n\
         \x20 create   --dir D --name N --size 50G [--vanilla] [--cluster-bits 16]\n\
         \x20 snapshot --dir D --active N --new M\n\
         \x20 convert  --dir D --active N\n\
         \x20 stream   --dir D --active N --from I --to J\n\
         \x20 job start --dir D --active N --kind stream|stamp [--rate 64M] \
         [--increment 32] [--id J] [--resume]\n\
         \x20 job list --dir D\n\
         \x20 job cancel --dir D --id J\n\
         \x20 gc run    --dir D --active A[,B,...] [--dry-run]\n\
         \x20 gc status --dir D --active A[,B,...]\n\
         \x20 info     --dir D --name N\n\
         \x20 check    --dir D --active N [--repair]\n\
         \n\
         study & demo:\n\
         \x20 characterize [--chains N] [--days N]\n\
         \x20 serve [--vms N] [--chain L] [--requests R] [--vanilla] \
         [--metrics FILE] [--trace FILE] [--trace-sample N]\n\
         \x20 metrics [--vms N] [--nodes K] [--requests R] [--names] \
         [--out FILE] [--trace FILE]   # Prometheus-text scrape\n\
         \x20 top [--vms N] [--iterations I] [--interval-ms MS]   # live fleet view\n\
         \x20 migrate --to node-1 [--vm vm-0] [--rate 64M] [--vms N] [--chain L]\n\
         \x20 rebalance [--dry-run] [--threshold 1.5] [--rate 256M]\n\
         \x20 node status [--nodes N] [--vms V] [--chain L]\n\
         \x20 dedup status [--nodes N] [--vms V] [--writes W]\n\
         \x20 control status [--nodes N] [--vms V]   # HA log + leases + failover\n\
         \x20 bench [--json [path]]   # CI smoke run -> BENCH_hotpath.json\n\
         \x20 selftest\n\
         \n\
         figures: cargo bench --bench fig12_memory (etc.); --full for paper scale"
    );
}
