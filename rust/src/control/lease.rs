//! Lease-based VM ownership.
//!
//! A coordinator may only serve a VM while it holds that VM's lease in
//! the [`super::StateStore`]. Leases are granted against the *virtual*
//! clock shared by the whole fleet (tests drive expiry by advancing
//! it), renewed by the leader's heartbeat, and adjudicated entirely
//! store-side: acquisition fails while a different holder's lease is
//! unexpired, so at most one coordinator owns a VM at any instant. An
//! expired lease is the failover signal — the new leader's
//! `Coordinator::takeover()` tears down whatever the dead owner left
//! behind (rings, capacity reservations, half-finished jobs) and
//! re-adopts the chain.

use std::collections::HashMap;

/// One VM's ownership claim.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Lease {
    /// The coordinator instance holding the claim.
    pub holder: String,
    /// Virtual-clock ns past which the claim is void.
    pub expires_ns: u64,
}

impl Lease {
    pub fn expired(&self, now_ns: u64) -> bool {
        self.expires_ns <= now_ns
    }
}

/// Partition a lease table into (live, expired) at `now_ns`, each
/// sorted by VM name so callers iterate deterministically.
pub fn partition_leases(
    leases: &HashMap<String, Lease>,
    now_ns: u64,
) -> (Vec<(String, Lease)>, Vec<(String, Lease)>) {
    let mut live = Vec::new();
    let mut expired = Vec::new();
    for (vm, lease) in leases {
        if lease.expired(now_ns) {
            expired.push((vm.clone(), lease.clone()));
        } else {
            live.push((vm.clone(), lease.clone()));
        }
    }
    live.sort();
    expired.sort();
    (live, expired)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expiry_is_inclusive_at_the_boundary() {
        let l = Lease { holder: "a".into(), expires_ns: 100 };
        assert!(!l.expired(99));
        assert!(l.expired(100), "a lease is void AT its expiry instant");
        assert!(l.expired(101));
    }

    #[test]
    fn partition_sorts_deterministically() {
        let mut t = HashMap::new();
        t.insert("vm-b".to_string(), Lease { holder: "x".into(), expires_ns: 50 });
        t.insert("vm-a".to_string(), Lease { holder: "x".into(), expires_ns: 500 });
        t.insert("vm-c".to_string(), Lease { holder: "y".into(), expires_ns: 10 });
        let (live, expired) = partition_leases(&t, 100);
        assert_eq!(live.len(), 1);
        assert_eq!(live[0].0, "vm-a");
        assert_eq!(expired.len(), 2);
        assert_eq!(expired[0].0, "vm-b");
        assert_eq!(expired[1].0, "vm-c");
    }
}
