//! The durable, highly-available control plane (DESIGN.md §15).
//!
//! Everything the coordinator used to keep only in process memory —
//! the placement name→node index, the GC registry's refcounts and
//! condemned sets, the migration journal index, block-job descriptors,
//! VM definitions — is persisted as it mutates into a write-ahead
//! [`StateStore`] on a dedicated metadata node. Recovery becomes log
//! replay plus per-lease validation, O(active leases) instead of the
//! O(fleet) node scans of the PR-4 path (which survives as the
//! fallback for a log torn beyond its last valid snapshot).
//!
//! The same store arbitrates multi-coordinator operation: epoch-fenced
//! leader election ([`StateStore::campaign`]) plus per-VM ownership
//! [`Lease`]s. A standby tails the log with [`StateStore::reopen`],
//! campaigns when the leader dies, and `Coordinator::takeover()`
//! re-adopts exactly the VMs whose leases expired — the failover cost
//! is proportional to active work, never to fleet size (the paper's
//! scale argument, applied to the control plane itself).

pub mod lease;
pub mod record;
pub mod statestore;

pub use lease::{partition_leases, Lease};
pub use record::ControlRecord;
pub use statestore::{
    FleetView, JobRecord, StateStore, StoreStatus, VmSpec,
};
