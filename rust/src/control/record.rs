//! The StateStore's durable record vocabulary and on-disk framing.
//!
//! Every control-plane mutation is one text record — a whitespace-
//! separated line, human-readable with `sqemu control status` or a hex
//! dump — wrapped in a checksummed, length-prefixed frame:
//!
//! ```text
//! [u32 payload len (LE)] [u32 FNV-1a-32 of payload (LE)] [payload]
//! ```
//!
//! Replay walks frames until the first invalid one (short, zero/insane
//! length, checksum mismatch, non-UTF-8): everything before it is the
//! durable prefix, everything after is a torn tail from a crashed
//! append and is overwritten by the next write. *Unknown* record tags
//! inside a valid frame are skipped, not fatal, so an older replica can
//! tail a log written by a newer one (forward compatibility).
//!
//! Names (files, nodes, VMs, holders) are single tokens: the fleet's
//! naming scheme (`vm-3`, `node-0`, `disk-7`) never contains
//! whitespace, and the codec encodes the empty string as `-`.

use crate::blockjob::JobKind;
use crate::qcow::image::DataMode;
use crate::vdisk::DriverKind;

/// Largest payload a frame may carry; anything bigger at replay time is
/// treated as a torn length word, not an allocation request.
pub const MAX_PAYLOAD: usize = 1 << 20;

/// One durable control-plane mutation. See the module docs for the
/// wire format; `encode`/`parse` are exact inverses for every variant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ControlRecord {
    /// Leader election: `leader` now owns write access under `epoch`.
    Epoch { epoch: u64, leader: String },
    /// The name→node placement index gained an entry.
    Place { file: String, node: String },
    /// The placement index dropped an entry.
    Unplace { file: String },
    /// A chain's full file list (base first, active last).
    Chain { id: String, files: Vec<String> },
    /// A chain left the registry (decommission).
    ChainDrop { id: String },
    /// A file entered the deferred-delete set.
    Condemn { file: String, bytes: u64, origin: String },
    /// A condemned file was resurrected by a new reference.
    Uncondemn { file: String },
    /// A condemned file was physically deleted by a sweep.
    Swept { file: String },
    /// A superseded migration replica was condemned on `node`.
    CondemnReplica { node: String, file: String, bytes: u64, origin: String },
    /// A condemned replica was physically deleted.
    SweptReplica { node: String, file: String },
    /// A VM definition: everything needed to re-adopt its chain.
    Vm {
        name: String,
        driver: DriverKind,
        slice_entries: u64,
        max_bytes: u64,
        data_mode: DataMode,
        active: String,
    },
    /// A VM was stopped cleanly and needs no re-adoption.
    VmStop { name: String },
    /// `holder` owns `vm` until the virtual clock passes `expires_ns`.
    Lease { vm: String, holder: String, expires_ns: u64 },
    /// The lease on `vm` was released.
    Unlease { vm: String },
    /// Job-id fence: ids up to and including `job-<last>` were issued.
    JobSeq { last: u64 },
    /// A block job started; `capacity` records a target-node byte
    /// reservation the job holds (released by orphan cleanup).
    Job { id: String, vm: String, kind: JobKind, capacity: Option<(String, u64)> },
    /// A block job reached a terminal state.
    JobEnd { id: String },
    /// A chain migration of `vm` toward `target` is in flight.
    Migration { vm: String, target: String },
    /// The migration of `vm` resolved (either way).
    MigrationEnd { vm: String },
    /// Clean-shutdown marker: when this is the log's last record, the
    /// whole fleet state is exactly what the log says (skip all scans).
    Shutdown,
    /// First record of a compacted generation.
    Snapshot,
}

fn tok(s: &str) -> &str {
    if s.is_empty() { "-" } else { s }
}

fn untok(s: &str) -> String {
    if s == "-" { String::new() } else { s.to_string() }
}

fn driver_parse(s: &str) -> Option<DriverKind> {
    match s {
        "vqemu" => Some(DriverKind::Vanilla),
        "sqemu" => Some(DriverKind::Scalable),
        _ => None,
    }
}

fn mode_name(m: DataMode) -> &'static str {
    match m {
        DataMode::Real => "real",
        DataMode::Synthetic => "synthetic",
    }
}

fn mode_parse(s: &str) -> Option<DataMode> {
    match s {
        "real" => Some(DataMode::Real),
        "synthetic" => Some(DataMode::Synthetic),
        _ => None,
    }
}

impl ControlRecord {
    /// Serialize to one whitespace-separated text line.
    pub fn encode(&self) -> String {
        use ControlRecord::*;
        match self {
            Epoch { epoch, leader } => {
                format!("epoch {epoch} {}", tok(leader))
            }
            Place { file, node } => format!("place {file} {node}"),
            Unplace { file } => format!("unplace {file}"),
            Chain { id, files } => {
                let mut s = format!("chain {id}");
                for f in files {
                    s.push(' ');
                    s.push_str(f);
                }
                s
            }
            ChainDrop { id } => format!("chaindrop {id}"),
            Condemn { file, bytes, origin } => {
                format!("condemn {file} {bytes} {}", tok(origin))
            }
            Uncondemn { file } => format!("uncondemn {file}"),
            Swept { file } => format!("swept {file}"),
            CondemnReplica { node, file, bytes, origin } => {
                format!("rcondemn {node} {file} {bytes} {}", tok(origin))
            }
            SweptReplica { node, file } => format!("rswept {node} {file}"),
            Vm { name, driver, slice_entries, max_bytes, data_mode, active } => {
                format!(
                    "vm {name} {} {slice_entries} {max_bytes} {} {active}",
                    driver.name(),
                    mode_name(*data_mode)
                )
            }
            VmStop { name } => format!("vmstop {name}"),
            Lease { vm, holder, expires_ns } => {
                format!("lease {vm} {} {expires_ns}", tok(holder))
            }
            Unlease { vm } => format!("unlease {vm}"),
            JobSeq { last } => format!("jobseq {last}"),
            Job { id, vm, kind, capacity } => match capacity {
                Some((node, bytes)) => {
                    format!("job {id} {vm} {} {node} {bytes}", kind.name())
                }
                None => format!("job {id} {vm} {}", kind.name()),
            },
            JobEnd { id } => format!("jobend {id}"),
            Migration { vm, target } => format!("mig {vm} {target}"),
            MigrationEnd { vm } => format!("migend {vm}"),
            Shutdown => "shutdown".to_string(),
            Snapshot => "snapshot".to_string(),
        }
    }

    /// Parse one line; `None` for unknown tags or malformed arity (the
    /// caller skips the record — see the module docs).
    pub fn parse(line: &str) -> Option<ControlRecord> {
        use ControlRecord::*;
        let mut it = line.split_ascii_whitespace();
        let rec = match it.next()? {
            "epoch" => Epoch {
                epoch: it.next()?.parse().ok()?,
                leader: untok(it.next()?),
            },
            "place" => Place {
                file: it.next()?.to_string(),
                node: it.next()?.to_string(),
            },
            "unplace" => Unplace { file: it.next()?.to_string() },
            "chain" => Chain {
                id: it.next()?.to_string(),
                files: it.map(str::to_string).collect(),
            },
            "chaindrop" => ChainDrop { id: it.next()?.to_string() },
            "condemn" => Condemn {
                file: it.next()?.to_string(),
                bytes: it.next()?.parse().ok()?,
                origin: untok(it.next()?),
            },
            "uncondemn" => Uncondemn { file: it.next()?.to_string() },
            "swept" => Swept { file: it.next()?.to_string() },
            "rcondemn" => CondemnReplica {
                node: it.next()?.to_string(),
                file: it.next()?.to_string(),
                bytes: it.next()?.parse().ok()?,
                origin: untok(it.next()?),
            },
            "rswept" => SweptReplica {
                node: it.next()?.to_string(),
                file: it.next()?.to_string(),
            },
            "vm" => Vm {
                name: it.next()?.to_string(),
                driver: driver_parse(it.next()?)?,
                slice_entries: it.next()?.parse().ok()?,
                max_bytes: it.next()?.parse().ok()?,
                data_mode: mode_parse(it.next()?)?,
                active: it.next()?.to_string(),
            },
            "vmstop" => VmStop { name: it.next()?.to_string() },
            "lease" => Lease {
                vm: it.next()?.to_string(),
                holder: untok(it.next()?),
                expires_ns: it.next()?.parse().ok()?,
            },
            "unlease" => Unlease { vm: it.next()?.to_string() },
            "jobseq" => JobSeq { last: it.next()?.parse().ok()? },
            "job" => {
                let id = it.next()?.to_string();
                let vm = it.next()?.to_string();
                let kind = JobKind::parse(it.next()?)?;
                let capacity = match it.next() {
                    Some(node) => {
                        Some((node.to_string(), it.next()?.parse().ok()?))
                    }
                    None => None,
                };
                Job { id, vm, kind, capacity }
            }
            "jobend" => JobEnd { id: it.next()?.to_string() },
            "mig" => Migration {
                vm: it.next()?.to_string(),
                target: it.next()?.to_string(),
            },
            "migend" => MigrationEnd { vm: it.next()?.to_string() },
            "shutdown" => Shutdown,
            "snapshot" => Snapshot,
            _ => return None,
        };
        Some(rec)
    }
}

/// FNV-1a over `data`, 32-bit — the same family the coordinator's shard
/// router uses; cheap and good enough to reject torn frames (the threat
/// model is a truncated write, not an adversary).
pub fn fnv1a32(data: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in data {
        h ^= u32::from(b);
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Wrap a payload line in its length + checksum frame.
pub fn frame(payload: &str) -> Vec<u8> {
    let bytes = payload.as_bytes();
    let mut out = Vec::with_capacity(8 + bytes.len());
    out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(&fnv1a32(bytes).to_le_bytes());
    out.extend_from_slice(bytes);
    out
}

/// Decode the frame starting at `buf[off..]`. `None` means "the valid
/// prefix ends here": too short, zero or oversized length, checksum
/// mismatch, or a non-UTF-8 payload.
pub fn decode_frame(buf: &[u8], off: usize) -> Option<(&str, usize)> {
    let rest = buf.get(off..)?;
    let len_bytes: [u8; 4] = rest.get(0..4)?.try_into().ok()?;
    let len = u32::from_le_bytes(len_bytes) as usize;
    if len == 0 || len > MAX_PAYLOAD {
        return None;
    }
    let want_bytes: [u8; 4] = rest.get(4..8)?.try_into().ok()?;
    let want = u32::from_le_bytes(want_bytes);
    let payload = rest.get(8..8 + len)?;
    if fnv1a32(payload) != want {
        return None;
    }
    let text = std::str::from_utf8(payload).ok()?;
    Some((text, off + 8 + len))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_variants() -> Vec<ControlRecord> {
        use ControlRecord::*;
        vec![
            Epoch { epoch: 7, leader: "coord-a".into() },
            Epoch { epoch: 0, leader: String::new() },
            Place { file: "disk-0".into(), node: "node-1".into() },
            Unplace { file: "disk-0".into() },
            Chain {
                id: "vm-0".into(),
                files: vec!["base".into(), "top".into()],
            },
            Chain { id: "vm-1".into(), files: vec![] },
            ChainDrop { id: "vm-0".into() },
            Condemn { file: "old".into(), bytes: 4096, origin: "vm-0".into() },
            Uncondemn { file: "old".into() },
            Swept { file: "old".into() },
            CondemnReplica {
                node: "node-0".into(),
                file: "img".into(),
                bytes: 123,
                origin: "vm-2".into(),
            },
            SweptReplica { node: "node-0".into(), file: "img".into() },
            Vm {
                name: "vm-0".into(),
                driver: crate::vdisk::DriverKind::Scalable,
                slice_entries: 512,
                max_bytes: 1 << 20,
                data_mode: crate::qcow::image::DataMode::Real,
                active: "vm-0-s2".into(),
            },
            VmStop { name: "vm-0".into() },
            Lease { vm: "vm-0".into(), holder: "coord-a".into(), expires_ns: 99 },
            Unlease { vm: "vm-0".into() },
            JobSeq { last: 41 },
            Job {
                id: "job-3".into(),
                vm: "vm-0".into(),
                kind: crate::blockjob::JobKind::Mirror,
                capacity: Some(("node-1".into(), 1 << 30)),
            },
            Job {
                id: "job-4".into(),
                vm: "vm-1".into(),
                kind: crate::blockjob::JobKind::Stream,
                capacity: None,
            },
            JobEnd { id: "job-3".into() },
            Migration { vm: "vm-0".into(), target: "node-1".into() },
            MigrationEnd { vm: "vm-0".into() },
            Shutdown,
            Snapshot,
        ]
    }

    #[test]
    fn every_variant_round_trips() {
        for rec in all_variants() {
            let line = rec.encode();
            let back = ControlRecord::parse(&line)
                .unwrap_or_else(|| panic!("unparsable: {line}"));
            assert_eq!(back, rec, "{line}");
        }
    }

    #[test]
    fn unknown_and_malformed_lines_are_skipped_not_fatal() {
        assert_eq!(ControlRecord::parse("futurerec a b c"), None);
        assert_eq!(ControlRecord::parse(""), None);
        assert_eq!(ControlRecord::parse("epoch notanumber x"), None);
        assert_eq!(ControlRecord::parse("place onlyonetoken"), None);
        assert_eq!(ControlRecord::parse("vm v badkind 1 2 real a"), None);
    }

    #[test]
    fn frames_survive_and_reject() {
        let a = frame("epoch 1 me");
        let b = frame("place f n0");
        let mut buf = [a.clone(), b.clone()].concat();
        let (t1, off1) = decode_frame(&buf, 0).unwrap();
        assert_eq!(t1, "epoch 1 me");
        let (t2, off2) = decode_frame(&buf, off1).unwrap();
        assert_eq!(t2, "place f n0");
        assert_eq!(off2, buf.len());
        assert!(decode_frame(&buf, off2).is_none(), "clean end of log");
        // flip one payload byte: checksum rejects the frame
        buf[a.len() + 9] ^= 0xff;
        assert!(decode_frame(&buf, a.len()).is_none());
        // torn tail: drop the last byte of an otherwise valid frame
        assert!(decode_frame(&a[..a.len() - 1], 0).is_none());
        // zero-length frames terminate replay (zeroed preallocation)
        assert!(decode_frame(&[0u8; 16], 0).is_none());
    }
}
