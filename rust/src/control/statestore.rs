//! The write-ahead StateStore: the control plane's durable memory and
//! its multi-coordinator arbiter.
//!
//! One [`StateStore`] lives on a dedicated metadata
//! [`StorageNode`] (the etcd of this fleet — *not* a data node, so
//! data-path scans and capacity math never see control files). It keeps
//! two files:
//!
//! * `.ctl.log.<gen>` — the append log of [`ControlRecord`] frames for
//!   generation `gen`. Generation 0 starts empty; every later
//!   generation starts with a `snapshot` marker followed by a full
//!   re-emission of the fleet state (compaction).
//! * `.ctl.gen` — a single-frame pointer naming the current
//!   generation, overwritten in place only after the next generation
//!   is durable (the atomic compaction flip).
//!
//! Crash safety is the WAL classic: every append is `write_at` +
//! `flush` of one checksummed frame; replay stops at the first invalid
//! frame and later appends overwrite the torn tail. A failed append
//! *wedges* the store — the in-memory view no longer trusts the disk
//! suffix — until [`StateStore::reopen`] re-replays the durable
//! prefix.
//!
//! Epoch fencing: `campaign()` bumps the epoch and records the new
//! leader; every fenced mutation carries the epoch its caller holds
//! and is rejected when a later campaign has run. A deposed leader's
//! control operations therefore fail at the persist gate, before they
//! touch the fleet ([`FleetView`] is only advanced by records that
//! landed). Data-plane bookkeeping (placement/GC observers) appends
//! unfenced: those records describe mutations that already happened
//! on shared storage, and compaction heals any drift.

use super::lease::Lease;
use super::record::{self, ControlRecord};
use crate::blockjob::JobKind;
use crate::cache::CacheConfig;
use crate::qcow::image::DataMode;
use crate::storage::backend::BackendRef;
use crate::storage::node::StorageNode;
use crate::util::lock_unpoisoned;
use crate::vdisk::DriverKind;
use anyhow::{bail, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

/// Pointer file naming the current log generation.
pub const GEN_FILE: &str = ".ctl.gen";
/// Prefix of generation log files.
pub const LOG_PREFIX: &str = ".ctl.log.";
/// Appends between automatic compactions (tunable per store).
pub const DEFAULT_COMPACT_EVERY: u64 = 512;

fn log_name(gen: u64) -> String {
    format!("{LOG_PREFIX}{gen}")
}

/// Everything the coordinator needs to re-adopt a VM's chain.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VmSpec {
    pub driver: DriverKind,
    pub cache: CacheConfig,
    pub data_mode: DataMode,
    /// Active-volume name (the chain head to reopen).
    pub active: String,
}

/// A block job the log believes is (or was) running.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JobRecord {
    pub vm: String,
    pub kind: JobKind,
    /// A `(node, bytes)` capacity reservation the job holds; orphan
    /// cleanup releases it when the owner dies.
    pub capacity: Option<(String, u64)>,
}

/// The control-plane state a log replay reconstructs: what recovery
/// installs instead of scanning every node.
#[derive(Clone, Debug, Default)]
pub struct FleetView {
    pub epoch: u64,
    pub leader: String,
    /// file name → node name (the placement index).
    pub placement: HashMap<String, String>,
    /// chain id → file list, base first, active last.
    pub chains: HashMap<String, Vec<String>>,
    /// deferred-delete set: file → (bytes, origin).
    pub condemned: BTreeMap<String, (u64, String)>,
    /// condemned migration replicas: (node, file) → (bytes, origin).
    pub replicas: BTreeMap<(String, String), (u64, String)>,
    pub vms: HashMap<String, VmSpec>,
    pub leases: HashMap<String, Lease>,
    pub jobs: BTreeMap<String, JobRecord>,
    /// vm → target node of an in-flight migration.
    pub migrations: HashMap<String, String>,
    /// Highest job number issued (`job-<n>`); seeds the id counter so a
    /// new leader never reuses a dead leader's job ids.
    pub max_job_seq: u64,
    /// The log's last record is the clean-shutdown marker.
    pub clean_shutdown: bool,
    /// Valid records applied; 0 means a virgin store (recovery must
    /// not trust an empty view over a populated fleet).
    pub records: u64,
    /// A generation > 0 log did not begin with its snapshot: the state
    /// is torn beyond the last valid snapshot and only a full scan can
    /// rebuild it.
    pub torn: bool,
}

impl FleetView {
    /// Fold one record into the view.
    pub fn apply(&mut self, rec: &ControlRecord) {
        use ControlRecord::*;
        self.records += 1;
        self.clean_shutdown = matches!(rec, Shutdown);
        match rec {
            Epoch { epoch, leader } => {
                self.epoch = *epoch;
                self.leader = leader.clone();
            }
            Place { file, node } => {
                self.placement.insert(file.clone(), node.clone());
            }
            Unplace { file } => {
                self.placement.remove(file);
            }
            Chain { id, files } => {
                self.chains.insert(id.clone(), files.clone());
            }
            ChainDrop { id } => {
                self.chains.remove(id);
            }
            Condemn { file, bytes, origin } => {
                self.condemned
                    .insert(file.clone(), (*bytes, origin.clone()));
            }
            Uncondemn { file } | Swept { file } => {
                self.condemned.remove(file);
            }
            CondemnReplica { node, file, bytes, origin } => {
                self.replicas.insert(
                    (node.clone(), file.clone()),
                    (*bytes, origin.clone()),
                );
            }
            SweptReplica { node, file } => {
                self.replicas.remove(&(node.clone(), file.clone()));
            }
            Vm { name, driver, slice_entries, max_bytes, data_mode, active } => {
                self.vms.insert(
                    name.clone(),
                    VmSpec {
                        driver: *driver,
                        cache: CacheConfig {
                            slice_entries: *slice_entries,
                            max_bytes: *max_bytes,
                        },
                        data_mode: *data_mode,
                        active: active.clone(),
                    },
                );
            }
            VmStop { name } => {
                self.vms.remove(name);
            }
            Lease { vm, holder, expires_ns } => {
                self.leases.insert(
                    vm.clone(),
                    super::lease::Lease {
                        holder: holder.clone(),
                        expires_ns: *expires_ns,
                    },
                );
            }
            Unlease { vm } => {
                self.leases.remove(vm);
            }
            JobSeq { last } => {
                self.max_job_seq = self.max_job_seq.max(*last);
            }
            Job { id, vm, kind, capacity } => {
                if let Some(n) = id
                    .strip_prefix("job-")
                    .and_then(|s| s.parse::<u64>().ok())
                {
                    self.max_job_seq = self.max_job_seq.max(n);
                }
                self.jobs.insert(
                    id.clone(),
                    JobRecord {
                        vm: vm.clone(),
                        kind: *kind,
                        capacity: capacity.clone(),
                    },
                );
            }
            JobEnd { id } => {
                self.jobs.remove(id);
            }
            Migration { vm, target } => {
                self.migrations.insert(vm.clone(), target.clone());
            }
            MigrationEnd { vm } => {
                self.migrations.remove(vm);
            }
            Shutdown | Snapshot => {}
        }
    }

    /// Re-emit the whole view as the record sequence of a compacted
    /// generation, deterministic order (snapshot marker first).
    pub fn snapshot_records(&self) -> Vec<ControlRecord> {
        use ControlRecord::*;
        let mut out = vec![
            Snapshot,
            Epoch { epoch: self.epoch, leader: self.leader.clone() },
        ];
        let mut placed: Vec<_> = self.placement.iter().collect();
        placed.sort();
        for (file, node) in placed {
            out.push(Place { file: file.clone(), node: node.clone() });
        }
        let mut chains: Vec<_> = self.chains.iter().collect();
        chains.sort();
        for (id, files) in chains {
            out.push(Chain { id: id.clone(), files: files.clone() });
        }
        for (file, (bytes, origin)) in &self.condemned {
            out.push(Condemn {
                file: file.clone(),
                bytes: *bytes,
                origin: origin.clone(),
            });
        }
        for ((node, file), (bytes, origin)) in &self.replicas {
            out.push(CondemnReplica {
                node: node.clone(),
                file: file.clone(),
                bytes: *bytes,
                origin: origin.clone(),
            });
        }
        let mut vms: Vec<_> = self.vms.iter().collect();
        vms.sort_by(|a, b| a.0.cmp(b.0));
        for (name, spec) in vms {
            out.push(Vm {
                name: name.clone(),
                driver: spec.driver,
                slice_entries: spec.cache.slice_entries,
                max_bytes: spec.cache.max_bytes,
                data_mode: spec.data_mode,
                active: spec.active.clone(),
            });
        }
        let mut leases: Vec<_> = self.leases.iter().collect();
        leases.sort_by(|a, b| a.0.cmp(b.0));
        for (vm, lease) in leases {
            out.push(Lease {
                vm: vm.clone(),
                holder: lease.holder.clone(),
                expires_ns: lease.expires_ns,
            });
        }
        for (id, job) in &self.jobs {
            out.push(Job {
                id: id.clone(),
                vm: job.vm.clone(),
                kind: job.kind,
                capacity: job.capacity.clone(),
            });
        }
        let mut migs: Vec<_> = self.migrations.iter().collect();
        migs.sort();
        for (vm, target) in migs {
            out.push(Migration { vm: vm.clone(), target: target.clone() });
        }
        out.push(JobSeq { last: self.max_job_seq });
        if self.clean_shutdown {
            out.push(Shutdown);
        }
        out
    }
}

/// Health/identity summary for `sqemu control status` and the
/// `sqemu_control_*` telemetry families. The operation counters
/// (`appends`, `compactions`, `lease_renewals`) count since this store
/// handle last replayed the log — a `reopen()` (standby tailing,
/// takeover) restarts them.
#[derive(Clone, Debug)]
pub struct StoreStatus {
    pub generation: u64,
    pub log_bytes: u64,
    pub records: u64,
    pub epoch: u64,
    pub leader: String,
    pub vms: usize,
    pub leases: usize,
    pub jobs: usize,
    pub migrations: usize,
    pub wedged: bool,
    pub clean_shutdown: bool,
    /// Records appended through this handle.
    pub appends: u64,
    /// Compactions completed through this handle.
    pub compactions: u64,
    /// Lease renewals granted through this handle.
    pub lease_renewals: u64,
}

struct Inner {
    gen: u64,
    log: BackendRef,
    ptr: BackendRef,
    /// End of the valid frame prefix; appends land here, overwriting
    /// any torn tail a crashed append left behind.
    len: u64,
    since_snapshot: u64,
    appends: u64,
    /// Compactions completed (telemetry).
    compactions: u64,
    /// Lease renewals granted (telemetry).
    lease_renewals: u64,
    /// A durable write failed: the disk suffix is untrusted until
    /// `reopen()` re-replays it.
    wedged: bool,
    view: FleetView,
}

/// See the module docs. Shared by every coordinator instance of a
/// fleet: `Arc<StateStore>` is the one arbiter of epochs and leases.
pub struct StateStore {
    node: Arc<StorageNode>,
    compact_every: AtomicU64,
    inner: Mutex<Inner>,
}

impl StateStore {
    /// Open (or initialize) the store on its dedicated metadata node.
    pub fn open(node: Arc<StorageNode>) -> Result<Arc<StateStore>> {
        let inner = Self::load(&node)?;
        Ok(Arc::new(StateStore {
            node,
            compact_every: AtomicU64::new(DEFAULT_COMPACT_EVERY),
            inner: Mutex::new(inner),
        }))
    }

    /// Re-replay the durable prefix from disk, clearing a wedge. The
    /// standby's log-tailing primitive and the first step of takeover.
    pub fn reopen(&self) -> Result<()> {
        let fresh = Self::load(&self.node)?;
        *lock_unpoisoned(&self.inner) = fresh;
        Ok(())
    }

    fn load(node: &Arc<StorageNode>) -> Result<Inner> {
        let ptr = match node.open_file(GEN_FILE) {
            Ok(b) => b,
            Err(_) => {
                // virgin store (or the metadata node is down, which the
                // create below surfaces)
                let ptr = node.create_file(GEN_FILE)?;
                ptr.write_at(&record::frame("gen 0"), 0)?;
                ptr.flush()?;
                ptr
            }
        };
        let gen = match Self::read_pointer(&ptr) {
            Some(g) => g,
            // torn pointer: fall back to the newest log on disk (the
            // flip is written only after that log is durable)
            None => Self::highest_gen(node).unwrap_or(0),
        };
        let log = match node.open_file(&log_name(gen)) {
            Ok(b) => b,
            Err(_) => node.create_file(&log_name(gen))?,
        };
        let mut buf = vec![0u8; log.len() as usize];
        log.read_at(&mut buf, 0)?;
        let (view, len) = Self::replay(&buf, gen);
        // sweep generations a crash mid-compaction left behind
        for name in node.file_names() {
            if let Some(g) = name
                .strip_prefix(LOG_PREFIX)
                .and_then(|s| s.parse::<u64>().ok())
            {
                if g != gen {
                    let _ = node.delete_file(&name);
                }
            }
        }
        Ok(Inner {
            gen,
            log,
            ptr,
            len,
            since_snapshot: 0,
            appends: 0,
            compactions: 0,
            lease_renewals: 0,
            wedged: false,
            view,
        })
    }

    fn read_pointer(ptr: &BackendRef) -> Option<u64> {
        let mut buf = vec![0u8; (ptr.len() as usize).min(64)];
        ptr.read_at(&mut buf, 0).ok()?;
        let (line, _) = record::decode_frame(&buf, 0)?;
        line.strip_prefix("gen ")?.parse().ok()
    }

    fn highest_gen(node: &Arc<StorageNode>) -> Option<u64> {
        node.file_names()
            .iter()
            .filter_map(|n| n.strip_prefix(LOG_PREFIX))
            .filter_map(|s| s.parse::<u64>().ok())
            .max()
    }

    fn replay(buf: &[u8], gen: u64) -> (FleetView, u64) {
        let mut view = FleetView::default();
        let mut off = 0usize;
        let mut first = true;
        while let Some((line, next)) = record::decode_frame(buf, off) {
            if let Some(rec) = ControlRecord::parse(line) {
                if first && gen > 0 && rec != ControlRecord::Snapshot {
                    view.torn = true;
                }
                view.apply(&rec);
            }
            first = false;
            off = next;
        }
        if gen > 0 && first {
            view.torn = true; // the compacted snapshot itself is gone
        }
        (view, off as u64)
    }

    fn append_locked(inner: &mut Inner, rec: &ControlRecord) -> Result<()> {
        if inner.wedged {
            bail!("state store wedged by a failed append; reopen() first");
        }
        let frame = record::frame(&rec.encode());
        // the record reaches the disk (write + flush) before any
        // in-memory state changes: a crash between the two replays the
        // record, never invents unlogged state
        // lint: durable-before(view-apply)
        let wrote = inner
            .log
            .write_at(&frame, inner.len)
            .and_then(|()| inner.log.flush());
        if let Err(e) = wrote {
            inner.wedged = true;
            return Err(e);
        }
        inner.len += frame.len() as u64;
        inner.appends += 1;
        inner.since_snapshot += 1;
        // lint: mutates(view-apply)
        inner.view.apply(rec);
        Ok(())
    }

    fn maybe_compact_locked(&self, inner: &mut Inner) {
        if inner.since_snapshot >= self.compact_every.load(Relaxed) {
            // failure wedges the store; appends keep failing until a
            // reopen, which lands back on whichever generation's flip
            // became durable
            let _ = self.compact_locked(inner);
        }
    }

    fn compact_locked(&self, inner: &mut Inner) -> Result<()> {
        if inner.wedged {
            bail!("state store wedged; reopen() before compacting");
        }
        let old_gen = inner.gen;
        let new_gen = old_gen + 1;
        let result = (|| -> Result<(BackendRef, u64)> {
            let name = log_name(new_gen);
            let _ = self.node.delete_file(&name); // stale leftover
            let log = self.node.create_file(&name)?;
            let mut buf = Vec::new();
            for rec in inner.view.snapshot_records() {
                buf.extend_from_slice(&record::frame(&rec.encode()));
            }
            log.write_at(&buf, 0)?;
            log.flush()?;
            // the atomic flip: a crash before this flush replays the
            // old generation, after it the new one
            // lint: index-flip(generation)
            inner.ptr.write_at(&record::frame(&format!("gen {new_gen}")), 0)?;
            inner.ptr.flush()?;
            let _ = self.node.delete_file(&log_name(old_gen));
            Ok((log, buf.len() as u64))
        })();
        match result {
            Ok((log, len)) => {
                inner.gen = new_gen;
                inner.log = log;
                inner.len = len;
                inner.since_snapshot = 0;
                inner.compactions += 1;
                // the fresh generation replays these records
                inner.view.records = inner.view.snapshot_records().len() as u64;
                Ok(())
            }
            Err(e) => {
                inner.wedged = true;
                Err(e)
            }
        }
    }

    /// Compact now (normally automatic every [`DEFAULT_COMPACT_EVERY`]
    /// appends; see [`StateStore::set_compact_every`]).
    pub fn compact(&self) -> Result<()> {
        self.compact_locked(&mut lock_unpoisoned(&self.inner))
    }

    pub fn set_compact_every(&self, every: u64) {
        self.compact_every.store(every.max(1), Relaxed);
    }

    /// Bump the epoch and take leadership. Always permitted (elections
    /// are how the fence moves); returns the new epoch, which fences
    /// every previous leader's fenced appends.
    pub fn campaign(&self, who: &str) -> Result<u64> {
        let mut inner = lock_unpoisoned(&self.inner);
        let epoch = inner.view.epoch + 1;
        Self::append_locked(
            &mut inner,
            &ControlRecord::Epoch { epoch, leader: who.to_string() },
        )?;
        Ok(epoch)
    }

    /// Fenced append: rejected unless `epoch` is the current one.
    pub fn append(&self, epoch: u64, rec: &ControlRecord) -> Result<()> {
        let mut inner = lock_unpoisoned(&self.inner);
        Self::check_fence(&inner, epoch)?;
        Self::append_locked(&mut inner, rec)?;
        self.maybe_compact_locked(&mut inner);
        Ok(())
    }

    /// Unfenced append, for data-plane bookkeeping observers (the
    /// record describes a mutation that already happened on shared
    /// storage; see the module docs).
    pub fn append_unfenced(&self, rec: &ControlRecord) -> Result<()> {
        let mut inner = lock_unpoisoned(&self.inner);
        Self::append_locked(&mut inner, rec)?;
        self.maybe_compact_locked(&mut inner);
        Ok(())
    }

    fn check_fence(inner: &Inner, epoch: u64) -> Result<()> {
        if epoch != inner.view.epoch {
            bail!(
                "epoch fence: write under epoch {epoch} rejected, current \
                 epoch is {} (leader '{}')",
                inner.view.epoch,
                inner.view.leader
            );
        }
        Ok(())
    }

    /// Acquire `vm`'s lease for `holder`: succeeds when the VM is
    /// unleased, already `holder`'s, or the previous lease expired.
    /// Returns the expiry instant.
    pub fn acquire_lease(
        &self,
        epoch: u64,
        vm: &str,
        holder: &str,
        ttl_ns: u64,
    ) -> Result<u64> {
        let mut inner = lock_unpoisoned(&self.inner);
        Self::check_fence(&inner, epoch)?;
        let now = self.node.clock().now();
        if let Some(l) = inner.view.leases.get(vm) {
            if l.holder != holder && !l.expired(now) {
                bail!(
                    "vm '{vm}' is leased to '{}' for another {} ns",
                    l.holder,
                    l.expires_ns - now
                );
            }
        }
        let expires_ns = now.saturating_add(ttl_ns);
        Self::append_locked(
            &mut inner,
            &ControlRecord::Lease {
                vm: vm.to_string(),
                holder: holder.to_string(),
                expires_ns,
            },
        )?;
        self.maybe_compact_locked(&mut inner);
        Ok(expires_ns)
    }

    /// Renew a lease `holder` still owns (permitted even past expiry,
    /// as long as nobody else claimed it in between).
    pub fn renew_lease(
        &self,
        epoch: u64,
        vm: &str,
        holder: &str,
        ttl_ns: u64,
    ) -> Result<u64> {
        let mut inner = lock_unpoisoned(&self.inner);
        Self::check_fence(&inner, epoch)?;
        match inner.view.leases.get(vm) {
            Some(l) if l.holder == holder => {}
            Some(l) => bail!(
                "vm '{vm}' lease now belongs to '{}', not '{holder}'",
                l.holder
            ),
            None => bail!("vm '{vm}' holds no lease to renew"),
        }
        let expires_ns = self.node.clock().now().saturating_add(ttl_ns);
        Self::append_locked(
            &mut inner,
            &ControlRecord::Lease {
                vm: vm.to_string(),
                holder: holder.to_string(),
                expires_ns,
            },
        )?;
        inner.lease_renewals += 1;
        self.maybe_compact_locked(&mut inner);
        Ok(expires_ns)
    }

    /// Release `vm`'s lease. A no-op when no lease exists; rejected
    /// when a *different* holder owns an unexpired lease.
    pub fn release_lease(
        &self,
        epoch: u64,
        vm: &str,
        holder: &str,
    ) -> Result<()> {
        let mut inner = lock_unpoisoned(&self.inner);
        Self::check_fence(&inner, epoch)?;
        let now = self.node.clock().now();
        match inner.view.leases.get(vm) {
            None => return Ok(()),
            Some(l) if l.holder != holder && !l.expired(now) => bail!(
                "vm '{vm}' lease belongs to '{}', not '{holder}'",
                l.holder
            ),
            Some(_) => {}
        }
        Self::append_locked(
            &mut inner,
            &ControlRecord::Unlease { vm: vm.to_string() },
        )?;
        self.maybe_compact_locked(&mut inner);
        Ok(())
    }

    pub fn lease_of(&self, vm: &str) -> Option<Lease> {
        lock_unpoisoned(&self.inner).view.leases.get(vm).cloned()
    }

    pub fn current_epoch(&self) -> u64 {
        lock_unpoisoned(&self.inner).view.epoch
    }

    pub fn leader(&self) -> String {
        lock_unpoisoned(&self.inner).view.leader.clone()
    }

    pub fn is_wedged(&self) -> bool {
        lock_unpoisoned(&self.inner).wedged
    }

    /// Clone the replayed fleet state (recovery's input).
    pub fn view(&self) -> FleetView {
        lock_unpoisoned(&self.inner).view.clone()
    }

    pub fn node(&self) -> &Arc<StorageNode> {
        &self.node
    }

    /// Replace the derived state (placement, chains, jobs, migrations,
    /// condemnations) with what a full fleet scan found, then compact —
    /// the self-heal after a torn-beyond-snapshot log. Leases, VM specs
    /// and the epoch from the valid prefix are preserved.
    pub fn reseed(
        &self,
        placement: Vec<(String, String)>,
        chains: Vec<(String, Vec<String>)>,
        last_job_id: u64,
    ) -> Result<()> {
        let mut inner = lock_unpoisoned(&self.inner);
        let v = &mut inner.view;
        v.placement = placement.into_iter().collect();
        v.chains = chains.into_iter().collect();
        v.condemned.clear();
        v.replicas.clear();
        v.jobs.clear();
        v.migrations.clear();
        v.max_job_seq = v.max_job_seq.max(last_job_id);
        v.torn = false;
        self.compact_locked(&mut inner)
    }

    pub fn status(&self) -> StoreStatus {
        let inner = lock_unpoisoned(&self.inner);
        StoreStatus {
            generation: inner.gen,
            log_bytes: inner.len,
            records: inner.view.records,
            epoch: inner.view.epoch,
            leader: inner.view.leader.clone(),
            vms: inner.view.vms.len(),
            leases: inner.view.leases.len(),
            jobs: inner.view.jobs.len(),
            migrations: inner.view.migrations.len(),
            wedged: inner.wedged,
            clean_shutdown: inner.view.clean_shutdown,
            appends: inner.appends,
            compactions: inner.compactions,
            lease_renewals: inner.lease_renewals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::storage::fault::FaultInjector;

    fn meta_node() -> Arc<StorageNode> {
        StorageNode::new("meta", VirtClock::new(), CostModel::default())
    }

    fn place(file: &str, node: &str) -> ControlRecord {
        ControlRecord::Place { file: file.into(), node: node.into() }
    }

    #[test]
    fn fresh_store_persists_and_replays() {
        let node = meta_node();
        let store = StateStore::open(Arc::clone(&node)).unwrap();
        let epoch = store.campaign("coord-a").unwrap();
        assert_eq!(epoch, 1);
        store.append(epoch, &place("disk-0", "node-0")).unwrap();
        store.append(epoch, &place("disk-1", "node-1")).unwrap();
        store
            .acquire_lease(epoch, "vm-0", "coord-a", 1_000_000)
            .unwrap();
        drop(store);
        let store = StateStore::open(node).unwrap();
        let v = store.view();
        assert_eq!(v.epoch, 1);
        assert_eq!(v.leader, "coord-a");
        assert_eq!(v.placement.get("disk-0").unwrap(), "node-0");
        assert_eq!(v.placement.get("disk-1").unwrap(), "node-1");
        assert_eq!(v.leases.get("vm-0").unwrap().holder, "coord-a");
        assert!(!v.torn);
        assert!(!v.clean_shutdown);
    }

    #[test]
    fn torn_tail_is_dropped_and_overwritten() {
        let node = meta_node();
        let store = StateStore::open(Arc::clone(&node)).unwrap();
        let e = store.campaign("a").unwrap();
        store.append(e, &place("f0", "n0")).unwrap();
        store.append(e, &place("f1", "n0")).unwrap();
        let valid = store.status().log_bytes;
        // simulate a crashed append: half a frame straight to the log
        let log = node.open_file(&log_name(0)).unwrap();
        let torn = record::frame("place f2 n0");
        log.write_at(&torn[..torn.len() - 3], valid).unwrap();
        store.reopen().unwrap();
        let v = store.view();
        assert_eq!(v.placement.len(), 2, "torn record not replayed");
        assert!(v.placement.contains_key("f1"));
        // the next append overwrites the torn tail and replays cleanly
        store.append(e, &place("f3", "n1")).unwrap();
        store.reopen().unwrap();
        assert_eq!(store.view().placement.len(), 3);
        assert_eq!(store.view().placement.get("f3").unwrap(), "n1");
    }

    #[test]
    fn epoch_fencing_rejects_the_deposed_leader() {
        let store = StateStore::open(meta_node()).unwrap();
        let e1 = store.campaign("a").unwrap();
        store.append(e1, &place("f", "n0")).unwrap();
        let e2 = store.campaign("b").unwrap();
        assert!(e2 > e1);
        assert_eq!(store.leader(), "b");
        let err = store.append(e1, &place("g", "n0")).unwrap_err();
        assert!(format!("{err:#}").contains("epoch fence"), "{err:#}");
        assert!(store.acquire_lease(e1, "vm", "a", 10).is_err());
        store.append(e2, &place("g", "n0")).unwrap();
    }

    #[test]
    fn lease_single_holder_until_expiry() {
        let node = meta_node();
        let clock = Arc::clone(node.clock());
        let store = StateStore::open(node).unwrap();
        let e = store.campaign("arb").unwrap();
        store.acquire_lease(e, "vm-0", "a", 1_000).unwrap();
        assert!(
            store.acquire_lease(e, "vm-0", "b", 1_000).is_err(),
            "unexpired lease is exclusive"
        );
        store.acquire_lease(e, "vm-0", "a", 1_000).unwrap();
        store.renew_lease(e, "vm-0", "a", 2_000).unwrap();
        clock.advance(10_000);
        store.acquire_lease(e, "vm-0", "b", 1_000).unwrap();
        let err = store.renew_lease(e, "vm-0", "a", 1_000).unwrap_err();
        assert!(format!("{err:#}").contains("belongs to"), "{err:#}");
        assert_eq!(store.lease_of("vm-0").unwrap().holder, "b");
        // release: wrong holder rejected while unexpired, owner allowed
        assert!(store.release_lease(e, "vm-0", "a").is_err());
        store.release_lease(e, "vm-0", "b").unwrap();
        assert!(store.lease_of("vm-0").is_none());
        store.release_lease(e, "vm-0", "a").unwrap();
    }

    #[test]
    fn compaction_flips_generations_and_preserves_state() {
        let node = meta_node();
        let store = StateStore::open(Arc::clone(&node)).unwrap();
        store.set_compact_every(8);
        let e = store.campaign("a").unwrap();
        for i in 0..20 {
            store.append(e, &place(&format!("f{i}"), "n0")).unwrap();
        }
        let st = store.status();
        assert!(st.generation >= 1, "auto-compaction ran: {st:?}");
        // exactly one log generation (+ pointer) remains on disk
        let names = node.file_names();
        let logs: Vec<_> = names
            .iter()
            .filter(|n| n.starts_with(LOG_PREFIX))
            .collect();
        assert_eq!(logs.len(), 1, "{names:?}");
        store.reopen().unwrap();
        let v = store.view();
        assert_eq!(v.placement.len(), 20);
        assert_eq!(v.epoch, e);
        assert!(!v.torn);
    }

    #[test]
    fn clean_shutdown_marker_is_last_record_only() {
        let store = StateStore::open(meta_node()).unwrap();
        let e = store.campaign("a").unwrap();
        store.append(e, &ControlRecord::Shutdown).unwrap();
        store.reopen().unwrap();
        assert!(store.view().clean_shutdown);
        store.append(e, &place("f", "n0")).unwrap();
        store.reopen().unwrap();
        assert!(!store.view().clean_shutdown, "any later record dirties");
    }

    #[test]
    fn wedged_store_refuses_writes_until_reopen() {
        let clock = VirtClock::new();
        let injector = FaultInjector::new();
        let node = StorageNode::with_fault_injection(
            "meta",
            clock,
            CostModel::default(),
            u64::MAX,
            Arc::clone(&injector),
        );
        let store = StateStore::open(Arc::clone(&node)).unwrap();
        let e = store.campaign("a").unwrap();
        store.append(e, &place("f0", "n0")).unwrap();
        injector.arm(0, None);
        assert!(store.append(e, &place("f1", "n0")).is_err());
        injector.revive();
        let err = store.append(e, &place("f2", "n0")).unwrap_err();
        assert!(format!("{err:#}").contains("wedged"), "{err:#}");
        store.reopen().unwrap();
        store.append(e, &place("f2", "n0")).unwrap();
        let v = store.view();
        assert!(v.placement.contains_key("f0"));
        assert!(v.placement.contains_key("f2"));
    }

    #[test]
    fn power_cut_at_every_append_leaves_a_replayable_prefix() {
        // probe run: count the durable events of the scripted history
        let script = |store: &StateStore| {
            let e = match store.campaign("a") {
                Ok(e) => e,
                Err(_) => return,
            };
            for i in 0..6 {
                let _ = store.append(e, &place(&format!("f{i}"), "n0"));
            }
            let _ = store.acquire_lease(e, "vm-0", "a", 1_000_000);
            let _ = store.append(e, &ControlRecord::Shutdown);
        };
        let run = |cut: Option<u64>| -> (u64, FleetView) {
            let injector = FaultInjector::new();
            let node = StorageNode::with_fault_injection(
                "meta",
                VirtClock::new(),
                CostModel::default(),
                u64::MAX,
                Arc::clone(&injector),
            );
            let store = StateStore::open(Arc::clone(&node)).unwrap();
            store.set_compact_every(4); // exercise compaction flips too
            if let Some(k) = cut {
                injector.arm(k, Some(crate::storage::fault::SECTOR));
            }
            script(&store);
            injector.revive();
            store.reopen().unwrap();
            (injector.events(), store.view())
        };
        let (events, full) = run(None);
        assert!(full.clean_shutdown && full.placement.len() == 6);
        for k in 0..events {
            let (_, v) = run(Some(k));
            assert!(!v.torn, "cut at {k}: prefix must replay, not tear");
            assert!(v.records <= full.records, "cut at {k}");
            assert!(v.epoch <= 1, "cut at {k}");
            assert!(v.placement.len() <= 6, "cut at {k}");
            // a replayed placement entry is always one the script wrote
            for (f, n) in &v.placement {
                assert!(f.starts_with('f') && n == "n0", "cut at {k}");
            }
        }
    }

    #[test]
    fn torn_beyond_snapshot_flags_full_scan_fallback() {
        let node = meta_node();
        let store = StateStore::open(Arc::clone(&node)).unwrap();
        let e = store.campaign("a").unwrap();
        for i in 0..4 {
            store.append(e, &place(&format!("f{i}"), "n0")).unwrap();
        }
        store.compact().unwrap();
        let gen = store.status().generation;
        assert!(gen >= 1);
        // corrupt the snapshot at the head of the compacted generation
        let log = node.open_file(&log_name(gen)).unwrap();
        log.write_at(&[0xFF; 16], 0).unwrap();
        store.reopen().unwrap();
        assert!(store.view().torn, "snapshot gone ⇒ only a scan helps");
    }
}
