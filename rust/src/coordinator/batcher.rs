//! Bulk translation: resolve many virtual clusters at once through the
//! AOT-compiled kernels (L1/L2 of the stack), with the host kernels as a
//! bit-exact fallback when artifacts are absent.
//!
//! Used by the coordinator for *bulk* control-plane work — boot-time
//! prefetch planning, migration/copy planning, Fig 13c-style accounting —
//! never on the per-request path (which is pure driver code).

use crate::qcow::Chain;
use crate::runtime::service::RuntimeService;
use crate::runtime::{host, UNALLOCATED};
use anyhow::Result;

pub struct BulkTranslator {
    runtime: Option<RuntimeService>,
    /// histogram width when falling back to host kernels
    hist_files: usize,
}

impl BulkTranslator {
    pub fn new(runtime: Option<RuntimeService>) -> Self {
        let hist_files = runtime.as_ref().map(|r| r.chain).unwrap_or(32);
        BulkTranslator { runtime, hist_files }
    }

    pub fn is_accelerated(&self) -> bool {
        self.runtime.is_some()
    }

    /// Flatten a stamped chain's active volume into the kernel-side
    /// (off, bfi) arrays, where `off` is the host *cluster index* in the
    /// owning file. Only indexes the first `max_clusters` virtual
    /// clusters (kernel tiles are fixed-size; callers loop for more).
    pub fn flatten_active(chain: &Chain, start: u64, max_clusters: usize) -> Result<(Vec<i32>, Vec<i32>)> {
        let active = chain.active();
        let geom = *active.geom();
        let end = (start + max_clusters as u64).min(geom.num_vclusters());
        let mut off = Vec::with_capacity((end - start) as usize);
        let mut bfi = Vec::with_capacity((end - start) as usize);
        for vc in start..end {
            match active.l2_entry(vc)?.sqemu_view(active.chain_index()) {
                Some((b, o)) => {
                    off.push((o >> geom.cluster_bits) as i32);
                    bfi.push(b as i32);
                }
                None => {
                    off.push(UNALLOCATED);
                    bfi.push(UNALLOCATED);
                }
            }
        }
        Ok((off, bfi))
    }

    /// Resolve `vbs` (virtual cluster indices, all < off.len()) against a
    /// flattened table. Returns (bfi, host_cluster, per-file histogram).
    pub fn translate(
        &self,
        off: &[i32],
        bfi: &[i32],
        vbs: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>, Vec<i64>)> {
        match &self.runtime {
            Some(rt) if off.len() <= rt.clusters => rt.translate_direct(off, bfi, vbs),
            _ => Ok(host::translate_direct(off, bfi, vbs, self.hist_files)),
        }
    }

    /// Boot-prefetch plan for a VM: the set of (bfi, host cluster) pairs
    /// the first `span` virtual clusters resolve to — the coordinator
    /// warms the storage-node caches / unified cache with these.
    pub fn prefetch_plan(&self, chain: &Chain, span: usize) -> Result<Vec<(i32, i32)>> {
        let (off, bfi) = Self::flatten_active(chain, 0, span)?;
        let vbs: Vec<i32> = (0..off.len() as i32).collect();
        let (rb, ro, _) = self.translate(&off, &bfi, &vbs)?;
        Ok(rb
            .into_iter()
            .zip(ro)
            .filter(|&(b, _)| b != UNALLOCATED)
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaingen::{generate, ChainSpec};
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::qcow::image::DataMode;
    use crate::storage::node::StorageNode;

    fn chain() -> Chain {
        let node = StorageNode::new("s", VirtClock::new(), CostModel::default());
        generate(
            &*node,
            &ChainSpec {
                disk_size: 16 << 20,
                chain_len: 4,
                populated: 0.6,
                data_mode: DataMode::Real,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn flatten_matches_resolve_walk() {
        let c = chain();
        let geom = *c.active().geom();
        let (off, bfi) = BulkTranslator::flatten_active(&c, 0, 10_000).unwrap();
        assert_eq!(off.len(), geom.num_vclusters() as usize);
        for vc in 0..geom.num_vclusters() {
            let walk = c.resolve_walk(vc).unwrap();
            match walk {
                None => assert_eq!(bfi[vc as usize], UNALLOCATED),
                Some((b, o)) => {
                    assert_eq!(bfi[vc as usize], b as i32);
                    assert_eq!(off[vc as usize], (o >> geom.cluster_bits) as i32);
                }
            }
        }
    }

    #[test]
    fn host_fallback_translates() {
        let c = chain();
        let bt = BulkTranslator::new(None);
        assert!(!bt.is_accelerated());
        let plan = bt.prefetch_plan(&c, 256).unwrap();
        assert!(!plan.is_empty());
        for (b, o) in plan {
            assert!(b >= 0 && o >= 0);
        }
    }

    #[test]
    fn accelerated_path_matches_host_when_available() {
        let c = chain();
        let Some(svc) = RuntimeService::try_default() else {
            eprintln!("SKIP: no artifacts");
            return;
        };
        let accel = BulkTranslator::new(Some(svc));
        let host_bt = BulkTranslator::new(None);
        let (off, bfi) = BulkTranslator::flatten_active(&c, 0, 4096).unwrap();
        let vbs: Vec<i32> = (0..off.len() as i32).collect();
        let (ab, ao, _) = accel.translate(&off, &bfi, &vbs).unwrap();
        let (hb, ho, _) = host_bt.translate(&off, &bfi, &vbs).unwrap();
        assert_eq!(ab, hb);
        assert_eq!(ao, ho);
    }
}
