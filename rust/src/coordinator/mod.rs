//! Layer-3 coordinator: the multi-VM storage service.
//!
//! The paper's infrastructure runs many VMs whose chains live on shared
//! storage nodes; the provider's control plane creates snapshots, copies
//! disks, streams chains and balances placement (§3). This module is that
//! control plane, scaled to the simulation:
//!
//! * [`server::Coordinator`] — owns the storage nodes and the VM fleet.
//!   The data plane is sharded: a fixed pool of [`shard`] executors (one
//!   per core, not one per VM) owns disjoint VM sets, and each VM's
//!   driver lives on exactly one shard (drivers stay single-owner, like
//!   a Qemu process). Guest requests flow through per-VM lock-free
//!   submission/completion [`ring`]s (backpressure = SQ full); clients
//!   can keep many operations in flight and reap completions
//!   asynchronously, with per-VM program order preserved.
//! * [`crate::storage::iosched`] — per-node I/O schedulers let a shard merge
//!   vectored runs ACROSS VMs targeting the same node inside a serving
//!   pass (cross-VM extent batching under the Timed cost model).
//! * [`placement::NodeSet`] — multi-node [`FileStore`]: new files go to
//!   the least-loaded node with capacity (thin provisioning: a chain can
//!   continue on another node, §4.1).
//! * [`batcher::BulkTranslator`] — bulk virtual-cluster resolution via
//!   the AOT PJRT kernels (boot prefetch, migration planning); falls back
//!   to the bit-exact host kernels without artifacts.
//! * [`streaming::StreamingOrchestrator`] — plans merges with the
//!   `stream_fold` kernel, validates the plan, pauses the VM, executes
//!   [`crate::qcow::snapshot::stream_merge`], verifies with `qcheck`
//!   and resumes (the offline baseline).
//! * live block jobs — [`server::Coordinator::start_job`] admits a
//!   [`crate::blockjob`] stream/stamp job against the per-node
//!   bandwidth budget and runs it on the VM worker interleaved with
//!   guest I/O (no pause); lifecycle via `list_jobs` / `cancel_job` /
//!   `pause_job` / `resume_job` and `sqemu job ...`.
//! * garbage collection — the coordinator owns the [`crate::gc`]
//!   reference registry; chain-shape changes (launch, snapshot, stream,
//!   live-job completion, decommission) re-declare each chain's file
//!   set, and [`server::Coordinator::run_gc`] sweeps the deferred-delete
//!   set under the same admission/rate machinery as the live jobs.
//! * migration & rebalancing — [`server::Coordinator::migrate_vm`] moves
//!   a VM's whole chain to another node under guest I/O (a
//!   [`crate::migrate::MirrorJob`] with a capacity reservation on the
//!   recipient), and [`server::Coordinator::rebalance`] plans and
//!   executes donor→recipient moves whenever per-node pressure skews
//!   past a threshold; `Coordinator::recover()` resolves interrupted
//!   migrations from their durable journals and rebuilds the placement
//!   index.
//! * HA control plane — [`server::Coordinator::attach_control`] wires a
//!   write-ahead [`crate::control::StateStore`] under the placement and
//!   GC registries, turns VM ownership lease-based, fences every
//!   control mutation by election epoch, and lets
//!   `Coordinator::recover()` *replay* fleet state in O(log) + O(active
//!   leases) instead of scanning every node;
//!   [`server::Coordinator::takeover`] is the live-failover analogue
//!   for a standby coordinator.
//! * telemetry — the coordinator owns the fleet
//!   [`crate::telemetry::Registry`] and the span-trace
//!   [`crate::telemetry::TraceRing`]; `Coordinator::new` registers the
//!   standard collector set ([`crate::telemetry::fleet`]) so a scrape
//!   (`sqemu metrics`, `Registry::render`) sees every subsystem without
//!   any of them growing scrape-side state. Trace-sampled VM slots
//!   (one per `CoordinatorConfig::trace_sample` launches) record span
//!   events into executor-owned buffers the stats reaper drains.
//!
//! [`FileStore`]: crate::storage::store::FileStore

pub mod batcher;
pub mod placement;
pub mod ring;
pub mod server;
pub mod shard;
pub mod stats;
pub mod streaming;

pub use batcher::BulkTranslator;
pub use placement::{NodeSet, PlacementEvent, PlacementObserver};
pub use ring::RingReply;
pub use server::{
    BatchOp, BatchReply, Coordinator, CoordinatorConfig, JobSpec, RebalanceReport,
    RecoveryReport, VmClient, VmConfig,
};
pub use shard::ShardStatsSnapshot;
