//! Placement: which storage node holds which backing file.
//!
//! §3: "cloud providers use the snapshot feature to transparently
//! distribute a virtual disk among several storage servers ... for load
//! balancing reasons". `NodeSet` is a [`FileStore`] whose create places
//! each new file on the least-used node with room, so a chain's files can
//! span nodes transparently.

use crate::storage::backend::BackendRef;
use crate::storage::node::StorageNode;
use crate::storage::store::FileStore;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

pub struct NodeSet {
    nodes: Vec<Arc<StorageNode>>,
    /// file name -> node index
    index: Mutex<HashMap<String, usize>>,
}

impl NodeSet {
    pub fn new(nodes: Vec<Arc<StorageNode>>) -> Result<NodeSet> {
        if nodes.is_empty() {
            bail!("need at least one storage node");
        }
        Ok(NodeSet { nodes, index: Mutex::new(HashMap::new()) })
    }

    /// Least-used node that still has capacity headroom.
    fn pick_node(&self) -> Result<usize> {
        let mut best: Option<(usize, u64)> = None;
        for (i, n) in self.nodes.iter().enumerate() {
            let used = n.used_bytes();
            if used >= n.capacity {
                continue;
            }
            if best.map_or(true, |(_, bu)| used < bu) {
                best = Some((i, used));
            }
        }
        best.map(|(i, _)| i)
            .ok_or_else(|| anyhow!("all storage nodes at capacity"))
    }

    pub fn nodes(&self) -> &[Arc<StorageNode>] {
        &self.nodes
    }

    /// Which node holds `name`?
    pub fn locate(&self, name: &str) -> Option<String> {
        let idx = *self.index.lock().unwrap().get(name)?;
        Some(self.nodes[idx].name.clone())
    }

    /// Per-node stored bytes (load-balance report).
    pub fn usage(&self) -> Vec<(String, u64)> {
        self.nodes
            .iter()
            .map(|n| (n.name.clone(), n.used_bytes()))
            .collect()
    }
}

impl FileStore for NodeSet {
    fn create_file(&self, name: &str) -> Result<BackendRef> {
        let mut index = self.index.lock().unwrap();
        if index.contains_key(name) {
            bail!("file '{name}' already exists in the node set");
        }
        let node_idx = self.pick_node()?;
        let backend = self.nodes[node_idx].create_file(name)?;
        index.insert(name.to_string(), node_idx);
        Ok(backend)
    }

    fn open_file(&self, name: &str) -> Result<BackendRef> {
        let index = self.index.lock().unwrap();
        let &node_idx = index
            .get(name)
            .ok_or_else(|| anyhow!("no file '{name}' in the node set"))?;
        self.nodes[node_idx].open_file(name)
    }

    fn delete_file(&self, name: &str) -> Result<()> {
        let mut index = self.index.lock().unwrap();
        let node_idx = index
            .remove(name)
            .ok_or_else(|| anyhow!("no file '{name}' in the node set"))?;
        self.nodes[node_idx].delete_file(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::qcow::image::DataMode;
    use crate::qcow::{snapshot, Chain, Image};
    use crate::qcow::layout::{Geometry, FEATURE_BFI};

    fn set(caps: &[u64]) -> NodeSet {
        let clock = VirtClock::new();
        let nodes = caps
            .iter()
            .enumerate()
            .map(|(i, &cap)| {
                StorageNode::with_capacity(
                    &format!("node-{i}"),
                    clock.clone(),
                    CostModel::default(),
                    cap,
                )
            })
            .collect();
        NodeSet::new(nodes).unwrap()
    }

    #[test]
    fn balances_across_nodes() {
        let ns = set(&[u64::MAX, u64::MAX]);
        for i in 0..4 {
            let f = ns.create_file(&format!("f{i}")).unwrap();
            f.write_at(&[1u8; 64 << 10], 0).unwrap();
        }
        let usage = ns.usage();
        assert!(usage[0].1 > 0 && usage[1].1 > 0, "{usage:?}");
    }

    #[test]
    fn respects_capacity() {
        let ns = set(&[128 << 10, u64::MAX]);
        for i in 0..6 {
            let f = ns.create_file(&format!("f{i}")).unwrap();
            f.write_at(&[1u8; 64 << 10], 0).unwrap();
        }
        let usage = ns.usage();
        assert!(usage[0].1 <= 192 << 10, "node-0 overfilled: {usage:?}");
        assert!(usage[1].1 >= 256 << 10);
    }

    #[test]
    fn chain_spans_nodes_transparently() {
        let ns = set(&[256 << 10, u64::MAX]);
        let geom = Geometry::new(16, 16 << 20).unwrap();
        let b = ns.create_file("img-0").unwrap();
        let img =
            Image::create("img-0", b, geom, FEATURE_BFI, 0, None, DataMode::Real)
                .unwrap();
        let mut chain = Chain::new(std::sync::Arc::new(img)).unwrap();
        for i in 0..6 {
            snapshot::snapshot_sqemu(&mut chain, &ns, &format!("img-{}", i + 1))
                .unwrap();
        }
        // files landed on both nodes, chain still opens through the set
        let located: std::collections::HashSet<String> = (0..7)
            .map(|i| ns.locate(&format!("img-{i}")).unwrap())
            .collect();
        assert!(located.len() > 1, "all files on one node");
        let reopened = Chain::open(&ns, "img-6", DataMode::Real).unwrap();
        assert_eq!(reopened.len(), 7);
    }

    #[test]
    fn open_missing_fails() {
        let ns = set(&[u64::MAX]);
        assert!(ns.open_file("nope").is_err());
        assert!(ns.delete_file("nope").is_err());
    }
}
