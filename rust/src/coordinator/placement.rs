//! Placement: which storage node holds which backing file.
//!
//! §3: "cloud providers use the snapshot feature to transparently
//! distribute a virtual disk among several storage servers ... for load
//! balancing reasons". `NodeSet` is a [`FileStore`] whose create places
//! each new file on the least-used node with room, so a chain's files can
//! span nodes transparently.
//!
//! Placement is no longer write-once: the [`crate::migrate`] subsystem
//! moves whole chains between nodes under guest I/O and commits the move
//! by flipping this index ([`NodeSet::commit_migration`]); crash
//! recovery rebuilds the index from the nodes' durable file lists
//! ([`NodeSet::rebuild_index`]). Chain-locality placement
//! ([`NodeSet::create_file_near`] / [`NodeSet::hinted`]) keeps a chain's
//! snapshots on the node already holding it instead of scattering them
//! file-by-file.

use crate::migrate::journal::JOURNAL_PREFIX;
use crate::storage::backend::BackendRef;
use crate::storage::node::StorageNode;
use crate::storage::store::FileStore;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// A placement mutation, reported to the [`NodeSet`] observer *before*
/// the index changes (write-ahead: the durable record must exist before
/// the volatile state it describes).
#[derive(Debug)]
pub enum PlacementEvent<'a> {
    /// `file` is being created on `node`.
    Placed { file: &'a str, node: &'a str },
    /// `file` is being deleted.
    Removed { file: &'a str },
    /// Every file in `files` is being re-pointed at `node` (migration
    /// switchover).
    Migrated { files: &'a [String], node: &'a str },
}

/// Write-ahead hook with veto: an `Err` aborts the mutation before it
/// happens. The control plane installs one that appends the event to
/// the [`crate::control::StateStore`]; a wedged store then refuses new
/// placements instead of silently diverging from its log.
pub type PlacementObserver =
    Box<dyn Fn(&PlacementEvent<'_>) -> Result<()> + Send + Sync>;

pub struct NodeSet {
    nodes: Vec<Arc<StorageNode>>,
    /// file name -> node index
    index: Mutex<HashMap<String, usize>>,
    /// Write-ahead observer (see [`PlacementObserver`]). Lock order:
    /// `index` may be held while the observer runs; the observer takes
    /// only its own store lock, never back into this set.
    observer: Mutex<Option<PlacementObserver>>,
}

impl NodeSet {
    pub fn new(nodes: Vec<Arc<StorageNode>>) -> Result<NodeSet> {
        if nodes.is_empty() {
            bail!("need at least one storage node");
        }
        Ok(NodeSet {
            nodes,
            index: Mutex::new(HashMap::new()),
            observer: Mutex::new(None),
        })
    }

    /// Install (or replace) the write-ahead placement observer.
    pub fn set_observer(&self, obs: Option<PlacementObserver>) {
        *self.observer.lock().unwrap() = obs;
    }

    fn notify(&self, ev: &PlacementEvent<'_>) -> Result<()> {
        match self.observer.lock().unwrap().as_ref() {
            Some(obs) => obs(ev),
            None => Ok(()),
        }
    }

    /// Does node `i` still have thin-provisioning headroom? Committed
    /// bytes (pressure + migration reservations), not raw usage:
    /// condemned (pending GC delete) bytes do not block placement —
    /// their reclamation is already scheduled — while reserved bytes
    /// DO: an in-flight migration has committed them.
    fn has_headroom(&self, i: usize) -> bool {
        let n = &self.nodes[i];
        n.committed_bytes() < n.capacity
    }

    /// Least-committed node that still has capacity headroom.
    fn pick_node(&self) -> Result<usize> {
        let mut best: Option<(usize, u64)> = None;
        for (i, n) in self.nodes.iter().enumerate() {
            let used = n.committed_bytes();
            if used >= n.capacity {
                continue;
            }
            if best.map_or(true, |(_, bu)| used < bu) {
                best = Some((i, used));
            }
        }
        best.map(|(i, _)| i)
            .ok_or_else(|| anyhow!("all storage nodes at capacity"))
    }

    pub fn nodes(&self) -> &[Arc<StorageNode>] {
        &self.nodes
    }

    /// Which node holds `name`?
    pub fn locate(&self, name: &str) -> Option<String> {
        let idx = *self.index.lock().unwrap().get(name)?;
        Some(self.nodes[idx].name.clone())
    }

    /// The node holding `name` (GC needs the node itself, not its name).
    pub fn node_of(&self, name: &str) -> Option<Arc<StorageNode>> {
        let idx = *self.index.lock().unwrap().get(name)?;
        Some(Arc::clone(&self.nodes[idx]))
    }

    /// Look a node up by its own name (`node-0`, ...).
    pub fn node_named(&self, node: &str) -> Option<Arc<StorageNode>> {
        self.nodes.iter().find(|n| n.name == node).cloned()
    }

    fn node_idx(&self, node: &str) -> Option<usize> {
        self.nodes.iter().position(|n| n.name == node)
    }

    /// Create `name` on the node already holding `near` (chain-locality
    /// placement: a snapshot's new head belongs next to its chain),
    /// falling back to [`pick_node`] when that node is unknown or out of
    /// headroom.
    ///
    /// [`pick_node`]: NodeSet::pick_node
    pub fn create_file_near(&self, name: &str, near: &str) -> Result<BackendRef> {
        let mut index = self.index.lock().unwrap();
        if index.contains_key(name) {
            bail!("file '{name}' already exists in the node set");
        }
        let node_idx = match index.get(near).copied() {
            Some(i) if self.has_headroom(i) => i,
            _ => self.pick_node()?,
        };
        self.notify(&PlacementEvent::Placed {
            file: name,
            node: &self.nodes[node_idx].name,
        })?;
        let backend = self.nodes[node_idx].create_file(name)?;
        index.insert(name.to_string(), node_idx);
        Ok(backend)
    }

    /// Create `name` on the named node, no fallback (deterministic
    /// placement for fixtures, demos and benches).
    pub fn create_file_on(&self, name: &str, node: &str) -> Result<BackendRef> {
        let node_idx = self
            .node_idx(node)
            .ok_or_else(|| anyhow!("no storage node '{node}'"))?;
        let mut index = self.index.lock().unwrap();
        if index.contains_key(name) {
            bail!("file '{name}' already exists in the node set");
        }
        self.notify(&PlacementEvent::Placed {
            file: name,
            node: &self.nodes[node_idx].name,
        })?;
        let backend = self.nodes[node_idx].create_file(name)?;
        index.insert(name.to_string(), node_idx);
        Ok(backend)
    }

    /// A [`FileStore`] view whose creates land near `near` (snapshot
    /// locality: pass the chain's active volume).
    pub fn hinted(self: &Arc<Self>, near: &str) -> HintedStore {
        HintedStore { set: Arc::clone(self), near: near.to_string() }
    }

    /// A [`FileStore`] view whose creates all land on one named node.
    pub fn pinned(self: &Arc<Self>, node: &str) -> Result<PinnedStore> {
        if self.node_idx(node).is_none() {
            bail!("no storage node '{node}'");
        }
        Ok(PinnedStore { set: Arc::clone(self), node: node.to_string() })
    }

    /// Atomic switchover of a migration: every `name` now resolves to
    /// `target`. The caller has already made the target copies durable
    /// and committed the migration journal; the superseded source copies
    /// are its to condemn.
    pub fn commit_migration(&self, names: &[String], target: &str) -> Result<()> {
        let t = self
            .node_idx(target)
            .ok_or_else(|| anyhow!("no storage node '{target}'"))?;
        self.notify(&PlacementEvent::Migrated { files: names, node: target })?;
        let mut index = self.index.lock().unwrap();
        for n in names {
            index.insert(n.clone(), t);
        }
        Ok(())
    }

    /// Replace the index wholesale from a replayed durable log,
    /// validating each entry against the named node's actual files —
    /// trust but verify, per entry, with NO full listing pass. Entries
    /// naming an unknown node or a file the node no longer holds are
    /// dropped and returned (the log may be slightly ahead of a crash).
    /// The observer is NOT consulted: this installs what the log already
    /// records.
    pub fn install_index(&self, entries: &[(String, String)]) -> Vec<String> {
        let mut index = self.index.lock().unwrap();
        index.clear();
        let mut dropped = Vec::new();
        for (file, node) in entries {
            match self.node_idx(node) {
                Some(i) if self.nodes[i].open_file(file).is_ok() => {
                    index.insert(file.clone(), i);
                }
                _ => dropped.push(file.clone()),
            }
        }
        dropped
    }

    /// The current name→node mapping, sorted by file name (what
    /// [`crate::control::StateStore::reseed`] persists after a full-scan
    /// recovery).
    pub fn index_snapshot(&self) -> Vec<(String, String)> {
        let index = self.index.lock().unwrap();
        let mut v: Vec<(String, String)> = index
            .iter()
            .map(|(f, &i)| (f.clone(), self.nodes[i].name.clone()))
            .collect();
        v.sort();
        v
    }

    /// Rebuild the name→node index from the nodes' durable file lists —
    /// the index itself is volatile and a freshly booted coordinator
    /// would otherwise be unable to locate any pre-existing chain file
    /// (the pre-fix bug: `locate`/`node_of`/`delete_file` silently
    /// worked on an empty map after recovery). Migration journals
    /// (`.migrate.*`) are control-plane metadata, not placed files, and
    /// are skipped. Returns the names found on more than one node —
    /// after [`crate::migrate::recover_migrations`] resolved every
    /// journal there should be none; survivors indicate corruption and
    /// keep the LAST node scanned as a deterministic tiebreak.
    pub fn rebuild_index(&self) -> Vec<String> {
        let mut index = self.index.lock().unwrap();
        index.clear();
        let mut duplicates = Vec::new();
        for (i, node) in self.nodes.iter().enumerate() {
            let mut names = node.file_names();
            names.sort();
            for f in names {
                if f.starts_with(JOURNAL_PREFIX) {
                    continue;
                }
                if index.insert(f.clone(), i).is_some() {
                    duplicates.push(f);
                }
            }
        }
        duplicates
    }

    /// Per-node stored bytes (load-balance report).
    pub fn usage(&self) -> Vec<(String, u64)> {
        self.nodes
            .iter()
            .map(|n| (n.name.clone(), n.used_bytes()))
            .collect()
    }

    /// Per-node capacity report including the GC and migration view.
    pub fn node_stats(&self) -> Vec<NodeStats> {
        self.nodes
            .iter()
            .map(|n| NodeStats {
                name: n.name.clone(),
                used_bytes: n.used_bytes(),
                logical_bytes: n.logical_bytes(),
                condemned_bytes: n.condemned_bytes(),
                pressure_bytes: n.pressure_bytes(),
                reserved_bytes: n.reserved_bytes(),
                reclaimed_bytes: n.reclaimed_bytes(),
                gc_deletes: n.gc_deletes(),
            })
            .collect()
    }

    /// Aggregate stored bytes across the whole set.
    pub fn total_used_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.used_bytes()).sum()
    }

    /// Aggregate thin-provisioning pressure across the whole set.
    pub fn total_pressure_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.pressure_bytes()).sum()
    }
}

/// One node's capacity / reclamation snapshot.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NodeStats {
    pub name: String,
    /// Physically stored bytes (everything, condemned included). This —
    /// not logical bytes — is what placement, `would_overflow` and
    /// reservations run on: real pressure after zero-cluster
    /// suppression, compression and dedup.
    pub used_bytes: u64,
    /// Guest-addressable bytes mapped by the chains stored here, per the
    /// coordinator's last capacity scan (0 before any scan).
    /// `logical_bytes / used_bytes` is the node's capacity
    /// multiplication factor.
    pub logical_bytes: u64,
    /// Bytes awaiting a GC sweep.
    pub condemned_bytes: u64,
    /// used - condemned: what thin provisioning counts.
    pub pressure_bytes: u64,
    /// Bytes reserved for in-flight migration copies (also counted by
    /// placement and `would_overflow`).
    pub reserved_bytes: u64,
    /// Bytes returned by GC sweeps so far.
    pub reclaimed_bytes: u64,
    /// Files deleted by GC sweeps so far.
    pub gc_deletes: u64,
}

impl FileStore for NodeSet {
    fn create_file(&self, name: &str) -> Result<BackendRef> {
        let mut index = self.index.lock().unwrap();
        if index.contains_key(name) {
            bail!("file '{name}' already exists in the node set");
        }
        let node_idx = self.pick_node()?;
        self.notify(&PlacementEvent::Placed {
            file: name,
            node: &self.nodes[node_idx].name,
        })?;
        let backend = self.nodes[node_idx].create_file(name)?;
        index.insert(name.to_string(), node_idx);
        Ok(backend)
    }

    fn open_file(&self, name: &str) -> Result<BackendRef> {
        let index = self.index.lock().unwrap();
        let &node_idx = index
            .get(name)
            .ok_or_else(|| anyhow!("no file '{name}' in the node set"))?;
        self.nodes[node_idx].open_file(name)
    }

    fn delete_file(&self, name: &str) -> Result<()> {
        let mut index = self.index.lock().unwrap();
        let &node_idx = index
            .get(name)
            .ok_or_else(|| anyhow!("no file '{name}' in the node set"))?;
        self.notify(&PlacementEvent::Removed { file: name })?;
        index.remove(name);
        self.nodes[node_idx].delete_file(name)
    }
}

/// Chain-locality view of a [`NodeSet`]: creates land on the node holding
/// the `near` anchor (falling back to least-used placement on overflow).
pub struct HintedStore {
    set: Arc<NodeSet>,
    near: String,
}

impl FileStore for HintedStore {
    fn create_file(&self, name: &str) -> Result<BackendRef> {
        self.set.create_file_near(name, &self.near)
    }

    fn open_file(&self, name: &str) -> Result<BackendRef> {
        self.set.open_file(name)
    }

    fn delete_file(&self, name: &str) -> Result<()> {
        self.set.delete_file(name)
    }
}

/// Deterministic-placement view of a [`NodeSet`]: creates land on one
/// named node, errors included (no fallback).
pub struct PinnedStore {
    set: Arc<NodeSet>,
    node: String,
}

impl FileStore for PinnedStore {
    fn create_file(&self, name: &str) -> Result<BackendRef> {
        self.set.create_file_on(name, &self.node)
    }

    fn open_file(&self, name: &str) -> Result<BackendRef> {
        self.set.open_file(name)
    }

    fn delete_file(&self, name: &str) -> Result<()> {
        self.set.delete_file(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::qcow::image::DataMode;
    use crate::qcow::layout::{Geometry, FEATURE_BFI};
    use crate::qcow::{snapshot, Chain, Image};

    fn set(caps: &[u64]) -> NodeSet {
        let clock = VirtClock::new();
        let nodes = caps
            .iter()
            .enumerate()
            .map(|(i, &cap)| {
                StorageNode::with_capacity(
                    &format!("node-{i}"),
                    clock.clone(),
                    CostModel::default(),
                    cap,
                )
            })
            .collect();
        NodeSet::new(nodes).unwrap()
    }

    #[test]
    fn balances_across_nodes() {
        let ns = set(&[u64::MAX, u64::MAX]);
        for i in 0..4 {
            let f = ns.create_file(&format!("f{i}")).unwrap();
            f.write_at(&[1u8; 64 << 10], 0).unwrap();
        }
        let usage = ns.usage();
        assert!(usage[0].1 > 0 && usage[1].1 > 0, "{usage:?}");
    }

    #[test]
    fn respects_capacity() {
        let ns = set(&[128 << 10, u64::MAX]);
        for i in 0..6 {
            let f = ns.create_file(&format!("f{i}")).unwrap();
            f.write_at(&[1u8; 64 << 10], 0).unwrap();
        }
        let usage = ns.usage();
        assert!(usage[0].1 <= 192 << 10, "node-0 overfilled: {usage:?}");
        assert!(usage[1].1 >= 256 << 10);
    }

    #[test]
    fn chain_spans_nodes_transparently() {
        let ns = set(&[256 << 10, u64::MAX]);
        let geom = Geometry::new(16, 16 << 20).unwrap();
        let b = ns.create_file("img-0").unwrap();
        let img =
            Image::create("img-0", b, geom, FEATURE_BFI, 0, None, DataMode::Real)
                .unwrap();
        let mut chain = Chain::new(std::sync::Arc::new(img)).unwrap();
        for i in 0..6 {
            snapshot::snapshot_sqemu(&mut chain, &ns, &format!("img-{}", i + 1))
                .unwrap();
        }
        // files landed on both nodes, chain still opens through the set
        let located: std::collections::HashSet<String> = (0..7)
            .map(|i| ns.locate(&format!("img-{i}")).unwrap())
            .collect();
        assert!(located.len() > 1, "all files on one node");
        let reopened = Chain::open(&ns, "img-6", DataMode::Real).unwrap();
        assert_eq!(reopened.len(), 7);
    }

    #[test]
    fn condemned_capacity_reopens_placement() {
        let ns = set(&[256 << 10, 256 << 10]);
        let f0 = ns.create_file("f0").unwrap(); // lands on node-0
        f0.write_at(&[1u8; 100 << 10], 0).unwrap();
        let f1 = ns.create_file("f1").unwrap(); // least-used: node-1
        f1.write_at(&[1u8; 40 << 10], 0).unwrap();
        // normally the next file would land on node-1 (40K < 100K); with
        // f0 condemned, node-0's pressure drops to zero and wins
        let n0 = ns.node_of("f0").unwrap();
        n0.mark_condemned("f0");
        let f = ns.create_file("f-new").unwrap();
        f.write_at(&[1u8; 8 << 10], 0).unwrap();
        assert_eq!(ns.locate("f-new").unwrap(), n0.name);
        let stats = ns.node_stats();
        let s0 = stats.iter().find(|s| s.name == n0.name).unwrap();
        assert_eq!(s0.condemned_bytes, 100 << 10);
        assert_eq!(s0.pressure_bytes, 8 << 10);
        assert_eq!(s0.used_bytes, (100 << 10) + (8 << 10));
    }

    #[test]
    fn reservations_steer_placement_away() {
        let ns = set(&[u64::MAX, u64::MAX]);
        let f0 = ns.create_file("f0").unwrap(); // node-0 (first of equals)
        f0.write_at(&[1u8; 8 << 10], 0).unwrap();
        // node-1 is emptier, but a migration reserved space on it
        ns.node_named("node-1").unwrap().reserve(1 << 20).unwrap();
        ns.create_file("f1").unwrap();
        assert_eq!(ns.locate("f1").unwrap(), "node-0");
        let stats = ns.node_stats();
        assert_eq!(stats[1].reserved_bytes, 1 << 20);
    }

    #[test]
    fn hinted_creates_colocate_until_overflow() {
        let ns = Arc::new(set(&[192 << 10, u64::MAX]));
        let f0 = ns.create_file_on("anchor", "node-0").unwrap();
        f0.write_at(&[1u8; 64 << 10], 0).unwrap();
        let hinted = ns.hinted("anchor");
        let f1 = hinted.create_file("h1").unwrap();
        f1.write_at(&[1u8; 64 << 10], 0).unwrap();
        assert_eq!(ns.locate("h1").unwrap(), "node-0", "hint honoured");
        // node-0 is full now (192 KiB capacity, 128 KiB + new file would
        // round past it): the hint falls back to pick_node
        let f2 = hinted.create_file("h2").unwrap();
        f2.write_at(&[1u8; 64 << 10], 0).unwrap();
        let f3 = hinted.create_file("h3").unwrap();
        f3.write_at(&[1u8; 64 << 10], 0).unwrap();
        assert_eq!(
            ns.locate("h3").unwrap(),
            "node-1",
            "overflow falls back to least-used placement"
        );
        // unknown anchors never fail creation
        let h = ns.hinted("no-such-file");
        h.create_file("h4").unwrap();
    }

    #[test]
    fn commit_migration_flips_the_index() {
        let ns = set(&[u64::MAX, u64::MAX]);
        ns.create_file_on("a", "node-0").unwrap();
        ns.create_file_on("b", "node-0").unwrap();
        ns.commit_migration(&["a".into(), "b".into()], "node-1").unwrap();
        assert_eq!(ns.locate("a").unwrap(), "node-1");
        assert_eq!(ns.locate("b").unwrap(), "node-1");
        assert!(ns.commit_migration(&["a".into()], "node-9").is_err());
    }

    #[test]
    fn rebuild_index_restores_location_after_reboot() {
        let clock = VirtClock::new();
        let a = StorageNode::new("node-0", clock.clone(), CostModel::default());
        let b = StorageNode::new("node-1", clock.clone(), CostModel::default());
        let ns1 = NodeSet::new(vec![Arc::clone(&a), Arc::clone(&b)]).unwrap();
        ns1.create_file_on("f0", "node-0").unwrap();
        ns1.create_file_on("f1", "node-1").unwrap();
        b.create_file(".migrate.vm").unwrap(); // journal: never indexed
        // "reboot": a fresh set over the same durable nodes knows nothing
        let ns2 = NodeSet::new(vec![Arc::clone(&a), Arc::clone(&b)]).unwrap();
        assert!(ns2.locate("f0").is_none(), "pre-rebuild: index empty");
        let dups = ns2.rebuild_index();
        assert!(dups.is_empty());
        assert_eq!(ns2.locate("f0").unwrap(), "node-0");
        assert_eq!(ns2.locate("f1").unwrap(), "node-1");
        assert!(ns2.locate(".migrate.vm").is_none(), "journals stay off-index");
        // a lingering duplicate (unresolved migration) is reported
        a.create_file("f1").unwrap();
        let dups = ns2.rebuild_index();
        assert_eq!(dups, vec!["f1".to_string()]);
    }

    #[test]
    fn open_missing_fails() {
        let ns = set(&[u64::MAX]);
        assert!(ns.open_file("nope").is_err());
        assert!(ns.delete_file("nope").is_err());
    }

    #[test]
    fn observer_is_write_ahead_and_can_veto() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let ns = set(&[u64::MAX]);
        let veto = Arc::new(AtomicBool::new(false));
        let log = Arc::new(Mutex::new(Vec::<String>::new()));
        let (v2, l2) = (Arc::clone(&veto), Arc::clone(&log));
        ns.set_observer(Some(Box::new(move |ev| {
            if v2.load(Ordering::Relaxed) {
                bail!("store wedged");
            }
            l2.lock().unwrap().push(format!("{ev:?}"));
            Ok(())
        })));
        ns.create_file("f0").unwrap();
        ns.commit_migration(&["f0".into()], "node-0").unwrap();
        ns.delete_file("f0").unwrap();
        assert_eq!(log.lock().unwrap().len(), 3);
        // vetoed mutations must not happen at all
        veto.store(true, Ordering::Relaxed);
        assert!(ns.create_file("f1").is_err());
        ns.set_observer(None);
        assert!(ns.open_file("f1").is_err(), "vetoed create left no file");
        ns.create_file("f1").unwrap();
    }

    #[test]
    fn install_index_validates_entries_without_listing() {
        let ns = set(&[u64::MAX, u64::MAX]);
        ns.create_file_on("a", "node-0").unwrap();
        ns.create_file_on("b", "node-1").unwrap();
        let snap = ns.index_snapshot();
        assert_eq!(
            snap,
            vec![
                ("a".to_string(), "node-0".to_string()),
                ("b".to_string(), "node-1".to_string())
            ]
        );
        let lists: u64 = ns.nodes().iter().map(|n| n.list_ops()).sum();
        // a log slightly ahead of the crash: 'ghost' was logged but its
        // create never hit the node; 'c' names an unknown node
        let mut entries = snap.clone();
        entries.push(("ghost".to_string(), "node-0".to_string()));
        entries.push(("c".to_string(), "node-9".to_string()));
        let dropped = ns.install_index(&entries);
        assert_eq!(dropped, vec!["ghost".to_string(), "c".to_string()]);
        assert_eq!(ns.locate("a").unwrap(), "node-0");
        assert_eq!(ns.locate("b").unwrap(), "node-1");
        assert!(ns.locate("ghost").is_none());
        let after: u64 = ns.nodes().iter().map(|n| n.list_ops()).sum();
        assert_eq!(after, lists, "per-entry validation, no listing pass");
    }
}
