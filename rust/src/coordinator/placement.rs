//! Placement: which storage node holds which backing file.
//!
//! §3: "cloud providers use the snapshot feature to transparently
//! distribute a virtual disk among several storage servers ... for load
//! balancing reasons". `NodeSet` is a [`FileStore`] whose create places
//! each new file on the least-used node with room, so a chain's files can
//! span nodes transparently.

use crate::storage::backend::BackendRef;
use crate::storage::node::StorageNode;
use crate::storage::store::FileStore;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

pub struct NodeSet {
    nodes: Vec<Arc<StorageNode>>,
    /// file name -> node index
    index: Mutex<HashMap<String, usize>>,
}

impl NodeSet {
    pub fn new(nodes: Vec<Arc<StorageNode>>) -> Result<NodeSet> {
        if nodes.is_empty() {
            bail!("need at least one storage node");
        }
        Ok(NodeSet { nodes, index: Mutex::new(HashMap::new()) })
    }

    /// Least-used node that still has capacity headroom. Pressure, not
    /// raw usage: condemned (pending GC delete) bytes do not block
    /// placement — their reclamation is already scheduled.
    fn pick_node(&self) -> Result<usize> {
        let mut best: Option<(usize, u64)> = None;
        for (i, n) in self.nodes.iter().enumerate() {
            let used = n.pressure_bytes();
            if used >= n.capacity {
                continue;
            }
            if best.map_or(true, |(_, bu)| used < bu) {
                best = Some((i, used));
            }
        }
        best.map(|(i, _)| i)
            .ok_or_else(|| anyhow!("all storage nodes at capacity"))
    }

    pub fn nodes(&self) -> &[Arc<StorageNode>] {
        &self.nodes
    }

    /// Which node holds `name`?
    pub fn locate(&self, name: &str) -> Option<String> {
        let idx = *self.index.lock().unwrap().get(name)?;
        Some(self.nodes[idx].name.clone())
    }

    /// The node holding `name` (GC needs the node itself, not its name).
    pub fn node_of(&self, name: &str) -> Option<Arc<StorageNode>> {
        let idx = *self.index.lock().unwrap().get(name)?;
        Some(Arc::clone(&self.nodes[idx]))
    }

    /// Per-node stored bytes (load-balance report).
    pub fn usage(&self) -> Vec<(String, u64)> {
        self.nodes
            .iter()
            .map(|n| (n.name.clone(), n.used_bytes()))
            .collect()
    }

    /// Per-node capacity report including the GC view.
    pub fn node_stats(&self) -> Vec<NodeStats> {
        self.nodes
            .iter()
            .map(|n| NodeStats {
                name: n.name.clone(),
                used_bytes: n.used_bytes(),
                condemned_bytes: n.condemned_bytes(),
                pressure_bytes: n.pressure_bytes(),
                reclaimed_bytes: n.reclaimed_bytes(),
                gc_deletes: n.gc_deletes(),
            })
            .collect()
    }

    /// Aggregate stored bytes across the whole set.
    pub fn total_used_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.used_bytes()).sum()
    }

    /// Aggregate thin-provisioning pressure across the whole set.
    pub fn total_pressure_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.pressure_bytes()).sum()
    }
}

/// One node's capacity / reclamation snapshot.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NodeStats {
    pub name: String,
    /// Physically stored bytes (everything, condemned included).
    pub used_bytes: u64,
    /// Bytes awaiting a GC sweep.
    pub condemned_bytes: u64,
    /// used - condemned: what thin provisioning counts.
    pub pressure_bytes: u64,
    /// Bytes returned by GC sweeps so far.
    pub reclaimed_bytes: u64,
    /// Files deleted by GC sweeps so far.
    pub gc_deletes: u64,
}

impl FileStore for NodeSet {
    fn create_file(&self, name: &str) -> Result<BackendRef> {
        let mut index = self.index.lock().unwrap();
        if index.contains_key(name) {
            bail!("file '{name}' already exists in the node set");
        }
        let node_idx = self.pick_node()?;
        let backend = self.nodes[node_idx].create_file(name)?;
        index.insert(name.to_string(), node_idx);
        Ok(backend)
    }

    fn open_file(&self, name: &str) -> Result<BackendRef> {
        let index = self.index.lock().unwrap();
        let &node_idx = index
            .get(name)
            .ok_or_else(|| anyhow!("no file '{name}' in the node set"))?;
        self.nodes[node_idx].open_file(name)
    }

    fn delete_file(&self, name: &str) -> Result<()> {
        let mut index = self.index.lock().unwrap();
        let node_idx = index
            .remove(name)
            .ok_or_else(|| anyhow!("no file '{name}' in the node set"))?;
        self.nodes[node_idx].delete_file(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::qcow::image::DataMode;
    use crate::qcow::{snapshot, Chain, Image};
    use crate::qcow::layout::{Geometry, FEATURE_BFI};

    fn set(caps: &[u64]) -> NodeSet {
        let clock = VirtClock::new();
        let nodes = caps
            .iter()
            .enumerate()
            .map(|(i, &cap)| {
                StorageNode::with_capacity(
                    &format!("node-{i}"),
                    clock.clone(),
                    CostModel::default(),
                    cap,
                )
            })
            .collect();
        NodeSet::new(nodes).unwrap()
    }

    #[test]
    fn balances_across_nodes() {
        let ns = set(&[u64::MAX, u64::MAX]);
        for i in 0..4 {
            let f = ns.create_file(&format!("f{i}")).unwrap();
            f.write_at(&[1u8; 64 << 10], 0).unwrap();
        }
        let usage = ns.usage();
        assert!(usage[0].1 > 0 && usage[1].1 > 0, "{usage:?}");
    }

    #[test]
    fn respects_capacity() {
        let ns = set(&[128 << 10, u64::MAX]);
        for i in 0..6 {
            let f = ns.create_file(&format!("f{i}")).unwrap();
            f.write_at(&[1u8; 64 << 10], 0).unwrap();
        }
        let usage = ns.usage();
        assert!(usage[0].1 <= 192 << 10, "node-0 overfilled: {usage:?}");
        assert!(usage[1].1 >= 256 << 10);
    }

    #[test]
    fn chain_spans_nodes_transparently() {
        let ns = set(&[256 << 10, u64::MAX]);
        let geom = Geometry::new(16, 16 << 20).unwrap();
        let b = ns.create_file("img-0").unwrap();
        let img =
            Image::create("img-0", b, geom, FEATURE_BFI, 0, None, DataMode::Real)
                .unwrap();
        let mut chain = Chain::new(std::sync::Arc::new(img)).unwrap();
        for i in 0..6 {
            snapshot::snapshot_sqemu(&mut chain, &ns, &format!("img-{}", i + 1))
                .unwrap();
        }
        // files landed on both nodes, chain still opens through the set
        let located: std::collections::HashSet<String> = (0..7)
            .map(|i| ns.locate(&format!("img-{i}")).unwrap())
            .collect();
        assert!(located.len() > 1, "all files on one node");
        let reopened = Chain::open(&ns, "img-6", DataMode::Real).unwrap();
        assert_eq!(reopened.len(), 7);
    }

    #[test]
    fn condemned_capacity_reopens_placement() {
        let ns = set(&[256 << 10, 256 << 10]);
        let f0 = ns.create_file("f0").unwrap(); // lands on node-0
        f0.write_at(&[1u8; 100 << 10], 0).unwrap();
        let f1 = ns.create_file("f1").unwrap(); // least-used: node-1
        f1.write_at(&[1u8; 40 << 10], 0).unwrap();
        // normally the next file would land on node-1 (40K < 100K); with
        // f0 condemned, node-0's pressure drops to zero and wins
        let n0 = ns.node_of("f0").unwrap();
        n0.mark_condemned("f0");
        let f = ns.create_file("f-new").unwrap();
        f.write_at(&[1u8; 8 << 10], 0).unwrap();
        assert_eq!(ns.locate("f-new").unwrap(), n0.name);
        let stats = ns.node_stats();
        let s0 = stats.iter().find(|s| s.name == n0.name).unwrap();
        assert_eq!(s0.condemned_bytes, 100 << 10);
        assert_eq!(s0.pressure_bytes, 8 << 10);
        assert_eq!(s0.used_bytes, (100 << 10) + (8 << 10));
    }

    #[test]
    fn open_missing_fails() {
        let ns = set(&[u64::MAX]);
        assert!(ns.open_file("nope").is_err());
        assert!(ns.delete_file("nope").is_err());
    }
}
