//! Submission/completion rings: the lock-free guest-I/O fast path.
//!
//! Each VM owns a fixed-capacity SQ/CQ ring pair (io_uring style). Guest
//! clients push [`SqEntry`]s into the submission ring tagged with a
//! monotonically increasing tag — no channel allocation, no blocking
//! round-trip — and reap [`CqEntry`]s from the completion ring whenever
//! they choose. The shard executor that owns the VM drains the SQ in
//! program order, executes against the driver, and pushes one completion
//! per submission; per-VM ordering is therefore exactly submission
//! order, and a `Flush` entry is a barrier by construction (everything
//! before it in the ring has completed when it runs).
//!
//! The rings are Vyukov bounded MPMC queues: per-slot sequence numbers
//! arbitrate producers and consumers without locks. The only lock on the
//! path is the completion *stash* — a rendezvous map clients move CQ
//! entries into so that many client threads can each wait for their own
//! tag (and where the executor parks completions if the CQ itself is
//! full, so the data plane never blocks on a slow reaper).

use crate::util::sync_shim::{
    yield_now, AtomicBool, AtomicU64, AtomicUsize, Ordering, UnsafeCell,
};
use crate::util::Notify;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::mem::MaybeUninit;
// The stash rendezvous stays on std primitives even under `--cfg loom`:
// loom models the lock-free Ring and the Notify doorbell; the stash is
// an ordinary mutex-protected map outside the modeled state space (and
// needs `Condvar::wait_timeout`, which loom does not provide).
use std::sync::{Arc, Condvar, Mutex, PoisonError};

/// One operation of a batched guest submission ([`super::VmClient::submit`]).
#[derive(Debug)]
pub enum BatchOp {
    Read { voff: u64, len: usize },
    Write { voff: u64, data: Vec<u8> },
}

/// Per-operation result of a batch, in submission order.
#[derive(Debug)]
pub enum BatchReply {
    Read(Vec<u8>),
    Write,
}

/// One submission-ring entry: a guest request plus its completion tag
/// and enqueue timestamp (virtual ns, for guest-visible latency).
#[derive(Debug)]
pub enum SqEntry {
    Read { tag: u64, voff: u64, len: usize, t_enq: u64 },
    Write { tag: u64, voff: u64, data: Vec<u8>, t_enq: u64 },
    Batch { tag: u64, ops: Vec<BatchOp>, t_enq: u64 },
    /// Durability barrier: completes only after every earlier entry in
    /// this ring has completed (guaranteed by in-order execution).
    Flush { tag: u64, t_enq: u64 },
}

impl SqEntry {
    pub fn tag(&self) -> u64 {
        match self {
            SqEntry::Read { tag, .. }
            | SqEntry::Write { tag, .. }
            | SqEntry::Batch { tag, .. }
            | SqEntry::Flush { tag, .. } => *tag,
        }
    }
}

/// The payload of a completion.
#[derive(Debug)]
pub enum RingReply {
    Read(Result<Vec<u8>>),
    Write(Result<()>),
    Batch(Result<Vec<BatchReply>>),
    Flush(Result<()>),
}

/// One completion-ring entry.
#[derive(Debug)]
pub struct CqEntry {
    pub tag: u64,
    pub reply: RingReply,
}

// ------------------------------------------------------------------
// The bounded lock-free MPMC ring (Dmitry Vyukov's algorithm): each
// slot carries a sequence number; a producer claims slot `pos` when
// `seq == pos`, a consumer when `seq == pos + 1`. CAS on head/tail
// arbitrates concurrent producers/consumers; the sequence store
// publishes the payload.
// ------------------------------------------------------------------

struct Slot<T> {
    seq: AtomicUsize,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// Fixed-capacity lock-free MPMC queue.
pub struct Ring<T> {
    buf: Box<[Slot<T>]>,
    mask: usize,
    /// enqueue position
    tail: AtomicUsize,
    /// dequeue position
    head: AtomicUsize,
}

// SAFETY: sending a Ring<T> between threads moves the T payloads with
// it; T: Send makes that sound, and no field holds thread-affine state
// (atomics and raw cells only).
unsafe impl<T: Send> Send for Ring<T> {}
// SAFETY: concurrent &Ring access is arbitrated by the per-slot
// sequence protocol — a value is written by exactly one producer (the
// tail CAS admits one claimant per position) and read by exactly one
// consumer (the head CAS likewise), with the slot's Release store /
// Acquire load pairing ordering payload access. No &T is ever shared
// across threads, so T: Send (not T: Sync) is the right bound.
unsafe impl<T: Send> Sync for Ring<T> {}

impl<T> Ring<T> {
    /// A ring holding at least `cap` entries (rounded up to a power of
    /// two, minimum 2).
    pub fn with_capacity(cap: usize) -> Ring<T> {
        let cap = cap.max(2).next_power_of_two();
        debug_assert!(cap.is_power_of_two(), "mask arithmetic needs 2^n");
        let buf: Vec<Slot<T>> = (0..cap)
            .map(|i| Slot {
                seq: AtomicUsize::new(i),
                val: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        Ring {
            buf: buf.into_boxed_slice(),
            mask: cap - 1,
            tail: AtomicUsize::new(0),
            head: AtomicUsize::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Approximate occupancy (exact when producers/consumers are quiet).
    pub fn len(&self) -> usize {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Relaxed);
        tail.wrapping_sub(head).min(self.capacity())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue; returns the value back when the ring is full.
    pub fn push(&self, v: T) -> std::result::Result<(), T> {
        let mut pos = self.tail.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - pos as isize;
            if dif == 0 {
                match self.tail.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // the CAS admitted exactly this producer for
                        // `pos`; no consumer touches the slot until the
                        // Release store below bumps seq past `pos`
                        debug_assert_eq!(
                            slot.seq.load(Ordering::Relaxed),
                            pos,
                            "claimed slot mutated by another thread"
                        );
                        // SAFETY: the tail CAS above made this thread
                        // the unique owner of slot `pos & mask` until
                        // the seq store publishes it; the slot is
                        // uninitialized (seq == pos means the previous
                        // payload was moved out or never existed), so
                        // writing MaybeUninit is sound and leaks
                        // nothing.
                        slot.val.with_mut(|p| unsafe { (*p).write(v) });
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        return Ok(());
                    }
                    Err(now) => pos = now,
                }
            } else if dif < 0 {
                return Err(v); // full
            } else {
                pos = self.tail.load(Ordering::Relaxed);
            }
        }
    }

    /// Dequeue; `None` when empty.
    pub fn pop(&self) -> Option<T> {
        let mut pos = self.head.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - pos.wrapping_add(1) as isize;
            if dif == 0 {
                match self.head.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        debug_assert_eq!(
                            slot.seq.load(Ordering::Relaxed),
                            pos.wrapping_add(1),
                            "popped slot not in published state"
                        );
                        // SAFETY: the head CAS made this thread the
                        // unique consumer of slot `pos & mask`; seq ==
                        // pos + 1 means the producer's Release store
                        // published a fully initialized value, and the
                        // Acquire load of seq above synchronizes with
                        // it. assume_init_read moves the value out
                        // exactly once — the seq store below re-marks
                        // the slot writable, so no double-read can
                        // follow.
                        let v = slot
                            .val
                            .with_mut(|p| unsafe { (*p).assume_init_read() });
                        slot.seq.store(
                            pos.wrapping_add(self.mask + 1),
                            Ordering::Release,
                        );
                        return Some(v);
                    }
                    Err(now) => pos = now,
                }
            } else if dif < 0 {
                return None; // empty
            } else {
                pos = self.head.load(Ordering::Relaxed);
            }
        }
    }
}

impl<T> Drop for Ring<T> {
    fn drop(&mut self) {
        // drain undelivered payloads so they are not leaked
        while self.pop().is_some() {}
    }
}

/// How long a completion waiter sleeps between rechecks if a wakeup is
/// ever missed (defense in depth — the executor wakes the stash
/// condvar after every burst, so this backstop should never be the
/// mechanism that makes progress).
const WAIT_BACKSTOP: std::time::Duration = std::time::Duration::from_millis(50);

/// The SQ/CQ ring pair of one VM, plus the completion rendezvous.
pub struct VmRings {
    sq: Ring<SqEntry>,
    cq: Ring<CqEntry>,
    next_tag: AtomicU64,
    /// Completions moved out of the CQ (by reapers looking for another
    /// tag, or by the executor when the CQ is full), keyed by tag.
    stash: Mutex<HashMap<u64, RingReply>>,
    reap_cv: Condvar,
    /// Set when the owning executor drops this VM (stop or panic):
    /// submitters and waiters error with "vm worker gone".
    dead: AtomicBool,
    /// Doorbell of the shard executor owning this VM.
    doorbell: Arc<Notify>,
    /// Submission stalls on a full SQ (backpressure episodes).
    pub backpressure: AtomicU64,
}

impl VmRings {
    pub fn new(depth: usize, doorbell: Arc<Notify>) -> Arc<VmRings> {
        Arc::new(VmRings {
            sq: Ring::with_capacity(depth),
            cq: Ring::with_capacity(depth),
            next_tag: AtomicU64::new(1),
            stash: Mutex::new(HashMap::new()),
            reap_cv: Condvar::new(),
            dead: AtomicBool::new(false),
            doorbell,
            backpressure: AtomicU64::new(0),
        })
    }

    pub fn next_tag(&self) -> u64 {
        self.next_tag.fetch_add(1, Ordering::Relaxed)
    }

    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::Acquire)
    }

    /// The owning executor is gone: fail pending and future waiters.
    pub fn mark_dead(&self) {
        self.dead.store(true, Ordering::Release);
        // serialize with waiters so none parks after missing the flag
        let _g = self.stash.lock().unwrap_or_else(PoisonError::into_inner);
        self.reap_cv.notify_all();
        self.doorbell.notify();
    }

    /// Current submission-queue occupancy (ring observability).
    pub fn sq_len(&self) -> usize {
        self.sq.len()
    }

    pub fn sq_capacity(&self) -> usize {
        self.sq.capacity()
    }

    /// Enqueue a submission, blocking while the SQ is full (the bounded
    /// queue IS the backpressure mechanism, exactly like the old
    /// `sync_channel`). Errors if the VM's executor is gone.
    pub fn submit(&self, entry: SqEntry) -> Result<()> {
        let mut entry = entry;
        let mut stalled = false;
        loop {
            if self.is_dead() {
                bail!("vm worker gone");
            }
            match self.sq.push(entry) {
                Ok(()) => {
                    self.doorbell.notify();
                    return Ok(());
                }
                Err(back) => {
                    if !stalled {
                        stalled = true;
                        self.backpressure.fetch_add(1, Ordering::Relaxed);
                        // the consumer may be parked on a stale "empty"
                        // observation — ring once per stall episode
                        self.doorbell.notify();
                    }
                    entry = back;
                    yield_now();
                }
            }
        }
    }

    /// Executor side: next submission in program order.
    pub fn pop_sq(&self) -> Option<SqEntry> {
        self.sq.pop()
    }

    /// Executor side: deliver a completion. Never blocks — a full CQ
    /// overflows into the stash (the reaper finds it either way).
    pub fn complete(&self, tag: u64, reply: RingReply) {
        if let Err(e) = self.cq.push(CqEntry { tag, reply }) {
            let mut stash =
                self.stash.lock().unwrap_or_else(PoisonError::into_inner);
            stash.insert(tag, e.reply);
        }
    }

    /// Executor side: wake reapers after a burst of completions. Locks
    /// the stash mutex so a reaper that just found nothing is either
    /// still holding the lock (and will see the CQ entries on its next
    /// drain) or already parked (and is woken here).
    pub fn wake_reapers(&self) {
        let _g = self.stash.lock().unwrap_or_else(PoisonError::into_inner);
        self.reap_cv.notify_all();
    }

    /// Reap the completion for `tag` without blocking. `Ok(None)` means
    /// still in flight.
    pub fn try_wait(&self, tag: u64) -> Result<Option<RingReply>> {
        let mut stash =
            self.stash.lock().unwrap_or_else(PoisonError::into_inner);
        Self::drain_cq(&self.cq, &mut stash);
        if let Some(r) = stash.remove(&tag) {
            return Ok(Some(r));
        }
        if self.is_dead() {
            return Err(anyhow!("vm worker gone"));
        }
        Ok(None)
    }

    /// Block until the completion for `tag` arrives.
    pub fn wait(&self, tag: u64) -> Result<RingReply> {
        let mut stash =
            self.stash.lock().unwrap_or_else(PoisonError::into_inner);
        loop {
            Self::drain_cq(&self.cq, &mut stash);
            if let Some(r) = stash.remove(&tag) {
                return Ok(r);
            }
            if self.is_dead() {
                // one final drain happened above; the completion will
                // never arrive now
                bail!("vm worker gone");
            }
            let (g, _t) = self
                .reap_cv
                .wait_timeout(stash, WAIT_BACKSTOP)
                .unwrap_or_else(PoisonError::into_inner);
            stash = g;
        }
    }

    fn drain_cq(cq: &Ring<CqEntry>, stash: &mut HashMap<u64, RingReply>) {
        while let Some(e) = cq.pop() {
            stash.insert(e.tag, e.reply);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_fifo_and_capacity() {
        let r: Ring<u32> = Ring::with_capacity(4);
        assert_eq!(r.capacity(), 4);
        assert!(r.is_empty());
        for i in 0..4 {
            r.push(i).unwrap();
        }
        assert_eq!(r.push(99), Err(99), "full ring rejects");
        assert_eq!(r.len(), 4);
        for i in 0..4 {
            assert_eq!(r.pop(), Some(i));
        }
        assert_eq!(r.pop(), None);
        // reusable after wraparound
        for round in 0..10u32 {
            r.push(round).unwrap();
            assert_eq!(r.pop(), Some(round));
        }
    }

    #[test]
    fn ring_capacity_rounds_up() {
        let r: Ring<u8> = Ring::with_capacity(5);
        assert_eq!(r.capacity(), 8);
        let r: Ring<u8> = Ring::with_capacity(0);
        assert_eq!(r.capacity(), 2);
    }

    #[test]
    fn ring_drop_releases_undelivered() {
        // would leak (or double-free on a bug) under miri/asan; here we
        // just exercise the path
        let r: Ring<Vec<u8>> = Ring::with_capacity(4);
        r.push(vec![1, 2, 3]).unwrap();
        r.push(vec![4]).unwrap();
        drop(r);
    }

    #[test]
    fn ring_mpmc_under_contention() {
        let r: Arc<Ring<u64>> = Arc::new(Ring::with_capacity(64));
        // miri interprets every yield: keep the interleaving pressure,
        // shrink the volume
        const PER: u64 = if cfg!(miri) { 200 } else { 10_000 };
        let producers: Vec<_> = (0..4u64)
            .map(|p| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    for i in 0..PER {
                        let mut v = p * PER + i;
                        loop {
                            match r.push(v) {
                                Ok(()) => break,
                                Err(b) => {
                                    v = b;
                                    std::thread::yield_now();
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    let mut idle = 0u32;
                    let idle_max = if cfg!(miri) { 2_000 } else { 20_000 };
                    while idle < idle_max {
                        match r.pop() {
                            Some(v) => {
                                got.push(v);
                                idle = 0;
                            }
                            None => {
                                idle += 1;
                                std::thread::yield_now();
                            }
                        }
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<u64> = Vec::new();
        for c in consumers {
            all.extend(c.join().unwrap());
        }
        all.sort_unstable();
        let expect: Vec<u64> = (0..4 * PER).collect();
        assert_eq!(all, expect, "every value delivered exactly once");
    }

    #[test]
    fn vmrings_roundtrip_and_stash_rendezvous() {
        let doorbell = Arc::new(Notify::new());
        let r = VmRings::new(8, doorbell);
        let t1 = r.next_tag();
        let t2 = r.next_tag();
        assert_ne!(t1, t2);
        // complete out of order; each waiter still gets its own tag
        r.complete(t2, RingReply::Write(Ok(())));
        r.complete(t1, RingReply::Read(Ok(vec![7u8])));
        r.wake_reapers();
        match r.wait(t1).unwrap() {
            RingReply::Read(Ok(b)) => assert_eq!(b, vec![7u8]),
            other => panic!("wrong reply: {other:?}"),
        }
        match r.wait(t2).unwrap() {
            RingReply::Write(Ok(())) => {}
            other => panic!("wrong reply: {other:?}"),
        }
    }

    #[test]
    fn vmrings_cq_overflow_lands_in_stash() {
        let doorbell = Arc::new(Notify::new());
        let r = VmRings::new(2, doorbell);
        let tags: Vec<u64> = (0..10).map(|_| r.next_tag()).collect();
        for &t in &tags {
            r.complete(t, RingReply::Flush(Ok(())));
        }
        r.wake_reapers();
        for &t in &tags {
            assert!(r.try_wait(t).unwrap().is_some(), "tag {t} delivered");
        }
    }

    #[test]
    fn vmrings_dead_fails_waiters_and_submitters() {
        let doorbell = Arc::new(Notify::new());
        let r = VmRings::new(4, Arc::clone(&doorbell));
        let tag = r.next_tag();
        let r2 = Arc::clone(&r);
        let h = std::thread::spawn(move || r2.wait(tag));
        std::thread::sleep(std::time::Duration::from_millis(10));
        r.mark_dead();
        assert!(h.join().unwrap().is_err(), "waiter unblocked with error");
        let e = SqEntry::Flush { tag: r.next_tag(), t_enq: 0 };
        assert!(r.submit(e).is_err(), "dead rings refuse submissions");
    }

    #[test]
    fn vmrings_submit_rings_the_doorbell() {
        let doorbell = Arc::new(Notify::new());
        let r = VmRings::new(4, Arc::clone(&doorbell));
        r.submit(SqEntry::Flush { tag: r.next_tag(), t_enq: 0 }).unwrap();
        assert!(
            doorbell.wait_timeout(std::time::Duration::from_millis(100)),
            "submission woke the shard"
        );
        assert_eq!(r.sq_len(), 1);
        assert!(r.pop_sq().is_some());
    }
}

// Model checks: every interleaving of the ring's atomics, run by the CI
// loom job (`RUSTFLAGS="--cfg loom" cargo test --lib --release loom_`).
// Kept deliberately small — loom explores the full state space, so one
// push per producer already covers the claim/publish races.
#[cfg(all(test, loom))]
mod loom_tests {
    use super::*;
    use loom::thread;

    /// A flush entry pushed after a write must never be popped first:
    /// in-ring order IS the flush barrier (module docs), so FIFO under
    /// every interleaving is the property the data plane relies on.
    #[test]
    fn loom_ring_spsc_fifo_is_the_flush_barrier() {
        loom::model(|| {
            let r: Arc<Ring<u32>> = Arc::new(Ring::with_capacity(2));
            let p = Arc::clone(&r);
            let t = thread::spawn(move || {
                p.push(1).unwrap(); // the guest write
                p.push(2).unwrap(); // the flush barrier
            });
            let mut got = Vec::new();
            while got.len() < 2 {
                match r.pop() {
                    Some(v) => got.push(v),
                    None => thread::yield_now(),
                }
            }
            assert_eq!(got, [1, 2], "flush reordered past its write");
            assert_eq!(r.pop(), None, "ring drained");
            t.join().unwrap();
        });
    }

    /// Two producers race for slots; every value is delivered exactly
    /// once (no lost or duplicated payloads under any interleaving of
    /// the tail CAS and the seq publish stores).
    #[test]
    fn loom_ring_mpmc_exactly_once() {
        loom::model(|| {
            let r: Arc<Ring<usize>> = Arc::new(Ring::with_capacity(2));
            let a = Arc::clone(&r);
            let b = Arc::clone(&r);
            let ta = thread::spawn(move || a.push(1).unwrap());
            let tb = thread::spawn(move || b.push(2).unwrap());
            let mut got = Vec::new();
            while got.len() < 2 {
                match r.pop() {
                    Some(v) => got.push(v),
                    None => thread::yield_now(),
                }
            }
            ta.join().unwrap();
            tb.join().unwrap();
            got.sort_unstable();
            assert_eq!(got, [1, 2], "each push delivered exactly once");
        });
    }

    /// Full/empty edges stay exact under wraparound: a full ring
    /// rejects (returning the value), an emptied ring yields None, and
    /// the slot sequence arithmetic survives reuse.
    #[test]
    fn loom_ring_full_empty_edges() {
        loom::model(|| {
            let r: Ring<u8> = Ring::with_capacity(2);
            r.push(1).unwrap();
            r.push(2).unwrap();
            assert_eq!(r.push(3), Err(3), "full ring returns the value");
            assert_eq!(r.pop(), Some(1));
            r.push(4).unwrap(); // reused slot after wraparound
            assert_eq!(r.pop(), Some(2));
            assert_eq!(r.pop(), Some(4));
            assert_eq!(r.pop(), None, "empty ring yields None");
        });
    }
}
