//! The coordinator server: VM fleet management over a storage-node set.
//!
//! Architecture (sharded data plane — PR 7; previously one thread per
//! VM):
//!
//! ```text
//!  clients ──► VmClient ──► SQ ring ─┐            shard executor 0
//!              (lock-free,           ├─► owns VMs {a, d, ...}:
//!               tag-based            │   drains SQs in bursts,
//!               completions          │   drives block jobs, advances
//!               via CQ ring)         │   the virtual clock when idle
//!                                    │       │
//!  clients ──► VmClient ──► SQ ring ─┘       ▼ per-node I/O scheduler
//!                                        merge window batches extents
//!  shard executor 1 owns {b, c, ...}     ACROSS VMs before the Timed
//!     (VM → shard by name hash)          cost model bills seeks
//!
//!  control plane: launch / snapshot / stream / stop, bulk translation,
//!  live block jobs (admission via the per-node JobScheduler) — all over
//!  per-shard control channels, never through the rings
//! ```
//!
//! Each VM still has exactly one owner (its shard executor), so drivers
//! stay single-owner like a Qemu process; what changed is that N shards
//! serve the whole fleet instead of one thread per VM. Guest submissions
//! flow through per-VM SQ/CQ ring pairs ([`super::ring`]); per-VM
//! program order is preserved (the executor drains each SQ in order),
//! and results are bit-identical to the sequential path. Fleet state is
//! sharded too: per-shard VM tables and job ledgers, an atomic job-id
//! counter, and per-shard stats accumulators drained once per serving
//! pass instead of per-request atomics.
//!
//! Live jobs and guest requests interleave on the shard: every serving
//! pass gives each runnable job one bounded increment, and while a shard
//! is otherwise idle it drains jobs continuously (advancing the virtual
//! clock across rate-limiter stalls). Guest requests always preempt the
//! next increment, so the guest-visible latency tail is bounded by one
//! increment — the contrast with the offline [`Coordinator::stream_vm`]
//! pause is the subject of `benches/fig20_live_blockjobs.rs`.

use super::batcher::BulkTranslator;
use super::placement::{NodeSet, PlacementEvent};
use super::ring::{RingReply, SqEntry, VmRings};
use super::shard::{Shard, ShardControl, ShardHandle, ShardStatsSnapshot};
use super::stats::{VmStats, VmStatsSnapshot};
use super::streaming::{StreamReport, StreamingOrchestrator};
use crate::blockjob::scheduler::{JobScheduler, Reservation};
use crate::blockjob::{
    BlockJob, JobKind, JobRunner, JobShared, JobStatus, LiveStampJob,
    LiveStreamJob, Step,
};
use crate::cache::CacheConfig;
use crate::chaingen::ChainSpec;
use crate::control::{
    partition_leases, ControlRecord, FleetView, StateStore, StoreStatus,
};
use crate::gc::{GcEvent, GcJob, GcRegistry, GcReport};
use crate::metrics::clock::{CostModel, VirtClock};
use crate::metrics::counters::CounterSnapshot;
use crate::metrics::memory::MemoryAccountant;
use crate::dedup::{
    chain_logical_bytes, CapacityPolicy, CapacityScanJob, DedupIndex,
};
use crate::util::retry::RetryPolicy;
use crate::qcow::image::DataMode;
use crate::qcow::{qcheck, snapshot, Chain};
use crate::migrate::rebalance::{NodePressure, RebalancePlan, VmFootprint};
use crate::runtime::service::RuntimeService;
use crate::storage::node::StorageNode;
use crate::util::lock_unpoisoned;
use crate::vdisk::scalable::ScalableDriver;
use crate::vdisk::vanilla::VanillaDriver;
use crate::vdisk::{Driver, DriverKind};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::atomic::AtomicU64;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Mutex};

pub use super::ring::{BatchOp, BatchReply};
pub(crate) use super::shard::JobBuilder;

/// Fleet-level configuration.
#[derive(Clone)]
pub struct CoordinatorConfig {
    pub cost: CostModel,
    /// Per-VM submission/completion ring depth (backpressure bound: a
    /// full SQ blocks the submitter).
    pub queue_depth: usize,
    /// Shard executors serving the fleet (VM → shard by name hash).
    /// 0 = auto: one per available core, capped at 8.
    pub shards: usize,
    /// Aggregate background-job bandwidth budget per storage node
    /// (bytes/second) — the admission ceiling of the [`JobScheduler`].
    pub job_budget_bps: u64,
    /// Clusters a job may process per increment (the guest's worst-case
    /// wait behind one job step).
    pub job_increment_clusters: u64,
    /// Enable the capacity subsystem fleet-wide: every launched driver
    /// gets zero detection, compression and content-addressed dedup
    /// through the coordinator's shared [`DedupIndex`]
    /// ([`crate::dedup::CapacityPolicy::full`]). Off by default — the
    /// write path is then bit-for-bit the pre-subsystem one.
    pub capacity: bool,
    /// Lease TTL for VM ownership when a control plane is attached
    /// ([`Coordinator::attach_control`]): a coordinator owns each of
    /// its VMs for this long past the last acquire/renew, and a standby
    /// must wait out the remainder before re-adopting
    /// ([`Coordinator::takeover`]).
    pub lease_ttl_ns: u64,
    /// Span-trace sampling: every Nth launched VM carries a
    /// [`crate::telemetry::TraceBuf`] and records request→shard→node hop
    /// timestamps into the coordinator's trace ring. 0 disables tracing
    /// (the default); 1 traces every VM.
    pub trace_sample: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            cost: CostModel::default(),
            queue_depth: 64,
            shards: 0,
            job_budget_bps: 512 << 20,
            job_increment_clusters: 32,
            capacity: false,
            lease_ttl_ns: 30_000_000_000,
            trace_sample: 0,
        }
    }
}

/// Per-VM launch configuration.
#[derive(Clone, Debug)]
pub struct VmConfig {
    pub driver: DriverKind,
    pub cache: CacheConfig,
    /// Open an existing chain by active-volume name, or generate one.
    pub chain: VmChain,
}

#[derive(Clone, Debug)]
pub enum VmChain {
    Existing { active_name: String, data_mode: DataMode },
    Generate(ChainSpec),
}

/// Parameters of a live block job (`sqemu job start`).
#[derive(Clone, Copy, Debug)]
pub struct JobSpec {
    pub kind: JobKind,
    /// Bytes/second of job I/O; 0 = unlimited (reserves the node's whole
    /// maintenance budget at admission).
    pub rate_bps: u64,
    /// Create the job paused; it holds its bandwidth reservation but
    /// runs no increments until [`Coordinator::resume_job`].
    pub start_paused: bool,
}

impl JobSpec {
    pub fn stream(rate_bps: u64) -> JobSpec {
        JobSpec { kind: JobKind::Stream, rate_bps, start_paused: false }
    }

    pub fn stamp(rate_bps: u64) -> JobSpec {
        JobSpec { kind: JobKind::Stamp, rate_bps, start_paused: false }
    }

    pub fn paused(mut self) -> JobSpec {
        self.start_paused = true;
        self
    }
}

/// Outcome of [`Coordinator::recover`]: the crash-recovery sweep a node
/// runs over its images before admitting guest I/O.
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    /// Image files found and checked.
    pub images_checked: u64,
    /// Images `qcheck --repair` had to change.
    pub images_repaired: u64,
    /// Chain heads walked for cross-file validation.
    pub chains_checked: u64,
    /// Chains that needed a chain-level repair pass.
    pub chains_repaired: u64,
    /// Interrupted migrations resolved target-authoritative (journal
    /// committed: superseded source copies deleted).
    pub migrations_committed: u64,
    /// Interrupted migrations rolled back source-authoritative (no
    /// commit record: partial target copies deleted).
    pub migrations_rolled_back: u64,
    /// File names still present on more than one node after migration
    /// resolution — should be empty; survivors indicate corruption.
    pub duplicate_files: Vec<String>,
    /// Files that would not open/repair (orphans of interrupted creates,
    /// foreign files) with the reason — GC's business, not a hard error.
    pub unopenable: Vec<String>,
}

/// Outcome of [`Coordinator::rebalance`].
#[derive(Clone, Debug)]
pub struct RebalanceReport {
    /// The planner's verdict (moves + before/projected ratios).
    pub plan: RebalancePlan,
    /// Moves actually executed (0 on a dry run).
    pub executed: usize,
    /// Fleet max/min committed-pressure ratio after execution (equals
    /// the pre-plan ratio on a dry run).
    pub final_ratio: f64,
}

/// Registry entry for one VM: which shard owns it, plus everything the
/// control plane may need without a round-trip to that shard.
struct VmMeta {
    shard: usize,
    rings: Arc<VmRings>,
    stats: Arc<VmStats>,
    driver_kind: DriverKind,
    cache: CacheConfig,
    data_mode: DataMode,
    /// Chain head at launch / last chain-shape change — what the durable
    /// VM record tells a failed-over coordinator to reopen.
    active: String,
}

/// Registry entry for a job: its cross-thread handle plus whatever must
/// be given back once the job is terminal — bandwidth reservations
/// (migrations hold one per involved node) and, for migrations, the
/// capacity reservation on the recipient.
struct JobEntry {
    vm: String,
    shared: Arc<JobShared>,
    reservations: Vec<Reservation>,
    capacity: Option<(Arc<StorageNode>, u64)>,
    /// Terminal state already written to the control log (the reap runs
    /// on every job API call; `JobEnd` must go out exactly once).
    ended: bool,
}

/// This coordinator's attachment to the shared [`StateStore`]: the
/// store handle, the epoch its fenced appends run under, and the
/// identity its leases are held as. `epoch` starts at 0 — which passes
/// the store's fence only while no election has ever happened (the
/// single-coordinator case) — and moves only through
/// [`Coordinator::campaign`], so a deposed leader keeps its stale epoch
/// and every fenced write it attempts is rejected.
struct ControlHandle {
    store: Arc<StateStore>,
    epoch: u64,
    who: String,
}

/// FNV-1a: the VM → shard map. Stateless, so any component can compute
/// an owner from a name alone; uniform enough that fleet-scale runs
/// spread evenly (the fig25 bench asserts shard balance indirectly via
/// utilization).
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The coordinator: owns nodes, shard executors, the sharded VM/job
/// registries, the AOT runtime and the GC reference registry.
pub struct Coordinator {
    pub nodes: Arc<NodeSet>,
    pub clock: Arc<VirtClock>,
    pub acct: Arc<MemoryAccountant>,
    cfg: CoordinatorConfig,
    runtime: Option<RuntimeService>,
    /// The executor pool. Index = shard id; a VM's owner is
    /// `fnv1a(name) % shards.len()`.
    shards: Vec<Shard>,
    /// Per-shard VM tables: the only map a launch/lookup touches is the
    /// owner shard's, so fleet-wide launches don't serialize on one lock.
    vms: Vec<Mutex<HashMap<String, VmMeta>>>,
    scheduler: JobScheduler,
    /// Per-shard job ledgers (a job lives in its VM's shard; GC sweeps
    /// land wherever "(gc)" hashes).
    jobs: Vec<Mutex<Vec<JobEntry>>>,
    next_job_id: AtomicU64,
    gc: Arc<GcRegistry>,
    /// Fleet-wide content-addressed extent index (volatile accelerator;
    /// see [`crate::dedup::DedupIndex`]). Always present — drivers only
    /// consult it when [`CoordinatorConfig::capacity`] is on.
    dedup: Arc<DedupIndex>,
    /// HA control plane, when attached: write-ahead state log, lease
    /// table and epoch fence ([`Coordinator::attach_control`]).
    control: Mutex<Option<ControlHandle>>,
    /// The fleet metrics registry ([`crate::telemetry`]): every
    /// subsystem's collector is registered at construction; `sqemu
    /// metrics` and the serve scrape hook render it.
    telemetry: Arc<crate::telemetry::Registry>,
    /// Shared span-event ring for trace-sampled VMs.
    trace: Arc<crate::telemetry::TraceRing>,
    /// Launches seen, for the every-Nth trace-sampling decision.
    trace_seq: AtomicU64,
}

impl Coordinator {
    pub fn new(
        nodes: Arc<NodeSet>,
        clock: Arc<VirtClock>,
        cfg: CoordinatorConfig,
        runtime: Option<RuntimeService>,
    ) -> Arc<Coordinator> {
        let scheduler = JobScheduler::new(cfg.job_budget_bps);
        let gc = Arc::new(GcRegistry::new(Arc::clone(&nodes)));
        let n_shards = if cfg.shards > 0 {
            cfg.shards
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .clamp(1, 8)
        };
        let scheds: Vec<_> = nodes
            .nodes()
            .iter()
            .map(|n| Arc::clone(n.scheduler()))
            .collect();
        let shards = (0..n_shards)
            .map(|i| {
                Shard::spawn(
                    i,
                    Arc::clone(&clock),
                    Arc::clone(&gc),
                    scheds.clone(),
                )
            })
            .collect();
        let telemetry = crate::telemetry::Registry::new(Arc::clone(&clock));
        let coord = Arc::new(Coordinator {
            nodes,
            clock,
            acct: MemoryAccountant::new(),
            cfg,
            runtime,
            shards,
            vms: (0..n_shards).map(|_| Mutex::new(HashMap::new())).collect(),
            scheduler,
            jobs: (0..n_shards).map(|_| Mutex::new(Vec::new())).collect(),
            next_job_id: AtomicU64::new(0),
            gc,
            dedup: Arc::new(DedupIndex::new()),
            control: Mutex::new(None),
            telemetry,
            trace: crate::telemetry::TraceRing::new(65_536),
            trace_seq: AtomicU64::new(0),
        });
        // collectors hold Weak<Coordinator> / subsystem Arcs, so this
        // registration after Arc::new creates no cycle
        crate::telemetry::fleet::register_fleet(&coord);
        coord
    }

    /// The fleet metrics registry (`sqemu metrics` renders it).
    pub fn telemetry(&self) -> &Arc<crate::telemetry::Registry> {
        &self.telemetry
    }

    /// The shared span-trace ring (`--trace FILE` dumps it).
    pub fn trace_ring(&self) -> &Arc<crate::telemetry::TraceRing> {
        &self.trace
    }

    /// Every VM's shared stats handle, without a shard barrier — the
    /// telemetry scrape path (a scrape may lag in-flight deltas by one
    /// reaper flush, which a monotone exporter can't observe).
    pub(crate) fn vm_stat_handles(&self) -> Vec<(String, Arc<VmStats>)> {
        let mut out: Vec<(String, Arc<VmStats>)> = self
            .vms
            .iter()
            .flat_map(|t| {
                lock_unpoisoned(t)
                    .iter()
                    .map(|(name, m)| (name.clone(), Arc::clone(&m.stats)))
                    .collect::<Vec<_>>()
            })
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// The fleet dedup index (`sqemu dedup status` reads it).
    pub fn dedup_index(&self) -> &Arc<DedupIndex> {
        &self.dedup
    }

    /// Convenience: a coordinator over `n` fresh unlimited nodes.
    pub fn with_fresh_nodes(n: usize) -> Result<Arc<Coordinator>> {
        let clock = VirtClock::new();
        let nodes = (0..n)
            .map(|i| {
                crate::storage::node::StorageNode::new(
                    &format!("node-{i}"),
                    clock.clone(),
                    CostModel::default(),
                )
            })
            .collect();
        let runtime = RuntimeService::try_default();
        Ok(Coordinator::new(
            Arc::new(NodeSet::new(nodes)?),
            clock,
            CoordinatorConfig::default(),
            runtime,
        ))
    }

    pub fn translator(&self) -> BulkTranslator {
        BulkTranslator::new(self.runtime.clone())
    }

    pub fn streaming(&self) -> StreamingOrchestrator {
        StreamingOrchestrator::new(self.runtime.clone())
    }

    /// Which shard owns (or would own) the named VM.
    fn shard_of(&self, name: &str) -> usize {
        (fnv1a(name) % self.shards.len() as u64) as usize
    }

    /// Read a field of one VM's registry entry under its shard's lock.
    fn meta<T>(
        &self,
        name: &str,
        f: impl FnOnce(&VmMeta) -> T,
    ) -> Result<T> {
        let map = lock_unpoisoned(&self.vms[self.shard_of(name)]);
        map.get(name).map(f).ok_or_else(|| anyhow!("no vm '{name}'"))
    }

    /// Executor-pool observability: per-shard VM count, live SQ
    /// occupancy, served submissions, passes and park wakeups (the
    /// `sqemu node status` shard table, `sqemu serve` ring stats).
    pub fn shard_stats(&self) -> Vec<ShardStatsSnapshot> {
        self.shards
            .iter()
            .map(|s| {
                let mut snap = s.stats.snapshot(s.index);
                // occupancy from the registry rings is live; the
                // executor's own copy refreshes only at pass end
                let map = lock_unpoisoned(&self.vms[s.index]);
                snap.vms = map.len() as u64;
                snap.queued =
                    map.values().map(|m| m.rings.sq_len() as u64).sum();
                snap
            })
            .collect()
    }

    fn build_driver(
        &self,
        chain: Chain,
        cfg: &VmConfig,
    ) -> Box<dyn Driver + Send> {
        // the dedup context is pinned to the node holding the active
        // volume at launch; a later migration leaves old extents keyed
        // under the old node (a missed-sharing cost, never a corruption
        // — sharing re-verifies the extent file against the chain)
        let policy = if self.cfg.capacity {
            let node = self
                .nodes
                .locate(&chain.active().name)
                .unwrap_or_default();
            // warm the index with the chain's immutable backing extents
            // so clones over a shared golden base dedup against it from
            // their first write; best-effort — an unreadable backing
            // file only costs sharing, and qcheck already gated on it
            let _ = crate::dedup::seed_chain(&self.dedup, &node, &chain);
            Some(CapacityPolicy::full(Arc::clone(&self.dedup), &node))
        } else {
            None
        };
        let mut driver: Box<dyn Driver + Send> = match cfg.driver {
            DriverKind::Vanilla => Box::new(VanillaDriver::new(
                chain,
                cfg.cache,
                self.clock.clone(),
                self.cfg.cost,
                self.acct.clone(),
            )),
            DriverKind::Scalable => Box::new(ScalableDriver::new(
                chain,
                cfg.cache,
                self.clock.clone(),
                self.cfg.cost,
                self.acct.clone(),
            )),
        };
        if let Some(p) = policy {
            driver.set_capacity_policy(p);
        }
        driver
    }

    /// Launch a VM: open/generate its chain, hand the driver to the
    /// owning shard executor, and register the rings.
    ///
    /// The registry is NOT held while the chain is opened or generated:
    /// chain construction is heavy and fallible, and holding the map
    /// across it both serialized launches and (worse) poisoned a whole
    /// shard's table if construction panicked — one bad launch killed
    /// stats/list/launch for every sibling VM.
    ///
    /// With a control plane attached, ownership is lease-based: the
    /// lease on `name` is acquired (fenced) *before* any chain work, so
    /// two coordinators over the same nodes can never both adopt a VM —
    /// the loser fails here, not after corrupting the chain. The launch
    /// error path gives the lease back.
    pub fn launch_vm(self: &Arc<Self>, name: &str, cfg: VmConfig) -> Result<VmClient> {
        let leased = match self.control_parts() {
            Some((store, epoch, who)) => {
                store.acquire_lease(epoch, name, &who, self.cfg.lease_ttl_ns)?;
                true
            }
            None => false,
        };
        match self.launch_vm_inner(name, cfg) {
            Ok(client) => Ok(client),
            Err(e) => {
                if leased {
                    if let Some((store, epoch, who)) = self.control_parts() {
                        let _ = store.release_lease(epoch, name, &who);
                    }
                }
                Err(e)
            }
        }
    }

    fn launch_vm_inner(
        self: &Arc<Self>,
        name: &str,
        cfg: VmConfig,
    ) -> Result<VmClient> {
        let shard = self.shard_of(name);
        if lock_unpoisoned(&self.vms[shard]).contains_key(name) {
            bail!("vm '{name}' already running");
        }
        let (chain, data_mode) = match &cfg.chain {
            VmChain::Existing { active_name, data_mode } => {
                let chain =
                    Chain::open(self.nodes.as_ref(), active_name, *data_mode)?;
                // Recovery gate: a pre-existing Real chain may be the
                // survivor of a crash — it must pass (or be repaired to
                // pass) qcheck before guest I/O is admitted. Leaks count
                // too: a crash in the sanctioned refcount-before-
                // reference window leaves a leak-only chain (is_clean()
                // but leaked > 0) that only repair ever reclaims.
                // Synthetic chains are simulation fixtures, not crash
                // survivors — skip the walk (it would also charge the
                // shared node clock before the benchmark starts).
                if *data_mode == DataMode::Real {
                    let report = qcheck::check_chain(&chain)?;
                    if !report.is_clean() || report.leaked_clusters != 0 {
                        // repair mutates image files in place; a file
                        // shared with a *running* chain (GC refcount
                        // held by another VM) must not be rewritten
                        // under concurrent readers — that needs the
                        // quiesced startup pass instead
                        if chain.file_names().iter().any(|f| self.gc.refcount(f) > 0)
                        {
                            bail!(
                                "chain '{active_name}' needs repair but shares \
                                 files with running chains; quiesce the fleet \
                                 and run Coordinator::recover()"
                            );
                        }
                        qcheck::repair_chain(&chain)?;
                        let after = qcheck::check_chain(&chain)?;
                        if !after.is_clean() || after.leaked_clusters != 0 {
                            bail!(
                                "chain '{active_name}' unrecoverable: {} leaks, {}",
                                after.leaked_clusters,
                                after.errors.join("; ")
                            );
                        }
                    }
                }
                (chain, *data_mode)
            }
            VmChain::Generate(spec) => (
                crate::chaingen::generate(self.nodes.as_ref(), spec)?,
                spec.data_mode,
            ),
        };
        let active = chain.active().name.clone();
        let stats = Arc::new(VmStats::default());
        let rings = VmRings::new(
            self.cfg.queue_depth,
            Arc::clone(&self.shards[shard].notify),
        );
        {
            let mut vms = lock_unpoisoned(&self.vms[shard]);
            if vms.contains_key(name) {
                bail!("vm '{name}' already running");
            }
            // the chain's files are now referenced by this VM's chain (GC
            // refcounts; shared bases gain one reference per chain)
            self.gc.sync_chain(name, chain.file_names());
            // lint: mutates(vm-record)
            vms.insert(
                name.to_string(),
                VmMeta {
                    shard,
                    rings: Arc::clone(&rings),
                    stats: Arc::clone(&stats),
                    driver_kind: cfg.driver,
                    cache: cfg.cache,
                    data_mode,
                    active: active.clone(),
                },
            );
        }
        // durable VM record, write-ahead of adoption (fenced: a deposed
        // leader's launch dies here, before the shard takes the driver)
        // lint: durable-rollback(vm-record)
        if let Err(e) = self.persist(&ControlRecord::Vm {
            name: name.to_string(),
            driver: cfg.driver,
            slice_entries: cfg.cache.slice_entries,
            max_bytes: cfg.cache.max_bytes,
            data_mode,
            active,
        }) {
            // lint: rolls-back(vm-record)
            lock_unpoisoned(&self.vms[shard]).remove(name);
            self.gc.drop_chain(name);
            return Err(e);
        }
        let driver = self.build_driver(chain, &cfg);
        // every-Nth sampling decision is made here, at launch: the slot
        // either carries a TraceBuf for its whole life or never pays
        // more than one is_some() branch per request
        let seq = self.trace_seq.fetch_add(1, Relaxed);
        let trace = if self.cfg.trace_sample > 0 && seq % self.cfg.trace_sample == 0
        {
            Some(crate::telemetry::TraceBuf::new(name, Arc::clone(&self.trace)))
        } else {
            None
        };
        let (reply, rx) = sync_channel(1);
        let adopted = self
            .shards[shard]
            .send(ShardControl::AddVm {
                name: name.to_string(),
                driver,
                rings: Arc::clone(&rings),
                stats,
                trace,
                reply,
            })
            .and_then(|()| {
                rx.recv().map_err(|_| anyhow!("shard executor gone"))?
            });
        if let Err(e) = adopted {
            lock_unpoisoned(&self.vms[shard]).remove(name);
            self.gc.drop_chain(name);
            return Err(e);
        }
        Ok(VmClient {
            vm: name.to_string(),
            rings,
            clock: Arc::clone(&self.clock),
            ctl: self.shards[shard].handle(),
        })
    }

    /// Get a fresh client handle for a running VM.
    pub fn client(&self, name: &str) -> Result<VmClient> {
        let (shard, rings) =
            self.meta(name, |m| (m.shard, Arc::clone(&m.rings)))?;
        Ok(VmClient {
            vm: name.to_string(),
            rings,
            clock: Arc::clone(&self.clock),
            ctl: self.shards[shard].handle(),
        })
    }

    /// A snapshot of one VM's service stats. Round-trips a stats barrier
    /// through the owning shard first, so every completion the caller
    /// has already observed is counted (per-pass delta flushing would
    /// otherwise make the freshest requests invisible for one pass).
    pub fn vm_stats(&self, name: &str) -> Result<VmStatsSnapshot> {
        let (shard, stats) =
            self.meta(name, |m| (m.shard, Arc::clone(&m.stats)))?;
        let (reply, rx) = sync_channel(1);
        if self.shards[shard].send(ShardControl::SyncStats { reply }).is_ok() {
            let _ = rx.recv();
        }
        Ok(stats.snapshot())
    }

    pub fn vm_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .vms
            .iter()
            .flat_map(|t| lock_unpoisoned(t).keys().cloned().collect::<Vec<_>>())
            .collect();
        v.sort();
        v
    }

    /// The file names of a running VM's chain, base first (pauses the
    /// VM on its shard for the read).
    pub fn chain_files(&self, name: &str) -> Result<Vec<String>> {
        let client = self.client(name)?;
        let joined =
            client.with_chain(Box::new(|chain| Ok(chain.file_names().join("\n"))))??;
        Ok(joined.lines().map(str::to_string).collect())
    }

    /// Re-declare a VM chain's file set to the GC registry (after any
    /// chain-shape change): files the chain dropped lose a reference and
    /// are condemned once nothing else references them.
    fn sync_vm_chain(&self, name: &str) -> Result<()> {
        let files = self.chain_files(name)?;
        let active = files.last().cloned().unwrap_or_default();
        self.gc.sync_chain(name, files);
        // keep the registry entry and the durable VM record pointed at
        // the (possibly new) chain head; best-effort — the GC observer
        // already logged the authoritative file set above
        let rec = {
            let mut map = lock_unpoisoned(&self.vms[self.shard_of(name)]);
            map.get_mut(name).map(|m| {
                m.active = active.clone();
                ControlRecord::Vm {
                    name: name.to_string(),
                    driver: m.driver_kind,
                    slice_entries: m.cache.slice_entries,
                    max_bytes: m.cache.max_bytes,
                    data_mode: m.data_mode,
                    active,
                }
            })
        };
        if let Some(rec) = rec {
            // lint: durable-after(vm-chain-head)
            self.persist_best_effort(&rec);
        }
        Ok(())
    }

    /// Snapshot a running VM's disk: pause (drain), snapshot, swap the
    /// driver onto the lengthened chain.
    pub fn snapshot_vm(self: &Arc<Self>, name: &str, new_file: &str) -> Result<u64> {
        let (kind, stats) =
            self.meta(name, |m| (m.driver_kind, Arc::clone(&m.stats)))?;
        let client = self.client(name)?;
        let nodes = Arc::clone(&self.nodes);
        let new_file = new_file.to_string();
        let t0 = self.clock.now();
        client.with_chain(Box::new(move |chain| {
            // chain-locality placement: the new head belongs on the node
            // already holding the chain's active volume, not wherever
            // least-used placement would scatter it (falls back to
            // pick_node when that node is out of headroom)
            let store = nodes.hinted(&chain.active().name);
            match kind {
                DriverKind::Scalable => {
                    snapshot::snapshot_sqemu(chain, &store, &new_file)?
                }
                DriverKind::Vanilla => {
                    snapshot::snapshot_vanilla(chain, &store, &new_file)?
                }
            }
            Ok(new_file.clone())
        }))??;
        stats.snapshots.fetch_add(1, Relaxed);
        self.sync_vm_chain(name)?;
        Ok(self.clock.now() - t0)
    }

    /// Stream-merge a window of a running VM's chain (paused — the
    /// offline baseline; [`Coordinator::start_job`] is the live path).
    pub fn stream_vm(self: &Arc<Self>, name: &str, from: u16, to: u16) -> Result<StreamReport> {
        let stats = self.meta(name, |m| Arc::clone(&m.stats))?;
        let orch = self.streaming();
        let client = self.client(name)?;
        let t0 = self.clock.now();
        let report_json = client.with_chain(Box::new(move |chain| {
            let report = orch.merge(chain, from, to)?;
            Ok(format!(
                "{} {} {} {}",
                report.planned_clusters, report.copied_clusters,
                report.len_before, report.len_after
            ))
        }))??;
        stats.streams.fetch_add(1, Relaxed);
        // measure the disruption window before the GC bookkeeping below —
        // the registry sync pauses the VM again and must not inflate
        // the merge cost the benches compare live jobs against
        let merge_ns = self.clock.now() - t0;
        // the merged window's files just left the chain: hand them to GC
        self.sync_vm_chain(name)?;
        let parts: Vec<u64> = report_json
            .split_whitespace()
            .map(|p| p.parse().unwrap_or(0))
            .collect();
        Ok(StreamReport {
            from,
            to,
            planned_clusters: parts[0],
            copied_clusters: parts[1],
            len_before: parts[2] as usize,
            len_after: parts[3] as usize,
            merge_ns,
        })
    }

    // ------------------------------------------------------- live jobs

    /// Start a live block job on a running VM. Admission reserves
    /// `spec.rate_bps` of maintenance bandwidth on the storage node
    /// holding the VM's active volume; the reservation is released when
    /// the job reaches a terminal state (checked lazily by the job
    /// APIs). Returns the job's cross-thread handle.
    pub fn start_job(self: &Arc<Self>, vm: &str, spec: JobSpec) -> Result<Arc<JobShared>> {
        self.reap_jobs();
        let builder: JobBuilder = match spec.kind {
            JobKind::Gc => bail!("gc jobs own no chain; use Coordinator::run_gc"),
            JobKind::Mirror => {
                bail!("migrations carry a target node; use Coordinator::migrate_vm")
            }
            JobKind::Scan => bail!(
                "capacity scans own no chain; use Coordinator::start_capacity_scan"
            ),
            JobKind::Stream => Box::new(|chain, fence| {
                Ok(Box::new(LiveStreamJob::new(chain, Arc::clone(fence)))
                    as Box<dyn BlockJob>)
            }),
            JobKind::Stamp => Box::new(|chain, fence| {
                Ok(Box::new(LiveStampJob::new(chain, Arc::clone(fence)))
                    as Box<dyn BlockJob>)
            }),
        };
        let client = self.client(vm)?;
        // locate the active volume's node for admission
        let active_name =
            client.with_chain(Box::new(|chain| Ok(chain.active().name.clone())))??;
        let node = self.nodes.locate(&active_name).ok_or_else(|| {
            anyhow!("cannot locate the node holding '{active_name}' for job admission")
        })?;
        let reservation = self.scheduler.admit(&node, spec.rate_bps)?;
        let shared = Arc::new(JobShared::new(&self.next_job_id(), spec.kind, spec.rate_bps));
        if spec.start_paused {
            shared.pause();
        }
        // write-ahead job descriptor (fenced): a failed-over coordinator
        // learns this job existed and releases whatever it still held
        // lint: durable-before(job-ledger)
        if let Err(e) = self.persist(&ControlRecord::Job {
            id: shared.id.clone(),
            vm: vm.to_string(),
            kind: spec.kind,
            capacity: None,
        }) {
            self.scheduler.release(&reservation);
            return Err(e);
        }
        if let Err(e) = self.send_job_start(vm, builder, &shared) {
            self.scheduler.release(&reservation);
            // lint: durable-after(job-end)
            self.persist_best_effort(&ControlRecord::JobEnd {
                id: shared.id.clone(),
            });
            return Err(e);
        }
        self.note_job_started(vm);
        // lint: mutates(job-ledger)
        self.push_job(JobEntry {
            vm: vm.to_string(),
            shared: Arc::clone(&shared),
            reservations: vec![reservation],
            capacity: None,
            ended: false,
        });
        Ok(shared)
    }

    fn next_job_id(&self) -> String {
        format!("job-{}", self.next_job_id.fetch_add(1, Relaxed) + 1)
    }

    fn push_job(&self, entry: JobEntry) {
        let shard = self.shard_of(&entry.vm);
        lock_unpoisoned(&self.jobs[shard]).push(entry);
    }

    fn send_job_start(
        &self,
        vm: &str,
        builder: JobBuilder,
        shared: &Arc<JobShared>,
    ) -> Result<()> {
        let shard = self.meta(vm, |m| m.shard)?;
        let (reply, rx) = sync_channel(1);
        self.shards[shard]
            .send(ShardControl::JobStart {
                vm: vm.to_string(),
                builder,
                shared: Arc::clone(shared),
                increment_clusters: self.cfg.job_increment_clusters,
                reply,
            })
            .map_err(|_| anyhow!("vm worker gone"))?;
        rx.recv().map_err(|_| anyhow!("vm worker gone"))?
    }

    fn note_job_started(&self, vm: &str) {
        if let Ok(stats) = self.meta(vm, |m| Arc::clone(&m.stats)) {
            stats.jobs_started.fetch_add(1, Relaxed);
        }
    }

    // ------------------------------------------------------- migration

    /// Live-migrate a VM's whole chain to storage node `target` while
    /// the guest keeps serving: a [`crate::migrate::MirrorJob`] admitted
    /// like any other live job (bandwidth reserved on the recipient and
    /// every donor node) plus a *capacity* reservation on the recipient
    /// for the chain's bytes, held until the job is terminal so
    /// placement cannot overcommit the node mid-copy. The reservation is
    /// released by the lazy reap (any job API or [`Coordinator::wait_job`]);
    /// between switchover and reap the recipient is conservatively
    /// over-committed by the landed bytes. Returns the job handle; poll
    /// it or [`Coordinator::wait_job`] it.
    pub fn migrate_vm(
        self: &Arc<Self>,
        vm: &str,
        target: &str,
        rate_bps: u64,
    ) -> Result<Arc<JobShared>> {
        self.reap_jobs();
        let target_node = self
            .nodes
            .node_named(target)
            .ok_or_else(|| anyhow!("no storage node '{target}'"))?;
        let files = self.chain_files(vm)?;
        let mut moved_bytes = 0u64;
        let mut admit_nodes: Vec<String> = vec![target_node.name.clone()];
        let mut any = false;
        for f in &files {
            let node = self
                .nodes
                .node_of(f)
                .ok_or_else(|| anyhow!("cannot locate '{f}' in the node set"))?;
            if node.name == target_node.name {
                continue;
            }
            any = true;
            moved_bytes += node.open_file(f).map(|b| b.stored_bytes()).unwrap_or(0);
            if !admit_nodes.contains(&node.name) {
                admit_nodes.push(node.name.clone());
            }
        }
        if !any {
            bail!("vm '{vm}' chain already lives on node '{target}'");
        }
        target_node.reserve(moved_bytes)?;
        let mut reservations: Vec<Reservation> = Vec::new();
        for n in &admit_nodes {
            match self.scheduler.admit(n, rate_bps) {
                Ok(r) => reservations.push(r),
                Err(e) => {
                    for r in &reservations {
                        self.scheduler.release(r);
                    }
                    target_node.release(moved_bytes);
                    return Err(e);
                }
            }
        }
        let shared =
            Arc::new(JobShared::new(&self.next_job_id(), JobKind::Mirror, rate_bps));
        // write-ahead (fenced): the migration intent and the job's
        // capacity reservation on the recipient — exactly what a
        // failed-over coordinator must resolve and release
        let persisted = self
            // lint: durable-before(migration-intent)
            .persist(&ControlRecord::Migration {
                vm: vm.to_string(),
                target: target_node.name.clone(),
            })
            .and_then(|()| {
                // lint: durable-before(migration-job)
                self.persist(&ControlRecord::Job {
                    id: shared.id.clone(),
                    vm: vm.to_string(),
                    kind: JobKind::Mirror,
                    capacity: Some((target_node.name.clone(), moved_bytes)),
                })
            });
        if let Err(e) = persisted {
            for r in &reservations {
                self.scheduler.release(r);
            }
            target_node.release(moved_bytes);
            return Err(e);
        }
        let nodes = Arc::clone(&self.nodes);
        let gc = Arc::clone(&self.gc);
        let (vm_id, target_name) = (vm.to_string(), target_node.name.clone());
        let builder: JobBuilder = Box::new(move |chain, _fence| {
            Ok(Box::new(crate::migrate::MirrorJob::new(
                chain,
                nodes,
                gc,
                &target_name,
                &vm_id,
            )?) as Box<dyn BlockJob>)
        });
        // lint: mutates(migration-intent)
        if let Err(e) = self.send_job_start(vm, builder, &shared) {
            for r in &reservations {
                self.scheduler.release(r);
            }
            target_node.release(moved_bytes);
            // lint: durable-after(job-end)
            self.persist_best_effort(&ControlRecord::JobEnd {
                id: shared.id.clone(),
            });
            // lint: durable-after(migration-end)
            self.persist_best_effort(&ControlRecord::MigrationEnd {
                vm: vm.to_string(),
            });
            return Err(e);
        }
        self.note_job_started(vm);
        // lint: mutates(migration-job)
        self.push_job(JobEntry {
            vm: vm.to_string(),
            shared: Arc::clone(&shared),
            reservations,
            capacity: Some((target_node, moved_bytes)),
            ended: false,
        });
        Ok(shared)
    }

    /// Block until `shared` is terminal (the owning shard drains the job
    /// while its VMs are idle), release its reservations, and return the
    /// final status.
    pub fn wait_job(&self, shared: &Arc<JobShared>) -> JobStatus {
        while !shared.state().is_terminal() {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        self.reap_jobs();
        shared.status()
    }

    /// Plan (and unless `dry_run`, execute) a fleet rebalance: read
    /// per-node pressure, pick donor→recipient chain moves under
    /// `threshold` (max/min committed-pressure ratio), and drive each
    /// move through [`Coordinator::migrate_vm`] sequentially. Returns
    /// the plan and the ratio it left the fleet at.
    pub fn rebalance(
        self: &Arc<Self>,
        threshold: f64,
        rate_bps: u64,
        dry_run: bool,
    ) -> Result<RebalanceReport> {
        let pressures: Vec<NodePressure> = self
            .nodes
            .nodes()
            .iter()
            .map(|n| NodePressure {
                name: n.name.clone(),
                pressure: n.committed_bytes(),
                capacity: n.capacity,
            })
            .collect();
        let mut footprints: Vec<VmFootprint> = Vec::new();
        for vm in self.vm_names() {
            let files = self.chain_files(&vm)?;
            // BTreeMap: the dominant-node pick must break ties
            // deterministically (dry-run and execution see one plan)
            let mut per_node: std::collections::BTreeMap<String, u64> =
                std::collections::BTreeMap::new();
            let mut total = 0u64;
            for f in &files {
                if let Some(node) = self.nodes.node_of(f) {
                    let bytes =
                        node.open_file(f).map(|b| b.stored_bytes()).unwrap_or(0);
                    *per_node.entry(node.name.clone()).or_default() += bytes;
                    total += bytes;
                }
            }
            // the planner needs both sides of a scattered chain: what a
            // move takes off the dominant node vs what it lands on the
            // recipient
            let Some((home, resident)) =
                per_node.into_iter().max_by_key(|(_, bytes)| *bytes)
            else {
                continue;
            };
            footprints.push(VmFootprint { vm, node: home, bytes: resident, total });
        }
        let plan = crate::migrate::plan(&pressures, &footprints, threshold, 16);
        let mut executed = 0usize;
        if !dry_run {
            for m in &plan.moves {
                let shared = self.migrate_vm(&m.vm, &m.to, rate_bps)?;
                let st = self.wait_job(&shared);
                if st.state != crate::blockjob::JobState::Completed {
                    bail!(
                        "rebalance: migration of '{}' to '{}' ended {}: {:?}",
                        m.vm,
                        m.to,
                        st.state.name(),
                        st.error
                    );
                }
                executed += 1;
            }
        }
        let final_ratio = crate::migrate::rebalance::pressure_ratio(
            &self
                .nodes
                .nodes()
                .iter()
                .map(|n| n.committed_bytes())
                .collect::<Vec<_>>(),
        );
        Ok(RebalanceReport { plan, executed, final_ratio })
    }

    /// All jobs ever started (oldest first, by job id), with live status.
    pub fn list_jobs(&self) -> Vec<(String, JobStatus)> {
        self.reap_jobs();
        let mut all: Vec<(u64, String, JobStatus)> = Vec::new();
        for table in &self.jobs {
            for e in lock_unpoisoned(table).iter() {
                let seq = e
                    .shared
                    .id
                    .strip_prefix("job-")
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(u64::MAX);
                all.push((seq, e.vm.clone(), e.shared.status()));
            }
        }
        // ledgers are sharded: restore fleet-wide start order by id
        all.sort_by_key(|(seq, ..)| *seq);
        all.into_iter().map(|(_, vm, st)| (vm, st)).collect()
    }

    fn find_job(&self, id: &str) -> Result<Arc<JobShared>> {
        for table in &self.jobs {
            if let Some(e) =
                lock_unpoisoned(table).iter().find(|e| e.shared.id == id)
            {
                return Ok(Arc::clone(&e.shared));
            }
        }
        Err(anyhow!("no job '{id}'"))
    }

    /// Status of one job by id.
    pub fn job_status(&self, id: &str) -> Result<JobStatus> {
        self.reap_jobs();
        Ok(self.find_job(id)?.status())
    }

    /// Request cooperative cancellation of a job.
    pub fn cancel_job(&self, id: &str) -> Result<()> {
        self.find_job(id)?.cancel();
        Ok(())
    }

    pub fn pause_job(&self, id: &str) -> Result<()> {
        self.find_job(id)?.pause();
        Ok(())
    }

    pub fn resume_job(&self, id: &str) -> Result<()> {
        self.find_job(id)?.resume();
        Ok(())
    }

    // -------------------------------------------------- garbage collection

    /// The cross-chain reference registry (refcounts, deferred deletes).
    pub fn gc_registry(&self) -> &Arc<GcRegistry> {
        &self.gc
    }

    /// Rescan every chain's tables and refresh each node's cached
    /// logical-bytes counter ([`StorageNode::set_logical_bytes`]).
    /// Logical bytes are guest-addressable mapped bytes — what the fleet
    /// would store with no zero suppression, compression or dedup — and
    /// a chain's total is attributed to the node holding its active
    /// volume. Returns `(node, logical, physical)` per node. Physical
    /// pressure is live either way; this scan only feeds reporting
    /// (`sqemu node status`, fig24), so staleness between calls is fine.
    pub fn refresh_capacity(&self) -> Vec<(String, u64, u64)> {
        let mut backed: std::collections::HashSet<String> =
            std::collections::HashSet::new();
        let mut names: Vec<String> = Vec::new();
        for node in self.nodes.nodes() {
            for f in node.file_names() {
                if f.starts_with(crate::migrate::JOURNAL_PREFIX) {
                    continue;
                }
                let opened = node
                    .open_file(&f)
                    .and_then(|b| crate::qcow::Image::open(&f, b, DataMode::Real));
                if let Ok(img) = opened {
                    if let Some(b) = img.backing_name() {
                        backed.insert(b);
                    }
                    if !names.contains(&f) {
                        names.push(f);
                    }
                }
            }
        }
        let mut logical: HashMap<String, u64> = HashMap::new();
        for head in names.iter().filter(|n| !backed.contains(*n)) {
            let Some(node) = self.nodes.locate(head) else { continue };
            let Ok(chain) = Chain::open(self.nodes.as_ref(), head, DataMode::Real)
            else {
                continue;
            };
            if let Ok(bytes) = chain_logical_bytes(&chain) {
                *logical.entry(node).or_default() += bytes;
            }
        }
        self.nodes
            .nodes()
            .iter()
            .map(|n| {
                let l = logical.get(&n.name).copied().unwrap_or(0);
                n.set_logical_bytes(l);
                (n.name.clone(), l, n.used_bytes())
            })
            .collect()
    }

    /// Audit node files against chain reachability (`gc --dry-run`),
    /// plus the dedup index against file existence: an extent whose
    /// backing file is gone means the sweep's `prune_missing` wiring
    /// broke, and the audit flags it like any other leak.
    pub fn gc_audit(&self) -> crate::gc::AuditReport {
        let mut report = crate::gc::audit(self.nodes.as_ref(), &self.gc);
        report.stale_extents = self
            .dedup
            .stale_extents(|f| self.nodes.locate(f).is_some());
        report
    }

    /// Run a GC sweep: physically delete the deferred-delete set at
    /// `rate_bps` bytes/second of reclamation I/O (0 = unlimited). The
    /// sweep is a [`GcJob`] driven through the standard [`JobRunner`]
    /// (it appears in `list_jobs` and honours `cancel_job`), admitted
    /// against the maintenance budget of every node holding condemned
    /// files. Reclaimed bytes are attributed to the VMs whose chains
    /// dropped the files.
    pub fn run_gc(&self, rate_bps: u64) -> Result<GcReport> {
        self.reap_jobs();
        // admission: one reservation per node with condemned files
        // (named condemnations via the index, migration replicas via
        // their pinned node)
        let node_names = self.gc.condemned_nodes();
        let mut reservations = Vec::new();
        for n in &node_names {
            match self.scheduler.admit(n, rate_bps) {
                Ok(r) => reservations.push(r),
                Err(e) => {
                    for r in &reservations {
                        self.scheduler.release(r);
                    }
                    return Err(e);
                }
            }
        }
        let shared = Arc::new(JobShared::new(&self.next_job_id(), JobKind::Gc, rate_bps));
        // lint: durable-before(gc-job)
        if let Err(e) = self.persist(&ControlRecord::Job {
            id: shared.id.clone(),
            vm: "(gc)".to_string(),
            kind: JobKind::Gc,
            capacity: None,
        }) {
            for r in &reservations {
                self.scheduler.release(r);
            }
            return Err(e);
        }
        // lint: mutates(gc-job)
        self.push_job(JobEntry {
            vm: "(gc)".to_string(),
            shared: Arc::clone(&shared),
            reservations: Vec::new(),
            capacity: None,
            ended: false,
        });
        let run = (|| -> Result<()> {
            let mut driver =
                crate::gc::scratch_driver(Arc::clone(&self.clock), self.cfg.cost)?;
            let fence = Arc::clone(driver.fence());
            let job = Box::new(GcJob::new(Arc::clone(&self.gc)));
            let mut runner = JobRunner::new(
                job,
                Arc::clone(&shared),
                fence,
                self.cfg.job_increment_clusters.max(1),
                4 << 20,
                self.clock.now(),
            );
            loop {
                match runner.step(&mut driver, self.clock.now()) {
                    Step::Finished => break,
                    Step::Starved { ready_at } => {
                        // advance the shared clock in bounded quanta, like
                        // the shard idle loop: VMs serving guests
                        // concurrently must not see one giant time jump
                        // attributed to their in-flight requests
                        const GC_IDLE_QUANTUM_NS: u64 = 100_000_000;
                        let now = self.clock.now();
                        if ready_at > now {
                            self.clock.advance((ready_at - now).min(GC_IDLE_QUANTUM_NS));
                        }
                    }
                    // run_gc is synchronous: wait out an external pause
                    // instead of spinning
                    Step::Paused => {
                        std::thread::sleep(std::time::Duration::from_millis(1))
                    }
                    Step::Ran => {}
                }
            }
            Ok(())
        })();
        for r in &reservations {
            self.scheduler.release(r);
        }
        run?;
        let t = shared.status();
        // per-VM attribution: bytes reclaimed from files each VM's chain
        // dropped (decommissioned chains have no VM entry left — their
        // share stays fleet-level in the registry totals)
        let by_origin = self.gc.drain_reclaimed_by();
        for (origin, bytes) in by_origin {
            let _ = self.meta(&origin, |m| {
                m.stats.reclaimed_bytes.fetch_add(bytes, Relaxed);
                m.stats.gc_runs.fetch_add(1, Relaxed);
            });
        }
        if let Some(err) = t.error {
            bail!("gc sweep failed: {err}");
        }
        // extents stored in files the sweep just deleted leave the
        // dedup index with them (sharers' on-disk references were
        // release-gated before the files could be condemned)
        self.dedup
            .prune_missing(|f| self.nodes.locate(f).is_some());
        // committed migration journals whose replicas the sweep just
        // deleted have served their purpose (a journal must outlive the
        // source copies it covers, never the other way round)
        let journals_cleaned = crate::migrate::cleanup_journals(self.nodes.as_ref());
        Ok(GcReport {
            files_deleted: t.copied,
            reclaimed_bytes: t.bytes_copied,
            gc_ns: t.finished_ns.saturating_sub(t.started_ns),
            remaining_condemned: self.gc.condemned_count() as u64,
            journals_cleaned,
        })
    }

    /// Decommission a VM *and its chain*: stop it and release every file
    /// reference the chain held. Files referenced by no other chain are
    /// condemned for the next GC sweep — the snapshot-deletion path;
    /// shared bases survive as long as any other chain uses them.
    pub fn decommission_vm(&self, name: &str) -> Result<()> {
        self.stop_vm(name)?;
        self.gc.drop_chain(name);
        Ok(())
    }

    /// Crash recovery, run at startup BEFORE launching VMs.
    ///
    /// With a control plane attached and a usable log, state is
    /// *replayed* — O(log records) bookkeeping plus O(active leases)
    /// integrity checks, instead of walking every file on every node;
    /// after a clean shutdown even the per-lease qcheck walk is skipped
    /// (the marker certifies every chain was flushed and closed). A log
    /// torn beyond its last compacted snapshot (or never written) falls
    /// back to the full fleet scan, whose findings then *reseed* the
    /// store so the next boot replays again.
    pub fn recover(&self) -> RecoveryReport {
        let Some((store, ..)) = self.control_parts() else {
            return self.recover_full_scan();
        };
        let v = store.view();
        if !v.torn && v.records > 0 {
            return self.recover_from_view(&v);
        }
        let report = self.recover_full_scan();
        self.next_job_id.fetch_max(v.max_job_seq, Relaxed);
        let _ = store.reseed(
            self.nodes.index_snapshot(),
            self.gc.chains(),
            self.next_job_id.load(Relaxed),
        );
        report
    }

    /// Replay recovery: rebuild volatile coordinator state from the
    /// [`StateStore`]'s replayed view. Per logged migration exactly one
    /// journal is probed on its known target node; the placement index
    /// is installed entry-by-entry (each validated with one `open_file`
    /// on the named node — no listing); GC refcounts/condemnations are
    /// installed, not rescanned; and only chains the lease table says
    /// were open get a qcheck walk.
    fn recover_from_view(&self, v: &FleetView) -> RecoveryReport {
        let mut report = RecoveryReport::default();
        // Reboot semantics: only file bytes survived; per-node volatile
        // bookkeeping is re-derived from the log below.
        for node in self.nodes.nodes() {
            node.clear_volatile();
        }
        self.dedup.clear();
        // In-flight migrations first — targeted: the log names the vm
        // and target, so the journal is probed where it must live.
        let mut migs: Vec<(String, String)> = v
            .migrations
            .iter()
            .map(|(vm, t)| (vm.clone(), t.clone()))
            .collect();
        migs.sort();
        for (vm, target) in &migs {
            let r = crate::migrate::recover_migrations_for(
                self.nodes.as_ref(),
                vm,
                target,
            );
            report.migrations_committed += r.committed;
            report.migrations_rolled_back += r.rolled_back;
            report.unopenable.extend(r.errors);
        }
        // The name→node index comes from the log; entries the journal
        // resolution just deleted (superseded source copies) drop out
        // in per-entry validation.
        let mut entries: Vec<(String, String)> = v
            .placement
            .iter()
            .map(|(f, n)| (f.clone(), n.clone()))
            .collect();
        entries.sort();
        for f in self.nodes.install_index(&entries) {
            report
                .unopenable
                .push(format!("{f}: logged placement has no file"));
        }
        // Files a committed journal landed on the target before the
        // crash could persist their Place records: re-point them (the
        // placement observer heals the log as commit_migration runs),
        // then close the migration in the log.
        for (vm, target) in &migs {
            if let (Some(files), Some(tnode)) =
                (v.chains.get(vm), self.nodes.node_named(target))
            {
                for f in files {
                    if self.nodes.locate(f).is_none()
                        && tnode.open_file(f).is_ok()
                    {
                        let _ = self
                            .nodes
                            .commit_migration(std::slice::from_ref(f), target);
                    }
                }
            }
            // lint: durable-after(migration-end)
            self.persist_best_effort(&ControlRecord::MigrationEnd {
                vm: vm.clone(),
            });
        }
        // GC registry: installed from the log (condemned marks land
        // back on the owning nodes), no listing, no re-logged events.
        self.gc.install(
            v.chains.iter().map(|(k, f)| (k.clone(), f.clone())).collect(),
            v.condemned.iter().map(|(k, c)| (k.clone(), c.clone())).collect(),
            v.replicas.iter().map(|(k, c)| (k.clone(), c.clone())).collect(),
        );
        // Integrity gate: qcheck only what the lease table says was
        // open at the crash — the O(active leases) bound. After a clean
        // shutdown even this is skipped.
        if !v.clean_shutdown {
            let (live, expired) = partition_leases(&v.leases, self.clock.now());
            for (vm, _) in live.iter().chain(expired.iter()) {
                let Some(spec) = v.vms.get(vm) else { continue };
                if spec.data_mode != DataMode::Real {
                    continue;
                }
                report.chains_checked += 1;
                let checked = Chain::open(
                    self.nodes.as_ref(),
                    &spec.active,
                    DataMode::Real,
                )
                .and_then(|chain| {
                    let before = qcheck::check_chain(&chain)?;
                    if !before.is_clean() || before.leaked_clusters != 0 {
                        qcheck::repair_chain(&chain)?;
                        report.chains_repaired += 1;
                        let after = qcheck::check_chain(&chain)?;
                        if !after.is_clean() {
                            bail!("still dirty: {}", after.errors.join("; "));
                        }
                    }
                    Ok(())
                });
                if let Err(e) = checked {
                    report
                        .unopenable
                        .push(format!("chain {}: {e:#}", spec.active));
                }
            }
        }
        // Jobs in the log were running at the crash; nothing is running
        // now. Close them out (their node reservations were volatile
        // and died with the old process).
        for id in v.jobs.keys() {
            // lint: durable-after(job-end)
            self.persist_best_effort(&ControlRecord::JobEnd { id: id.clone() });
        }
        // job ids must never repeat across the crash
        self.next_job_id.fetch_max(v.max_job_seq, Relaxed);
        // NOTE: no synchronous refresh_capacity() here — logical-bytes
        // reporting converges via the rate-limited background
        // [`Coordinator::start_capacity_scan`] instead of delaying
        // guest-I/O admission behind a full chain walk.
        report
    }

    /// Crash-recovery pass over every image on this coordinator's
    /// nodes: each file that parses as an image gets `qcheck --repair`
    /// if dirty, then every chain head (an image no other image backs
    /// onto) is re-checked as a chain so cross-file stamps are validated
    /// too. The [`Coordinator::recover`] fallback when no usable control
    /// log exists — the images must not be concurrently open
    /// ([`Coordinator::launch_vm`] additionally gates each `Existing`
    /// chain on a clean check at launch).
    pub fn recover_full_scan(&self) -> RecoveryReport {
        let mut report = RecoveryReport::default();
        // Reboot semantics: only file bytes survived. Per-node volatile
        // bookkeeping (condemned marks, migration reservations, write
        // watches) is cleared and re-derived from durable state.
        for node in self.nodes.nodes() {
            node.clear_volatile();
        }
        // the dedup index is volatile too: only file bytes survive, and
        // every physical sharing is protected by on-disk cluster
        // refcounts or file-level GC references — the index is rebuilt
        // opportunistically as guests write
        self.dedup.clear();
        // Interrupted migrations first: every name must resolve to
        // exactly one authoritative copy (journal committed → target
        // wins, superseded sources deleted; else → source wins, partial
        // targets deleted) BEFORE the index is rebuilt or images opened.
        let mig = crate::migrate::recover_migrations(self.nodes.as_ref());
        report.migrations_committed = mig.committed;
        report.migrations_rolled_back = mig.rolled_back;
        for e in mig.errors {
            report.unopenable.push(e);
        }
        // The name→node index is volatile too: rebuild it from the
        // nodes' durable file lists (pre-fix, a freshly booted
        // coordinator could not locate any chain file).
        report.duplicate_files = self.nodes.rebuild_index();
        let mut backed: std::collections::HashSet<String> =
            std::collections::HashSet::new();
        let mut images: Vec<String> = Vec::new();
        for node in self.nodes.nodes() {
            for name in node.file_names() {
                if name.starts_with(crate::migrate::JOURNAL_PREFIX) {
                    continue; // control-plane metadata, not an image
                }
                let opened = node
                    .open_file(&name)
                    .and_then(|b| crate::qcow::Image::open(&name, b, DataMode::Real));
                let img = match opened {
                    Ok(img) => img,
                    Err(e) => {
                        report.unopenable.push(format!("{name}: {e:#}"));
                        continue;
                    }
                };
                report.images_checked += 1;
                if let Some(b) = img.backing_name() {
                    backed.insert(b);
                }
                images.push(name.clone());
                match qcheck::check_image(&img) {
                    Ok(r) if r.is_clean() && r.leaked_clusters == 0 => {}
                    _ => match qcheck::repair_image(&img) {
                        Ok(rep) if rep.changed() => report.images_repaired += 1,
                        Ok(_) => {}
                        Err(e) => {
                            report.unopenable.push(format!("{name}: repair: {e:#}"))
                        }
                    },
                }
            }
        }
        for head in images.iter().filter(|n| !backed.contains(*n)) {
            report.chains_checked += 1;
            let recovered = Chain::open(self.nodes.as_ref(), head, DataMode::Real)
                .and_then(|chain| {
                    let before = qcheck::check_chain(&chain)?;
                    if !before.is_clean() {
                        qcheck::repair_chain(&chain)?;
                        report.chains_repaired += 1;
                        let after = qcheck::check_chain(&chain)?;
                        if !after.is_clean() {
                            bail!("still dirty: {}", after.errors.join("; "));
                        }
                    }
                    Ok(())
                });
            if let Err(e) = recovered {
                report.unopenable.push(format!("chain {head}: {e:#}"));
            }
        }
        // the logical-bytes cache was cleared with the rest of the
        // volatile bookkeeping: rebuild it from the recovered chains
        self.refresh_capacity();
        report
    }

    /// Release bandwidth and capacity reservations of terminal jobs
    /// (lazy reaping). A completed migration's copied bytes are real
    /// usage on the recipient by now, so its capacity reservation is
    /// released either way — the files themselves keep the space.
    fn reap_jobs(&self) {
        let mut closed: Vec<ControlRecord> = Vec::new();
        for table in &self.jobs {
            let mut jobs = lock_unpoisoned(table);
            for e in jobs.iter_mut() {
                if e.shared.state().is_terminal() {
                    for r in e.reservations.drain(..) {
                        self.scheduler.release(&r);
                    }
                    if let Some((node, bytes)) = e.capacity.take() {
                        node.release(bytes);
                    }
                    if !e.ended {
                        e.ended = true;
                        closed.push(ControlRecord::JobEnd {
                            id: e.shared.id.clone(),
                        });
                        if e.shared.kind == JobKind::Mirror {
                            closed.push(ControlRecord::MigrationEnd {
                                vm: e.vm.clone(),
                            });
                        }
                    }
                }
            }
        }
        // write-behind and best-effort, outside the ledger locks:
        // terminal-state records must never block reaping
        for rec in &closed {
            // lint: durable-after(job-end)
            self.persist_best_effort(rec);
        }
    }

    /// Stop one VM (serves what its clients already queued, flushes its
    /// caches, cancels any running job). With a control plane attached
    /// the stop is persisted write-ahead (fenced — a deposed leader may
    /// not stop VMs the new leader adopted) and the VM's lease released.
    pub fn stop_vm(&self, name: &str) -> Result<()> {
        let shard = self.shard_of(name);
        if !lock_unpoisoned(&self.vms[shard]).contains_key(name) {
            bail!("no vm '{name}'");
        }
        // lint: durable-before(vm-stop)
        self.persist(&ControlRecord::VmStop { name: name.to_string() })?;
        // lint: mutates(vm-stop)
        let meta = lock_unpoisoned(&self.vms[shard])
            .remove(name)
            .ok_or_else(|| anyhow!("no vm '{name}'"))?;
        let (reply, rx) = sync_channel(1);
        if self
            .shards[meta.shard]
            .send(ShardControl::RemoveVm { name: name.to_string(), reply })
            .is_ok()
        {
            // wait for the drain + flush; the shard replies even for a
            // VM it already lost to a panic
            let _ = rx.recv();
        }
        self.reap_jobs();
        if let Some((store, epoch, who)) = self.control_parts() {
            let _ = store.release_lease(epoch, name, &who);
        }
        Ok(())
    }

    /// Stop the whole fleet (the shard executors stay up for relaunch).
    pub fn shutdown(&self) {
        let names = self.vm_names();
        for n in names {
            let _ = self.stop_vm(&n);
        }
    }

    // ----------------------------------------------- HA control plane

    /// Attach a write-ahead [`StateStore`] (the durable HA control
    /// plane). From here on:
    ///
    /// * every placement mutation is persisted *before* it happens, and
    ///   vetoed if the append fails (a wedged log refuses new placements
    ///   instead of silently diverging from what it recorded);
    /// * GC registry mutations are persisted write-behind (GC state is
    ///   reconstructible — a lost event costs a re-condemnation, never
    ///   correctness);
    /// * VM ownership is lease-based and launches/stops/jobs are fenced
    ///   by epoch ([`Coordinator::campaign`]).
    ///
    /// The store must live on a dedicated metadata node *outside* this
    /// coordinator's [`NodeSet`] — data-plane scans, placement and GC
    /// must never see control-plane files.
    pub fn attach_control(&self, store: Arc<StateStore>, who: &str) -> Result<()> {
        if self.nodes.node_named(&store.node().name).is_some() {
            bail!(
                "control store node '{}' is in the data NodeSet; give the \
                 log a dedicated metadata node",
                store.node().name
            );
        }
        let s = Arc::clone(&store);
        self.nodes.set_observer(Some(Box::new(move |ev| match ev {
            PlacementEvent::Placed { file, node } => {
                // lint: durable-after(placement-event)
                s.append_unfenced(&ControlRecord::Place {
                    file: (*file).to_string(),
                    node: (*node).to_string(),
                })
            }
            // lint: durable-after(placement-event)
            PlacementEvent::Removed { file } => s.append_unfenced(
                &ControlRecord::Unplace { file: (*file).to_string() },
            ),
            PlacementEvent::Migrated { files, node } => {
                for f in files.iter() {
                    // lint: durable-after(placement-event)
                    s.append_unfenced(&ControlRecord::Place {
                        file: f.clone(),
                        node: (*node).to_string(),
                    })?;
                }
                Ok(())
            }
        })));
        let s = Arc::clone(&store);
        self.gc.set_observer(Some(Box::new(move |ev| {
            let rec = match ev {
                GcEvent::Chain { id, files } => ControlRecord::Chain {
                    id: id.clone(),
                    files: files.clone(),
                },
                GcEvent::ChainDrop { id } => {
                    ControlRecord::ChainDrop { id: id.clone() }
                }
                GcEvent::Condemned { file, bytes, origin } => {
                    ControlRecord::Condemn {
                        file: file.clone(),
                        bytes: *bytes,
                        origin: origin.clone(),
                    }
                }
                GcEvent::Uncondemned { file } => {
                    ControlRecord::Uncondemn { file: file.clone() }
                }
                GcEvent::Swept { file } => {
                    ControlRecord::Swept { file: file.clone() }
                }
                GcEvent::CondemnedReplica { node, file, bytes, origin } => {
                    ControlRecord::CondemnReplica {
                        node: node.clone(),
                        file: file.clone(),
                        bytes: *bytes,
                        origin: origin.clone(),
                    }
                }
                GcEvent::SweptReplica { node, file } => {
                    ControlRecord::SweptReplica {
                        node: node.clone(),
                        file: file.clone(),
                    }
                }
            };
            // write-behind and best-effort by design
            // lint: durable-after(gc-event)
            let _ = s.append_unfenced(&rec);
        })));
        // a rebooting leader re-adopts its recorded epoch; anyone else
        // starts at 0 and must campaign before fenced writes pass
        let epoch =
            if store.leader() == who { store.current_epoch() } else { 0 };
        *lock_unpoisoned(&self.control) =
            Some(ControlHandle { store, epoch, who: who.to_string() });
        Ok(())
    }

    /// Win an election: bump the store epoch, fencing every append a
    /// previous leader (including a deposed *this* instance) attempts
    /// under its older epoch. Returns the new epoch.
    pub fn campaign(&self) -> Result<u64> {
        let mut ctl = lock_unpoisoned(&self.control);
        let Some(h) = ctl.as_mut() else {
            bail!("no control plane attached");
        };
        let epoch = h.store.campaign(&h.who)?;
        h.epoch = epoch;
        Ok(epoch)
    }

    fn control_parts(&self) -> Option<(Arc<StateStore>, u64, String)> {
        lock_unpoisoned(&self.control)
            .as_ref()
            .map(|h| (Arc::clone(&h.store), h.epoch, h.who.clone()))
    }

    /// Fenced write-ahead append; a no-op without a control plane.
    fn persist(&self, rec: &ControlRecord) -> Result<()> {
        if let Some((store, epoch, _)) = self.control_parts() {
            store.append(epoch, rec)?;
        }
        Ok(())
    }

    /// Fenced append where failure must not abort the caller (terminal
    /// job states, bookkeeping that replay re-derives anyway).
    fn persist_best_effort(&self, rec: &ControlRecord) {
        if let Some((store, epoch, _)) = self.control_parts() {
            let _ = store.append(epoch, rec);
        }
    }

    /// Leader failover: take over a fleet whose previous leader died.
    ///
    /// Unlike [`Coordinator::recover`] this runs against *live* nodes —
    /// volatile node state survived in their processes, so nothing is
    /// cleared. The standby tails the log (retrying with jittered
    /// backoff while the metadata node may still be coming back), wins
    /// an election (fencing every straggler write the dead leader might
    /// still attempt), resolves in-flight migrations from their
    /// journals, releases the dead leader's logged capacity
    /// reservations, and re-adopts each VM as its lease expires —
    /// O(active leases) work, no fleet scan, no guest byte whose flush
    /// was acknowledged is lost.
    pub fn takeover(self: &Arc<Self>) -> Result<RecoveryReport> {
        let (store, _, who) = self
            .control_parts()
            .ok_or_else(|| anyhow!("no control plane attached"))?;
        // standby log-tailing: replay the log from disk; the retry rides
        // out a metadata node that is itself still rebooting
        let policy = RetryPolicy::new(1_000_000, 1_000_000_000, 30_000_000_000);
        let clock = Arc::clone(&self.clock);
        policy.run(
            fnv1a(&who),
            || clock.now(),
            |ns| clock.advance(ns),
            || store.reopen(),
        )?;
        self.campaign()?;
        let v = store.view();
        let mut report = RecoveryReport::default();
        // targeted journal resolution, exactly as in replay recovery
        let mut migs: Vec<(String, String)> = v
            .migrations
            .iter()
            .map(|(vm, t)| (vm.clone(), t.clone()))
            .collect();
        migs.sort();
        for (vm, target) in &migs {
            let r = crate::migrate::recover_migrations_for(
                self.nodes.as_ref(),
                vm,
                target,
            );
            report.migrations_committed += r.committed;
            report.migrations_rolled_back += r.rolled_back;
            report.unopenable.extend(r.errors);
            if let (Some(files), Some(tnode)) =
                (v.chains.get(vm), self.nodes.node_named(target))
            {
                for f in files {
                    if self.nodes.locate(f).as_deref() != Some(target.as_str())
                        && tnode.open_file(f).is_ok()
                    {
                        let _ = self
                            .nodes
                            .commit_migration(std::slice::from_ref(f), target);
                    }
                }
            }
            // lint: durable-after(migration-end)
            self.persist_best_effort(&ControlRecord::MigrationEnd {
                vm: vm.clone(),
            });
        }
        // the dead leader's jobs are not running here; give back the
        // capacity the log says they held and close them out
        let mut job_ids: Vec<&String> = v.jobs.keys().collect();
        job_ids.sort();
        for id in job_ids {
            let job = &v.jobs[id];
            if let Some((node_name, bytes)) = &job.capacity {
                if let Some(node) = self.nodes.node_named(node_name) {
                    node.release(*bytes);
                }
            }
            // lint: durable-after(job-end)
            self.persist_best_effort(&ControlRecord::JobEnd { id: id.clone() });
        }
        self.next_job_id.fetch_max(v.max_job_seq, Relaxed);
        // re-adopt each leased VM; never steal a live lease — the old
        // holder may still be flushing, so wait out the TTL on the
        // virtual clock (lease expiry is the only safe handover)
        let mut leased: Vec<String> = v.leases.keys().cloned().collect();
        leased.sort();
        for vm in leased {
            if self.meta(&vm, |_| ()).is_ok() {
                continue; // already running here
            }
            if let Some(l) = store.lease_of(&vm) {
                let now = self.clock.now();
                if l.holder != who && !l.expired(now) {
                    self.clock.advance(l.expires_ns - now);
                }
            }
            let Some(spec) = v.vms.get(&vm) else {
                // a lease with no VM record: half-finished launch; the
                // expired lease is the only orphan to clean
                if let Some((store, epoch, who)) = self.control_parts() {
                    let _ = store.release_lease(epoch, &vm, &who);
                }
                continue;
            };
            report.chains_checked += 1;
            let cfg = VmConfig {
                driver: spec.driver,
                cache: spec.cache,
                chain: VmChain::Existing {
                    active_name: spec.active.clone(),
                    data_mode: spec.data_mode,
                },
            };
            if let Err(e) = self.launch_vm(&vm, cfg) {
                report.unopenable.push(format!("vm {vm}: {e:#}"));
            }
        }
        Ok(report)
    }

    /// Hard-kill this coordinator instance: crash semantics for
    /// failover. Every owned VM is abandoned on its shard — no drain,
    /// no flush; unflushed cache contents are lost exactly as a power
    /// cut would lose them (flush-acknowledged bytes are already on the
    /// nodes). Leases, bandwidth and capacity reservations are
    /// deliberately NOT released: cleaning up the dead owner's mess is
    /// [`Coordinator::takeover`]'s job, in O(leases).
    pub fn halt(&self) {
        for (shard, table) in self.vms.iter().enumerate() {
            let names: Vec<String> =
                lock_unpoisoned(table).keys().cloned().collect();
            for name in names {
                let (reply, rx) = sync_channel(1);
                if self
                    .shards[shard]
                    .send(ShardControl::AbandonVm { name, reply })
                    .is_ok()
                {
                    let _ = rx.recv();
                }
            }
            lock_unpoisoned(table).clear();
        }
        for table in &self.jobs {
            lock_unpoisoned(table).clear();
        }
        *lock_unpoisoned(&self.control) = None;
    }

    /// Renew every lease this instance holds (the leader's heartbeat).
    /// Each renewal retries with jittered exponential backoff until
    /// that lease's own expiry — a transiently failing store must not
    /// cost ownership while the TTL still has runway. Returns how many
    /// leases were renewed.
    pub fn renew_leases(&self) -> Result<usize> {
        let Some((store, epoch, who)) = self.control_parts() else {
            return Ok(0);
        };
        let mut renewed = 0;
        for vm in self.vm_names() {
            let Some(l) = store.lease_of(&vm) else { continue };
            if l.holder != who {
                continue;
            }
            let clock = Arc::clone(&self.clock);
            let deadline = l.expires_ns.saturating_sub(clock.now());
            let policy = RetryPolicy::new(1_000_000, 100_000_000, deadline);
            policy.run(
                fnv1a(&vm),
                || clock.now(),
                |ns| clock.advance(ns),
                || store.renew_lease(epoch, &vm, &who, self.cfg.lease_ttl_ns),
            )?;
            renewed += 1;
        }
        Ok(renewed)
    }

    /// Refresh per-node logical-bytes counters as a rate-limited
    /// *background* [`CapacityScanJob`] instead of the synchronous
    /// [`Coordinator::refresh_capacity`] walk: recovery returns as soon
    /// as guest I/O is safe and the reporting counters converge behind
    /// it at `rate_bps`. Runs on its own thread against a scratch
    /// driver (it owns no VM chain) and appears in
    /// [`Coordinator::list_jobs`] like any other job.
    pub fn start_capacity_scan(&self, rate_bps: u64) -> Result<Arc<JobShared>> {
        self.reap_jobs();
        // the scan reads chains on every node: admit against each
        // node's maintenance budget
        let mut reservations = Vec::new();
        for n in self.nodes.nodes() {
            match self.scheduler.admit(&n.name, rate_bps) {
                Ok(r) => reservations.push(r),
                Err(e) => {
                    for r in &reservations {
                        self.scheduler.release(r);
                    }
                    return Err(e);
                }
            }
        }
        let shared = Arc::new(JobShared::new(
            &self.next_job_id(),
            JobKind::Scan,
            rate_bps,
        ));
        // lint: durable-before(scan-job)
        if let Err(e) = self.persist(&ControlRecord::Job {
            id: shared.id.clone(),
            vm: "(scan)".to_string(),
            kind: JobKind::Scan,
            capacity: None,
        }) {
            for r in &reservations {
                self.scheduler.release(r);
            }
            return Err(e);
        }
        // discovery (the one listing pass) happens at construction;
        // increments only walk chains
        let job = CapacityScanJob::new(Arc::clone(&self.nodes));
        let clock = Arc::clone(&self.clock);
        let cost = self.cfg.cost;
        let increment = self.cfg.job_increment_clusters.max(1);
        let worker = Arc::clone(&shared);
        let spawned = std::thread::Builder::new()
            .name("capacity-scan".into())
            .spawn(move || {
                let mut driver =
                    match crate::gc::scratch_driver(Arc::clone(&clock), cost) {
                        Ok(d) => d,
                        Err(e) => {
                            worker.set_error(format!("{e:#}"));
                            worker.set_state(crate::blockjob::JobState::Failed);
                            return;
                        }
                    };
                let fence = Arc::clone(driver.fence());
                let mut runner = JobRunner::new(
                    Box::new(job),
                    Arc::clone(&worker),
                    fence,
                    increment,
                    4 << 20,
                    clock.now(),
                );
                loop {
                    match runner.step(&mut driver, clock.now()) {
                        Step::Finished => break,
                        Step::Starved { ready_at } => {
                            // bounded clock quanta, like the shard idle
                            // loop (guests must not see one giant jump)
                            const SCAN_IDLE_QUANTUM_NS: u64 = 100_000_000;
                            let now = clock.now();
                            if ready_at > now {
                                clock.advance(
                                    (ready_at - now).min(SCAN_IDLE_QUANTUM_NS),
                                );
                            }
                        }
                        Step::Paused => std::thread::sleep(
                            std::time::Duration::from_millis(1),
                        ),
                        Step::Ran => {}
                    }
                }
            });
        if let Err(e) = spawned {
            for r in &reservations {
                self.scheduler.release(r);
            }
            // lint: durable-after(job-end)
            self.persist_best_effort(&ControlRecord::JobEnd {
                id: shared.id.clone(),
            });
            return Err(anyhow!("capacity-scan thread: {e}"));
        }
        // lint: mutates(scan-job)
        self.push_job(JobEntry {
            vm: "(scan)".to_string(),
            shared: Arc::clone(&shared),
            reservations,
            capacity: None,
            ended: false,
        });
        Ok(shared)
    }

    /// Control-plane status (`sqemu control status`).
    pub fn control_status(&self) -> Result<StoreStatus> {
        let (store, ..) = self
            .control_parts()
            .ok_or_else(|| anyhow!("no control plane attached"))?;
        Ok(store.status())
    }

    /// Stop the fleet and write the clean-shutdown marker: the next
    /// [`Coordinator::recover`] over this store trusts the log outright
    /// and skips even the per-lease qcheck walk.
    pub fn shutdown_clean(&self) -> Result<()> {
        self.shutdown();
        // lint: durable-after(shutdown-marker)
        self.persist(&ControlRecord::Shutdown)
    }

    pub fn data_mode_of(&self, name: &str) -> Result<DataMode> {
        self.meta(name, |m| m.data_mode)
    }

    pub fn cache_of(&self, name: &str) -> Result<CacheConfig> {
        self.meta(name, |m| m.cache)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
        // the shards Vec drops next: each executor gets a Shutdown and
        // is joined (Shard::drop)
    }
}

/// Client handle to a running VM's submission/completion rings.
///
/// The sync API (`read`/`write`/`flush`/...) submits one entry and waits
/// for its completion — same contract as the old channel round-trip. The
/// async API (`submit_read`/`submit_write`/`submit_flush`/
/// `submit_batch` + `complete`/`try_complete`) decouples the two halves:
/// a client can keep many operations in flight on one VM and reap
/// completions in any order, while the VM executes them in submission
/// order (per-VM program order is the ring's contract).
#[derive(Clone)]
pub struct VmClient {
    vm: String,
    rings: Arc<VmRings>,
    clock: Arc<VirtClock>,
    ctl: ShardHandle,
}

impl VmClient {
    // ----------------------------------------------------- async half

    /// Queue a read; returns its completion tag. Blocks only while the
    /// SQ is full (backpressure). The buffer is allocated by the
    /// executor and arrives with the completion.
    pub fn submit_read(&self, voff: u64, len: usize) -> Result<u64> {
        let tag = self.rings.next_tag();
        self.rings
            .submit(SqEntry::Read { tag, voff, len, t_enq: self.clock.now() })?;
        Ok(tag)
    }

    /// Queue a write; returns its completion tag.
    pub fn submit_write(&self, voff: u64, data: Vec<u8>) -> Result<u64> {
        let tag = self.rings.next_tag();
        self.rings
            .submit(SqEntry::Write { tag, voff, data, t_enq: self.clock.now() })?;
        Ok(tag)
    }

    /// Queue a batch; returns its completion tag.
    pub fn submit_batch(&self, ops: Vec<BatchOp>) -> Result<u64> {
        let tag = self.rings.next_tag();
        self.rings
            .submit(SqEntry::Batch { tag, ops, t_enq: self.clock.now() })?;
        Ok(tag)
    }

    /// Queue a flush barrier; completes only after everything submitted
    /// before it on this VM has completed.
    pub fn submit_flush(&self) -> Result<u64> {
        let tag = self.rings.next_tag();
        self.rings
            .submit(SqEntry::Flush { tag, t_enq: self.clock.now() })?;
        Ok(tag)
    }

    /// Block until the completion for `tag` arrives.
    pub fn complete(&self, tag: u64) -> Result<RingReply> {
        self.rings.wait(tag)
    }

    /// Reap the completion for `tag` if it has arrived (`Ok(None)` =
    /// still in flight).
    pub fn try_complete(&self, tag: u64) -> Result<Option<RingReply>> {
        self.rings.try_wait(tag)
    }

    // ------------------------------------------------------ sync half

    pub fn read(&self, voff: u64, len: usize) -> Result<Vec<u8>> {
        let tag = self.submit_read(voff, len)?;
        match self.complete(tag)? {
            RingReply::Read(r) => r,
            other => bail!("mismatched completion for read: {other:?}"),
        }
    }

    pub fn write(&self, voff: u64, data: Vec<u8>) -> Result<()> {
        let tag = self.submit_write(voff, data)?;
        match self.complete(tag)? {
            RingReply::Write(r) => r,
            other => bail!("mismatched completion for write: {other:?}"),
        }
    }

    /// Submit a batch of operations as ONE ring entry. Ops execute in
    /// submission order on the owning shard; runs of consecutive
    /// reads/writes go through the driver's vectored path, so adjacent
    /// requests amortize slice resolution and merge device reads.
    pub fn submit(&self, ops: Vec<BatchOp>) -> Result<Vec<BatchReply>> {
        let tag = self.submit_batch(ops)?;
        match self.complete(tag)? {
            RingReply::Batch(r) => r,
            other => bail!("mismatched completion for batch: {other:?}"),
        }
    }

    /// Vectored read: every `(voff, len)` request answered with its own
    /// buffer, one ring entry for the lot.
    pub fn readv(&self, reqs: &[(u64, usize)]) -> Result<Vec<Vec<u8>>> {
        let ops = reqs
            .iter()
            .map(|&(voff, len)| BatchOp::Read { voff, len })
            .collect();
        Ok(self
            .submit(ops)?
            .into_iter()
            .map(|r| match r {
                BatchReply::Read(buf) => buf,
                BatchReply::Write => Vec::new(),
            })
            .collect())
    }

    /// Vectored write: all `(voff, data)` pairs in one ring entry.
    pub fn writev(&self, reqs: Vec<(u64, Vec<u8>)>) -> Result<()> {
        let ops = reqs
            .into_iter()
            .map(|(voff, data)| BatchOp::Write { voff, data })
            .collect();
        self.submit(ops)?;
        Ok(())
    }

    pub fn flush(&self) -> Result<()> {
        let tag = self.submit_flush()?;
        match self.complete(tag)? {
            RingReply::Flush(r) => r,
            other => bail!("mismatched completion for flush: {other:?}"),
        }
    }

    /// Live SQ occupancy and capacity of this VM's submission ring.
    pub fn ring_occupancy(&self) -> (usize, usize) {
        (self.rings.sq_len(), self.rings.sq_capacity())
    }

    pub fn counters(&self) -> Result<CounterSnapshot> {
        let (reply, rx) = sync_channel(1);
        self.ctl
            .send(ShardControl::Counters { vm: self.vm.clone(), reply })
            .map_err(|_| anyhow!("vm worker gone"))?;
        rx.recv().map_err(|_| anyhow!("vm worker gone"))
    }

    fn with_chain(
        &self,
        f: Box<dyn FnOnce(&mut Chain) -> Result<String> + Send>,
    ) -> Result<Result<String>> {
        let (reply, rx) = sync_channel(1);
        self.ctl
            .send(ShardControl::WithChain { vm: self.vm.clone(), f, reply })
            .map_err(|_| anyhow!("vm worker gone"))?;
        Ok(rx.recv().map_err(|_| anyhow!("vm worker gone"))?)
    }
}
