//! The coordinator server: VM fleet management over a storage-node set.
//!
//! Architecture (thread-per-VM, like one Qemu process per VM):
//!
//! ```text
//!  clients ──► VmClient ──► bounded queue ──► VM worker thread
//!                               │                 │ owns the Driver
//!                       (backpressure =           │ (vanilla | sqemu)
//!                        full queue blocks)       ▼
//!                                          Chain on NodeSet
//!  control plane: launch / snapshot / stream / stop, bulk translation
//! ```

use super::batcher::BulkTranslator;
use super::placement::NodeSet;
use super::stats::{VmStats, VmStatsSnapshot};
use super::streaming::{StreamReport, StreamingOrchestrator};
use crate::cache::CacheConfig;
use crate::chaingen::ChainSpec;
use crate::metrics::clock::{CostModel, VirtClock};
use crate::metrics::counters::CounterSnapshot;
use crate::metrics::memory::MemoryAccountant;
use crate::qcow::image::DataMode;
use crate::qcow::{snapshot, Chain};
use crate::runtime::service::RuntimeService;
use crate::vdisk::scalable::ScalableDriver;
use crate::vdisk::vanilla::VanillaDriver;
use crate::vdisk::{Driver, DriverKind};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Fleet-level configuration.
pub struct CoordinatorConfig {
    pub cost: CostModel,
    /// Per-VM request queue depth (backpressure bound).
    pub queue_depth: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { cost: CostModel::default(), queue_depth: 64 }
    }
}

/// Per-VM launch configuration.
#[derive(Clone, Debug)]
pub struct VmConfig {
    pub driver: DriverKind,
    pub cache: CacheConfig,
    /// Open an existing chain by active-volume name, or generate one.
    pub chain: VmChain,
}

#[derive(Clone, Debug)]
pub enum VmChain {
    Existing { active_name: String, data_mode: DataMode },
    Generate(ChainSpec),
}

enum Request {
    Read { voff: u64, len: usize, reply: SyncSender<Result<Vec<u8>>> },
    Write { voff: u64, data: Vec<u8>, reply: SyncSender<Result<()>> },
    Flush { reply: SyncSender<Result<()>> },
    Counters { reply: SyncSender<CounterSnapshot> },
    /// Pause the worker and hand the chain to `f` (snapshot/stream).
    WithChain {
        f: Box<dyn FnOnce(&mut Chain) -> Result<String> + Send>,
        reply: SyncSender<Result<String>>,
    },
    Stop,
}

struct VmHandle {
    tx: SyncSender<Request>,
    join: Option<JoinHandle<()>>,
    stats: Arc<VmStats>,
    driver_kind: DriverKind,
    cache: CacheConfig,
    data_mode: DataMode,
}

/// The coordinator: owns nodes, VMs and the AOT runtime.
pub struct Coordinator {
    pub nodes: Arc<NodeSet>,
    pub clock: Arc<VirtClock>,
    pub acct: Arc<MemoryAccountant>,
    cfg: CoordinatorConfig,
    runtime: Option<RuntimeService>,
    vms: Mutex<HashMap<String, VmHandle>>,
}

impl Coordinator {
    pub fn new(
        nodes: Arc<NodeSet>,
        clock: Arc<VirtClock>,
        cfg: CoordinatorConfig,
        runtime: Option<RuntimeService>,
    ) -> Arc<Coordinator> {
        Arc::new(Coordinator {
            nodes,
            clock,
            acct: MemoryAccountant::new(),
            cfg,
            runtime,
            vms: Mutex::new(HashMap::new()),
        })
    }

    /// Convenience: a coordinator over `n` fresh unlimited nodes.
    pub fn with_fresh_nodes(n: usize) -> Result<Arc<Coordinator>> {
        let clock = VirtClock::new();
        let nodes = (0..n)
            .map(|i| {
                crate::storage::node::StorageNode::new(
                    &format!("node-{i}"),
                    clock.clone(),
                    CostModel::default(),
                )
            })
            .collect();
        let runtime = RuntimeService::try_default();
        Ok(Coordinator::new(
            Arc::new(NodeSet::new(nodes)?),
            clock,
            CoordinatorConfig::default(),
            runtime,
        ))
    }

    pub fn translator(&self) -> BulkTranslator {
        BulkTranslator::new(self.runtime.clone())
    }

    pub fn streaming(&self) -> StreamingOrchestrator {
        StreamingOrchestrator::new(self.runtime.clone())
    }

    fn build_driver(
        &self,
        chain: Chain,
        cfg: &VmConfig,
    ) -> Box<dyn Driver + Send> {
        match cfg.driver {
            DriverKind::Vanilla => Box::new(VanillaDriver::new(
                chain,
                cfg.cache,
                self.clock.clone(),
                self.cfg.cost,
                self.acct.clone(),
            )),
            DriverKind::Scalable => Box::new(ScalableDriver::new(
                chain,
                cfg.cache,
                self.clock.clone(),
                self.cfg.cost,
                self.acct.clone(),
            )),
        }
    }

    /// Launch a VM: open/generate its chain and start its worker thread.
    pub fn launch_vm(self: &Arc<Self>, name: &str, cfg: VmConfig) -> Result<VmClient> {
        let mut vms = self.vms.lock().unwrap();
        if vms.contains_key(name) {
            bail!("vm '{name}' already running");
        }
        let (chain, data_mode) = match &cfg.chain {
            VmChain::Existing { active_name, data_mode } => (
                Chain::open(self.nodes.as_ref(), active_name, *data_mode)?,
                *data_mode,
            ),
            VmChain::Generate(spec) => (
                crate::chaingen::generate(self.nodes.as_ref(), spec)?,
                spec.data_mode,
            ),
        };
        let driver = self.build_driver(chain, &cfg);
        let stats = Arc::new(VmStats::default());
        let (tx, rx) = sync_channel::<Request>(self.cfg.queue_depth);
        let worker_stats = Arc::clone(&stats);
        let vm_name = name.to_string();
        let join = std::thread::Builder::new()
            .name(format!("vm-{name}"))
            .spawn(move || worker_loop(vm_name, driver, rx, worker_stats))
            .expect("spawn vm worker");
        vms.insert(
            name.to_string(),
            VmHandle {
                tx: tx.clone(),
                join: Some(join),
                stats,
                driver_kind: cfg.driver,
                cache: cfg.cache,
                data_mode,
            },
        );
        Ok(VmClient { tx })
    }

    /// Get a fresh client handle for a running VM.
    pub fn client(&self, name: &str) -> Result<VmClient> {
        let vms = self.vms.lock().unwrap();
        let h = vms.get(name).ok_or_else(|| anyhow!("no vm '{name}'"))?;
        Ok(VmClient { tx: h.tx.clone() })
    }

    pub fn vm_stats(&self, name: &str) -> Result<VmStatsSnapshot> {
        let vms = self.vms.lock().unwrap();
        let h = vms.get(name).ok_or_else(|| anyhow!("no vm '{name}'"))?;
        Ok(h.stats.snapshot())
    }

    pub fn vm_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.vms.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Snapshot a running VM's disk: pause (drain), snapshot, swap the
    /// worker onto the lengthened chain.
    pub fn snapshot_vm(self: &Arc<Self>, name: &str, new_file: &str) -> Result<u64> {
        let (kind, stats) = {
            let vms = self.vms.lock().unwrap();
            let h = vms.get(name).ok_or_else(|| anyhow!("no vm '{name}'"))?;
            (h.driver_kind, Arc::clone(&h.stats))
        };
        let client = self.client(name)?;
        let nodes = Arc::clone(&self.nodes);
        let new_file = new_file.to_string();
        let t0 = self.clock.now();
        client.with_chain(Box::new(move |chain| {
            match kind {
                DriverKind::Scalable => {
                    snapshot::snapshot_sqemu(chain, nodes.as_ref(), &new_file)?
                }
                DriverKind::Vanilla => {
                    snapshot::snapshot_vanilla(chain, nodes.as_ref(), &new_file)?
                }
            }
            Ok(new_file.clone())
        }))??;
        stats.snapshots.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(self.clock.now() - t0)
    }

    /// Stream-merge a window of a running VM's chain (paused).
    pub fn stream_vm(self: &Arc<Self>, name: &str, from: u16, to: u16) -> Result<StreamReport> {
        let stats = {
            let vms = self.vms.lock().unwrap();
            let h = vms.get(name).ok_or_else(|| anyhow!("no vm '{name}'"))?;
            Arc::clone(&h.stats)
        };
        let orch = self.streaming();
        let client = self.client(name)?;
        let t0 = self.clock.now();
        let report_json = client.with_chain(Box::new(move |chain| {
            let report = orch.merge(chain, from, to)?;
            Ok(format!(
                "{} {} {} {}",
                report.planned_clusters, report.copied_clusters,
                report.len_before, report.len_after
            ))
        }))??;
        stats.streams.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let parts: Vec<u64> = report_json
            .split_whitespace()
            .map(|p| p.parse().unwrap_or(0))
            .collect();
        Ok(StreamReport {
            from,
            to,
            planned_clusters: parts[0],
            copied_clusters: parts[1],
            len_before: parts[2] as usize,
            len_after: parts[3] as usize,
            merge_ns: self.clock.now() - t0,
        })
    }

    /// Stop one VM (flushes its caches).
    pub fn stop_vm(&self, name: &str) -> Result<()> {
        let mut vms = self.vms.lock().unwrap();
        let mut h = vms.remove(name).ok_or_else(|| anyhow!("no vm '{name}'"))?;
        let _ = h.tx.send(Request::Stop);
        if let Some(j) = h.join.take() {
            let _ = j.join();
        }
        Ok(())
    }

    /// Stop the whole fleet.
    pub fn shutdown(&self) {
        let names = self.vm_names();
        for n in names {
            let _ = self.stop_vm(&n);
        }
    }

    pub fn data_mode_of(&self, name: &str) -> Result<DataMode> {
        let vms = self.vms.lock().unwrap();
        Ok(vms
            .get(name)
            .ok_or_else(|| anyhow!("no vm '{name}'"))?
            .data_mode)
    }

    pub fn cache_of(&self, name: &str) -> Result<CacheConfig> {
        let vms = self.vms.lock().unwrap();
        Ok(vms.get(name).ok_or_else(|| anyhow!("no vm '{name}'"))?.cache)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let names: Vec<String> = self.vms.lock().unwrap().keys().cloned().collect();
        for n in names {
            let _ = self.stop_vm(&n);
        }
    }
}

/// Client handle to a running VM's request queue.
#[derive(Clone)]
pub struct VmClient {
    tx: SyncSender<Request>,
}

impl VmClient {
    pub fn read(&self, voff: u64, len: usize) -> Result<Vec<u8>> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::Read { voff, len, reply })
            .map_err(|_| anyhow!("vm worker gone"))?;
        rx.recv().map_err(|_| anyhow!("vm worker gone"))?
    }

    pub fn write(&self, voff: u64, data: Vec<u8>) -> Result<()> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::Write { voff, data, reply })
            .map_err(|_| anyhow!("vm worker gone"))?;
        rx.recv().map_err(|_| anyhow!("vm worker gone"))?
    }

    pub fn flush(&self) -> Result<()> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::Flush { reply })
            .map_err(|_| anyhow!("vm worker gone"))?;
        rx.recv().map_err(|_| anyhow!("vm worker gone"))?
    }

    pub fn counters(&self) -> Result<CounterSnapshot> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::Counters { reply })
            .map_err(|_| anyhow!("vm worker gone"))?;
        rx.recv().map_err(|_| anyhow!("vm worker gone"))
    }

    fn with_chain(
        &self,
        f: Box<dyn FnOnce(&mut Chain) -> Result<String> + Send>,
    ) -> Result<Result<String>> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::WithChain { f, reply })
            .map_err(|_| anyhow!("vm worker gone"))?;
        Ok(rx.recv().map_err(|_| anyhow!("vm worker gone"))?)
    }
}

/// The worker: single owner of the VM's driver. Chain-level operations
/// (snapshot/stream) tear the driver down, run on the bare chain, and
/// rebuild it — mirroring how the provider pauses a VM's I/O for these.
fn worker_loop(
    _name: String,
    mut driver: Box<dyn Driver + Send>,
    rx: Receiver<Request>,
    stats: Arc<VmStats>,
) {
    use std::sync::atomic::Ordering::Relaxed;
    while let Ok(req) = rx.recv() {
        match req {
            Request::Read { voff, len, reply } => {
                let mut buf = vec![0u8; len];
                let r = driver.read(voff, &mut buf).map(|()| buf);
                stats.reads.fetch_add(1, Relaxed);
                stats.bytes_read.fetch_add(len as u64, Relaxed);
                let _ = reply.send(r);
            }
            Request::Write { voff, data, reply } => {
                let n = data.len() as u64;
                let r = driver.write(voff, &data);
                stats.writes.fetch_add(1, Relaxed);
                stats.bytes_written.fetch_add(n, Relaxed);
                let _ = reply.send(r);
            }
            Request::Flush { reply } => {
                let _ = reply.send(driver.flush());
            }
            Request::Counters { reply } => {
                let _ = reply.send(driver.counters());
            }
            Request::WithChain { f, reply } => {
                let r = (|| -> Result<String> {
                    driver.flush()?;
                    let out = f(driver.chain_mut())?;
                    driver.reopen()?;
                    Ok(out)
                })();
                let _ = reply.send(r);
            }
            Request::Stop => {
                let _ = driver.flush();
                break;
            }
        }
    }
}
