//! The coordinator server: VM fleet management over a storage-node set.
//!
//! Architecture (thread-per-VM, like one Qemu process per VM):
//!
//! ```text
//!  clients ──► VmClient ──► bounded queue ──► VM worker thread
//!                               │                 │ owns the Driver
//!                       (backpressure =           │ (vanilla | sqemu)
//!                        full queue blocks)       │ + at most one live
//!                                                 ▼   block-job runner
//!                                          Chain on NodeSet
//!  control plane: launch / snapshot / stream / stop, bulk translation,
//!  live block jobs (admission via the per-node JobScheduler)
//! ```
//!
//! Live jobs and guest requests interleave on the worker thread: after
//! every guest request the worker gives the job runner one bounded step,
//! and while the queue is idle it drains the job continuously (advancing
//! the virtual clock across rate-limiter stalls). Guest requests always
//! preempt the next increment, so the guest-visible latency tail is
//! bounded by one increment — the contrast with the offline
//! [`Coordinator::stream_vm`] pause is the subject of
//! `benches/fig20_live_blockjobs.rs`.

use super::batcher::BulkTranslator;
use super::placement::NodeSet;
use super::stats::{VmStats, VmStatsSnapshot};
use super::streaming::{StreamReport, StreamingOrchestrator};
use crate::blockjob::scheduler::{JobScheduler, Reservation};
use crate::blockjob::{
    BlockJob, JobFence, JobKind, JobRunner, JobShared, JobStatus, LiveStampJob,
    LiveStreamJob, Step,
};
use crate::cache::CacheConfig;
use crate::chaingen::ChainSpec;
use crate::gc::{GcJob, GcRegistry, GcReport};
use crate::metrics::clock::{CostModel, VirtClock};
use crate::metrics::counters::CounterSnapshot;
use crate::metrics::memory::MemoryAccountant;
use crate::dedup::{chain_logical_bytes, CapacityPolicy, DedupIndex};
use crate::qcow::image::DataMode;
use crate::qcow::{qcheck, snapshot, Chain};
use crate::migrate::rebalance::{NodePressure, RebalancePlan, VmFootprint};
use crate::runtime::service::RuntimeService;
use crate::storage::node::StorageNode;
use crate::util::lock_unpoisoned;
use crate::vdisk::scalable::ScalableDriver;
use crate::vdisk::vanilla::VanillaDriver;
use crate::vdisk::{Driver, DriverKind};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Fleet-level configuration.
pub struct CoordinatorConfig {
    pub cost: CostModel,
    /// Per-VM request queue depth (backpressure bound).
    pub queue_depth: usize,
    /// Aggregate background-job bandwidth budget per storage node
    /// (bytes/second) — the admission ceiling of the [`JobScheduler`].
    pub job_budget_bps: u64,
    /// Clusters a job may process per increment (the guest's worst-case
    /// wait behind one job step).
    pub job_increment_clusters: u64,
    /// Enable the capacity subsystem fleet-wide: every launched driver
    /// gets zero detection, compression and content-addressed dedup
    /// through the coordinator's shared [`DedupIndex`]
    /// ([`crate::dedup::CapacityPolicy::full`]). Off by default — the
    /// write path is then bit-for-bit the pre-subsystem one.
    pub capacity: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            cost: CostModel::default(),
            queue_depth: 64,
            job_budget_bps: 512 << 20,
            job_increment_clusters: 32,
            capacity: false,
        }
    }
}

/// Per-VM launch configuration.
#[derive(Clone, Debug)]
pub struct VmConfig {
    pub driver: DriverKind,
    pub cache: CacheConfig,
    /// Open an existing chain by active-volume name, or generate one.
    pub chain: VmChain,
}

#[derive(Clone, Debug)]
pub enum VmChain {
    Existing { active_name: String, data_mode: DataMode },
    Generate(ChainSpec),
}

/// Parameters of a live block job (`sqemu job start`).
#[derive(Clone, Copy, Debug)]
pub struct JobSpec {
    pub kind: JobKind,
    /// Bytes/second of job I/O; 0 = unlimited (reserves the node's whole
    /// maintenance budget at admission).
    pub rate_bps: u64,
    /// Create the job paused; it holds its bandwidth reservation but
    /// runs no increments until [`Coordinator::resume_job`].
    pub start_paused: bool,
}

impl JobSpec {
    pub fn stream(rate_bps: u64) -> JobSpec {
        JobSpec { kind: JobKind::Stream, rate_bps, start_paused: false }
    }

    pub fn stamp(rate_bps: u64) -> JobSpec {
        JobSpec { kind: JobKind::Stamp, rate_bps, start_paused: false }
    }

    pub fn paused(mut self) -> JobSpec {
        self.start_paused = true;
        self
    }
}

/// Outcome of [`Coordinator::recover`]: the crash-recovery sweep a node
/// runs over its images before admitting guest I/O.
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    /// Image files found and checked.
    pub images_checked: u64,
    /// Images `qcheck --repair` had to change.
    pub images_repaired: u64,
    /// Chain heads walked for cross-file validation.
    pub chains_checked: u64,
    /// Chains that needed a chain-level repair pass.
    pub chains_repaired: u64,
    /// Interrupted migrations resolved target-authoritative (journal
    /// committed: superseded source copies deleted).
    pub migrations_committed: u64,
    /// Interrupted migrations rolled back source-authoritative (no
    /// commit record: partial target copies deleted).
    pub migrations_rolled_back: u64,
    /// File names still present on more than one node after migration
    /// resolution — should be empty; survivors indicate corruption.
    pub duplicate_files: Vec<String>,
    /// Files that would not open/repair (orphans of interrupted creates,
    /// foreign files) with the reason — GC's business, not a hard error.
    pub unopenable: Vec<String>,
}

/// Outcome of [`Coordinator::rebalance`].
#[derive(Clone, Debug)]
pub struct RebalanceReport {
    /// The planner's verdict (moves + before/projected ratios).
    pub plan: RebalancePlan,
    /// Moves actually executed (0 on a dry run).
    pub executed: usize,
    /// Fleet max/min committed-pressure ratio after execution (equals
    /// the pre-plan ratio on a dry run).
    pub final_ratio: f64,
}

/// One operation of a batched guest submission ([`VmClient::submit`]).
#[derive(Debug)]
pub enum BatchOp {
    Read { voff: u64, len: usize },
    Write { voff: u64, data: Vec<u8> },
}

/// Per-operation result of a batch, in submission order.
#[derive(Debug)]
pub enum BatchReply {
    Read(Vec<u8>),
    Write,
}

enum Request {
    Read { voff: u64, len: usize, t_enq: u64, reply: SyncSender<Result<Vec<u8>>> },
    Write { voff: u64, data: Vec<u8>, t_enq: u64, reply: SyncSender<Result<()>> },
    /// A guest-built batch: executed in order, reads/writes grouped
    /// through the driver's vectored entry points — one channel
    /// round-trip for the whole set.
    Batch { ops: Vec<BatchOp>, t_enq: u64, reply: SyncSender<Result<Vec<BatchReply>>> },
    Flush { reply: SyncSender<Result<()>> },
    Counters { reply: SyncSender<CounterSnapshot> },
    /// Pause the worker and hand the chain to `f` (snapshot/stream).
    WithChain {
        f: Box<dyn FnOnce(&mut Chain) -> Result<String> + Send>,
        reply: SyncSender<Result<String>>,
    },
    /// Begin a live block job on this VM's worker.
    JobStart {
        builder: JobBuilder,
        shared: Arc<JobShared>,
        increment_clusters: u64,
        reply: SyncSender<Result<()>>,
    },
    Stop,
}

/// Constructs a job on the worker thread, where the driver's chain and
/// fence live. Stream/stamp builders are trivial closures; the migration
/// builder captures the node set, GC registry and target so the
/// [`crate::migrate::MirrorJob`] can journal and create its target
/// copies at start.
type JobBuilder =
    Box<dyn FnOnce(&Chain, &Arc<JobFence>) -> Result<Box<dyn BlockJob>> + Send>;

struct VmHandle {
    tx: SyncSender<Request>,
    join: Option<JoinHandle<()>>,
    stats: Arc<VmStats>,
    driver_kind: DriverKind,
    cache: CacheConfig,
    data_mode: DataMode,
}

/// Registry entry for a job: its cross-thread handle plus whatever must
/// be given back once the job is terminal — bandwidth reservations
/// (migrations hold one per involved node) and, for migrations, the
/// capacity reservation on the recipient.
struct JobEntry {
    vm: String,
    shared: Arc<JobShared>,
    reservations: Vec<Reservation>,
    capacity: Option<(Arc<StorageNode>, u64)>,
}

/// The coordinator: owns nodes, VMs, the AOT runtime, the job ledger and
/// the GC reference registry.
pub struct Coordinator {
    pub nodes: Arc<NodeSet>,
    pub clock: Arc<VirtClock>,
    pub acct: Arc<MemoryAccountant>,
    cfg: CoordinatorConfig,
    runtime: Option<RuntimeService>,
    vms: Mutex<HashMap<String, VmHandle>>,
    scheduler: JobScheduler,
    jobs: Mutex<Vec<JobEntry>>,
    next_job_id: Mutex<u64>,
    gc: Arc<GcRegistry>,
    /// Fleet-wide content-addressed extent index (volatile accelerator;
    /// see [`crate::dedup::DedupIndex`]). Always present — drivers only
    /// consult it when [`CoordinatorConfig::capacity`] is on.
    dedup: Arc<DedupIndex>,
}

impl Coordinator {
    pub fn new(
        nodes: Arc<NodeSet>,
        clock: Arc<VirtClock>,
        cfg: CoordinatorConfig,
        runtime: Option<RuntimeService>,
    ) -> Arc<Coordinator> {
        let scheduler = JobScheduler::new(cfg.job_budget_bps);
        let gc = Arc::new(GcRegistry::new(Arc::clone(&nodes)));
        Arc::new(Coordinator {
            nodes,
            clock,
            acct: MemoryAccountant::new(),
            cfg,
            runtime,
            vms: Mutex::new(HashMap::new()),
            scheduler,
            jobs: Mutex::new(Vec::new()),
            next_job_id: Mutex::new(0),
            gc,
            dedup: Arc::new(DedupIndex::new()),
        })
    }

    /// The fleet dedup index (`sqemu dedup status` reads it).
    pub fn dedup_index(&self) -> &Arc<DedupIndex> {
        &self.dedup
    }

    /// Convenience: a coordinator over `n` fresh unlimited nodes.
    pub fn with_fresh_nodes(n: usize) -> Result<Arc<Coordinator>> {
        let clock = VirtClock::new();
        let nodes = (0..n)
            .map(|i| {
                crate::storage::node::StorageNode::new(
                    &format!("node-{i}"),
                    clock.clone(),
                    CostModel::default(),
                )
            })
            .collect();
        let runtime = RuntimeService::try_default();
        Ok(Coordinator::new(
            Arc::new(NodeSet::new(nodes)?),
            clock,
            CoordinatorConfig::default(),
            runtime,
        ))
    }

    pub fn translator(&self) -> BulkTranslator {
        BulkTranslator::new(self.runtime.clone())
    }

    pub fn streaming(&self) -> StreamingOrchestrator {
        StreamingOrchestrator::new(self.runtime.clone())
    }

    fn build_driver(
        &self,
        chain: Chain,
        cfg: &VmConfig,
    ) -> Box<dyn Driver + Send> {
        // the dedup context is pinned to the node holding the active
        // volume at launch; a later migration leaves old extents keyed
        // under the old node (a missed-sharing cost, never a corruption
        // — sharing re-verifies the extent file against the chain)
        let policy = if self.cfg.capacity {
            let node = self
                .nodes
                .locate(&chain.active().name)
                .unwrap_or_default();
            // warm the index with the chain's immutable backing extents
            // so clones over a shared golden base dedup against it from
            // their first write; best-effort — an unreadable backing
            // file only costs sharing, and qcheck already gated on it
            let _ = crate::dedup::seed_chain(&self.dedup, &node, &chain);
            Some(CapacityPolicy::full(Arc::clone(&self.dedup), &node))
        } else {
            None
        };
        let mut driver: Box<dyn Driver + Send> = match cfg.driver {
            DriverKind::Vanilla => Box::new(VanillaDriver::new(
                chain,
                cfg.cache,
                self.clock.clone(),
                self.cfg.cost,
                self.acct.clone(),
            )),
            DriverKind::Scalable => Box::new(ScalableDriver::new(
                chain,
                cfg.cache,
                self.clock.clone(),
                self.cfg.cost,
                self.acct.clone(),
            )),
        };
        if let Some(p) = policy {
            driver.set_capacity_policy(p);
        }
        driver
    }

    /// Launch a VM: open/generate its chain and start its worker thread.
    ///
    /// The fleet map is NOT held while the chain is opened or generated:
    /// chain construction is heavy and fallible, and holding the map
    /// across it both serialized launches and (worse) poisoned the whole
    /// fleet if construction panicked — one bad launch killed
    /// stats/list/launch for every other VM.
    pub fn launch_vm(self: &Arc<Self>, name: &str, cfg: VmConfig) -> Result<VmClient> {
        if lock_unpoisoned(&self.vms).contains_key(name) {
            bail!("vm '{name}' already running");
        }
        let (chain, data_mode) = match &cfg.chain {
            VmChain::Existing { active_name, data_mode } => {
                let chain =
                    Chain::open(self.nodes.as_ref(), active_name, *data_mode)?;
                // Recovery gate: a pre-existing Real chain may be the
                // survivor of a crash — it must pass (or be repaired to
                // pass) qcheck before guest I/O is admitted. Leaks count
                // too: a crash in the sanctioned refcount-before-
                // reference window leaves a leak-only chain (is_clean()
                // but leaked > 0) that only repair ever reclaims.
                // Synthetic chains are simulation fixtures, not crash
                // survivors — skip the walk (it would also charge the
                // shared node clock before the benchmark starts).
                if *data_mode == DataMode::Real {
                    let report = qcheck::check_chain(&chain)?;
                    if !report.is_clean() || report.leaked_clusters != 0 {
                        // repair mutates image files in place; a file
                        // shared with a *running* chain (GC refcount
                        // held by another VM) must not be rewritten
                        // under concurrent readers — that needs the
                        // quiesced startup pass instead
                        if chain.file_names().iter().any(|f| self.gc.refcount(f) > 0)
                        {
                            bail!(
                                "chain '{active_name}' needs repair but shares \
                                 files with running chains; quiesce the fleet \
                                 and run Coordinator::recover()"
                            );
                        }
                        qcheck::repair_chain(&chain)?;
                        let after = qcheck::check_chain(&chain)?;
                        if !after.is_clean() || after.leaked_clusters != 0 {
                            bail!(
                                "chain '{active_name}' unrecoverable: {} leaks, {}",
                                after.leaked_clusters,
                                after.errors.join("; ")
                            );
                        }
                    }
                }
                (chain, *data_mode)
            }
            VmChain::Generate(spec) => (
                crate::chaingen::generate(self.nodes.as_ref(), spec)?,
                spec.data_mode,
            ),
        };
        let mut vms = lock_unpoisoned(&self.vms);
        if vms.contains_key(name) {
            bail!("vm '{name}' already running");
        }
        // the chain's files are now referenced by this VM's chain (GC
        // refcounts; shared bases gain one reference per chain)
        self.gc.sync_chain(name, chain.file_names());
        let driver = self.build_driver(chain, &cfg);
        let stats = Arc::new(VmStats::default());
        let (tx, rx) = sync_channel::<Request>(self.cfg.queue_depth);
        let worker_stats = Arc::clone(&stats);
        let worker_clock = Arc::clone(&self.clock);
        let worker_gc = Arc::clone(&self.gc);
        let vm_name = name.to_string();
        let join = std::thread::Builder::new()
            .name(format!("vm-{name}"))
            .spawn(move || {
                // contain panics to this VM: the worker dies (its clients
                // see "vm worker gone"), the fleet does not. The shared
                // locks it might have held recover via lock_unpoisoned.
                let panic_stats = Arc::clone(&worker_stats);
                let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    move || {
                        worker_loop(
                            vm_name,
                            driver,
                            rx,
                            worker_stats,
                            worker_clock,
                            worker_gc,
                        )
                    },
                ));
                if caught.is_err() {
                    panic_stats.worker_panics.fetch_add(1, Relaxed);
                }
            })
            .expect("spawn vm worker");
        vms.insert(
            name.to_string(),
            VmHandle {
                tx: tx.clone(),
                join: Some(join),
                stats,
                driver_kind: cfg.driver,
                cache: cfg.cache,
                data_mode,
            },
        );
        Ok(VmClient { tx, clock: Arc::clone(&self.clock) })
    }

    /// Get a fresh client handle for a running VM.
    pub fn client(&self, name: &str) -> Result<VmClient> {
        let vms = lock_unpoisoned(&self.vms);
        let h = vms.get(name).ok_or_else(|| anyhow!("no vm '{name}'"))?;
        Ok(VmClient { tx: h.tx.clone(), clock: Arc::clone(&self.clock) })
    }

    pub fn vm_stats(&self, name: &str) -> Result<VmStatsSnapshot> {
        let vms = lock_unpoisoned(&self.vms);
        let h = vms.get(name).ok_or_else(|| anyhow!("no vm '{name}'"))?;
        Ok(h.stats.snapshot())
    }

    pub fn vm_names(&self) -> Vec<String> {
        let mut v: Vec<String> = lock_unpoisoned(&self.vms).keys().cloned().collect();
        v.sort();
        v
    }

    /// The file names of a running VM's chain, base first (pauses the
    /// worker for the read).
    pub fn chain_files(&self, name: &str) -> Result<Vec<String>> {
        let client = self.client(name)?;
        let joined =
            client.with_chain(Box::new(|chain| Ok(chain.file_names().join("\n"))))??;
        Ok(joined.lines().map(str::to_string).collect())
    }

    /// Re-declare a VM chain's file set to the GC registry (after any
    /// chain-shape change): files the chain dropped lose a reference and
    /// are condemned once nothing else references them.
    fn sync_vm_chain(&self, name: &str) -> Result<()> {
        let files = self.chain_files(name)?;
        self.gc.sync_chain(name, files);
        Ok(())
    }

    /// Snapshot a running VM's disk: pause (drain), snapshot, swap the
    /// worker onto the lengthened chain.
    pub fn snapshot_vm(self: &Arc<Self>, name: &str, new_file: &str) -> Result<u64> {
        let (kind, stats) = {
            let vms = lock_unpoisoned(&self.vms);
            let h = vms.get(name).ok_or_else(|| anyhow!("no vm '{name}'"))?;
            (h.driver_kind, Arc::clone(&h.stats))
        };
        let client = self.client(name)?;
        let nodes = Arc::clone(&self.nodes);
        let new_file = new_file.to_string();
        let t0 = self.clock.now();
        client.with_chain(Box::new(move |chain| {
            // chain-locality placement: the new head belongs on the node
            // already holding the chain's active volume, not wherever
            // least-used placement would scatter it (falls back to
            // pick_node when that node is out of headroom)
            let store = nodes.hinted(&chain.active().name);
            match kind {
                DriverKind::Scalable => {
                    snapshot::snapshot_sqemu(chain, &store, &new_file)?
                }
                DriverKind::Vanilla => {
                    snapshot::snapshot_vanilla(chain, &store, &new_file)?
                }
            }
            Ok(new_file.clone())
        }))??;
        stats.snapshots.fetch_add(1, Relaxed);
        self.sync_vm_chain(name)?;
        Ok(self.clock.now() - t0)
    }

    /// Stream-merge a window of a running VM's chain (paused — the
    /// offline baseline; [`Coordinator::start_job`] is the live path).
    pub fn stream_vm(self: &Arc<Self>, name: &str, from: u16, to: u16) -> Result<StreamReport> {
        let stats = {
            let vms = lock_unpoisoned(&self.vms);
            let h = vms.get(name).ok_or_else(|| anyhow!("no vm '{name}'"))?;
            Arc::clone(&h.stats)
        };
        let orch = self.streaming();
        let client = self.client(name)?;
        let t0 = self.clock.now();
        let report_json = client.with_chain(Box::new(move |chain| {
            let report = orch.merge(chain, from, to)?;
            Ok(format!(
                "{} {} {} {}",
                report.planned_clusters, report.copied_clusters,
                report.len_before, report.len_after
            ))
        }))??;
        stats.streams.fetch_add(1, Relaxed);
        // measure the disruption window before the GC bookkeeping below —
        // the registry sync pauses the worker again and must not inflate
        // the merge cost the benches compare live jobs against
        let merge_ns = self.clock.now() - t0;
        // the merged window's files just left the chain: hand them to GC
        self.sync_vm_chain(name)?;
        let parts: Vec<u64> = report_json
            .split_whitespace()
            .map(|p| p.parse().unwrap_or(0))
            .collect();
        Ok(StreamReport {
            from,
            to,
            planned_clusters: parts[0],
            copied_clusters: parts[1],
            len_before: parts[2] as usize,
            len_after: parts[3] as usize,
            merge_ns,
        })
    }

    // ------------------------------------------------------- live jobs

    /// Start a live block job on a running VM. Admission reserves
    /// `spec.rate_bps` of maintenance bandwidth on the storage node
    /// holding the VM's active volume; the reservation is released when
    /// the job reaches a terminal state (checked lazily by the job
    /// APIs). Returns the job's cross-thread handle.
    pub fn start_job(self: &Arc<Self>, vm: &str, spec: JobSpec) -> Result<Arc<JobShared>> {
        self.reap_jobs();
        let builder: JobBuilder = match spec.kind {
            JobKind::Gc => bail!("gc jobs own no chain; use Coordinator::run_gc"),
            JobKind::Mirror => {
                bail!("migrations carry a target node; use Coordinator::migrate_vm")
            }
            JobKind::Stream => Box::new(|chain, fence| {
                Ok(Box::new(LiveStreamJob::new(chain, Arc::clone(fence)))
                    as Box<dyn BlockJob>)
            }),
            JobKind::Stamp => Box::new(|chain, fence| {
                Ok(Box::new(LiveStampJob::new(chain, Arc::clone(fence)))
                    as Box<dyn BlockJob>)
            }),
        };
        let client = self.client(vm)?;
        // locate the active volume's node for admission
        let active_name =
            client.with_chain(Box::new(|chain| Ok(chain.active().name.clone())))??;
        let node = self.nodes.locate(&active_name).ok_or_else(|| {
            anyhow!("cannot locate the node holding '{active_name}' for job admission")
        })?;
        let reservation = self.scheduler.admit(&node, spec.rate_bps)?;
        let shared = Arc::new(JobShared::new(&self.next_job_id(), spec.kind, spec.rate_bps));
        if spec.start_paused {
            shared.pause();
        }
        if let Err(e) = self.send_job_start(&client, builder, &shared) {
            self.scheduler.release(&reservation);
            return Err(e);
        }
        self.note_job_started(vm);
        lock_unpoisoned(&self.jobs).push(JobEntry {
            vm: vm.to_string(),
            shared: Arc::clone(&shared),
            reservations: vec![reservation],
            capacity: None,
        });
        Ok(shared)
    }

    fn next_job_id(&self) -> String {
        let mut n = lock_unpoisoned(&self.next_job_id);
        *n += 1;
        format!("job-{}", *n)
    }

    fn send_job_start(
        &self,
        client: &VmClient,
        builder: JobBuilder,
        shared: &Arc<JobShared>,
    ) -> Result<()> {
        let (reply, rx) = sync_channel(1);
        client
            .tx
            .send(Request::JobStart {
                builder,
                shared: Arc::clone(shared),
                increment_clusters: self.cfg.job_increment_clusters,
                reply,
            })
            .map_err(|_| anyhow!("vm worker gone"))?;
        rx.recv().map_err(|_| anyhow!("vm worker gone"))?
    }

    fn note_job_started(&self, vm: &str) {
        let vms = lock_unpoisoned(&self.vms);
        if let Some(h) = vms.get(vm) {
            h.stats.jobs_started.fetch_add(1, Relaxed);
        }
    }

    // ------------------------------------------------------- migration

    /// Live-migrate a VM's whole chain to storage node `target` while
    /// the guest keeps serving: a [`crate::migrate::MirrorJob`] admitted
    /// like any other live job (bandwidth reserved on the recipient and
    /// every donor node) plus a *capacity* reservation on the recipient
    /// for the chain's bytes, held until the job is terminal so
    /// placement cannot overcommit the node mid-copy. The reservation is
    /// released by the lazy reap (any job API or [`Coordinator::wait_job`]);
    /// between switchover and reap the recipient is conservatively
    /// over-committed by the landed bytes. Returns the job handle; poll
    /// it or [`Coordinator::wait_job`] it.
    pub fn migrate_vm(
        self: &Arc<Self>,
        vm: &str,
        target: &str,
        rate_bps: u64,
    ) -> Result<Arc<JobShared>> {
        self.reap_jobs();
        let client = self.client(vm)?;
        let target_node = self
            .nodes
            .node_named(target)
            .ok_or_else(|| anyhow!("no storage node '{target}'"))?;
        let files = self.chain_files(vm)?;
        let mut moved_bytes = 0u64;
        let mut admit_nodes: Vec<String> = vec![target_node.name.clone()];
        let mut any = false;
        for f in &files {
            let node = self
                .nodes
                .node_of(f)
                .ok_or_else(|| anyhow!("cannot locate '{f}' in the node set"))?;
            if node.name == target_node.name {
                continue;
            }
            any = true;
            moved_bytes += node.open_file(f).map(|b| b.stored_bytes()).unwrap_or(0);
            if !admit_nodes.contains(&node.name) {
                admit_nodes.push(node.name.clone());
            }
        }
        if !any {
            bail!("vm '{vm}' chain already lives on node '{target}'");
        }
        target_node.reserve(moved_bytes)?;
        let mut reservations: Vec<Reservation> = Vec::new();
        for n in &admit_nodes {
            match self.scheduler.admit(n, rate_bps) {
                Ok(r) => reservations.push(r),
                Err(e) => {
                    for r in &reservations {
                        self.scheduler.release(r);
                    }
                    target_node.release(moved_bytes);
                    return Err(e);
                }
            }
        }
        let shared =
            Arc::new(JobShared::new(&self.next_job_id(), JobKind::Mirror, rate_bps));
        let nodes = Arc::clone(&self.nodes);
        let gc = Arc::clone(&self.gc);
        let (vm_id, target_name) = (vm.to_string(), target_node.name.clone());
        let builder: JobBuilder = Box::new(move |chain, _fence| {
            Ok(Box::new(crate::migrate::MirrorJob::new(
                chain,
                nodes,
                gc,
                &target_name,
                &vm_id,
            )?) as Box<dyn BlockJob>)
        });
        if let Err(e) = self.send_job_start(&client, builder, &shared) {
            for r in &reservations {
                self.scheduler.release(r);
            }
            target_node.release(moved_bytes);
            return Err(e);
        }
        self.note_job_started(vm);
        lock_unpoisoned(&self.jobs).push(JobEntry {
            vm: vm.to_string(),
            shared: Arc::clone(&shared),
            reservations,
            capacity: Some((target_node, moved_bytes)),
        });
        Ok(shared)
    }

    /// Block until `shared` is terminal (the worker drains the job while
    /// its queue is idle), release its reservations, and return the
    /// final status.
    pub fn wait_job(&self, shared: &Arc<JobShared>) -> JobStatus {
        while !shared.state().is_terminal() {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        self.reap_jobs();
        shared.status()
    }

    /// Plan (and unless `dry_run`, execute) a fleet rebalance: read
    /// per-node pressure, pick donor→recipient chain moves under
    /// `threshold` (max/min committed-pressure ratio), and drive each
    /// move through [`Coordinator::migrate_vm`] sequentially. Returns
    /// the plan and the ratio it left the fleet at.
    pub fn rebalance(
        self: &Arc<Self>,
        threshold: f64,
        rate_bps: u64,
        dry_run: bool,
    ) -> Result<RebalanceReport> {
        let pressures: Vec<NodePressure> = self
            .nodes
            .nodes()
            .iter()
            .map(|n| NodePressure {
                name: n.name.clone(),
                pressure: n.committed_bytes(),
                capacity: n.capacity,
            })
            .collect();
        let mut footprints: Vec<VmFootprint> = Vec::new();
        for vm in self.vm_names() {
            let files = self.chain_files(&vm)?;
            // BTreeMap: the dominant-node pick must break ties
            // deterministically (dry-run and execution see one plan)
            let mut per_node: std::collections::BTreeMap<String, u64> =
                std::collections::BTreeMap::new();
            let mut total = 0u64;
            for f in &files {
                if let Some(node) = self.nodes.node_of(f) {
                    let bytes =
                        node.open_file(f).map(|b| b.stored_bytes()).unwrap_or(0);
                    *per_node.entry(node.name.clone()).or_default() += bytes;
                    total += bytes;
                }
            }
            // the planner needs both sides of a scattered chain: what a
            // move takes off the dominant node vs what it lands on the
            // recipient
            let Some((home, resident)) =
                per_node.into_iter().max_by_key(|(_, bytes)| *bytes)
            else {
                continue;
            };
            footprints.push(VmFootprint { vm, node: home, bytes: resident, total });
        }
        let plan = crate::migrate::plan(&pressures, &footprints, threshold, 16);
        let mut executed = 0usize;
        if !dry_run {
            for m in &plan.moves {
                let shared = self.migrate_vm(&m.vm, &m.to, rate_bps)?;
                let st = self.wait_job(&shared);
                if st.state != crate::blockjob::JobState::Completed {
                    bail!(
                        "rebalance: migration of '{}' to '{}' ended {}: {:?}",
                        m.vm,
                        m.to,
                        st.state.name(),
                        st.error
                    );
                }
                executed += 1;
            }
        }
        let final_ratio = crate::migrate::rebalance::pressure_ratio(
            &self
                .nodes
                .nodes()
                .iter()
                .map(|n| n.committed_bytes())
                .collect::<Vec<_>>(),
        );
        Ok(RebalanceReport { plan, executed, final_ratio })
    }

    /// All jobs ever started (newest last), with live status.
    pub fn list_jobs(&self) -> Vec<(String, JobStatus)> {
        self.reap_jobs();
        self.jobs
            .lock()
            .unwrap()
            .iter()
            .map(|e| (e.vm.clone(), e.shared.status()))
            .collect()
    }

    /// Status of one job by id.
    pub fn job_status(&self, id: &str) -> Result<JobStatus> {
        self.reap_jobs();
        self.jobs
            .lock()
            .unwrap()
            .iter()
            .find(|e| e.shared.id == id)
            .map(|e| e.shared.status())
            .ok_or_else(|| anyhow!("no job '{id}'"))
    }

    /// Request cooperative cancellation of a job.
    pub fn cancel_job(&self, id: &str) -> Result<()> {
        let jobs = lock_unpoisoned(&self.jobs);
        let e = jobs
            .iter()
            .find(|e| e.shared.id == id)
            .ok_or_else(|| anyhow!("no job '{id}'"))?;
        e.shared.cancel();
        Ok(())
    }

    pub fn pause_job(&self, id: &str) -> Result<()> {
        let jobs = lock_unpoisoned(&self.jobs);
        let e = jobs
            .iter()
            .find(|e| e.shared.id == id)
            .ok_or_else(|| anyhow!("no job '{id}'"))?;
        e.shared.pause();
        Ok(())
    }

    pub fn resume_job(&self, id: &str) -> Result<()> {
        let jobs = lock_unpoisoned(&self.jobs);
        let e = jobs
            .iter()
            .find(|e| e.shared.id == id)
            .ok_or_else(|| anyhow!("no job '{id}'"))?;
        e.shared.resume();
        Ok(())
    }

    // -------------------------------------------------- garbage collection

    /// The cross-chain reference registry (refcounts, deferred deletes).
    pub fn gc_registry(&self) -> &Arc<GcRegistry> {
        &self.gc
    }

    /// Rescan every chain's tables and refresh each node's cached
    /// logical-bytes counter ([`StorageNode::set_logical_bytes`]).
    /// Logical bytes are guest-addressable mapped bytes — what the fleet
    /// would store with no zero suppression, compression or dedup — and
    /// a chain's total is attributed to the node holding its active
    /// volume. Returns `(node, logical, physical)` per node. Physical
    /// pressure is live either way; this scan only feeds reporting
    /// (`sqemu node status`, fig24), so staleness between calls is fine.
    pub fn refresh_capacity(&self) -> Vec<(String, u64, u64)> {
        let mut backed: std::collections::HashSet<String> =
            std::collections::HashSet::new();
        let mut names: Vec<String> = Vec::new();
        for node in self.nodes.nodes() {
            for f in node.file_names() {
                if f.starts_with(crate::migrate::JOURNAL_PREFIX) {
                    continue;
                }
                let opened = node
                    .open_file(&f)
                    .and_then(|b| crate::qcow::Image::open(&f, b, DataMode::Real));
                if let Ok(img) = opened {
                    if let Some(b) = img.backing_name() {
                        backed.insert(b);
                    }
                    if !names.contains(&f) {
                        names.push(f);
                    }
                }
            }
        }
        let mut logical: HashMap<String, u64> = HashMap::new();
        for head in names.iter().filter(|n| !backed.contains(*n)) {
            let Some(node) = self.nodes.locate(head) else { continue };
            let Ok(chain) = Chain::open(self.nodes.as_ref(), head, DataMode::Real)
            else {
                continue;
            };
            if let Ok(bytes) = chain_logical_bytes(&chain) {
                *logical.entry(node).or_default() += bytes;
            }
        }
        self.nodes
            .nodes()
            .iter()
            .map(|n| {
                let l = logical.get(&n.name).copied().unwrap_or(0);
                n.set_logical_bytes(l);
                (n.name.clone(), l, n.used_bytes())
            })
            .collect()
    }

    /// Audit node files against chain reachability (`gc --dry-run`),
    /// plus the dedup index against file existence: an extent whose
    /// backing file is gone means the sweep's `prune_missing` wiring
    /// broke, and the audit flags it like any other leak.
    pub fn gc_audit(&self) -> crate::gc::AuditReport {
        let mut report = crate::gc::audit(self.nodes.as_ref(), &self.gc);
        report.stale_extents = self
            .dedup
            .stale_extents(|f| self.nodes.locate(f).is_some());
        report
    }

    /// Run a GC sweep: physically delete the deferred-delete set at
    /// `rate_bps` bytes/second of reclamation I/O (0 = unlimited). The
    /// sweep is a [`GcJob`] driven through the standard [`JobRunner`]
    /// (it appears in `list_jobs` and honours `cancel_job`), admitted
    /// against the maintenance budget of every node holding condemned
    /// files. Reclaimed bytes are attributed to the VMs whose chains
    /// dropped the files.
    pub fn run_gc(&self, rate_bps: u64) -> Result<GcReport> {
        self.reap_jobs();
        // admission: one reservation per node with condemned files
        // (named condemnations via the index, migration replicas via
        // their pinned node)
        let node_names = self.gc.condemned_nodes();
        let mut reservations = Vec::new();
        for n in &node_names {
            match self.scheduler.admit(n, rate_bps) {
                Ok(r) => reservations.push(r),
                Err(e) => {
                    for r in &reservations {
                        self.scheduler.release(r);
                    }
                    return Err(e);
                }
            }
        }
        let id = {
            let mut n = lock_unpoisoned(&self.next_job_id);
            *n += 1;
            format!("job-{}", *n)
        };
        let shared = Arc::new(JobShared::new(&id, JobKind::Gc, rate_bps));
        lock_unpoisoned(&self.jobs).push(JobEntry {
            vm: "(gc)".to_string(),
            shared: Arc::clone(&shared),
            reservations: Vec::new(),
            capacity: None,
        });
        let run = (|| -> Result<()> {
            let mut driver =
                crate::gc::scratch_driver(Arc::clone(&self.clock), self.cfg.cost)?;
            let fence = Arc::clone(driver.fence());
            let job = Box::new(GcJob::new(Arc::clone(&self.gc)));
            let mut runner = JobRunner::new(
                job,
                Arc::clone(&shared),
                fence,
                self.cfg.job_increment_clusters.max(1),
                4 << 20,
                self.clock.now(),
            );
            loop {
                match runner.step(&mut driver, self.clock.now()) {
                    Step::Finished => break,
                    Step::Starved { ready_at } => {
                        // advance the shared clock in bounded quanta, like
                        // the worker idle loop: VMs serving guests
                        // concurrently must not see one giant time jump
                        // attributed to their in-flight requests
                        const GC_IDLE_QUANTUM_NS: u64 = 100_000_000;
                        let now = self.clock.now();
                        if ready_at > now {
                            self.clock.advance((ready_at - now).min(GC_IDLE_QUANTUM_NS));
                        }
                    }
                    // run_gc is synchronous: wait out an external pause
                    // instead of spinning
                    Step::Paused => {
                        std::thread::sleep(std::time::Duration::from_millis(1))
                    }
                    Step::Ran => {}
                }
            }
            Ok(())
        })();
        for r in &reservations {
            self.scheduler.release(r);
        }
        run?;
        let t = shared.status();
        // per-VM attribution: bytes reclaimed from files each VM's chain
        // dropped (decommissioned chains have no VM entry left — their
        // share stays fleet-level in the registry totals)
        let by_origin = self.gc.drain_reclaimed_by();
        {
            let vms = lock_unpoisoned(&self.vms);
            for (origin, bytes) in by_origin {
                if let Some(h) = vms.get(&origin) {
                    h.stats.reclaimed_bytes.fetch_add(bytes, Relaxed);
                    h.stats.gc_runs.fetch_add(1, Relaxed);
                }
            }
        }
        if let Some(err) = t.error {
            bail!("gc sweep failed: {err}");
        }
        // extents stored in files the sweep just deleted leave the
        // dedup index with them (sharers' on-disk references were
        // release-gated before the files could be condemned)
        self.dedup
            .prune_missing(|f| self.nodes.locate(f).is_some());
        // committed migration journals whose replicas the sweep just
        // deleted have served their purpose (a journal must outlive the
        // source copies it covers, never the other way round)
        let journals_cleaned = crate::migrate::cleanup_journals(self.nodes.as_ref());
        Ok(GcReport {
            files_deleted: t.copied,
            reclaimed_bytes: t.bytes_copied,
            gc_ns: t.finished_ns.saturating_sub(t.started_ns),
            remaining_condemned: self.gc.condemned_count() as u64,
            journals_cleaned,
        })
    }

    /// Decommission a VM *and its chain*: stop the worker and release
    /// every file reference the chain held. Files referenced by no other
    /// chain are condemned for the next GC sweep — the snapshot-deletion
    /// path; shared bases survive as long as any other chain uses them.
    pub fn decommission_vm(&self, name: &str) -> Result<()> {
        self.stop_vm(name)?;
        self.gc.drop_chain(name);
        Ok(())
    }

    /// Crash-recovery pass over every image on this coordinator's
    /// nodes: each file that parses as an image gets `qcheck --repair`
    /// if dirty, then every chain head (an image no other image backs
    /// onto) is re-checked as a chain so cross-file stamps are validated
    /// too. Run at node startup, BEFORE launching VMs — the images must
    /// not be concurrently open ([`Coordinator::launch_vm`] additionally
    /// gates each `Existing` chain on a clean check at launch).
    pub fn recover(&self) -> RecoveryReport {
        let mut report = RecoveryReport::default();
        // Reboot semantics: only file bytes survived. Per-node volatile
        // bookkeeping (condemned marks, migration reservations, write
        // watches) is cleared and re-derived from durable state.
        for node in self.nodes.nodes() {
            node.clear_volatile();
        }
        // the dedup index is volatile too: only file bytes survive, and
        // every physical sharing is protected by on-disk cluster
        // refcounts or file-level GC references — the index is rebuilt
        // opportunistically as guests write
        self.dedup.clear();
        // Interrupted migrations first: every name must resolve to
        // exactly one authoritative copy (journal committed → target
        // wins, superseded sources deleted; else → source wins, partial
        // targets deleted) BEFORE the index is rebuilt or images opened.
        let mig = crate::migrate::recover_migrations(self.nodes.as_ref());
        report.migrations_committed = mig.committed;
        report.migrations_rolled_back = mig.rolled_back;
        for e in mig.errors {
            report.unopenable.push(e);
        }
        // The name→node index is volatile too: rebuild it from the
        // nodes' durable file lists (pre-fix, a freshly booted
        // coordinator could not locate any chain file).
        report.duplicate_files = self.nodes.rebuild_index();
        let mut backed: std::collections::HashSet<String> =
            std::collections::HashSet::new();
        let mut images: Vec<String> = Vec::new();
        for node in self.nodes.nodes() {
            for name in node.file_names() {
                if name.starts_with(crate::migrate::JOURNAL_PREFIX) {
                    continue; // control-plane metadata, not an image
                }
                let opened = node
                    .open_file(&name)
                    .and_then(|b| crate::qcow::Image::open(&name, b, DataMode::Real));
                let img = match opened {
                    Ok(img) => img,
                    Err(e) => {
                        report.unopenable.push(format!("{name}: {e:#}"));
                        continue;
                    }
                };
                report.images_checked += 1;
                if let Some(b) = img.backing_name() {
                    backed.insert(b);
                }
                images.push(name.clone());
                match qcheck::check_image(&img) {
                    Ok(r) if r.is_clean() && r.leaked_clusters == 0 => {}
                    _ => match qcheck::repair_image(&img) {
                        Ok(rep) if rep.changed() => report.images_repaired += 1,
                        Ok(_) => {}
                        Err(e) => {
                            report.unopenable.push(format!("{name}: repair: {e:#}"))
                        }
                    },
                }
            }
        }
        for head in images.iter().filter(|n| !backed.contains(*n)) {
            report.chains_checked += 1;
            let recovered = Chain::open(self.nodes.as_ref(), head, DataMode::Real)
                .and_then(|chain| {
                    let before = qcheck::check_chain(&chain)?;
                    if !before.is_clean() {
                        qcheck::repair_chain(&chain)?;
                        report.chains_repaired += 1;
                        let after = qcheck::check_chain(&chain)?;
                        if !after.is_clean() {
                            bail!("still dirty: {}", after.errors.join("; "));
                        }
                    }
                    Ok(())
                });
            if let Err(e) = recovered {
                report.unopenable.push(format!("chain {head}: {e:#}"));
            }
        }
        // the logical-bytes cache was cleared with the rest of the
        // volatile bookkeeping: rebuild it from the recovered chains
        self.refresh_capacity();
        report
    }

    /// Release bandwidth and capacity reservations of terminal jobs
    /// (lazy reaping). A completed migration's copied bytes are real
    /// usage on the recipient by now, so its capacity reservation is
    /// released either way — the files themselves keep the space.
    fn reap_jobs(&self) {
        let mut jobs = lock_unpoisoned(&self.jobs);
        for e in jobs.iter_mut() {
            if e.shared.state().is_terminal() {
                for r in e.reservations.drain(..) {
                    self.scheduler.release(&r);
                }
                if let Some((node, bytes)) = e.capacity.take() {
                    node.release(bytes);
                }
            }
        }
    }

    /// Stop one VM (flushes its caches; cancels any running job).
    pub fn stop_vm(&self, name: &str) -> Result<()> {
        let mut vms = lock_unpoisoned(&self.vms);
        let mut h = vms.remove(name).ok_or_else(|| anyhow!("no vm '{name}'"))?;
        let _ = h.tx.send(Request::Stop);
        if let Some(j) = h.join.take() {
            let _ = j.join();
        }
        drop(vms);
        self.reap_jobs();
        Ok(())
    }

    /// Stop the whole fleet.
    pub fn shutdown(&self) {
        let names = self.vm_names();
        for n in names {
            let _ = self.stop_vm(&n);
        }
    }

    pub fn data_mode_of(&self, name: &str) -> Result<DataMode> {
        let vms = lock_unpoisoned(&self.vms);
        Ok(vms
            .get(name)
            .ok_or_else(|| anyhow!("no vm '{name}'"))?
            .data_mode)
    }

    pub fn cache_of(&self, name: &str) -> Result<CacheConfig> {
        let vms = lock_unpoisoned(&self.vms);
        Ok(vms.get(name).ok_or_else(|| anyhow!("no vm '{name}'"))?.cache)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let names: Vec<String> = lock_unpoisoned(&self.vms).keys().cloned().collect();
        for n in names {
            let _ = self.stop_vm(&n);
        }
    }
}

/// Client handle to a running VM's request queue.
#[derive(Clone)]
pub struct VmClient {
    tx: SyncSender<Request>,
    clock: Arc<VirtClock>,
}

impl VmClient {
    pub fn read(&self, voff: u64, len: usize) -> Result<Vec<u8>> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::Read { voff, len, t_enq: self.clock.now(), reply })
            .map_err(|_| anyhow!("vm worker gone"))?;
        rx.recv().map_err(|_| anyhow!("vm worker gone"))?
    }

    pub fn write(&self, voff: u64, data: Vec<u8>) -> Result<()> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::Write { voff, data, t_enq: self.clock.now(), reply })
            .map_err(|_| anyhow!("vm worker gone"))?;
        rx.recv().map_err(|_| anyhow!("vm worker gone"))?
    }

    /// Submit a batch of operations in ONE channel round-trip. Ops
    /// execute in submission order on the worker; runs of consecutive
    /// reads/writes go through the driver's vectored path, so adjacent
    /// requests amortize slice resolution and merge device reads.
    pub fn submit(&self, ops: Vec<BatchOp>) -> Result<Vec<BatchReply>> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::Batch { ops, t_enq: self.clock.now(), reply })
            .map_err(|_| anyhow!("vm worker gone"))?;
        rx.recv().map_err(|_| anyhow!("vm worker gone"))?
    }

    /// Vectored read: every `(voff, len)` request answered with its own
    /// buffer, one round-trip for the lot.
    pub fn readv(&self, reqs: &[(u64, usize)]) -> Result<Vec<Vec<u8>>> {
        let ops = reqs
            .iter()
            .map(|&(voff, len)| BatchOp::Read { voff, len })
            .collect();
        Ok(self
            .submit(ops)?
            .into_iter()
            .map(|r| match r {
                BatchReply::Read(buf) => buf,
                BatchReply::Write => Vec::new(),
            })
            .collect())
    }

    /// Vectored write: all `(voff, data)` pairs in one round-trip.
    pub fn writev(&self, reqs: Vec<(u64, Vec<u8>)>) -> Result<()> {
        let ops = reqs
            .into_iter()
            .map(|(voff, data)| BatchOp::Write { voff, data })
            .collect();
        self.submit(ops)?;
        Ok(())
    }

    pub fn flush(&self) -> Result<()> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::Flush { reply })
            .map_err(|_| anyhow!("vm worker gone"))?;
        rx.recv().map_err(|_| anyhow!("vm worker gone"))?
    }

    pub fn counters(&self) -> Result<CounterSnapshot> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::Counters { reply })
            .map_err(|_| anyhow!("vm worker gone"))?;
        rx.recv().map_err(|_| anyhow!("vm worker gone"))
    }

    fn with_chain(
        &self,
        f: Box<dyn FnOnce(&mut Chain) -> Result<String> + Send>,
    ) -> Result<Result<String>> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::WithChain { f, reply })
            .map_err(|_| anyhow!("vm worker gone"))?;
        Ok(rx.recv().map_err(|_| anyhow!("vm worker gone"))?)
    }
}

/// The worker: single owner of the VM's driver and (at most one) live
/// job runner. Chain-level operations (snapshot/stream) tear the driver
/// down, run on the bare chain, and rebuild it; they are refused while a
/// job is running (conflicting chain rewrites). Job increments run after
/// each guest request and continuously while the queue is idle.
fn worker_loop(
    name: String,
    mut driver: Box<dyn Driver + Send>,
    rx: Receiver<Request>,
    stats: Arc<VmStats>,
    clock: Arc<VirtClock>,
    gc: Arc<GcRegistry>,
) {
    let mut runner: Option<JobRunner> = None;
    loop {
        // poll (don't block) while a runnable job wants the CPU
        let req = if runner.as_ref().map_or(false, |r| r.wants_cpu()) {
            match rx.try_recv() {
                Ok(r) => Some(r),
                Err(TryRecvError::Empty) => None,
                Err(TryRecvError::Disconnected) => break,
            }
        } else if runner.is_some() {
            // paused job: wake periodically to notice resume/cancel
            match rx.recv_timeout(std::time::Duration::from_millis(2)) {
                Ok(r) => Some(r),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        } else {
            match rx.recv() {
                Ok(r) => Some(r),
                Err(_) => break,
            }
        };
        let Some(req) = req else {
            // idle: drain the job, advancing virtual time over stalls
            let step = runner
                .as_mut()
                .map(|r| r.step(driver.as_mut(), clock.now()));
            match step {
                Some(Step::Starved { ready_at }) => {
                    // advance idle virtual time in bounded quanta: a
                    // request enqueued concurrently is charged at most
                    // one quantum of the stall, not all of it
                    const IDLE_QUANTUM_NS: u64 = 100_000;
                    let now = clock.now();
                    if ready_at > now {
                        clock.advance((ready_at - now).min(IDLE_QUANTUM_NS));
                    }
                }
                Some(Step::Finished) => {
                    finish_job(&name, driver.as_ref(), &mut runner, &stats, &gc)
                }
                _ => {}
            }
            continue;
        };
        let stop = match req {
            req @ (Request::Read { .. } | Request::Write { .. } | Request::Batch { .. }) => {
                // opportunistically drain queued guest I/O behind this
                // request into one burst: their channel round-trips are
                // already paid, and the driver's vectored path amortizes
                // slice resolution and merges contiguous device reads
                let mut burst = vec![req];
                let mut tail: Option<Request> = None;
                while burst.len() < BURST_DRAIN_MAX {
                    match rx.try_recv() {
                        Ok(
                            q @ (Request::Read { .. }
                            | Request::Write { .. }
                            | Request::Batch { .. }),
                        ) => burst.push(q),
                        Ok(other) => {
                            tail = Some(other);
                            break;
                        }
                        Err(_) => break,
                    }
                }
                serve_guest_burst(driver.as_mut(), burst, &stats, &clock);
                match tail {
                    Some(t) => handle_control(t, &mut driver, &mut runner, &stats, &clock),
                    None => false,
                }
            }
            other => handle_control(other, &mut driver, &mut runner, &stats, &clock),
        };
        if stop {
            let _ = driver.flush();
            break;
        }
        // one bounded job step rides behind every request (no clock
        // advance here: a starved job waits for idle time)
        let step = match runner.as_mut() {
            Some(r) if r.wants_cpu() => Some(r.step(driver.as_mut(), clock.now())),
            _ => None,
        };
        if let Some(Step::Finished) = step {
            finish_job(&name, driver.as_ref(), &mut runner, &stats, &gc);
        }
    }
}

/// How many queued guest requests the worker drains into one vectored
/// burst behind the first (their channel latency is already paid; the
/// cap bounds how long a control request can wait behind guest I/O).
const BURST_DRAIN_MAX: usize = 32;

/// Handle one non-guest-I/O request on the worker. Returns true when the
/// worker must stop.
fn handle_control(
    req: Request,
    driver: &mut Box<dyn Driver + Send>,
    runner: &mut Option<JobRunner>,
    stats: &Arc<VmStats>,
    clock: &Arc<VirtClock>,
) -> bool {
    match req {
        req @ (Request::Read { .. } | Request::Write { .. } | Request::Batch { .. }) => {
            // defensive: guest I/O normally arrives through the burst path
            serve_guest_burst(driver.as_mut(), vec![req], stats, clock);
            false
        }
        Request::Flush { reply } => {
            let _ = reply.send(driver.flush());
            false
        }
        Request::Counters { reply } => {
            let _ = reply.send(driver.counters());
            false
        }
        Request::WithChain { f, reply } => {
            let r = if runner.is_some() {
                Err(anyhow!(
                    "chain operation refused: a live block job is running"
                ))
            } else {
                (|| -> Result<String> {
                    driver.flush()?;
                    let out = f(driver.chain_mut())?;
                    driver.reopen()?;
                    Ok(out)
                })()
            };
            let _ = reply.send(r);
            false
        }
        Request::JobStart { builder, shared, increment_clusters, reply } => {
            let r = if runner.is_some() {
                Err(anyhow!("a block job is already running on this vm"))
            } else {
                (|| {
                    let fence = Arc::clone(driver.fence());
                    // flush first: a migration mirror reads the files
                    // underneath the driver, so cached dirty state must
                    // be on "disk" before the bulk copy starts
                    driver.flush()?;
                    let job = builder(driver.chain(), &fence)?;
                    let burst = increment_clusters
                        .saturating_mul(driver.chain().active().geom().cluster_size());
                    *runner = Some(JobRunner::new(
                        job,
                        shared,
                        fence,
                        increment_clusters,
                        burst,
                        clock.now(),
                    ));
                    Ok(())
                })()
            };
            let _ = reply.send(r);
            false
        }
        Request::Stop => {
            if let Some(r) = runner.take() {
                // the worker is going away: a running job cannot
                // make further progress — record it as cancelled
                r.shared().cancel();
                stats.jobs_cancelled.fetch_add(1, Relaxed);
                r.shared().set_state(crate::blockjob::JobState::Cancelled);
                driver.fence().end();
            }
            true
        }
    }
}

type ReadReq = (u64, usize, u64, SyncSender<Result<Vec<u8>>>);
type WriteReq = (u64, Vec<u8>, u64, SyncSender<Result<()>>);

/// Serve a burst of guest I/O: runs of consecutive reads become one
/// `readv`, consecutive writes one `writev`, explicit batches execute in
/// place — each original request is replied to individually. Afterwards
/// the driver's coalescer counters are mirrored into the VM stats.
fn serve_guest_burst(
    driver: &mut dyn Driver,
    burst: Vec<Request>,
    stats: &Arc<VmStats>,
    clock: &Arc<VirtClock>,
) {
    let mut it = burst.into_iter().peekable();
    while let Some(req) = it.next() {
        match req {
            Request::Read { voff, len, t_enq, reply } => {
                let mut reads: Vec<ReadReq> = vec![(voff, len, t_enq, reply)];
                while matches!(it.peek(), Some(Request::Read { .. })) {
                    let Some(Request::Read { voff, len, t_enq, reply }) = it.next()
                    else {
                        unreachable!()
                    };
                    reads.push((voff, len, t_enq, reply));
                }
                serve_reads(driver, reads, stats, clock);
            }
            Request::Write { voff, data, t_enq, reply } => {
                let mut writes: Vec<WriteReq> = vec![(voff, data, t_enq, reply)];
                while matches!(it.peek(), Some(Request::Write { .. })) {
                    let Some(Request::Write { voff, data, t_enq, reply }) = it.next()
                    else {
                        unreachable!()
                    };
                    writes.push((voff, data, t_enq, reply));
                }
                serve_writes(driver, writes, stats, clock);
            }
            Request::Batch { ops, t_enq, reply } => {
                serve_batch(driver, ops, t_enq, reply, stats, clock);
            }
            _ => unreachable!("serve_guest_burst only receives guest I/O"),
        }
    }
    let v = driver.vec_io();
    stats.merged_ios.store(v.merged_ios, Relaxed);
    stats.coalesced_bytes.store(v.coalesced_bytes, Relaxed);
}

fn serve_reads(
    driver: &mut dyn Driver,
    reads: Vec<ReadReq>,
    stats: &Arc<VmStats>,
    clock: &Arc<VirtClock>,
) {
    if reads.len() == 1 {
        // lone request: the classic scalar path
        let (voff, len, t_enq, reply) = reads.into_iter().next().expect("one read");
        let mut buf = vec![0u8; len];
        let r = driver.read(voff, &mut buf).map(|()| buf);
        stats.reads.fetch_add(1, Relaxed);
        stats.bytes_read.fetch_add(len as u64, Relaxed);
        stats.record_latency(clock.now().saturating_sub(t_enq));
        let _ = reply.send(r);
        return;
    }
    let mut bufs: Vec<Vec<u8>> = reads.iter().map(|r| vec![0u8; r.1]).collect();
    let res = {
        let mut iovs: Vec<(u64, &mut [u8])> = reads
            .iter()
            .zip(bufs.iter_mut())
            .map(|(r, b)| (r.0, b.as_mut_slice()))
            .collect();
        driver.readv(&mut iovs)
    };
    match res {
        Ok(()) => {
            let n = reads.len() as u64;
            stats.reads.fetch_add(n, Relaxed);
            stats.batched_ops.fetch_add(n, Relaxed);
            for ((_voff, len, t_enq, reply), buf) in reads.into_iter().zip(bufs) {
                stats.bytes_read.fetch_add(len as u64, Relaxed);
                stats.record_latency(clock.now().saturating_sub(t_enq));
                let _ = reply.send(Ok(buf));
            }
        }
        Err(_) => {
            // fall back to per-request scalar reads: error isolation and
            // stats accounting stay identical to the pre-vectored path
            // (reads have no side effects, so the retry is safe)
            for (voff, len, t_enq, reply) in reads {
                let mut buf = vec![0u8; len];
                let r = driver.read(voff, &mut buf).map(|()| buf);
                stats.reads.fetch_add(1, Relaxed);
                stats.bytes_read.fetch_add(len as u64, Relaxed);
                stats.record_latency(clock.now().saturating_sub(t_enq));
                let _ = reply.send(r);
            }
        }
    }
}

fn serve_writes(
    driver: &mut dyn Driver,
    writes: Vec<WriteReq>,
    stats: &Arc<VmStats>,
    clock: &Arc<VirtClock>,
) {
    if writes.len() == 1 {
        let (voff, data, t_enq, reply) = writes.into_iter().next().expect("one write");
        let n = data.len() as u64;
        let r = driver.write(voff, &data);
        stats.writes.fetch_add(1, Relaxed);
        stats.bytes_written.fetch_add(n, Relaxed);
        stats.record_latency(clock.now().saturating_sub(t_enq));
        let _ = reply.send(r);
        return;
    }
    let res = {
        let iovs: Vec<(u64, &[u8])> =
            writes.iter().map(|w| (w.0, w.1.as_slice())).collect();
        driver.writev(&iovs)
    };
    match res {
        Ok(()) => {
            let n = writes.len() as u64;
            stats.writes.fetch_add(n, Relaxed);
            stats.batched_ops.fetch_add(n, Relaxed);
            for (_voff, data, t_enq, reply) in writes {
                stats.bytes_written.fetch_add(data.len() as u64, Relaxed);
                stats.record_latency(clock.now().saturating_sub(t_enq));
                let _ = reply.send(Ok(()));
            }
        }
        Err(_) => {
            // fall back to per-request scalar writes (idempotent: the
            // vectored attempt is itself a scalar loop, so re-applying
            // the prefix writes the same bytes to the same clusters) —
            // each request gets its own verdict, like the old loop
            for (voff, data, t_enq, reply) in writes {
                let n = data.len() as u64;
                let r = driver.write(voff, &data);
                stats.writes.fetch_add(1, Relaxed);
                stats.bytes_written.fetch_add(n, Relaxed);
                stats.record_latency(clock.now().saturating_sub(t_enq));
                let _ = reply.send(r);
            }
        }
    }
}

fn serve_batch(
    driver: &mut dyn Driver,
    ops: Vec<BatchOp>,
    t_enq: u64,
    reply: SyncSender<Result<Vec<BatchReply>>>,
    stats: &Arc<VmStats>,
    clock: &Arc<VirtClock>,
) {
    let r = run_batch(driver, ops, stats);
    stats.record_latency(clock.now().saturating_sub(t_enq));
    let _ = reply.send(r);
}

/// Execute a batch in submission order: consecutive reads become one
/// `readv`, consecutive writes one `writev` — so a write is visible to
/// every later read of the same batch. Stats are accounted per executed
/// group, so ops that changed on-disk state before a later group failed
/// still show up in the counters.
fn run_batch(
    driver: &mut dyn Driver,
    ops: Vec<BatchOp>,
    stats: &Arc<VmStats>,
) -> Result<Vec<BatchReply>> {
    let mut replies = Vec::with_capacity(ops.len());
    let mut i = 0usize;
    while i < ops.len() {
        match ops[i] {
            BatchOp::Read { .. } => {
                let mut j = i;
                while j < ops.len() && matches!(ops[j], BatchOp::Read { .. }) {
                    j += 1;
                }
                let mut bufs: Vec<Vec<u8>> = ops[i..j]
                    .iter()
                    .map(|o| match o {
                        BatchOp::Read { len, .. } => vec![0u8; *len],
                        BatchOp::Write { .. } => unreachable!(),
                    })
                    .collect();
                {
                    let mut iovs: Vec<(u64, &mut [u8])> = ops[i..j]
                        .iter()
                        .zip(bufs.iter_mut())
                        .map(|(o, b)| match o {
                            BatchOp::Read { voff, .. } => (*voff, b.as_mut_slice()),
                            BatchOp::Write { .. } => unreachable!(),
                        })
                        .collect();
                    driver.readv(&mut iovs)?;
                }
                stats.reads.fetch_add((j - i) as u64, Relaxed);
                stats.batched_ops.fetch_add((j - i) as u64, Relaxed);
                stats
                    .bytes_read
                    .fetch_add(bufs.iter().map(|b| b.len() as u64).sum(), Relaxed);
                replies.extend(bufs.into_iter().map(BatchReply::Read));
                i = j;
            }
            BatchOp::Write { .. } => {
                let mut j = i;
                while j < ops.len() && matches!(ops[j], BatchOp::Write { .. }) {
                    j += 1;
                }
                let iovs: Vec<(u64, &[u8])> = ops[i..j]
                    .iter()
                    .map(|o| match o {
                        BatchOp::Write { voff, data } => (*voff, data.as_slice()),
                        BatchOp::Read { .. } => unreachable!(),
                    })
                    .collect();
                let bytes: u64 = iovs.iter().map(|(_, d)| d.len() as u64).sum();
                driver.writev(&iovs)?;
                stats.writes.fetch_add((j - i) as u64, Relaxed);
                stats.batched_ops.fetch_add((j - i) as u64, Relaxed);
                stats.bytes_written.fetch_add(bytes, Relaxed);
                replies.extend((i..j).map(|_| BatchReply::Write));
                i = j;
            }
        }
    }
    Ok(replies)
}

/// Account a finished job and drop its runner. A *completed* job changed
/// the chain's shape (stream collapses it), so the new file set is
/// re-declared to the GC registry: dropped backing files lose this
/// chain's reference and are condemned once nothing else holds one.
fn finish_job(
    name: &str,
    driver: &dyn Driver,
    runner: &mut Option<JobRunner>,
    stats: &Arc<VmStats>,
    gc: &Arc<GcRegistry>,
) {
    let Some(r) = runner.take() else { return };
    let st = r.shared().status();
    match st.state {
        crate::blockjob::JobState::Completed => {
            stats.jobs_completed.fetch_add(1, Relaxed);
            gc.sync_chain(name, driver.chain().file_names());
        }
        crate::blockjob::JobState::Cancelled => {
            stats.jobs_cancelled.fetch_add(1, Relaxed);
        }
        _ => {
            stats.jobs_failed.fetch_add(1, Relaxed);
        }
    }
    stats.job_increments.fetch_add(st.increments, Relaxed);
    stats.job_copied_clusters.fetch_add(st.copied, Relaxed);
}
