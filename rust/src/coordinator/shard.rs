//! Shard executors: the worker pool of the sharded data plane.
//!
//! A shard owns a disjoint set of VMs (assignment by name hash — see
//! [`super::server`]) and drives everything that used to run on one
//! thread per VM: guest I/O from each VM's submission ring, at most one
//! live block-job runner per VM, and idle virtual-clock advancement.
//! One serving pass round-robins the shard's VMs, draining up to
//! [`BURST_DRAIN_MAX`] submissions per VM under one cross-VM merge
//! window ([`crate::storage::iosched::MergeWindow`]), then gives every
//! runnable job one bounded step, then flushes the per-VM
//! [`StatsDelta`] accumulators into the shared stats — the stats
//! reaper that keeps atomics off the per-request path.
//!
//! When no VM has queued submissions and no job is runnable, the
//! executor PARKS on its doorbell ([`crate::util::Notify`]) instead of
//! polling: submitters, control messages and job `resume`/`cancel` ring
//! it. An idle fleet burns no CPU (the old worker spun on a 2 ms
//! `recv_timeout` whenever a paused job existed).
//!
//! Panic containment is per VM, as before: a panic while serving a VM
//! (or stepping its job) kills that VM — its rings are marked dead, so
//! its clients see "vm worker gone" — and the shard keeps serving its
//! other VMs.

use super::ring::{BatchOp, BatchReply, RingReply, SqEntry, VmRings};
use super::stats::{StatsDelta, VmStats};
use crate::blockjob::{BlockJob, JobFence, JobRunner, JobShared, JobState, Step};
use crate::gc::GcRegistry;
use crate::metrics::clock::VirtClock;
use crate::metrics::counters::CounterSnapshot;
use crate::qcow::Chain;
use crate::storage::iosched::{IoScheduler, MergeWindow};
use crate::telemetry::trace::TraceBuf;
use crate::util::Notify;
use crate::vdisk::{DiskOp, Driver, VecIoSnapshot};
use anyhow::{anyhow, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

/// How many queued submissions one VM may contribute to one serving
/// pass (fairness bound: no VM's burst starves its shard neighbours).
pub const BURST_DRAIN_MAX: usize = 32;

/// Bounded idle virtual-time advance per pass while a job is
/// rate-limit starved: a request enqueued concurrently is charged at
/// most one quantum of the stall, not all of it.
const IDLE_QUANTUM_NS: u64 = 100_000;

/// Backstop for the parked executor: even a lost doorbell (which the
/// latching [`Notify`] should make impossible) only delays work by
/// this much.
const PARK_BACKSTOP: std::time::Duration = std::time::Duration::from_millis(100);

/// Constructs a job on the shard executor, where the driver's chain and
/// fence live. Stream/stamp builders are trivial closures; the
/// migration builder captures the node set, GC registry and target so
/// the [`crate::migrate::MirrorJob`] can journal and create its target
/// copies at start.
pub(crate) type JobBuilder =
    Box<dyn FnOnce(&Chain, &Arc<JobFence>) -> Result<Box<dyn BlockJob>> + Send>;

/// Control-plane messages to a shard executor (rare path; guest I/O
/// never travels here — it goes through the rings).
pub(crate) enum ShardControl {
    /// Adopt a VM: the executor becomes the single owner of its driver.
    AddVm {
        name: String,
        driver: Box<dyn Driver + Send>,
        rings: Arc<VmRings>,
        stats: Arc<VmStats>,
        /// Span-event buffer for a trace-sampled VM (`None` for the
        /// unsampled majority — the label-cardinality rule).
        trace: Option<crate::telemetry::trace::TraceBuf>,
        reply: SyncSender<Result<()>>,
    },
    /// Stop a VM: serve what its clients already queued, flush, cancel
    /// any running job, mark its rings dead. Idempotent.
    RemoveVm { name: String, reply: SyncSender<Result<()>> },
    /// Drop a VM with crash semantics: NO serving of queued requests and
    /// NO cache flush — whatever was not yet flush-acknowledged is lost,
    /// exactly as a power cut would lose it. The HA failover tests use
    /// this (`Coordinator::halt`) to kill a leader mid-workload; a real
    /// stop goes through `RemoveVm`. Idempotent.
    AbandonVm { name: String, reply: SyncSender<()> },
    /// Pause the VM and hand its bare chain to `f` (snapshot/stream).
    WithChain {
        vm: String,
        f: Box<dyn FnOnce(&mut Chain) -> Result<String> + Send>,
        reply: SyncSender<Result<String>>,
    },
    /// Begin a live block job on this VM.
    JobStart {
        vm: String,
        builder: JobBuilder,
        shared: Arc<JobShared>,
        increment_clusters: u64,
        reply: SyncSender<Result<()>>,
    },
    /// Low-level driver counters of one VM.
    Counters { vm: String, reply: SyncSender<CounterSnapshot> },
    /// Flush every VM's stats delta, then reply — the barrier
    /// `Coordinator::vm_stats` uses so completed requests are always
    /// visible in the snapshot that follows them.
    SyncStats { reply: SyncSender<()> },
    /// Terminate the executor (coordinator drop).
    Shutdown,
}

/// Executor-level counters (`sqemu node status` shard table, the
/// spurious-wakeup regression test).
#[derive(Debug, Default)]
pub struct ShardStats {
    /// Times the executor resumed from a park (doorbell or backstop).
    pub wakeups: AtomicU64,
    /// Serving passes executed.
    pub passes: AtomicU64,
    /// Ring submissions served.
    pub served: AtomicU64,
    /// VMs currently owned.
    pub vm_count: AtomicU64,
    /// Total SQ occupancy across owned VMs at the last pass end.
    pub sq_depth: AtomicU64,
}

/// Point-in-time view of one shard (public reporting surface).
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardStatsSnapshot {
    pub shard: usize,
    pub vms: u64,
    pub queued: u64,
    pub served: u64,
    pub passes: u64,
    pub wakeups: u64,
}

impl ShardStats {
    pub fn snapshot(&self, shard: usize) -> ShardStatsSnapshot {
        ShardStatsSnapshot {
            shard,
            vms: self.vm_count.load(Relaxed),
            queued: self.sq_depth.load(Relaxed),
            served: self.served.load(Relaxed),
            passes: self.passes.load(Relaxed),
            wakeups: self.wakeups.load(Relaxed),
        }
    }
}

/// Handle to one spawned shard executor.
pub(crate) struct Shard {
    pub(crate) index: usize,
    tx: Sender<ShardControl>,
    pub(crate) notify: Arc<Notify>,
    pub(crate) stats: Arc<ShardStats>,
    join: Option<JoinHandle<()>>,
}

impl Shard {
    pub(crate) fn spawn(
        index: usize,
        clock: Arc<VirtClock>,
        gc: Arc<GcRegistry>,
        scheds: Vec<Arc<IoScheduler>>,
    ) -> Shard {
        let (tx, rx) = channel::<ShardControl>();
        let notify = Arc::new(Notify::new());
        let stats = Arc::new(ShardStats::default());
        let (n2, s2) = (Arc::clone(&notify), Arc::clone(&stats));
        let join = std::thread::Builder::new()
            .name(format!("shard-{index}"))
            .spawn(move || shard_loop(rx, n2, s2, clock, gc, scheds))
            .expect("spawn shard executor");
        Shard { index, tx, notify, stats, join: Some(join) }
    }

    /// Enqueue a control message and ring the doorbell.
    pub(crate) fn send(&self, c: ShardControl) -> Result<()> {
        self.tx.send(c).map_err(|_| anyhow!("shard executor gone"))?;
        self.notify.notify();
        Ok(())
    }

    /// A cloneable control-plane address of this shard (what a
    /// [`super::server::VmClient`] holds).
    pub(crate) fn handle(&self) -> ShardHandle {
        ShardHandle { tx: self.tx.clone(), notify: Arc::clone(&self.notify) }
    }
}

/// Cloneable sender half of a shard's control channel.
#[derive(Clone)]
pub(crate) struct ShardHandle {
    tx: Sender<ShardControl>,
    notify: Arc<Notify>,
}

impl ShardHandle {
    pub(crate) fn send(&self, c: ShardControl) -> Result<()> {
        self.tx.send(c).map_err(|_| anyhow!("shard executor gone"))?;
        self.notify.notify();
        Ok(())
    }
}

impl Drop for Shard {
    fn drop(&mut self) {
        let _ = self.tx.send(ShardControl::Shutdown);
        self.notify.notify();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// One VM owned by a shard.
struct VmSlot {
    name: String,
    driver: Box<dyn Driver + Send>,
    rings: Arc<VmRings>,
    stats: Arc<VmStats>,
    delta: StatsDelta,
    /// Driver coalescer totals at the last reap — the watermark that
    /// turns the driver-lifetime `vec_io()` counters into monotone
    /// deltas on the shared stats (exporter-safe, panic-safe).
    vec_io_seen: VecIoSnapshot,
    /// Span-event buffer when this VM is trace-sampled (`None` for the
    /// unsampled majority: one branch per request, no other cost).
    trace: Option<TraceBuf>,
    runner: Option<JobRunner>,
    dead: bool,
}

/// A panic reached this VM: record it, fail its clients, cancel its
/// job. The slot is removed by the caller; the shard lives on.
/// Completions the clients could already reap are flushed first — a
/// mid-pass panic must not make delivered results invisible to stats.
fn kill_slot(slot: &mut VmSlot) {
    slot.dead = true;
    reap_slot_stats(slot);
    slot.stats.worker_panics.fetch_add(1, Relaxed);
    slot.rings.mark_dead();
    if let Some(r) = slot.runner.take() {
        r.shared().cancel();
        r.shared().set_state(JobState::Cancelled);
        r.shared().clear_waker();
        slot.stats.jobs_cancelled.fetch_add(1, Relaxed);
    }
}

/// Flush a slot's accumulated delta, mirrored ring counters, coalescer
/// watermark and pending trace events into the shared state (the reaper
/// step — the only place per-pass accumulation crosses a lock/atomic).
fn reap_slot_stats(slot: &mut VmSlot) {
    slot.delta.flush_into(&slot.stats);
    // the driver's coalescer counters are driver-lifetime totals:
    // publish the growth since the last reap as a fetch_add, so the
    // shared counters are monotone (exporter-safe) and never stale
    // between passes
    let v = slot.driver.vec_io();
    let d_ios = v.merged_ios.saturating_sub(slot.vec_io_seen.merged_ios);
    let d_bytes =
        v.coalesced_bytes.saturating_sub(slot.vec_io_seen.coalesced_bytes);
    if d_ios > 0 {
        slot.stats.merged_ios.fetch_add(d_ios, Relaxed);
    }
    if d_bytes > 0 {
        slot.stats.coalesced_bytes.fetch_add(d_bytes, Relaxed);
    }
    slot.vec_io_seen = v;
    slot.stats
        .backpressure
        .store(slot.rings.backpressure.load(Relaxed), Relaxed);
    if let Some(t) = slot.trace.as_mut() {
        t.flush();
    }
}

fn shard_loop(
    ctl: Receiver<ShardControl>,
    notify: Arc<Notify>,
    stats: Arc<ShardStats>,
    clock: Arc<VirtClock>,
    gc: Arc<GcRegistry>,
    scheds: Vec<Arc<IoScheduler>>,
) {
    let mut vms: Vec<VmSlot> = Vec::new();
    loop {
        // ---- control (rare path) -----------------------------------
        loop {
            match ctl.try_recv() {
                Ok(ShardControl::Shutdown) | Err(TryRecvError::Disconnected) => {
                    shutdown_slots(&mut vms, &clock, &gc);
                    return;
                }
                Ok(c) => handle_control(c, &mut vms, &notify, &gc, &clock),
                Err(TryRecvError::Empty) => break,
            }
        }
        stats.vm_count.store(vms.len() as u64, Relaxed);
        stats.passes.fetch_add(1, Relaxed);

        // ---- serving pass: guest I/O under one merge window --------
        let mut served = 0u64;
        {
            let _window = MergeWindow::open(scheds.clone());
            for slot in vms.iter_mut() {
                match catch_unwind(AssertUnwindSafe(|| serve_slot(slot, &clock))) {
                    Ok(n) => served += n,
                    Err(_) => kill_slot(slot),
                }
            }
        }
        vms.retain(|s| !s.dead);

        // ---- one bounded job step per runnable job -----------------
        let mut any_ran = false;
        let mut min_ready: Option<u64> = None;
        for slot in vms.iter_mut() {
            if !slot.runner.as_ref().map_or(false, |r| r.wants_cpu()) {
                continue;
            }
            let now = clock.now();
            let stepped = catch_unwind(AssertUnwindSafe(|| {
                slot.runner
                    .as_mut()
                    .expect("checked runnable")
                    .step(slot.driver.as_mut(), now)
            }));
            match stepped {
                Ok(Step::Ran) => any_ran = true,
                Ok(Step::Finished) => {
                    finish_job(slot, &gc);
                    any_ran = true;
                }
                Ok(Step::Starved { ready_at }) => {
                    min_ready =
                        Some(min_ready.map_or(ready_at, |m| m.min(ready_at)));
                }
                Ok(Step::Paused) => {}
                Err(_) => kill_slot(slot),
            }
        }
        vms.retain(|s| !s.dead);

        // ---- stats reaper ------------------------------------------
        stats.served.fetch_add(served, Relaxed);
        for slot in vms.iter_mut() {
            reap_slot_stats(slot);
        }
        stats.sq_depth.store(
            vms.iter().map(|s| s.rings.sq_len() as u64).sum(),
            Relaxed,
        );

        // ---- idle policy -------------------------------------------
        if served == 0 && !any_ran {
            if let Some(ready_at) = min_ready {
                // a job is rate-limit starved: only virtual time can
                // unblock it — advance in bounded quanta, don't park
                let now = clock.now();
                if ready_at > now {
                    clock.advance((ready_at - now).min(IDLE_QUANTUM_NS));
                }
            } else {
                // nothing runnable anywhere: park until a submitter,
                // control message or job resume/cancel rings the bell
                notify.wait_timeout(PARK_BACKSTOP);
                stats.wakeups.fetch_add(1, Relaxed);
            }
        }
    }
}

fn shutdown_slots(
    vms: &mut Vec<VmSlot>,
    clock: &Arc<VirtClock>,
    gc: &Arc<GcRegistry>,
) {
    let _ = gc;
    for slot in vms.iter_mut() {
        let _ = catch_unwind(AssertUnwindSafe(|| {
            while serve_slot(slot, clock) > 0 {}
            let _ = slot.driver.flush();
        }));
        if let Some(r) = slot.runner.take() {
            r.shared().cancel();
            r.shared().set_state(JobState::Cancelled);
            r.shared().clear_waker();
            slot.stats.jobs_cancelled.fetch_add(1, Relaxed);
            slot.driver.fence().end();
        }
        reap_slot_stats(slot);
        slot.rings.mark_dead();
    }
    vms.clear();
}

/// Run `f` against the named slot with per-VM panic containment. A
/// panic kills the VM and returns `None` — callers then drop the reply
/// channel, which clients observe as "vm worker gone" (exactly the old
/// worker-death surface).
fn with_slot<T>(
    vms: &mut Vec<VmSlot>,
    name: &str,
    f: impl FnOnce(&mut VmSlot) -> T,
) -> Option<T> {
    let idx = vms.iter().position(|s| s.name == name)?;
    match catch_unwind(AssertUnwindSafe(|| f(&mut vms[idx]))) {
        Ok(t) => Some(t),
        Err(_) => {
            kill_slot(&mut vms[idx]);
            vms.remove(idx);
            None
        }
    }
}

fn handle_control(
    c: ShardControl,
    vms: &mut Vec<VmSlot>,
    notify: &Arc<Notify>,
    gc: &Arc<GcRegistry>,
    clock: &Arc<VirtClock>,
) {
    match c {
        ShardControl::AddVm { name, driver, rings, stats, trace, reply } => {
            // the watermark starts at the driver's current totals, so a
            // re-adopted driver doesn't re-publish its history
            let vec_io_seen = driver.vec_io();
            vms.push(VmSlot {
                name,
                driver,
                rings,
                stats,
                delta: StatsDelta::default(),
                vec_io_seen,
                trace,
                runner: None,
                dead: false,
            });
            let _ = reply.send(Ok(()));
        }
        ShardControl::RemoveVm { name, reply } => {
            let Some(idx) = vms.iter().position(|s| s.name == name) else {
                // already gone (panicked earlier) — stop is idempotent
                let _ = reply.send(Ok(()));
                return;
            };
            let mut slot = vms.remove(idx);
            // old Stop semantics: requests the clients queued before the
            // stop are served, then caches are flushed
            let _ = catch_unwind(AssertUnwindSafe(|| {
                while serve_slot(&mut slot, clock) > 0 {}
                let _ = slot.driver.flush();
            }));
            if let Some(r) = slot.runner.take() {
                // the VM is going away: a running job cannot make
                // further progress — record it as cancelled
                r.shared().cancel();
                r.shared().set_state(JobState::Cancelled);
                r.shared().clear_waker();
                slot.stats.jobs_cancelled.fetch_add(1, Relaxed);
                slot.driver.fence().end();
            }
            reap_slot_stats(&mut slot);
            slot.rings.mark_dead();
            let _ = reply.send(Ok(()));
        }
        ShardControl::AbandonVm { name, reply } => {
            let Some(idx) = vms.iter().position(|s| s.name == name) else {
                let _ = reply.send(());
                return;
            };
            let mut slot = vms.remove(idx);
            // crash semantics: the unflushed cache dies with the slot —
            // only flush-acknowledged bytes survive on the nodes
            if let Some(r) = slot.runner.take() {
                r.shared().cancel();
                r.shared().set_state(JobState::Cancelled);
                r.shared().clear_waker();
                slot.stats.jobs_cancelled.fetch_add(1, Relaxed);
                slot.driver.fence().end();
            }
            slot.rings.mark_dead();
            let _ = reply.send(());
        }
        ShardControl::WithChain { vm, f, reply } => {
            let r = with_slot(vms, &vm, |slot| {
                if slot.runner.is_some() {
                    return Err(anyhow!(
                        "chain operation refused: a live block job is running"
                    ));
                }
                slot.driver.flush()?;
                let out = f(slot.driver.chain_mut())?;
                slot.driver.reopen()?;
                Ok(out)
            });
            match r {
                Some(r) => {
                    let _ = reply.send(r);
                }
                None => {
                    let _ = reply.send(Err(anyhow!("vm worker gone")));
                }
            }
        }
        ShardControl::JobStart { vm, builder, shared, increment_clusters, reply } => {
            let waker = Arc::clone(notify);
            let clock = Arc::clone(clock);
            let r = with_slot(vms, &vm, move |slot| {
                if slot.runner.is_some() {
                    return Err(anyhow!(
                        "a block job is already running on this vm"
                    ));
                }
                let fence = Arc::clone(slot.driver.fence());
                // flush first: a migration mirror reads the files
                // underneath the driver, so cached dirty state must be
                // on "disk" before the bulk copy starts
                slot.driver.flush()?;
                let job = builder(slot.driver.chain(), &fence)?;
                let burst = increment_clusters.saturating_mul(
                    slot.driver.chain().active().geom().cluster_size(),
                );
                // resume/cancel must unpark this executor
                shared.set_waker(waker);
                slot.runner = Some(JobRunner::new(
                    job,
                    shared,
                    fence,
                    increment_clusters,
                    burst,
                    clock.now(),
                ));
                Ok(())
            });
            if let Some(r) = r {
                let _ = reply.send(r);
            } // on panic: reply dropped → client sees "vm worker gone"
        }
        ShardControl::Counters { vm, reply } => {
            if let Some(c) = with_slot(vms, &vm, |slot| slot.driver.counters()) {
                let _ = reply.send(c);
            }
        }
        ShardControl::SyncStats { reply } => {
            for slot in vms.iter_mut() {
                reap_slot_stats(slot);
            }
            let _ = reply.send(());
        }
        ShardControl::Shutdown => unreachable!("handled by the shard loop"),
    }
}

// ----------------------------------------------------------------- I/O

type ReadReq = (u64, u64, usize, u64); // tag, voff, len, t_enq
type WriteReq = (u64, u64, Vec<u8>, u64); // tag, voff, data, t_enq

/// Drain and serve up to one burst of this VM's submission ring, in
/// program order: runs of consecutive reads become one `readv`,
/// consecutive writes one `writev`, batches execute through the
/// driver's [`DiskOp`] submit surface — one completion per submission.
/// Returns the number of submissions served.
fn serve_slot(slot: &mut VmSlot, clock: &VirtClock) -> u64 {
    let mut entries: Vec<SqEntry> = Vec::new();
    while entries.len() < BURST_DRAIN_MAX {
        match slot.rings.pop_sq() {
            Some(e) => entries.push(e),
            None => break,
        }
    }
    if entries.is_empty() {
        return 0;
    }
    let served = entries.len() as u64;
    let mut it = entries.into_iter().peekable();
    while let Some(e) = it.next() {
        match e {
            SqEntry::Read { tag, voff, len, t_enq } => {
                let mut reads: Vec<ReadReq> = vec![(tag, voff, len, t_enq)];
                while matches!(it.peek(), Some(SqEntry::Read { .. })) {
                    let Some(SqEntry::Read { tag, voff, len, t_enq }) = it.next()
                    else {
                        unreachable!()
                    };
                    reads.push((tag, voff, len, t_enq));
                }
                serve_reads(slot, reads, clock);
            }
            SqEntry::Write { tag, voff, data, t_enq } => {
                let mut writes: Vec<WriteReq> = vec![(tag, voff, data, t_enq)];
                while matches!(it.peek(), Some(SqEntry::Write { .. })) {
                    let Some(SqEntry::Write { tag, voff, data, t_enq }) =
                        it.next()
                    else {
                        unreachable!()
                    };
                    writes.push((tag, voff, data, t_enq));
                }
                serve_writes(slot, writes, clock);
            }
            SqEntry::Batch { tag, ops, t_enq } => {
                let t_serve = clock.now();
                let n_ops = ops.len() as u64;
                let r = run_batch(&mut *slot.driver, &mut slot.delta, ops);
                let done = clock.now();
                slot.delta.record_latency(done.saturating_sub(t_enq));
                if let Some(t) = slot.trace.as_mut() {
                    t.record(tag, "batch", n_ops, t_enq, t_serve, done);
                }
                slot.rings.complete(tag, RingReply::Batch(r));
            }
            SqEntry::Flush { tag, t_enq } => {
                // a flush completes only after everything before it in
                // the ring — guaranteed by in-order execution here
                let t_serve = clock.now();
                let r = slot.driver.flush();
                if let Some(t) = slot.trace.as_mut() {
                    t.record(tag, "flush", 0, t_enq, t_serve, clock.now());
                }
                slot.rings.complete(tag, RingReply::Flush(r));
            }
        }
    }
    // coalescer counters and the StatsDelta are published together by
    // the per-pass reaper (reap_slot_stats), not here
    slot.rings.wake_reapers();
    served
}

fn serve_reads(slot: &mut VmSlot, reads: Vec<ReadReq>, clock: &VirtClock) {
    let t_serve = clock.now();
    if reads.len() == 1 {
        // lone request: the classic scalar path
        let (tag, voff, len, t_enq) = reads.into_iter().next().expect("one read");
        let mut buf = vec![0u8; len];
        let r = slot.driver.read(voff, &mut buf).map(|()| buf);
        let done = clock.now();
        slot.delta.reads += 1;
        slot.delta.bytes_read += len as u64;
        slot.delta.record_latency(done.saturating_sub(t_enq));
        if let Some(t) = slot.trace.as_mut() {
            t.record(tag, "read", len as u64, t_enq, t_serve, done);
        }
        slot.rings.complete(tag, RingReply::Read(r));
        return;
    }
    let mut bufs: Vec<Vec<u8>> = reads.iter().map(|r| vec![0u8; r.2]).collect();
    let res = {
        let mut iovs: Vec<(u64, &mut [u8])> = reads
            .iter()
            .zip(bufs.iter_mut())
            .map(|(r, b)| (r.1, b.as_mut_slice()))
            .collect();
        slot.driver.readv(&mut iovs)
    };
    match res {
        Ok(()) => {
            let n = reads.len() as u64;
            slot.delta.reads += n;
            slot.delta.batched_ops += n;
            for ((tag, _voff, len, t_enq), buf) in reads.into_iter().zip(bufs) {
                let done = clock.now();
                slot.delta.bytes_read += len as u64;
                slot.delta.record_latency(done.saturating_sub(t_enq));
                if let Some(t) = slot.trace.as_mut() {
                    t.record(tag, "read", len as u64, t_enq, t_serve, done);
                }
                slot.rings.complete(tag, RingReply::Read(Ok(buf)));
            }
        }
        Err(_) => {
            // fall back to per-request scalar reads: error isolation and
            // accounting stay identical to the pre-vectored path (reads
            // have no side effects, so the retry is safe)
            for (tag, voff, len, t_enq) in reads {
                let mut buf = vec![0u8; len];
                let r = slot.driver.read(voff, &mut buf).map(|()| buf);
                let done = clock.now();
                slot.delta.reads += 1;
                slot.delta.bytes_read += len as u64;
                slot.delta.record_latency(done.saturating_sub(t_enq));
                if let Some(t) = slot.trace.as_mut() {
                    t.record(tag, "read", len as u64, t_enq, t_serve, done);
                }
                slot.rings.complete(tag, RingReply::Read(r));
            }
        }
    }
}

fn serve_writes(slot: &mut VmSlot, writes: Vec<WriteReq>, clock: &VirtClock) {
    let t_serve = clock.now();
    if writes.len() == 1 {
        let (tag, voff, data, t_enq) =
            writes.into_iter().next().expect("one write");
        let n = data.len() as u64;
        let r = slot.driver.write(voff, &data);
        let done = clock.now();
        slot.delta.writes += 1;
        slot.delta.bytes_written += n;
        slot.delta.record_latency(done.saturating_sub(t_enq));
        if let Some(t) = slot.trace.as_mut() {
            t.record(tag, "write", n, t_enq, t_serve, done);
        }
        slot.rings.complete(tag, RingReply::Write(r));
        return;
    }
    let res = {
        let iovs: Vec<(u64, &[u8])> =
            writes.iter().map(|w| (w.1, w.2.as_slice())).collect();
        slot.driver.writev(&iovs)
    };
    match res {
        Ok(()) => {
            let n = writes.len() as u64;
            slot.delta.writes += n;
            slot.delta.batched_ops += n;
            for (tag, _voff, data, t_enq) in writes {
                let done = clock.now();
                let n = data.len() as u64;
                slot.delta.bytes_written += n;
                slot.delta.record_latency(done.saturating_sub(t_enq));
                if let Some(t) = slot.trace.as_mut() {
                    t.record(tag, "write", n, t_enq, t_serve, done);
                }
                slot.rings.complete(tag, RingReply::Write(Ok(())));
            }
        }
        Err(_) => {
            // fall back to per-request scalar writes (idempotent: the
            // vectored attempt is itself a scalar loop, so re-applying
            // the prefix writes the same bytes to the same clusters) —
            // each request gets its own verdict
            for (tag, voff, data, t_enq) in writes {
                let n = data.len() as u64;
                let r = slot.driver.write(voff, &data);
                let done = clock.now();
                slot.delta.writes += 1;
                slot.delta.bytes_written += n;
                slot.delta.record_latency(done.saturating_sub(t_enq));
                if let Some(t) = slot.trace.as_mut() {
                    t.record(tag, "write", n, t_enq, t_serve, done);
                }
                slot.rings.complete(tag, RingReply::Write(r));
            }
        }
    }
}

/// Execute a batch in submission order through [`Driver::submit`]:
/// consecutive same-kind ops group into one vectored call, so a write
/// is visible to every later read of the same batch. Ops executed
/// before a failure still count in the stats (their on-disk effects are
/// real), like the old per-group accounting.
fn run_batch(
    driver: &mut dyn Driver,
    delta: &mut StatsDelta,
    ops: Vec<BatchOp>,
) -> Result<Vec<BatchReply>> {
    let mut bufs: Vec<Vec<u8>> = ops
        .iter()
        .filter_map(|o| match o {
            BatchOp::Read { len, .. } => Some(vec![0u8; *len]),
            BatchOp::Write { .. } => None,
        })
        .collect();
    let res = {
        let mut bi = bufs.iter_mut();
        let mut dops: Vec<DiskOp<'_>> = ops
            .iter()
            .map(|o| match o {
                BatchOp::Read { voff, .. } => DiskOp::Read {
                    voff: *voff,
                    buf: bi.next().expect("one buf per read").as_mut_slice(),
                },
                BatchOp::Write { voff, data } => {
                    DiskOp::Write { voff: *voff, data: data.as_slice() }
                }
            })
            .collect();
        driver.submit(&mut dops)
    };
    for o in ops.iter().take(res.completed) {
        match o {
            BatchOp::Read { len, .. } => {
                delta.reads += 1;
                delta.batched_ops += 1;
                delta.bytes_read += *len as u64;
            }
            BatchOp::Write { data, .. } => {
                delta.writes += 1;
                delta.batched_ops += 1;
                delta.bytes_written += data.len() as u64;
            }
        }
    }
    if let Some(e) = res.error {
        return Err(e);
    }
    let mut bi = bufs.into_iter();
    Ok(ops
        .into_iter()
        .map(|o| match o {
            BatchOp::Read { .. } => {
                BatchReply::Read(bi.next().expect("one buf per read"))
            }
            BatchOp::Write { .. } => BatchReply::Write,
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheConfig;
    use crate::chaingen::{generate, ChainSpec};
    use crate::metrics::clock::CostModel;
    use crate::metrics::memory::MemoryAccountant;
    use crate::qcow::image::DataMode;
    use crate::storage::node::StorageNode;
    use crate::vdisk::scalable::ScalableDriver;

    fn test_slot() -> (Arc<StorageNode>, VmSlot, Arc<VirtClock>) {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let chain = generate(
            &*node,
            &ChainSpec {
                disk_size: 8 << 20,
                chain_len: 1,
                populated: 1.0,
                stamped: true,
                data_mode: DataMode::Real,
                ..Default::default()
            },
        )
        .unwrap();
        let driver = ScalableDriver::new(
            chain,
            CacheConfig::new(32, 1 << 20),
            clock.clone(),
            CostModel::default(),
            MemoryAccountant::new(),
        );
        let rings = VmRings::new(64, Arc::new(Notify::new()));
        let slot = VmSlot {
            name: "vm".into(),
            driver: Box::new(driver),
            rings,
            stats: Arc::new(VmStats::default()),
            delta: StatsDelta::default(),
            vec_io_seen: VecIoSnapshot::default(),
            trace: None,
            runner: None,
            dead: false,
        };
        (node, slot, clock)
    }

    fn submit_read(slot: &VmSlot, voff: u64, len: usize) {
        let tag = slot.rings.next_tag();
        slot.rings
            .submit(SqEntry::Read { tag, voff, len, t_enq: 0 })
            .unwrap();
    }

    /// Regression (coalescer-counter staleness): completions a client has
    /// already reaped must be visible in the shared stats even when the
    /// serving pass panics later in the same burst — the old code only
    /// mirrored `vec_io()` at the *end* of `serve_slot`, so a panic (and
    /// `kill_slot`) dropped both the StatsDelta and the coalescer
    /// counters of every request that had already completed.
    #[test]
    fn panic_mid_pass_does_not_lose_observed_completions() {
        let (_node, mut slot, clock) = test_slot();
        // a coalescible burst: 8 contiguous reads -> one merged device read
        for i in 0..8u64 {
            submit_read(&slot, i * 4096, 4096);
        }
        // a lone write breaks the read run, so the burst above completes
        // (and its replies are reapable) before the poison entry runs ...
        let wtag = slot.rings.next_tag();
        slot.rings
            .submit(SqEntry::Write {
                tag: wtag,
                voff: 0,
                data: vec![1u8; 512],
                t_enq: 0,
            })
            .unwrap();
        // ... and a read whose buffer cannot be allocated panics the pass
        submit_read(&slot, 0, usize::MAX);
        let res = catch_unwind(AssertUnwindSafe(|| serve_slot(&mut slot, &clock)));
        assert!(res.is_err(), "the poison read must panic the pass");
        // the shard loop's panic containment: kill the slot, fleet lives on
        kill_slot(&mut slot);
        let snap = slot.stats.snapshot();
        assert_eq!(snap.reads, 8, "8 read completions were delivered");
        assert_eq!(snap.writes, 1, "the write completion was delivered");
        assert!(
            snap.merged_ios > 0,
            "the burst's merged device reads must survive the panic"
        );
        assert!(snap.coalesced_bytes > 0);
    }

    /// The coalescer counters flow through the same per-pass reap as
    /// `StatsDelta` — and re-reaping an idle slot must not double-count
    /// (delta watermark, not a lifetime-total store).
    #[test]
    fn coalescer_counters_reap_with_the_pass_flush() {
        let (_node, mut slot, clock) = test_slot();
        for i in 0..8u64 {
            submit_read(&slot, i * 4096, 4096);
        }
        assert_eq!(serve_slot(&mut slot, &clock), 8);
        reap_slot_stats(&mut slot);
        let first = slot.stats.snapshot();
        assert_eq!(first.reads, 8);
        assert!(first.merged_ios > 0, "contiguous burst coalesced");
        // idle pass: nothing new to reap
        reap_slot_stats(&mut slot);
        let second = slot.stats.snapshot();
        assert_eq!(second.merged_ios, first.merged_ios, "no double count");
        assert_eq!(second.coalesced_bytes, first.coalesced_bytes);
        // a second burst adds on top (monotone counters, exporter-safe)
        for i in 0..8u64 {
            submit_read(&slot, i * 4096, 4096);
        }
        assert_eq!(serve_slot(&mut slot, &clock), 8);
        reap_slot_stats(&mut slot);
        let third = slot.stats.snapshot();
        assert!(third.merged_ios > second.merged_ios);
    }
}

/// Account a finished job and drop its runner. A *completed* job
/// changed the chain's shape (stream collapses it), so the new file set
/// is re-declared to the GC registry: dropped backing files lose this
/// chain's reference and are condemned once nothing else holds one.
fn finish_job(slot: &mut VmSlot, gc: &Arc<GcRegistry>) {
    let Some(r) = slot.runner.take() else { return };
    r.shared().clear_waker();
    let st = r.shared().status();
    match st.state {
        JobState::Completed => {
            slot.stats.jobs_completed.fetch_add(1, Relaxed);
            gc.sync_chain(&slot.name, slot.driver.chain().file_names());
        }
        JobState::Cancelled => {
            slot.stats.jobs_cancelled.fetch_add(1, Relaxed);
        }
        _ => {
            slot.stats.jobs_failed.fetch_add(1, Relaxed);
        }
    }
    slot.stats.job_increments.fetch_add(st.increments, Relaxed);
    slot.stats.job_copied_clusters.fetch_add(st.copied, Relaxed);
}
