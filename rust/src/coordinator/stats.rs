//! Per-VM and fleet-level service statistics.

use crate::metrics::histogram::Histogram;
use crate::util::lock_unpoisoned;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Debug, Default)]
pub struct VmStats {
    pub reads: AtomicU64,
    pub writes: AtomicU64,
    pub bytes_read: AtomicU64,
    pub bytes_written: AtomicU64,
    pub snapshots: AtomicU64,
    pub streams: AtomicU64,
    /// Requests rejected/blocked by a full queue (backpressure events).
    pub backpressure: AtomicU64,
    /// Live block jobs (see [`crate::blockjob`]).
    pub jobs_started: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_cancelled: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub job_increments: AtomicU64,
    pub job_copied_clusters: AtomicU64,
    /// Bytes GC physically reclaimed from files this VM's chain dropped
    /// (streamed-away backing files, deleted snapshots).
    pub reclaimed_bytes: AtomicU64,
    /// GC sweeps that reclaimed capacity on behalf of this VM.
    pub gc_runs: AtomicU64,
    /// Guest operations served through the vectored path (explicit
    /// `Request::Batch` submissions plus worker-drained bursts).
    pub batched_ops: AtomicU64,
    /// Mirror of the driver's coalescer counters (device reads that
    /// merged >= 2 cluster segments, and their bytes). Watermark-reaped:
    /// the shard's per-pass stats reaper fetch-adds the delta since the
    /// last flush, so the counters stay monotone for the exporter and
    /// never go stale between batched requests.
    pub merged_ios: AtomicU64,
    pub coalesced_bytes: AtomicU64,
    /// Worker threads of this VM that died panicking: the VM is dead
    /// (its clients see "vm worker gone") but the fleet lives on.
    pub worker_panics: AtomicU64,
    /// Guest-visible request latency (enqueue → reply) in virtual ns —
    /// the number a live job must keep flat while it drains the chain.
    pub req_latency: Mutex<Histogram>,
}

impl VmStats {
    pub fn record_latency(&self, ns: u64) {
        lock_unpoisoned(&self.req_latency).record(ns);
    }

    /// A copy of the full latency distribution (the telemetry fleet
    /// aggregate merges these across VMs at scrape time).
    pub fn latency_histogram(&self) -> Histogram {
        lock_unpoisoned(&self.req_latency).clone()
    }

    pub fn snapshot(&self) -> VmStatsSnapshot {
        let lat = lock_unpoisoned(&self.req_latency);
        VmStatsSnapshot {
            reads: self.reads.load(Ordering::Relaxed),
            writes: self.writes.load(Ordering::Relaxed),
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            snapshots: self.snapshots.load(Ordering::Relaxed),
            streams: self.streams.load(Ordering::Relaxed),
            backpressure: self.backpressure.load(Ordering::Relaxed),
            jobs_started: self.jobs_started.load(Ordering::Relaxed),
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            jobs_cancelled: self.jobs_cancelled.load(Ordering::Relaxed),
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            job_increments: self.job_increments.load(Ordering::Relaxed),
            job_copied_clusters: self.job_copied_clusters.load(Ordering::Relaxed),
            reclaimed_bytes: self.reclaimed_bytes.load(Ordering::Relaxed),
            gc_runs: self.gc_runs.load(Ordering::Relaxed),
            batched_ops: self.batched_ops.load(Ordering::Relaxed),
            merged_ios: self.merged_ios.load(Ordering::Relaxed),
            coalesced_bytes: self.coalesced_bytes.load(Ordering::Relaxed),
            worker_panics: self.worker_panics.load(Ordering::Relaxed),
            req_count: lat.count(),
            req_mean_ns: lat.mean() as u64,
            req_p50_ns: lat.quantile(0.50),
            req_p99_ns: lat.quantile(0.99),
            req_max_ns: lat.max(),
        }
    }
}

/// Hot-path stats accumulator: a shard executor counts served guest
/// requests here (plain fields, no atomics, no locks) and flushes into
/// the shared [`VmStats`] once per serving pass — the "stats reaper"
/// that keeps per-request accounting out of the data plane.
#[derive(Debug, Default)]
pub struct StatsDelta {
    pub reads: u64,
    pub writes: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub batched_ops: u64,
    pub latency: Histogram,
}

impl StatsDelta {
    pub fn is_empty(&self) -> bool {
        self.reads == 0
            && self.writes == 0
            && self.batched_ops == 0
            && self.latency.count() == 0
    }

    pub fn record_latency(&mut self, ns: u64) {
        self.latency.record(ns);
    }

    /// Drain this delta into the shared stats (leaves `self` zeroed).
    pub fn flush_into(&mut self, stats: &VmStats) {
        if self.is_empty() {
            return;
        }
        stats.reads.fetch_add(self.reads, Ordering::Relaxed);
        stats.writes.fetch_add(self.writes, Ordering::Relaxed);
        stats.bytes_read.fetch_add(self.bytes_read, Ordering::Relaxed);
        stats
            .bytes_written
            .fetch_add(self.bytes_written, Ordering::Relaxed);
        stats.batched_ops.fetch_add(self.batched_ops, Ordering::Relaxed);
        if self.latency.count() > 0 {
            lock_unpoisoned(&stats.req_latency).merge(&self.latency);
        }
        *self = StatsDelta::default();
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct VmStatsSnapshot {
    pub reads: u64,
    pub writes: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub snapshots: u64,
    pub streams: u64,
    pub backpressure: u64,
    pub jobs_started: u64,
    pub jobs_completed: u64,
    pub jobs_cancelled: u64,
    pub jobs_failed: u64,
    pub job_increments: u64,
    pub job_copied_clusters: u64,
    pub reclaimed_bytes: u64,
    pub gc_runs: u64,
    pub batched_ops: u64,
    pub merged_ios: u64,
    pub coalesced_bytes: u64,
    pub worker_panics: u64,
    pub req_count: u64,
    pub req_mean_ns: u64,
    pub req_p50_ns: u64,
    pub req_p99_ns: u64,
    pub req_max_ns: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_copies_counters() {
        let s = VmStats::default();
        s.reads.fetch_add(3, Ordering::Relaxed);
        s.bytes_read.fetch_add(100, Ordering::Relaxed);
        let snap = s.snapshot();
        assert_eq!(snap.reads, 3);
        assert_eq!(snap.bytes_read, 100);
        assert_eq!(snap.writes, 0);
        assert_eq!(snap.jobs_started, 0);
    }

    #[test]
    fn snapshot_survives_a_poisoned_latency_lock() {
        // regression (lock-poison cascade): a worker that panics while
        // holding the histogram lock must not take vm_stats down with it
        let s = VmStats::default();
        s.record_latency(500);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = s.req_latency.lock().unwrap();
            panic!("worker dies mid-record");
        }));
        s.record_latency(700);
        let snap = s.snapshot();
        assert_eq!(snap.req_count, 2, "stats keep working after the panic");
    }

    #[test]
    fn delta_flush_accumulates_and_resets() {
        let s = VmStats::default();
        let mut d = StatsDelta::default();
        assert!(d.is_empty());
        d.reads += 2;
        d.bytes_read += 8192;
        d.record_latency(1_000);
        d.record_latency(3_000);
        d.flush_into(&s);
        assert!(d.is_empty(), "flush zeroes the delta");
        d.writes += 1;
        d.bytes_written += 512;
        d.flush_into(&s);
        let snap = s.snapshot();
        assert_eq!(snap.reads, 2);
        assert_eq!(snap.writes, 1);
        assert_eq!(snap.bytes_read, 8192);
        assert_eq!(snap.bytes_written, 512);
        assert_eq!(snap.req_count, 2, "histogram merged");
    }

    #[test]
    fn latency_percentiles_surface_in_snapshot() {
        let s = VmStats::default();
        for _ in 0..99 {
            s.record_latency(1_000);
        }
        s.record_latency(1_000_000);
        let snap = s.snapshot();
        assert_eq!(snap.req_count, 100);
        assert!(snap.req_p50_ns <= 1_000);
        assert!(snap.req_p99_ns >= 900_000 || snap.req_max_ns >= 1_000_000);
        assert!(snap.req_mean_ns > 1_000);
    }
}
