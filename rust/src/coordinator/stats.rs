//! Per-VM and fleet-level service statistics.

use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Debug, Default)]
pub struct VmStats {
    pub reads: AtomicU64,
    pub writes: AtomicU64,
    pub bytes_read: AtomicU64,
    pub bytes_written: AtomicU64,
    pub snapshots: AtomicU64,
    pub streams: AtomicU64,
    /// Requests rejected/blocked by a full queue (backpressure events).
    pub backpressure: AtomicU64,
}

impl VmStats {
    pub fn snapshot(&self) -> VmStatsSnapshot {
        VmStatsSnapshot {
            reads: self.reads.load(Ordering::Relaxed),
            writes: self.writes.load(Ordering::Relaxed),
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            snapshots: self.snapshots.load(Ordering::Relaxed),
            streams: self.streams.load(Ordering::Relaxed),
            backpressure: self.backpressure.load(Ordering::Relaxed),
        }
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct VmStatsSnapshot {
    pub reads: u64,
    pub writes: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub snapshots: u64,
    pub streams: u64,
    pub backpressure: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_copies_counters() {
        let s = VmStats::default();
        s.reads.fetch_add(3, Ordering::Relaxed);
        s.bytes_read.fetch_add(100, Ordering::Relaxed);
        let snap = s.snapshot();
        assert_eq!(snap.reads, 3);
        assert_eq!(snap.bytes_read, 100);
        assert_eq!(snap.writes, 0);
    }
}
