//! Per-VM and fleet-level service statistics.

use crate::metrics::histogram::Histogram;
use crate::util::lock_unpoisoned;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Debug, Default)]
pub struct VmStats {
    pub reads: AtomicU64,
    pub writes: AtomicU64,
    pub bytes_read: AtomicU64,
    pub bytes_written: AtomicU64,
    pub snapshots: AtomicU64,
    pub streams: AtomicU64,
    /// Requests rejected/blocked by a full queue (backpressure events).
    pub backpressure: AtomicU64,
    /// Live block jobs (see [`crate::blockjob`]).
    pub jobs_started: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_cancelled: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub job_increments: AtomicU64,
    pub job_copied_clusters: AtomicU64,
    /// Bytes GC physically reclaimed from files this VM's chain dropped
    /// (streamed-away backing files, deleted snapshots).
    pub reclaimed_bytes: AtomicU64,
    /// GC sweeps that reclaimed capacity on behalf of this VM.
    pub gc_runs: AtomicU64,
    /// Guest operations served through the vectored path (explicit
    /// `Request::Batch` submissions plus worker-drained bursts).
    pub batched_ops: AtomicU64,
    /// Mirror of the driver's coalescer counters (device reads that
    /// merged >= 2 cluster segments, and their bytes), refreshed after
    /// every batched request.
    pub merged_ios: AtomicU64,
    pub coalesced_bytes: AtomicU64,
    /// Worker threads of this VM that died panicking: the VM is dead
    /// (its clients see "vm worker gone") but the fleet lives on.
    pub worker_panics: AtomicU64,
    /// Guest-visible request latency (enqueue → reply) in virtual ns —
    /// the number a live job must keep flat while it drains the chain.
    pub req_latency: Mutex<Histogram>,
}

impl VmStats {
    pub fn record_latency(&self, ns: u64) {
        lock_unpoisoned(&self.req_latency).record(ns);
    }

    pub fn snapshot(&self) -> VmStatsSnapshot {
        let lat = lock_unpoisoned(&self.req_latency);
        VmStatsSnapshot {
            reads: self.reads.load(Ordering::Relaxed),
            writes: self.writes.load(Ordering::Relaxed),
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            snapshots: self.snapshots.load(Ordering::Relaxed),
            streams: self.streams.load(Ordering::Relaxed),
            backpressure: self.backpressure.load(Ordering::Relaxed),
            jobs_started: self.jobs_started.load(Ordering::Relaxed),
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            jobs_cancelled: self.jobs_cancelled.load(Ordering::Relaxed),
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            job_increments: self.job_increments.load(Ordering::Relaxed),
            job_copied_clusters: self.job_copied_clusters.load(Ordering::Relaxed),
            reclaimed_bytes: self.reclaimed_bytes.load(Ordering::Relaxed),
            gc_runs: self.gc_runs.load(Ordering::Relaxed),
            batched_ops: self.batched_ops.load(Ordering::Relaxed),
            merged_ios: self.merged_ios.load(Ordering::Relaxed),
            coalesced_bytes: self.coalesced_bytes.load(Ordering::Relaxed),
            worker_panics: self.worker_panics.load(Ordering::Relaxed),
            req_count: lat.count(),
            req_mean_ns: lat.mean() as u64,
            req_p50_ns: lat.quantile(0.50),
            req_p99_ns: lat.quantile(0.99),
            req_max_ns: lat.max(),
        }
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct VmStatsSnapshot {
    pub reads: u64,
    pub writes: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub snapshots: u64,
    pub streams: u64,
    pub backpressure: u64,
    pub jobs_started: u64,
    pub jobs_completed: u64,
    pub jobs_cancelled: u64,
    pub jobs_failed: u64,
    pub job_increments: u64,
    pub job_copied_clusters: u64,
    pub reclaimed_bytes: u64,
    pub gc_runs: u64,
    pub batched_ops: u64,
    pub merged_ios: u64,
    pub coalesced_bytes: u64,
    pub worker_panics: u64,
    pub req_count: u64,
    pub req_mean_ns: u64,
    pub req_p50_ns: u64,
    pub req_p99_ns: u64,
    pub req_max_ns: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_copies_counters() {
        let s = VmStats::default();
        s.reads.fetch_add(3, Ordering::Relaxed);
        s.bytes_read.fetch_add(100, Ordering::Relaxed);
        let snap = s.snapshot();
        assert_eq!(snap.reads, 3);
        assert_eq!(snap.bytes_read, 100);
        assert_eq!(snap.writes, 0);
        assert_eq!(snap.jobs_started, 0);
    }

    #[test]
    fn snapshot_survives_a_poisoned_latency_lock() {
        // regression (lock-poison cascade): a worker that panics while
        // holding the histogram lock must not take vm_stats down with it
        let s = VmStats::default();
        s.record_latency(500);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = s.req_latency.lock().unwrap();
            panic!("worker dies mid-record");
        }));
        s.record_latency(700);
        let snap = s.snapshot();
        assert_eq!(snap.req_count, 2, "stats keep working after the panic");
    }

    #[test]
    fn latency_percentiles_surface_in_snapshot() {
        let s = VmStats::default();
        for _ in 0..99 {
            s.record_latency(1_000);
        }
        s.record_latency(1_000_000);
        let snap = s.snapshot();
        assert_eq!(snap.req_count, 100);
        assert!(snap.req_p50_ns <= 1_000);
        assert!(snap.req_p99_ns >= 900_000 || snap.req_max_ns >= 1_000_000);
        assert!(snap.req_mean_ns > 1_000);
    }
}
