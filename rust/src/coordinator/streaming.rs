//! Streaming orchestration: plan a backing-file merge with the
//! `stream_fold` kernel, validate the plan against the on-disk state,
//! then execute [`crate::qcow::snapshot::stream_merge`].
//!
//! §4.1 notes streaming disrupts guest I/O (a 100x latency hit on their
//! testbed); the orchestrator therefore runs merges while the VM worker
//! is paused (the server drains the queue first) and reports the merge
//! cost so operators can schedule it.

use super::batcher::BulkTranslator;
use crate::qcow::{qcheck, snapshot, Chain};
use crate::runtime::service::RuntimeService;
use crate::runtime::{host, UNALLOCATED};
use anyhow::{bail, Result};

pub struct StreamingOrchestrator {
    runtime: Option<RuntimeService>,
}

/// Outcome of a planned + executed merge.
#[derive(Clone, Debug)]
pub struct StreamReport {
    pub from: u16,
    pub to: u16,
    /// Clusters the plan predicted the window resolves (kernel-side).
    pub planned_clusters: u64,
    /// Data clusters actually copied by the merge.
    pub copied_clusters: u64,
    /// Chain length before/after.
    pub len_before: usize,
    pub len_after: usize,
    /// Virtual ns the merge took (the guest-visible disruption window).
    pub merge_ns: u64,
}

impl StreamingOrchestrator {
    pub fn new(runtime: Option<RuntimeService>) -> Self {
        StreamingOrchestrator { runtime }
    }

    /// Plan: fold the per-file tables of the window `[from, to]` and
    /// count the clusters whose latest version lives in a *dropped* file
    /// (those must be copied). Uses the `stream_fold` PJRT kernel when
    /// loaded, tiling over both depth and table width.
    pub fn plan(&self, chain: &Chain, from: u16, to: u16) -> Result<u64> {
        let (tile_c, tile_d) = match &self.runtime {
            Some(rt) => (rt.clusters, rt.stream_depth),
            None => (8192, 8),
        };
        self.plan_with_tiles(chain, from, to, tile_c, tile_d)
    }

    /// [`StreamingOrchestrator::plan`] with explicit tile sizes. A depth
    /// tile holds the carried accumulator row plus `tile_d - 1` table
    /// rows, clamped to at least one table row per pass — a `tile_d` of 1
    /// (a runtime exporting `stream_depth: 1`) must still advance the
    /// fold cursor, not spin forever; such a pass exceeds the kernel's
    /// row capacity and folds on the host instead.
    fn plan_with_tiles(
        &self,
        chain: &Chain,
        from: u16,
        to: u16,
        tile_c: usize,
        tile_d: usize,
    ) -> Result<u64> {
        if from >= to || (to as usize) >= chain.len() {
            bail!("invalid stream window {from}..={to}");
        }
        let geom = *chain.active().geom();
        let total = geom.num_vclusters() as usize;
        let tile_c = tile_c.max(1);
        let rows_per_pass = tile_d.saturating_sub(1).max(1);
        let mut planned = 0u64;
        let mut start = 0usize;
        while start < total {
            let width = tile_c.min(total - start);
            // fold the window in depth-sized passes, carrying the
            // accumulated table forward (merge is associative)
            let mut acc_off = vec![UNALLOCATED; width];
            let mut acc_bfi = vec![UNALLOCATED; width];
            let mut idx = from;
            while idx <= to {
                let depth = ((to - idx + 1) as usize).min(rows_per_pass);
                let mut offs = vec![(acc_off.clone(), acc_bfi.clone())];
                for d in 0..depth {
                    let img = chain.get(idx + d as u16).unwrap();
                    let mut off = vec![UNALLOCATED; width];
                    let mut bfi = vec![UNALLOCATED; width];
                    for (i, vc) in (start as u64..(start + width) as u64).enumerate() {
                        // stamps are authoritative (matching stream_merge's
                        // owner scan): a stamped entry — including a dedup
                        // share into another file — names the real owner,
                        // so the fold's newest row carries the true bfi
                        if let Some((b, o)) = img.l2_entry(vc)?.sqemu_view(idx + d as u16) {
                            off[i] = (o >> geom.cluster_bits) as i32;
                            bfi[i] = b as i32;
                        }
                    }
                    offs.push((off, bfi));
                }
                let off_rows: Vec<Vec<i32>> = offs.iter().map(|(o, _)| o.clone()).collect();
                let bfi_rows: Vec<Vec<i32>> = offs.iter().map(|(_, b)| b.clone()).collect();
                let (no, nb) = match &self.runtime {
                    // accumulator + depth rows must fit the exported depth
                    Some(rt) if off_rows.len() <= rt.stream_depth => {
                        rt.stream_fold(&off_rows, &bfi_rows)?
                    }
                    _ => host::stream_fold(&off_rows, &bfi_rows),
                };
                acc_off = no;
                acc_bfi = nb;
                idx += depth as u16;
            }
            planned += acc_bfi
                .iter()
                .filter(|&&b| b != UNALLOCATED && (b as u16) >= from && (b as u16) < to)
                .count() as u64;
            start += width;
        }
        Ok(planned)
    }

    /// Plan, execute and validate a merge. The caller must have paused
    /// the VM owning the chain (the server does).
    pub fn merge(&self, chain: &mut Chain, from: u16, to: u16) -> Result<StreamReport> {
        let planned = self.plan(chain, from, to)?;
        let len_before = chain.len();
        // the disruption window is measured on the chain's own node
        // clock, so CLI/test callers get a real number, not a
        // server-filled placeholder (clock-less backends report 0-0)
        let t0 = chain.active().backend().now_ns();
        let copied = snapshot::stream_merge(chain, from, to)?;
        if copied != planned {
            bail!("stream plan mismatch: planned {planned}, copied {copied}");
        }
        // post-merge consistency gate: a merge that corrupted the chain
        // must fail loudly, not hand the VM a broken disk
        let check = qcheck::check_chain(chain)?;
        if !check.is_clean() {
            bail!(
                "post-merge qcheck found {} errors: {}",
                check.errors.len(),
                check.errors.join("; ")
            );
        }
        Ok(StreamReport {
            from,
            to,
            planned_clusters: planned,
            copied_clusters: copied,
            len_before,
            len_after: chain.len(),
            merge_ns: chain.active().backend().now_ns().saturating_sub(t0),
        })
    }

    pub fn is_accelerated(&self) -> bool {
        self.runtime.is_some()
    }

    /// Expose the translator sharing this orchestrator's runtime.
    pub fn translator(&self) -> BulkTranslator {
        BulkTranslator::new(self.runtime.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaingen::{generate, ChainSpec};
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::qcow::image::DataMode;
    use crate::qcow::qcheck;
    use crate::storage::node::StorageNode;

    fn chain(len: usize) -> Chain {
        let node = StorageNode::new("s", VirtClock::new(), CostModel::default());
        generate(
            &*node,
            &ChainSpec {
                disk_size: 16 << 20,
                chain_len: len,
                populated: 0.5,
                data_mode: DataMode::Real,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn plan_matches_execution_host_path() {
        let mut c = chain(6);
        let orch = StreamingOrchestrator::new(None);
        let report = orch.merge(&mut c, 1, 3).unwrap();
        assert_eq!(report.planned_clusters, report.copied_clusters);
        assert_eq!(report.len_after, report.len_before - 2);
        assert!(qcheck::check_chain(&c).unwrap().is_clean());
    }

    #[test]
    fn plan_matches_execution_pjrt_path() {
        let Some(svc) = RuntimeService::try_default() else {
            eprintln!("SKIP: no artifacts");
            return;
        };
        let mut c = chain(12);
        let orch = StreamingOrchestrator::new(Some(svc));
        assert!(orch.is_accelerated());
        let report = orch.merge(&mut c, 0, 9).unwrap();
        assert_eq!(report.planned_clusters, report.copied_clusters);
        assert_eq!(report.len_after, report.len_before - 9);
        assert!(qcheck::check_chain(&c).unwrap().is_clean());
    }

    #[test]
    fn rejects_bad_window() {
        let c = chain(3);
        let orch = StreamingOrchestrator::new(None);
        assert!(orch.plan(&c, 2, 2).is_err());
        assert!(orch.plan(&c, 0, 5).is_err());
    }

    #[test]
    fn plan_terminates_and_agrees_at_depth_tile_one() {
        // regression: a runtime exporting stream_depth = 1 used to clamp
        // the per-pass depth to 0, so the fold cursor never advanced and
        // plan() spun forever; the pass must carry at least one table row
        let c = chain(6);
        let orch = StreamingOrchestrator::new(None);
        let reference = orch.plan(&c, 1, 4).unwrap();
        for tile_d in [1usize, 2, 3] {
            let planned = orch.plan_with_tiles(&c, 1, 4, 8192, tile_d).unwrap();
            assert_eq!(planned, reference, "tile_d={tile_d}");
        }
        // narrow width tiles must agree too
        assert_eq!(orch.plan_with_tiles(&c, 1, 4, 7, 1).unwrap(), reference);
    }

    #[test]
    fn merge_reports_nonzero_disruption_window() {
        // regression: merge_ns was hardcoded 0 ("filled by the server"),
        // so CLI/test callers reported a zero disruption window; it is
        // now measured on the chain's node clock inside merge()
        let mut c = chain(6);
        let orch = StreamingOrchestrator::new(None);
        let report = orch.merge(&mut c, 1, 3).unwrap();
        assert!(report.copied_clusters > 0, "merge did real work");
        assert!(
            report.merge_ns > 0,
            "disruption window must be measured, not a placeholder"
        );
    }
}
