//! Logical-vs-physical capacity scanner.
//!
//! *Physical* bytes are what a node's files actually store — already
//! post-zero, post-compression, post-dedup, because special clusters
//! allocate less (or nothing). *Logical* bytes are what the guests can
//! address: every virtual cluster a chain maps, whatever trick stores
//! it. The ratio of the two is the fleet's capacity multiplication
//! (Fig 24). Logical bytes are computed by scanning L1/L2 tables rather
//! than by incremental counters: chains migrate between nodes and
//! crash-recover, and a scan is always right where a counter drifts.

use super::{content_hash, DedupIndex};
use crate::qcow::image::DataMode;
use crate::qcow::{Chain, Image, L2Entry};
use anyhow::Result;

/// Per-image census of mapped L2 entries by storage class.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MappedBreakdown {
    /// Plain locally-allocated data clusters.
    pub plain: u64,
    /// `OFLAG_ZERO` clusters (present, zero stored bytes).
    pub zero: u64,
    /// `OFLAG_COMPRESSED` clusters (sub-cluster stored bytes).
    pub compressed: u64,
    /// Remote references (snapshot-copy stamps and dedup shares into a
    /// backing file of the same chain).
    pub remote: u64,
}

impl MappedBreakdown {
    /// Entries that are present in this image (shadow the backing file).
    pub fn mapped(&self) -> u64 {
        self.plain + self.zero + self.compressed + self.remote
    }
}

/// Scan one image's tables and classify every mapped entry.
pub fn image_breakdown(img: &Image) -> Result<MappedBreakdown> {
    let geom = *img.geom();
    let mut b = MappedBreakdown::default();
    for l1_idx in 0..geom.l1_entries() {
        let l2_off = img.l1_entry(l1_idx);
        if l2_off == 0 {
            continue;
        }
        let entries = img.read_l2_slice(l2_off, 0, geom.entries_per_l2())?;
        for &raw in &entries {
            let e = L2Entry(raw);
            if e.is_zero() {
                continue;
            }
            if e.is_zero_cluster() {
                b.zero += 1;
            } else if e.is_compressed() {
                b.compressed += 1;
            } else if e.is_allocated_here() {
                b.plain += 1;
            } else {
                b.remote += 1;
            }
        }
    }
    Ok(b)
}

/// Guest-addressable mapped bytes of a chain: the number of distinct
/// virtual clusters mapped by *any* image in the chain, times the
/// cluster size. This is what the fleet would store with no sharing at
/// all — each chain bills the full content its guest can read,
/// including the clusters it inherits from a shared golden base.
pub fn chain_logical_bytes(chain: &Chain) -> Result<u64> {
    let geom = *chain.active().geom();
    let n = geom.num_vclusters() as usize;
    let mut mapped = vec![false; n];
    for img in chain.images() {
        let geom = *img.geom();
        for l1_idx in 0..geom.l1_entries() {
            let l2_off = img.l1_entry(l1_idx);
            if l2_off == 0 {
                continue;
            }
            let entries = img.read_l2_slice(l2_off, 0, geom.entries_per_l2())?;
            for (l2_idx, &raw) in entries.iter().enumerate() {
                if raw != 0 {
                    let vc = l1_idx * geom.entries_per_l2() + l2_idx as u64;
                    if let Some(m) = mapped.get_mut(vc as usize) {
                        *m = true;
                    }
                }
            }
        }
    }
    Ok(mapped.iter().filter(|&&m| m).count() as u64 * geom.cluster_size())
}

/// Physical bytes of a chain: what its files actually occupy.
pub fn chain_physical_bytes(chain: &Chain) -> u64 {
    chain.total_file_bytes()
}

/// Declare every plain data cluster of a chain's *immutable* backing
/// files as shareable extents in `index`.
///
/// Clones launched over a shared golden base can then resolve guest
/// rewrites of base content — the in-guest file-copy / reinstall
/// pattern — to remote references instead of fresh allocations. The
/// active volume is deliberately excluded: its clusters can be
/// rewritten in place, which would leave stale extents behind;
/// active-file extents enter the index through the write path, which
/// retires them on overwrite. Synthetic images are skipped (content is
/// generated, not stored, so a hash of it is meaningless). Returns the
/// number of clusters hashed.
pub fn seed_chain(index: &DedupIndex, node: &str, chain: &Chain) -> Result<u64> {
    let imgs = chain.images();
    let Some((_active, backing)) = imgs.split_last() else {
        return Ok(0);
    };
    let mut hashed = 0u64;
    for img in backing {
        if img.data_mode() != DataMode::Real {
            continue;
        }
        let geom = *img.geom();
        let mut buf = vec![0u8; geom.cluster_size() as usize];
        for l1_idx in 0..geom.l1_entries() {
            let l2_off = img.l1_entry(l1_idx);
            if l2_off == 0 {
                continue;
            }
            let entries = img.read_l2_slice(l2_off, 0, geom.entries_per_l2())?;
            for &raw in &entries {
                let e = L2Entry(raw);
                if e.is_zero()
                    || e.is_zero_cluster()
                    || e.is_compressed()
                    || !e.is_allocated_here()
                {
                    continue;
                }
                img.read_data(e.host_offset(), 0, &mut buf)?;
                index.declare(node, content_hash(&buf), &img.name, e.host_offset());
                hashed += 1;
            }
        }
    }
    Ok(hashed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qcow::image::DataMode;
    use crate::qcow::layout::{Geometry, FEATURE_BFI};
    use crate::storage::mem::MemBackend;
    use std::sync::Arc;

    fn img() -> Image {
        Image::create(
            "cap-0",
            Arc::new(MemBackend::new()),
            Geometry::new(16, 16 << 20).unwrap(),
            FEATURE_BFI,
            0,
            None,
            DataMode::Real,
        )
        .unwrap()
    }

    #[test]
    fn breakdown_classifies_all_entry_kinds() {
        let i = img();
        let off = i.alloc_data_cluster().unwrap();
        i.set_l2_entry(0, L2Entry::local(off, Some(0))).unwrap();
        i.set_l2_entry(1, L2Entry::zero_cluster(Some(0))).unwrap();
        i.set_l2_entry(2, L2Entry::compressed(off, 8, Some(0))).unwrap();
        i.set_l2_entry(3, L2Entry::remote(off, 0)).unwrap();
        let b = image_breakdown(&i).unwrap();
        assert_eq!(
            b,
            MappedBreakdown { plain: 1, zero: 1, compressed: 1, remote: 1 }
        );
        assert_eq!(b.mapped(), 4);
    }

    #[test]
    fn chain_logical_counts_distinct_vclusters() {
        let i = img();
        let off = i.alloc_data_cluster().unwrap();
        i.set_l2_entry(0, L2Entry::local(off, Some(0))).unwrap();
        i.set_l2_entry(5, L2Entry::zero_cluster(Some(0))).unwrap();
        let chain = Chain::new(Arc::new(i)).unwrap();
        let cs = chain.active().geom().cluster_size();
        assert_eq!(chain_logical_bytes(&chain).unwrap(), 2 * cs);
        assert!(chain_physical_bytes(&chain) > 0);
    }
}
