//! Per-cluster compression codec for `OFLAG_COMPRESSED` payloads.
//!
//! A dependency-free byte-level RLE: guest images are full of long
//! repeated runs (zero padding, freshly formatted filesystems, fill
//! patterns), which is exactly what per-cluster compression is expected
//! to catch in this reproduction. The on-disk payload embeds its own
//! compressed length so a read costs exactly one device I/O of the
//! stored (unit-rounded) size — the `Timed` backend then bills the
//! compressed bytes, not the logical cluster.
//!
//! Token stream:
//! * control byte `c < 0x80`  — literal run: the next `c + 1` bytes are
//!   copied verbatim (1..=128 literals).
//! * control byte `c >= 0x80` — repeat run: the next byte repeats
//!   `(c - 0x80) + RUN_MIN` times (4..=131).
//!
//! Worst case (incompressible data) expands by 1/128 + O(1), so
//! [`try_compress`] only reports success when the framed payload is
//! strictly smaller than the input cluster.

use anyhow::{bail, Result};

/// Shortest run worth a repeat token (a repeat token costs 2 bytes).
const RUN_MIN: usize = 4;
const RUN_MAX: usize = 131;
const LIT_MAX: usize = 128;

/// Bytes of framing prepended to the compressed stream on disk.
pub const FRAME_BYTES: u64 = 4;

/// Compress `src`. Returns the raw token stream (unframed).
pub fn compress(src: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(src.len() / 4 + 8);
    let mut lit_start = 0usize;
    let mut i = 0usize;
    while i < src.len() {
        // measure the run starting at i
        let b = src[i];
        let mut run = 1usize;
        while run < RUN_MAX && i + run < src.len() && src[i + run] == b {
            run += 1;
        }
        if run >= RUN_MIN {
            flush_literals(&mut out, &src[lit_start..i]);
            out.push(0x80 + (run - RUN_MIN) as u8);
            out.push(b);
            i += run;
            lit_start = i;
        } else {
            i += run;
        }
    }
    flush_literals(&mut out, &src[lit_start..]);
    out
}

fn flush_literals(out: &mut Vec<u8>, mut lits: &[u8]) {
    while !lits.is_empty() {
        let n = lits.len().min(LIT_MAX);
        out.push((n - 1) as u8);
        out.extend_from_slice(&lits[..n]);
        lits = &lits[n..];
    }
}

/// Decompress a token stream into `out`, which must be filled exactly.
pub fn decompress(src: &[u8], out: &mut [u8]) -> Result<()> {
    let mut i = 0usize;
    let mut o = 0usize;
    while i < src.len() {
        let c = src[i] as usize;
        i += 1;
        if c < 0x80 {
            let n = c + 1;
            if i + n > src.len() || o + n > out.len() {
                bail!("corrupt compressed payload (literal run overflow)");
            }
            out[o..o + n].copy_from_slice(&src[i..i + n]);
            i += n;
            o += n;
        } else {
            let n = (c - 0x80) + RUN_MIN;
            if i >= src.len() || o + n > out.len() {
                bail!("corrupt compressed payload (repeat run overflow)");
            }
            out[o..o + n].fill(src[i]);
            i += 1;
            o += n;
        }
    }
    if o != out.len() {
        bail!("corrupt compressed payload (short output: {o} of {})", out.len());
    }
    Ok(())
}

/// Compress a full cluster for on-disk storage: `[comp_len u32 LE]` +
/// token stream. Returns `None` when the framed payload is not strictly
/// smaller than the cluster (store it uncompressed instead).
pub fn try_compress(cluster: &[u8]) -> Option<Vec<u8>> {
    let tokens = compress(cluster);
    let framed = FRAME_BYTES as usize + tokens.len();
    if framed >= cluster.len() {
        return None;
    }
    let mut out = Vec::with_capacity(framed);
    out.extend_from_slice(&(tokens.len() as u32).to_le_bytes());
    out.extend_from_slice(&tokens);
    Some(out)
}

/// Decode a framed payload (as stored on disk, possibly with unit-round
/// padding after the stream) into a full cluster buffer.
pub fn decode_framed(stored: &[u8], out: &mut [u8]) -> Result<()> {
    if stored.len() < FRAME_BYTES as usize {
        bail!("compressed payload shorter than its frame");
    }
    let comp_len = u32::from_le_bytes(stored[..4].try_into().unwrap()) as usize;
    let Some(tokens) = stored[4..].get(..comp_len) else {
        bail!("compressed payload length {comp_len} exceeds stored bytes");
    };
    decompress(tokens, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(src: &[u8]) {
        let tokens = compress(src);
        let mut out = vec![0xAAu8; src.len()];
        decompress(&tokens, &mut out).unwrap();
        assert_eq!(out, src);
    }

    #[test]
    fn roundtrip_patterns() {
        roundtrip(&[]);
        roundtrip(&[7]);
        roundtrip(&[0u8; 4096]);
        roundtrip(&[0xFF; 131 * 3 + 5]);
        let mixed: Vec<u8> = (0..4096u32).map(|i| (i % 251) as u8).collect();
        roundtrip(&mixed);
        let mut runs = vec![0u8; 1000];
        runs.extend((0..500u32).map(|i| (i * 7 % 256) as u8));
        runs.extend(vec![9u8; 300]);
        roundtrip(&runs);
    }

    #[test]
    fn repetitive_data_shrinks() {
        let zeros = vec![0u8; 65536];
        let framed = try_compress(&zeros).expect("zeros compress");
        assert!(framed.len() < 2048, "64 KiB of zeros -> {} B", framed.len());
        let mut out = vec![1u8; 65536];
        decode_framed(&framed, &mut out).unwrap();
        assert_eq!(out, zeros);
    }

    #[test]
    fn incompressible_data_is_rejected() {
        // counter-mode pseudo-noise has no runs >= RUN_MIN
        let noise: Vec<u8> = (0..4096u64)
            .map(|i| (i.wrapping_mul(0x9E3779B97F4A7C15) >> 56) as u8)
            .collect();
        assert!(try_compress(&noise).is_none());
    }

    #[test]
    fn framed_payload_tolerates_padding() {
        let data = vec![5u8; 512];
        let mut framed = try_compress(&data).unwrap();
        framed.resize(framed.len() + 37, 0); // unit-round padding
        let mut out = vec![0u8; 512];
        decode_framed(&framed, &mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn corrupt_payloads_error_not_panic() {
        let mut out = vec![0u8; 64];
        assert!(decompress(&[0x7F, 1, 2], &mut out).is_err()); // short literals
        assert!(decompress(&[0xFF], &mut out).is_err()); // missing repeat byte
        assert!(decode_framed(&[1, 0], &mut out).is_err()); // short frame
        assert!(decode_framed(&[200, 0, 0, 0, 1], &mut out).is_err()); // bad len
    }
}
