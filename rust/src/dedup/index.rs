//! Fleet-wide content-addressed extent index.
//!
//! Generalizes the GC registry's `(node, file)` refcounting to
//! `(node, content-hash)` *extents*: when a driver writes a full cluster
//! whose bytes already exist on the same storage node — in the shared
//! golden base of a cloned population, or earlier in its own head — the
//! new L2 entry references the existing extent instead of allocating a
//! fresh cluster, and the index counts one more sharer.
//!
//! The index is a **volatile accelerator + accounting structure**, not a
//! correctness anchor: physical sharing is always protected by on-disk
//! cluster refcounts (`Allocator::incref`, sharers within one file) or
//! file-level GC refcounts (remote references into a backing file of the
//! same chain, which `GcRegistry::sync_chain` already pins). Crash
//! recovery clears it ([`DedupIndex::clear`]); the only cost of a lost
//! entry is a missed sharing opportunity. The invariants it must keep
//! while alive:
//!
//! * an extent is only handed out for sharing while its `(file, word)`
//!   still holds the declared bytes — any overwrite or free of a
//!   declared cluster retires the extent first ([`DedupIndex::retire`]);
//! * an extent's refcount counts every L2 entry referencing it (the
//!   declaring write included), so reclaim of the backing cluster is
//!   gated on the count reaching zero ([`DedupIndex::release`]);
//! * extents of a GC-deleted file are dropped with the file
//!   ([`DedupIndex::drop_file`], wired into the coordinator's sweep).

use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

/// One stored copy of some cluster content on a node.
#[derive(Clone, Debug)]
pub struct Extent {
    /// Image file holding the bytes.
    pub file: String,
    /// Offset *word* inside the file — includes descriptor bits, so a
    /// compressed extent is shared as a compressed reference.
    pub word: u64,
    /// L2 entries referencing this extent (declarer included).
    pub refs: u64,
}

#[derive(Default)]
struct Inner {
    /// (node, content hash) -> extent. BTreeMap: deterministic iteration
    /// for status output and the audit hook.
    extents: BTreeMap<(String, u64), Extent>,
    /// (node, file, word) -> hash: reverse map so overwrites and frees —
    /// which know *where*, not *what* — can retire the extent.
    by_loc: BTreeMap<(String, String, u64), u64>,
    /// node -> logical bytes served by sharing instead of allocation.
    saved_bytes: HashMap<String, u64>,
    /// Lifetime operation counters (telemetry): dedup hits that shared
    /// an extent, CoW reference releases, and in-place retirements.
    ops: DedupOps,
}

/// Cumulative dedup operation counters (the telemetry
/// hit/share/CoW/reclaim families). Monotone for the exporter.
#[derive(Clone, Copy, Debug, Default)]
pub struct DedupOps {
    /// Writes served by taking a reference on an existing extent.
    pub shares: u64,
    /// References dropped by overwrite/free (the CoW break path).
    pub releases: u64,
    /// Extents withdrawn from sharing by in-place overwrite.
    pub retires: u64,
}

/// Per-node / fleet dedup counters for status output.
#[derive(Clone, Debug, Default)]
pub struct DedupStats {
    pub extents: u64,
    /// Total sharers across all extents (>= extents).
    pub refs: u64,
    /// Bytes of guest writes served by sharing an existing extent.
    pub saved_bytes: u64,
}

/// The fleet-wide index. One per coordinator, shared by every driver.
#[derive(Default)]
pub struct DedupIndex {
    inner: Mutex<Inner>,
}

impl DedupIndex {
    pub fn new() -> DedupIndex {
        DedupIndex::default()
    }

    /// Register freshly written cluster content as shareable. First
    /// writer wins: if the hash is already declared on this node the
    /// existing extent stays (the caller missed the lookup race and
    /// simply stored a private copy).
    pub fn declare(&self, node: &str, hash: u64, file: &str, word: u64) {
        let mut inner = self.inner.lock().unwrap();
        let key = (node.to_string(), hash);
        if inner.extents.contains_key(&key) {
            return;
        }
        inner.extents.insert(
            key,
            Extent { file: file.to_string(), word, refs: 1 },
        );
        inner
            .by_loc
            .insert((node.to_string(), file.to_string(), word), hash);
    }

    /// Find an extent for `hash` on `node` without taking a reference.
    pub fn lookup(&self, node: &str, hash: u64) -> Option<Extent> {
        self.inner
            .lock()
            .unwrap()
            .extents
            .get(&(node.to_string(), hash))
            .cloned()
    }

    /// Take one more reference on an extent (a write was served by
    /// sharing it); `bytes` is the logical cluster size saved.
    pub fn share(&self, node: &str, hash: u64, bytes: u64) -> Option<Extent> {
        let mut inner = self.inner.lock().unwrap();
        let e = inner.extents.get_mut(&(node.to_string(), hash))?;
        e.refs += 1;
        let out = e.clone();
        *inner.saved_bytes.entry(node.to_string()).or_default() += bytes;
        inner.ops.shares += 1;
        Some(out)
    }

    /// Drop one reference from the extent at `(node, file, word)` — a
    /// sharer (or the declarer) was overwritten or freed. Returns the
    /// remaining refcount; the extent disappears at zero. No-op (None)
    /// if the location is not a declared extent.
    pub fn release(&self, node: &str, file: &str, word: u64) -> Option<u64> {
        let mut inner = self.inner.lock().unwrap();
        let loc = (node.to_string(), file.to_string(), word);
        let hash = *inner.by_loc.get(&loc)?;
        let key = (node.to_string(), hash);
        let e = inner.extents.get_mut(&key)?;
        e.refs -= 1;
        let left = e.refs;
        if left == 0 {
            inner.extents.remove(&key);
            inner.by_loc.remove(&loc);
        }
        inner.ops.releases += 1;
        Some(left)
    }

    /// The content at `(node, file, word)` is about to change (in-place
    /// overwrite of a declared cluster): the extent no longer describes
    /// stored bytes, so withdraw it from sharing entirely, whatever its
    /// refcount. Existing sharers keep their on-disk references (the
    /// cluster itself is refcount-protected); only future sharing stops.
    pub fn retire(&self, node: &str, file: &str, word: u64) {
        let mut inner = self.inner.lock().unwrap();
        let loc = (node.to_string(), file.to_string(), word);
        if let Some(hash) = inner.by_loc.remove(&loc) {
            inner.extents.remove(&(node.to_string(), hash));
            inner.ops.retires += 1;
        }
    }

    /// A file was physically deleted (GC sweep) or left its node
    /// (migration switchover): drop every extent stored in it, on any
    /// node. Sharers' on-disk references were release-gated before the
    /// file could be condemned, so this only prunes the index.
    pub fn drop_file(&self, file: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.extents.retain(|_, e| e.file != file);
        inner
            .by_loc
            .retain(|(_, f, _), _| f != file);
    }

    /// Drop every extent whose backing file fails `exists` — the
    /// post-sweep reconciliation (GC deletes whole condemned files, so
    /// pruning by surviving file set needs no per-deletion callback).
    /// Returns the number of extents pruned.
    pub fn prune_missing(&self, exists: impl Fn(&str) -> bool) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        let before = inner.extents.len();
        inner.extents.retain(|_, e| exists(&e.file));
        inner.by_loc.retain(|(_, f, _), _| exists(f));
        (before - inner.extents.len()) as u64
    }

    /// Forget everything (crash recovery: the index is volatile state).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.extents.clear();
        inner.by_loc.clear();
        // saved_bytes survives: it is a cumulative savings ledger, not a
        // claim about current index contents
    }

    /// Counters for one node.
    pub fn node_stats(&self, node: &str) -> DedupStats {
        let inner = self.inner.lock().unwrap();
        let mut s = DedupStats::default();
        for ((n, _), e) in inner.extents.iter() {
            if n == node {
                s.extents += 1;
                s.refs += e.refs;
            }
        }
        s.saved_bytes = inner.saved_bytes.get(node).copied().unwrap_or(0);
        s
    }

    /// Fleet-wide counters.
    pub fn fleet_stats(&self) -> DedupStats {
        let inner = self.inner.lock().unwrap();
        DedupStats {
            extents: inner.extents.len() as u64,
            refs: inner.extents.values().map(|e| e.refs).sum(),
            saved_bytes: inner.saved_bytes.values().sum(),
        }
    }

    /// Lifetime operation counters (telemetry hit/CoW/reclaim families).
    pub fn op_counts(&self) -> DedupOps {
        self.inner.lock().unwrap().ops
    }

    /// Audit hook: extents whose backing file fails `exists` — should
    /// always be empty when the sweep wiring is correct.
    pub fn stale_extents(&self, exists: impl Fn(&str) -> bool) -> Vec<(String, u64)> {
        let inner = self.inner.lock().unwrap();
        inner
            .extents
            .iter()
            .filter(|(_, e)| !exists(&e.file))
            .map(|((n, h), _)| (n.clone(), *h))
            .collect()
    }
}

/// FNV-1a over cluster bytes — the content hash. Stable, dependency-free
/// and fast enough for the simulated fleet; collisions are guarded by
/// the honest path (a collision would share wrong bytes, so production
/// systems use a cryptographic hash — the structure is what the
/// reproduction studies, not the hash width).
pub fn content_hash(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn declare_lookup_share_release() {
        let ix = DedupIndex::new();
        let h = content_hash(b"cluster-bytes");
        ix.declare("n0", h, "base-0", 7 << 16);
        let e = ix.lookup("n0", h).unwrap();
        assert_eq!(e.file, "base-0");
        assert_eq!(e.word, 7 << 16);
        assert_eq!(e.refs, 1);
        // other node: miss (dedup cannot span nodes physically)
        assert!(ix.lookup("n1", h).is_none());
        let e = ix.share("n0", h, 65536).unwrap();
        assert_eq!(e.refs, 2);
        assert_eq!(ix.node_stats("n0").saved_bytes, 65536);
        // a sharer goes away: extent survives
        assert_eq!(ix.release("n0", "base-0", 7 << 16), Some(1));
        assert!(ix.lookup("n0", h).is_some(), "still one reference");
        // last reference: extent reclaimed
        assert_eq!(ix.release("n0", "base-0", 7 << 16), Some(0));
        assert!(ix.lookup("n0", h).is_none());
        assert_eq!(ix.release("n0", "base-0", 7 << 16), None, "idempotent");
        let ops = ix.op_counts();
        assert_eq!((ops.shares, ops.releases, ops.retires), (1, 2, 0));
    }

    #[test]
    fn shared_extent_never_reclaimed_early() {
        let ix = DedupIndex::new();
        let h = content_hash(b"shared");
        ix.declare("n0", h, "head-1", 3 << 16);
        ix.share("n0", h, 1 << 16);
        ix.share("n0", h, 1 << 16);
        // two releases: two sharers still outstanding after the first
        assert_eq!(ix.release("n0", "head-1", 3 << 16), Some(2));
        assert_eq!(ix.release("n0", "head-1", 3 << 16), Some(1));
        assert!(ix.lookup("n0", h).is_some());
        assert_eq!(ix.release("n0", "head-1", 3 << 16), Some(0));
        assert!(ix.lookup("n0", h).is_none());
    }

    #[test]
    fn retire_withdraws_changed_content() {
        let ix = DedupIndex::new();
        let h = content_hash(b"v1");
        ix.declare("n0", h, "head-1", 5 << 16);
        ix.share("n0", h, 1 << 16);
        // the declared cluster is overwritten in place: no new sharing
        ix.retire("n0", "head-1", 5 << 16);
        assert!(ix.lookup("n0", h).is_none());
        // redeclare with the new content at the same location
        let h2 = content_hash(b"v2");
        ix.declare("n0", h2, "head-1", 5 << 16);
        assert!(ix.lookup("n0", h2).is_some());
        assert_eq!(ix.op_counts().retires, 1);
    }

    #[test]
    fn drop_file_prunes_and_audit_sees_stale() {
        let ix = DedupIndex::new();
        ix.declare("n0", 11, "base-0", 1 << 16);
        ix.declare("n0", 22, "head-1", 2 << 16);
        ix.declare("n1", 33, "base-0", 1 << 16);
        let stale = ix.stale_extents(|f| f != "base-0");
        assert_eq!(stale.len(), 2, "both nodes' base extents flagged");
        ix.drop_file("base-0");
        assert!(ix.lookup("n0", 11).is_none());
        assert!(ix.lookup("n1", 33).is_none());
        assert!(ix.lookup("n0", 22).is_some());
        assert!(ix.stale_extents(|f| f != "base-0").is_empty());
        // prune_missing is the sweep-facing spelling of the same cleanup
        ix.declare("n0", 44, "gone", 4 << 16);
        assert_eq!(ix.prune_missing(|f| f == "head-1"), 1);
        assert!(ix.lookup("n0", 44).is_none());
        assert!(ix.lookup("n0", 22).is_some());
        ix.clear();
        assert_eq!(ix.fleet_stats().extents, 0);
    }

    #[test]
    fn stats_aggregate_per_node_and_fleet() {
        let ix = DedupIndex::new();
        ix.declare("n0", 1, "f", 1 << 16);
        ix.declare("n1", 2, "g", 2 << 16);
        ix.share("n0", 1, 100);
        ix.share("n0", 1, 100);
        let n0 = ix.node_stats("n0");
        assert_eq!((n0.extents, n0.refs, n0.saved_bytes), (1, 3, 200));
        let fleet = ix.fleet_stats();
        assert_eq!((fleet.extents, fleet.refs, fleet.saved_bytes), (2, 4, 200));
    }
}
