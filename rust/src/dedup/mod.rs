//! Capacity multiplication: zero clusters, compressed clusters, and
//! fleet-wide content-addressed dedup.
//!
//! Long snapshot chains inflate storage as well as I/O: every written
//! cluster costs a full cluster of capacity, even all-zero ones, and
//! cloned populations store the same bytes once per clone. This
//! subsystem multiplies effective fleet capacity three ways:
//!
//! * **Zero detection** — an all-zero guest write allocates nothing; it
//!   leaves an `OFLAG_ZERO` L2 entry and reads are served from a shared
//!   zero page with zero device time.
//! * **Compression** ([`codec`]) — a cluster that shrinks is stored as a
//!   sector-aligned sub-cluster payload (`OFLAG_COMPRESSED`), billed at
//!   its compressed size on the wire and disk, with the decompress cost
//!   modeled on read.
//! * **Dedup** ([`index`]) — a cluster whose bytes already exist on the
//!   node (shared golden base, earlier write in the same head) becomes a
//!   reference to the existing extent: a remote L2 reference into a
//!   backing file of the chain, or a refcount-shared cluster within the
//!   active file.
//!
//! [`capacity`] splits accounting into logical vs physical bytes so
//! placement and rebalancing operate on real, post-dedup pressure.
//!
//! All three features default **off** ([`CapacityPolicy`]); drivers
//! enable them per VM via `Driver::set_capacity_policy`. Compression and
//! dedup require `DataMode::Real` (synthetic data is generated, not
//! stored, so content cannot round-trip); drivers ignore those bits on
//! synthetic images.

pub mod capacity;
pub mod codec;
pub mod index;
pub mod scan;

pub use capacity::{
    chain_logical_bytes, chain_physical_bytes, image_breakdown, seed_chain, MappedBreakdown,
};
pub use index::{content_hash, DedupIndex, DedupStats, Extent};
pub use scan::CapacityScanJob;

use std::sync::Arc;

/// Per-VM switches for the capacity subsystem. Default: everything off
/// (bit-for-bit the pre-subsystem write path).
#[derive(Clone, Default)]
pub struct CapacityPolicy {
    /// Detect all-zero full-cluster writes and store `OFLAG_ZERO`
    /// entries instead of data clusters.
    pub zero_detect: bool,
    /// Compress full-cluster writes that shrink (`OFLAG_COMPRESSED`).
    pub compress: bool,
    /// Content-addressed sharing through the fleet [`DedupIndex`];
    /// carries the node name the VM's files live on (the index cannot
    /// share across nodes physically).
    pub dedup: Option<DedupContext>,
}

/// Where a VM's writes may dedup to.
#[derive(Clone)]
pub struct DedupContext {
    pub index: Arc<DedupIndex>,
    /// Storage node holding this VM's chain.
    pub node: String,
}

impl CapacityPolicy {
    /// Everything on — the fig24 configuration.
    pub fn full(index: Arc<DedupIndex>, node: &str) -> CapacityPolicy {
        CapacityPolicy {
            zero_detect: true,
            compress: true,
            dedup: Some(DedupContext { index, node: node.to_string() }),
        }
    }

    pub fn any_enabled(&self) -> bool {
        self.zero_detect || self.compress || self.dedup.is_some()
    }
}
