//! The capacity scan as a [`BlockJob`]: the background, rate-limited
//! form of `Coordinator::refresh_capacity`.
//!
//! Recovery used to refresh every node's logical-bytes counter
//! synchronously — a full walk of every chain's tables before the
//! coordinator would answer anything. The counter only feeds reporting
//! (`sqemu node status`, fig24), so that walk now runs as a standard
//! block job instead: admitted against the maintenance budget, paced by
//! the [`crate::blockjob::RateLimiter`], pausable and cancellable, and
//! interleaving with guest I/O like any stream or GC sweep.
//!
//! Work units are *chain heads* (one "cluster" of budget = one head);
//! the bytes reported per increment are the logical bytes the walk
//! covered, so the limiter meters scan I/O in proportion to how much
//! table-walking each chain costs. Construction does the one discovery
//! listing pass; increments never list nodes again.

use super::capacity::chain_logical_bytes;
use crate::blockjob::{BlockJob, Increment, JobKind};
use crate::coordinator::placement::NodeSet;
use crate::qcow::image::DataMode;
use crate::qcow::Chain;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

pub struct CapacityScanJob {
    nodes: Arc<NodeSet>,
    /// Chain heads still to walk (discovered at construction).
    heads: Vec<String>,
    /// Progress denominator.
    total: u64,
    /// Logical bytes accumulated per node name so far.
    logical: HashMap<String, u64>,
}

impl CapacityScanJob {
    /// Discover the fleet's chain heads (images no other image backs
    /// onto) in one listing pass over the nodes.
    pub fn new(nodes: Arc<NodeSet>) -> CapacityScanJob {
        let mut backed: std::collections::HashSet<String> =
            std::collections::HashSet::new();
        let mut names: Vec<String> = Vec::new();
        for node in nodes.nodes() {
            for f in node.file_names() {
                if f.starts_with(crate::migrate::JOURNAL_PREFIX) {
                    continue;
                }
                let opened = node.open_file(&f).and_then(|b| {
                    crate::qcow::Image::open(&f, b, DataMode::Real)
                });
                if let Ok(img) = opened {
                    if let Some(b) = img.backing_name() {
                        backed.insert(b);
                    }
                    if !names.contains(&f) {
                        names.push(f);
                    }
                }
            }
        }
        let heads: Vec<String> = names
            .into_iter()
            .filter(|n| !backed.contains(n))
            .collect();
        let total = heads.len() as u64;
        CapacityScanJob { nodes, heads, total, logical: HashMap::new() }
    }
}

impl BlockJob for CapacityScanJob {
    fn kind(&self) -> JobKind {
        JobKind::Scan
    }

    fn total_clusters(&self) -> u64 {
        self.total
    }

    fn run_increment(&mut self, _chain: &mut Chain, budget: u64) -> Result<Increment> {
        let mut inc = Increment::default();
        while inc.processed < budget.max(1) {
            let Some(head) = self.heads.pop() else {
                inc.complete = true;
                return Ok(inc);
            };
            inc.processed += 1;
            // a head that vanished or will not open since discovery is
            // skipped, exactly as the synchronous scan skips it — the
            // counter is reporting, never correctness
            let Some(node) = self.nodes.locate(&head) else { continue };
            let Ok(chain) =
                Chain::open(self.nodes.as_ref(), &head, DataMode::Real)
            else {
                continue;
            };
            if let Ok(bytes) = chain_logical_bytes(&chain) {
                *self.logical.entry(node).or_default() += bytes;
                inc.copied += 1;
                inc.bytes += bytes;
            }
        }
        inc.complete = self.heads.is_empty();
        Ok(inc)
    }

    fn finalize(&mut self, _chain: &mut Chain) -> Result<()> {
        for node in self.nodes.nodes() {
            let l = self.logical.get(&node.name).copied().unwrap_or(0);
            node.set_logical_bytes(l);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blockjob::{JobRunner, JobShared, JobState, Step};
    use crate::chaingen::ChainSpec;
    use crate::gc::scratch_driver;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::storage::node::StorageNode;
    use crate::vdisk::Driver as _;

    fn fleet_with_chain() -> (Arc<VirtClock>, Arc<NodeSet>) {
        let clock = VirtClock::new();
        let nodes = Arc::new(
            NodeSet::new(vec![
                StorageNode::new("n0", clock.clone(), CostModel::default()),
                StorageNode::new("n1", clock.clone(), CostModel::default()),
            ])
            .unwrap(),
        );
        let spec = ChainSpec {
            chain_len: 3,
            data_mode: DataMode::Real,
            prefix: "scan".into(),
            ..Default::default()
        };
        crate::chaingen::generate(nodes.as_ref(), &spec).unwrap();
        (clock, nodes)
    }

    #[test]
    fn background_scan_matches_the_synchronous_walk() {
        let (clock, nodes) = fleet_with_chain();
        // the synchronous reference: walk the chain directly
        let chain =
            Chain::open(nodes.as_ref(), "scan-2", DataMode::Real).unwrap();
        let expect = chain_logical_bytes(&chain).unwrap();
        let home = nodes.locate("scan-2").unwrap();
        drop(chain);

        let mut d = scratch_driver(clock.clone(), CostModel::default()).unwrap();
        let shared = Arc::new(JobShared::new("scan-1", JobKind::Scan, 0));
        let fence = Arc::clone(d.fence());
        let job = Box::new(CapacityScanJob::new(Arc::clone(&nodes)));
        let mut r =
            JobRunner::new(job, Arc::clone(&shared), fence, 1, 1 << 20, clock.now());
        loop {
            match r.step(&mut d, clock.now()) {
                Step::Finished => break,
                Step::Starved { ready_at } => {
                    let now = clock.now();
                    clock.advance(ready_at - now);
                }
                _ => {}
            }
        }
        let st = shared.status();
        assert_eq!(st.state, JobState::Completed, "error: {:?}", st.error);
        assert_eq!(st.bytes_copied, expect, "scan bills the logical bytes");
        for node in nodes.nodes() {
            let want = if node.name == home { expect } else { 0 };
            assert_eq!(node.logical_bytes(), want, "node {}", node.name);
        }
    }

    #[test]
    fn discovery_happens_once_at_construction() {
        let (_clock, nodes) = fleet_with_chain();
        let before: u64 = nodes.nodes().iter().map(|n| n.list_ops()).sum();
        let mut job = CapacityScanJob::new(Arc::clone(&nodes));
        let listed: u64 = nodes.nodes().iter().map(|n| n.list_ops()).sum();
        assert!(listed > before, "construction lists the nodes");
        // increments take a &mut Chain per the trait; the scan never
        // touches it, so any open chain stands in
        let mut scratch =
            Chain::open(nodes.as_ref(), "scan-2", DataMode::Real).unwrap();
        while !job.run_increment(&mut scratch, 1).unwrap().complete {}
        let end: u64 = nodes.nodes().iter().map(|n| n.list_ops()).sum();
        assert_eq!(end, listed, "increments never re-list the nodes");
    }
}
