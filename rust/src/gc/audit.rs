//! Leak audit: diff the files physically present on the storage nodes
//! against chain reachability — the `qcheck` of capacity.
//!
//! Reachability is computed from the *on-disk truth*: for every
//! registered chain we walk backing-file pointers from its active
//! volume, exactly like [`crate::qcow::Chain::open`] would. A file on a
//! node that no walk reaches and that is not already condemned is a
//! **leak** — capacity stranded forever unless an operator intervenes
//! (the pre-GC repo leaked every streamed-away backing file this way).

use super::registry::GcRegistry;
use crate::coordinator::placement::NodeSet;
use crate::qcow::image::{DataMode, Image};
use crate::storage::store::FileStore;
use anyhow::{bail, Result};
use std::collections::HashSet;

/// Outcome of a leak audit (`sqemu gc --dry-run` analogue).
#[derive(Clone, Debug, Default)]
pub struct AuditReport {
    /// Files reachable from a registered chain's active volume.
    pub reachable: u64,
    /// Files in the deferred-delete set (awaiting a GC sweep).
    pub condemned: Vec<String>,
    /// Target copies a running (uncommitted) migration is still
    /// building — off-index by design, not leaks.
    pub in_flight: Vec<String>,
    /// Files on nodes that are neither reachable nor condemned, with
    /// their stored bytes: stranded capacity.
    pub leaked: Vec<(String, u64)>,
    /// Walk failures (broken backing links, unopenable images).
    pub errors: Vec<String>,
    /// Dedup extents whose backing file no longer exists, as
    /// `(node, content_hash)` — filled by the coordinator's
    /// [`crate::coordinator::Coordinator::gc_audit`] from the fleet
    /// [`crate::dedup::DedupIndex`]; always empty when the sweep's
    /// `prune_missing` wiring is correct.
    pub stale_extents: Vec<(String, u64)>,
}

impl AuditReport {
    pub fn is_clean(&self) -> bool {
        self.leaked.is_empty() && self.errors.is_empty() && self.stale_extents.is_empty()
    }

    /// Bytes stranded by leaks.
    pub fn leaked_bytes(&self) -> u64 {
        self.leaked.iter().map(|(_, b)| *b).sum()
    }
}

/// Walk the backing-file pointers from chain head `head`, inserting
/// every visited file name into `reachable`. Fails on an unopenable
/// image or a backing loop. Shared by the coordinator audit and the
/// CLI `sqemu gc` reachability pass, so the loop guard and error
/// handling cannot drift apart.
pub fn walk_backing(
    store: &dyn FileStore,
    head: &str,
    reachable: &mut HashSet<String>,
) -> Result<()> {
    let mut cursor = Some(head.to_string());
    let mut hops = 0usize;
    while let Some(name) = cursor.take() {
        hops += 1;
        if hops > u16::MAX as usize {
            bail!("backing loop via '{name}'");
        }
        let img = store
            .open_file(&name)
            .and_then(|b| Image::open(&name, b, DataMode::Real))
            .map_err(|e| anyhow::anyhow!("cannot open '{name}': {e:#}"))?;
        cursor = img.backing_name();
        reachable.insert(name);
    }
    Ok(())
}

/// Audit `nodes` against the chains registered in `registry`.
///
/// Node-aware since migrations exist: a file name can briefly live on
/// two nodes, and only the copy the placement index points at counts as
/// reachable — the off-index copy must be a condemned migration replica
/// or it is a leak. Migration journals (`.migrate.*`) are control-plane
/// metadata cleaned up by GC/recovery, not capacity.
pub fn audit(nodes: &NodeSet, registry: &GcRegistry) -> AuditReport {
    let mut report = AuditReport::default();
    let mut reachable: HashSet<String> = HashSet::new();
    for (chain_id, files) in registry.chains() {
        let Some(active) = files.last() else { continue };
        if let Err(e) = walk_backing(nodes, active, &mut reachable) {
            report.errors.push(format!("chain '{chain_id}': {e:#}"));
        }
    }
    report.reachable = reachable.len() as u64;
    let condemned: HashSet<String> = registry
        .condemned()
        .into_iter()
        .map(|(name, _)| name)
        .collect();
    for node in nodes.nodes() {
        // target copies of a migration still in flight on this node:
        // listed in an uncommitted journal — off-index by design
        let mut in_flight: HashSet<String> = HashSet::new();
        for jname in node
            .file_names()
            .into_iter()
            .filter(|n| n.starts_with(crate::migrate::JOURNAL_PREFIX))
        {
            if let Some(state) = crate::migrate::journal::read_journal(node, &jname) {
                if !state.committed {
                    in_flight.extend(state.moves.into_iter().map(|(f, _)| f));
                }
            }
        }
        for f in node.file_names() {
            if f.starts_with(crate::migrate::JOURNAL_PREFIX) {
                continue;
            }
            let on_index = nodes.locate(&f).as_deref() == Some(node.name.as_str());
            if on_index && reachable.contains(&f) {
                continue;
            }
            if on_index && condemned.contains(&f) {
                report.condemned.push(f);
                continue;
            }
            if registry.is_replica_condemned(&node.name, &f) {
                report.condemned.push(format!("{f}@{}", node.name));
                continue;
            }
            if !on_index && in_flight.contains(&f) {
                report.in_flight.push(format!("{f}@{}", node.name));
                continue;
            }
            let bytes = node.open_file(&f).map(|b| b.stored_bytes()).unwrap_or(0);
            report.leaked.push((f, bytes));
        }
    }
    report.condemned.sort();
    report.in_flight.sort();
    report.leaked.sort();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::qcow::layout::{Geometry, FEATURE_BFI};
    use crate::qcow::{snapshot, Chain};
    use crate::storage::node::StorageNode;
    use crate::storage::store::FileStore;
    use std::sync::Arc;

    fn setup() -> (Arc<NodeSet>, Arc<GcRegistry>) {
        let clock = VirtClock::new();
        let nodes = Arc::new(
            NodeSet::new(vec![StorageNode::new(
                "n0",
                clock,
                CostModel::default(),
            )])
            .unwrap(),
        );
        let reg = Arc::new(GcRegistry::new(Arc::clone(&nodes)));
        (nodes, reg)
    }

    fn make_chain(nodes: &NodeSet, reg: &GcRegistry, id: &str, len: usize) {
        let b = nodes.create_file(&format!("{id}-0")).unwrap();
        let img = Image::create(
            &format!("{id}-0"),
            b,
            Geometry::new(16, 4 << 20).unwrap(),
            FEATURE_BFI,
            0,
            None,
            DataMode::Real,
        )
        .unwrap();
        let mut chain = Chain::new(Arc::new(img)).unwrap();
        for i in 1..len {
            snapshot::snapshot_sqemu(&mut chain, nodes, &format!("{id}-{i}")).unwrap();
        }
        reg.sync_chain(
            id,
            chain.images().iter().map(|i| i.name.clone()).collect(),
        );
    }

    #[test]
    fn clean_fleet_audits_clean() {
        let (nodes, reg) = setup();
        make_chain(&nodes, &reg, "a", 3);
        let r = audit(&nodes, &reg);
        assert!(r.is_clean(), "{:?}", r.leaked);
        assert_eq!(r.reachable, 3);
    }

    #[test]
    fn orphan_file_is_flagged_as_leak() {
        let (nodes, reg) = setup();
        make_chain(&nodes, &reg, "a", 2);
        // a file nobody references and nobody condemned
        let b = nodes.create_file("orphan").unwrap();
        b.write_at(&[9u8; 8 << 10], 0).unwrap();
        let r = audit(&nodes, &reg);
        assert!(!r.is_clean());
        assert_eq!(r.leaked.len(), 1);
        assert_eq!(r.leaked[0].0, "orphan");
        assert_eq!(r.leaked_bytes(), 8 << 10);
    }

    #[test]
    fn off_index_copy_is_a_leak_unless_replica_condemned() {
        let clock = crate::metrics::clock::VirtClock::new();
        let nodes = Arc::new(
            NodeSet::new(vec![
                StorageNode::new("n0", clock.clone(), CostModel::default()),
                StorageNode::new("n1", clock.clone(), CostModel::default()),
            ])
            .unwrap(),
        );
        let reg = Arc::new(GcRegistry::new(Arc::clone(&nodes)));
        make_chain(&nodes, &reg, "a", 1);
        // simulate a committed migration: a second physical copy of the
        // chain file on n1, index flipped to it — the n0 copy is now
        // off-index
        let file = "a-0";
        let src = nodes.node_of(file).unwrap();
        let (dst_node_name, dst) = if src.name == "n0" { ("n1", nodes.node_named("n1").unwrap()) } else { ("n0", nodes.node_named("n0").unwrap()) };
        let src_backend = nodes.open_file(file).unwrap();
        let mut buf = vec![0u8; src_backend.len() as usize];
        src_backend.read_at(&mut buf, 0).unwrap();
        let copy = dst.create_file(file).unwrap();
        copy.write_at(&buf, 0).unwrap();
        nodes.commit_migration(&[file.to_string()], dst_node_name).unwrap();
        // journals are ignored by the audit
        dst.create_file(".migrate.a").unwrap();

        let r = audit(&nodes, &reg);
        assert_eq!(r.leaked.len(), 1, "off-index copy not condemned: {r:?}");
        assert_eq!(r.leaked[0].0, file);

        reg.condemn_replica(&src.name, file, "a");
        let r = audit(&nodes, &reg);
        assert!(r.is_clean(), "{:?}", r.leaked);
        assert_eq!(r.condemned, vec![format!("{file}@{}", src.name)]);
    }

    #[test]
    fn in_flight_migration_copies_are_not_leaks() {
        let clock = crate::metrics::clock::VirtClock::new();
        let nodes = Arc::new(
            NodeSet::new(vec![
                StorageNode::new("n0", clock.clone(), CostModel::default()),
                StorageNode::new("n1", clock.clone(), CostModel::default()),
            ])
            .unwrap(),
        );
        let reg = Arc::new(GcRegistry::new(Arc::clone(&nodes)));
        make_chain(&nodes, &reg, "a", 1);
        let file = "a-0";
        let src_name = nodes.locate(file).unwrap();
        let dst = if src_name == "n0" {
            nodes.node_named("n1").unwrap()
        } else {
            nodes.node_named("n0").unwrap()
        };
        // an uncommitted journal + a partial target copy = a migration
        // mid-copy, not a leak
        let _j = crate::migrate::MigrationJournal::create(
            &dst,
            "a",
            &[(file.to_string(), src_name)],
        )
        .unwrap();
        dst.create_file(file).unwrap().write_at(b"part", 0).unwrap();
        let r = audit(&nodes, &reg);
        assert!(r.is_clean(), "{:?}", r.leaked);
        assert_eq!(r.in_flight, vec![format!("{file}@{}", dst.name)]);
    }

    #[test]
    fn condemned_files_are_not_leaks() {
        let (nodes, reg) = setup();
        make_chain(&nodes, &reg, "a", 2);
        make_chain(&nodes, &reg, "b", 2);
        reg.drop_chain("b");
        let r = audit(&nodes, &reg);
        assert!(r.is_clean(), "condemned != leaked: {:?}", r.leaked);
        assert_eq!(r.condemned, vec!["b-0".to_string(), "b-1".to_string()]);
        assert_eq!(r.reachable, 2);
    }
}
