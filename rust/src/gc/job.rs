//! The GC sweep as a [`BlockJob`]: rate-limited physical deletion of
//! condemned files, driven through the same [`crate::blockjob::JobRunner`]
//! machinery as live streams — so it inherits pause/resume/cooperative
//! cancel, bandwidth metering and progress reporting for free.
//!
//! Work units are *files* (one "cluster" of budget = one file); the bytes
//! reported per increment are the stored bytes of the deleted file, so
//! the [`crate::blockjob::RateLimiter`] meters reclamation I/O the same
//! way it meters stream copies. Deletion is atomic per file (see
//! [`GcRegistry::sweep_one`]): a cancel between increments leaves every
//! remaining file still condemned, never half-deleted.

use super::registry::GcRegistry;
use crate::blockjob::{BlockJob, Increment, JobKind};
use crate::cache::CacheConfig;
use crate::metrics::clock::{CostModel, VirtClock};
use crate::metrics::memory::MemoryAccountant;
use crate::qcow::image::{DataMode, Image};
use crate::qcow::layout::{Geometry, FEATURE_BFI};
use crate::qcow::Chain;
use crate::storage::backend::BackendRef;
use crate::storage::mem::MemBackend;
use crate::vdisk::scalable::ScalableDriver;
use anyhow::Result;
use std::sync::Arc;

pub struct GcJob {
    registry: Arc<GcRegistry>,
    /// Condemned files at job start (progress denominator).
    total: u64,
}

impl GcJob {
    pub fn new(registry: Arc<GcRegistry>) -> GcJob {
        let total = registry.condemned_count() as u64;
        GcJob { registry, total }
    }
}

impl BlockJob for GcJob {
    fn kind(&self) -> JobKind {
        JobKind::Gc
    }

    fn total_clusters(&self) -> u64 {
        self.total
    }

    fn run_increment(&mut self, _chain: &mut Chain, budget: u64) -> Result<Increment> {
        let mut inc = Increment::default();
        while inc.processed < budget {
            match self.registry.sweep_one() {
                Some((_name, bytes)) => {
                    inc.processed += 1;
                    inc.copied += 1;
                    inc.bytes += bytes;
                }
                None => {
                    // nothing more is deletable THIS run — entries a
                    // transient failure kept condemned (e.g. a replica
                    // on a down node) wait for the next sweep; spinning
                    // on them here would never terminate
                    inc.complete = true;
                    return Ok(inc);
                }
            }
        }
        inc.complete = self.registry.condemned_count() == 0;
        Ok(inc)
    }

    fn finalize(&mut self, _chain: &mut Chain) -> Result<()> {
        self.registry.note_run();
        Ok(())
    }
}

/// A minimal driver for hosting a [`GcJob`] in a
/// [`crate::blockjob::JobRunner`]: the job never touches its chain, but
/// the runner's completion protocol needs flush/reopen/qcheck targets.
/// The scratch image lives on a bare in-memory backend (no node, no
/// clock charges) so it costs nothing and pollutes no capacity stats.
pub fn scratch_driver(clock: Arc<VirtClock>, cost: CostModel) -> Result<ScalableDriver> {
    let backend: BackendRef = Arc::new(MemBackend::new());
    let img = Image::create(
        "gc-scratch",
        backend,
        Geometry::new(16, 1 << 20)?,
        FEATURE_BFI,
        0,
        None,
        DataMode::Real,
    )?;
    let chain = Chain::new(Arc::new(img))?;
    Ok(ScalableDriver::new(
        chain,
        CacheConfig::new(4, 256 << 10),
        clock,
        cost,
        MemoryAccountant::new(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blockjob::{JobRunner, JobShared, JobState, Step};
    use crate::coordinator::placement::NodeSet;
    use crate::storage::node::StorageNode;
    use crate::storage::store::FileStore;
    use crate::vdisk::Driver as _;

    fn condemned_set(n: usize) -> (Arc<VirtClock>, Arc<NodeSet>, Arc<GcRegistry>) {
        let clock = VirtClock::new();
        let nodes = Arc::new(
            NodeSet::new(vec![StorageNode::new(
                "n0",
                clock.clone(),
                CostModel::default(),
            )])
            .unwrap(),
        );
        for i in 0..n {
            let b = nodes.create_file(&format!("f{i}")).unwrap();
            b.write_at(&[2u8; 4 << 10], 0).unwrap();
        }
        let reg = Arc::new(GcRegistry::new(Arc::clone(&nodes)));
        reg.sync_chain("c", (0..n).map(|i| format!("f{i}")).collect());
        reg.drop_chain("c");
        assert_eq!(reg.condemned_count(), n);
        (clock, nodes, reg)
    }

    #[test]
    fn runs_to_completion_through_runner() {
        let (clock, nodes, reg) = condemned_set(5);
        let mut d = scratch_driver(clock.clone(), CostModel::default()).unwrap();
        let shared = Arc::new(JobShared::new("gc-1", JobKind::Gc, 0));
        let fence = Arc::clone(d.fence());
        let job = Box::new(GcJob::new(Arc::clone(&reg)));
        let mut r = JobRunner::new(job, Arc::clone(&shared), fence, 2, 1 << 20, clock.now());
        loop {
            match r.step(&mut d, clock.now()) {
                Step::Finished => break,
                Step::Starved { ready_at } => {
                    let now = clock.now();
                    clock.advance(ready_at - now);
                }
                _ => {}
            }
        }
        let st = shared.status();
        assert_eq!(st.state, JobState::Completed, "error: {:?}", st.error);
        assert_eq!(st.copied, 5, "all files deleted");
        assert_eq!(st.bytes_copied, 5 * (4 << 10));
        assert_eq!(reg.condemned_count(), 0);
        assert_eq!(reg.gc_runs(), 1);
        for i in 0..5 {
            assert!(nodes.open_file(&format!("f{i}")).is_err());
        }
    }

    #[test]
    fn rate_limit_meters_deletions() {
        let (clock, _nodes, reg) = condemned_set(4);
        let mut d = scratch_driver(clock.clone(), CostModel::default()).unwrap();
        // 4 KiB files against a 4 KiB/s budget: each deletion starves the
        // bucket for ~1 s of virtual time
        let shared = Arc::new(JobShared::new("gc-2", JobKind::Gc, 4 << 10));
        let fence = Arc::clone(d.fence());
        let job = Box::new(GcJob::new(Arc::clone(&reg)));
        let mut r = JobRunner::new(job, Arc::clone(&shared), fence, 1, 4 << 10, clock.now());
        let mut starved = 0u32;
        loop {
            match r.step(&mut d, clock.now()) {
                Step::Finished => break,
                Step::Starved { ready_at } => {
                    starved += 1;
                    let now = clock.now();
                    clock.advance(ready_at - now);
                }
                _ => {}
            }
        }
        assert!(starved > 0, "limiter never engaged");
        assert_eq!(shared.status().state, JobState::Completed);
    }
}
