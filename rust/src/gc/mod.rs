//! Garbage collection: chain-aware capacity reclamation.
//!
//! PR 1's streaming (offline [`crate::qcow::snapshot::stream_merge`] and
//! the live [`crate::blockjob::LiveStreamJob`]) collapses chains but left
//! every dropped backing file on its storage node forever — on the
//! paper's 500–1000-file chains that permanently strands the capacity
//! the merge was supposed to reclaim, and thin-provisioning placement
//! then refuses allocations against phantom usage. §3 (Fig 8) shows base
//! images are shared by many chains, so reclamation must be
//! reference-counted, never a blind delete. This module is the missing
//! subsystem:
//!
//! * [`GcRegistry`] — cross-chain reference registry: which chains
//!   (across all VMs) reference each image file. After any merge,
//!   live-stream completion or chain decommission, files whose refcount
//!   hits zero move to the *deferred-delete set* (condemned); shared
//!   bases survive until the last referencing chain drops them, and a
//!   chain opened between condemnation and the sweep resurrects the
//!   file.
//! * [`GcJob`] — the sweep as a [`crate::blockjob::BlockJob`]: bounded,
//!   rate-limited physical deletion through the standard `JobRunner`
//!   (pause / resume / cancel / progress), admitted against node
//!   maintenance bandwidth by the `JobScheduler` like any other job.
//! * [`audit`] — the `qcheck` of capacity: diff node files against
//!   chain reachability; anything unreachable and not condemned is a
//!   leak.
//!
//! Capacity integration: condemned bytes stop counting against
//! thin-provisioning pressure immediately
//! ([`crate::storage::node::StorageNode::pressure_bytes`] /
//! `would_overflow`), and physically drop out of `used_bytes` once the
//! sweep deletes them — `benches/fig21_gc_reclaim.rs` plots both curves
//! while 100-deep chains stream with and without GC.

pub mod audit;
pub mod job;
pub mod registry;

pub use audit::{audit, walk_backing, AuditReport};
pub use job::{scratch_driver, GcJob};
pub use registry::{Condemned, GcEvent, GcObserver, GcRegistry};

/// Outcome of one coordinator GC run
/// ([`crate::coordinator::Coordinator::run_gc`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct GcReport {
    /// Files physically deleted by this run.
    pub files_deleted: u64,
    /// Bytes returned to the nodes by this run.
    pub reclaimed_bytes: u64,
    /// Virtual ns the sweep took (rate-limited).
    pub gc_ns: u64,
    /// Condemned files left behind (cancelled / resurrected races).
    pub remaining_condemned: u64,
    /// Committed migration journals removed because the sweep deleted
    /// the last source replica they covered.
    pub journals_cleaned: u64,
}
