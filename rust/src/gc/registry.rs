//! The cross-chain reference registry and deferred-delete set.
//!
//! §3's characterization shows base images are shared by many chains
//! (Fig 8), so reclamation must be reference-counted, never a blind
//! delete: a file is only *condemned* (moved to the deferred-delete set)
//! when the last chain referencing it drops it, and it is only
//! *physically* deleted by a [`super::GcJob`] sweep — with a final
//! refcount re-check at delete time, so a chain opened between
//! condemnation and the sweep resurrects the file instead of losing it.

use crate::coordinator::placement::NodeSet;
use crate::storage::store::FileStore;
use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

/// A file awaiting physical deletion.
#[derive(Clone, Debug)]
pub struct Condemned {
    /// Stored bytes at condemnation time (refreshed at delete time).
    pub bytes: u64,
    /// The chain whose drop condemned the file (stats attribution).
    pub origin: String,
}

/// A registry mutation, reported to the observer *after* it happened
/// (write-behind). GC state is reconstructible — a missed event costs at
/// worst a re-condemnation check at the next recovery, and the control
/// plane's log compaction re-emits the full registry periodically — so
/// unlike placement there is no veto: the observer is pure bookkeeping.
#[derive(Clone, Debug)]
pub enum GcEvent {
    /// A chain declared (or re-declared) its file set.
    Chain { id: String, files: Vec<String> },
    /// A chain was dropped entirely.
    ChainDrop { id: String },
    /// A file entered the deferred-delete set.
    Condemned { file: String, bytes: u64, origin: String },
    /// A condemned file was resurrected by a new reference.
    Uncondemned { file: String },
    /// A condemned file was physically deleted.
    Swept { file: String },
    /// A superseded migration replica entered the delete set.
    CondemnedReplica { node: String, file: String, bytes: u64, origin: String },
    /// A condemned replica was physically deleted.
    SweptReplica { node: String, file: String },
}

/// Write-behind hook; infallible by design (see [`GcEvent`]).
pub type GcObserver = Box<dyn Fn(&GcEvent) + Send + Sync>;

#[derive(Default)]
struct Inner {
    /// file name -> chain ids referencing it
    refs: HashMap<String, HashSet<String>>,
    /// chain id -> its file list, base first, active last
    chains: HashMap<String, Vec<String>>,
    /// deferred-delete set (BTreeMap: deterministic sweep order)
    condemned: BTreeMap<String, Condemned>,
    /// Superseded migration replicas, keyed `(node name, file name)`:
    /// the committed switchover moved the *name* to another node, so
    /// these copies are off-index and gated by no refcount — the name's
    /// references follow the index. Deleted directly on their node.
    replicas: BTreeMap<(String, String), Condemned>,
    /// bytes reclaimed per origin chain since the last drain
    reclaimed_by: HashMap<String, u64>,
}

/// Fleet-wide GC state: who references what, and what may be deleted.
pub struct GcRegistry {
    nodes: Arc<NodeSet>,
    inner: Mutex<Inner>,
    gc_runs: AtomicU64,
    reclaimed_bytes: AtomicU64,
    files_deleted: AtomicU64,
    /// Write-behind observer. Lock order: events are collected under
    /// `inner` and emitted strictly after it unlocks, so the observer
    /// may take any lock of its own.
    observer: Mutex<Option<GcObserver>>,
}

impl GcRegistry {
    pub fn new(nodes: Arc<NodeSet>) -> GcRegistry {
        GcRegistry {
            nodes,
            inner: Mutex::new(Inner::default()),
            gc_runs: AtomicU64::new(0),
            reclaimed_bytes: AtomicU64::new(0),
            files_deleted: AtomicU64::new(0),
            observer: Mutex::new(None),
        }
    }

    /// Install (or replace) the write-behind observer.
    pub fn set_observer(&self, obs: Option<GcObserver>) {
        *self.observer.lock().unwrap() = obs;
    }

    fn emit(&self, evs: &[GcEvent]) {
        if evs.is_empty() {
            return;
        }
        if let Some(obs) = self.observer.lock().unwrap().as_ref() {
            for ev in evs {
                obs(ev);
            }
        }
    }

    /// Declare the current file set of a chain (called after open,
    /// snapshot, offline stream and live-job completion). Files the chain
    /// no longer references are unref'd; files whose last reference this
    /// was are condemned. Newly referenced files are resurrected from the
    /// deferred-delete set if a sweep had not reached them yet.
    pub fn sync_chain(&self, chain_id: &str, files: Vec<String>) {
        let mut evs = vec![GcEvent::Chain {
            id: chain_id.to_string(),
            files: files.clone(),
        }];
        let mut inner = self.inner.lock().unwrap();
        let new_set: HashSet<String> = files.iter().cloned().collect();
        let old = inner
            .chains
            .insert(chain_id.to_string(), files.clone())
            .unwrap_or_default();
        for f in &files {
            inner
                .refs
                .entry(f.clone())
                .or_default()
                .insert(chain_id.to_string());
            if inner.condemned.remove(f).is_some() {
                if let Some(node) = self.nodes.node_of(f) {
                    node.uncondemn(f);
                }
                evs.push(GcEvent::Uncondemned { file: f.clone() });
            }
        }
        for f in old {
            if !new_set.contains(&f) {
                unref(&self.nodes, &mut inner, &f, chain_id, &mut evs);
            }
        }
        drop(inner);
        self.emit(&evs);
    }

    /// Drop a chain entirely (decommission / snapshot-chain deletion):
    /// release all its references; files it referenced alone are
    /// condemned.
    pub fn drop_chain(&self, chain_id: &str) {
        let mut evs = vec![GcEvent::ChainDrop { id: chain_id.to_string() }];
        let mut inner = self.inner.lock().unwrap();
        let files = inner.chains.remove(chain_id).unwrap_or_default();
        for f in files {
            unref(&self.nodes, &mut inner, &f, chain_id, &mut evs);
        }
        drop(inner);
        self.emit(&evs);
    }

    /// How many chains reference `file`?
    pub fn refcount(&self, file: &str) -> usize {
        self.inner
            .lock()
            .unwrap()
            .refs
            .get(file)
            .map_or(0, |s| s.len())
    }

    pub fn is_condemned(&self, file: &str) -> bool {
        self.inner.lock().unwrap().condemned.contains_key(file)
    }

    /// Condemn the superseded copy of `file` on `node_name` after a
    /// committed migration moved the name elsewhere. The copy leaves
    /// thin-provisioning pressure immediately and is physically deleted
    /// by the next sweep — directly on its node, bypassing the name
    /// index (which now points at the migration target).
    pub fn condemn_replica(&self, node_name: &str, file: &str, origin: &str) {
        let Some(node) = self.nodes.node_named(node_name) else {
            return;
        };
        let bytes = node.open_file(file).map(|b| b.stored_bytes()).unwrap_or(0);
        node.mark_condemned(file);
        self.inner.lock().unwrap().replicas.insert(
            (node_name.to_string(), file.to_string()),
            Condemned { bytes, origin: origin.to_string() },
        );
        self.emit(&[GcEvent::CondemnedReplica {
            node: node_name.to_string(),
            file: file.to_string(),
            bytes,
            origin: origin.to_string(),
        }]);
    }

    /// Is the copy of `file` on `node_name` a condemned migration
    /// replica?
    pub fn is_replica_condemned(&self, node_name: &str, file: &str) -> bool {
        self.inner
            .lock()
            .unwrap()
            .replicas
            .contains_key(&(node_name.to_string(), file.to_string()))
    }

    /// Snapshot of the condemned migration replicas.
    pub fn condemned_replicas(&self) -> Vec<((String, String), Condemned)> {
        self.inner
            .lock()
            .unwrap()
            .replicas
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }

    pub fn condemned_count(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.condemned.len() + inner.replicas.len()
    }

    /// Names of every node holding something deletable (sweep
    /// admission): the index nodes of name-condemned files plus the
    /// pinned nodes of condemned replicas.
    pub fn condemned_nodes(&self) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        let mut names: Vec<String> = Vec::new();
        for file in inner.condemned.keys() {
            if let Some(n) = self.nodes.locate(file) {
                if !names.contains(&n) {
                    names.push(n);
                }
            }
        }
        for (node, _) in inner.replicas.keys() {
            if !names.contains(node) {
                names.push(node.clone());
            }
        }
        names
    }

    /// Snapshot of the deferred-delete set (name, info), sweep order.
    pub fn condemned(&self) -> Vec<(String, Condemned)> {
        self.inner
            .lock()
            .unwrap()
            .condemned
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }

    /// Bytes awaiting reclamation (named condemnations plus replicas).
    pub fn condemned_bytes(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner.condemned.values().map(|c| c.bytes).sum::<u64>()
            + inner.replicas.values().map(|c| c.bytes).sum::<u64>()
    }

    /// Registered chains and their file lists (leak-audit input).
    pub fn chains(&self) -> Vec<(String, Vec<String>)> {
        let inner = self.inner.lock().unwrap();
        let mut v: Vec<(String, Vec<String>)> = inner
            .chains
            .iter()
            .map(|(k, f)| (k.clone(), f.clone()))
            .collect();
        v.sort();
        v
    }

    /// Physically delete one condemned file, oldest name first. The
    /// deferred entry is only removed together with the deletion, so a
    /// cancelled sweep leaves every untouched file still condemned (no
    /// half states). Returns `(name, reclaimed_bytes)`, or `None` when
    /// the deferred-delete set is empty.
    pub fn sweep_one(&self) -> Option<(String, u64)> {
        let mut evs: Vec<GcEvent> = Vec::new();
        let mut inner = self.inner.lock().unwrap();
        // superseded migration replicas first: off-index copies, no
        // refcount gate (the name's references follow the flipped index)
        let replica_keys: Vec<(String, String)> =
            inner.replicas.keys().cloned().collect();
        for key in replica_keys {
            let Some(c) = inner.replicas.remove(&key) else { continue };
            let (node_name, file) = key.clone();
            let Some(node) = self.nodes.node_named(&node_name) else {
                continue; // node left the set: nothing left to reclaim
            };
            let bytes = node
                .open_file(&file)
                .map(|b| b.stored_bytes())
                .unwrap_or(c.bytes);
            if node.delete_file(&file).is_err() {
                // transient failure (e.g. the node is down): keep the
                // replica condemned so a later sweep retries instead of
                // stranding the copy forever
                inner.replicas.insert(key, c);
                continue;
            }
            node.note_reclaimed(bytes);
            self.reclaimed_bytes.fetch_add(bytes, Relaxed);
            self.files_deleted.fetch_add(1, Relaxed);
            *inner.reclaimed_by.entry(c.origin).or_default() += bytes;
            evs.push(GcEvent::SweptReplica { node: node_name, file: file.clone() });
            drop(inner);
            self.emit(&evs);
            return Some((file, bytes));
        }
        loop {
            let Some(name) = inner.condemned.keys().next().cloned() else {
                drop(inner);
                self.emit(&evs);
                return None;
            };
            let c = inner.condemned.remove(&name).expect("key just seen");
            // safety gate: never delete a file a chain re-referenced
            // after condemnation
            if inner.refs.get(&name).is_some_and(|s| !s.is_empty()) {
                if let Some(node) = self.nodes.node_of(&name) {
                    node.uncondemn(&name);
                }
                evs.push(GcEvent::Uncondemned { file: name });
                continue;
            }
            let Some(node) = self.nodes.node_of(&name) else {
                evs.push(GcEvent::Swept { file: name });
                continue; // already gone from every node
            };
            let bytes = node
                .open_file(&name)
                .map(|b| b.stored_bytes())
                .unwrap_or(c.bytes);
            if self.nodes.delete_file(&name).is_err() {
                continue;
            }
            node.note_reclaimed(bytes);
            self.reclaimed_bytes.fetch_add(bytes, Relaxed);
            self.files_deleted.fetch_add(1, Relaxed);
            *inner.reclaimed_by.entry(c.origin).or_default() += bytes;
            evs.push(GcEvent::Swept { file: name.clone() });
            drop(inner);
            self.emit(&evs);
            return Some((name, bytes));
        }
    }

    /// Take the per-origin reclaimed-bytes ledger (per-VM stats).
    pub fn drain_reclaimed_by(&self) -> Vec<(String, u64)> {
        let mut inner = self.inner.lock().unwrap();
        std::mem::take(&mut inner.reclaimed_by).into_iter().collect()
    }

    pub fn note_run(&self) {
        self.gc_runs.fetch_add(1, Relaxed);
    }

    pub fn gc_runs(&self) -> u64 {
        self.gc_runs.load(Relaxed)
    }

    pub fn reclaimed_total(&self) -> u64 {
        self.reclaimed_bytes.load(Relaxed)
    }

    pub fn files_deleted(&self) -> u64 {
        self.files_deleted.load(Relaxed)
    }

    pub fn nodes(&self) -> &Arc<NodeSet> {
        &self.nodes
    }

    /// Replace the registry wholesale from a replayed durable log:
    /// refcounts are re-derived from the chain file lists, condemned
    /// entries re-mark their nodes (the per-node condemned set is
    /// volatile). NO events are emitted — this installs what the log
    /// already records.
    pub fn install(
        &self,
        chains: Vec<(String, Vec<String>)>,
        condemned: Vec<(String, (u64, String))>,
        replicas: Vec<((String, String), (u64, String))>,
    ) {
        let mut inner = self.inner.lock().unwrap();
        inner.refs.clear();
        inner.chains.clear();
        inner.condemned.clear();
        inner.replicas.clear();
        for (id, files) in chains {
            for f in &files {
                inner.refs.entry(f.clone()).or_default().insert(id.clone());
            }
            inner.chains.insert(id, files);
        }
        for (file, (bytes, origin)) in condemned {
            if let Some(node) = self.nodes.node_of(&file) {
                node.mark_condemned(&file);
            }
            inner.condemned.insert(file, Condemned { bytes, origin });
        }
        for ((node_name, file), (bytes, origin)) in replicas {
            if let Some(node) = self.nodes.node_named(&node_name) {
                node.mark_condemned(&file);
            }
            inner
                .replicas
                .insert((node_name, file), Condemned { bytes, origin });
        }
    }
}

/// Drop `origin`'s reference to `file`; condemn the file when that was
/// the last reference and it still exists on a node. Condemnations are
/// appended to `evs` for the caller's write-behind emit.
fn unref(
    nodes: &NodeSet,
    inner: &mut Inner,
    file: &str,
    origin: &str,
    evs: &mut Vec<GcEvent>,
) {
    if let Some(set) = inner.refs.get_mut(file) {
        set.remove(origin);
        if !set.is_empty() {
            return;
        }
        inner.refs.remove(file);
    }
    let Some(node) = nodes.node_of(file) else {
        return;
    };
    let bytes = node.open_file(file).map(|b| b.stored_bytes()).unwrap_or(0);
    node.mark_condemned(file);
    inner.condemned.insert(
        file.to_string(),
        Condemned { bytes, origin: origin.to_string() },
    );
    evs.push(GcEvent::Condemned {
        file: file.to_string(),
        bytes,
        origin: origin.to_string(),
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::storage::node::StorageNode;

    fn setup(files: &[&str]) -> (Arc<NodeSet>, Arc<GcRegistry>) {
        let clock = VirtClock::new();
        let nodes = Arc::new(
            NodeSet::new(vec![StorageNode::new(
                "n0",
                clock,
                CostModel::default(),
            )])
            .unwrap(),
        );
        for f in files {
            let b = nodes.create_file(f).unwrap();
            b.write_at(&[1u8; 1 << 10], 0).unwrap();
        }
        let reg = Arc::new(GcRegistry::new(Arc::clone(&nodes)));
        (nodes, reg)
    }

    #[test]
    fn shared_file_survives_until_last_reference() {
        let (_nodes, reg) = setup(&["base", "a-1", "b-1"]);
        reg.sync_chain("a", vec!["base".into(), "a-1".into()]);
        reg.sync_chain("b", vec!["base".into(), "b-1".into()]);
        assert_eq!(reg.refcount("base"), 2);
        // chain a collapses to its active alone
        reg.sync_chain("a", vec!["a-1".into()]);
        assert_eq!(reg.refcount("base"), 1);
        assert!(!reg.is_condemned("base"));
        // chain b collapses too: now base is condemned
        reg.sync_chain("b", vec!["b-1".into()]);
        assert_eq!(reg.refcount("base"), 0);
        assert!(reg.is_condemned("base"));
        assert!(reg.condemned_bytes() >= 1 << 10);
    }

    #[test]
    fn resurrect_before_sweep() {
        let (nodes, reg) = setup(&["base"]);
        reg.sync_chain("a", vec!["base".into()]);
        reg.drop_chain("a");
        assert!(reg.is_condemned("base"));
        // a new chain opens the file before GC runs
        reg.sync_chain("b", vec!["base".into()]);
        assert!(!reg.is_condemned("base"));
        assert_eq!(reg.sweep_one(), None, "nothing deletable");
        assert!(nodes.open_file("base").is_ok());
    }

    #[test]
    fn replica_condemnation_bypasses_the_refcount_gate() {
        let (nodes, reg) = setup(&["img"]);
        // a second physical copy of the same name on another node is not
        // representable through setup(); simulate the post-switchover
        // state: the name is live (referenced) but the n0 copy is a
        // superseded replica
        reg.sync_chain("vm", vec!["img".into()]);
        assert_eq!(reg.refcount("img"), 1);
        reg.condemn_replica("n0", "img", "vm");
        assert!(reg.is_replica_condemned("n0", "img"));
        assert_eq!(reg.condemned_count(), 1);
        assert!(reg.condemned_bytes() >= 1 << 10);
        assert_eq!(reg.condemned_nodes(), vec!["n0".to_string()]);
        // the sweep deletes the replica even though the NAME is referenced
        let (name, bytes) = reg.sweep_one().unwrap();
        assert_eq!(name, "img");
        assert_eq!(bytes, 1 << 10);
        assert!(nodes.node_named("n0").unwrap().open_file("img").is_err());
        assert_eq!(reg.condemned_count(), 0);
        // unknown node: condemnation is a no-op
        reg.condemn_replica("n9", "img", "vm");
        assert_eq!(reg.condemned_count(), 0);
    }

    #[test]
    fn sweep_deletes_and_accounts() {
        let (nodes, reg) = setup(&["f0", "f1"]);
        reg.sync_chain("c", vec!["f0".into(), "f1".into()]);
        reg.drop_chain("c");
        let (n0, b0) = reg.sweep_one().unwrap();
        assert_eq!(n0, "f0");
        assert_eq!(b0, 1 << 10);
        assert!(nodes.open_file("f0").is_err());
        assert!(nodes.open_file("f1").is_ok());
        reg.sweep_one().unwrap();
        assert_eq!(reg.sweep_one(), None);
        assert_eq!(reg.files_deleted(), 2);
        assert_eq!(reg.reclaimed_total(), 2 << 10);
        let by = reg.drain_reclaimed_by();
        assert_eq!(by, vec![("c".to_string(), 2u64 << 10)]);
        assert!(reg.drain_reclaimed_by().is_empty(), "ledger drained");
    }
}
