//! VM boot trace (Fig 17): the boot reads kernel/initrd/userspace from
//! the *base image* (read-only distribution files — the file-0 spike in
//! Fig 13c) plus scattered config/state reads across the chain. Boot
//! time = virtual time to replay the trace.

use super::{Workload, WorkloadStats};
use crate::metrics::clock::VirtClock;
use crate::util::rng::Rng;
use crate::vdisk::Driver;
use anyhow::Result;
use std::sync::Arc;

pub struct BootTrace {
    /// Sequential bytes read from the image head (kernel + initrd; the
    /// Ubuntu 18.04 guest of the paper reads ~120 MiB at boot).
    pub sequential_bytes: u64,
    /// Scattered 16 KiB reads across the disk (daemons, config, logs).
    pub scattered_reads: u64,
    pub seed: u64,
}

impl Default for BootTrace {
    fn default() -> Self {
        BootTrace { sequential_bytes: 96 << 20, scattered_reads: 1500, seed: 0xB007 }
    }
}

impl Workload for BootTrace {
    fn name(&self) -> &str {
        "vm-boot"
    }

    fn run(
        &mut self,
        driver: &mut dyn Driver,
        clock: &Arc<VirtClock>,
    ) -> Result<WorkloadStats> {
        let disk = driver.chain().active().geom().virtual_size;
        let seq = self.sequential_bytes.min(disk / 2);
        let mut rng = Rng::new(self.seed);
        let t0 = clock.now();
        let mut stats = WorkloadStats::default();
        // phase 1: kernel/initrd — sequential from the disk head
        let mut buf = vec![0u8; 1 << 20];
        let mut pos = 0u64;
        while pos < seq {
            let n = buf.len().min((seq - pos) as usize);
            driver.read(pos, &mut buf[..n])?;
            pos += n as u64;
            stats.ops += 1;
            stats.bytes += n as u64;
        }
        // phase 2: init daemons — scattered small reads over the disk
        let mut small = vec![0u8; 16 << 10];
        let span = (disk - small.len() as u64) / small.len() as u64;
        for _ in 0..self.scattered_reads {
            let p = rng.below(span) * small.len() as u64;
            driver.read(p, &mut small)?;
            stats.ops += 1;
            stats.bytes += small.len() as u64;
        }
        stats.elapsed_ns = clock.now() - t0;
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheConfig;
    use crate::chaingen::{generate, ChainSpec};
    use crate::metrics::clock::CostModel;
    use crate::metrics::memory::MemoryAccountant;
    use crate::qcow::image::DataMode;
    use crate::storage::node::StorageNode;
    use crate::vdisk::vanilla::VanillaDriver;
    use crate::vdisk::Driver;

    #[test]
    fn boot_reads_head_then_scatters() {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let spec = ChainSpec {
            disk_size: 32 << 20,
            chain_len: 3,
            populated: 0.7,
            data_mode: DataMode::Synthetic,
            ..Default::default()
        };
        let chain = generate(&node, &spec).unwrap();
        let mut d = VanillaDriver::new(
            chain,
            CacheConfig::default(),
            clock.clone(),
            CostModel::default(),
            MemoryAccountant::new(),
        );
        let mut bt = BootTrace { sequential_bytes: 4 << 20, scattered_reads: 100, seed: 1 };
        let stats = bt.run(&mut d, &clock).unwrap();
        assert!(stats.bytes >= 4 << 20);
        assert!(stats.elapsed_ns > 0);
        // the base image saw the bulk of the lookups (Fig 13c spike)
        let lookups = d.counters().per_file_lookups;
        assert!(lookups[0] > 0);
    }
}
