//! `dd`: sequential full-disk read at a fixed block size (§6.1:
//! `dd if=/dev/sda of=/dev/null bs=4M`).

use super::{Workload, WorkloadStats};
use crate::metrics::clock::VirtClock;
use crate::vdisk::Driver;
use anyhow::Result;
use std::sync::Arc;

pub struct Dd {
    /// Read block size (paper: 4 MiB).
    pub block_size: usize,
    /// Stop after this many bytes (None = whole disk).
    pub limit: Option<u64>,
}

impl Default for Dd {
    fn default() -> Self {
        Dd { block_size: 4 << 20, limit: None }
    }
}

impl Workload for Dd {
    fn name(&self) -> &str {
        "dd"
    }

    fn run(
        &mut self,
        driver: &mut dyn Driver,
        clock: &Arc<VirtClock>,
    ) -> Result<WorkloadStats> {
        let disk = driver.chain().active().geom().virtual_size;
        let end = self.limit.map_or(disk, |l| l.min(disk));
        let mut buf = vec![0u8; self.block_size];
        let t0 = clock.now();
        let mut stats = WorkloadStats::default();
        let mut pos = 0u64;
        while pos < end {
            let n = self.block_size.min((end - pos) as usize);
            driver.read(pos, &mut buf[..n])?;
            pos += n as u64;
            stats.ops += 1;
            stats.bytes += n as u64;
        }
        stats.elapsed_ns = clock.now() - t0;
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheConfig;
    use crate::chaingen::{generate, ChainSpec};
    use crate::metrics::clock::CostModel;
    use crate::metrics::memory::MemoryAccountant;
    use crate::qcow::image::DataMode;
    use crate::storage::node::StorageNode;
    use crate::vdisk::scalable::ScalableDriver;

    #[test]
    fn reads_whole_disk() {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let spec = ChainSpec {
            disk_size: 16 << 20,
            chain_len: 3,
            populated: 0.5,
            data_mode: DataMode::Synthetic,
            ..Default::default()
        };
        let chain = generate(&node, &spec).unwrap();
        let mut d = ScalableDriver::new(
            chain,
            CacheConfig::default(),
            clock.clone(),
            CostModel::default(),
            MemoryAccountant::new(),
        );
        let stats = Dd::default().run(&mut d, &clock).unwrap();
        assert_eq!(stats.bytes, 16 << 20);
        assert!(stats.elapsed_ns > 0);
        assert!(stats.throughput_bps() > 0.0);
    }

    #[test]
    fn limit_caps_bytes() {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let spec = ChainSpec {
            disk_size: 16 << 20,
            chain_len: 1,
            populated: 0.2,
            data_mode: DataMode::Synthetic,
            ..Default::default()
        };
        let chain = generate(&node, &spec).unwrap();
        let mut d = ScalableDriver::new(
            chain,
            CacheConfig::default(),
            clock.clone(),
            CostModel::default(),
            MemoryAccountant::new(),
        );
        let mut dd = Dd { block_size: 1 << 20, limit: Some(3 << 20) };
        let stats = dd.run(&mut d, &clock).unwrap();
        assert_eq!(stats.bytes, 3 << 20);
        assert_eq!(stats.ops, 3);
    }
}
