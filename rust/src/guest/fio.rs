//! `fio`: random small reads on the raw device (§6.1 / Fig 16:
//! 4 KiB random reads "on the disk node in /dev").

use super::{Workload, WorkloadStats};
use crate::metrics::clock::VirtClock;
use crate::util::rng::Rng;
use crate::vdisk::Driver;
use anyhow::Result;
use std::sync::Arc;

pub struct Fio {
    /// I/O size (paper: 4 KiB).
    pub io_size: usize,
    /// Number of random reads to issue.
    pub ops: u64,
    pub seed: u64,
}

impl Default for Fio {
    fn default() -> Self {
        Fio { io_size: 4 << 10, ops: 10_000, seed: 0xF10 }
    }
}

impl Workload for Fio {
    fn name(&self) -> &str {
        "fio-randread"
    }

    fn run(
        &mut self,
        driver: &mut dyn Driver,
        clock: &Arc<VirtClock>,
    ) -> Result<WorkloadStats> {
        let disk = driver.chain().active().geom().virtual_size;
        let span = disk - self.io_size as u64;
        let mut rng = Rng::new(self.seed);
        let mut buf = vec![0u8; self.io_size];
        let t0 = clock.now();
        let mut stats = WorkloadStats::default();
        for _ in 0..self.ops {
            // align to the I/O size like fio's default
            let pos = rng.below(span / self.io_size as u64) * self.io_size as u64;
            driver.read(pos, &mut buf)?;
            stats.ops += 1;
            stats.bytes += self.io_size as u64;
        }
        stats.elapsed_ns = clock.now() - t0;
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheConfig;
    use crate::chaingen::{generate, ChainSpec};
    use crate::metrics::clock::CostModel;
    use crate::metrics::memory::MemoryAccountant;
    use crate::qcow::image::DataMode;
    use crate::storage::node::StorageNode;
    use crate::vdisk::vanilla::VanillaDriver;

    #[test]
    fn issues_requested_ops() {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let spec = ChainSpec {
            disk_size: 8 << 20,
            chain_len: 2,
            populated: 0.6,
            data_mode: DataMode::Synthetic,
            ..Default::default()
        };
        let chain = generate(&node, &spec).unwrap();
        let mut d = VanillaDriver::new(
            chain,
            CacheConfig::default(),
            clock.clone(),
            CostModel::default(),
            MemoryAccountant::new(),
        );
        let mut fio = Fio { ops: 500, ..Default::default() };
        let stats = fio.run(&mut d, &clock).unwrap();
        assert_eq!(stats.ops, 500);
        assert_eq!(stats.bytes, 500 * 4096);
        assert!(stats.iops() > 0.0);
    }
}
