//! A mini LSM key-value store over the virtual disk — the RocksDB
//! stand-in for the §6.4.2 macro-benchmark.
//!
//! Layout on the virtual disk: a fixed set of SSTable segments, each a
//! contiguous run of 4 KiB blocks of records, plus an in-memory sparse
//! index (RocksDB keeps index/filter blocks resident too). `get` resolves
//! the key through the index and reads exactly one 4 KiB data block —
//! the same one-device-read-per-point-lookup behaviour a tuned RocksDB
//! shows on YCSB-C.

use crate::util::rng::Rng;
use crate::vdisk::Driver;
use anyhow::{bail, Result};
use std::collections::HashMap;

pub const BLOCK: usize = 4 << 10;
/// Records per block (fixed-size 128 B records: 16 B key, 112 B value).
pub const RECORDS_PER_BLOCK: u64 = (BLOCK / 128) as u64;

/// An immutable LSM store occupying `fill_fraction` of the disk.
pub struct KvStore {
    /// Number of records loaded.
    pub records: u64,
    /// First virtual byte of the store's area.
    base: u64,
    /// Blocks in the store.
    blocks: u64,
    /// Byte distance between consecutive blocks (== BLOCK when dense;
    /// larger when the store is spread across the whole disk to match a
    /// chain whose valid clusters are uniformly distributed, §6.4.2).
    stride: u64,
    /// When set, blocks live inside these cluster base offsets
    /// (BLOCKS_PER_CLUSTER blocks each) — the §6.4.2 store whose records
    /// sit in the chain's *populated* clusters.
    cluster_map: Option<Vec<u64>>,
    /// Segment boundaries (block index of each segment start) — the
    /// in-memory sparse index.
    segments: Vec<u64>,
}

impl KvStore {
    /// Build the store by writing records through the driver ("we created
    /// a RocksDB database that fills 40% of the VM disk size", §6.4.2).
    pub fn build(
        driver: &mut dyn Driver,
        fill_fraction: f64,
        seed: u64,
    ) -> Result<KvStore> {
        let disk = driver.chain().active().geom().virtual_size;
        let bytes = (disk as f64 * fill_fraction) as u64;
        let blocks = bytes / BLOCK as u64;
        if blocks == 0 {
            bail!("disk too small for a kv store");
        }
        let base = 0u64;
        let mut rng = Rng::new(seed);
        let mut block = vec![0u8; BLOCK];
        // 16 segments like an L1-heavy LSM tree
        let n_segments = 16u64.min(blocks);
        let mut segments = Vec::new();
        for s in 0..n_segments {
            segments.push(blocks * s / n_segments);
        }
        for b in 0..blocks {
            rng.fill_bytes(&mut block);
            // stamp each record slot with its key for verification
            for r in 0..RECORDS_PER_BLOCK {
                let key = b * RECORDS_PER_BLOCK + r;
                let off = (r as usize) * 128;
                block[off..off + 8].copy_from_slice(&key.to_le_bytes());
            }
            driver.write(base + b * BLOCK as u64, &block)?;
        }
        driver.flush()?;
        Ok(KvStore {
            records: blocks * RECORDS_PER_BLOCK,
            base,
            blocks,
            stride: BLOCK as u64,
            cluster_map: None,
            segments,
        })
    }

    /// Attach to an already-built store (same parameters) without
    /// rewriting it — lets benches reuse one populated chain.
    pub fn attach(driver: &dyn Driver, fill_fraction: f64) -> Result<KvStore> {
        let disk = driver.chain().active().geom().virtual_size;
        let blocks = (disk as f64 * fill_fraction) as u64 / BLOCK as u64;
        if blocks == 0 {
            bail!("disk too small for a kv store");
        }
        let n_segments = 16u64.min(blocks);
        let segments = (0..n_segments).map(|s| blocks * s / n_segments).collect();
        Ok(KvStore {
            records: blocks * RECORDS_PER_BLOCK,
            base: 0,
            blocks,
            stride: BLOCK as u64,
            cluster_map: None,
            segments,
        })
    }

    /// Attach a store whose blocks are *spread uniformly over the whole
    /// disk* — the §6.4.2 setup, where the database's valid clusters are
    /// uniformly distributed over the generated chain's layers. Reads
    /// hit pre-populated chain clusters (content is whatever the layer
    /// holds; the key-stamp check is skipped by stamp==0 tolerance in
    /// `get` only for truly zero blocks, so use `get_unchecked`).
    pub fn attach_spread(driver: &dyn Driver, fill_fraction: f64) -> Result<KvStore> {
        let disk = driver.chain().active().geom().virtual_size;
        let blocks = (disk as f64 * fill_fraction) as u64 / BLOCK as u64;
        if blocks == 0 {
            bail!("disk too small for a kv store");
        }
        let stride = (disk / blocks) & !(BLOCK as u64 - 1);
        let n_segments = 16u64.min(blocks);
        let segments = (0..n_segments).map(|s| blocks * s / n_segments).collect();
        Ok(KvStore {
            records: blocks * RECORDS_PER_BLOCK,
            base: 0,
            blocks,
            stride: stride.max(BLOCK as u64),
            cluster_map: None,
            segments,
        })
    }

    /// Attach a store whose blocks live in the chain's *populated*
    /// clusters — the faithful §6.4.2 setup: YCSB keys always resolve to
    /// existing data ("a uniform distribution of valid clusters of the
    /// Qcow2 chains generated"). The scan is setup-time only (uncached
    /// walk, not on the benchmarked path).
    pub fn attach_populated(driver: &dyn Driver) -> Result<KvStore> {
        let chain = driver.chain();
        let geom = *chain.active().geom();
        let blocks_per_cluster = geom.cluster_size() / BLOCK as u64;
        let mut clusters = Vec::new();
        for vc in 0..geom.num_vclusters() {
            if chain.resolve_walk(vc)?.is_some() {
                clusters.push(vc * geom.cluster_size());
            }
        }
        if clusters.is_empty() {
            bail!("chain has no populated clusters");
        }
        let blocks = clusters.len() as u64 * blocks_per_cluster;
        let n_segments = 16u64.min(blocks);
        let segments = (0..n_segments).map(|s| blocks * s / n_segments).collect();
        Ok(KvStore {
            records: blocks * RECORDS_PER_BLOCK,
            base: 0,
            blocks,
            stride: BLOCK as u64,
            cluster_map: Some(clusters),
            segments,
        })
    }

    /// Virtual byte offset of a block index.
    fn block_voff(&self, block_idx: u64) -> u64 {
        match &self.cluster_map {
            None => self.base + block_idx * self.stride,
            Some(map) => {
                let per = (64 << 10) / BLOCK as u64;
                map[(block_idx / per) as usize] + (block_idx % per) * BLOCK as u64
            }
        }
    }

    /// Point lookup without content verification (spread-attached stores
    /// read whatever the chain layers hold).
    pub fn get_unchecked(&self, driver: &mut dyn Driver, key: u64) -> Result<Vec<u8>> {
        if key >= self.records {
            bail!("key {key} out of range");
        }
        let block_idx = key / RECORDS_PER_BLOCK;
        let _segment = match self.segments.binary_search(&block_idx) {
            Ok(i) => i,
            Err(i) => i.saturating_sub(1),
        };
        let mut block = vec![0u8; BLOCK];
        driver.read(self.block_voff(block_idx), &mut block)?;
        let r = (key % RECORDS_PER_BLOCK) as usize * 128;
        Ok(block[r + 16..r + 128].to_vec())
    }

    /// Point lookup: sparse-index resolve (in RAM) + one block read.
    /// Returns the 112-byte value.
    pub fn get(&self, driver: &mut dyn Driver, key: u64) -> Result<Vec<u8>> {
        if key >= self.records {
            bail!("key {key} out of range");
        }
        let block_idx = key / RECORDS_PER_BLOCK;
        // binary search the segment index (RAM cost only, like RocksDB's
        // resident index blocks)
        let _segment = match self.segments.binary_search(&block_idx) {
            Ok(i) => i,
            Err(i) => i.saturating_sub(1),
        };
        let mut block = vec![0u8; BLOCK];
        driver.read(self.block_voff(block_idx), &mut block)?;
        let r = (key % RECORDS_PER_BLOCK) as usize * 128;
        // verify the stored key stamp (catches translation bugs)
        let stored = u64::from_le_bytes(block[r..r + 8].try_into().unwrap());
        if stored != key && stored != 0 {
            bail!("kv corruption: key {key} found stamp {stored}");
        }
        Ok(block[r + 16..r + 128].to_vec())
    }

    pub fn blocks(&self) -> u64 {
        self.blocks
    }

    /// Extract and stamp-check one record from its block.
    fn record_from(&self, key: u64, block: &[u8]) -> Result<Vec<u8>> {
        let r = (key % RECORDS_PER_BLOCK) as usize * 128;
        let stored = u64::from_le_bytes(block[r..r + 8].try_into().unwrap());
        if stored != key && stored != 0 {
            bail!("kv corruption: key {key} found stamp {stored}");
        }
        Ok(block[r + 16..r + 128].to_vec())
    }

    /// Extract one record without the stamp check.
    fn record_from_unchecked(&self, key: u64, block: &[u8]) -> Vec<u8> {
        let r = (key % RECORDS_PER_BLOCK) as usize * 128;
        block[r + 16..r + 128].to_vec()
    }

    /// Shared vectored-fetch plumbing: read the listed block indices in
    /// order with ONE `readv` (adjacent blocks coalesce into merged
    /// device reads).
    fn read_blocks(
        &self,
        driver: &mut dyn Driver,
        block_idxs: &[u64],
    ) -> Result<Vec<Vec<u8>>> {
        let mut blocks: Vec<Vec<u8>> =
            (0..block_idxs.len()).map(|_| vec![0u8; BLOCK]).collect();
        {
            let mut iovs: Vec<(u64, &mut [u8])> = block_idxs
                .iter()
                .zip(blocks.iter_mut())
                .map(|(&bi, b)| (self.block_voff(bi), b.as_mut_slice()))
                .collect();
            driver.readv(&mut iovs)?;
        }
        Ok(blocks)
    }

    fn check_keys(&self, keys: &[u64]) -> Result<()> {
        for &k in keys {
            if k >= self.records {
                bail!("key {k} out of range");
            }
        }
        Ok(())
    }

    /// Map each key to a deduplicated covering-block list: keys sharing a
    /// block share ONE device read. Returns (unique block indices, per-key
    /// position into that list).
    fn dedup_blocks(&self, keys: &[u64]) -> (Vec<u64>, Vec<usize>) {
        let mut uniq: Vec<u64> = Vec::new();
        let mut pos: HashMap<u64, usize> = HashMap::new();
        let per_key = keys
            .iter()
            .map(|&k| {
                let bi = k / RECORDS_PER_BLOCK;
                *pos.entry(bi).or_insert_with(|| {
                    uniq.push(bi);
                    uniq.len() - 1
                })
            })
            .collect();
        (uniq, per_key)
    }

    /// Batched point lookups: one vectored read over all covering blocks
    /// (one channel/driver submission). Values are returned in key order.
    pub fn multi_get(&self, driver: &mut dyn Driver, keys: &[u64]) -> Result<Vec<Vec<u8>>> {
        self.check_keys(keys)?;
        let (uniq, per_key) = self.dedup_blocks(keys);
        let blocks = self.read_blocks(driver, &uniq)?;
        keys.iter()
            .zip(per_key.iter())
            .map(|(&k, &bi)| self.record_from(k, &blocks[bi]))
            .collect()
    }

    /// [`KvStore::multi_get`] without content verification (spread-attached
    /// stores read whatever the chain layers hold — see
    /// [`KvStore::get_unchecked`]).
    pub fn multi_get_unchecked(
        &self,
        driver: &mut dyn Driver,
        keys: &[u64],
    ) -> Result<Vec<Vec<u8>>> {
        self.check_keys(keys)?;
        let (uniq, per_key) = self.dedup_blocks(keys);
        let blocks = self.read_blocks(driver, &uniq)?;
        Ok(keys
            .iter()
            .zip(per_key.iter())
            .map(|(&k, &bi)| self.record_from_unchecked(k, &blocks[bi]))
            .collect())
    }

    /// Range scan: `n` consecutive records starting at `start`, read with
    /// one vectored request over the covering blocks — on a sequential
    /// layout the whole scan collapses to ~one device read per
    /// physically contiguous run.
    pub fn scan(&self, driver: &mut dyn Driver, start: u64, n: u64) -> Result<Vec<Vec<u8>>> {
        if n == 0 {
            return Ok(Vec::new());
        }
        if start >= self.records || self.records - start < n {
            bail!("scan [{start}, +{n}) out of range ({} records)", self.records);
        }
        let end = start + n;
        let first_b = start / RECORDS_PER_BLOCK;
        let last_b = (end - 1) / RECORDS_PER_BLOCK;
        let idxs: Vec<u64> = (first_b..=last_b).collect();
        let blocks = self.read_blocks(driver, &idxs)?;
        (start..end)
            .map(|key| {
                let b = ((key / RECORDS_PER_BLOCK) - first_b) as usize;
                self.record_from(key, &blocks[b])
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheConfig;
    use crate::chaingen::{generate, ChainSpec};
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::metrics::memory::MemoryAccountant;
    use crate::qcow::image::DataMode;
    use crate::storage::node::StorageNode;
    use crate::vdisk::scalable::ScalableDriver;

    fn driver() -> (ScalableDriver, std::sync::Arc<VirtClock>) {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let spec = ChainSpec {
            disk_size: 8 << 20,
            chain_len: 2,
            populated: 0.0, // store writes populate it
            data_mode: DataMode::Real,
            ..Default::default()
        };
        let chain = generate(&node, &spec).unwrap();
        (
            ScalableDriver::new(
                chain,
                CacheConfig::default(),
                clock.clone(),
                CostModel::default(),
                MemoryAccountant::new(),
            ),
            clock,
        )
    }

    #[test]
    fn build_and_get_roundtrip() {
        let (mut d, _clock) = driver();
        let kv = KvStore::build(&mut d, 0.4, 1).unwrap();
        assert!(kv.records > 1000);
        for key in [0u64, 1, kv.records / 2, kv.records - 1] {
            let v = kv.get(&mut d, key).unwrap();
            assert_eq!(v.len(), 112);
        }
        assert!(kv.get(&mut d, kv.records).is_err());
    }

    #[test]
    fn multi_get_matches_scalar_gets() {
        let (mut d, _clock) = driver();
        let kv = KvStore::build(&mut d, 0.3, 7).unwrap();
        let keys = [0u64, 5, kv.records / 3, kv.records / 2, kv.records - 1];
        let batch = kv.multi_get(&mut d, &keys).unwrap();
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(batch[i], kv.get(&mut d, k).unwrap(), "key {k}");
        }
        assert!(kv.multi_get(&mut d, &[kv.records]).is_err());
    }

    #[test]
    fn scan_matches_scalar_gets() {
        let (mut d, _clock) = driver();
        let kv = KvStore::build(&mut d, 0.3, 9).unwrap();
        let start = RECORDS_PER_BLOCK - 2; // straddle a block boundary
        let vals = kv.scan(&mut d, start, 10).unwrap();
        assert_eq!(vals.len(), 10);
        for (i, v) in vals.iter().enumerate() {
            assert_eq!(*v, kv.get(&mut d, start + i as u64).unwrap());
        }
        assert!(kv.scan(&mut d, kv.records - 1, 2).is_err());
        assert!(kv.scan(&mut d, 0, 0).unwrap().is_empty());
    }

    #[test]
    fn detects_stamps_after_snapshot() {
        let (mut d, _clock) = driver();
        let kv = KvStore::build(&mut d, 0.25, 2).unwrap();
        // all gets still verify after going through COW layers
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let key = rng.below(kv.records);
            kv.get(&mut d, key).unwrap();
        }
    }
}
