//! Simulated guest workloads — the benchmarks of §6.1 as request
//! streams against a [`Driver`]:
//!
//! * [`dd`] — `dd if=/dev/sda of=/dev/null bs=4M`: sequential full-disk
//!   read (Figs 10, 12, 13, 14, 15);
//! * [`fio`] — random 4 KiB reads on the raw device (Fig 16);
//! * [`kvstore`] + [`ycsb`] — an LSM key-value store on the virtual disk
//!   driven by YCSB-C uniform point reads (the RocksDB stand-in, Fig 18);
//! * [`boot`] — a VM boot read trace, concentrated on the base image
//!   (Fig 17 and the file-0 spike of Fig 13c).
//!
//! All throughput/latency numbers are virtual-time based (deterministic).

pub mod boot;
pub mod dd;
pub mod fio;
pub mod kvstore;
pub mod ycsb;

use crate::metrics::clock::VirtClock;
use crate::vdisk::Driver;
use anyhow::Result;
use std::sync::Arc;

/// Common result of a workload run.
#[derive(Clone, Debug, Default)]
pub struct WorkloadStats {
    /// Operations issued.
    pub ops: u64,
    /// Guest-visible bytes transferred.
    pub bytes: u64,
    /// Virtual nanoseconds elapsed.
    pub elapsed_ns: u64,
}

impl WorkloadStats {
    pub fn throughput_bps(&self) -> f64 {
        if self.elapsed_ns == 0 {
            return 0.0;
        }
        self.bytes as f64 / (self.elapsed_ns as f64 / 1e9)
    }

    pub fn iops(&self) -> f64 {
        if self.elapsed_ns == 0 {
            return 0.0;
        }
        self.ops as f64 / (self.elapsed_ns as f64 / 1e9)
    }

    pub fn mean_latency_ns(&self) -> f64 {
        if self.ops == 0 {
            return 0.0;
        }
        self.elapsed_ns as f64 / self.ops as f64
    }
}

/// A guest benchmark that can be replayed against any driver.
pub trait Workload {
    fn name(&self) -> &str;
    fn run(&mut self, driver: &mut dyn Driver, clock: &Arc<VirtClock>)
        -> Result<WorkloadStats>;
}
