//! YCSB-C: read-only point lookups with uniform key distribution
//! (§6.4.2: "YCSB-C, which simulates a user performing read-only
//! requests ... 500K requests"; the paper populates with "a uniform
//! distribution of valid clusters").

use super::kvstore::KvStore;
use super::{Workload, WorkloadStats};
use crate::metrics::clock::VirtClock;
use crate::util::rng::Rng;
use crate::vdisk::Driver;
use anyhow::Result;
use std::sync::Arc;

pub struct YcsbC {
    pub store: KvStore,
    pub requests: u64,
    pub seed: u64,
    /// Verify record stamps (dense stores built through the driver);
    /// spread-attached stores read pre-populated chain content instead.
    pub checked: bool,
}

impl YcsbC {
    pub fn new(store: KvStore, requests: u64, seed: u64) -> Self {
        YcsbC { store, requests, seed, checked: true }
    }

    pub fn unchecked(store: KvStore, requests: u64, seed: u64) -> Self {
        YcsbC { store, requests, seed, checked: false }
    }
}

impl Workload for YcsbC {
    fn name(&self) -> &str {
        "ycsb-c"
    }

    fn run(
        &mut self,
        driver: &mut dyn Driver,
        clock: &Arc<VirtClock>,
    ) -> Result<WorkloadStats> {
        let mut rng = Rng::new(self.seed);
        let t0 = clock.now();
        let mut stats = WorkloadStats::default();
        for _ in 0..self.requests {
            let key = rng.below(self.store.records);
            let v = if self.checked {
                self.store.get(driver, key)?
            } else {
                self.store.get_unchecked(driver, key)?
            };
            stats.ops += 1;
            stats.bytes += v.len() as u64;
        }
        stats.elapsed_ns = clock.now() - t0;
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheConfig;
    use crate::chaingen::{generate, ChainSpec};
    use crate::metrics::clock::CostModel;
    use crate::metrics::memory::MemoryAccountant;
    use crate::qcow::image::DataMode;
    use crate::storage::node::StorageNode;
    use crate::vdisk::scalable::ScalableDriver;

    #[test]
    fn runs_requested_requests() {
        let clock = VirtClock::new();
        let node = StorageNode::new("s", clock.clone(), CostModel::default());
        let spec = ChainSpec {
            disk_size: 8 << 20,
            chain_len: 1,
            populated: 0.0,
            data_mode: DataMode::Real,
            ..Default::default()
        };
        let chain = generate(&node, &spec).unwrap();
        let mut d = ScalableDriver::new(
            chain,
            CacheConfig::default(),
            clock.clone(),
            CostModel::default(),
            MemoryAccountant::new(),
        );
        let store = KvStore::build(&mut d, 0.3, 7).unwrap();
        let mut y = YcsbC::new(store, 200, 11);
        let stats = y.run(&mut d, &clock).unwrap();
        assert_eq!(stats.ops, 200);
        assert!(stats.throughput_bps() > 0.0);
        assert!(stats.mean_latency_ns() > 0.0);
    }
}
