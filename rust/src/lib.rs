//! # sqemu-rs — Virtual Disk Snapshot Management at Scale
//!
//! Reproduction of the SQEMU paper (CS.DC 2022): a cluster-granular
//! copy-on-write virtual-disk format with external snapshot chains, the two
//! driver designs the paper compares (vanilla per-backing-file recursion vs.
//! SQEMU direct access + unified indexing cache), a simulated cloud storage
//! substrate (virtual-time latency model, NFS-like storage nodes, guest
//! workloads), and a multi-VM storage coordinator whose bulk paths execute
//! AOT-compiled JAX/Pallas kernels through PJRT.
//!
//! Layering (see DESIGN.md):
//! * [`util`], [`metrics`] — substrate: errors, JSON, PRNG, virtual clock,
//!   histograms, memory accounting.
//! * [`storage`] — pluggable backends + the Eq. 1 latency model.
//! * [`qcow`] — the on-disk format (vanilla + the `backing_file_index`
//!   extension) and snapshot operations.
//! * [`cache`] — L2 slice caches: per-backing-file (vanilla) and unified
//!   with cache correction (SQEMU).
//! * [`vdisk`] — the two request-path drivers and their low-level metrics.
//! * [`blockjob`] — live chain maintenance: incremental, rate-limited
//!   stream/stamp jobs interleaved with guest I/O.
//! * [`gc`] — chain garbage collection: cross-chain reference registry,
//!   deferred-delete set, rate-limited sweep job and leak audit.
//! * [`dedup`] — capacity multiplication: the compressed-cluster codec,
//!   the fleet-wide content-addressed extent index, and the
//!   logical-vs-physical capacity scanner.
//! * [`migrate`] — live chain migration between storage nodes (mirror
//!   job, crash-safe switchover journal) and the fleet rebalancer.
//! * [`control`] — the durable HA control plane: write-ahead StateStore
//!   on a dedicated metadata node, lease-based VM ownership, and
//!   epoch-fenced leader election for multi-coordinator fleets.
//! * [`guest`] — simulated guest workloads (dd, fio, YCSB over an LSM
//!   key-value store, VM boot).
//! * [`chaingen`], [`characterize`] — chain generation + the §3 study.
//! * [`runtime`] — PJRT artifact loading/execution (the AOT bridge).
//! * [`coordinator`] — the multi-VM storage node: router, batcher,
//!   streaming orchestrator, placement.
//! * [`telemetry`] — the fleet observability plane: pull-based metrics
//!   registry + Prometheus-text exporter over every subsystem's existing
//!   stats, and ring-buffered span tracing for sampled VMs.
//! * [`bench`] — the figure-regeneration harness used by `cargo bench`.

pub mod bench;
pub mod blockjob;
pub mod cache;
pub mod chaingen;
pub mod characterize;
pub mod cli;
pub mod control;
pub mod coordinator;
pub mod dedup;
pub mod gc;
pub mod guest;
pub mod metrics;
pub mod migrate;
pub mod qcow;
pub mod runtime;
pub mod storage;
pub mod telemetry;
pub mod util;
pub mod vdisk;

pub use qcow::{Chain, Image};

