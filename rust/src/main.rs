fn main() -> anyhow::Result<()> {
    sqemu::cli::run(std::env::args().skip(1).collect())
}
