//! Virtual clock: deterministic simulated time.
//!
//! All I/O costs in the simulator (Eq. 1's T_M, T_L, T_D plus bandwidth
//! terms) are charged to a shared `VirtClock` instead of sleeping, so the
//! figure benches reproduce the paper's *latency structure* quickly and
//! deterministically. The §Perf pass measures the same code paths under
//! wall time with a free clock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Monotonic virtual nanosecond counter, shareable across threads.
#[derive(Debug, Default)]
pub struct VirtClock {
    ns: AtomicU64,
}

impl VirtClock {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Current virtual time in nanoseconds.
    pub fn now(&self) -> u64 {
        self.ns.load(Ordering::Relaxed)
    }

    /// Advance virtual time; returns the new time.
    pub fn advance(&self, ns: u64) -> u64 {
        self.ns.fetch_add(ns, Ordering::Relaxed) + ns
    }

    /// Reset to zero (between bench configurations).
    pub fn reset(&self) {
        self.ns.store(0, Ordering::Relaxed);
    }

    /// Run `f` and return (result, elapsed virtual ns).
    pub fn measure<T>(&self, f: impl FnOnce() -> T) -> (T, u64) {
        let t0 = self.now();
        let out = f();
        (out, self.now() - t0)
    }
}

/// The paper's Eq. 1 cost constants (§4.2), in nanoseconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostModel {
    /// T_M: RAM access (cache hit handling) — "about 100 ns".
    pub t_ram: u64,
    /// T_L: traversal of all software and network layers — "about 1 µs".
    pub t_layers: u64,
    /// T_D: disk access — "about 80 µs".
    pub t_disk: u64,
    /// Sequential device bandwidth in bytes/s (for data transfers; the
    /// testbed's SATA SSD over 10 GbE NFS — SSD is the bottleneck).
    pub bandwidth: u64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            t_ram: 100,
            t_layers: 1_000,
            t_disk: 80_000,
            bandwidth: 500 << 20, // 500 MiB/s sequential SSD
        }
    }
}

impl CostModel {
    /// Cost of one device I/O of `len` bytes (metadata or data).
    pub fn io_ns(&self, len: u64) -> u64 {
        self.t_layers + self.t_disk + len * 1_000_000_000 / self.bandwidth
    }

    /// Cost of one in-RAM cache probe.
    pub fn ram_ns(&self) -> u64 {
        self.t_ram
    }

    /// Eq. 1: average lookup cost for a chain of length `n` given event
    /// ratios (hit, miss, unallocated sum to <= 1 per level).
    pub fn eq1_avg_lookup_ns(
        &self,
        hit: f64,
        miss: f64,
        unalloc: f64,
        n: u64,
    ) -> f64 {
        let t_m = self.t_ram as f64;
        let t_dl = (self.t_disk + self.t_layers) as f64;
        let t_f = self.t_layers as f64; // chain-hop software cost
        (hit * t_m + miss * (t_dl + t_f) + unalloc * t_f) * n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances() {
        let c = VirtClock::new();
        assert_eq!(c.now(), 0);
        c.advance(100);
        c.advance(50);
        assert_eq!(c.now(), 150);
        c.reset();
        assert_eq!(c.now(), 0);
    }

    #[test]
    fn measure_returns_elapsed() {
        let c = VirtClock::new();
        let (v, dt) = c.measure(|| {
            c.advance(42);
            "x"
        });
        assert_eq!(v, "x");
        assert_eq!(dt, 42);
    }

    #[test]
    fn cost_model_io() {
        let m = CostModel::default();
        // metadata slice read: dominated by t_disk
        assert!(m.io_ns(256) > m.t_disk);
        // 64 KiB data cluster at 500 MiB/s adds ~125 µs
        let data = m.io_ns(64 << 10);
        assert!(data > m.t_disk + 100_000, "data={data}");
    }

    #[test]
    fn eq1_scales_linearly_in_chain() {
        let m = CostModel::default();
        let y1 = m.eq1_avg_lookup_ns(0.9, 0.05, 0.05, 1);
        let y100 = m.eq1_avg_lookup_ns(0.9, 0.05, 0.05, 100);
        assert!((y100 / y1 - 100.0).abs() < 1e-9);
    }

    #[test]
    fn clock_shared_across_threads() {
        let c = VirtClock::new();
        let mut handles = vec![];
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.advance(1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.now(), 4000);
    }
}
