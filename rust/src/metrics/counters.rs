//! Low-level event counters — the paper's §6.3 metrics: cache misses,
//! cache hits, cache hit unallocated, per-backing-file lookup counts.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Counters for one driver instance (shared across its caches).
#[derive(Debug, Default)]
pub struct CacheCounters {
    /// Entry found in cache and allocated ("cache hit").
    pub hits: AtomicU64,
    /// Slice absent from cache — a device fetch was required.
    pub misses: AtomicU64,
    /// Entry found but cluster not allocated in this file — the chain
    /// walk (vanilla) / backing-file fetch (sqemu) trigger ("cache hit
    /// unallocated").
    pub hit_unallocated: AtomicU64,
    /// Total cache lookups, attributed per backing file index (Fig 13c).
    per_file_lookups: Mutex<Vec<u64>>,
}

impl CacheCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn unallocated(&self) {
        self.hit_unallocated.fetch_add(1, Ordering::Relaxed);
    }

    /// Bulk variants for the batched resolvers (one call per slice group).
    pub fn add_hits(&self, n: u64) {
        self.hits.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_unallocated(&self, n: u64) {
        self.hit_unallocated.fetch_add(n, Ordering::Relaxed);
    }

    /// Record one cache lookup against backing file `bfi`.
    pub fn lookup_on(&self, bfi: usize) {
        let mut v = self.per_file_lookups.lock().unwrap();
        if v.len() <= bfi {
            v.resize(bfi + 1, 0);
        }
        v[bfi] += 1;
    }

    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            hit_unallocated: self.hit_unallocated.load(Ordering::Relaxed),
            per_file_lookups: self.per_file_lookups.lock().unwrap().clone(),
        }
    }

    pub fn reset(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
        self.hit_unallocated.store(0, Ordering::Relaxed);
        self.per_file_lookups.lock().unwrap().clear();
    }
}

/// Point-in-time copy of the counters, for reports and assertions.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CounterSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub hit_unallocated: u64,
    pub per_file_lookups: Vec<u64>,
}

impl CounterSnapshot {
    pub fn total_lookups(&self) -> u64 {
        self.hits + self.misses + self.hit_unallocated
    }

    /// Ratios for Eq. 1 (hit%, miss%, unalloc%).
    pub fn ratios(&self) -> (f64, f64, f64) {
        let t = self.total_lookups() as f64;
        if t == 0.0 {
            return (0.0, 0.0, 0.0);
        }
        (
            self.hits as f64 / t,
            self.misses as f64 / t,
            self.hit_unallocated as f64 / t,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_snapshots() {
        let c = CacheCounters::new();
        c.hit();
        c.hit();
        c.miss();
        c.unallocated();
        c.lookup_on(3);
        c.lookup_on(3);
        c.lookup_on(0);
        let s = c.snapshot();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
        assert_eq!(s.hit_unallocated, 1);
        assert_eq!(s.total_lookups(), 4);
        assert_eq!(s.per_file_lookups, vec![1, 0, 0, 2]);
        let (h, m, u) = s.ratios();
        assert!((h - 0.5).abs() < 1e-9);
        assert!((m - 0.25).abs() < 1e-9);
        assert!((u - 0.25).abs() < 1e-9);
    }

    #[test]
    fn reset_clears() {
        let c = CacheCounters::new();
        c.hit();
        c.lookup_on(1);
        c.reset();
        let s = c.snapshot();
        assert_eq!(s.total_lookups(), 0);
        assert!(s.per_file_lookups.is_empty());
    }
}
