//! Log-bucketed latency histogram (Fig 14's lookup-latency distributions).

/// Histogram over u64 nanosecond values with ~4% resolution: buckets are
/// (power-of-two, 16 sub-buckets) — the HdrHistogram idea, sized small.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

const SUB_BITS: u32 = 4; // 16 sub-buckets per octave
const SUB: u64 = 1 << SUB_BITS;
const BUCKETS: usize = (64 - SUB_BITS as usize) * SUB as usize;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; BUCKETS],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    fn index(v: u64) -> usize {
        if v < SUB {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros() as u64;
        let octave = msb - SUB_BITS as u64 + 1;
        let sub = (v >> (msb - SUB_BITS as u64)) - SUB;
        (octave * SUB + SUB + sub) as usize - SUB as usize
    }

    /// Lower bound of the bucket containing `v` (representative value).
    fn bucket_value(idx: usize) -> u64 {
        let idx = idx as u64;
        if idx < SUB {
            return idx;
        }
        let octave = (idx - SUB) / SUB + 1;
        let sub = (idx - SUB) % SUB;
        (SUB + sub) << (octave - 1)
    }

    pub fn record(&mut self, v: u64) {
        let idx = Self::index(v).min(BUCKETS - 1);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Sum of all recorded values (the exporter's `_sum` line).
    pub fn sum(&self) -> u128 {
        self.sum
    }

    /// Cumulative counts at fixed, data-independent bucket bounds — the
    /// exporter's `_bucket{le=...}` series. One bound per octave
    /// (inclusive upper bounds `15, 31, 63, ...`), so buckets from any
    /// two histograms align and merge exactly. The series is trimmed
    /// after the first bound that already covers every recorded value
    /// (the exporter appends `+Inf` itself); an empty histogram yields
    /// one zero bucket.
    pub fn buckets(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        let groups = BUCKETS / SUB as usize;
        for g in 0..groups {
            let lo = g * SUB as usize;
            let hi = lo + SUB as usize;
            cum += self.counts[lo..hi].iter().sum::<u64>();
            let bound = if hi >= BUCKETS {
                u64::MAX
            } else {
                Self::bucket_value(hi) - 1
            };
            out.push((bound, cum));
            if cum == self.total && bound >= self.max {
                break;
            }
        }
        out
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate quantile (q in [0,1]) via bucket representative values.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = (q * self.total as f64).ceil().max(1.0) as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Self::bucket_value(i);
            }
        }
        self.max
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Non-empty (bucket_low_value, count) pairs — the Fig 14 series.
    pub fn series(&self) -> Vec<(u64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (Self::bucket_value(i), c))
            .collect()
    }

    /// Detect multi-modality: representative values of local maxima whose
    /// count exceeds `frac` of the total (Fig 14 reports two modes under
    /// SQEMU — hit vs hit-unallocated).
    pub fn modes(&self, frac: f64) -> Vec<u64> {
        let thresh = (self.total as f64 * frac) as u64;
        let mut out = vec![];
        for i in 0..self.counts.len() {
            let c = self.counts[i];
            if c == 0 || c < thresh {
                continue;
            }
            let prev = if i > 0 { self.counts[i - 1] } else { 0 };
            let next = if i + 1 < self.counts.len() { self.counts[i + 1] } else { 0 };
            if c >= prev && c >= next {
                out.push(Self::bucket_value(i));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_monotone() {
        let mut last = 0;
        for v in [0u64, 1, 15, 16, 17, 31, 32, 100, 1000, 1 << 20, 1 << 40] {
            let idx = Histogram::index(v);
            assert!(idx >= last, "v={v} idx={idx} last={last}");
            last = idx;
        }
    }

    #[test]
    fn bucket_value_within_4pct() {
        for v in [100u64, 1_000, 80_000, 1_000_000, 123_456_789] {
            let bv = Histogram::bucket_value(Histogram::index(v));
            assert!(bv <= v, "bv={bv} v={v}");
            assert!((v - bv) as f64 / (v as f64) < 1.0 / 16.0 + 1e-9);
        }
    }

    #[test]
    fn mean_and_quantiles() {
        let mut h = Histogram::new();
        for _ in 0..900 {
            h.record(100);
        }
        for _ in 0..100 {
            h.record(80_000);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - (900.0 * 100.0 + 100.0 * 80_000.0) / 1000.0).abs() < 1.0);
        assert!(h.quantile(0.5) <= 100);
        assert!(h.quantile(0.95) >= 75_000);
    }

    #[test]
    fn bimodal_detection() {
        let mut h = Histogram::new();
        for _ in 0..500 {
            h.record(120);
        }
        for _ in 0..500 {
            h.record(270_000);
        }
        let modes = h.modes(0.1);
        assert_eq!(modes.len(), 2, "modes={modes:?}");
    }

    #[test]
    fn buckets_are_fixed_cumulative_and_trimmed() {
        let mut h = Histogram::new();
        for v in [3u64, 14, 20, 500, 500, 70_000] {
            h.record(v);
        }
        let b = h.buckets();
        // fixed octave bounds: 15, 31, 63, ...
        assert_eq!(b[0].0, 15);
        assert_eq!(b[1].0, 31);
        assert_eq!(b[0].1, 2, "3 and 14 fall in the first octave");
        assert_eq!(b[1].1, 3, "20 joins cumulatively");
        // cumulative and monotone, ending at the total
        let mut last = 0;
        for &(_, c) in &b {
            assert!(c >= last);
            last = c;
        }
        assert_eq!(b.last().unwrap().1, h.count());
        assert!(b.last().unwrap().0 >= h.max(), "trimmed after covering max");
        // empty histogram still yields one zero bucket
        assert_eq!(Histogram::new().buckets(), vec![(15, 0)]);
    }

    /// Property (merge ≡ whole): recording a random sample set into one
    /// histogram must be indistinguishable — buckets, quantiles, count,
    /// sum, min, max — from recording disjoint parts and merging.
    #[test]
    fn merge_of_parts_equals_whole_property() {
        let mut rng = crate::util::rng::Rng::new(0x9157_0661);
        for round in 0..20 {
            let n = 1 + rng.below(400) as usize;
            let parts = 1 + rng.below(5) as usize;
            let mut whole = Histogram::new();
            let mut shards: Vec<Histogram> =
                (0..parts).map(|_| Histogram::new()).collect();
            for i in 0..n {
                // spread magnitudes across many octaves
                let v = rng.below(1 << (1 + rng.below(40)));
                whole.record(v);
                shards[i % parts].record(v);
            }
            let mut merged = Histogram::new();
            for s in &shards {
                merged.merge(s);
            }
            assert_eq!(merged.count(), whole.count(), "round {round}");
            assert_eq!(merged.sum(), whole.sum());
            assert_eq!(merged.min(), whole.min());
            assert_eq!(merged.max(), whole.max());
            assert_eq!(merged.buckets(), whole.buckets());
            for q in [0.0, 0.01, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
                assert_eq!(
                    merged.quantile(q),
                    whole.quantile(q),
                    "round {round} q {q}"
                );
            }
        }
    }

    /// Property (quantile ≡ bucketed rank): `quantile(q)` returns
    /// exactly the lower bound of the bucket holding the rank-`q`
    /// sample of the sorted data (≤ the true value, within one
    /// sub-bucket of resolution).
    #[test]
    fn quantile_matches_sorted_rank_property() {
        let mut rng = crate::util::rng::Rng::new(0xC0FF_EE00);
        for _ in 0..10 {
            let n = 1 + rng.below(300) as usize;
            let mut h = Histogram::new();
            let mut vals = Vec::with_capacity(n);
            for _ in 0..n {
                let v = rng.below(1 << (1 + rng.below(36)));
                h.record(v);
                vals.push(v);
            }
            vals.sort_unstable();
            for q in [0.01f64, 0.1, 0.5, 0.9, 0.99, 1.0] {
                let target =
                    ((q * n as f64).ceil().max(1.0) as usize).min(n) - 1;
                let truth = vals[target];
                let got = h.quantile(q);
                let expect = Histogram::bucket_value(Histogram::index(truth));
                assert_eq!(got, expect, "q={q} truth={truth}");
                assert!(got <= truth);
            }
        }
    }

    #[test]
    fn merge_adds() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(10);
        b.record(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), 10);
        assert_eq!(a.max(), 1000);
    }
}
