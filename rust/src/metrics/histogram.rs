//! Log-bucketed latency histogram (Fig 14's lookup-latency distributions).

/// Histogram over u64 nanosecond values with ~4% resolution: buckets are
/// (power-of-two, 16 sub-buckets) — the HdrHistogram idea, sized small.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

const SUB_BITS: u32 = 4; // 16 sub-buckets per octave
const SUB: u64 = 1 << SUB_BITS;
const BUCKETS: usize = (64 - SUB_BITS as usize) * SUB as usize;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; BUCKETS],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    fn index(v: u64) -> usize {
        if v < SUB {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros() as u64;
        let octave = msb - SUB_BITS as u64 + 1;
        let sub = (v >> (msb - SUB_BITS as u64)) - SUB;
        (octave * SUB + SUB + sub) as usize - SUB as usize
    }

    /// Lower bound of the bucket containing `v` (representative value).
    fn bucket_value(idx: usize) -> u64 {
        let idx = idx as u64;
        if idx < SUB {
            return idx;
        }
        let octave = (idx - SUB) / SUB + 1;
        let sub = (idx - SUB) % SUB;
        (SUB + sub) << (octave - 1)
    }

    pub fn record(&mut self, v: u64) {
        let idx = Self::index(v).min(BUCKETS - 1);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate quantile (q in [0,1]) via bucket representative values.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = (q * self.total as f64).ceil().max(1.0) as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Self::bucket_value(i);
            }
        }
        self.max
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Non-empty (bucket_low_value, count) pairs — the Fig 14 series.
    pub fn series(&self) -> Vec<(u64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (Self::bucket_value(i), c))
            .collect()
    }

    /// Detect multi-modality: representative values of local maxima whose
    /// count exceeds `frac` of the total (Fig 14 reports two modes under
    /// SQEMU — hit vs hit-unallocated).
    pub fn modes(&self, frac: f64) -> Vec<u64> {
        let thresh = (self.total as f64 * frac) as u64;
        let mut out = vec![];
        for i in 0..self.counts.len() {
            let c = self.counts[i];
            if c == 0 || c < thresh {
                continue;
            }
            let prev = if i > 0 { self.counts[i - 1] } else { 0 };
            let next = if i + 1 < self.counts.len() { self.counts[i + 1] } else { 0 };
            if c >= prev && c >= next {
                out.push(Self::bucket_value(i));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_monotone() {
        let mut last = 0;
        for v in [0u64, 1, 15, 16, 17, 31, 32, 100, 1000, 1 << 20, 1 << 40] {
            let idx = Histogram::index(v);
            assert!(idx >= last, "v={v} idx={idx} last={last}");
            last = idx;
        }
    }

    #[test]
    fn bucket_value_within_4pct() {
        for v in [100u64, 1_000, 80_000, 1_000_000, 123_456_789] {
            let bv = Histogram::bucket_value(Histogram::index(v));
            assert!(bv <= v, "bv={bv} v={v}");
            assert!((v - bv) as f64 / (v as f64) < 1.0 / 16.0 + 1e-9);
        }
    }

    #[test]
    fn mean_and_quantiles() {
        let mut h = Histogram::new();
        for _ in 0..900 {
            h.record(100);
        }
        for _ in 0..100 {
            h.record(80_000);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - (900.0 * 100.0 + 100.0 * 80_000.0) / 1000.0).abs() < 1.0);
        assert!(h.quantile(0.5) <= 100);
        assert!(h.quantile(0.95) >= 75_000);
    }

    #[test]
    fn bimodal_detection() {
        let mut h = Histogram::new();
        for _ in 0..500 {
            h.record(120);
        }
        for _ in 0..500 {
            h.record(270_000);
        }
        let modes = h.modes(0.1);
        assert_eq!(modes.len(), 2, "modes={modes:?}");
    }

    #[test]
    fn merge_adds() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(10);
        b.record(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), 10);
        assert_eq!(a.max(), 1000);
    }
}
