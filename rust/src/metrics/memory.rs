//! Explicit memory accountant — the stand-in for the paper's Qemu RSS
//! measurements.
//!
//! §4.3 attributes the footprint growth to per-snapshot structures: the L2
//! indexing caches (dominant) plus per-snapshot driver state. Every such
//! allocation in this codebase registers its live bytes here, so Figs 10
//! and 12 are regenerated from exactly the structures the paper blames.

use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;

/// Memory categories tracked separately (massif-style attribution).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemCategory {
    /// L2 slice caches (the dominant §4.3 culprit).
    Cache,
    /// Per-snapshot driver instance state (BDS-like structs).
    DriverState,
    /// In-RAM L1 tables (loaded at open, one per file).
    L1Table,
    /// Coordinator-level state (queues, routing tables).
    Coordinator,
}

const N_CATEGORIES: usize = 4;

impl MemCategory {
    fn idx(self) -> usize {
        match self {
            MemCategory::Cache => 0,
            MemCategory::DriverState => 1,
            MemCategory::L1Table => 2,
            MemCategory::Coordinator => 3,
        }
    }

    pub const ALL: [MemCategory; N_CATEGORIES] = [
        MemCategory::Cache,
        MemCategory::DriverState,
        MemCategory::L1Table,
        MemCategory::Coordinator,
    ];

    pub fn name(self) -> &'static str {
        match self {
            MemCategory::Cache => "cache",
            MemCategory::DriverState => "driver_state",
            MemCategory::L1Table => "l1_table",
            MemCategory::Coordinator => "coordinator",
        }
    }
}

/// Shared accountant; `Registration` guards release on drop.
#[derive(Debug, Default)]
pub struct MemoryAccountant {
    live: [AtomicI64; N_CATEGORIES],
    peak: AtomicI64,
}

impl MemoryAccountant {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Register `bytes` of live memory; returns a guard that releases it.
    pub fn register(
        self: &Arc<Self>,
        cat: MemCategory,
        bytes: u64,
    ) -> Registration {
        self.live[cat.idx()].fetch_add(bytes as i64, Ordering::Relaxed);
        self.bump_peak();
        Registration { acct: Arc::clone(self), cat, bytes }
    }

    fn bump_peak(&self) {
        let t = self.total() as i64;
        self.peak.fetch_max(t, Ordering::Relaxed);
    }

    pub fn live(&self, cat: MemCategory) -> u64 {
        self.live[cat.idx()].load(Ordering::Relaxed).max(0) as u64
    }

    /// Total live bytes across categories — the "Qemu overhead on top of
    /// guest RAM" the paper plots.
    pub fn total(&self) -> u64 {
        MemCategory::ALL.iter().map(|&c| self.live(c)).sum()
    }

    /// Peak total observed since construction/reset (the paper reports
    /// peak RSS during the benchmark run).
    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::Relaxed).max(0) as u64
    }

    pub fn reset_peak(&self) {
        self.peak.store(self.total() as i64, Ordering::Relaxed);
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        for c in MemCategory::ALL {
            s.push_str(&format!(
                "{:>14}: {}\n",
                c.name(),
                crate::util::human_bytes(self.live(c))
            ));
        }
        s.push_str(&format!(
            "{:>14}: {} (peak {})\n",
            "total",
            crate::util::human_bytes(self.total()),
            crate::util::human_bytes(self.peak())
        ));
        s
    }
}

/// RAII guard: releases the registered bytes when dropped. `resize` adjusts
/// a live registration (cache growth/shrink).
#[derive(Debug)]
pub struct Registration {
    acct: Arc<MemoryAccountant>,
    cat: MemCategory,
    bytes: u64,
}

impl Registration {
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    pub fn resize(&mut self, new_bytes: u64) {
        let delta = new_bytes as i64 - self.bytes as i64;
        self.acct.live[self.cat.idx()].fetch_add(delta, Ordering::Relaxed);
        self.bytes = new_bytes;
        self.acct.bump_peak();
    }
}

impl Drop for Registration {
    fn drop(&mut self) {
        self.acct.live[self.cat.idx()]
            .fetch_sub(self.bytes as i64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_release() {
        let a = MemoryAccountant::new();
        {
            let _r1 = a.register(MemCategory::Cache, 1000);
            let _r2 = a.register(MemCategory::DriverState, 500);
            assert_eq!(a.live(MemCategory::Cache), 1000);
            assert_eq!(a.total(), 1500);
        }
        assert_eq!(a.total(), 0);
        assert_eq!(a.peak(), 1500);
    }

    #[test]
    fn resize_adjusts() {
        let a = MemoryAccountant::new();
        let mut r = a.register(MemCategory::Cache, 100);
        r.resize(250);
        assert_eq!(a.live(MemCategory::Cache), 250);
        r.resize(50);
        assert_eq!(a.live(MemCategory::Cache), 50);
        assert_eq!(a.peak(), 250);
    }

    #[test]
    fn peak_reset() {
        let a = MemoryAccountant::new();
        let r = a.register(MemCategory::Cache, 100);
        drop(r);
        assert_eq!(a.peak(), 100);
        a.reset_peak();
        assert_eq!(a.peak(), 0);
    }
}
