//! Measurement substrate: virtual time, counters, latency histograms and
//! the explicit memory accountant that stands in for the paper's RSS
//! measurements (§4.3, Fig 10/12).
//!
//! These are the primitives the fleet telemetry plane
//! ([`crate::telemetry`]) exports: [`Histogram`] renders as cumulative
//! Prometheus buckets via [`histogram::Histogram::buckets`], and
//! [`VirtClock`] stamps every scrape sample.

pub mod clock;
pub mod counters;
pub mod histogram;
pub mod memory;

pub use clock::VirtClock;
pub use counters::CacheCounters;
pub use histogram::Histogram;
pub use memory::{MemCategory, MemoryAccountant};
