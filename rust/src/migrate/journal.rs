//! The migration journal: the durable record that makes a live chain
//! migration crash-safe.
//!
//! During a migration the same file name exists on two nodes (the source
//! copy serving the guest and the target copy being built). The NodeSet
//! index knows which is authoritative, but the index is volatile — after
//! a power cut only file bytes survive. The journal, a `.migrate.<vm>`
//! file on the TARGET node, is the durable arbiter, with two ordering
//! rules (DESIGN.md §12):
//!
//! 1. the journal's `begin` record (with the full move list) is durable
//!    BEFORE any target copy is created — every duplicate file a crash
//!    can leave behind is covered by a journal;
//! 2. the `committed` record is durable only AFTER every target byte is
//!    flushed — it is THE switchover point: recovery finding it makes
//!    the target authoritative (source copies are superseded); recovery
//!    not finding it rolls the partial target copies back.
//!
//! The line format reuses the PR-4 job-journal conventions: one
//! whitespace-separated record per `\n`-terminated line, a torn
//! (unterminated or unparsable) tail is skipped, `checkpoint` lines
//! carry the durable copy cursor. Recovery today resolves uncommitted
//! migrations by rolling the partial copies back wholesale; the cursor
//! is recorded (target flushed before the line that claims it) so the
//! planned resume path (ROADMAP: "Migration resume") can continue an
//! interrupted bulk copy instead.

use crate::storage::backend::BackendRef;
use crate::storage::node::StorageNode;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Name prefix of journal files on their target node. These are
/// control-plane metadata: placement (`NodeSet::rebuild_index`) and the
/// GC leak audit skip them.
pub const JOURNAL_PREFIX: &str = ".migrate.";

/// Writer handle for one migration's journal (lives on the target node).
pub struct MigrationJournal {
    backend: BackendRef,
    len: u64,
}

impl MigrationJournal {
    pub fn journal_name(vm: &str) -> String {
        format!("{JOURNAL_PREFIX}{vm}")
    }

    /// Create the journal on `target` and durably record the migration
    /// intent — vm id plus every `(file, source node)` pair — BEFORE the
    /// caller creates any target copy (ordering rule 1).
    pub fn create(
        target: &Arc<StorageNode>,
        vm: &str,
        moves: &[(String, String)],
    ) -> Result<MigrationJournal> {
        let name = Self::journal_name(vm);
        if target.open_file(&name).is_ok() {
            bail!(
                "migration journal '{name}' already exists on node '{}': an \
                 earlier migration of this vm is unresolved (run gc or recover \
                 first)",
                target.name
            );
        }
        let backend = target.create_file(&name)?;
        let mut j = MigrationJournal { backend, len: 0 };
        j.append(&format!("begin {vm}"))?;
        for (file, src) in moves {
            j.append(&format!("file {file} {src}"))?;
        }
        j.backend.flush()?;
        Ok(j)
    }

    fn append(&mut self, line: &str) -> Result<()> {
        let data = format!("{line}\n");
        self.backend.write_at(data.as_bytes(), self.len)?;
        self.len += data.len() as u64;
        Ok(())
    }

    /// Durably record the copy cursor: `file_idx` files are fully
    /// mirrored and the current file is mirrored up to byte `cursor`.
    /// The caller flushed the target copies first (image state before
    /// the journal line that claims it — the PR-4 ordering).
    pub fn checkpoint(&mut self, file_idx: usize, cursor: u64) -> Result<()> {
        self.append(&format!("checkpoint {file_idx} {cursor}"))?;
        self.backend.flush()
    }

    /// Durably record the switchover (ordering rule 2). After this
    /// returns, recovery resolves the migration target-authoritative.
    pub fn commit(&mut self) -> Result<()> {
        self.append("committed")?;
        self.backend.flush()
    }
}

/// Parsed state of one journal.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JournalState {
    pub vm: String,
    /// `(file, source node)` pairs being moved.
    pub moves: Vec<(String, String)>,
    pub committed: bool,
    /// Last durable copy cursor, if any: (files fully mirrored, byte
    /// offset within the next).
    pub cursor: Option<(usize, u64)>,
}

/// Parse journal content. Only `\n`-terminated lines count — the final
/// unterminated line is the torn tail of a crashed append — and unknown
/// or malformed records are skipped, never fatal. Returns `None` when no
/// durable `begin` record exists (such a journal covers nothing: the
/// ordering rules say no target copy can predate the begin flush).
pub fn parse(content: &str) -> Option<JournalState> {
    let mut state: Option<JournalState> = None;
    let lines: Vec<&str> = content.lines().collect();
    let n = if content.ends_with('\n') {
        lines.len()
    } else {
        lines.len().saturating_sub(1)
    };
    for line in &lines[..n] {
        let f: Vec<&str> = line.split_whitespace().collect();
        match f.as_slice() {
            ["begin", vm] => {
                state = Some(JournalState {
                    vm: vm.to_string(),
                    moves: Vec::new(),
                    committed: false,
                    cursor: None,
                })
            }
            ["file", name, src] => {
                if let Some(s) = state.as_mut() {
                    s.moves.push((name.to_string(), src.to_string()));
                }
            }
            ["checkpoint", idx, cur] => {
                if let Some(s) = state.as_mut() {
                    if let (Ok(i), Ok(c)) = (idx.parse(), cur.parse()) {
                        s.cursor = Some((i, c));
                    }
                }
            }
            ["committed"] => {
                if let Some(s) = state.as_mut() {
                    s.committed = true;
                }
            }
            _ => {}
        }
    }
    state
}

/// Read and parse the journal file `name` on `node` (`None` when absent
/// or useless — see [`parse`]).
pub fn read_journal(node: &Arc<StorageNode>, name: &str) -> Option<JournalState> {
    let backend = node.open_file(name).ok()?;
    let len = backend.len() as usize;
    let mut buf = vec![0u8; len];
    backend.read_at(&mut buf, 0).ok()?;
    parse(&String::from_utf8_lossy(&buf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::{CostModel, VirtClock};

    fn node() -> Arc<StorageNode> {
        StorageNode::new("t", VirtClock::new(), CostModel::default())
    }

    #[test]
    fn roundtrip_with_checkpoints_and_commit() {
        let t = node();
        let moves = vec![
            ("img-0".to_string(), "node-0".to_string()),
            ("img-1".to_string(), "node-0".to_string()),
        ];
        let mut j = MigrationJournal::create(&t, "vm-a", &moves).unwrap();
        let name = MigrationJournal::journal_name("vm-a");
        let st = read_journal(&t, &name).unwrap();
        assert_eq!(st.vm, "vm-a");
        assert_eq!(st.moves, moves);
        assert!(!st.committed);
        assert_eq!(st.cursor, None);

        j.checkpoint(1, 4096).unwrap();
        j.commit().unwrap();
        let st = read_journal(&t, &name).unwrap();
        assert!(st.committed);
        assert_eq!(st.cursor, Some((1, 4096)));

        // a second migration of the same vm must not start over the
        // unresolved journal
        assert!(MigrationJournal::create(&t, "vm-a", &moves).is_err());
    }

    #[test]
    fn torn_tail_is_skipped() {
        let full = "begin vm\nfile img-0 node-0\ncommitted\n";
        let st = parse(full).unwrap();
        assert!(st.committed);
        // losing the trailing newline of `committed` un-commits it
        let torn = &full[..full.len() - 1];
        let st = parse(torn).unwrap();
        assert!(!st.committed, "torn commit record does not count");
        assert_eq!(st.moves.len(), 1);
        // a journal cut before the begin flush covers nothing
        assert_eq!(parse("begi"), None);
        assert_eq!(parse(""), None);
    }

    #[test]
    fn unknown_records_are_ignored() {
        let st = parse("begin vm\nwat 1 2 3\nfile a node-0\ncheckpoint x y\n").unwrap();
        assert_eq!(st.moves, vec![("a".to_string(), "node-0".to_string())]);
        assert_eq!(st.cursor, None, "malformed checkpoint skipped");
    }
}
