//! `MirrorJob`: copy a VM's whole chain to another storage node while
//! the guest keeps writing, then switch over atomically.
//!
//! The job runs through the standard [`crate::blockjob::JobRunner`]
//! machinery on the VM's worker thread, so increments interleave with
//! guest I/O and the rate limiter meters copy bandwidth. Three phases:
//!
//! 1. **Bulk** — every chain file is copied byte-range by byte-range
//!    through the storage backends (chunks of one cluster; all-zero
//!    chunks are skipped, preserving sparseness). Before any target copy
//!    exists, a [`MigrationJournal`] on the recipient durably records
//!    the move list; the copy cursor is checkpointed into it (flush
//!    target, then journal line — the PR-4 ordering).
//! 2. **Converge** — every source file is watched
//!    ([`crate::storage::node::StorageNode::watch`], the byte-interval
//!    analogue of the [`JobFence`] write intercept), so guest writes that
//!    landed behind the bulk cursor are drained as dirty intervals and
//!    re-mirrored. Rounds repeat until a round drains nothing (or the
//!    round cap trips — a guest outrunning the rate limit is caught by
//!    the finalize drain, which is atomic).
//! 3. **Switchover** (`finalize`, atomic with respect to guest I/O) —
//!    final drain, flush every target copy, durably commit the journal,
//!    flip the [`NodeSet`] index, condemn the superseded source copies
//!    as GC *replicas* (never double-referenced: the name's refcounts
//!    follow the index), and reopen the chain through the flipped
//!    namespace so the driver rebinds to the target node.
//!
//! Cancel/failure before the commit record tears the partial target
//! copies and the journal down (`Drop`); a crash instead is resolved by
//! [`super::recover_migrations`] from the journal.
//!
//! [`JobFence`]: crate::blockjob::JobFence
//! [`NodeSet`]: crate::coordinator::placement::NodeSet

use super::journal::MigrationJournal;
use crate::blockjob::{BlockJob, Increment, JobKind};
use crate::coordinator::placement::NodeSet;
use crate::gc::GcRegistry;
use crate::qcow::image::DataMode;
use crate::qcow::Chain;
use crate::storage::backend::BackendRef;
use crate::storage::node::StorageNode;
use crate::storage::watch::{WriteLog, DIRTY_ALL};
use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;
use std::sync::Arc;

/// Converge rounds before the job stops chasing the guest and lets the
/// atomic finalize drain close the gap.
const MAX_CONVERGE_ROUNDS: u32 = 16;
/// Bulk chunks between durable cursor checkpoints.
const CHECKPOINT_EVERY_CHUNKS: u64 = 32;

/// One chain file being mirrored.
struct FileMirror {
    name: String,
    src_node: Arc<StorageNode>,
    src: BackendRef,
    dst: BackendRef,
    log: Arc<WriteLog>,
    /// Source length when the bulk pass started.
    bulk_len: u64,
    /// Bulk-copy cursor (bytes).
    cursor: u64,
    /// Source length the mirror has accounted for (tail growth beyond it
    /// is queued as a dirty extent on the next drain).
    mirrored_len: u64,
}

pub struct MirrorJob {
    nodes: Arc<NodeSet>,
    gc: Arc<GcRegistry>,
    target: Arc<StorageNode>,
    vm: String,
    data_mode: DataMode,
    active_name: String,
    files: Vec<FileMirror>,
    journal: MigrationJournal,
    chunk: u64,
    buf: Vec<u8>,
    /// Bulk progress: index of the file currently being copied.
    file_idx: usize,
    bulk_done: bool,
    /// Dirty extents awaiting re-mirror: (file index, offset, length).
    pending: VecDeque<(usize, u64, u64)>,
    converge_rounds: u32,
    /// A converge round drained nothing (or the cap tripped): ready for
    /// the atomic switchover.
    quiesced: bool,
    committed: bool,
    chunks_since_ckpt: u64,
    total: u64,
}

impl MirrorJob {
    /// Set up a mirror of `chain` onto `target`. Durably journals the
    /// intent on the recipient BEFORE creating any target copy, then
    /// creates the copies and begins watching the sources. Files already
    /// on the target node are skipped; errors tear everything down.
    pub fn new(
        chain: &Chain,
        nodes: Arc<NodeSet>,
        gc: Arc<GcRegistry>,
        target: &str,
        vm: &str,
    ) -> Result<MirrorJob> {
        let target_node = nodes
            .node_named(target)
            .ok_or_else(|| anyhow!("no storage node '{target}'"))?;
        let chunk = chain.active().geom().cluster_size();
        let mut metas: Vec<(String, Arc<StorageNode>)> = Vec::new();
        for img in chain.images() {
            let name = img.name.clone();
            let src_node = nodes
                .node_of(&name)
                .ok_or_else(|| anyhow!("cannot locate '{name}' in the node set"))?;
            if src_node.name == target_node.name {
                continue; // already home
            }
            metas.push((name, src_node));
        }
        if metas.is_empty() {
            bail!("chain of '{vm}' already lives on node '{target}'");
        }
        let moves: Vec<(String, String)> = metas
            .iter()
            .map(|(n, s)| (n.clone(), s.name.clone()))
            .collect();
        // ordering rule 1: the journal covers every duplicate before the
        // first duplicate can exist
        let journal = MigrationJournal::create(&target_node, vm, &moves)?;
        let mut files: Vec<FileMirror> = Vec::new();
        let mut err: Option<anyhow::Error> = None;
        for (name, src_node) in &metas {
            let built = (|| -> Result<FileMirror> {
                let src = src_node.open_file(name)?;
                let dst = target_node.create_file(name)?;
                // the in-flight copy's bytes are covered by the caller's
                // capacity reservation: keep them out of pressure until
                // the switchover makes them the authoritative copy, or
                // the recipient double-counts up to 2x the chain
                target_node.mark_condemned(name);
                let log = src_node.watch(name)?;
                let bulk_len = src.len();
                Ok(FileMirror {
                    name: name.clone(),
                    src_node: Arc::clone(src_node),
                    src,
                    dst,
                    log,
                    bulk_len,
                    cursor: 0,
                    mirrored_len: bulk_len,
                })
            })();
            match built {
                Ok(f) => files.push(f),
                Err(e) => {
                    err = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = err {
            // tear down ONLY what this constructor created (the built
            // FileMirrors' target copies and the journal) — the target
            // node may legitimately hold same-name files it must keep,
            // e.g. the not-yet-swept replicas of an earlier migration
            // away from it
            for f in &files {
                f.src_node.unwatch(&f.name);
                let _ = target_node.delete_file(&f.name);
            }
            for (name, src_node) in &metas {
                src_node.unwatch(name);
            }
            let _ = target_node.delete_file(&MigrationJournal::journal_name(vm));
            return Err(e);
        }
        let total = files
            .iter()
            .map(|f| crate::util::div_ceil(f.bulk_len, chunk))
            .sum::<u64>()
            .max(1);
        Ok(MirrorJob {
            nodes,
            gc,
            target: target_node,
            vm: vm.to_string(),
            data_mode: chain.active().data_mode(),
            active_name: chain.active().name.clone(),
            files,
            journal,
            buf: vec![0u8; chunk as usize],
            chunk,
            file_idx: 0,
            bulk_done: false,
            pending: VecDeque::new(),
            converge_rounds: 0,
            quiesced: false,
            committed: false,
            chunks_since_ckpt: 0,
            total,
        })
    }

    /// File names being moved (diagnostics / tests).
    pub fn moved_files(&self) -> Vec<String> {
        self.files.iter().map(|f| f.name.clone()).collect()
    }

    fn done(&self) -> bool {
        self.bulk_done && self.quiesced && self.pending.is_empty()
    }

    /// Copy one bulk chunk (or close out the current file). All-zero
    /// chunks are skipped: the fresh target reads them as holes anyway,
    /// and materializing them would triple the copy's memory footprint.
    fn step_bulk(&mut self, inc: &mut Increment) -> Result<()> {
        let Some(f) = self.files.get_mut(self.file_idx) else {
            self.bulk_done = true;
            return Ok(());
        };
        if f.cursor >= f.bulk_len {
            // file boundary: propagate the length (sparse tails carry no
            // bytes) and checkpoint the durable cursor
            f.dst.truncate_to(f.bulk_len)?;
            f.dst.flush()?;
            self.journal.checkpoint(self.file_idx + 1, 0)?;
            self.chunks_since_ckpt = 0;
            self.file_idx += 1;
            if self.file_idx >= self.files.len() {
                self.bulk_done = true;
            }
            return Ok(());
        }
        let n = self.chunk.min(f.bulk_len - f.cursor) as usize;
        f.src.read_at(&mut self.buf[..n], f.cursor)?;
        if self.buf[..n].iter().any(|&b| b != 0) {
            f.dst.write_at(&self.buf[..n], f.cursor)?;
            inc.copied += 1;
        }
        f.cursor += n as u64;
        inc.processed += 1;
        inc.bytes += n as u64;
        self.chunks_since_ckpt += 1;
        if self.chunks_since_ckpt >= CHECKPOINT_EVERY_CHUNKS {
            // target state first, then the journal line that claims it:
            // a crash between the two resumes a little early, never late
            f.dst.flush()?;
            self.journal.checkpoint(self.file_idx, f.cursor)?;
            self.chunks_since_ckpt = 0;
        }
        Ok(())
    }

    /// Drain every file's write log (plus tail growth) into the pending
    /// queue. Returns the number of extents queued.
    fn refill_pending(&mut self) -> usize {
        let mut queued = 0usize;
        for (i, f) in self.files.iter_mut().enumerate() {
            for (off, len) in f.log.drain() {
                let (off, len) = if len == DIRTY_ALL {
                    (0, f.src.len())
                } else {
                    (off, len)
                };
                if len > 0 {
                    self.pending.push_back((i, off, len));
                    queued += 1;
                }
            }
            let src_len = f.src.len();
            if src_len > f.mirrored_len {
                self.pending.push_back((i, f.mirrored_len, src_len - f.mirrored_len));
                f.mirrored_len = src_len;
                queued += 1;
            }
        }
        queued
    }

    /// Re-mirror (up to) one chunk of a dirty extent; the remainder goes
    /// back to the front of the queue. Dirty chunks are always written —
    /// the guest may have overwritten non-zero bytes WITH zeros.
    fn step_extent(&mut self, ext: (usize, u64, u64), inc: &mut Increment) -> Result<()> {
        let (i, off, len) = ext;
        let n = self.chunk.min(len);
        let f = &mut self.files[i];
        let cap = f.src.len().saturating_sub(off).min(n) as usize;
        if cap > 0 {
            f.src.read_at(&mut self.buf[..cap], off)?;
            f.dst.write_at(&self.buf[..cap], off)?;
            inc.copied += 1;
        }
        inc.processed += 1;
        inc.bytes += cap as u64;
        if len > n {
            self.pending.push_front((i, off + n, len - n));
        }
        Ok(())
    }
}

impl BlockJob for MirrorJob {
    fn kind(&self) -> JobKind {
        JobKind::Mirror
    }

    fn total_clusters(&self) -> u64 {
        self.total
    }

    fn run_increment(&mut self, _chain: &mut Chain, budget: u64) -> Result<Increment> {
        let mut inc = Increment::default();
        while inc.processed < budget && !self.done() {
            if !self.bulk_done {
                self.step_bulk(&mut inc)?;
                continue;
            }
            if self.pending.is_empty() && !self.quiesced {
                self.converge_rounds += 1;
                let queued = self.refill_pending();
                if queued == 0 || self.converge_rounds >= MAX_CONVERGE_ROUNDS {
                    // quiet (or the guest outruns us): whatever is left —
                    // pending below, plus anything written from here on —
                    // is closed out by the atomic finalize drain
                    self.quiesced = true;
                }
            }
            match self.pending.pop_front() {
                Some(ext) => self.step_extent(ext, &mut inc)?,
                None => break,
            }
        }
        inc.complete = self.done();
        Ok(inc)
    }

    /// The switchover. Atomic with respect to guest I/O (runs on the VM
    /// worker); the runner flushed the driver first, so the write logs
    /// hold every last byte.
    fn finalize(&mut self, chain: &mut Chain) -> Result<()> {
        // final drain: one refill suffices (copying reads the sources,
        // never writes them), but loop defensively until dry
        loop {
            if self.pending.is_empty() && self.refill_pending() == 0 {
                break;
            }
            while let Some(ext) = self.pending.pop_front() {
                let mut scratch = Increment::default();
                self.step_extent(ext, &mut scratch)?;
            }
        }
        // every target byte durable BEFORE the commit record (rule 2);
        // length must match in both directions — a source that shrank
        // (repair-style discard, surfaced as DIRTY_ALL by the watch)
        // must not leave a stale tail on the target
        for f in &self.files {
            let src_len = f.src.len();
            if f.dst.len() > src_len {
                f.dst.shrink_to(src_len)?;
            }
            f.dst.truncate_to(src_len)?;
            f.dst.flush()?;
        }
        // Prevalidate the switched-over chain BEFORE the commit record:
        // opening the target copies is the only fallible part of the
        // switchover, and it must fail while rollback is still legal —
        // after the commit the target is authoritative and nothing may
        // tear it down. Moved files open from the target, unmoved ones
        // through the (still source-pointing) namespace.
        let mut switched: Vec<Arc<crate::qcow::Image>> =
            Vec::with_capacity(chain.len());
        for img in chain.images() {
            let name = img.name.as_str();
            let backend = if self.files.iter().any(|f| f.name == name) {
                self.target.open_file(name)?
            } else {
                self.nodes.open_file(name)?
            };
            switched.push(Arc::new(crate::qcow::Image::open(
                name,
                backend,
                self.data_mode,
            )?));
        }
        // lint: durable-before(switchover)
        self.journal.commit()?;
        // THE switchover point: from here the target is authoritative —
        // exactly like crash recovery would rule — so nothing below may
        // roll it back (`Drop` must not tear the target down), and
        // nothing below can fail (the namespace flip and the in-memory
        // bookkeeping are infallible; the chain images were prevalidated
        // above)
        self.committed = true;
        // the in-memory switchover the journal just made durable; the
        // landed bytes count as pressure again now that they are the
        // authoritative copy (the capacity reservation covered them
        // during the copy and is released when the job is reaped)
        let names: Vec<String> = self.files.iter().map(|f| f.name.clone()).collect();
        // lint: index-flip(switchover)
        self.nodes.commit_migration(&names, &self.target.name)?;
        for f in &self.files {
            self.target.uncondemn(&f.name);
        }
        // superseded source copies: condemned replicas for the next GC
        // sweep — never double-referenced, the name's refcounts follow
        // the flipped index
        for f in &self.files {
            self.gc
                .condemn_replica(&f.src_node.name, &f.name, &self.vm);
            f.src_node.unwatch(&f.name);
        }
        // rebind the chain to the prevalidated target-bound images so
        // the caller's post-finalize reopen builds caches over them
        chain.replace_images(switched);
        Ok(())
    }
}

impl Drop for MirrorJob {
    fn drop(&mut self) {
        if self.committed {
            return;
        }
        // cancelled or failed before the commit record: the source stays
        // authoritative — tear down the partial target copies and the
        // journal (recovery's rollback, minus the crash). Best-effort: on
        // a dead (power-cut) node the deletes fail and recovery resolves
        // the leftovers from the journal instead.
        for f in &self.files {
            f.src_node.unwatch(&f.name);
            let _ = self.target.delete_file(&f.name);
        }
        let _ = self
            .target
            .delete_file(&MigrationJournal::journal_name(&self.vm));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::qcow::entry::L2Entry;
    use crate::qcow::layout::{Geometry, FEATURE_BFI};
    use crate::qcow::{qcheck, snapshot, Image};
    use crate::storage::store::FileStore;

    fn two_nodes() -> (Arc<VirtClock>, Arc<NodeSet>, Arc<GcRegistry>) {
        let clock = VirtClock::new();
        let nodes = Arc::new(
            NodeSet::new(vec![
                StorageNode::new("node-0", clock.clone(), CostModel::default()),
                StorageNode::new("node-1", clock.clone(), CostModel::default()),
            ])
            .unwrap(),
        );
        let gc = Arc::new(GcRegistry::new(Arc::clone(&nodes)));
        (clock, nodes, gc)
    }

    fn build_chain(nodes: &Arc<NodeSet>, depth: usize) -> Chain {
        let store = nodes.pinned("node-0").unwrap();
        let b = store.create_file("img-0").unwrap();
        let img = Image::create(
            "img-0",
            b,
            Geometry::new(12, 256 << 10).unwrap(),
            FEATURE_BFI,
            0,
            None,
            DataMode::Real,
        )
        .unwrap();
        let mut chain = Chain::new(Arc::new(img)).unwrap();
        for i in 0..depth {
            let img = chain.active();
            let off = img.alloc_data_cluster().unwrap();
            img.write_data(off, 0, &[i as u8 + 1; 64]).unwrap();
            img.set_l2_entry(i as u64, L2Entry::local(off, Some(img.chain_index())))
                .unwrap();
            snapshot::snapshot_sqemu(&mut chain, &store, &format!("img-{}", i + 1))
                .unwrap();
        }
        chain
    }

    fn run_to_done(job: &mut MirrorJob, chain: &mut Chain) {
        let mut inc = Increment::default();
        while !inc.complete {
            inc = job.run_increment(chain, 7).unwrap();
            assert!(inc.processed <= 7, "budget respected");
        }
    }

    #[test]
    fn mirrors_quiet_chain_and_switches_over() {
        let (_c, nodes, gc) = two_nodes();
        let mut chain = build_chain(&nodes, 3);
        gc.sync_chain("vm", chain.file_names());
        let mut job = MirrorJob::new(
            &chain,
            Arc::clone(&nodes),
            Arc::clone(&gc),
            "node-1",
            "vm",
        )
        .unwrap();
        assert_eq!(job.moved_files().len(), 4);
        run_to_done(&mut job, &mut chain);
        job.finalize(&mut chain).unwrap();
        for i in 0..4 {
            assert_eq!(
                nodes.locate(&format!("img-{i}")).unwrap(),
                "node-1",
                "index flipped"
            );
            assert!(
                gc.is_replica_condemned("node-0", &format!("img-{i}")),
                "source copy condemned"
            );
        }
        // the chain now reads through node-1, bit-identically
        assert!(qcheck::check_chain(&chain).unwrap().is_clean());
        for i in 0..3u64 {
            let (bfi, off) = chain.resolve_walk(i).unwrap().unwrap();
            let mut buf = [0u8; 8];
            chain.get(bfi).unwrap().read_data(off, 0, &mut buf).unwrap();
            assert_eq!(buf, [i as u8 + 1; 8]);
        }
        // sweeping the replicas empties the source node
        while gc.sweep_one().is_some() {}
        let n0 = nodes.node_named("node-0").unwrap();
        assert!(n0.file_names().is_empty(), "{:?}", n0.file_names());
        // journal cleanup now finds nothing lingering
        assert_eq!(super::super::cleanup_journals(nodes.as_ref()), 1);
        let n1 = nodes.node_named("node-1").unwrap();
        assert_eq!(n1.file_names().len(), 4, "{:?}", n1.file_names());
    }

    #[test]
    fn writes_during_mirror_are_remirrored() {
        let (_c, nodes, gc) = two_nodes();
        let mut chain = build_chain(&nodes, 2);
        gc.sync_chain("vm", chain.file_names());
        let mut job =
            MirrorJob::new(&chain, Arc::clone(&nodes), Arc::clone(&gc), "node-1", "vm")
                .unwrap();
        // a couple of increments into the bulk copy, the guest dirties a
        // cluster it already copied
        job.run_increment(&mut chain, 2).unwrap();
        let active = Arc::clone(chain.active());
        let off = active.alloc_data_cluster().unwrap();
        active.write_data(off, 0, &[0xEE; 128]).unwrap();
        active
            .set_l2_entry(0, L2Entry::local(off, Some(active.chain_index())))
            .unwrap();
        run_to_done(&mut job, &mut chain);
        job.finalize(&mut chain).unwrap();
        let (bfi, o) = chain.resolve_walk(0).unwrap().unwrap();
        let mut buf = [0u8; 16];
        chain.get(bfi).unwrap().read_data(o, 0, &mut buf).unwrap();
        assert_eq!(buf, [0xEE; 16], "late write survived the move");
        assert!(qcheck::check_chain(&chain).unwrap().is_clean());
    }

    #[test]
    fn cancel_tears_down_target_copies_and_journal() {
        let (_c, nodes, gc) = two_nodes();
        let mut chain = build_chain(&nodes, 2);
        {
            let mut job = MirrorJob::new(
                &chain,
                Arc::clone(&nodes),
                Arc::clone(&gc),
                "node-1",
                "vm",
            )
            .unwrap();
            job.run_increment(&mut chain, 3).unwrap();
            // dropped without finalize: the cancel path
        }
        let n1 = nodes.node_named("node-1").unwrap();
        assert!(n1.file_names().is_empty(), "{:?}", n1.file_names());
        for i in 0..3 {
            assert_eq!(nodes.locate(&format!("img-{i}")).unwrap(), "node-0");
        }
        // and the sources are no longer watched
        let n0 = nodes.node_named("node-0").unwrap();
        let log = n0.watch("img-0").unwrap();
        n0.unwatch("img-0");
        assert!(!log.is_active());
    }

    #[test]
    fn refuses_a_noop_migration() {
        let (_c, nodes, gc) = two_nodes();
        let chain = build_chain(&nodes, 1);
        assert!(MirrorJob::new(&chain, Arc::clone(&nodes), gc, "node-0", "vm").is_err());
        assert!(
            MirrorJob::new(&chain, nodes, Arc::new(GcRegistry::new(two_nodes().1)), "node-9", "vm")
                .is_err()
        );
    }
}
