//! Live chain migration & fleet rebalancing: data placement as a
//! continuously corrected decision.
//!
//! Until this subsystem, a chain was pinned forever to whatever node
//! [`NodeSet`]'s create-time placement chose — the only relief valve was
//! GC. The paper's fleet characterization (§3) shows why that rots:
//! chains grow to ~1000 files, thin provisioning makes node usage
//! diverge, and shared bases pin capacity wherever history put it.
//! Production block stores treat placement as a managed quantity
//! (cf. FlexBSO's mobility argument); this module is that manager:
//!
//! * [`MirrorJob`] — a [`crate::blockjob::BlockJob`] that copies every
//!   file of a VM's chain to a target node while the guest keeps
//!   writing: bulk copy, dirty-interval convergence via the
//!   [`crate::storage::watch`] write intercept, then an atomic
//!   switchover (journal commit → index flip → source copies condemned
//!   as GC replicas → chain rebound to the target).
//! * [`journal`] — the `.migrate.<vm>` durable record on the recipient
//!   that makes the whole dance crash-safe: recovery resolves every
//!   interrupted migration to exactly one authoritative copy
//!   ([`recover_migrations`]), source- or target-authoritative depending
//!   on whether the commit record became durable.
//! * [`rebalance`] — the planner that reads per-node pressure and plans
//!   donor→recipient chain moves under an imbalance threshold;
//!   [`crate::coordinator::Coordinator::rebalance`] executes the plan.
//!
//! Capacity integration: the recipient `reserve`s the chain's bytes for
//! the whole copy (placement and `would_overflow` count reservations),
//! and the superseded source copies drop out of pressure the moment they
//! are condemned — `benches/fig23_migration.rs` plots both the guest's
//! p99 during a migration and the fleet's max/min pressure ratio with
//! and without the rebalancer.
//!
//! [`NodeSet`]: crate::coordinator::placement::NodeSet

pub mod journal;
pub mod mirror;
pub mod rebalance;

pub use journal::{MigrationJournal, JOURNAL_PREFIX};
pub use mirror::MirrorJob;
pub use rebalance::{plan, NodePressure, PlannedMove, RebalancePlan, VmFootprint};

use crate::coordinator::placement::NodeSet;

/// Outcome of the recovery pass over interrupted migrations.
#[derive(Clone, Debug, Default)]
pub struct MigrationRecovery {
    /// Journals found committed: the target copies were made
    /// authoritative and the superseded source copies deleted.
    pub committed: u64,
    /// Journals found uncommitted: the partial target copies were rolled
    /// back, leaving the source authoritative.
    pub rolled_back: u64,
    /// Non-fatal oddities (unreadable journals, missing nodes).
    pub errors: Vec<String>,
}

/// Resolve every migration journal on `nodes` so each file name has
/// exactly one authoritative copy. Run at recovery time, BEFORE the
/// name→node index is rebuilt and before any image is opened:
///
/// * `committed` journal → the switchover happened: delete the listed
///   files from their *source* nodes (superseded copies), then the
///   journal;
/// * uncommitted journal → the switchover never happened: delete the
///   listed files from the *target* node (partial copies), then the
///   journal.
///
/// A journal that does not parse to a durable `begin` record covers
/// nothing (the ordering rules put the begin flush before the first
/// target create) and is simply deleted.
pub fn recover_migrations(nodes: &NodeSet) -> MigrationRecovery {
    let mut report = MigrationRecovery::default();
    for target in nodes.nodes() {
        let mut journals: Vec<String> = target
            .file_names()
            .into_iter()
            .filter(|n| n.starts_with(JOURNAL_PREFIX))
            .collect();
        journals.sort();
        for jname in journals {
            resolve_journal(nodes, target, &jname, &mut report);
        }
    }
    report
}

/// Resolve one journal on `target` (see [`recover_migrations`] for the
/// rules). No-op if the journal does not exist.
fn resolve_journal(
    nodes: &NodeSet,
    target: &std::sync::Arc<crate::storage::node::StorageNode>,
    jname: &str,
    report: &mut MigrationRecovery,
) {
    // rule 4: the journal may only be deleted once every
    // superseded/partial copy it covers is gone — if any survives, the
    // journal stays behind as the arbiter for the next recovery pass
    let mut cleared = true;
    match journal::read_journal(target, jname) {
        // torn before the begin flush: covers nothing (no target copy
        // can predate it) — expected under a crash at the journal
        // create, just drop it
        None => {
            if target.open_file(jname).is_err() {
                return; // never existed at all
            }
        }
        Some(state) if state.committed => {
            for (file, src_name) in &state.moves {
                let Some(src) = nodes.node_named(src_name) else {
                    report.errors.push(format!(
                        "{jname}: source node '{src_name}' unknown"
                    ));
                    cleared = false;
                    continue;
                };
                if src.name == target.name || src.open_file(file).is_err() {
                    continue; // nothing superseded left behind
                }
                if target.open_file(file).is_err() {
                    // committed yet the target copy is missing:
                    // corrupted state — keep both the source copy
                    // and the journal, surface it
                    report.errors.push(format!(
                        "{jname}: committed but '{file}' absent on \
                         target '{}'",
                        target.name
                    ));
                    cleared = false;
                    continue;
                }
                if src.delete_file(file).is_err() {
                    cleared = false;
                }
            }
            report.committed += 1;
        }
        Some(state) => {
            for (file, _) in &state.moves {
                if target.open_file(file).is_ok()
                    && target.delete_file(file).is_err()
                {
                    cleared = false;
                }
            }
            report.rolled_back += 1;
        }
    }
    if cleared {
        let _ = target.delete_file(jname);
    }
}

/// Targeted migration recovery for ONE vm against a KNOWN target node —
/// the O(active leases) replay path. The durable control log records
/// which VM was migrating where, so recovery probes exactly one journal
/// name on exactly one node instead of listing every file of every node
/// the way [`recover_migrations`] must.
pub fn recover_migrations_for(
    nodes: &NodeSet,
    vm: &str,
    target_name: &str,
) -> MigrationRecovery {
    let mut report = MigrationRecovery::default();
    let Some(target) = nodes.node_named(target_name) else {
        report
            .errors
            .push(format!("migration target node '{target_name}' unknown"));
        return report;
    };
    let jname = MigrationJournal::journal_name(vm);
    resolve_journal(nodes, &target, &jname, &mut report);
    report
}

/// Delete committed journals whose superseded source copies are all
/// gone (the live-path cleanup: a journal must outlive the replicas it
/// covers, so [`crate::coordinator::Coordinator::run_gc`] calls this
/// after the sweep). Returns the number of journals removed.
pub fn cleanup_journals(nodes: &NodeSet) -> u64 {
    let mut cleaned = 0u64;
    for target in nodes.nodes() {
        for jname in target
            .file_names()
            .into_iter()
            .filter(|n| n.starts_with(JOURNAL_PREFIX))
        {
            let Some(state) = journal::read_journal(target, &jname) else {
                continue;
            };
            if !state.committed {
                continue; // an in-flight migration still owns it
            }
            let lingering = state.moves.iter().any(|(file, src_name)| {
                nodes
                    .node_named(src_name)
                    .map_or(false, |src| {
                        src.name != target.name && src.open_file(file).is_ok()
                    })
            });
            if !lingering && target.delete_file(&jname).is_ok() {
                cleaned += 1;
            }
        }
    }
    cleaned
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::storage::node::StorageNode;
    use std::sync::Arc;

    fn fleet() -> Arc<NodeSet> {
        let clock = VirtClock::new();
        Arc::new(
            NodeSet::new(vec![
                StorageNode::new("node-0", clock.clone(), CostModel::default()),
                StorageNode::new("node-1", clock.clone(), CostModel::default()),
            ])
            .unwrap(),
        )
    }

    #[test]
    fn committed_journal_resolves_target_authoritative() {
        let nodes = fleet();
        let (n0, n1) = (nodes.node_named("node-0").unwrap(), nodes.node_named("node-1").unwrap());
        n0.create_file("img").unwrap().write_at(b"old", 0).unwrap();
        let mut j = MigrationJournal::create(
            &n1,
            "vm",
            &[("img".to_string(), "node-0".to_string())],
        )
        .unwrap();
        n1.create_file("img").unwrap().write_at(b"new", 0).unwrap();
        j.commit().unwrap();
        let r = recover_migrations(nodes.as_ref());
        assert_eq!((r.committed, r.rolled_back), (1, 0));
        assert!(n0.open_file("img").is_err(), "superseded source copy gone");
        let mut buf = [0u8; 3];
        n1.open_file("img").unwrap().read_at(&mut buf, 0).unwrap();
        assert_eq!(&buf, b"new");
        assert!(n1.file_names().iter().all(|f| !f.starts_with(JOURNAL_PREFIX)));
    }

    #[test]
    fn uncommitted_journal_rolls_back_partial_copies() {
        let nodes = fleet();
        let (n0, n1) = (nodes.node_named("node-0").unwrap(), nodes.node_named("node-1").unwrap());
        n0.create_file("img").unwrap().write_at(b"old", 0).unwrap();
        let _j = MigrationJournal::create(
            &n1,
            "vm",
            &[("img".to_string(), "node-0".to_string())],
        )
        .unwrap();
        n1.create_file("img").unwrap().write_at(b"par", 0).unwrap();
        let r = recover_migrations(nodes.as_ref());
        assert_eq!((r.committed, r.rolled_back), (0, 1));
        assert!(n1.open_file("img").is_err(), "partial target copy gone");
        let mut buf = [0u8; 3];
        n0.open_file("img").unwrap().read_at(&mut buf, 0).unwrap();
        assert_eq!(&buf, b"old", "source stays authoritative");
        assert!(n1.file_names().is_empty());
    }

    #[test]
    fn recovery_keeps_the_journal_when_a_source_copy_cannot_be_cleared() {
        let nodes = fleet();
        let (n0, n1) = (nodes.node_named("node-0").unwrap(), nodes.node_named("node-1").unwrap());
        n0.create_file("img").unwrap().write_at(b"old", 0).unwrap();
        // journal names a source node this NodeSet does not know: the
        // superseded copy cannot be cleared, so the journal must stay
        // behind as the arbiter (rule 4)
        let mut j = MigrationJournal::create(
            &n1,
            "vm",
            &[("img".to_string(), "node-gone".to_string())],
        )
        .unwrap();
        n1.create_file("img").unwrap().write_at(b"new", 0).unwrap();
        j.commit().unwrap();
        let r = recover_migrations(nodes.as_ref());
        assert_eq!(r.committed, 1);
        assert!(!r.errors.is_empty());
        assert!(
            n1.open_file(&MigrationJournal::journal_name("vm")).is_ok(),
            "journal deleted despite an uncleared source copy"
        );
    }

    #[test]
    fn targeted_recovery_probes_one_journal_without_listing() {
        let nodes = fleet();
        let (n0, n1) = (nodes.node_named("node-0").unwrap(), nodes.node_named("node-1").unwrap());
        n0.create_file("img").unwrap().write_at(b"old", 0).unwrap();
        let mut j = MigrationJournal::create(
            &n1,
            "vm",
            &[("img".to_string(), "node-0".to_string())],
        )
        .unwrap();
        n1.create_file("img").unwrap().write_at(b"new", 0).unwrap();
        j.commit().unwrap();
        let lists: u64 = nodes.nodes().iter().map(|n| n.list_ops()).sum();
        let r = recover_migrations_for(nodes.as_ref(), "vm", "node-1");
        assert_eq!((r.committed, r.rolled_back), (1, 0));
        assert!(n0.open_file("img").is_err(), "superseded source copy gone");
        assert!(
            n1.open_file(&MigrationJournal::journal_name("vm")).is_err(),
            "resolved journal removed"
        );
        let after: u64 = nodes.nodes().iter().map(|n| n.list_ops()).sum();
        assert_eq!(after, lists, "targeted recovery never lists a node");
        // a vm that never migrated: clean no-op either way
        let r2 = recover_migrations_for(nodes.as_ref(), "ghost", "node-1");
        assert_eq!((r2.committed, r2.rolled_back), (0, 0));
        assert!(r2.errors.is_empty());
        // an unknown target is reported, not panicked on
        let r3 = recover_migrations_for(nodes.as_ref(), "vm", "node-9");
        assert!(!r3.errors.is_empty());
    }

    #[test]
    fn cleanup_keeps_journals_with_lingering_sources() {
        let nodes = fleet();
        let (n0, n1) = (nodes.node_named("node-0").unwrap(), nodes.node_named("node-1").unwrap());
        n0.create_file("img").unwrap().write_at(b"old", 0).unwrap();
        let mut j = MigrationJournal::create(
            &n1,
            "vm",
            &[("img".to_string(), "node-0".to_string())],
        )
        .unwrap();
        n1.create_file("img").unwrap().write_at(b"new", 0).unwrap();
        j.commit().unwrap();
        assert_eq!(cleanup_journals(nodes.as_ref()), 0, "source replica lingers");
        n0.delete_file("img").unwrap();
        assert_eq!(cleanup_journals(nodes.as_ref()), 1);
        assert!(n1
            .file_names()
            .iter()
            .all(|f| !f.starts_with(JOURNAL_PREFIX)));
    }
}
