//! The fleet rebalancer: turn per-node pressure skew into a plan of
//! chain migrations.
//!
//! The paper's fleet characterization (§3) shows capacity skew is
//! endemic: chains grow unevenly and thin provisioning makes node usage
//! diverge over time. The planner is deliberately pure — it takes node
//! pressures and per-VM chain footprints and returns moves — so it can
//! be unit-tested and dry-run; [`crate::coordinator::Coordinator::rebalance`]
//! feeds it live stats and drives the moves through `migrate_vm` (one at
//! a time, each under the standard JobScheduler admission).

/// One node's committed capacity as the planner sees it.
#[derive(Clone, Debug)]
pub struct NodePressure {
    pub name: String,
    /// pressure + migration reservations (what placement counts).
    pub pressure: u64,
    pub capacity: u64,
}

/// One VM's chain placement.
#[derive(Clone, Debug)]
pub struct VmFootprint {
    pub vm: String,
    /// Node holding the bulk of the chain (the donor a move relieves).
    pub node: String,
    /// Stored bytes resident on that node — what actually LEAVES the
    /// donor when the chain moves.
    pub bytes: u64,
    /// Stored bytes of the whole chain — what actually LANDS on the
    /// recipient (a scattered chain moves more onto the recipient than
    /// it takes off any single donor).
    pub total: u64,
}

/// One planned migration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlannedMove {
    pub vm: String,
    pub from: String,
    pub to: String,
    /// Whole-chain bytes the recipient must absorb (an upper bound:
    /// files already resident on the recipient are skipped by the
    /// mirror).
    pub bytes: u64,
}

/// A rebalance plan plus the imbalance it starts from and projects to.
#[derive(Clone, Debug, Default)]
pub struct RebalancePlan {
    pub moves: Vec<PlannedMove>,
    /// max/min committed-pressure ratio before any move.
    pub ratio_before: f64,
    /// Projected ratio once every planned move lands.
    pub ratio_projected: f64,
}

/// max/min pressure ratio of a fleet (the +1 guards empty nodes: an
/// empty fleet is perfectly balanced, not infinitely skewed).
pub fn pressure_ratio(pressures: &[u64]) -> f64 {
    let max = pressures.iter().copied().max().unwrap_or(0);
    let min = pressures.iter().copied().min().unwrap_or(0);
    (max + 1) as f64 / (min + 1) as f64
}

/// Greedy donor→recipient planning: while the fleet's max/min pressure
/// ratio exceeds `threshold`, move the largest chain off the most loaded
/// node that (a) fits the least loaded node's capacity and (b) stays
/// within the donor→recipient gap after accounting both sides of the
/// move — a bigger move would leave the recipient above the donor,
/// mirroring the skew instead of shrinking it (and, at chain
/// granularity, oscillating forever). Every accepted move strictly
/// narrows the gap, so the loop terminates; `max_moves` is a backstop.
///
/// Scattered chains are modeled conservatively: the donor is credited
/// only its resident bytes, the recipient is charged the whole chain,
/// and third-party nodes that also lose resident bytes keep their
/// pre-move pressure (over-estimating them is safe — it can only make
/// the planner less aggressive, never overcommit a node).
pub fn plan(
    nodes: &[NodePressure],
    vms: &[VmFootprint],
    threshold: f64,
    max_moves: usize,
) -> RebalancePlan {
    let mut pressure: Vec<u64> = nodes.iter().map(|n| n.pressure).collect();
    // (vm, home node, bytes on home, whole-chain bytes)
    let mut home: Vec<(String, String, u64, u64)> = vms
        .iter()
        .map(|v| (v.vm.clone(), v.node.clone(), v.bytes, v.total))
        .collect();
    let ratio_before = pressure_ratio(&pressure);
    let mut plan = RebalancePlan {
        moves: Vec::new(),
        ratio_before,
        ratio_projected: ratio_before,
    };
    if nodes.len() < 2 {
        return plan;
    }
    for _ in 0..max_moves {
        if pressure_ratio(&pressure) <= threshold {
            break;
        }
        let donor = (0..nodes.len())
            .max_by_key(|&i| pressure[i])
            .expect("non-empty");
        let recipient = (0..nodes.len())
            .min_by_key(|&i| pressure[i])
            .expect("non-empty");
        if donor == recipient {
            break;
        }
        let gap = pressure[donor] - pressure[recipient];
        // Largest-relief chain on the donor that fits the recipient and
        // keeps the recipient at or below the shrunken donor
        // (bytes + total <= gap): every accepted move strictly narrows
        // the gap, never mirrors the skew. For a co-located chain
        // (bytes == total) this is the classic half-gap guard; a
        // scattered chain lands MORE on the recipient (total) than it
        // takes off the donor (bytes), and the guard accounts for that.
        let candidate = home
            .iter()
            .enumerate()
            .filter(|(_, (_, node, bytes, total))| {
                *node == nodes[donor].name
                    && *bytes > 0
                    && bytes.saturating_add(*total) <= gap
                    && pressure[recipient].saturating_add(*total)
                        <= nodes[recipient].capacity
            })
            .max_by_key(|(_, (_, _, bytes, _))| *bytes)
            .map(|(i, _)| i);
        let Some(i) = candidate else { break };
        let (vm, _, bytes, total) = home[i].clone();
        plan.moves.push(PlannedMove {
            vm,
            from: nodes[donor].name.clone(),
            to: nodes[recipient].name.clone(),
            bytes: total,
        });
        pressure[donor] -= bytes;
        pressure[recipient] += total;
        home[i].1 = nodes[recipient].name.clone();
        // after the move the whole chain is co-located on the recipient
        home[i].2 = total;
    }
    plan.ratio_projected = pressure_ratio(&pressure);
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node(name: &str, pressure: u64) -> NodePressure {
        NodePressure { name: name.into(), pressure, capacity: u64::MAX }
    }

    fn vm(vm: &str, node: &str, bytes: u64) -> VmFootprint {
        // co-located chain: donor-resident == whole-chain bytes
        VmFootprint { vm: vm.into(), node: node.into(), bytes, total: bytes }
    }

    #[test]
    fn balanced_fleet_plans_nothing() {
        let p = plan(
            &[node("a", 100), node("b", 110)],
            &[vm("v0", "a", 100), vm("v1", "b", 110)],
            1.5,
            8,
        );
        assert!(p.moves.is_empty());
        assert!(p.ratio_before < 1.5);
    }

    #[test]
    fn skewed_fleet_converges_under_threshold() {
        let nodes = [node("a", 600), node("b", 100), node("c", 100)];
        let vms: Vec<VmFootprint> =
            (0..6).map(|i| vm(&format!("v{i}"), "a", 100)).collect();
        let p = plan(&nodes, &vms, 1.5, 16);
        assert!(p.ratio_before > 4.0);
        assert!(
            p.ratio_projected <= 1.5,
            "projected {} with moves {:?}",
            p.ratio_projected,
            p.moves
        );
        assert!(p.moves.len() >= 2 && p.moves.len() <= 6);
        assert!(p.moves.iter().all(|m| m.from == "a"));
    }

    #[test]
    fn respects_recipient_capacity() {
        let nodes = [
            node("a", 600),
            NodePressure { name: "b".into(), pressure: 0, capacity: 50 },
        ];
        let vms = [vm("v0", "a", 300), vm("v1", "a", 300)];
        let p = plan(&nodes, &vms, 1.5, 8);
        assert!(p.moves.is_empty(), "nothing fits the tiny recipient: {:?}", p.moves);
    }

    #[test]
    fn scattered_chain_charges_recipient_its_whole_size() {
        // v0 keeps 100 of its 300 bytes on the donor: moving it relieves
        // the donor by 100 but lands 300 on the recipient
        let nodes = [node("a", 400), node("b", 0)];
        let vms = [VmFootprint {
            vm: "v0".into(),
            node: "a".into(),
            bytes: 100,
            total: 300,
        }];
        let p = plan(&nodes, &vms, 1.05, 8);
        // bytes + total = 400 <= gap 400: accepted, and the projection
        // uses the asymmetric accounting
        assert_eq!(p.moves.len(), 1);
        assert_eq!(p.moves[0].bytes, 300);
        assert!((p.ratio_projected - 301.0 / 301.0).abs() < 1e-9);
    }

    #[test]
    fn does_not_overshoot_the_gap() {
        // one huge chain cannot be moved without inverting the skew
        let nodes = [node("a", 1000), node("b", 900)];
        let vms = [vm("v0", "a", 1000)];
        let p = plan(&nodes, &vms, 1.05, 8);
        assert!(p.moves.is_empty());
    }

    #[test]
    fn moved_vm_is_not_moved_twice_from_the_same_node() {
        let nodes = [node("a", 400), node("b", 0)];
        let vms = [vm("v0", "a", 200), vm("v1", "a", 200)];
        let p = plan(&nodes, &vms, 1.1, 8);
        // moving one 200-byte chain equalizes; a second move would just
        // swing the skew back
        assert_eq!(p.moves.len(), 1);
        assert!((p.ratio_projected - 1.0).abs() < 0.02);
    }
}
