//! Snapshot chains: an ordered list of images, base (index 0) to active
//! volume (last). "The virtual disk of a VM can thus be seen as a chain
//! linking multiple backing files" (§1).

use super::image::{DataMode, Image};
use crate::storage::store::FileStore;
use anyhow::{bail, Result};
use std::sync::Arc;

/// An open chain. Index 0 is the base image; the last image is the active
/// volume receiving all writes.
pub struct Chain {
    images: Vec<Arc<Image>>,
}

impl Chain {
    /// Start a chain from a single base image.
    pub fn new(base: Arc<Image>) -> Result<Chain> {
        if base.chain_index() != 0 {
            bail!("base image has chain_index {}", base.chain_index());
        }
        Ok(Chain { images: vec![base] })
    }

    /// Open a chain by its active volume's file name, following backing
    /// names across the storage node ("Qemu initializes a linked list
    /// corresponding to the snapshot chain at VM startup", §2).
    pub fn open(node: &dyn FileStore, active_name: &str, data_mode: DataMode) -> Result<Chain> {
        let mut rev = Vec::new();
        let mut cursor = Some(active_name.to_string());
        while let Some(name) = cursor {
            let backend = node.open_file(&name)?;
            let img = Image::open(&name, backend, data_mode)?;
            cursor = img.backing_name();
            rev.push(Arc::new(img));
            if rev.len() > u16::MAX as usize {
                bail!("backing chain loop detected via '{active_name}'");
            }
        }
        rev.reverse();
        // validate chain indices are consistent
        for (i, img) in rev.iter().enumerate() {
            if img.chain_index() as usize != i {
                bail!(
                    "chain index mismatch: file '{}' says {} but sits at {}",
                    img.name,
                    img.chain_index(),
                    i
                );
            }
        }
        Ok(Chain { images: rev })
    }

    /// Append a freshly created active volume.
    pub fn push(&mut self, img: Arc<Image>) -> Result<()> {
        if img.chain_index() as usize != self.images.len() {
            bail!(
                "new volume chain_index {} != expected {}",
                img.chain_index(),
                self.images.len()
            );
        }
        if img.backing_name().as_deref() != Some(self.active().name.as_str()) {
            bail!("new volume does not back onto the current active volume");
        }
        self.images.push(img);
        Ok(())
    }

    /// Replace the whole image list (streaming/merge rebuilds).
    pub fn replace_images(&mut self, images: Vec<Arc<Image>>) {
        self.images = images;
    }

    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// The active volume (all writes land here).
    pub fn active(&self) -> &Arc<Image> {
        self.images.last().expect("chain is never empty")
    }

    pub fn get(&self, idx: u16) -> Option<&Arc<Image>> {
        self.images.get(idx as usize)
    }

    pub fn images(&self) -> &[Arc<Image>] {
        &self.images
    }

    /// Resolve a virtual cluster by walking the chain (uncached reference
    /// path — the semantic ground truth both drivers must agree with).
    /// Stamps are authoritative: a stamped remote entry resolves directly
    /// to its owner. This matters for dedup shares, which reference a
    /// *different* virtual cluster's storage in the owner file — walking
    /// past them to the owner's own table would resolve the wrong data.
    pub fn resolve_walk(&self, vcluster: u64) -> Result<Option<(u16, u64)>> {
        for idx in (0..self.images.len()).rev() {
            let e = self.images[idx].l2_entry(vcluster)?;
            if let Some((bfi, off)) = e.sqemu_view(idx as u16) {
                return Ok(Some((bfi, off)));
            }
        }
        Ok(None)
    }

    /// Total physical bytes across all files (Fig 19a).
    pub fn total_file_bytes(&self) -> u64 {
        self.images.iter().map(|i| i.file_len()).sum()
    }

    /// File names, base first, active last (the GC registry's unit of
    /// reference).
    pub fn file_names(&self) -> Vec<String> {
        self.images.iter().map(|i| i.name.clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::qcow::entry::L2Entry;
    use crate::qcow::layout::Geometry;
    use crate::qcow::snapshot;
    use crate::storage::node::StorageNode;

    fn node() -> Arc<StorageNode> {
        StorageNode::new("s", VirtClock::new(), CostModel::default())
    }

    fn base_on(node: &crate::storage::node::StorageNode) -> Arc<Image> {
        let backend = node.create_file("img-0").unwrap();
        Arc::new(
            Image::create(
                "img-0",
                backend,
                Geometry::new(16, 64 << 20).unwrap(),
                0,
                0,
                None,
                DataMode::Real,
            )
            .unwrap(),
        )
    }

    #[test]
    fn open_follows_backing_names() {
        let node = node();
        let mut chain = Chain::new(base_on(&node)).unwrap();
        snapshot::snapshot_vanilla(&mut chain, &node, "img-1").unwrap();
        snapshot::snapshot_vanilla(&mut chain, &node, "img-2").unwrap();
        let reopened = Chain::open(&node, "img-2", DataMode::Real).unwrap();
        assert_eq!(reopened.len(), 3);
        assert_eq!(reopened.get(0).unwrap().name, "img-0");
        assert_eq!(reopened.active().name, "img-2");
    }

    #[test]
    fn resolve_walk_prefers_newest() {
        let node = node();
        let mut chain = Chain::new(base_on(&node)).unwrap();
        let base_off = chain.active().alloc_data_cluster().unwrap();
        chain
            .active()
            .set_l2_entry(9, L2Entry::local(base_off, None))
            .unwrap();
        snapshot::snapshot_vanilla(&mut chain, &node, "img-1").unwrap();
        // overwritten in the new active volume
        let new_off = chain.active().alloc_data_cluster().unwrap();
        chain
            .active()
            .set_l2_entry(9, L2Entry::local(new_off, None))
            .unwrap();
        assert_eq!(chain.resolve_walk(9).unwrap(), Some((1, new_off)));
        assert_eq!(chain.resolve_walk(10).unwrap(), None);
    }

    #[test]
    fn push_validates_linkage() {
        let node = node();
        let mut chain = Chain::new(base_on(&node)).unwrap();
        let b = node.create_file("stray").unwrap();
        let stray = Arc::new(
            Image::create(
                "stray",
                b,
                *chain.active().geom(),
                0,
                5, // wrong index
                Some("img-0"),
                DataMode::Real,
            )
            .unwrap(),
        );
        assert!(chain.push(stray).is_err());
    }
}
