//! L2 table entry encoding.
//!
//! 64-bit entry layout (the §5.2 extension lives in formerly reserved
//! bits, preserving backward compatibility):
//!
//! ```text
//! bit  63       ALLOCATED — cluster data lives in *this* file (vanilla
//!               semantics; the only bit a vanilla driver interprets)
//! bits 62..47   bfi_plus_1 — 16-bit backing_file_index + 1 of the file
//!               holding the latest version of the cluster; 0 = unstamped
//!               (vanilla image). The paper uses 16 bits (§5.2).
//! bits 46..0    host byte offset of the data cluster inside the owning
//!               file (cluster aligned)
//! ```
//!
//! Because host offsets are cluster aligned and the minimum cluster size
//! is 512 B, the low 9 bits of the offset field are always zero for a
//! plain data cluster. They carry a *cluster descriptor* (the qcow2 v3
//! `OFLAG_ZERO` / `OFLAG_COMPRESSED` analogue):
//!
//! ```text
//! bit  0        OFLAG_ZERO — the cluster reads as zeros; no host
//!               cluster is allocated (the rest of the offset field is 0)
//! bit  1        OFLAG_COMPRESSED — the offset (minus descriptor bits)
//!               points at a sector-aligned compressed payload packed
//!               into a shared host cluster
//! bits 8..2     compressed payload size, in units of cluster_size/128,
//!               stored as units-1 (1..=128 units)
//! ```
//!
//! The descriptor travels *inside* the offset word: [`L2Entry::host_offset`]
//! and the `(bfi, offset)` resolution tuples threaded through caches,
//! coalescers and snapshot copies pass it through opaquely (a plain
//! cluster has descriptor 0, so nothing changes for existing entries).
//! Only I/O endpoints decode it, via [`decode_offset`] /
//! [`L2Entry::data_offset`].

/// The paper's unallocated sentinel on the kernel side is -1; on disk an
/// all-zero entry means "no information in this file".
pub const BFI_BITS: u32 = 16;
const BFI_SHIFT: u32 = 47;
const BFI_MASK: u64 = ((1 << BFI_BITS) - 1) << BFI_SHIFT;
const ALLOCATED: u64 = 1 << 63;
const OFFSET_MASK: u64 = (1 << BFI_SHIFT) - 1;

/// Width of the per-cluster descriptor in the low bits of the offset
/// field (equals the minimum cluster_bits, so the bits are always free).
pub const DESC_BITS: u32 = 9;
/// Mask of the descriptor bits inside the offset word.
pub const DESC_MASK: u64 = (1 << DESC_BITS) - 1;
/// Cluster reads as zeros; no host cluster backs it.
pub const OFLAG_ZERO: u64 = 1 << 0;
/// Cluster is stored as a compressed sub-cluster payload.
pub const OFLAG_COMPRESSED: u64 = 1 << 1;
const COMP_SIZE_SHIFT: u32 = 2;
const COMP_SIZE_MASK: u64 = 0x7f << COMP_SIZE_SHIFT;

/// Decoded interpretation of an offset word carried in `(bfi, offset)`
/// resolution tuples. Everything between the L2 tables and the device
/// treats the word as opaque; I/O endpoints call [`decode_offset`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClusterLoc {
    /// Plain data cluster at this (cluster-aligned) device offset.
    Data(u64),
    /// Reads as zeros; never touches the device.
    Zero,
    /// Compressed payload at this sector-aligned device offset,
    /// `units * cluster_size / 128` stored bytes.
    Compressed { off: u64, units: u64 },
}

/// Decode the descriptor bits of an offset word (see [`ClusterLoc`]).
pub fn decode_offset(word: u64) -> ClusterLoc {
    let desc = word & DESC_MASK;
    if desc & OFLAG_ZERO != 0 {
        ClusterLoc::Zero
    } else if desc & OFLAG_COMPRESSED != 0 {
        ClusterLoc::Compressed {
            off: word & !DESC_MASK,
            units: ((desc & COMP_SIZE_MASK) >> COMP_SIZE_SHIFT) + 1,
        }
    } else {
        ClusterLoc::Data(word)
    }
}

/// Decoded view of one L2 entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct L2Entry(pub u64);

impl L2Entry {
    pub const ZERO: L2Entry = L2Entry(0);

    /// Entry for a cluster allocated in this file, optionally stamped with
    /// this file's own chain index.
    pub fn local(host_off: u64, own_index: Option<u16>) -> L2Entry {
        debug_assert_eq!(host_off & !OFFSET_MASK, 0, "offset too large");
        let mut v = ALLOCATED | (host_off & OFFSET_MASK);
        if let Some(idx) = own_index {
            v |= ((idx as u64 + 1) << BFI_SHIFT) & BFI_MASK;
        }
        L2Entry(v)
    }

    /// Stamped reference to a cluster owned by backing file `bfi`
    /// (SQEMU snapshot-copy entries, §5.4). Not ALLOCATED: a vanilla
    /// driver must treat it as a hole.
    pub fn remote(host_off: u64, bfi: u16) -> L2Entry {
        debug_assert_eq!(host_off & !OFFSET_MASK, 0, "offset too large");
        L2Entry(((bfi as u64 + 1) << BFI_SHIFT) | (host_off & OFFSET_MASK))
    }

    /// Entry for an all-zero cluster (`OFLAG_ZERO`): present, reads as
    /// zeros, allocates no host cluster. ALLOCATED so it shadows backing
    /// data for both drivers' chain walks.
    pub fn zero_cluster(own_index: Option<u16>) -> L2Entry {
        L2Entry::local(OFLAG_ZERO, own_index)
    }

    /// Entry for a compressed cluster: `payload_units * cluster_size/128`
    /// stored bytes at sector-aligned `data_off` inside this file.
    pub fn compressed(data_off: u64, payload_units: u64, own_index: Option<u16>) -> L2Entry {
        debug_assert_eq!(data_off & DESC_MASK, 0, "payload not sector aligned");
        debug_assert!((1..=128).contains(&payload_units), "bad payload size");
        let desc = OFLAG_COMPRESSED | ((payload_units - 1) << COMP_SIZE_SHIFT);
        L2Entry::local(data_off | desc, own_index)
    }

    /// Cluster data present in this very file?
    pub fn is_allocated_here(&self) -> bool {
        self.0 & ALLOCATED != 0
    }

    /// Completely empty entry (no local data, no stamp)?
    pub fn is_zero(&self) -> bool {
        self.0 == 0
    }

    /// The stamped backing_file_index, if any.
    pub fn bfi(&self) -> Option<u16> {
        let raw = (self.0 & BFI_MASK) >> BFI_SHIFT;
        if raw == 0 {
            None
        } else {
            Some((raw - 1) as u16)
        }
    }

    /// The raw offset word: host byte offset of the data cluster in the
    /// owning file, *including* descriptor bits (opaque pass-through —
    /// plain clusters have descriptor 0). Decode at I/O endpoints with
    /// [`decode_offset`] or use [`Self::data_offset`].
    pub fn host_offset(&self) -> u64 {
        self.0 & OFFSET_MASK
    }

    /// Device byte offset with the descriptor bits stripped.
    pub fn data_offset(&self) -> u64 {
        self.0 & OFFSET_MASK & !DESC_MASK
    }

    /// Raw descriptor bits (0 for a plain data cluster).
    pub fn descriptor(&self) -> u64 {
        self.0 & DESC_MASK
    }

    /// Is this a present, `OFLAG_ZERO`-flagged cluster?
    pub fn is_zero_cluster(&self) -> bool {
        self.0 & OFLAG_ZERO != 0
    }

    /// Is this a compressed cluster?
    pub fn is_compressed(&self) -> bool {
        self.0 & OFLAG_COMPRESSED != 0
    }

    /// Decoded location of this entry's data (see [`ClusterLoc`]).
    pub fn loc(&self) -> ClusterLoc {
        decode_offset(self.0 & OFFSET_MASK)
    }

    /// Structurally valid descriptor? Exactly one of: plain (descriptor
    /// 0), a pure zero cluster (`OFLAG_ZERO` alone, offset bits 0), or
    /// compressed (`OFLAG_COMPRESSED` + size). Anything else — e.g. a
    /// garbage misaligned offset whose low bits happen to be set — is
    /// corruption for `qcheck` to flag.
    pub fn descriptor_valid(&self) -> bool {
        let d = self.descriptor();
        if d == 0 {
            true
        } else if d & OFLAG_ZERO != 0 {
            d == OFLAG_ZERO && self.data_offset() == 0
        } else {
            d & OFLAG_COMPRESSED != 0
        }
    }

    /// What a *vanilla* driver sees: allocated-here offset or hole.
    pub fn vanilla_view(&self) -> Option<u64> {
        if self.is_allocated_here() {
            Some(self.host_offset())
        } else {
            None
        }
    }

    /// What the *SQEMU* driver sees: (owning bfi, offset) if the entry is
    /// stamped or locally allocated; None for a true hole.
    ///
    /// `own_index` is the chain index of the file the entry was read from
    /// (used for unstamped-but-allocated vanilla entries).
    pub fn sqemu_view(&self, own_index: u16) -> Option<(u16, u64)> {
        match (self.bfi(), self.is_allocated_here()) {
            (Some(bfi), _) => Some((bfi, self.host_offset())),
            (None, true) => Some((own_index, self.host_offset())),
            (None, false) => None,
        }
    }

    pub fn raw(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_roundtrip() {
        let e = L2Entry::local(7 << 16, Some(12));
        assert!(e.is_allocated_here());
        assert_eq!(e.bfi(), Some(12));
        assert_eq!(e.host_offset(), 7 << 16);
        assert_eq!(e.vanilla_view(), Some(7 << 16));
        assert_eq!(e.sqemu_view(12), Some((12, 7 << 16)));
    }

    #[test]
    fn remote_is_hole_for_vanilla() {
        let e = L2Entry::remote(3 << 16, 4);
        assert!(!e.is_allocated_here());
        assert_eq!(e.vanilla_view(), None); // backward compat (§5.1)
        assert_eq!(e.sqemu_view(9), Some((4, 3 << 16)));
    }

    #[test]
    fn unstamped_local_uses_own_index() {
        let e = L2Entry::local(5 << 16, None);
        assert_eq!(e.bfi(), None);
        assert_eq!(e.sqemu_view(3), Some((3, 5 << 16)));
        assert_eq!(e.vanilla_view(), Some(5 << 16));
    }

    #[test]
    fn zero_is_hole_for_both() {
        let e = L2Entry::ZERO;
        assert!(e.is_zero());
        assert_eq!(e.vanilla_view(), None);
        assert_eq!(e.sqemu_view(0), None);
    }

    #[test]
    fn bfi_16bit_range() {
        // the paper reserves 16 bits for backing_file_index (§5.2)
        let e = L2Entry::remote(1 << 16, u16::MAX - 1);
        assert_eq!(e.bfi(), Some(u16::MAX - 1));
        assert_eq!(e.host_offset(), 1 << 16);
    }

    #[test]
    fn zero_cluster_is_present_but_deviceless() {
        let e = L2Entry::zero_cluster(Some(2));
        assert!(e.is_allocated_here(), "zero entries shadow backing data");
        assert!(e.is_zero_cluster());
        assert!(!e.is_zero(), "present, not a hole");
        assert_eq!(e.bfi(), Some(2));
        assert_eq!(e.data_offset(), 0);
        assert_eq!(e.loc(), ClusterLoc::Zero);
        // the flag survives a snapshot copy (remote re-encoding)
        let copied = L2Entry::remote(e.host_offset(), 2);
        assert_eq!(copied.loc(), ClusterLoc::Zero);
        assert!(copied.is_zero_cluster());
    }

    #[test]
    fn compressed_roundtrip() {
        let e = L2Entry::compressed(5 << 16, 17, Some(3));
        assert!(e.is_allocated_here());
        assert!(e.is_compressed());
        assert!(!e.is_zero_cluster());
        assert_eq!(e.data_offset(), 5 << 16);
        assert_eq!(e.bfi(), Some(3));
        assert_eq!(
            e.loc(),
            ClusterLoc::Compressed { off: 5 << 16, units: 17 }
        );
        // full unit range encodes
        for units in [1u64, 64, 128] {
            let e = L2Entry::compressed(1 << 20, units, None);
            assert_eq!(e.loc(), ClusterLoc::Compressed { off: 1 << 20, units });
        }
    }

    #[test]
    fn descriptor_validity() {
        assert!(L2Entry::local(7 << 16, Some(0)).descriptor_valid());
        assert!(L2Entry::zero_cluster(None).descriptor_valid());
        assert!(L2Entry::compressed(1 << 16, 128, None).descriptor_valid());
        // garbage low bits are corruption, not a descriptor
        assert!(!L2Entry::local((1 << 16) + 5, Some(0)).descriptor_valid());
        assert!(!L2Entry::local((1 << 16) + 4, None).descriptor_valid());
        // zero flag with a nonzero offset is torn garbage
        assert!(!L2Entry::local((1 << 16) | OFLAG_ZERO, None).descriptor_valid());
    }

    #[test]
    fn plain_entries_have_empty_descriptor() {
        let e = L2Entry::local(7 << 16, Some(1));
        assert_eq!(e.descriptor(), 0);
        assert_eq!(e.data_offset(), e.host_offset());
        assert_eq!(e.loc(), ClusterLoc::Data(7 << 16));
        assert_eq!(decode_offset(7 << 16), ClusterLoc::Data(7 << 16));
    }

    #[test]
    fn descriptor_survives_offset_word_passthrough() {
        // caches / coalescers carry host_offset() words opaquely and
        // re-encode them through remote()/local()
        let e = L2Entry::compressed(9 << 16, 100, Some(4));
        let word = e.host_offset();
        let restamped = L2Entry::local(word, Some(4));
        assert_eq!(restamped.loc(), e.loc());
        assert_eq!(decode_offset(word), e.loc());
    }

    #[test]
    fn max_offset_preserved() {
        let off = ((1u64 << 47) - 1) & !0xffff; // max cluster-aligned
        let e = L2Entry::local(off, Some(0));
        assert_eq!(e.host_offset(), off);
        assert_eq!(e.bfi(), Some(0));
        assert!(e.is_allocated_here());
    }
}
