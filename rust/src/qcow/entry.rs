//! L2 table entry encoding.
//!
//! 64-bit entry layout (the §5.2 extension lives in formerly reserved
//! bits, preserving backward compatibility):
//!
//! ```text
//! bit  63       ALLOCATED — cluster data lives in *this* file (vanilla
//!               semantics; the only bit a vanilla driver interprets)
//! bits 62..47   bfi_plus_1 — 16-bit backing_file_index + 1 of the file
//!               holding the latest version of the cluster; 0 = unstamped
//!               (vanilla image). The paper uses 16 bits (§5.2).
//! bits 46..0    host byte offset of the data cluster inside the owning
//!               file (cluster aligned)
//! ```

/// The paper's unallocated sentinel on the kernel side is -1; on disk an
/// all-zero entry means "no information in this file".
pub const BFI_BITS: u32 = 16;
const BFI_SHIFT: u32 = 47;
const BFI_MASK: u64 = ((1 << BFI_BITS) - 1) << BFI_SHIFT;
const ALLOCATED: u64 = 1 << 63;
const OFFSET_MASK: u64 = (1 << BFI_SHIFT) - 1;

/// Decoded view of one L2 entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct L2Entry(pub u64);

impl L2Entry {
    pub const ZERO: L2Entry = L2Entry(0);

    /// Entry for a cluster allocated in this file, optionally stamped with
    /// this file's own chain index.
    pub fn local(host_off: u64, own_index: Option<u16>) -> L2Entry {
        debug_assert_eq!(host_off & !OFFSET_MASK, 0, "offset too large");
        let mut v = ALLOCATED | (host_off & OFFSET_MASK);
        if let Some(idx) = own_index {
            v |= ((idx as u64 + 1) << BFI_SHIFT) & BFI_MASK;
        }
        L2Entry(v)
    }

    /// Stamped reference to a cluster owned by backing file `bfi`
    /// (SQEMU snapshot-copy entries, §5.4). Not ALLOCATED: a vanilla
    /// driver must treat it as a hole.
    pub fn remote(host_off: u64, bfi: u16) -> L2Entry {
        debug_assert_eq!(host_off & !OFFSET_MASK, 0, "offset too large");
        L2Entry(((bfi as u64 + 1) << BFI_SHIFT) | (host_off & OFFSET_MASK))
    }

    /// Cluster data present in this very file?
    pub fn is_allocated_here(&self) -> bool {
        self.0 & ALLOCATED != 0
    }

    /// Completely empty entry (no local data, no stamp)?
    pub fn is_zero(&self) -> bool {
        self.0 == 0
    }

    /// The stamped backing_file_index, if any.
    pub fn bfi(&self) -> Option<u16> {
        let raw = (self.0 & BFI_MASK) >> BFI_SHIFT;
        if raw == 0 {
            None
        } else {
            Some((raw - 1) as u16)
        }
    }

    /// Host byte offset of the data cluster in the owning file.
    pub fn host_offset(&self) -> u64 {
        self.0 & OFFSET_MASK
    }

    /// What a *vanilla* driver sees: allocated-here offset or hole.
    pub fn vanilla_view(&self) -> Option<u64> {
        if self.is_allocated_here() {
            Some(self.host_offset())
        } else {
            None
        }
    }

    /// What the *SQEMU* driver sees: (owning bfi, offset) if the entry is
    /// stamped or locally allocated; None for a true hole.
    ///
    /// `own_index` is the chain index of the file the entry was read from
    /// (used for unstamped-but-allocated vanilla entries).
    pub fn sqemu_view(&self, own_index: u16) -> Option<(u16, u64)> {
        match (self.bfi(), self.is_allocated_here()) {
            (Some(bfi), _) => Some((bfi, self.host_offset())),
            (None, true) => Some((own_index, self.host_offset())),
            (None, false) => None,
        }
    }

    pub fn raw(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_roundtrip() {
        let e = L2Entry::local(7 << 16, Some(12));
        assert!(e.is_allocated_here());
        assert_eq!(e.bfi(), Some(12));
        assert_eq!(e.host_offset(), 7 << 16);
        assert_eq!(e.vanilla_view(), Some(7 << 16));
        assert_eq!(e.sqemu_view(12), Some((12, 7 << 16)));
    }

    #[test]
    fn remote_is_hole_for_vanilla() {
        let e = L2Entry::remote(3 << 16, 4);
        assert!(!e.is_allocated_here());
        assert_eq!(e.vanilla_view(), None); // backward compat (§5.1)
        assert_eq!(e.sqemu_view(9), Some((4, 3 << 16)));
    }

    #[test]
    fn unstamped_local_uses_own_index() {
        let e = L2Entry::local(5 << 16, None);
        assert_eq!(e.bfi(), None);
        assert_eq!(e.sqemu_view(3), Some((3, 5 << 16)));
        assert_eq!(e.vanilla_view(), Some(5 << 16));
    }

    #[test]
    fn zero_is_hole_for_both() {
        let e = L2Entry::ZERO;
        assert!(e.is_zero());
        assert_eq!(e.vanilla_view(), None);
        assert_eq!(e.sqemu_view(0), None);
    }

    #[test]
    fn bfi_16bit_range() {
        // the paper reserves 16 bits for backing_file_index (§5.2)
        let e = L2Entry::remote(1 << 16, u16::MAX - 1);
        assert_eq!(e.bfi(), Some(u16::MAX - 1));
        assert_eq!(e.host_offset(), 1 << 16);
    }

    #[test]
    fn max_offset_preserved() {
        let off = ((1u64 << 47) - 1) & !0xffff; // max cluster-aligned
        let e = L2Entry::local(off, Some(0));
        assert_eq!(e.host_offset(), off);
        assert_eq!(e.bfi(), Some(0));
        assert!(e.is_allocated_here());
    }
}
