//! One virtual-disk image file: header + L1 + L2 tables + refcounts +
//! data clusters, all accessed through a [`Backend`].
//!
//! `Image` is deliberately *driver-free*: it exposes the on-disk structures
//! (L1 lookups, raw L2 slices, cluster allocation, data I/O) and the two
//! drivers in [`crate::vdisk`] implement the vanilla and SQEMU request
//! paths on top. Snapshot creation lives in [`crate::qcow::snapshot`].

use super::entry::{L2Entry, DESC_BITS};
use super::layout::{Geometry, Header, ENTRY_SIZE, FEATURE_BFI, HEADER_SLOT_SIZE};
use super::refcount::Allocator;
use crate::dedup::codec;
use crate::storage::backend::{read_u64, write_u64, BackendRef};
use crate::util::div_ceil;
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Mutex, RwLock};

/// Compressed payloads start on this alignment so their offsets leave
/// the descriptor bits of the L2 offset word free.
const PAYLOAD_ALIGN: u64 = 1 << DESC_BITS;

/// How data clusters are materialized.
///
/// `Real` stores actual bytes (correctness tests, small disks).
/// `Synthetic` charges the I/O time but generates deterministic bytes on
/// read instead of storing them — the substitution that lets the figure
/// benches run paper-scale disks (50 GiB x chain 1000) in host RAM.
/// Metadata (header, L1/L2, refcounts) is always real.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataMode {
    Real,
    Synthetic,
}

/// An open image file.
pub struct Image {
    pub name: String,
    backend: BackendRef,
    geom: Geometry,
    /// Feature flags; mutable because a live stamp job promotes a
    /// vanilla image to the SQEMU format in place ([`Image::set_feature_bfi`]).
    flags: AtomicU32,
    /// Mutable chain linkage: (chain_index, backing file name). Rewritten
    /// by streaming/placement via [`Image::update_header`].
    link: RwLock<(u16, Option<String>)>,
    /// L1 table mirrored in RAM ("with its small size, the entire content
    /// of L1 is loaded in RAM at VM boot time", §2).
    l1: RwLock<Vec<u64>>,
    alloc: Mutex<Allocator>,
    data_mode: DataMode,
    /// Seed for synthetic data generation (per-file, deterministic).
    seed: u64,
    /// Generation of the on-disk header (see [`Header::slot_offset`]):
    /// each rewrite bumps it and lands in the other slot, making header
    /// updates old-valid-or-new-valid under any crash.
    hdr_gen: AtomicU32,
    /// Packing cursor for compressed payloads: (host cluster offset,
    /// bytes used). `(0, 0)` = no open packing cluster (offset 0 is the
    /// header, never a payload cluster). Session-local: a reopen starts
    /// a fresh packing cluster, the old one keeps its payload refcounts.
    comp_cursor: Mutex<(u64, u64)>,
}

impl Image {
    /// Create a fresh image on `backend`.
    pub fn create(
        name: &str,
        backend: BackendRef,
        geom: Geometry,
        flags: u32,
        chain_index: u16,
        backing_name: Option<&str>,
        data_mode: DataMode,
    ) -> Result<Image> {
        let header = Header {
            geom,
            flags,
            chain_index,
            backing_name: backing_name.map(str::to_string),
            generation: 0,
        };
        let enc = header.encode();
        if enc.len() > HEADER_SLOT_SIZE {
            bail!("backing file name does not fit a header slot");
        }
        backend.write_at(&enc, 0)?;
        backend.truncate_to(geom.first_free_cluster() * geom.cluster_size())?;
        let mut alloc = Allocator::new(&geom);
        // account the fixed metadata region in the refcounts
        for c in 0..geom.first_free_cluster() {
            alloc_set_one(&mut alloc, &geom, backend.as_ref(), c)?;
        }
        // barrier: the image must be fully formed before its creation is
        // acknowledged (a crash before this point leaves an orphan file
        // recovery can safely delete, never a half-valid image in a chain)
        backend.flush()?;
        let l1 = vec![0u64; geom.l1_entries() as usize];
        Ok(Image {
            name: name.to_string(),
            backend,
            geom: header.geom,
            flags: AtomicU32::new(header.flags),
            link: RwLock::new((header.chain_index, header.backing_name)),
            l1: RwLock::new(l1),
            alloc: Mutex::new(alloc),
            data_mode,
            seed: fxhash(name.as_bytes()),
            hdr_gen: AtomicU32::new(0),
            comp_cursor: Mutex::new((0, 0)),
        })
    }

    /// Open an existing image, loading the header (newest valid slot)
    /// and the L1 table.
    pub fn open(name: &str, backend: BackendRef, data_mode: DataMode) -> Result<Image> {
        let mut hdr_buf = vec![0u8; 2 * HEADER_SLOT_SIZE];
        backend.read_at(&mut hdr_buf, 0)?;
        let header = Header::decode_slots(&hdr_buf).context("decode header")?;
        let geom = header.geom;
        let mut l1_raw = vec![0u8; (geom.l1_entries() * ENTRY_SIZE) as usize];
        backend.read_at(&mut l1_raw, geom.l1_offset())?;
        let l1: Vec<u64> = l1_raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let alloc = Allocator::from_file(&geom, backend.as_ref())?;
        Ok(Image {
            name: name.to_string(),
            backend,
            geom: header.geom,
            flags: AtomicU32::new(header.flags),
            link: RwLock::new((header.chain_index, header.backing_name)),
            l1: RwLock::new(l1),
            alloc: Mutex::new(alloc),
            data_mode,
            seed: fxhash(name.as_bytes()),
            hdr_gen: AtomicU32::new(header.generation),
            comp_cursor: Mutex::new((0, 0)),
        })
    }

    // ------------------------------------------------------ introspection

    pub fn geom(&self) -> &Geometry {
        &self.geom
    }

    pub fn flags(&self) -> u32 {
        self.flags.load(Ordering::Relaxed)
    }

    /// Does this image carry §5.2 backing_file_index stamps?
    pub fn has_bfi(&self) -> bool {
        self.flags() & FEATURE_BFI != 0
    }

    /// This file's position in its chain (0 = base image).
    pub fn chain_index(&self) -> u16 {
        self.link.read().unwrap().0
    }

    pub fn backing_name(&self) -> Option<String> {
        self.link.read().unwrap().1.clone()
    }

    pub fn data_mode(&self) -> DataMode {
        self.data_mode
    }

    pub fn backend(&self) -> &BackendRef {
        &self.backend
    }

    /// Physical file size in bytes (Fig 19a disk-usage accounting).
    pub fn file_len(&self) -> u64 {
        self.backend.len()
    }

    /// Host offset of the L2 table for `l1_idx`, 0 if absent.
    pub fn l1_entry(&self, l1_idx: u64) -> u64 {
        self.l1.read().unwrap()[l1_idx as usize]
    }

    /// In-RAM bytes of the L1 mirror (memory accounting).
    pub fn l1_bytes(&self) -> u64 {
        self.geom.l1_entries() * ENTRY_SIZE
    }

    // ------------------------------------------------------------- L2 ops

    /// Get the L2 table offset for `l1_idx`, allocating (and zeroing) the
    /// table on demand.
    pub fn ensure_l2(&self, l1_idx: u64) -> Result<u64> {
        if let off @ 1.. = self.l1_entry(l1_idx) {
            return Ok(off);
        }
        let geom = self.geom;
        let mut alloc = self.alloc.lock().unwrap();
        // re-check under the lock
        let existing = self.l1.read().unwrap()[l1_idx as usize];
        if existing != 0 {
            return Ok(existing);
        }
        let (off, reused) = alloc.alloc_tracked(&geom, self.backend.as_ref())?;
        if reused {
            let zeros = vec![0u8; geom.cluster_size() as usize];
            self.backend.write_at(&zeros, off)?;
        }
        write_u64(
            self.backend.as_ref(),
            geom.l1_offset() + l1_idx * ENTRY_SIZE,
            off,
        )?;
        self.l1.write().unwrap()[l1_idx as usize] = off;
        Ok(off)
    }

    /// Read one raw L2 slice (`len` entries starting at entry
    /// `slice_start` of the table at `l2_off`). One device I/O — this is
    /// the cache-miss fetch ("Qemu brings into the cache a slice", §2).
    pub fn read_l2_slice(&self, l2_off: u64, slice_start: u64, len: u64) -> Result<Vec<u64>> {
        let (mut raw, mut out) = (Vec::new(), Vec::new());
        self.read_l2_slice_into(l2_off, slice_start, len, &mut raw, &mut out)?;
        Ok(out)
    }

    /// Allocation-free variant of [`Image::read_l2_slice`]: decodes into
    /// caller-owned scratch buffers (§Perf: the drivers' miss path reuses
    /// one scratch pair across all fetches instead of allocating twice
    /// per miss).
    pub fn read_l2_slice_into(
        &self,
        l2_off: u64,
        slice_start: u64,
        len: u64,
        raw: &mut Vec<u8>,
        out: &mut Vec<u64>,
    ) -> Result<()> {
        raw.clear();
        raw.resize((len * ENTRY_SIZE) as usize, 0);
        self.backend
            .read_at(raw, l2_off + slice_start * ENTRY_SIZE)?;
        out.clear();
        out.extend(
            raw.chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap())),
        );
        Ok(())
    }

    /// Write back a dirty slice (cache eviction / VM shutdown, §2).
    pub fn write_l2_slice(&self, l2_off: u64, slice_start: u64, entries: &[u64]) -> Result<()> {
        let mut raw = Vec::with_capacity(entries.len() * 8);
        for e in entries {
            raw.extend_from_slice(&e.to_le_bytes());
        }
        self.backend.write_at(&raw, l2_off + slice_start * ENTRY_SIZE)
    }

    /// Uncached single-entry read (snapshot machinery, qcheck, tools —
    /// NOT the request path, which goes through the caches).
    pub fn l2_entry(&self, vcluster: u64) -> Result<L2Entry> {
        let (l1_idx, l2_idx) = self.geom.split_vcluster(vcluster);
        let l2_off = self.l1_entry(l1_idx);
        if l2_off == 0 {
            return Ok(L2Entry::ZERO);
        }
        Ok(L2Entry(read_u64(
            self.backend.as_ref(),
            l2_off + l2_idx * ENTRY_SIZE,
        )?))
    }

    /// Uncached single-entry write; allocates the L2 table on demand.
    pub fn set_l2_entry(&self, vcluster: u64, entry: L2Entry) -> Result<()> {
        let (l1_idx, l2_idx) = self.geom.split_vcluster(vcluster);
        let l2_off = self.ensure_l2(l1_idx)?;
        write_u64(
            self.backend.as_ref(),
            l2_off + l2_idx * ENTRY_SIZE,
            entry.raw(),
        )
    }

    // ------------------------------------------------------- data cluster

    /// Allocate a data cluster; returns its host byte offset, zeroed if it
    /// was reused.
    pub fn alloc_data_cluster(&self) -> Result<u64> {
        let geom = self.geom;
        let mut alloc = self.alloc.lock().unwrap();
        let (off, reused) = alloc.alloc_tracked(&geom, self.backend.as_ref())?;
        if reused && self.data_mode == DataMode::Real {
            let zeros = vec![0u8; geom.cluster_size() as usize];
            self.backend.write_at(&zeros, off)?;
        }
        Ok(off)
    }

    /// Free a data or metadata cluster (streaming/merge reclaims).
    pub fn free_cluster(&self, off: u64) -> Result<()> {
        self.alloc
            .lock()
            .unwrap()
            .free(&self.geom, self.backend.as_ref(), off)
    }

    /// On-disk refcount of the cluster containing `off` (the dedup
    /// shared-cluster copy-on-write guard: refcount > 1 means another L2
    /// entry references the same bytes, so in-place writes must CoW).
    pub fn cluster_refcount(&self, off: u64) -> Result<u16> {
        let geom = self.geom;
        self.alloc.lock().unwrap().refcount(
            &geom,
            self.backend.as_ref(),
            off / geom.cluster_size(),
        )
    }

    /// Share the cluster containing `off` with one more L2 entry
    /// (intra-file dedup): +1 refcount, refcount-before-reference order.
    pub fn incref_cluster(&self, off: u64) -> Result<()> {
        let geom = self.geom;
        self.alloc
            .lock()
            .unwrap()
            .incref(&geom, self.backend.as_ref(), off)
    }

    // ------------------------------------------------ compressed clusters

    /// Bytes per compressed-size unit (`cluster_size / 128`, matching
    /// the 7-bit size field of the L2 descriptor).
    pub fn comp_unit(&self) -> u64 {
        self.geom.cluster_size() >> 7
    }

    /// Compress and store one full cluster. Returns the L2 offset word
    /// (`payload_off | OFLAG_COMPRESSED | size`) or `None` when the data
    /// does not shrink. `Real` mode only — synthetic data is generated,
    /// not stored, so it cannot round-trip through a codec.
    ///
    /// Payloads are packed into shared "compressed host clusters" at
    /// sector alignment; the containing cluster's refcount equals the
    /// number of payloads (plus dedup sharers) inside, so reclaim is
    /// gated exactly like any shared cluster.
    pub fn write_compressed(&self, data: &[u8]) -> Result<Option<u64>> {
        debug_assert_eq!(data.len() as u64, self.geom.cluster_size());
        if self.data_mode != DataMode::Real {
            return Ok(None);
        }
        let Some(framed) = codec::try_compress(data) else {
            return Ok(None);
        };
        let unit = self.comp_unit();
        let units = div_ceil(framed.len() as u64, unit);
        debug_assert!(units >= 1 && units <= 128);
        let stored = units * unit;
        let off = self.alloc_compressed(stored)?;
        let mut padded = framed;
        padded.resize(stored as usize, 0);
        // one device write of the *compressed* bytes (Timed bills these)
        self.backend.write_at(&padded, off)?;
        Ok(Some(L2Entry::compressed(off, units, None).host_offset()))
    }

    /// Read a compressed cluster: ONE device I/O of the stored
    /// (unit-rounded) payload, then decode into the full-cluster `out`.
    /// The caller models the decompress CPU cost on its clock.
    pub fn read_compressed(&self, data_off: u64, units: u64, out: &mut [u8]) -> Result<()> {
        debug_assert_eq!(out.len() as u64, self.geom.cluster_size());
        if self.data_mode != DataMode::Real {
            bail!("compressed clusters require Real data mode");
        }
        let stored = units * self.comp_unit();
        let mut payload = vec![0u8; stored as usize];
        self.backend.read_at(&mut payload, data_off)?;
        codec::decode_framed(&payload, out)
    }

    /// Drop one payload reference on the compressed host cluster
    /// containing `data_off`; the cluster returns to the free list when
    /// its last payload (or dedup sharer) is released.
    pub fn free_compressed(&self, data_off: u64) -> Result<()> {
        let geom = self.geom;
        let cs = geom.cluster_size();
        let coff = data_off / cs * cs;
        let mut alloc = self.alloc.lock().unwrap();
        let mut cursor = self.comp_cursor.lock().unwrap();
        alloc.free(&geom, self.backend.as_ref(), coff)?;
        if cursor.0 == coff
            && alloc.refcount(&geom, self.backend.as_ref(), coff / cs)? == 0
        {
            // the open packing cluster was fully reclaimed: stop packing
            // into it before the allocator hands it out again
            *cursor = (0, 0);
        }
        Ok(())
    }

    /// Reserve `stored` sector-aligned bytes for one compressed payload,
    /// packing into the current compressed host cluster when it fits.
    fn alloc_compressed(&self, stored: u64) -> Result<u64> {
        let geom = self.geom;
        let cs = geom.cluster_size();
        let slot = div_ceil(stored, PAYLOAD_ALIGN) * PAYLOAD_ALIGN;
        debug_assert!(slot <= cs);
        let mut alloc = self.alloc.lock().unwrap();
        let mut cursor = self.comp_cursor.lock().unwrap();
        if cursor.0 != 0 && cursor.1 + slot <= cs {
            let off = cursor.0 + cursor.1;
            cursor.1 += slot;
            // refcount-before-reference: one count per payload
            alloc.incref(&geom, self.backend.as_ref(), cursor.0)?;
            return Ok(off);
        }
        let (coff, _reused) = alloc.alloc_tracked(&geom, self.backend.as_ref())?;
        *cursor = (coff, slot);
        Ok(coff)
    }

    /// Read guest data from `host_off` (+`within` bytes into the cluster).
    pub fn read_data(&self, host_off: u64, within: u64, buf: &mut [u8]) -> Result<()> {
        debug_assert!(within + buf.len() as u64 <= self.geom.cluster_size());
        match self.data_mode {
            DataMode::Real => self.backend.read_at(buf, host_off + within),
            DataMode::Synthetic => {
                self.backend.charge(host_off + within, buf.len() as u64);
                synth_fill(self.seed, host_off + within, buf);
                Ok(())
            }
        }
    }

    /// Read one physically contiguous run of guest data starting at
    /// absolute offset `run_off`, scattered into `bufs` in order: the
    /// vectored fast path. The run was coalesced by the driver across
    /// cluster boundaries, so it is billed as ONE device I/O (one seek
    /// plus bandwidth for the total bytes) regardless of how many
    /// clusters or destination buffers it spans.
    pub fn read_run_vectored(&self, run_off: u64, bufs: &mut [&mut [u8]]) -> Result<()> {
        match self.data_mode {
            DataMode::Real => {
                let mut iovs: Vec<(u64, &mut [u8])> = Vec::with_capacity(bufs.len());
                let mut off = run_off;
                for b in bufs.iter_mut() {
                    let dst: &mut [u8] = b;
                    let len = dst.len() as u64;
                    iovs.push((off, dst));
                    off += len;
                }
                self.backend.read_vectored(&mut iovs)
            }
            DataMode::Synthetic => {
                let total: u64 = bufs.iter().map(|b| b.len() as u64).sum();
                self.backend.charge(run_off, total);
                let mut off = run_off;
                for b in bufs.iter_mut() {
                    synth_fill(self.seed, off, b);
                    off += b.len() as u64;
                }
                Ok(())
            }
        }
    }

    /// Write guest data at `host_off` (+`within`).
    pub fn write_data(&self, host_off: u64, within: u64, data: &[u8]) -> Result<()> {
        debug_assert!(within + data.len() as u64 <= self.geom.cluster_size());
        match self.data_mode {
            DataMode::Real => self.backend.write_at(data, host_off + within),
            DataMode::Synthetic => {
                self.backend.charge(host_off + within, data.len() as u64);
                Ok(())
            }
        }
    }

    /// Expected synthetic content (test oracle for Synthetic mode).
    pub fn synth_expected(&self, host_off: u64, within: u64, buf: &mut [u8]) {
        synth_fill(self.seed, host_off + within, buf);
    }

    /// Rewrite the header with a new chain position / backing link
    /// (streaming and placement rebuild chains; §3's provider-made
    /// re-linking).
    pub fn update_header(
        &self,
        chain_index: u16,
        backing_name: Option<&str>,
    ) -> Result<()> {
        let mut link = self.link.write().unwrap();
        *link = (chain_index, backing_name.map(str::to_string));
        self.write_header_locked(&link)
    }

    /// Promote a vanilla image to the SQEMU format in place (live stamp
    /// job, §5.1's "vanilla disk images can be easily converted"): sets
    /// `FEATURE_BFI` in RAM and persists the header. The caller must
    /// have stamped the L2 tables first — after this, drivers treat the
    /// image's index as complete.
    pub fn set_feature_bfi(&self) -> Result<()> {
        let link = self.link.write().unwrap();
        self.flags.fetch_or(FEATURE_BFI, Ordering::Relaxed);
        self.write_header_locked(&link)
    }

    /// Rewrite the header from the current in-RAM state: write-new-then-
    /// flip. The new revision (generation + 1, checksummed) goes to the
    /// slot the current generation does NOT occupy, followed by a
    /// durability barrier; the opener picks the newest valid slot, so a
    /// crash anywhere in here leaves the header old-valid or new-valid,
    /// never garbage. The caller holds the `link` lock, serializing
    /// header writers.
    fn write_header_locked(&self, link: &(u16, Option<String>)) -> Result<()> {
        let generation = self.hdr_gen.load(Ordering::Relaxed).wrapping_add(1);
        let header = Header {
            geom: self.geom,
            flags: self.flags(),
            chain_index: link.0,
            backing_name: link.1.clone(),
            generation,
        };
        let enc = header.encode();
        if enc.len() > HEADER_SLOT_SIZE {
            bail!("backing file name does not fit a header slot");
        }
        self.backend.write_at(&enc, Header::slot_offset(generation))?;
        // the flip is durable before anything depends on the new header
        self.backend.flush()?;
        self.hdr_gen.store(generation, Ordering::Relaxed);
        Ok(())
    }

    // --------------------------------------------------- crash recovery

    /// Durability barrier on this image's file: everything written
    /// before the call survives a crash once it returns (the drivers'
    /// `flush` ends with this — the ack-vs-durable line of DESIGN.md §10).
    pub fn flush(&self) -> Result<()> {
        self.backend.flush()
    }

    /// Clear a dangling L1 pointer (repair only): zeroes the on-disk
    /// entry and the RAM mirror together.
    pub fn clear_l1_entry(&self, l1_idx: u64) -> Result<()> {
        write_u64(
            self.backend.as_ref(),
            self.geom.l1_offset() + l1_idx * ENTRY_SIZE,
            0,
        )?;
        self.l1.write().unwrap()[l1_idx as usize] = 0;
        Ok(())
    }

    /// Rebuild the in-RAM allocator from the on-disk refcounts — after
    /// `qcheck --repair` rewrote them, the bump pointer and free list
    /// must reflect the repaired state, not the pre-repair scan.
    pub fn reset_allocator(&self) -> Result<()> {
        let rebuilt = Allocator::from_file(&self.geom, self.backend.as_ref())?;
        *self.alloc.lock().unwrap() = rebuilt;
        Ok(())
    }
}

/// Mark one metadata cluster as allocated during image creation.
fn alloc_set_one(
    alloc: &mut Allocator,
    geom: &Geometry,
    backend: &dyn crate::storage::backend::Backend,
    cluster: u64,
) -> Result<()> {
    // incref from 0 -> 1 via the allocator's low-level path
    let off = cluster * geom.cluster_size();
    if alloc.refcount(geom, backend, cluster)? == 0 {
        alloc.incref(geom, backend, off)?;
    }
    Ok(())
}

/// FNV-1a — stable tiny hash for per-file synthetic seeds.
fn fxhash(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Deterministic bytes for synthetic data clusters: a cheap counter-mode
/// mix of (seed, absolute offset) so any sub-range is reproducible.
#[inline]
fn synth_word(seed: u64, word_idx: u64) -> u64 {
    let mut z = seed ^ word_idx.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

fn synth_fill(seed: u64, abs_off: u64, buf: &mut [u8]) {
    // aligned fast path (§Perf: most guest reads are 4 KiB-aligned; the
    // per-byte remainder handling cost ~20% of a warm synthetic read)
    if abs_off % 8 == 0 {
        let mut word_idx = abs_off / 8;
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&synth_word(seed, word_idx).to_le_bytes());
            word_idx += 1;
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = synth_word(seed, word_idx).to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
        return;
    }
    let mut i = 0usize;
    while i < buf.len() {
        let word_idx = (abs_off + i as u64) / 8;
        let bytes = synth_word(seed, word_idx).to_le_bytes();
        let in_word = ((abs_off + i as u64) % 8) as usize;
        let n = (8 - in_word).min(buf.len() - i);
        buf[i..i + n].copy_from_slice(&bytes[in_word..in_word + n]);
        i += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::mem::MemBackend;
    use std::sync::Arc;

    fn mem() -> BackendRef {
        Arc::new(MemBackend::new())
    }

    fn small_geom() -> Geometry {
        Geometry::new(16, 256 << 20).unwrap() // 256 MiB
    }

    #[test]
    fn create_open_roundtrip() {
        let b = mem();
        let img = Image::create(
            "base",
            Arc::clone(&b),
            small_geom(),
            FEATURE_BFI,
            0,
            None,
            DataMode::Real,
        )
        .unwrap();
        img.set_l2_entry(5, L2Entry::local(7 << 16, Some(0))).unwrap();
        drop(img);
        let img = Image::open("base", b, DataMode::Real).unwrap();
        assert!(img.has_bfi());
        assert_eq!(img.chain_index(), 0);
        assert_eq!(img.backing_name(), None);
        let e = img.l2_entry(5).unwrap();
        assert_eq!(e.host_offset(), 7 << 16);
        assert_eq!(e.bfi(), Some(0));
        assert_eq!(img.l2_entry(6).unwrap(), L2Entry::ZERO);
    }

    #[test]
    fn l2_allocated_on_demand() {
        let b = mem();
        let img =
            Image::create("a", b, small_geom(), 0, 0, None, DataMode::Real).unwrap();
        assert_eq!(img.l1_entry(0), 0);
        img.set_l2_entry(0, L2Entry::local(1 << 20, None)).unwrap();
        assert_ne!(img.l1_entry(0), 0);
    }

    #[test]
    fn data_roundtrip_real() {
        let b = mem();
        let img =
            Image::create("a", b, small_geom(), 0, 0, None, DataMode::Real).unwrap();
        let off = img.alloc_data_cluster().unwrap();
        img.write_data(off, 100, b"payload").unwrap();
        let mut buf = [0u8; 7];
        img.read_data(off, 100, &mut buf).unwrap();
        assert_eq!(&buf, b"payload");
    }

    #[test]
    fn synthetic_data_is_deterministic_and_unstored() {
        let b = mem();
        let before = b.len();
        let img = Image::create("s", Arc::clone(&b), small_geom(), 0, 0, None, DataMode::Synthetic)
            .unwrap();
        let off = img.alloc_data_cluster().unwrap();
        img.write_data(off, 0, &[1u8; 4096]).unwrap();
        let mut r1 = [0u8; 64];
        let mut r2 = [0u8; 64];
        img.read_data(off, 32, &mut r1).unwrap();
        img.read_data(off, 32, &mut r2).unwrap();
        assert_eq!(r1, r2);
        assert_ne!(r1, [0u8; 64]);
        // sub-range consistency with a larger read
        let mut big = [0u8; 128];
        img.read_data(off, 0, &mut big).unwrap();
        assert_eq!(&big[32..96], &r1);
        let _ = before;
    }

    #[test]
    fn slice_read_write() {
        let b = mem();
        let img =
            Image::create("a", b, small_geom(), 0, 0, None, DataMode::Real).unwrap();
        let l2_off = img.ensure_l2(0).unwrap();
        let entries: Vec<u64> = (0..32).map(|i| L2Entry::local(i << 16, None).raw()).collect();
        img.write_l2_slice(l2_off, 64, &entries).unwrap();
        let back = img.read_l2_slice(l2_off, 64, 32).unwrap();
        assert_eq!(back, entries);
        // other slices still zero
        let zeros = img.read_l2_slice(l2_off, 0, 32).unwrap();
        assert!(zeros.iter().all(|&e| e == 0));
    }

    #[test]
    fn backing_name_roundtrip() {
        let b = mem();
        Image::create(
            "child",
            Arc::clone(&b),
            small_geom(),
            0,
            3,
            Some("parent-file"),
            DataMode::Real,
        )
        .unwrap();
        let img = Image::open("child", b, DataMode::Real).unwrap();
        assert_eq!(img.backing_name().as_deref(), Some("parent-file"));
        assert_eq!(img.chain_index(), 3);
    }

    #[test]
    fn compressed_payloads_pack_and_roundtrip() {
        use crate::qcow::entry::ClusterLoc;
        let b = mem();
        let img =
            Image::create("c", b, small_geom(), 0, 0, None, DataMode::Real).unwrap();
        let cs = img.geom().cluster_size() as usize;
        let mut d1 = vec![0u8; cs];
        d1[..1000].fill(7);
        let mut d2 = vec![9u8; cs];
        d2[100] = 1;
        let w1 = img.write_compressed(&d1).unwrap().expect("compressible");
        let w2 = img.write_compressed(&d2).unwrap().expect("compressible");
        let (e1, e2) = (L2Entry::local(w1, None), L2Entry::local(w2, None));
        assert!(e1.is_compressed() && e2.is_compressed());
        // both payloads packed into ONE host cluster, refcount = payloads
        assert_eq!(e1.data_offset() / cs as u64, e2.data_offset() / cs as u64);
        assert_eq!(img.cluster_refcount(e1.data_offset()).unwrap(), 2);
        for (e, d) in [(e1, &d1), (e2, &d2)] {
            let ClusterLoc::Compressed { off, units } = e.loc() else {
                panic!("not compressed: {e:?}")
            };
            let mut out = vec![0xAAu8; cs];
            img.read_compressed(off, units, &mut out).unwrap();
            assert_eq!(&out, d, "bit-identical after decode");
        }
        // freeing payload references returns the cluster at zero
        img.free_compressed(e1.data_offset()).unwrap();
        assert_eq!(img.cluster_refcount(e2.data_offset()).unwrap(), 1);
        img.free_compressed(e2.data_offset()).unwrap();
        assert_eq!(img.cluster_refcount(e2.data_offset()).unwrap(), 0);
        // incompressible data is stored uncompressed (None)
        let noise: Vec<u8> = (0..cs as u64)
            .map(|i| (i.wrapping_mul(0x9E3779B97F4A7C15) >> 56) as u8)
            .collect();
        assert!(img.write_compressed(&noise).unwrap().is_none());
    }

    #[test]
    fn alloc_after_reopen_does_not_clobber() {
        let b = mem();
        let img = Image::create("a", Arc::clone(&b), small_geom(), 0, 0, None, DataMode::Real)
            .unwrap();
        let off1 = img.alloc_data_cluster().unwrap();
        img.write_data(off1, 0, b"keep me").unwrap();
        drop(img);
        let img = Image::open("a", b, DataMode::Real).unwrap();
        let off2 = img.alloc_data_cluster().unwrap();
        assert_ne!(off1, off2);
        let mut buf = [0u8; 7];
        img.read_data(off1, 0, &mut buf).unwrap();
        assert_eq!(&buf, b"keep me");
    }
}
