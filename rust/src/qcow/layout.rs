//! On-disk layout: header encoding and file geometry.

use crate::util::div_ceil;
use anyhow::{bail, Result};

/// Magic at offset 0: "SQRW" (SQemu ReWrite).
pub const MAGIC: u32 = 0x5351_5257;
/// v2 added the crash-consistent header: a generation counter and a
/// checksum, written alternately to one of two slots in cluster 0
/// (write-new-then-flip — the generation IS the flip).
pub const VERSION: u32 = 2;

/// Each header revision occupies one fixed-size slot; slot A at offset 0,
/// slot B at [`HEADER_SLOT_B`]. Both fit the minimum cluster (512 B), so
/// the pair always lives inside cluster 0 regardless of geometry. A
/// header (fixed fields + backing name) must fit one slot.
pub const HEADER_SLOT_SIZE: usize = 256;
pub const HEADER_SLOT_B: u64 = HEADER_SLOT_SIZE as u64;

/// Header feature flag: L2 entries carry `backing_file_index` stamps
/// (the §5.2 format extension). A vanilla driver ignores this flag.
pub const FEATURE_BFI: u32 = 1 << 0;

/// Default cluster size: 64 KiB (Qcow2 default, §2).
pub const DEFAULT_CLUSTER_BITS: u32 = 16;

/// Bytes per L2/L1 table entry.
pub const ENTRY_SIZE: u64 = 8;

/// Fixed header field block size (before the backing-file name).
const HEADER_FIXED: usize = 64;

/// File geometry, fully determined by (cluster_bits, virtual_size).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Geometry {
    pub cluster_bits: u32,
    pub virtual_size: u64,
}

impl Geometry {
    pub fn new(cluster_bits: u32, virtual_size: u64) -> Result<Self> {
        if !(9..=21).contains(&cluster_bits) {
            bail!("cluster_bits {cluster_bits} out of range [9, 21]");
        }
        if virtual_size == 0 {
            bail!("virtual size must be > 0");
        }
        Ok(Geometry { cluster_bits, virtual_size })
    }

    pub fn cluster_size(&self) -> u64 {
        1 << self.cluster_bits
    }

    /// L2 entries per L2 table (one table = one cluster).
    pub fn entries_per_l2(&self) -> u64 {
        self.cluster_size() / ENTRY_SIZE
    }

    /// Number of virtual clusters addressed by the disk.
    pub fn num_vclusters(&self) -> u64 {
        div_ceil(self.virtual_size, self.cluster_size())
    }

    /// Number of L1 entries (= max number of L2 tables).
    pub fn l1_entries(&self) -> u64 {
        div_ceil(self.num_vclusters(), self.entries_per_l2())
    }

    /// Clusters occupied by the contiguous L1 region.
    pub fn l1_clusters(&self) -> u64 {
        div_ceil(self.l1_entries() * ENTRY_SIZE, self.cluster_size()).max(1)
    }

    /// L1 starts right after the header (§2: "the L1 table comes right
    /// after the header").
    pub fn l1_offset(&self) -> u64 {
        self.cluster_size()
    }

    /// Refcount table offset (right after L1, preallocated).
    pub fn reftable_offset(&self) -> u64 {
        (1 + self.l1_clusters()) * self.cluster_size()
    }

    /// Host clusters coverable per refcount block (u16 refcounts).
    pub fn refcounts_per_block(&self) -> u64 {
        self.cluster_size() / 2
    }

    /// Preallocated refcount-table clusters: sized for the worst case of
    /// every virtual cluster allocated twice over (data + metadata slack).
    pub fn reftable_clusters(&self) -> u64 {
        let max_host_clusters =
            2 * self.num_vclusters() + 2 * self.l1_entries() + 1024;
        let blocks = div_ceil(max_host_clusters, self.refcounts_per_block());
        div_ceil(blocks * ENTRY_SIZE, self.cluster_size()).max(1)
    }

    /// First cluster free for on-demand allocation.
    pub fn first_free_cluster(&self) -> u64 {
        1 + self.l1_clusters() + self.reftable_clusters()
    }

    /// Decompose a virtual cluster index into (l1_index, l2_index).
    pub fn split_vcluster(&self, vcluster: u64) -> (u64, u64) {
        (vcluster / self.entries_per_l2(), vcluster % self.entries_per_l2())
    }

    /// Virtual byte offset -> (vcluster, offset within cluster).
    pub fn split_voffset(&self, voff: u64) -> (u64, u64) {
        (voff >> self.cluster_bits, voff & (self.cluster_size() - 1))
    }
}

/// Parsed image header (cluster 0).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Header {
    pub geom: Geometry,
    pub flags: u32,
    /// This file's position in its chain (0 = base image). Stored so the
    /// SQEMU driver can stamp entries it allocates.
    pub chain_index: u16,
    pub backing_name: Option<String>,
    /// Monotonic revision counter: each header rewrite bumps it and
    /// lands in the *other* slot, so a torn rewrite leaves the previous
    /// revision untouched and the opener picks the newest valid slot.
    pub generation: u32,
}

/// FNV-1a over the encoded header with the checksum field zeroed — the
/// validity proof of one slot (a torn slot write fails it).
fn header_checksum(buf: &[u8]) -> u32 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for (i, &b) in buf.iter().enumerate() {
        let b = if (60..64).contains(&i) { 0 } else { b };
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    (h ^ (h >> 32)) as u32
}

impl Header {
    pub fn encode(&self) -> Vec<u8> {
        let name = self.backing_name.as_deref().unwrap_or("");
        let mut buf = vec![0u8; HEADER_FIXED + name.len()];
        buf[0..4].copy_from_slice(&MAGIC.to_le_bytes());
        buf[4..8].copy_from_slice(&VERSION.to_le_bytes());
        buf[8..12].copy_from_slice(&self.geom.cluster_bits.to_le_bytes());
        buf[12..16].copy_from_slice(&self.flags.to_le_bytes());
        buf[16..24].copy_from_slice(&self.geom.virtual_size.to_le_bytes());
        buf[24..32].copy_from_slice(&self.geom.l1_offset().to_le_bytes());
        buf[32..36].copy_from_slice(&(self.geom.l1_entries() as u32).to_le_bytes());
        buf[36..38].copy_from_slice(&self.chain_index.to_le_bytes());
        buf[40..48].copy_from_slice(&self.geom.reftable_offset().to_le_bytes());
        buf[48..52]
            .copy_from_slice(&(self.geom.reftable_clusters() as u32).to_le_bytes());
        buf[52..56].copy_from_slice(&(name.len() as u32).to_le_bytes());
        buf[56..60].copy_from_slice(&self.generation.to_le_bytes());
        buf[HEADER_FIXED..].copy_from_slice(name.as_bytes());
        let ck = header_checksum(&buf);
        buf[60..64].copy_from_slice(&ck.to_le_bytes());
        buf
    }

    pub fn decode(buf: &[u8]) -> Result<Header> {
        if buf.len() < HEADER_FIXED {
            bail!("header too short");
        }
        let rd32 = |o: usize| u32::from_le_bytes(buf[o..o + 4].try_into().unwrap());
        let rd64 = |o: usize| u64::from_le_bytes(buf[o..o + 8].try_into().unwrap());
        if rd32(0) != MAGIC {
            bail!("bad magic {:#x}", rd32(0));
        }
        if rd32(4) != VERSION {
            bail!(
                "unsupported header version {} (v1 images predate the \
                 crash-consistent checksummed header and are not readable \
                 by this build)",
                rd32(4)
            );
        }
        let name_len = rd32(52) as usize;
        if HEADER_FIXED + name_len > buf.len() {
            bail!("backing name overruns header slot");
        }
        // the checksum covers the exact encoded bytes (fixed + name); a
        // torn or stale slot fails here before anything is trusted
        if header_checksum(&buf[..HEADER_FIXED + name_len]) != rd32(60) {
            bail!("header checksum mismatch (torn or stale slot)");
        }
        let geom = Geometry::new(rd32(8), rd64(16))?;
        // sanity: stored derived fields must match the geometry
        if rd64(24) != geom.l1_offset() || rd64(40) != geom.reftable_offset() {
            bail!("header geometry mismatch (corrupt image?)");
        }
        let flags = rd32(12);
        let chain_index = u16::from_le_bytes(buf[36..38].try_into().unwrap());
        let generation = rd32(56);
        let backing_name = if name_len == 0 {
            None
        } else {
            Some(
                std::str::from_utf8(&buf[HEADER_FIXED..HEADER_FIXED + name_len])?
                    .to_string(),
            )
        };
        Ok(Header { geom, flags, chain_index, backing_name, generation })
    }

    /// Decode the newest valid header of a buffer holding both slots
    /// (≥ 2 × [`HEADER_SLOT_SIZE`] bytes): each slot is validated
    /// independently and the highest valid generation wins — the
    /// read side of write-new-then-flip.
    pub fn decode_slots(buf: &[u8]) -> Result<Header> {
        if buf.len() < 2 * HEADER_SLOT_SIZE {
            bail!("header region too short for both slots");
        }
        let a = Header::decode(&buf[..HEADER_SLOT_SIZE]);
        let b = Header::decode(&buf[HEADER_SLOT_SIZE..2 * HEADER_SLOT_SIZE]);
        match (a, b) {
            (Ok(a), Ok(b)) => Ok(if b.generation > a.generation { b } else { a }),
            (Ok(a), Err(_)) => Ok(a),
            (Err(_), Ok(b)) => Ok(b),
            (Err(ea), Err(_)) => Err(ea.context("no valid header slot")),
        }
    }

    /// The slot byte offset a given generation is written to: even
    /// generations live in slot A, odd in slot B, so consecutive
    /// revisions never overwrite each other.
    pub fn slot_offset(generation: u32) -> u64 {
        if generation % 2 == 0 {
            0
        } else {
            HEADER_SLOT_B
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_50g_default() {
        // the paper's dominant disk size (take-away 1): 50 GiB
        let g = Geometry::new(DEFAULT_CLUSTER_BITS, 50 << 30).unwrap();
        assert_eq!(g.cluster_size(), 64 << 10);
        assert_eq!(g.entries_per_l2(), 8192);
        assert_eq!(g.num_vclusters(), 819_200);
        assert_eq!(g.l1_entries(), 100);
        assert_eq!(g.l1_clusters(), 1);
        // total L2 metadata to index the full disk: 100 tables * 64 KiB
        // = 6.25 MiB (the paper's full-disk cache size for 50 GiB, §6.1)
        assert_eq!(g.l1_entries() * g.cluster_size(), 6_553_600);
    }

    #[test]
    fn geometry_bounds() {
        assert!(Geometry::new(8, 1 << 20).is_err());
        assert!(Geometry::new(22, 1 << 20).is_err());
        assert!(Geometry::new(16, 0).is_err());
    }

    #[test]
    fn split_roundtrip() {
        let g = Geometry::new(16, 1 << 30).unwrap();
        let (l1, l2) = g.split_vcluster(8192 + 5);
        assert_eq!((l1, l2), (1, 5));
        let (vc, within) = g.split_voffset((8192 + 5) * 65536 + 123);
        assert_eq!(vc, 8192 + 5);
        assert_eq!(within, 123);
    }

    #[test]
    fn header_roundtrip() {
        let h = Header {
            geom: Geometry::new(16, 20 << 30).unwrap(),
            flags: FEATURE_BFI,
            chain_index: 42,
            backing_name: Some("snap-41".into()),
            generation: 7,
        };
        let enc = h.encode();
        let dec = Header::decode(&enc).unwrap();
        assert_eq!(h, dec);
    }

    #[test]
    fn header_no_backing() {
        let h = Header {
            geom: Geometry::new(16, 1 << 30).unwrap(),
            flags: 0,
            chain_index: 0,
            backing_name: None,
            generation: 0,
        };
        assert_eq!(Header::decode(&h.encode()).unwrap(), h);
    }

    #[test]
    fn header_rejects_garbage() {
        assert!(Header::decode(&[0u8; 64]).is_err());
        let h = Header {
            geom: Geometry::new(16, 1 << 30).unwrap(),
            flags: 0,
            chain_index: 0,
            backing_name: None,
            generation: 0,
        };
        let mut enc = h.encode();
        enc[24] ^= 0xff; // corrupt stored l1_offset
        assert!(Header::decode(&enc).is_err());
    }

    #[test]
    fn checksum_catches_any_single_byte_tear() {
        let h = Header {
            geom: Geometry::new(16, 1 << 30).unwrap(),
            flags: FEATURE_BFI,
            chain_index: 3,
            backing_name: Some("base".into()),
            generation: 5,
        };
        let enc = h.encode();
        for i in 0..enc.len() {
            let mut torn = enc.clone();
            torn[i] ^= 0x5A;
            assert!(
                Header::decode(&torn).is_err(),
                "byte {i} corruption accepted"
            );
        }
    }

    #[test]
    fn decode_slots_picks_newest_valid_generation() {
        let geom = Geometry::new(16, 1 << 30).unwrap();
        let old = Header {
            geom,
            flags: 0,
            chain_index: 1,
            backing_name: Some("old".into()),
            generation: 4,
        };
        let new = Header {
            geom,
            flags: FEATURE_BFI,
            chain_index: 1,
            backing_name: Some("new".into()),
            generation: 5,
        };
        let mut buf = vec![0u8; 2 * HEADER_SLOT_SIZE];
        let (eo, en) = (old.encode(), new.encode());
        buf[..eo.len()].copy_from_slice(&eo); // gen 4 -> slot A
        buf[HEADER_SLOT_SIZE..HEADER_SLOT_SIZE + en.len()].copy_from_slice(&en);
        assert_eq!(Header::decode_slots(&buf).unwrap(), new);
        // tear the newer slot: the opener falls back to the old header
        buf[HEADER_SLOT_SIZE + 20] ^= 0xFF;
        assert_eq!(Header::decode_slots(&buf).unwrap(), old);
        // both torn: unopenable, never garbage
        buf[10] ^= 0xFF;
        assert!(Header::decode_slots(&buf).is_err());
    }

    #[test]
    fn slot_alternates_by_generation() {
        assert_eq!(Header::slot_offset(0), 0);
        assert_eq!(Header::slot_offset(1), HEADER_SLOT_B);
        assert_eq!(Header::slot_offset(2), 0);
    }

    #[test]
    fn reftable_covers_allocations() {
        let g = Geometry::new(16, 50 << 30).unwrap();
        let coverable =
            g.reftable_clusters() * (g.cluster_size() / ENTRY_SIZE) * g.refcounts_per_block();
        assert!(coverable > 2 * g.num_vclusters());
    }
}
