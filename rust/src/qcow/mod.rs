//! The virtual-disk format substrate: a cluster-granular copy-on-write
//! format with external snapshot chains, modeled on Qcow2 (§2) plus the
//! paper's SQEMU extension (§5.2): a 16-bit `backing_file_index` stored in
//! reserved bits of each L2 entry, enabling direct access to the owning
//! backing file without walking the chain.
//!
//! Layout of one image file (see [`layout`]):
//!
//! ```text
//! cluster 0        header (magic, geometry, flags, backing-file name)
//! cluster 1..      L1 table, contiguous ("right after the header", §2)
//! next clusters    refcount table (preallocated, two-level)
//! remaining        L2 tables, refcount blocks and data clusters, allocated
//!                  on demand
//! ```
//!
//! Backward compatibility (§5.1–5.2): the extension only occupies formerly
//! reserved L2-entry bits and a header feature flag. A vanilla driver
//! ignores both and falls back to chain walking; the SQEMU driver detects
//! unstamped images and degrades the same way. `tests/compat.rs` verifies
//! both directions.

pub mod chain;
pub mod entry;
pub mod image;
pub mod layout;
pub mod qcheck;
pub mod refcount;
pub mod snapshot;

pub use chain::Chain;
pub use entry::L2Entry;
pub use image::{DataMode, Image};
pub use layout::{Geometry, Header, FEATURE_BFI};
