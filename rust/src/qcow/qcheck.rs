//! Image consistency checker — the `qemu-img check` analogue. Used by
//! integration tests after every mutating operation sequence, and exposed
//! through the CLI (`sqemu check`).

use super::chain::Chain;
use super::entry::L2Entry;
use super::image::Image;
use crate::util::div_ceil;
use anyhow::Result;
use std::collections::HashMap;

/// Outcome of checking one image.
#[derive(Clone, Debug, Default)]
pub struct CheckReport {
    /// Hard inconsistencies (corruption): misaligned/out-of-range offsets,
    /// reachable clusters with zero refcount, bad stamps.
    pub errors: Vec<String>,
    /// Clusters with a refcount but unreachable from any table (space
    /// leaks; tolerated, like `qemu-img check` leaks).
    pub leaked_clusters: u64,
    /// Reachable, correctly refcounted clusters.
    pub ok_clusters: u64,
}

impl CheckReport {
    pub fn is_clean(&self) -> bool {
        self.errors.is_empty()
    }
}

/// Check structural consistency of a single image.
pub fn check_image(img: &Image) -> Result<CheckReport> {
    let geom = *img.geom();
    let cs = geom.cluster_size();
    let file_len = img.file_len();
    let own = img.chain_index();
    let mut report = CheckReport::default();
    // expected refcounts: cluster index -> count
    let mut expected: HashMap<u64, u16> = HashMap::new();
    for c in 0..geom.first_free_cluster() {
        expected.insert(c, 1);
    }

    for l1_idx in 0..geom.l1_entries() {
        let l2_off = img.l1_entry(l1_idx);
        if l2_off == 0 {
            continue;
        }
        if l2_off % cs != 0 {
            report
                .errors
                .push(format!("L1[{l1_idx}] misaligned L2 offset {l2_off:#x}"));
            continue;
        }
        if l2_off >= file_len {
            report
                .errors
                .push(format!("L1[{l1_idx}] L2 offset {l2_off:#x} beyond EOF"));
            continue;
        }
        *expected.entry(l2_off / cs).or_default() += 1;
        let entries = img.read_l2_slice(l2_off, 0, geom.entries_per_l2())?;
        for (l2_idx, &raw) in entries.iter().enumerate() {
            let e = L2Entry(raw);
            if e.is_zero() {
                continue;
            }
            let off = e.host_offset();
            if off % cs != 0 {
                report.errors.push(format!(
                    "L2[{l1_idx}/{l2_idx}] misaligned data offset {off:#x}"
                ));
                continue;
            }
            match e.bfi() {
                Some(bfi) if e.is_allocated_here() && bfi != own => {
                    report.errors.push(format!(
                        "L2[{l1_idx}/{l2_idx}] local entry stamped {bfi} != own {own}"
                    ));
                }
                Some(bfi) if !e.is_allocated_here() && bfi >= own => {
                    report.errors.push(format!(
                        "L2[{l1_idx}/{l2_idx}] remote stamp {bfi} not below own {own}"
                    ));
                }
                _ => {}
            }
            if e.is_allocated_here() {
                if off >= file_len {
                    report.errors.push(format!(
                        "L2[{l1_idx}/{l2_idx}] data offset {off:#x} beyond EOF"
                    ));
                    continue;
                }
                *expected.entry(off / cs).or_default() += 1;
            }
        }
    }

    // refcount blocks are themselves refcounted
    let max_cluster = div_ceil(file_len, cs);
    let reftable =
        img.read_l2_slice(geom.reftable_offset(), 0, geom.reftable_clusters() * cs / 8)?;
    for &block_off in reftable.iter().filter(|&&o| o != 0) {
        if block_off % cs != 0 || block_off >= file_len {
            report
                .errors
                .push(format!("refcount block offset {block_off:#x} invalid"));
            continue;
        }
        *expected.entry(block_off / cs).or_default() += 1;
    }

    // compare expected vs stored refcounts
    for cluster in 0..max_cluster {
        let stored = stored_refcount(img, cluster)?;
        let exp = expected.get(&cluster).copied().unwrap_or(0);
        if stored == exp {
            if exp > 0 {
                report.ok_clusters += 1;
            }
        } else if stored > exp {
            // over-refcounted (or allocated but unreachable): a leak
            report.leaked_clusters += 1;
        } else {
            report.errors.push(format!(
                "cluster {cluster}: refcount {stored} < expected {exp}"
            ));
        }
    }
    Ok(report)
}

/// Check a whole chain: every image individually, plus cross-file stamp
/// validity (remote offsets must exist in the referenced file).
pub fn check_chain(chain: &Chain) -> Result<CheckReport> {
    let mut total = CheckReport::default();
    for (pos, img) in chain.images().iter().enumerate() {
        let r = check_image(img)?;
        total.errors.extend(
            r.errors
                .into_iter()
                .map(|e| format!("[{}] {e}", img.name)),
        );
        total.leaked_clusters += r.leaked_clusters;
        total.ok_clusters += r.ok_clusters;
        if img.chain_index() as usize != pos {
            total.errors.push(format!(
                "[{}] chain_index {} but position {pos}",
                img.name,
                img.chain_index()
            ));
        }
        // remote stamps must reference an existing cluster of the target
        if img.has_bfi() {
            let geom = *img.geom();
            for l1_idx in 0..geom.l1_entries() {
                let l2_off = img.l1_entry(l1_idx);
                if l2_off == 0 {
                    continue;
                }
                let entries = img.read_l2_slice(l2_off, 0, geom.entries_per_l2())?;
                for (l2_idx, &raw) in entries.iter().enumerate() {
                    let e = L2Entry(raw);
                    let Some(bfi) = e.bfi() else { continue };
                    if e.is_allocated_here() {
                        continue;
                    }
                    match chain.get(bfi) {
                        None => total.errors.push(format!(
                            "[{}] L2[{l1_idx}/{l2_idx}] stamp to missing file {bfi}",
                            img.name
                        )),
                        Some(owner) => {
                            if e.host_offset() >= owner.file_len() {
                                total.errors.push(format!(
                                    "[{}] L2[{l1_idx}/{l2_idx}] stamp offset beyond \
                                     '{}' EOF",
                                    img.name, owner.name
                                ));
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(total)
}

fn stored_refcount(img: &Image, cluster: u64) -> Result<u16> {
    let geom = *img.geom();
    let block_idx = cluster / geom.refcounts_per_block();
    let slot = geom.reftable_offset() + block_idx * 8;
    let block_off = crate::storage::backend::read_u64(img.backend().as_ref(), slot)?;
    if block_off == 0 {
        return Ok(0);
    }
    let idx = cluster % geom.refcounts_per_block();
    let mut b = [0u8; 2];
    img.backend().read_at(&mut b, block_off + idx * 2)?;
    Ok(u16::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clock::{CostModel, VirtClock};
    use crate::qcow::image::DataMode;
    use crate::qcow::layout::{Geometry, FEATURE_BFI};
    use crate::qcow::snapshot;
    use crate::storage::node::StorageNode;
    use std::sync::Arc;

    fn setup() -> (Arc<StorageNode>, Chain) {
        let node = StorageNode::new("s", VirtClock::new(), CostModel::default());
        let b = node.create_file("img-0").unwrap();
        let img = Image::create(
            "img-0",
            b,
            Geometry::new(16, 16 << 20).unwrap(),
            FEATURE_BFI,
            0,
            None,
            DataMode::Real,
        )
        .unwrap();
        let chain = Chain::new(Arc::new(img)).unwrap();
        (node, chain)
    }

    fn write_cluster(chain: &Chain, vc: u64) {
        let img = chain.active();
        let off = img.alloc_data_cluster().unwrap();
        img.write_data(off, 0, &[7u8; 16]).unwrap();
        img.set_l2_entry(vc, L2Entry::local(off, Some(img.chain_index())))
            .unwrap();
    }

    #[test]
    fn fresh_image_is_clean() {
        let (_n, chain) = setup();
        let r = check_image(chain.active()).unwrap();
        assert!(r.is_clean(), "{:?}", r.errors);
        assert!(r.ok_clusters >= 3); // header + L1 + reftable
    }

    #[test]
    fn populated_chain_is_clean() {
        let (node, mut chain) = setup();
        for vc in 0..10 {
            write_cluster(&chain, vc);
        }
        snapshot::snapshot_sqemu(&mut chain, &node, "img-1").unwrap();
        for vc in 5..15 {
            write_cluster(&chain, vc);
        }
        let r = check_chain(&chain).unwrap();
        assert!(r.is_clean(), "{:?}", r.errors);
    }

    #[test]
    fn detects_bad_stamp() {
        let (_n, chain) = setup();
        // a base image (own index 0) cannot hold remote stamps
        chain
            .active()
            .set_l2_entry(0, L2Entry::remote(1 << 16, 3))
            .unwrap();
        let r = check_chain(&chain).unwrap();
        assert!(!r.is_clean());
    }

    #[test]
    fn detects_misaligned_entry() {
        let (_n, chain) = setup();
        chain
            .active()
            .set_l2_entry(0, L2Entry::local((1 << 16) + 5, Some(0)))
            .unwrap();
        let r = check_image(chain.active()).unwrap();
        assert!(!r.is_clean());
    }

    #[test]
    fn stream_merge_leaves_clean_chain() {
        let (node, mut chain) = setup();
        write_cluster(&chain, 0);
        snapshot::snapshot_sqemu(&mut chain, &node, "img-1").unwrap();
        write_cluster(&chain, 1);
        snapshot::snapshot_sqemu(&mut chain, &node, "img-2").unwrap();
        write_cluster(&chain, 2);
        snapshot::snapshot_sqemu(&mut chain, &node, "img-3").unwrap();
        snapshot::stream_merge(&mut chain, 0, 2).unwrap();
        let r = check_chain(&chain).unwrap();
        assert!(r.is_clean(), "{:?}", r.errors);
    }
}
